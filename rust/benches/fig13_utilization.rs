//! `cargo bench --bench fig13_utilization` — regenerates the paper's fig13 utilization
//! series from the cycle-accurate simulator, and times the regeneration.

use nexus::coordinator::{self, report};
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("fig13_utilization", 3, || {
        let m = coordinator::run_matrix(1);
        out = report::fig13(&m);
    });
    println!("{out}");
}
