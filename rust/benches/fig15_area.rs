//! `cargo bench --bench fig15_area` — regenerates the paper's fig15 area
//! series from the cycle-accurate simulator, and times the regeneration.

use nexus::coordinator::report;
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("fig15_area", 10, || {
        out = report::fig15();
    });
    println!("{out}");
}
