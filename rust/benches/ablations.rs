//! `cargo bench --bench ablations` — design-choice ablations (en-route
//! execution, routing policy, buffer depth, AM window, Algorithm-1
//! placement) over the irregular suite.

use nexus::coordinator::ablation;
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("ablations", 2, || {
        out = ablation::report(1);
    });
    println!("{out}");
}
