//! `cargo bench --bench serve_throughput` — end-to-end service
//! throughput on a heavy-tailed request mix, plus a deliberate overload
//! burst to price backpressure.
//!
//! Phase 1 (throughput): an in-process server with the default worker
//! pool takes a corpus-drawn mix from 4 concurrent pipelining clients —
//! mostly smoke-size scenarios (the many-small mode of real batch
//! traffic), a minority of 8×8 hotspot/R-MAT runs, and a thin 16×16
//! tail. Seeds repeat, so the shared compile cache must show hits.
//!
//! Phase 2 (overload): a second server throttled to one worker and a
//! tiny queue receives a 64-request burst; the point measured is that
//! every request is *answered* — `ok + overloaded == sent`, rejections
//! are immediate, nothing is silently dropped.
//!
//! Emits `BENCH_SERVE.json` lines on stdout.

use nexus::serve::protocol::{parse_json, Json};
use nexus::serve::{Server, ServeOptions};
use nexus::util::json::JsonObj;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Instant;

/// The heavy-tailed scenario mix, weights chosen so ~80% of requests are
/// smoke-size, ~15% mid (8×8), ~5% heavy (16×16).
fn mix(i: usize) -> (&'static str, u64) {
    // Seeds cycle through a small set so repeats hit the compile cache.
    let seed = 1 + (i % 4) as u64;
    let name = match i % 20 {
        0..=7 => "smoke/spmv-uniform-d30-4x4",
        8..=11 => "smoke/spmv-hotspot-d30-4x4",
        12..=15 => "smoke/bfs-rmat-4x4",
        16 | 17 => "hotspot/spmv-rmat-d20-8x8",
        18 => "hotspot/spmv-hotspot-d20-8x8",
        _ => "hotspot/spmv-rmat-d6-16x16",
    };
    (name, seed)
}

/// Pipeline `requests` lines down one connection, return the response
/// lines (in order).
fn drive(addr: std::net::SocketAddr, requests: &[String]) -> Vec<String> {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let reader = BufReader::new(stream);
    for r in requests {
        writeln!(writer, "{r}").expect("write request");
    }
    writer.flush().expect("flush");
    let _ = writer.shutdown(std::net::Shutdown::Write);
    reader.lines().map(|l| l.expect("response line")).collect()
}

fn field_u64(v: &Json, key: &str) -> u64 {
    v.get(key).and_then(Json::as_u64).unwrap_or(0)
}

fn main() {
    // ---- Phase 1: sustained throughput on the heavy-tailed mix ----
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        queue_capacity: 512,
        ..ServeOptions::default()
    })
    .expect("bind");
    let addr = server.local_addr().expect("addr");
    let server_thread = thread::spawn(move || server.run().expect("serve"));

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 60;
    let started = Instant::now();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            thread::spawn(move || {
                let requests: Vec<String> = (0..PER_CLIENT)
                    .map(|i| {
                        let (name, seed) = mix(c * PER_CLIENT + i);
                        format!("{{\"scenario\":\"{name}\",\"seed\":{seed}}}")
                    })
                    .collect();
                drive(addr, &requests)
            })
        })
        .collect();
    let responses: Vec<String> = handles
        .into_iter()
        .flat_map(|h| h.join().expect("client"))
        .collect();
    let wall_s = started.elapsed().as_secs_f64();

    let total = CLIENTS * PER_CLIENT;
    let mut ok = 0usize;
    let mut cache_hits = 0usize;
    let mut exec_us_sum = 0u64;
    for line in &responses {
        let v = parse_json(line).expect("response must be JSON");
        match v.get("status").and_then(Json::as_str) {
            Some("ok") => {
                ok += 1;
                if v.get("cache").and_then(Json::as_str) == Some("hit") {
                    cache_hits += 1;
                }
                exec_us_sum += field_u64(&v, "exec_us");
            }
            other => panic!("phase 1 must not reject: {other:?} in {line}"),
        }
    }
    assert_eq!(ok, total, "every request answered ok");
    assert!(
        cache_hits > 0,
        "repeated (scenario, seed) pairs must hit the compile cache"
    );

    // Pull the server's own metrics before shutting it down.
    let metrics = drive(addr, &["GET /metrics".to_string(), "{\"cmd\":\"shutdown\"}".to_string()]);
    let m = parse_json(&metrics[0]).expect("metrics line");
    server_thread.join().expect("server thread");

    let hit_rate = m.get("cache_hit_rate").and_then(Json::as_f64).unwrap_or(0.0);
    let mut o = JsonObj::new();
    o.str("bench", "serve_throughput")
        .u64("clients", CLIENTS as u64)
        .u64("requests", total as u64)
        .u64("ok", ok as u64)
        .f64("wall_s", wall_s, 3)
        .f64("scenarios_per_sec", total as f64 / wall_s, 2)
        .f64("mean_exec_us", exec_us_sum as f64 / ok as f64, 1)
        .u64("client_cache_hits", cache_hits as u64)
        .u64("latency_p50_us", field_u64(&m, "latency_p50_us"))
        .u64("latency_p99_us", field_u64(&m, "latency_p99_us"))
        .u64("cache_hits", field_u64(&m, "cache_hits"))
        .u64("cache_misses", field_u64(&m, "cache_misses"))
        .f64("cache_hit_rate", hit_rate, 4);
    println!("BENCH_SERVE.json {}", o.build());

    // ---- Phase 2: overload burst against a throttled server ----
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_capacity: 8,
        ..ServeOptions::default()
    })
    .expect("bind burst server");
    let addr = server.local_addr().expect("addr");
    let server_thread = thread::spawn(move || server.run().expect("serve"));

    const BURST: usize = 64;
    let burst: Vec<String> = (0..BURST)
        .map(|i| format!("{{\"scenario\":\"hotspot/spmv-rmat-d20-8x8\",\"seed\":{}}}", 1 + i % 2))
        .collect();
    let started = Instant::now();
    let responses = drive(addr, &burst);
    let burst_wall_s = started.elapsed().as_secs_f64();

    let (mut ok, mut rejected) = (0usize, 0usize);
    for line in &responses {
        let v = parse_json(line).expect("burst response");
        match (
            v.get("status").and_then(Json::as_str),
            v.get("error").and_then(Json::as_str),
        ) {
            (Some("ok"), _) => ok += 1,
            (Some("error"), Some("overloaded")) => rejected += 1,
            other => panic!("unexpected burst response {other:?}: {line}"),
        }
    }
    assert_eq!(ok + rejected, BURST, "every burst request answered");
    assert!(rejected > 0, "the burst must trip backpressure");
    assert!(ok > 0, "admitted work still completes under overload");

    let _ = drive(addr, &["{\"cmd\":\"shutdown\"}".to_string()]);
    server_thread.join().expect("burst server thread");

    let mut o = JsonObj::new();
    o.str("bench", "serve_overload")
        .u64("burst", BURST as u64)
        .u64("ok", ok as u64)
        .u64("rejected", rejected as u64)
        .f64("wall_s", burst_wall_s, 3);
    println!("BENCH_SERVE.json {}", o.build());
}
