//! `cargo bench --bench hotpath` — the simulator's own performance: PE-cycle
//! throughput of `NexusFabric::step()` on a saturated fabric, the
//! compile-cache + fabric-reset hot path of the `Machine` session API,
//! active-set vs dense-oracle stepping on a sparse 16×16 mesh (reported as
//! a machine-readable `BENCH_STEP_MODE.json` line), plus the §4
//! compile-path timing comparison. This is the EXPERIMENTS.md §Perf probe.

use nexus::baselines::cgra::{mem_trace, GenericCgra};
use nexus::config::{ArchConfig, StepMode};
use nexus::machine::Machine;
use nexus::util::bench::{bench, throughput};
use std::time::Instant;

fn main() {
    // Compile the full suite once on a reusable session machine.
    let specs = nexus::workloads::suite(1);
    let cfg = ArchConfig::nexus();
    let mut machine = Machine::new(cfg.clone());
    let compiled: Vec<_> = specs
        .iter()
        .map(|s| machine.compile(s).expect("compile"))
        .collect();

    // Hot path: full suite on the Nexus fabric, measured in PE-cycles/s.
    let mut total_cycles = 0u64;
    let t0 = Instant::now();
    for c in &compiled {
        let e = machine.execute(c).expect("run");
        total_cycles += e.result.cycles;
    }
    let dt = t0.elapsed().as_secs_f64();
    throughput(
        "fabric step() PE-cycles",
        total_cycles * cfg.num_pes() as u64,
        dt,
    );

    // Repeated same-workload runs: a fresh machine (fabric allocation) per
    // workload — the seed's shape — vs one session machine (fabric reset,
    // cached programs). The session path must be no slower.
    let fresh = bench("suite end-to-end (fresh fabric)", 5, || {
        for c in &compiled {
            Machine::new(cfg.clone()).execute(c).expect("run");
        }
    });
    let reused = bench("suite end-to-end (reset+cache)", 5, || {
        for c in &compiled {
            machine.execute(c).expect("run");
        }
    });
    println!(
        "reset+cache vs fresh-fabric: {:.2}x",
        fresh / reused.max(1e-12)
    );

    // Dense-oracle vs active-set stepping on the *sparsest* suite workload
    // (SpMSpM-S4, 75%/75% sparsity) at 16×16 — the regime where idle-PE
    // scan overhead dominates the dense scheduler. Both runs validate the
    // same outputs; only host wall-clock differs.
    let spec = specs
        .iter()
        .find(|s| s.name() == "SpMSpM-S4")
        .expect("suite must contain SpMSpM-S4");
    let cfg16 = ArchConfig::nexus().with_array(16, 16);
    let mut m_active = Machine::new(cfg16.clone());
    let mut m_dense = Machine::new(cfg16.with_step_mode(StepMode::DenseOracle));
    let c_active = m_active.compile(spec).expect("compile");
    let c_dense = m_dense.compile(spec).expect("compile");
    let active_s = bench("step: active-set 16x16", 3, || {
        m_active.execute(&c_active).expect("active-set run");
    });
    let dense_s = bench("step: dense-oracle 16x16", 3, || {
        m_dense.execute(&c_dense).expect("dense-oracle run");
    });
    println!(
        "BENCH_STEP_MODE.json {{\"bench\":\"hotpath_step_mode\",\"mesh\":\"16x16\",\
         \"workload\":\"{}\",\"dense_s\":{:.6},\"active_s\":{:.6},\"speedup\":{:.3}}}",
        spec.name(),
        dense_s,
        active_s,
        dense_s / active_s.max(1e-12)
    );

    // Compile paths (§4: 0.55 s Nexus vs 7.22 s CGRA on the authors' setup).
    bench("compile path: nexus", 5, || {
        for s in &specs {
            std::hint::black_box(s.build(&cfg));
        }
    });
    bench("compile path: cached (Machine)", 5, || {
        for s in &specs {
            std::hint::black_box(machine.compile(s).expect("compile"));
        }
    });
    bench("compile path: generic CGRA", 5, || {
        let m = GenericCgra::default();
        for s in &specs {
            let dfg = s.dfg();
            let (trace, bytes) = mem_trace(s);
            std::hint::black_box(m.simulate(&dfg, &trace, bytes));
        }
    });
}
