//! `cargo bench --bench hotpath` — the simulator's own performance: PE-cycle
//! throughput of `NexusFabric::step()` on a saturated fabric, plus the §4
//! compile-path timing comparison. This is the EXPERIMENTS.md §Perf probe.

use nexus::baselines::cgra::{mem_trace, GenericCgra};
use nexus::config::ArchConfig;
use nexus::fabric::NexusFabric;
use nexus::util::bench::{bench, throughput};
use std::time::Instant;

fn main() {
    // Hot path: full suite on the Nexus fabric, measured in PE-cycles/s.
    let specs = nexus::workloads::suite(1);
    let cfg = ArchConfig::nexus();
    let built: Vec<_> = specs.iter().map(|s| s.build(&cfg)).collect();

    let mut total_cycles = 0u64;
    let t0 = Instant::now();
    for b in &built {
        let mut f = NexusFabric::new(cfg.clone());
        nexus::workloads::run_on_fabric(&mut f, b).expect("run");
        total_cycles += f.stats.cycles;
    }
    let dt = t0.elapsed().as_secs_f64();
    throughput(
        "fabric step() PE-cycles",
        total_cycles * cfg.num_pes() as u64,
        dt,
    );

    bench("suite end-to-end (nexus)", 5, || {
        for b in &built {
            let mut f = NexusFabric::new(cfg.clone());
            nexus::workloads::run_on_fabric(&mut f, b).expect("run");
        }
    });

    // Compile paths (§4: 0.55 s Nexus vs 7.22 s CGRA on the authors' setup).
    bench("compile path: nexus", 5, || {
        for s in &specs {
            std::hint::black_box(s.build(&cfg));
        }
    });
    bench("compile path: generic CGRA", 5, || {
        let m = GenericCgra::default();
        for s in &specs {
            let dfg = s.dfg();
            let (trace, bytes) = mem_trace(s);
            std::hint::black_box(m.simulate(&dfg, &trace, bytes));
        }
    });
}
