//! `cargo bench --bench fig10_power_ablation` — regenerates the paper's fig10 power ablation
//! series from the cycle-accurate simulator, and times the regeneration.

use nexus::coordinator::{self, report};
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("fig10_power_ablation", 3, || {
        let m = coordinator::run_matrix(1);
        out = report::fig10(&m);
    });
    println!("{out}");
}
