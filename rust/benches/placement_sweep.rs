//! `cargo bench --bench placement_sweep` — policy search over the
//! imbalance corpus sources: every placement × en-route claim policy
//! combination on SpMV over uniform, R-MAT, and hotspot inputs of the same
//! density. One machine-readable `BENCH_PLACEMENT.json` line per
//! (source, placement, claim) cell with cycles and the per-PE committed-op
//! imbalance metrics (`op_cv`, `op_max_mean`), plus one `best` summary line
//! per source naming the cheapest combination — the line CI's soft gate
//! reads to check that some non-default policy beats the default on the
//! skewed sources without regressing the uniform one.

use nexus::config::{ArchConfig, ClaimPolicy, PlacementPolicy};
use nexus::machine::Machine;
use nexus::tensor::gen;
use nexus::util::json::JsonObj;
use nexus::util::SplitMix64;
use nexus::workloads::Spec;

fn spec_for(source: &str, seed: u64) -> Spec {
    let n = 64;
    let density = 0.1;
    let mut rng = SplitMix64::new(seed);
    let a = match source {
        "uniform" => gen::random_csr(&mut rng, n, n, density),
        "rmat" => {
            let target = ((n * n) as f64 * density).round() as usize;
            gen::rmat_csr(&mut rng, n, n, target, gen::RMAT_PROBS)
        }
        "hotspot" => gen::hotspot_csr(&mut rng, n, n, density, 4, 0.85),
        other => panic!("unknown source {other}"),
    };
    let x = gen::random_vec(&mut rng, n, 3);
    Spec::Spmv { a, x }
}

fn main() {
    let seed = 1u64;
    let (w, h) = (8usize, 8usize);
    for source in ["uniform", "rmat", "hotspot"] {
        let spec = spec_for(source, seed);
        let mut default_cycles = 0u64;
        let mut best: Option<(u64, PlacementPolicy, ClaimPolicy)> = None;
        for placement in PlacementPolicy::ALL {
            for claim in ClaimPolicy::ALL {
                let cfg = ArchConfig::nexus()
                    .with_array(w, h)
                    .with_placement(placement)
                    .with_claim(claim);
                let mut m = Machine::new(cfg);
                let compiled = m.compile(&spec).expect("compile");
                let exec = m.execute(&compiled).expect("placement sweep run");
                assert!(
                    exec.validated(),
                    "{source} under {}+{} must validate",
                    placement.name(),
                    claim.name()
                );
                let stats = exec.stats.as_ref().expect("fabric stats");
                let cycles = exec.cycles();
                if placement == PlacementPolicy::default() && claim == ClaimPolicy::default() {
                    default_cycles = cycles;
                }
                if best.map_or(true, |(c, _, _)| cycles < c) {
                    best = Some((cycles, placement, claim));
                }
                let mut o = JsonObj::new();
                o.str("bench", "placement_sweep")
                    .str("mesh", &format!("{w}x{h}"))
                    .str("source", source)
                    .str("placement", placement.name())
                    .str("claim", claim.name())
                    .u64("cycles", cycles)
                    .f64("op_cv", stats.op_cv(), 4)
                    .f64("op_max_mean", stats.op_max_mean(), 4)
                    .f64("load_cv", stats.load_cv(), 4);
                println!("BENCH_PLACEMENT.json {}", o.build());
            }
        }
        let (best_cycles, best_p, best_c) = best.expect("at least one combination ran");
        let mut o = JsonObj::new();
        o.str("bench", "placement_sweep_best")
            .str("source", source)
            .str("placement", best_p.name())
            .str("claim", best_c.name())
            .u64("cycles", best_cycles)
            .u64("default_cycles", default_cycles);
        println!("BENCH_PLACEMENT.json {}", o.build());
    }
}
