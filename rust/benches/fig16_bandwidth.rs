//! `cargo bench --bench fig16_bandwidth` — regenerates the paper's fig16 bandwidth
//! series from the cycle-accurate simulator, and times the regeneration.

use nexus::coordinator::{self, report};
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("fig16_bandwidth", 2, || {
        let pts = coordinator::bandwidth_sweep(1);
        out = report::fig16(&pts);
    });
    println!("{out}");
}
