//! `cargo bench --bench fig11_performance` — regenerates the paper's fig11 performance
//! series from the cycle-accurate simulator, and times the regeneration.

use nexus::coordinator::{self, report};
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("fig11_performance", 3, || {
        let m = coordinator::run_matrix(1);
        out = report::fig11(&m);
    });
    println!("{out}");
}
