//! `cargo bench --bench fig17_scalability` — regenerates the paper's fig17 scalability
//! series from the cycle-accurate simulator, times the regeneration under
//! both simulator scheduling modes, and reports the dense-oracle vs
//! active-set wall-clock speedup as a machine-readable
//! `BENCH_STEP_MODE.json` line (the gap grows with the mesh, since the
//! dense scan pays for every idle PE every cycle).

use nexus::config::{ArchConfig, StepMode};
use nexus::coordinator::{self, report};
use nexus::util::bench::bench;

fn main() {
    let dims = [2usize, 4, 6, 8];
    let mut out = String::new();
    let active_s = bench("fig17_scalability (active-set)", 2, || {
        let pts = coordinator::scalability_sweep(1, &dims);
        out = report::fig17(&pts);
    });
    let dense_cfg = ArchConfig::nexus().with_step_mode(StepMode::DenseOracle);
    let mut dense_out = String::new();
    let dense_s = bench("fig17_scalability (dense-oracle)", 2, || {
        let pts = coordinator::scalability_sweep_with(&dense_cfg, 1, &dims);
        dense_out = report::fig17(&pts);
    });
    assert_eq!(out, dense_out, "step modes must produce identical figures");
    println!(
        "BENCH_STEP_MODE.json {{\"bench\":\"fig17_scalability\",\"dims\":\"2,4,6,8\",\
         \"dense_s\":{:.6},\"active_s\":{:.6},\"speedup\":{:.3}}}",
        dense_s,
        active_s,
        dense_s / active_s.max(1e-12)
    );
    println!("{out}");
}
