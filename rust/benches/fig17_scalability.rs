//! `cargo bench --bench fig17_scalability` — regenerates the paper's fig17 scalability
//! series from the cycle-accurate simulator, and times the regeneration.

use nexus::coordinator::{self, report};
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("fig17_scalability", 2, || {
        let pts = coordinator::scalability_sweep(1, &[2, 4, 6, 8]);
        out = report::fig17(&pts);
    });
    println!("{out}");
}
