//! `cargo bench --bench fig17_scalability` — the sharded-simulation
//! scaling benchmark. Builds uniform all-to-all traffic on large meshes
//! (32x32 and 64x64), partitions the fabric into 8 row-band shards, and
//! times the same program at 1/2/4/8 worker threads. Every thread count
//! must produce **bit-identical** outputs, cycle counts, and fabric stats
//! (the determinism contract of `ArchConfig::threads`); the wall-clock
//! ratios are emitted as machine-readable `BENCH_SHARDED.json` lines plus
//! one `SHARDED_SPEEDUP` summary per mesh (the CI speedup gate greps it).

use nexus::am::Message;
use nexus::compiler::{Program, ProgramBuilder};
use nexus::config::ArchConfig;
use nexus::fabric::stats::FabricStats;
use nexus::fabric::NexusFabric;
use nexus::isa::{ConfigEntry, Opcode};
use nexus::util::bench::bench;
use nexus::util::SplitMix64;

/// Uniform random traffic sized to the mesh: every PE sources two remote
/// stores and one Load->Mul->Accum MAC chain to random owners, so all
/// shard bands carry comparable load and the measured speedup reflects
/// real phase work rather than one hot band.
fn traffic_program(cfg: &ArchConfig, seed: u64) -> Program {
    let n = cfg.num_pes();
    let mut rng = SplitMix64::new(seed);
    let mut b = ProgramBuilder::new("fig17-sharded-traffic", cfg);
    assert_eq!(b.config(ConfigEntry::new(Opcode::Add, 1).res_addr()), 0);
    assert_eq!(b.config(ConfigEntry::new(Opcode::AccMin, 0).res_addr()), 1);
    assert_eq!(b.config(ConfigEntry::new(Opcode::Mul, 3)), 2);
    assert_eq!(b.config(ConfigEntry::new(Opcode::Accum, 3).res_addr()), 3);
    for src in 0..n {
        for k in 0..2u16 {
            let dst = rng.below_usize(n);
            let addr = b.alloc(dst, 1);
            let mut am = Message::new();
            am.opcode = Opcode::Store;
            am.op1 = 1 + k + (src % 31) as u16;
            am.result = addr;
            am.res_is_addr = true;
            am.push_dest(dst as u16);
            b.static_am(src, am);
            b.output(dst, addr);
        }
        let data_pe = rng.below_usize(n);
        let out_pe = rng.below_usize(n);
        let xa = b.place(data_pe, &[1 + (src % 5) as i16]);
        let ya = b.place(out_pe, &[0]);
        let mut am = Message::new();
        am.opcode = Opcode::Load; // op2 <- dmem[op2] at data_pe
        am.n_pc = 2; // -> Mul -> Accum
        am.op1 = 1 + (src % 7) as u16;
        am.op2 = xa;
        am.op2_is_addr = true;
        am.result = ya;
        am.res_is_addr = true;
        am.push_dest(data_pe as u16);
        am.push_dest(out_pe as u16);
        b.static_am(src, am);
        b.output(out_pe, ya);
    }
    b.build()
}

fn main() {
    const SHARDS: usize = 8;
    for dim in [32usize, 64] {
        // High AXI bandwidth floods the fabric with the static AMs quickly,
        // so the measurement is dominated by phase/route/commit work — the
        // part the shard workers parallelize — not by serialized injection.
        let base = ArchConfig::nexus()
            .with_array(dim, dim)
            .with_shards(SHARDS)
            .with_axi_bandwidth(256.0);
        base.validate().expect("bench config");
        let prog = traffic_program(&base, 1);
        let mut baseline: Option<(Vec<i16>, u64, FabricStats)> = None;
        let mut serial_s = 0.0;
        let mut best = (0usize, 0.0f64);
        for threads in [1usize, 2, 4, 8] {
            let mut f = NexusFabric::new(base.clone().with_threads(threads));
            let mut run = None;
            let secs = bench(&format!("fig17 {dim}x{dim} s{SHARDS} t{threads}"), 3, || {
                f.reset();
                let out = f.run_program(&prog).expect("sharded bench run");
                run = Some((out, f.cycles(), f.stats.clone()));
            });
            let (out, cycles, stats) = run.unwrap();
            match &baseline {
                None => {
                    baseline = Some((out, cycles, stats));
                    serial_s = secs;
                }
                Some((b_out, b_cycles, b_stats)) => {
                    assert_eq!(&out, b_out, "{dim}x{dim} t{threads}: outputs diverge");
                    assert_eq!(cycles, *b_cycles, "{dim}x{dim} t{threads}: cycles diverge");
                    if let Some(field) = stats.diff(b_stats) {
                        panic!("{dim}x{dim} t{threads}: stats diverge on {field}");
                    }
                }
            }
            let speedup = serial_s / secs.max(1e-12);
            if threads >= 4 && speedup > best.1 {
                best = (threads, speedup);
            }
            println!(
                "BENCH_SHARDED.json {{\"bench\":\"fig17_sharded\",\"mesh\":\"{dim}x{dim}\",\
                 \"shards\":{SHARDS},\"threads\":{threads},\"cycles\":{cycles},\
                 \"wall_s\":{secs:.6},\"speedup\":{speedup:.3}}}"
            );
        }
        println!(
            "SHARDED_SPEEDUP mesh={dim}x{dim} shards={SHARDS} best_threads={} speedup={:.3}",
            best.0, best.1
        );
    }
}
