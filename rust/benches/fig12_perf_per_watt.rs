//! `cargo bench --bench fig12_perf_per_watt` — regenerates the paper's fig12 perf per watt
//! series from the cycle-accurate simulator, and times the regeneration.

use nexus::coordinator::{self, report};
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("fig12_perf_per_watt", 3, || {
        let m = coordinator::run_matrix(1);
        out = report::fig12(&m);
    });
    println!("{out}");
}
