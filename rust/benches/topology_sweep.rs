//! `cargo bench --bench topology_sweep` — the fig14-style congestion
//! story across NoC topologies: SpMV over hotspot and R-MAT inputs at a
//! 16×16 array, on every [`TopologyKind`] (mesh, torus, ruche, chiplet).
//! One machine-readable `BENCH_TOPOLOGY.json` line per (source, topology)
//! cell, reporting cycles, mean port congestion, total/per-link flit
//! movement, the hottest directed link, peak per-cycle link demand, and
//! host wall-clock — the data behind "which topology decongests skewed
//! traffic, and at what latency cost".

use nexus::config::{ArchConfig, TopologyKind};
use nexus::machine::Machine;
use nexus::noc::routing::Dir;
use nexus::noc::LINKS_PER_PE;
use nexus::tensor::gen;
use nexus::util::bench::bench;
use nexus::util::json::JsonObj;
use nexus::util::SplitMix64;
use nexus::workloads::Spec;

fn spec_for(source: &str, seed: u64) -> Spec {
    let n = 128;
    let density = 0.08;
    let mut rng = SplitMix64::new(seed);
    let a = match source {
        "hotspot" => gen::hotspot_csr(&mut rng, n, n, density, 4, 0.9),
        "rmat" => {
            let target = ((n * n) as f64 * density).round() as usize;
            gen::rmat_csr(&mut rng, n, n, target, gen::RMAT_PROBS)
        }
        other => panic!("unknown source {other}"),
    };
    let x = gen::random_vec(&mut rng, n, 3);
    Spec::Spmv { a, x }
}

fn main() {
    let seed = 1u64;
    let (w, h) = (16usize, 16usize);
    for source in ["hotspot", "rmat"] {
        let spec = spec_for(source, seed);
        for kind in TopologyKind::ALL {
            let cfg = ArchConfig::nexus()
                .with_array(w, h)
                .with_topology(kind)
                .with_chiplet((8, 8), 4);
            let mut m = Machine::new(cfg.clone());
            let compiled = m.compile(&spec).expect("compile");
            let exec = m.execute(&compiled).expect("topology bench run");
            assert!(exec.validated(), "{source}/{} must validate", kind.name());
            let stats = exec.stats.as_ref().expect("fabric stats");
            let congestion = exec.result.congestion.iter().sum::<f64>()
                / exec.result.congestion.len() as f64;
            let (hot_from, hot_to, hot_flits) = match stats.max_link_flits() {
                Some((idx, flits)) => {
                    let from = idx / LINKS_PER_PE;
                    let dir = Dir::from_port(idx % LINKS_PER_PE + 1);
                    let to = nexus::noc::build_topology(&cfg)
                        .neighbor(from, dir)
                        .expect("hottest link wired");
                    (from, to, flits)
                }
                None => (0, 0, 0),
            };
            let wall_s = bench(
                &format!("spmv {source} {w}x{h} {}", kind.name()),
                3,
                || {
                    m.execute(&compiled).expect("topology bench run");
                },
            );
            let mut o = JsonObj::new();
            o.str("bench", "topology_sweep")
                .str("mesh", &format!("{w}x{h}"))
                .str("source", source)
                .str("topology", kind.name())
                .u64("cycles", exec.cycles())
                .f64("congestion", congestion, 4)
                .u64("link_flits", stats.link_flits_total())
                .u64("peak_link_demand", stats.peak_link_demand)
                .raw("hot_link", &format!("[{hot_from},{hot_to},{hot_flits}]"))
                .f64("utilization", exec.result.utilization, 4)
                .f64("wall_s", wall_s, 6);
            println!("BENCH_TOPOLOGY.json {}", o.build());
        }
    }
}
