//! `cargo bench --bench trace_overhead` — host-side cost of the tracing
//! subsystem. Tracing is bit-identical by construction (the property and
//! integration suites prove that); this bench bounds what it costs in
//! wall-clock: a disabled `TraceConfig` must be unmeasurable against run
//! noise, and full lifecycle + PE-state capture should stay under ~2x.
//! Emits a machine-readable `BENCH_TRACE.json` line; the soft gate is
//! advisory (host-speed dependent), not a hard assert.

use nexus::config::ArchConfig;
use nexus::machine::Machine;
use nexus::trace::TraceConfig;
use nexus::util::bench::bench;

fn main() {
    let specs = nexus::workloads::suite(1);
    let cfg = ArchConfig::nexus();

    // One session machine per trace mode so each path keeps its own warm
    // compile cache; the compiled artifacts are identical across modes
    // (tracing is excluded from the config tag).
    let mut m_off = Machine::new(cfg.clone());
    let mut m_full = Machine::new(cfg.clone().with_trace(TraceConfig::full()));
    let mut m_flight = Machine::new(cfg.clone().with_trace(TraceConfig::flight_recorder(256)));
    let compiled: Vec<_> = specs
        .iter()
        .map(|s| m_off.compile(s).expect("compile"))
        .collect();
    // Warm every cache (and fault in allocations) before timing.
    for c in &compiled {
        m_off.execute(c).expect("warmup off");
        m_full.execute(c).expect("warmup full");
        m_flight.execute(c).expect("warmup flight");
    }

    let off_s = bench("suite end-to-end (tracing off)", 5, || {
        for c in &compiled {
            m_off.execute(c).expect("run");
        }
    });
    let full_s = bench("suite end-to-end (tracing full)", 5, || {
        for c in &compiled {
            m_full.execute(c).expect("run");
        }
    });
    let flight_s = bench("suite end-to-end (flight recorder)", 5, || {
        for c in &compiled {
            m_flight.execute(c).expect("run");
        }
    });

    let full_x = full_s / off_s.max(1e-12);
    let flight_x = flight_s / off_s.max(1e-12);
    println!(
        "BENCH_TRACE.json {{\"bench\":\"trace_overhead\",\"workloads\":{},\
         \"off_s\":{:.6},\"full_s\":{:.6},\"flight_s\":{:.6},\
         \"full_overhead\":{:.3},\"flight_overhead\":{:.3}}}",
        compiled.len(),
        off_s,
        full_s,
        flight_s,
        full_x,
        flight_x
    );
    if full_x >= 2.0 {
        println!("WARNING: full tracing overhead {full_x:.2}x exceeds the 2x soft gate");
    }
    if flight_x >= 2.0 {
        println!("WARNING: flight-recorder overhead {flight_x:.2}x exceeds the 2x soft gate");
    }
}
