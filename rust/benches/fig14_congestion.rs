//! `cargo bench --bench fig14_congestion` — regenerates the paper's fig14 congestion
//! series from the cycle-accurate simulator, and times the regeneration.

use nexus::coordinator::{self, report};
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("fig14_congestion", 3, || {
        let m = coordinator::run_matrix(1);
        out = report::fig14(&m);
    });
    println!("{out}");
}
