//! `cargo bench --bench corpus` — the load-imbalance story, finally
//! measurable in-repo: SpMV over uniform vs R-MAT vs hotspot inputs of the
//! *same density* at 8×8 and 16×16 meshes, reporting cycles alongside the
//! per-PE committed-op imbalance metrics (`op_cv`, `op_max_mean`) and host
//! wall-clock. One machine-readable `BENCH_CORPUS_IMBALANCE.json` line per
//! (mesh, source) cell.

use nexus::config::ArchConfig;
use nexus::machine::Machine;
use nexus::tensor::gen;
use nexus::util::bench::bench;
use nexus::util::json::JsonObj;
use nexus::util::SplitMix64;
use nexus::workloads::Spec;

fn spec_for(source: &str, seed: u64) -> Spec {
    let n = 64;
    let density = 0.1;
    let mut rng = SplitMix64::new(seed);
    let a = match source {
        "uniform" => gen::random_csr(&mut rng, n, n, density),
        "rmat" => {
            let target = ((n * n) as f64 * density).round() as usize;
            gen::rmat_csr(&mut rng, n, n, target, gen::RMAT_PROBS)
        }
        "hotspot" => gen::hotspot_csr(&mut rng, n, n, density, 4, 0.85),
        other => panic!("unknown source {other}"),
    };
    let x = gen::random_vec(&mut rng, n, 3);
    Spec::Spmv { a, x }
}

fn main() {
    let seed = 1u64;
    for (w, h) in [(8usize, 8usize), (16, 16)] {
        for source in ["uniform", "rmat", "hotspot"] {
            let spec = spec_for(source, seed);
            let mut m = Machine::new(ArchConfig::nexus().with_array(w, h));
            let compiled = m.compile(&spec).expect("compile");
            let exec = m.execute(&compiled).expect("corpus bench run");
            assert!(exec.validated(), "{source} must validate");
            let stats = exec.stats.as_ref().expect("fabric stats");
            let wall_s = bench(
                &format!("spmv {source} {w}x{h}"),
                3,
                || {
                    m.execute(&compiled).expect("corpus bench run");
                },
            );
            let mut o = JsonObj::new();
            o.str("bench", "corpus_imbalance")
                .str("mesh", &format!("{w}x{h}"))
                .str("source", source)
                .f64("density", 0.1, 1)
                .u64("cycles", exec.cycles())
                .f64("op_cv", stats.op_cv(), 4)
                .f64("op_max_mean", stats.op_max_mean(), 4)
                .f64("load_cv", stats.load_cv(), 4)
                .f64("utilization", exec.result.utilization, 4)
                .f64("wall_s", wall_s, 6);
            println!("BENCH_CORPUS_IMBALANCE.json {}", o.build());
        }
    }
}
