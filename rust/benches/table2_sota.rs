//! `cargo bench --bench table2_sota` — regenerates the paper's table2 sota
//! series from the cycle-accurate simulator, and times the regeneration.

use nexus::coordinator::{self, report};
use nexus::util::bench::bench;

fn main() {
    let mut out = String::new();
    bench("table2_sota", 3, || {
        let m = coordinator::run_matrix(1);
        out = report::table2(&m);
    });
    println!("{out}");
}
