//! Topology-layer integration suite: the 2D mesh default stays
//! bit-identical across step modes on real workloads (the pre-refactor
//! behavior contract), every topology variant validates the workload
//! suite, wraparound/skip links actually shorten routes, chiplet boundary
//! crossings actually cost cycles, and the per-link congestion counters
//! obey their conservation invariant end to end through the `Machine`
//! layer.

use nexus::am::Message;
use nexus::compiler::{Program, ProgramBuilder};
use nexus::config::{ArchConfig, StepMode, TopologyKind};
use nexus::fabric::NexusFabric;
use nexus::isa::Opcode;
use nexus::machine::Machine;

/// `count` remote stores from the north-west corner PE to the south-east
/// corner PE — the worst-case mesh path, and the one wraparound (torus)
/// and skip (ruche) links shorten the most.
fn corner_storm(cfg: &ArchConfig, count: u16) -> Program {
    let far = cfg.num_pes() - 1;
    let mut b = ProgramBuilder::new("corner-storm", cfg);
    let addr = b.alloc(far, count as usize);
    for i in 0..count {
        let mut am = Message::new();
        am.opcode = Opcode::Store;
        am.op1 = i;
        am.result = addr + i;
        am.res_is_addr = true;
        am.push_dest(far as u16);
        b.static_am(0, am);
    }
    for i in 0..count {
        b.output(far, addr + i);
    }
    b.build()
}

fn run_storm(cfg: ArchConfig) -> NexusFabric {
    let prog = corner_storm(&cfg, 40);
    let mut f = NexusFabric::new(cfg);
    let out = f.run_program(&prog).expect("storm must drain");
    assert_eq!(out, (0..40).collect::<Vec<i16>>());
    f.check_conservation().unwrap();
    f
}

fn base_8x8(kind: TopologyKind) -> ArchConfig {
    ArchConfig::nexus()
        .with_array(8, 8)
        .with_topology(kind)
        .with_chiplet((4, 4), 6)
}

/// The regression contract of the refactor: the default topology is the
/// 2D mesh, and mesh execution stays bit-identical between the two step
/// modes on real suite workloads — outputs, cycles, and the full stats
/// block (which now includes the per-link counters).
#[test]
fn mesh_default_suite_is_bit_identical_across_modes() {
    assert_eq!(ArchConfig::nexus().topology, TopologyKind::Mesh2D);
    let specs = nexus::workloads::suite(1);
    let picks: Vec<_> = specs
        .iter()
        .filter(|s| {
            let n = s.name();
            n.starts_with("SpMV") || n == "BFS"
        })
        .collect();
    assert!(!picks.is_empty());
    // An explicit Mesh2D selection and the default must be the same thing.
    let mut default_m = Machine::new(ArchConfig::nexus());
    let mut explicit = Machine::new(ArchConfig::nexus().with_topology(TopologyKind::Mesh2D));
    let mut dense = Machine::new(ArchConfig::nexus().with_step_mode(StepMode::DenseOracle));
    for spec in &picks {
        let ed = default_m.run(spec).expect("default mesh run");
        let ee = explicit.run(spec).expect("explicit mesh run");
        let eo = dense.run(spec).expect("dense mesh run");
        assert!(ed.result.validated, "{}", spec.name());
        for other in [&ee, &eo] {
            assert_eq!(ed.outputs, other.outputs, "{}", spec.name());
            assert_eq!(ed.cycles(), other.cycles(), "{}", spec.name());
        }
        let (sa, sb) = (ed.stats.as_ref().unwrap(), eo.stats.as_ref().unwrap());
        if let Some(field) = sa.diff(sb) {
            panic!("{}: mesh stats diverged across modes on {field}", spec.name());
        }
    }
}

/// Every topology variant executes and validates real workloads through
/// the `Machine` layer, and the per-link counters partition `flit_hops`.
#[test]
fn all_topologies_validate_suite_workloads() {
    let specs = nexus::workloads::suite(1);
    let spmv = specs
        .iter()
        .find(|s| s.name().starts_with("SpMV"))
        .expect("suite has SpMV");
    for kind in TopologyKind::ALL {
        let cfg = ArchConfig::nexus().with_topology(kind).with_chiplet((2, 2), 3);
        let mut m = Machine::new(cfg);
        let e = m.run(spmv).unwrap_or_else(|err| panic!("{kind:?}: {err}"));
        assert!(e.result.validated, "{kind:?}: SpMV must validate");
        let s = e.stats.expect("fabric stats");
        assert_eq!(
            s.link_flits_total(),
            s.flit_hops,
            "{kind:?}: link counters must partition flit_hops"
        );
        assert!(s.peak_link_demand >= 1, "{kind:?}");
        let (_, hottest) = s.max_link_flits().expect("some link carried flits");
        assert!(hottest > 0, "{kind:?}");
    }
}

/// Wraparound and skip links must shorten worst-case routes: the
/// corner-to-corner storm crosses fewer total links on the torus (2-hop
/// wrap path vs 14) and the ruche (stride jumps) than on the mesh.
#[test]
fn torus_and_ruche_cut_corner_traffic() {
    let mesh = run_storm(base_8x8(TopologyKind::Mesh2D));
    let torus = run_storm(base_8x8(TopologyKind::Torus2D));
    let ruche = run_storm(base_8x8(TopologyKind::Ruche));
    assert!(
        torus.stats.flit_hops < mesh.stats.flit_hops,
        "torus {} !< mesh {}",
        torus.stats.flit_hops,
        mesh.stats.flit_hops
    );
    assert!(
        ruche.stats.flit_hops < mesh.stats.flit_hops,
        "ruche {} !< mesh {}",
        ruche.stats.flit_hops,
        mesh.stats.flit_hops
    );
}

/// Chiplet boundary crossings hold the staging slot for the configured
/// latency, so the same storm costs strictly more cycles than the
/// single-die mesh while crossing the same number of links.
#[test]
fn chiplet_crossings_cost_cycles_not_hops() {
    let mesh = run_storm(base_8x8(TopologyKind::Mesh2D));
    let chiplet = run_storm(base_8x8(TopologyKind::Chiplet2L));
    assert_eq!(
        chiplet.stats.flit_hops, mesh.stats.flit_hops,
        "chiplet routes like the mesh"
    );
    assert!(
        chiplet.cycles() > mesh.cycles(),
        "chiplet {} !> mesh {}: 6-cycle crossings must show up",
        chiplet.cycles(),
        mesh.cycles()
    );
}

/// The hottest link of an all-to-one hotspot on the mesh is one of the
/// four links into the hotspot PE — the per-link counters localize
/// congestion, not just count it.
#[test]
fn link_counters_localize_hotspot_congestion() {
    let cfg = ArchConfig::nexus().with_array(8, 8);
    let hot = 27usize; // interior PE: four in-links
    let mut b = ProgramBuilder::new("hotspot", &cfg);
    let addr = b.alloc(hot, 1);
    for i in 0..120u16 {
        let src = (i as usize * 7 + 1) % 64;
        if src == hot {
            continue;
        }
        let mut am = Message::new();
        am.opcode = Opcode::Store;
        am.op1 = i;
        am.result = addr;
        am.res_is_addr = true;
        am.push_dest(hot as u16);
        b.static_am(src, am);
    }
    b.output(hot, addr);
    let prog = b.build();
    let mut f = NexusFabric::new(cfg);
    f.run_program(&prog).expect("hotspot drains");
    let (_, peak) = f.stats.max_link_flits().expect("traffic flowed");
    assert!(peak > 0);
    // Flow conservation: every store funnels through one of the four
    // in-links of the hotspot, so the busiest of those must carry the
    // global per-link maximum.
    let max_into_hot = f
        .stats
        .link_flits
        .iter()
        .enumerate()
        .filter(|&(idx, _)| {
            let from = idx / nexus::noc::LINKS_PER_PE;
            let dir = nexus::noc::routing::Dir::from_port(idx % nexus::noc::LINKS_PER_PE + 1);
            f.topology().neighbor(from, dir) == Some(hot)
        })
        .map(|(_, &flits)| flits)
        .max()
        .unwrap();
    assert_eq!(
        max_into_hot, peak,
        "the hottest link must be one feeding the hotspot PE"
    );
}
