//! Integration tests: the full evaluation pipeline end to end — suite
//! validation on every fabric variant, the architecture roster, and the
//! headline shapes of the paper's figures.

use nexus::config::ArchConfig;
use nexus::coordinator::{self, report};
use nexus::machine::{Compiled, Machine};
use nexus::workloads::suite;

#[test]
fn full_suite_validates_on_all_fabric_variants() {
    for cfg in [
        ArchConfig::nexus(),
        ArchConfig::tia(),
        ArchConfig::tia_valiant(),
    ] {
        let rows = coordinator::validate_suite(&cfg, 1).unwrap();
        assert_eq!(rows.len(), 13, "{:?}", cfg.kind);
    }
}

#[test]
fn suite_validates_under_different_seeds() {
    // Different data, same choreography: the compiler must be correct for
    // arbitrary instances, not one lucky seed.
    for seed in [2, 3] {
        coordinator::validate_suite(&ArchConfig::nexus(), seed).unwrap();
    }
}

#[test]
fn fig11_headline_shapes() {
    let m = coordinator::run_matrix(1);
    // Paper §5: ~1.9x over Generic CGRA on irregular workloads.
    let sparse = m.geomean_speedup("Nexus", "GenericCGRA", Some("sparse"));
    assert!(
        (1.3..3.0).contains(&sparse),
        "sparse geomean {sparse} out of the paper's band"
    );
    let graph = m.geomean_speedup("Nexus", "GenericCGRA", Some("graph"));
    assert!(graph > 1.0, "graph geomean {graph}");
    // TIA-Valiant sits between TIA and Nexus on average.
    let val_vs_tia = m.geomean_speedup("TIA-Valiant", "TIA", None);
    assert!(val_vs_tia > 0.9, "Valiant should not lose badly to TIA: {val_vs_tia}");
    let nexus_vs_val = m.geomean_speedup("Nexus", "TIA-Valiant", None);
    assert!(nexus_vs_val > 1.0, "Nexus must beat TIA-Valiant: {nexus_vs_val}");
    // Systolic wins dense MatMul, loses Conv and deep sparsity (S4).
    let mm = m.workloads.iter().position(|w| w == "MatMul").unwrap();
    assert!(m.speedup(mm, "Systolic", "Nexus").unwrap() > 1.0);
    let conv = m.workloads.iter().position(|w| w == "Conv").unwrap();
    assert!(m.speedup(conv, "Nexus", "Systolic").unwrap() > 1.0, "im2col penalty");
    let s4 = m.workloads.iter().position(|w| w.contains("S4")).unwrap();
    assert!(m.speedup(s4, "Nexus", "Systolic").unwrap() > 1.0);
}

#[test]
fn fig13_utilization_shape() {
    let m = coordinator::run_matrix(1);
    let mean_util = |arch: &str| {
        let mut v = Vec::new();
        for wi in 0..m.workloads.len() {
            if let Some(r) = m.get(wi, arch) {
                v.push(r.utilization);
            }
        }
        nexus::util::mean(&v)
    };
    let nexus = mean_util("Nexus");
    let tia = mean_util("TIA");
    // Paper: ~1.7x higher fabric utilization than the data-local SOTA.
    assert!(
        nexus / tia > 1.3,
        "Nexus {nexus:.3} should clearly beat TIA {tia:.3}"
    );
}

#[test]
fn fig14_congestion_shape() {
    let m = coordinator::run_matrix(1);
    // Nexus's adaptive AM routing reduces mean congestion vs TIA on the
    // irregular (sparse+graph) workloads.
    let mean_cong = |arch: &str| {
        let mut v = Vec::new();
        for wi in 0..m.workloads.len() {
            if m.classes[wi] == "dense" {
                continue;
            }
            if let Some(r) = m.get(wi, arch) {
                v.extend(r.congestion.iter().copied());
            }
        }
        nexus::util::mean(&v)
    };
    let nexus = mean_cong("Nexus");
    let tia = mean_cong("TIA");
    assert!(
        nexus <= tia * 1.05,
        "Nexus congestion {nexus:.3} should not exceed TIA {tia:.3}"
    );
}

#[test]
fn spmspm_sparsity_trends_match_section_5_1() {
    // §5.1: sparser A (same B) hurts; sparser B (same A) helps (early AM
    // termination). Compare per-useful-op efficiency is already captured by
    // normalized perf; here check absolute cycle trends on matched sizes.
    let m = coordinator::run_matrix(1);
    let perf = |tag: &str| {
        let wi = m.workloads.iter().position(|w| w.contains(tag)).unwrap();
        m.get(wi, "Nexus").unwrap().perf()
    };
    // S3 (B sparser than S1) must not be slower per useful op than S1 by
    // much; S2 (A sparser) tends lower. We assert the paired ordering that
    // defines the trend: within fixed A sparsity, sparser B helps cycles.
    let m1 = coordinator::run_matrix(1);
    let cyc = |tag: &str| {
        let wi = m1.workloads.iter().position(|w| w.contains(tag)).unwrap();
        m1.get(wi, "Nexus").unwrap().cycles
    };
    assert!(cyc("S3") < cyc("S1"), "sparser B must cut cycles (early termination)");
    assert!(cyc("S4") < cyc("S2"), "sparser B must cut cycles (early termination)");
    let _ = perf; // perf-based variants covered by fig11 shapes
}

#[test]
fn in_network_fraction_is_majority_for_alu_heavy_sparse() {
    let specs = suite(1);
    let spec = specs.iter().find(|s| s.name().starts_with("SpMSpM-S1")).unwrap();
    let mut m = Machine::new(ArchConfig::nexus());
    let e = m.run(spec).unwrap();
    assert!(
        e.result.in_network_frac > 0.5,
        "most MULs should run en-route: {}",
        e.result.in_network_frac
    );
}

#[test]
fn reports_render_for_all_figures() {
    let m = coordinator::run_matrix(1);
    for s in [
        report::fig10(&m),
        report::fig11(&m),
        report::fig12(&m),
        report::fig13(&m),
        report::fig14(&m),
        report::fig15(),
        report::table1(),
        report::table2(&m),
    ] {
        assert!(s.len() > 100, "report suspiciously short:\n{s}");
    }
}

#[test]
fn scalability_sweep_scales() {
    let pts = coordinator::scalability_sweep(1, &[2, 4]);
    // 4x4 beats 2x2 on every covered workload (Fig 17 near-linear claim at
    // small scale).
    for w in ["MatMul", "BFS"] {
        let p2 = pts.iter().find(|p| p.dim == 2 && p.workload == w).unwrap();
        let p4 = pts.iter().find(|p| p.dim == 4 && p.workload == w).unwrap();
        assert!(
            p4.perf > p2.perf,
            "{w}: 4x4 ({}) should beat 2x2 ({})",
            p4.perf,
            p2.perf
        );
    }
}

#[test]
fn larger_sram_reduces_bandwidth_need() {
    // Two points of the Fig 16 curve: more on-chip SRAM => fewer tiles =>
    // less off-chip traffic per compute cycle.
    use nexus::tensor::gen;
    use nexus::util::SplitMix64;
    let mut rng = SplitMix64::new(99);
    let a = gen::skewed_csr(&mut rng, 96, 96, 0.3);
    let b = gen::random_csr(&mut rng, 96, 96, 0.3);
    let run = |bytes: usize| {
        let cfg = ArchConfig::nexus().with_dmem_bytes(bytes);
        let built = nexus::workloads::spmspm::build_tiled("f16", &a, &b, &cfg);
        let mut m = Machine::new(cfg);
        let e = m.execute(&Compiled::from_built(built)).unwrap();
        let s = e.stats.unwrap();
        s.offchip_bytes as f64 / s.compute_cycles() as f64
    };
    let small = run(1024);
    let large = run(16384);
    assert!(
        large < small,
        "16KB/PE ({large:.2} B/cyc) must need less BW than 1KB/PE ({small:.2})"
    );
}

#[test]
fn deterministic_across_runs() {
    let cfg = ArchConfig::nexus();
    let specs = suite(5);
    let spec = specs.iter().find(|s| s.name() == "BFS").unwrap();
    let mut cycles = Vec::new();
    for _ in 0..2 {
        let mut m = Machine::new(cfg.clone());
        cycles.push(m.run(spec).unwrap().cycles());
    }
    assert_eq!(cycles[0], cycles[1], "simulation must be deterministic");
}
