//! Integration suite for `nexus serve`: protocol correctness over real
//! sockets, bit-identity of served results against direct in-process
//! execution, explicit backpressure under overload, and lossless
//! graceful shutdown.
//!
//! Every test binds its own server on port 0, so the suite is parallel-
//! and CI-safe.

use nexus::config::ArchConfig;
use nexus::dataset::{effective_shards, Corpus};
use nexus::machine::Machine;
use nexus::serve::protocol::{outputs_digest, parse_json, stats_digest, Json};
use nexus::serve::{Server, ServeOptions};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread::{self, JoinHandle};

/// Bind a server with the given options (addr forced to port 0), return
/// its address and the running thread.
fn start(opts: ServeOptions) -> (SocketAddr, JoinHandle<()>) {
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        ..opts
    })
    .expect("bind");
    let addr = server.local_addr().expect("local addr");
    let handle = thread::spawn(move || server.run().expect("server run"));
    (addr, handle)
}

/// Pipeline `requests` down one connection, half-close, and collect every
/// response line in order.
fn drive(addr: SocketAddr, requests: &[&str]) -> Vec<String> {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let reader = BufReader::new(stream);
    for r in requests {
        writeln!(writer, "{r}").expect("write");
    }
    writer.flush().expect("flush");
    let _ = writer.shutdown(std::net::Shutdown::Write);
    reader.lines().map(|l| l.expect("read line")).collect()
}

fn shutdown(addr: SocketAddr, handle: JoinHandle<()>) {
    let lines = drive(addr, &["{\"cmd\":\"shutdown\"}"]);
    let v = parse_json(&lines[0]).expect("shutdown response");
    assert_eq!(v.get("status").and_then(Json::as_str), Some("ok"));
    handle.join().expect("server joins after shutdown");
}

fn status(line: &str) -> (String, Option<String>) {
    let v = parse_json(line).unwrap_or_else(|e| panic!("bad response {line}: {e}"));
    (
        v.get("status").and_then(Json::as_str).unwrap_or("?").to_string(),
        v.get("error").and_then(Json::as_str).map(str::to_string),
    )
}

#[test]
fn health_and_metrics_respond() {
    let (addr, handle) = start(ServeOptions::default());
    let lines = drive(addr, &["GET /health", "{\"cmd\":\"metrics\"}"]);
    assert_eq!(lines.len(), 2);
    let h = parse_json(&lines[0]).expect("health");
    assert_eq!(h.get("status").and_then(Json::as_str), Some("ok"));
    assert!(h.get("uptime_secs").and_then(Json::as_f64).is_some());
    let m = parse_json(&lines[1]).expect("metrics");
    for key in [
        "received",
        "completed",
        "rejected",
        "malformed",
        "latency_p50_us",
        "latency_p99_us",
        "queue_depth",
        "queue_capacity",
        "cache_hit_rate",
    ] {
        assert!(m.get(key).is_some(), "metrics missing {key}: {}", lines[1]);
    }
    shutdown(addr, handle);
}

/// The tentpole acceptance property: a served scenario is bit-identical
/// to a direct `Machine` compile+execute of the same (spec, seed,
/// shards) — outputs AND the full counter set, via their digests.
#[test]
fn served_results_are_bit_identical_to_direct_runs() {
    for shards in [1usize, 2] {
        let (addr, handle) = start(ServeOptions {
            shards,
            ..ServeOptions::default()
        });
        let corpus = Corpus::builtin();
        for (name, seed) in [
            ("smoke/spmv-uniform-d30-4x4", 7u64),
            ("smoke/bfs-rmat-4x4", 3),
            ("hotspot/spmv-rmat-d20-8x8", 11),
        ] {
            let req = format!("{{\"scenario\":\"{name}\",\"seed\":{seed}}}");
            let lines = drive(addr, &[&req]);
            let v = parse_json(&lines[0]).expect("run response");
            assert_eq!(
                v.get("status").and_then(Json::as_str),
                Some("ok"),
                "{name}: {}",
                lines[0]
            );

            // Direct run of the same (spec, seed, shards).
            let sc = corpus.find(name).expect("scenario");
            let spec = sc.spec(seed);
            let eff = effective_shards(shards, sc.mesh.1);
            let cfg = ArchConfig::nexus()
                .with_array(sc.mesh.0, sc.mesh.1)
                .with_shards(eff);
            let exec = Machine::new(cfg).run(&spec).expect("direct run");

            let hex = |key: &str| {
                v.get(key)
                    .and_then(Json::as_str)
                    .and_then(|s| u64::from_str_radix(s.trim_start_matches("0x"), 16).ok())
                    .unwrap_or_else(|| panic!("{name}: missing {key}"))
            };
            assert_eq!(
                hex("outputs_digest"),
                outputs_digest(&exec.outputs),
                "{name} (shards {eff}): served outputs differ from direct run"
            );
            assert_eq!(
                hex("stats_digest"),
                stats_digest(exec.stats.as_ref().expect("stats")),
                "{name} (shards {eff}): served counters differ from direct run"
            );
            assert_eq!(
                v.get("cycles").and_then(Json::as_u64),
                Some(exec.cycles()),
                "{name}"
            );
            assert_eq!(
                v.get("shards").and_then(Json::as_u64),
                Some(eff as u64),
                "{name}"
            );
            assert_eq!(v.get("validated").and_then(Json::as_bool), Some(true));
        }
        shutdown(addr, handle);
    }
}

/// Inline specs are served deterministically too, and repeating the same
/// request is a compile-cache hit with an identical digest.
#[test]
fn inline_specs_repeat_identically_with_cache_hits() {
    let (addr, handle) = start(ServeOptions::default());
    let req = "{\"spec\":{\"kernel\":\"spmv\",\"source\":\"hotspot\",\"n\":32,\
               \"density\":0.2,\"mesh\":[4,4]},\"seed\":5}";
    let lines = drive(addr, &[req, req, req]);
    assert_eq!(lines.len(), 3);
    let first = parse_json(&lines[0]).expect("first");
    assert_eq!(first.get("status").and_then(Json::as_str), Some("ok"));
    let digest = first.get("outputs_digest").and_then(Json::as_str).unwrap().to_string();
    let mut hits = 0;
    for line in &lines[1..] {
        let v = parse_json(line).expect("repeat");
        assert_eq!(
            v.get("outputs_digest").and_then(Json::as_str),
            Some(digest.as_str()),
            "repeat must be bit-identical"
        );
        if v.get("cache").and_then(Json::as_str) == Some("hit") {
            hits += 1;
        }
    }
    assert!(hits >= 1, "repeated spec must hit the shared compile cache");

    // The metrics cache block agrees: hit rate > 0.
    let m = parse_json(&drive(addr, &["GET /metrics"])[0]).expect("metrics");
    assert!(
        m.get("cache_hit_rate").and_then(Json::as_f64).unwrap() > 0.0,
        "cache hit rate must be > 0 after repeats"
    );
    shutdown(addr, handle);
}

#[test]
fn protocol_edge_cases_answer_typed_errors() {
    let (addr, handle) = start(ServeOptions {
        max_line_bytes: 512,
        ..ServeOptions::default()
    });
    let oversized = format!("{{\"scenario\":\"{}\"}}", "x".repeat(600));
    let cases = [
        ("{oops", "malformed"),
        ("{\"scenario\":\"no/such-scenario\"}", "unknown_scenario"),
        (oversized.as_str(), "oversized"),
        ("[1,2,3]", "bad_request"),
        ("{\"cmd\":\"explode\"}", "bad_request"),
        ("{\"spec\":{\"kernel\":\"dense-gemm\"}}", "bad_request"),
    ];
    let requests: Vec<&str> = cases.iter().map(|(req, _)| *req).collect();
    let lines = drive(addr, &requests);
    assert_eq!(lines.len(), cases.len(), "one response per bad request");
    for ((req, want), line) in cases.iter().zip(&lines) {
        let (st, err) = status(line);
        assert_eq!(st, "error", "{req} -> {line}");
        assert_eq!(err.as_deref(), Some(*want), "{req} -> {line}");
    }
    // The connection (and server) survives all of it.
    let ok = drive(addr, &["{\"scenario\":\"smoke/spmv-uniform-d30-4x4\"}"]);
    assert_eq!(status(&ok[0]).0, "ok");
    shutdown(addr, handle);
}

/// Overload: a burst beyond queue capacity on a single-worker server is
/// answered with immediate `overloaded` rejections — every request gets
/// exactly one response, nothing is dropped.
#[test]
fn overload_burst_is_rejected_not_dropped() {
    let (addr, handle) = start(ServeOptions {
        workers: 1,
        queue_capacity: 1,
        ..ServeOptions::default()
    });
    let req = "{\"scenario\":\"hotspot/spmv-rmat-d20-8x8\",\"seed\":1}";
    let requests: Vec<&str> = vec![req; 40];
    let lines = drive(addr, &requests);
    assert_eq!(lines.len(), 40, "every request must be answered");
    let (mut ok, mut overloaded) = (0, 0);
    for line in &lines {
        match status(line) {
            (st, _) if st == "ok" => ok += 1,
            (st, Some(e)) if st == "error" && e == "overloaded" => {
                assert!(
                    line.contains("\"error\":\"overloaded\""),
                    "literal code required: {line}"
                );
                overloaded += 1;
            }
            other => panic!("unexpected response {other:?}: {line}"),
        }
    }
    assert_eq!(ok + overloaded, 40, "answered == admitted + rejected");
    assert!(ok >= 1, "admitted work completes");
    assert!(
        overloaded >= 20,
        "a 40-deep burst into a 1-deep queue must mostly reject (got {overloaded})"
    );

    // Rejections are visible in metrics, and received == completed+rejected
    // (no silent drops).
    let m = parse_json(&drive(addr, &["GET /metrics"])[0]).expect("metrics");
    let g = |k: &str| m.get(k).and_then(Json::as_u64).unwrap();
    assert_eq!(g("received"), 40);
    assert_eq!(g("completed") + g("rejected"), g("received"));
    assert_eq!(g("completed"), ok as u64);
    assert_eq!(g("rejected"), overloaded as u64);
    shutdown(addr, handle);
}

/// Concurrent clients each get ordered, bit-identical responses.
#[test]
fn concurrent_clients_get_ordered_identical_results() {
    let (addr, handle) = start(ServeOptions {
        queue_capacity: 256,
        ..ServeOptions::default()
    });
    let names = [
        "smoke/spmv-uniform-d30-4x4",
        "smoke/spmv-hotspot-d30-4x4",
        "smoke/bfs-rmat-4x4",
    ];
    let clients: Vec<_> = (0..4)
        .map(|_| {
            thread::spawn(move || {
                let requests: Vec<String> = (0..6)
                    .map(|i| format!("{{\"scenario\":\"{}\",\"seed\":2}}", names[i % names.len()]))
                    .collect();
                let refs: Vec<&str> = requests.iter().map(String::as_str).collect();
                drive(addr, &refs)
            })
        })
        .collect();
    let all: Vec<Vec<String>> = clients.into_iter().map(|h| h.join().expect("client")).collect();
    for lines in &all {
        assert_eq!(lines.len(), 6);
        // Responses arrive in request order: scenario i matches names[i%3].
        for (i, line) in lines.iter().enumerate() {
            let v = parse_json(line).expect("response");
            assert_eq!(
                v.get("scenario").and_then(Json::as_str),
                Some(names[i % names.len()]),
                "responses must be in request order: {line}"
            );
            assert_eq!(v.get("status").and_then(Json::as_str), Some("ok"));
        }
        // And every client saw the same digests as the first client.
        for (a, b) in lines.iter().zip(&all[0]) {
            let (va, vb) = (parse_json(a).unwrap(), parse_json(b).unwrap());
            assert_eq!(
                va.get("outputs_digest").and_then(Json::as_str),
                vb.get("outputs_digest").and_then(Json::as_str)
            );
            assert_eq!(
                va.get("stats_digest").and_then(Json::as_str),
                vb.get("stats_digest").and_then(Json::as_str)
            );
        }
    }
    shutdown(addr, handle);
}

/// Graceful shutdown: work admitted before the shutdown request is
/// executed exactly once and its responses flush; the server then joins
/// (the exit-0 path) with `completed == admitted`.
#[test]
fn graceful_shutdown_drains_inflight_work_losslessly() {
    let (addr, handle) = start(ServeOptions {
        workers: 2,
        queue_capacity: 64,
        ..ServeOptions::default()
    });
    const K: usize = 8;
    let run = "{\"scenario\":\"smoke/spmv-uniform-d30-4x4\",\"seed\":4}";
    let mut requests: Vec<&str> = vec![run; K];
    requests.push("{\"cmd\":\"shutdown\"}");
    let lines = drive(addr, &requests);

    // All K runs answered ok (none lost to the shutdown), in order, then
    // the shutdown ack.
    assert_eq!(lines.len(), K + 1, "K responses + shutdown ack: {lines:?}");
    let mut digests = std::collections::HashSet::new();
    for line in &lines[..K] {
        let v = parse_json(line).expect("drained response");
        assert_eq!(
            v.get("status").and_then(Json::as_str),
            Some("ok"),
            "admitted work must complete through shutdown: {line}"
        );
        digests.insert(
            v.get("outputs_digest")
                .and_then(Json::as_str)
                .unwrap()
                .to_string(),
        );
    }
    assert_eq!(digests.len(), 1, "same request -> same digest every time");
    let ack = parse_json(&lines[K]).expect("ack");
    assert_eq!(ack.get("shutdown").and_then(Json::as_bool), Some(true));

    // The server exits cleanly: run() returns, the thread joins.
    handle.join().expect("server drains and joins");

    // New connections are refused after shutdown.
    assert!(
        TcpStream::connect(addr).is_err(),
        "listener must be closed after drain"
    );
}

/// Requests racing a shutdown are either completed or *answered* with
/// `shutting_down` — never silently dropped, never double-executed.
#[test]
fn requests_after_shutdown_are_answered_not_dropped() {
    let (addr, handle) = start(ServeOptions::default());
    // Connection A initiates the drain.
    let a = drive(addr, &["{\"cmd\":\"shutdown\"}"]);
    assert_eq!(status(&a[0]).0, "ok");
    handle.join().expect("server joins");
    // A fresh connection can no longer be made (the listener is gone);
    // this is the "rejecting new requests" half of the drain contract.
    assert!(TcpStream::connect(addr).is_err());
}
