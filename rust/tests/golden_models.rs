//! Golden-model integration: fabric vs AOT-compiled XLA artifacts via PJRT.
//! Requires `make artifacts` and the `pjrt` feature; skips (with a notice,
//! or SKIPPED rows) otherwise so `cargo test` works on a fresh checkout.

use nexus::runtime::artifacts_dir;

#[test]
fn three_way_agreement_reference_xla_fabric() {
    let dir = artifacts_dir();
    if !dir.join("spmv_ell.hlo.txt").exists() {
        eprintln!("skipping golden checks: run `make artifacts` first");
        return;
    }
    let rows = nexus::golden::check_all(&dir, 1).expect("golden checks");
    assert_eq!(rows.len(), 4);
    for (name, status) in rows {
        // Without the `pjrt` feature the runtime stub reports SKIPPED rows;
        // with it, present artifacts must agree three ways.
        if cfg!(feature = "pjrt") {
            assert!(status.starts_with("OK"), "{name}: {status}");
        } else {
            assert!(status.starts_with("SKIPPED"), "{name}: {status}");
        }
    }
}

#[test]
fn golden_checks_hold_for_multiple_seeds() {
    let dir = artifacts_dir();
    if !dir.join("spmv_ell.hlo.txt").exists() {
        eprintln!("skipping golden checks: run `make artifacts` first");
        return;
    }
    for seed in [7, 1234] {
        nexus::golden::check_all(&dir, seed).expect("golden checks");
    }
}
