//! Property-based differential suite: active-set stepping vs the dense
//! oracle (`StepMode::DenseOracle`) must be **bit-identical** — same
//! outputs, same cycle counts, same `FabricStats` field by field — across
//! random meshes, buffer depths, AXI/AM-queue parameters, and workload
//! densities, for every (exec policy × routing policy) combination.
//!
//! Every case additionally runs exactly one side under a random tracing
//! configuration ([`nexus::trace::TraceConfig`]), so each comparison
//! doubles as a zero-perturbation proof for the tracing subsystem.
//!
//! Each combination runs `NEXUS_PROP_CASES` randomized cases (default 200;
//! the CI release job raises it). On a mismatch the harness reports the
//! failing case seed (via `util::prop::forall_seeded`), the first differing
//! stats field (via `FabricStats::diff`), and the **first diverging cycle**
//! found by re-running both schedulers in lockstep and comparing
//! `NexusFabric::state_digest()` at every cycle boundary.

use nexus::am::Message;
use nexus::compiler::{Program, ProgramBuilder};
use nexus::config::{
    ArchConfig, ClaimPolicy, ExecPolicy, PlacementPolicy, RoutingPolicy, StepMode, TopologyKind,
};
use nexus::fabric::stats::FabricStats;
use nexus::fabric::{DeadlockError, NexusFabric};
use nexus::isa::{ConfigEntry, Opcode};
use nexus::pe::{StreamElem, StreamMode};
use nexus::trace::TraceConfig;
use nexus::util::prop::{ensure, forall_seeded};
use nexus::util::SplitMix64;

/// Randomized case count per policy combination (env-tunable so CI can run
/// a deeper sweep: `NEXUS_PROP_CASES=1000 cargo test --release`).
fn prop_cases() -> usize {
    nexus::util::prop::env_cases(200)
}

/// Random architectural configuration for one case: mesh dims, router
/// buffer depth, On/Off thresholds, AM-queue window, AXI bandwidth, idle
/// tree latency, and the PRNG seed all vary; the policies are pinned by the
/// calling test (one combination per test).
fn random_cfg(rng: &mut SplitMix64, exec: ExecPolicy, routing: RoutingPolicy) -> ArchConfig {
    const DIMS: [(usize, usize); 10] = [
        (2, 2),
        (2, 3),
        (3, 2),
        (3, 3),
        (4, 2),
        (2, 4),
        (4, 4),
        (5, 3),
        (3, 5),
        (4, 3),
    ];
    let (width, height) = DIMS[rng.below_usize(DIMS.len())];
    let router_buf_depth = 2 + rng.below_usize(3); // 2..=4
    let t_on = 2 + rng.below_usize(router_buf_depth - 1); // 2..=depth
    let mut cfg = ArchConfig::nexus();
    cfg.width = width;
    cfg.height = height;
    cfg.router_buf_depth = router_buf_depth;
    cfg.t_off = 1;
    cfg.t_on = t_on;
    cfg.am_queue_entries = [1, 2, 4, 8, 114][rng.below_usize(5)];
    cfg.axi_bytes_per_cycle = [1.0, 2.0, 8.0][rng.below_usize(3)];
    cfg.idle_tree_latency = [0, 2, 4][rng.below_usize(3)];
    cfg.exec = exec;
    cfg.routing = routing;
    // En-route claim policy and its knobs vary per case: every policy must
    // keep active-set and dense-oracle stepping bit-identical (the claim
    // phase is the one pass the two modes visit with different PE sets).
    cfg.claim = ClaimPolicy::ALL[rng.below_usize(ClaimPolicy::ALL.len())];
    cfg.claim_credit_period = 2 + rng.below(5); // 2..=6
    cfg.claim_steal_threshold = 1 + rng.below_usize(3); // 1..=3
    cfg.trigger_latency = rng.below(2);
    cfg.max_cycles = 20_000;
    cfg.seed = rng.next_u64();
    cfg.validate().expect("random config must be valid");
    cfg
}

/// Layer a randomized topology onto a [`random_cfg`] draw: Ruche strides
/// vary 2..=3, chiplet tile dims are random divisors of the mesh dims with
/// a random 1..=4-cycle crossing latency.
fn random_topo_cfg(
    rng: &mut SplitMix64,
    exec: ExecPolicy,
    routing: RoutingPolicy,
    kind: TopologyKind,
) -> ArchConfig {
    let mut cfg = random_cfg(rng, exec, routing);
    cfg.topology = kind;
    match kind {
        TopologyKind::Ruche => cfg.ruche_stride = 2 + rng.below_usize(2),
        TopologyKind::Chiplet2L => {
            let divisors = |n: usize| (1..=n).filter(|d| n % d == 0).collect::<Vec<usize>>();
            let (ws, hs) = (divisors(cfg.width), divisors(cfg.height));
            cfg.chiplet_dims = (ws[rng.below_usize(ws.len())], hs[rng.below_usize(hs.len())]);
            cfg.inter_chiplet_latency = 1 + rng.below_usize(4);
        }
        TopologyKind::Mesh2D | TopologyKind::Torus2D => {}
    }
    cfg.validate().expect("random topology config must be valid");
    cfg
}

/// Shared configuration-memory table for the random programs. Entry roles:
///
/// - 0: `Add -> 1` (res addr) — relaxation hop: dist + weight …
/// - 1: `AccMin -> 0` (res addr) — … min-updated at the owner, re-triggering
///   entry 0 on improvement (the SSSP cascade shape);
/// - 2: `Mul -> 3` — MAC chains: Load feeds a Mul …
/// - 3: `Accum -> 3` (res addr) — … accumulated at the output owner;
/// - 4: `Add -> 3` (res addr) — stream fan-out: emitted Adds then Accum.
fn install_config(b: &mut ProgramBuilder) {
    assert_eq!(b.config(ConfigEntry::new(Opcode::Add, 1).res_addr()), 0);
    assert_eq!(b.config(ConfigEntry::new(Opcode::AccMin, 0).res_addr()), 1);
    assert_eq!(b.config(ConfigEntry::new(Opcode::Mul, 3)), 2);
    assert_eq!(b.config(ConfigEntry::new(Opcode::Accum, 3).res_addr()), 3);
    assert_eq!(b.config(ConfigEntry::new(Opcode::Add, 3).res_addr()), 4);
}

/// A random small workload mixing the fabric's message shapes: remote
/// stores, Load→Mul→Accum MAC chains, `Stream` fan-outs, and AccMin
/// relaxation cascades. Density (message count per shape) is randomized per
/// case; every written word is registered as a program output so the
/// differential comparison covers all of them.
fn random_program(rng: &mut SplitMix64, cfg: &ArchConfig) -> Program {
    let n = cfg.num_pes();
    let mut b = ProgramBuilder::new("prop-case", cfg);
    install_config(&mut b);

    let n_store = rng.below_usize(11);
    let n_mac = rng.below_usize(9);
    let n_fanout = rng.below_usize(3);
    let relax_chain = if rng.chance(0.6) { 2 + rng.below_usize(3) } else { 0 };

    // Remote stores: one static AM, terminal at the destination.
    for i in 0..n_store {
        let src = rng.below_usize(n);
        let dst = rng.below_usize(n);
        let addr = b.alloc(dst, 1);
        let mut am = Message::new();
        am.opcode = Opcode::Store;
        am.op1 = (1 + i) as u16;
        am.result = addr;
        am.res_is_addr = true;
        am.push_dest(dst as u16);
        b.static_am(src, am);
        b.output(dst, addr);
    }

    // MAC chains: Load x at the data owner, Mul anywhere (en-route
    // eligible), Accum at the output owner.
    for _ in 0..n_mac {
        let src = rng.below_usize(n);
        let data_pe = rng.below_usize(n);
        let out_pe = rng.below_usize(n);
        let x = 1 + rng.below(5) as i16;
        let w = 1 + rng.below(5) as u16;
        let init = rng.below(10) as i16;
        let xa = b.place(data_pe, &[x]);
        let ya = b.place(out_pe, &[init]);
        let mut am = Message::new();
        am.opcode = Opcode::Load; // op2 <- dmem[op2] at data_pe
        am.n_pc = 2; // -> Mul -> Accum
        am.op1 = w;
        am.op2 = xa;
        am.op2_is_addr = true;
        am.result = ya;
        am.res_is_addr = true;
        am.push_dest(data_pe as u16);
        am.push_dest(out_pe as u16);
        b.static_am(src, am);
        b.output(out_pe, ya);
    }

    // Stream fan-outs: one Stream trigger emits per-destination Adds that
    // accumulate into scattered target words.
    for _ in 0..n_fanout {
        let src = rng.below_usize(n);
        let k = 1 + rng.below_usize(4);
        let mut elems = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..k {
            let pe = rng.below_usize(n);
            let addr = b.place(pe, &[rng.below(20) as i16]);
            outs.push((pe, addr));
            elems.push(StreamElem {
                value: 1 + rng.below(9) as i16,
                aux: addr,
                dest_pe: pe as u16,
                mode: StreamMode::PerDest,
            });
        }
        let base = b.stream(src, &elems);
        let key = b.keyed_trigger(src, base, k as u16);
        let mut am = Message::new();
        am.opcode = Opcode::Stream;
        am.n_pc = 4; // emitted AMs: Add -> Accum
        am.op1 = rng.below(6) as u16;
        am.op2 = key;
        am.op2_is_addr = true;
        am.push_dest(src as u16);
        b.static_am(src, am);
        for &(pe, addr) in &outs {
            b.output(pe, addr);
        }
    }

    // AccMin relaxation chain: node i's trigger streams an edge to node
    // i+1 (positive weights, so the cascade terminates), seeded by one
    // AccMin AM at node 0 — the BFS/SSSP shape with conditional
    // re-emission.
    if relax_chain > 0 {
        let nodes: Vec<usize> = (0..relax_chain).map(|_| rng.below_usize(n)).collect();
        let dists: Vec<u16> = nodes
            .iter()
            .map(|&pe| b.place(pe, &[nexus::tensor::graph::INF]))
            .collect();
        for i in 0..relax_chain - 1 {
            let e = StreamElem {
                value: 1 + rng.below(7) as i16,
                aux: dists[i + 1],
                dest_pe: nodes[i + 1] as u16,
                mode: StreamMode::PerDest,
            };
            let base = b.stream(nodes[i], &[e]);
            b.trigger(nodes[i], dists[i], base, 1);
        }
        let mut am = Message::new();
        am.opcode = Opcode::AccMin;
        am.n_pc = 0; // on improvement: emitted Add -> AccMin (cascade)
        am.op1 = rng.below(4) as u16;
        am.result = dists[0];
        am.res_is_addr = true;
        am.push_dest(nodes[0] as u16);
        b.static_am(rng.below_usize(n), am);
        for (i, &pe) in nodes.iter().enumerate() {
            b.output(pe, dists[i]);
        }
    }

    // Never emit a completely empty program (the comparison would be
    // vacuous): fall back to a single store.
    if n_store + n_mac + n_fanout == 0 && relax_chain == 0 {
        let addr = b.alloc(n - 1, 1);
        let mut am = Message::new();
        am.opcode = Opcode::Store;
        am.op1 = 42;
        am.result = addr;
        am.res_is_addr = true;
        am.push_dest((n - 1) as u16);
        b.static_am(0, am);
        b.output(n - 1, addr);
    }
    b.build()
}

/// Random tracing configuration for one case: off, full, a bounded flight
/// recorder, or a custom draw over capacities and event-class toggles.
/// Tracing must be invisible to every differential comparison, so each
/// case runs exactly one side traced and the other untraced — any
/// perturbation (a counter, a PRNG draw, a schedule change) shows up as a
/// cross-mode divergence.
fn random_trace_cfg(rng: &mut SplitMix64) -> TraceConfig {
    match rng.below_usize(4) {
        0 => TraceConfig::off(),
        1 => TraceConfig::full(),
        2 => TraceConfig::flight_recorder(1 + rng.below_usize(64)),
        _ => {
            let mut t = TraceConfig {
                enabled: true,
                shard_capacity: [1, 8, 1 << 10][rng.below_usize(3)],
                sink_capacity: [0, 1, 16][rng.below_usize(3)],
                lifecycle: rng.chance(0.7),
                pe_states: rng.chance(0.7),
            };
            if !t.lifecycle && !t.pe_states {
                t.lifecycle = true;
            }
            t
        }
    }
}

/// Outcome of one scheduler run, normalized for comparison.
type RunOutcome = Result<(Vec<i16>, u64, FabricStats), DeadlockError>;

fn run_mode(prog: &Program, cfg: &ArchConfig, mode: StepMode) -> (RunOutcome, NexusFabric) {
    let mut f = NexusFabric::new(cfg.clone().with_step_mode(mode));
    let r = f
        .run_program(prog)
        .map(|out| (out, f.cycles(), f.stats.clone()));
    (r, f)
}

/// Lockstep both schedulers over `prog` and return the first cycle whose
/// post-commit state digests differ (the mismatch diagnosis in failure
/// reports).
fn first_diverging_cycle(prog: &Program, cfg: &ArchConfig) -> Option<u64> {
    let mut fa = NexusFabric::new(cfg.clone().with_step_mode(StepMode::ActiveSet));
    let mut fd = NexusFabric::new(cfg.clone().with_step_mode(StepMode::DenseOracle));
    fa.begin_program(prog);
    fd.begin_program(prog);
    if fa.state_digest() != fd.state_digest() {
        return Some(fa.cycles());
    }
    for _ in 0..cfg.max_cycles + cfg.idle_tree_latency + 2 {
        fa.step();
        fd.step();
        if fa.state_digest() != fd.state_digest() {
            return Some(fa.cycles());
        }
        if fa.is_drained() && fd.is_drained() {
            return None;
        }
    }
    None
}

/// The core property: active-set and dense-oracle stepping are
/// indistinguishable — identical outputs, cycle counts, and stats on
/// success, identical timeout reports on deadlock.
fn equivalent(rng: &mut SplitMix64, exec: ExecPolicy, routing: RoutingPolicy) -> Result<(), String> {
    let cfg = random_cfg(rng, exec, routing);
    equivalent_on(rng, cfg)
}

/// [`equivalent`] over a caller-built configuration (the per-topology
/// variants feed [`random_topo_cfg`] draws through here).
fn equivalent_on(rng: &mut SplitMix64, cfg: ArchConfig) -> Result<(), String> {
    let prog = random_program(rng, &cfg);
    // Trace exactly the active-set side with a random config: every
    // comparison below then doubles as a trace-neutrality assertion.
    let traced = cfg.clone().with_trace(random_trace_cfg(rng));
    let (ra, fa) = run_mode(&prog, &traced, StepMode::ActiveSet);
    let (rd, _fd) = run_mode(&prog, &cfg, StepMode::DenseOracle);
    let diverged = || {
        first_diverging_cycle(&prog, &cfg)
            .map(|c| format!("first diverging cycle: {c}"))
            .unwrap_or_else(|| "no digest divergence found (writeback-only?)".into())
    };
    match (ra, rd) {
        (Ok((out_a, cyc_a, st_a)), Ok((out_d, cyc_d, st_d))) => {
            ensure(out_a == out_d, || {
                format!("outputs diverged ({}); active {out_a:?} vs dense {out_d:?}", diverged())
            })?;
            ensure(cyc_a == cyc_d, || {
                format!("cycles diverged: active {cyc_a} vs dense {cyc_d}; {}", diverged())
            })?;
            if let Some(field) = st_a.diff(&st_d) {
                return Err(format!("stats diverged on {field}; {}", diverged()));
            }
            // The active-set run must also pass conservation + wake audits.
            fa.check_conservation()
                .map_err(|e| format!("active-set conservation: {e}"))
        }
        (Err(ea), Err(ed)) => {
            ensure(ea.cycle == ed.cycle && ea.in_flight == ed.in_flight, || {
                format!(
                    "timeout reports diverged: active (cycle {}, {} in flight) vs \
                     dense (cycle {}, {} in flight); {}",
                    ea.cycle,
                    ea.in_flight,
                    ed.cycle,
                    ed.in_flight,
                    diverged()
                )
            })?;
            ensure(ea.culprits == ed.culprits, || {
                format!("culprit lists diverged: {:?} vs {:?}", ea.culprits, ed.culprits)
            })
        }
        (Ok((_, cyc, _)), Err(e)) => Err(format!(
            "active-set drained at cycle {cyc} but dense deadlocked at {}; {}",
            e.cycle,
            diverged()
        )),
        (Err(e), Ok((_, cyc, _))) => Err(format!(
            "dense drained at cycle {cyc} but active-set deadlocked at {}; {}",
            e.cycle,
            diverged()
        )),
    }
}

macro_rules! equivalence_test {
    ($name:ident, $seed:expr, $exec:expr, $routing:expr) => {
        #[test]
        fn $name() {
            forall_seeded($seed, prop_cases(), &mut |rng| {
                equivalent(rng, $exec, $routing)
            });
        }
    };
}

equivalence_test!(
    equivalence_enroute_turnmodel,
    0xE1,
    ExecPolicy::EnRoute,
    RoutingPolicy::TurnModelAdaptive
);
equivalence_test!(equivalence_enroute_xy, 0xE2, ExecPolicy::EnRoute, RoutingPolicy::Xy);
equivalence_test!(
    equivalence_enroute_valiant,
    0xE3,
    ExecPolicy::EnRoute,
    RoutingPolicy::Valiant
);
equivalence_test!(
    equivalence_destonly_turnmodel,
    0xD1,
    ExecPolicy::DestinationOnly,
    RoutingPolicy::TurnModelAdaptive
);
equivalence_test!(
    equivalence_destonly_xy,
    0xD2,
    ExecPolicy::DestinationOnly,
    RoutingPolicy::Xy
);
equivalence_test!(
    equivalence_destonly_valiant,
    0xD3,
    ExecPolicy::DestinationOnly,
    RoutingPolicy::Valiant
);

/// Per-topology equivalence: on every non-mesh topology, active-set vs
/// dense-oracle stepping stays bit-identical across random geometries,
/// topology parameters (stride / chiplet tiling / crossing latency), exec
/// policies, and routing policies. Runs half the case budget per topology.
macro_rules! topology_equivalence_test {
    ($name:ident, $seed:expr, $kind:expr) => {
        #[test]
        fn $name() {
            forall_seeded($seed, (prop_cases() / 2).max(50), &mut |rng| {
                let exec = if rng.chance(0.5) {
                    ExecPolicy::EnRoute
                } else {
                    ExecPolicy::DestinationOnly
                };
                let routing = [
                    RoutingPolicy::TurnModelAdaptive,
                    RoutingPolicy::Xy,
                    RoutingPolicy::Valiant,
                ][rng.below_usize(3)];
                let cfg = random_topo_cfg(rng, exec, routing, $kind);
                equivalent_on(rng, cfg)
            });
        }
    };
}

topology_equivalence_test!(equivalence_topology_torus, 0x701, TopologyKind::Torus2D);
topology_equivalence_test!(equivalence_topology_ruche, 0x702, TopologyKind::Ruche);
topology_equivalence_test!(equivalence_topology_chiplet, 0x703, TopologyKind::Chiplet2L);

/// Lockstep variant: instead of only comparing end states, step both
/// schedulers cycle by cycle and require equal state digests at *every*
/// boundary, with the wake-list invariants holding throughout. Stronger
/// (and much slower — a full-state digest per cycle per fabric), so it runs
/// an eighth of the case budget.
#[test]
fn lockstep_digests_and_wake_invariants() {
    let cases = (prop_cases() / 8).max(16);
    forall_seeded(0x10C5, cases, &mut |rng| {
        let exec = if rng.chance(0.5) { ExecPolicy::EnRoute } else { ExecPolicy::DestinationOnly };
        let routing = [
            RoutingPolicy::TurnModelAdaptive,
            RoutingPolicy::Xy,
            RoutingPolicy::Valiant,
        ][rng.below_usize(3)];
        let kind = TopologyKind::ALL[rng.below_usize(TopologyKind::ALL.len())];
        let mut cfg = random_topo_cfg(rng, exec, routing, kind);
        // Small data memories keep the per-cycle full-state digest cheap
        // (the random programs use well under 128 words per PE).
        cfg.dmem_words = 128;
        let prog = random_program(rng, &cfg);
        // Tracing the active side turns every per-cycle digest comparison
        // into a cycle-resolved trace-neutrality check.
        let mut fa = NexusFabric::new(
            cfg.clone()
                .with_step_mode(StepMode::ActiveSet)
                .with_trace(random_trace_cfg(rng)),
        );
        let mut fd = NexusFabric::new(cfg.clone().with_step_mode(StepMode::DenseOracle));
        fa.begin_program(&prog);
        fd.begin_program(&prog);
        let budget = cfg.max_cycles + cfg.idle_tree_latency + 2;
        for _ in 0..budget {
            fa.step();
            fd.step();
            ensure(fa.state_digest() == fd.state_digest(), || {
                format!("state digests diverged at cycle {}", fa.cycles())
            })?;
            fa.check_wake_consistency()
                .map_err(|e| format!("active-set wake audit at cycle {}: {e}", fa.cycles()))?;
            fd.check_wake_consistency()
                .map_err(|e| format!("dense wake audit at cycle {}: {e}", fd.cycles()))?;
            ensure(fa.is_drained() == fd.is_drained(), || {
                format!("drain detectors disagreed at cycle {}", fa.cycles())
            })?;
            if fa.is_drained() {
                return Ok(());
            }
        }
        Err(format!("program did not drain within {budget} cycles"))
    });
}

/// Regression (extends the PR-1 reset-determinism test to the active-set
/// core): `reset()` followed by `run_program` is bit-identical to a fresh
/// fabric *in both step modes*, on random programs.
#[test]
fn reset_is_bit_identical_in_both_modes() {
    forall_seeded(0x5E5E, (prop_cases() / 4).max(25), &mut |rng| {
        let cfg = random_cfg(rng, ExecPolicy::EnRoute, RoutingPolicy::TurnModelAdaptive);
        let prog = random_program(rng, &cfg);
        let dirty = random_program(rng, &cfg);
        for mode in [StepMode::ActiveSet, StepMode::DenseOracle] {
            let cfg = cfg.clone().with_step_mode(mode);
            let mut fresh = NexusFabric::new(cfg.clone());
            let out_fresh = fresh.run_program(&prog).map_err(|e| e.to_string())?;
            let mut reused = NexusFabric::new(cfg);
            let _ = reused.run_program(&dirty); // dirty the instance
            reused.reset();
            let out_reused = reused.run_program(&prog).map_err(|e| e.to_string())?;
            ensure(out_fresh == out_reused, || {
                format!("{mode:?}: outputs diverged after reset")
            })?;
            if let Some(field) = fresh.stats.diff(&reused.stats) {
                return Err(format!("{mode:?}: stats diverged after reset on {field}"));
            }
            ensure(fresh.state_digest() == reused.state_digest(), || {
                format!("{mode:?}: state digests diverged after reset")
            })?;
        }
        Ok(())
    });
}

/// Draw a random sharded configuration on `kind`: an even mesh height (so
/// shard counts 2 and 4 are reachable), a shard count drawn from the
/// divisors of the height, a random step mode, and 2..=4 worker threads.
fn random_sharded_cfg(rng: &mut SplitMix64, kind: TopologyKind) -> ArchConfig {
    let exec = if rng.chance(0.5) { ExecPolicy::EnRoute } else { ExecPolicy::DestinationOnly };
    let routing = [
        RoutingPolicy::TurnModelAdaptive,
        RoutingPolicy::Xy,
        RoutingPolicy::Valiant,
    ][rng.below_usize(3)];
    let mut cfg = loop {
        let c = random_topo_cfg(rng, exec, routing, kind);
        if c.height % 2 == 0 {
            break c;
        }
    };
    let shard_opts: Vec<usize> = [2usize, 4].into_iter().filter(|s| cfg.height % s == 0).collect();
    cfg.shards = shard_opts[rng.below_usize(shard_opts.len())];
    cfg.threads = 2 + rng.below_usize(3); // 2..=4
    if rng.chance(0.5) {
        cfg.step_mode = StepMode::DenseOracle;
    }
    cfg.validate().expect("random sharded config must be valid");
    cfg
}

/// Lockstep diagnosis for the sharded suite: step a single-threaded fabric
/// cycle by cycle against the parallel engine's per-epoch digest trace and
/// return the first cycle whose digests differ.
fn sharded_first_diverging_cycle(prog: &Program, cfg: &ArchConfig, epochs: u64) -> Option<u64> {
    let mut serial = NexusFabric::new(cfg.clone().with_threads(1));
    let mut parallel = NexusFabric::new(cfg.clone());
    serial.begin_program(prog);
    parallel.begin_program(prog);
    let trace = parallel.run_cycles_parallel(epochs);
    for &digest in &trace {
        serial.step();
        if serial.state_digest() != digest {
            return Some(serial.cycles());
        }
    }
    None
}

/// The sharded-stepping property: for a fixed shard count, the parallel
/// engine (threads >= 2) is **bit-identical** to single-threaded stepping —
/// same outputs, cycle counts, and stats on success, same deadlock reports
/// on timeout — across random geometries, topologies, step modes, and
/// policies. Divergences are diagnosed down to the first differing cycle
/// via the per-epoch digest trace.
fn sharded_equivalent(rng: &mut SplitMix64, kind: TopologyKind) -> Result<(), String> {
    let cfg = random_sharded_cfg(rng, kind);
    let prog = random_program(rng, &cfg);
    // The multi-threaded side runs traced: the shard rings are filled by
    // worker threads and merged at epoch barriers, and none of it may
    // disturb the serial-vs-parallel comparison.
    let trace = random_trace_cfg(rng);
    let run = |threads: usize, trace: TraceConfig| {
        let mut f = NexusFabric::new(cfg.clone().with_threads(threads).with_trace(trace));
        let r = f.run_program(&prog).map(|out| (out, f.cycles(), f.stats.clone()));
        (r, f)
    };
    let (rs, fs) = run(1, TraceConfig::off());
    let (rp, _fp) = run(cfg.threads, trace);
    let diverged = || {
        sharded_first_diverging_cycle(&prog, &cfg, 2_000)
            .map(|c| format!("first diverging cycle: {c}"))
            .unwrap_or_else(|| "no digest divergence in the first 2000 cycles".into())
    };
    match (rs, rp) {
        (Ok((out_s, cyc_s, st_s)), Ok((out_p, cyc_p, st_p))) => {
            ensure(out_s == out_p, || {
                format!(
                    "shards={} threads={}: outputs diverged ({}); serial {out_s:?} vs \
                     parallel {out_p:?}",
                    cfg.shards,
                    cfg.threads,
                    diverged()
                )
            })?;
            ensure(cyc_s == cyc_p, || {
                format!(
                    "shards={} threads={}: cycles diverged: serial {cyc_s} vs parallel \
                     {cyc_p}; {}",
                    cfg.shards,
                    cfg.threads,
                    diverged()
                )
            })?;
            if let Some(field) = st_s.diff(&st_p) {
                return Err(format!(
                    "shards={} threads={}: stats diverged on {field}; {}",
                    cfg.shards,
                    cfg.threads,
                    diverged()
                ));
            }
            fs.check_conservation()
                .map_err(|e| format!("serial sharded conservation: {e}"))
        }
        (Err(es), Err(ep)) => ensure(
            es.cycle == ep.cycle && es.in_flight == ep.in_flight && es.culprits == ep.culprits,
            || {
                format!(
                    "shards={} threads={}: timeout reports diverged: serial (cycle {}, {} \
                     in flight) vs parallel (cycle {}, {} in flight); {}",
                    cfg.shards,
                    cfg.threads,
                    es.cycle,
                    es.in_flight,
                    ep.cycle,
                    ep.in_flight,
                    diverged()
                )
            },
        ),
        (Ok((_, cyc, _)), Err(e)) => Err(format!(
            "serial drained at cycle {cyc} but parallel deadlocked at {}; {}",
            e.cycle,
            diverged()
        )),
        (Err(e), Ok((_, cyc, _))) => Err(format!(
            "parallel drained at cycle {cyc} but serial deadlocked at {}; {}",
            e.cycle,
            diverged()
        )),
    }
}

macro_rules! sharded_equivalence_test {
    ($name:ident, $seed:expr, $kind:expr) => {
        #[test]
        fn $name() {
            forall_seeded($seed, (prop_cases() / 4).max(25), &mut |rng| {
                sharded_equivalent(rng, $kind)
            });
        }
    };
}

sharded_equivalence_test!(sharded_lockstep_mesh, 0x5A1, TopologyKind::Mesh2D);
sharded_equivalence_test!(sharded_lockstep_torus, 0x5A2, TopologyKind::Torus2D);
sharded_equivalence_test!(sharded_lockstep_ruche, 0x5A3, TopologyKind::Ruche);
sharded_equivalence_test!(sharded_lockstep_chiplet, 0x5A4, TopologyKind::Chiplet2L);

/// Active-set vs dense-oracle equivalence *under sharding*: with shards=2
/// and a multi-threaded engine, the two scheduler modes must still be
/// bit-identical (the cross-mode property composes with the cross-thread
/// one).
#[test]
fn sharded_active_vs_dense_equivalence() {
    forall_seeded(0x5AD, (prop_cases() / 4).max(25), &mut |rng| {
        let mut cfg = random_sharded_cfg(rng, TopologyKind::Mesh2D);
        cfg.shards = 2;
        cfg.step_mode = StepMode::ActiveSet;
        equivalent_on(rng, cfg)
    });
}

/// Same seed, same shard count, **any** thread count: the per-epoch digest
/// traces and program outputs must be byte-for-byte identical at 1, 2, 3,
/// and 4 worker threads (`threads` is host-side only).
#[test]
fn sharded_same_seed_any_thread_count_is_deterministic() {
    forall_seeded(0x7D7D, (prop_cases() / 8).max(16), &mut |rng| {
        let cfg = random_sharded_cfg(rng, TopologyKind::Mesh2D);
        let prog = random_program(rng, &cfg);
        let trace_at = |threads: usize| {
            let mut f = NexusFabric::new(cfg.clone().with_threads(threads));
            f.begin_program(&prog);
            f.run_cycles_parallel(400)
        };
        let baseline = trace_at(1);
        for threads in 2..=4 {
            let t = trace_at(threads);
            if let Some(cycle) = baseline.iter().zip(&t).position(|(a, b)| a != b) {
                return Err(format!(
                    "shards={}: digest trace at {threads} threads diverged from \
                     single-threaded at cycle {cycle}",
                    cfg.shards
                ));
            }
        }
        let out_at = |threads: usize| {
            let mut f = NexusFabric::new(cfg.clone().with_threads(threads));
            f.run_program(&prog).map_err(|e| e.to_string())
        };
        let base_out = out_at(1);
        for threads in 2..=4 {
            ensure(out_at(threads) == base_out, || {
                format!("shards={}: outputs differ at {threads} threads", cfg.shards)
            })?;
        }
        Ok(())
    });
}

/// Full-suite equivalence on real workloads through the `Machine` session
/// layer: cycle counts, outputs, and the complete stats block must agree on
/// representative sparse / dense / graph kernels for each fabric variant.
#[test]
fn suite_workloads_equivalent_across_modes() {
    use nexus::machine::Machine;
    let specs = nexus::workloads::suite(1);
    let picks: Vec<_> = specs
        .iter()
        .filter(|s| {
            let n = s.name();
            n.starts_with("SpMV") || n == "SpMSpM-S4" || n == "BFS" || n == "Conv"
        })
        .collect();
    let names: Vec<String> = picks.iter().map(|s| s.name()).collect();
    assert!(picks.len() >= 3, "suite changed shape: {names:?}");
    for base in [ArchConfig::nexus(), ArchConfig::tia(), ArchConfig::tia_valiant()] {
        let mut active = Machine::new(base.clone());
        let mut dense = Machine::new(base.clone().with_step_mode(StepMode::DenseOracle));
        for spec in &picks {
            let ea = active.run(spec).expect("active-set run");
            let ed = dense.run(spec).expect("dense-oracle run");
            assert_eq!(ea.outputs, ed.outputs, "{} on {}", spec.name(), base.kind.name());
            assert_eq!(ea.cycles(), ed.cycles(), "{} on {}", spec.name(), base.kind.name());
            let (sa, sd) = (ea.stats.unwrap(), ed.stats.unwrap());
            if let Some(field) = sa.diff(&sd) {
                panic!("{} on {}: stats diverged on {field}", spec.name(), base.kind.name());
            }
        }
    }
}

/// Every placement × claim policy combination must preserve active-set vs
/// dense-oracle equivalence on a real SpMV workload (the random-program
/// suites above cover claim policies but bypass the partitioner, so
/// placement coverage has to come through the `Machine` layer).
#[test]
fn placement_and_claim_policies_equivalent_across_modes() {
    use nexus::machine::Machine;
    let specs = nexus::workloads::suite(1);
    let spec = specs
        .iter()
        .find(|s| s.name().starts_with("SpMV"))
        .expect("suite must contain an SpMV spec");
    for placement in PlacementPolicy::ALL {
        for claim in ClaimPolicy::ALL {
            let base = ArchConfig::nexus().with_placement(placement).with_claim(claim);
            let mut active = Machine::new(base.clone());
            let mut dense = Machine::new(base.with_step_mode(StepMode::DenseOracle));
            let ea = active.run(spec).expect("active-set run");
            let ed = dense.run(spec).expect("dense-oracle run");
            let tag = format!("{}+{}", placement.name(), claim.name());
            assert_eq!(ea.outputs, ed.outputs, "outputs diverged under {tag}");
            assert_eq!(ea.cycles(), ed.cycles(), "cycles diverged under {tag}");
            let (sa, sd) = (ea.stats.unwrap(), ed.stats.unwrap());
            if let Some(field) = sa.diff(&sd) {
                panic!("{tag}: stats diverged on {field}");
            }
        }
    }
}
