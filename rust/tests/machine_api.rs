//! Integration tests for the unified `Machine` execution API: typed errors
//! through the public surface, fabric-reset determinism, compile caching,
//! and pooled batch execution.

use nexus::baselines::systolic::Systolic;
use nexus::config::ArchConfig;
use nexus::machine::{ExecError, Machine, MachinePool};
use nexus::workloads::{suite, Spec};

/// Systolic arrays cannot express graph analytics: the machine reports a
/// typed `Unsupported` error instead of an `Option` or a panic.
#[test]
fn systolic_on_bfs_is_unsupported() {
    let specs = suite(1);
    let bfs = specs.iter().find(|s| s.name() == "BFS").unwrap();
    let mut m = Machine::from_backend(Box::new(Systolic::default()));
    match m.run(bfs) {
        Err(ExecError::Unsupported { arch, workload }) => {
            assert_eq!(arch, "Systolic");
            assert_eq!(workload, "BFS");
        }
        Ok(_) => panic!("systolic must not run BFS"),
        Err(e) => panic!("expected Unsupported, got {e}"),
    }
}

/// The systolic machine still runs everything the roster expects of it.
#[test]
fn systolic_supports_the_dense_and_sparse_suite() {
    let mut m = Machine::from_backend(Box::new(Systolic::default()));
    for spec in suite(1).iter().filter(|s| s.class() != "graph") {
        let e = m.run(spec).unwrap_or_else(|err| panic!("{}: {err}", spec.name()));
        assert!(e.cycles() > 0);
    }
}

/// A deadlocking program (cycle budget exhausted) must surface as a typed
/// `Err` through `Machine::execute`, never as a panic. An undersized
/// `max_cycles` on a real workload is the simplest public-API reproducer.
#[test]
fn deadlock_surfaces_as_err_through_machine_execute() {
    let specs = suite(1);
    let spmv = specs.iter().find(|s| s.name().starts_with("SpMV")).unwrap();
    let mut cfg = ArchConfig::nexus();
    cfg.max_cycles = 1; // no workload drains in one cycle
    let mut m = Machine::new(cfg);
    match m.run(spmv) {
        Err(ExecError::Deadlock(e)) => {
            assert!(e.cycle > 0);
            assert!(!e.detail.is_empty());
        }
        Ok(_) => panic!("one cycle cannot drain SpMV"),
        Err(e) => panic!("expected Deadlock, got {e}"),
    }
}

/// A deliberately undersized fabric (minimum-legal router buffers plus a
/// tight cycle budget) must surface `ExecError::Deadlock` whose report
/// *names the culprits*: which PEs/routers still hold work, and in which
/// queues. This is the contract sweep harnesses rely on to triage hangs
/// without re-running under a debugger.
#[test]
fn deadlock_report_names_culprit_components() {
    let specs = suite(1);
    let spmv = specs.iter().find(|s| s.name().starts_with("SpMV")).unwrap();
    let mut cfg = ArchConfig::nexus();
    cfg.router_buf_depth = 2; // minimum legal depth: maximum backpressure
    cfg.max_cycles = 40; // far too few cycles to drain
    let mut m = Machine::new(cfg);
    match m.run(spmv) {
        Err(ExecError::Deadlock(e)) => {
            assert!(
                !e.culprits.is_empty(),
                "timeout must name the components holding work"
            );
            assert!(
                e.culprits
                    .iter()
                    .all(|c| c.starts_with("PE") || c.starts_with('R')),
                "culprits must be PE/router entries: {:?}",
                e.culprits
            );
            // The human-readable Display carries the culprit list too.
            let shown = e.to_string();
            assert!(shown.contains("culprit"), "{shown}");
        }
        Ok(_) => panic!("40 cycles cannot drain SpMV"),
        Err(e) => panic!("expected Deadlock, got {e}"),
    }
}

/// `NexusFabric::reset()` reuse must be bit-identical to a freshly
/// constructed fabric: run two suite workloads back to back on one machine,
/// then compare outputs *and* full stats against fresh single-use machines.
#[test]
fn fabric_reset_matches_fresh_fabric_bit_for_bit() {
    let specs = suite(1);
    let picks: Vec<&Spec> = vec![
        specs.iter().find(|s| s.name().starts_with("SpMV")).unwrap(),
        specs.iter().find(|s| s.name() == "BFS").unwrap(),
    ];
    let cfg = ArchConfig::nexus();
    let mut session = Machine::new(cfg.clone());
    // Interleave: SpMV, BFS, then SpMV again from the compile cache.
    let first = session.run(picks[0]).unwrap();
    let second = session.run(picks[1]).unwrap();
    let third = session.run(picks[0]).unwrap();
    for (spec, reused) in [(picks[0], &first), (picks[1], &second), (picks[0], &third)] {
        let fresh = Machine::new(cfg.clone()).run(spec).unwrap();
        assert_eq!(fresh.outputs, reused.outputs, "{}", spec.name());
        assert_eq!(fresh.stats, reused.stats, "{}", spec.name());
        assert_eq!(fresh.result.cycles, reused.result.cycles, "{}", spec.name());
    }
}

/// Recompiling a workload on the same machine hits the cache.
#[test]
fn compile_cache_skips_recompilation() {
    let specs = suite(1);
    let spmv = specs.iter().find(|s| s.name().starts_with("SpMV")).unwrap();
    let mut m = Machine::new(ArchConfig::nexus());
    m.compile(spmv).unwrap();
    m.compile(spmv).unwrap();
    m.run(spmv).unwrap();
    assert_eq!(m.cached_programs(), 1);
}

/// Pooled batch execution returns results in job order with per-worker
/// machine reuse.
#[test]
fn pool_runs_suite_batch_in_order() {
    let specs = suite(1);
    let cfg = ArchConfig::nexus();
    let cycles = MachinePool::with_workers(4).run_batch_with(
        || Machine::new(cfg.clone()),
        &specs,
        |m, spec| m.run(spec).unwrap().cycles(),
    );
    assert_eq!(cycles.len(), specs.len());
    // Same batch serially on one machine must agree (order + determinism).
    let mut serial = Machine::new(cfg);
    for (spec, &c) in specs.iter().zip(&cycles) {
        assert_eq!(serial.run(spec).unwrap().cycles(), c, "{}", spec.name());
    }
}
