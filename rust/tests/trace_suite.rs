//! Integration suite for the tracing subsystem: zero-perturbation of
//! traced runs, event-count conservation against `FabricStats`, the
//! Chrome/Perfetto export round-trip (reparsed with the `nexus serve`
//! JSON parser), and the flight recorder riding on deadlock reports.

use nexus::config::ArchConfig;
use nexus::machine::{config_tag, Machine};
use nexus::serve::protocol::{parse_json, Json};
use nexus::trace::{chrome_trace_json, EventKind, TraceConfig};
use nexus::workloads::{suite, Spec};

fn pick<'a>(specs: &'a [Spec], prefix: &str) -> &'a Spec {
    specs
        .iter()
        .find(|s| s.name().starts_with(prefix))
        .unwrap_or_else(|| panic!("suite must contain a {prefix} spec"))
}

/// A traced run is bit-identical to an untraced one, and the captured
/// event stream conserves the commit counters: per PE, `AluCommit +
/// MemOp` events equal `per_pe_committed_ops`, and `Retire` events equal
/// `msgs_retired` — on the serial fabric and on a sharded multi-threaded
/// one.
#[test]
fn traced_run_matches_untraced_and_conserves_commit_events() {
    let specs = suite(1);
    let spec = pick(&specs, "SpMV");
    for (shards, threads) in [(1usize, 1usize), (2, 2)] {
        let base = ArchConfig::nexus().with_shards(shards).with_threads(threads);
        let mut plain = Machine::new(base.clone());
        let mut traced = Machine::new(base.clone().with_trace(TraceConfig::full()));
        let ep = plain.run(spec).expect("untraced run");
        let et = traced.run(spec).expect("traced run");
        let tag = format!("shards={shards} threads={threads}");
        assert_eq!(ep.outputs, et.outputs, "{tag}: outputs diverged");
        assert_eq!(ep.cycles(), et.cycles(), "{tag}: cycles diverged");
        let (sp, st) = (ep.stats.unwrap(), et.stats.unwrap());
        if let Some(field) = sp.diff(&st) {
            panic!("{tag}: stats diverged on {field}");
        }
        assert!(ep.trace.is_none(), "untraced execution must carry no trace");
        let events = et.trace.expect("traced execution must carry events");
        assert!(!events.is_empty(), "{tag}: no events captured");
        // The epoch-merged stream is nondecreasing in cycle at any
        // shard/thread count.
        assert!(
            events.windows(2).all(|w| w[0].cycle <= w[1].cycle),
            "{tag}: merged stream must be sorted by cycle"
        );
        let mut commits = vec![0u64; base.num_pes()];
        let mut retires = 0u64;
        for ev in &events {
            match ev.kind {
                EventKind::AluCommit | EventKind::MemOp => commits[ev.pe as usize] += 1,
                EventKind::Retire => retires += 1,
                _ => {}
            }
        }
        assert_eq!(
            commits, st.per_pe_committed_ops,
            "{tag}: commit events must conserve per_pe_committed_ops"
        );
        assert_eq!(
            retires, st.msgs_retired,
            "{tag}: retire events must conserve msgs_retired"
        );
        // Tracing is not part of the architecture: compile-cache artifacts
        // are shared between traced and untraced machines.
        assert_eq!(
            config_tag(&base),
            config_tag(&base.clone().with_trace(TraceConfig::full()))
        );
    }
}

/// The windowed time-series rides along on every traced-or-not run: a
/// real workload produces samples with nondecreasing cycles and
/// monotonically nondecreasing cumulative counters.
#[test]
fn series_samples_are_monotone_on_real_workloads() {
    let specs = suite(1);
    let mut m = Machine::new(ArchConfig::nexus());
    let e = m.run(pick(&specs, "SpMV")).expect("run");
    let s = e.stats.unwrap();
    assert!(!s.series.is_empty(), "a real run must produce series samples");
    for w in s.series.windows(2) {
        assert!(w[0].cycle < w[1].cycle, "sample cycles must increase");
        assert!(w[0].active_pe_cycles <= w[1].active_pe_cycles);
        assert!(w[0].flit_hops <= w[1].flit_hops);
        assert!(w[0].msgs_retired <= w[1].msgs_retired);
    }
    let last = s.series.last().unwrap();
    assert_eq!(
        last.msgs_retired, s.msgs_retired,
        "the closing sample must capture the final counter values"
    );
}

/// The Chrome trace-event export reparses with the crate's own JSON
/// parser and its event counts are exact: one metadata record per PE, one
/// instant event per captured fabric event, every instant on a valid PE
/// track.
#[test]
fn chrome_trace_export_reparses_with_exact_counts() {
    let specs = suite(1);
    let cfg = ArchConfig::nexus().with_trace(TraceConfig::full());
    let mut m = Machine::new(cfg.clone());
    let e = m.run(pick(&specs, "SpMV")).expect("traced run");
    let events = e.trace.expect("events");
    let json = chrome_trace_json(&events, cfg.width, cfg.height);
    let v = parse_json(&json).expect("export must reparse as JSON");
    assert_eq!(
        v.get("eventCount").and_then(Json::as_u64),
        Some(events.len() as u64)
    );
    let Some(Json::Arr(items)) = v.get("traceEvents") else {
        panic!("traceEvents must be an array");
    };
    let ph = |it: &Json| it.get("ph").and_then(Json::as_str).map(str::to_string);
    let meta = items.iter().filter(|it| ph(it).as_deref() == Some("M")).count();
    let inst = items.iter().filter(|it| ph(it).as_deref() == Some("i")).count();
    assert_eq!(meta, cfg.width * cfg.height, "one thread_name record per PE");
    assert_eq!(inst, events.len(), "one instant event per fabric event");
    for it in items {
        if ph(it).as_deref() == Some("i") {
            let tid = it.get("tid").and_then(Json::as_u64).expect("tid");
            assert!((tid as usize) < cfg.num_pes(), "tid {tid} out of range");
            assert!(it.get("ts").and_then(Json::as_u64).is_some(), "ts missing");
        }
    }
}

/// A bounded-sink (flight recorder) configuration dumps its most recent
/// events into the deadlock report — and the traced deadlock happens on
/// exactly the same cycle as the untraced one.
#[test]
fn flight_recorder_rides_on_deadlock_reports() {
    use nexus::am::Message;
    use nexus::compiler::ProgramBuilder;
    use nexus::fabric::NexusFabric;
    use nexus::isa::{ConfigEntry, Opcode};

    let mut cfg = ArchConfig::nexus();
    cfg.max_cycles = 500;
    cfg.trace = TraceConfig::flight_recorder(32);
    // A config chain that self-loops (Mul whose next entry is itself)
    // never becomes terminal: the run must time out, not drain.
    let mut b = ProgramBuilder::new("livelock", &cfg);
    let pc = b.config(ConfigEntry::new(Opcode::Mul, 0));
    let mut am = Message::new();
    am.opcode = Opcode::Mul;
    am.n_pc = pc;
    am.op1 = 1;
    am.op2 = 1;
    am.push_dest(15);
    b.static_am(0, am);
    let prog = b.build();

    let mut traced = NexusFabric::new(cfg.clone());
    let err = traced.run_program(&prog).expect_err("livelock must deadlock");
    assert!(!err.flight.is_empty(), "flight recorder must capture events");
    assert!(err.flight.len() <= 64, "dump is bounded: {}", err.flight.len());
    assert!(
        err.flight.iter().all(|l| l.starts_with("cycle ")),
        "lines must be cycle-stamped: {:?}",
        err.flight.first()
    );
    let rendered = err.to_string();
    assert!(rendered.contains("flight recorder"), "{rendered}");

    cfg.trace = TraceConfig::off();
    let mut plain = NexusFabric::new(cfg);
    let err2 = plain.run_program(&prog).expect_err("still deadlocks untraced");
    assert!(err2.flight.is_empty(), "untraced report carries no flight dump");
    assert_eq!(err.cycle, err2.cycle, "tracing must not move the deadlock");
    assert_eq!(err.in_flight, err2.in_flight);
}

/// Ring-buffer overflow in a tiny shard ring drops the oldest events but
/// keeps the run itself bit-identical; the drop is counted, not silent.
#[test]
fn tiny_shard_rings_degrade_gracefully() {
    let specs = suite(1);
    let spec = pick(&specs, "SpMV");
    let tiny = TraceConfig {
        enabled: true,
        shard_capacity: 4,
        sink_capacity: 0,
        lifecycle: true,
        pe_states: true,
    };
    let mut plain = Machine::new(ArchConfig::nexus());
    let mut traced = Machine::new(ArchConfig::nexus().with_trace(tiny));
    let ep = plain.run(spec).expect("untraced run");
    let et = traced.run(spec).expect("tiny-ring traced run");
    assert_eq!(ep.outputs, et.outputs);
    assert_eq!(ep.cycles(), et.cycles());
    let events = et.trace.expect("events survive overflow");
    // The stream stays merge-ordered even with per-epoch drops.
    assert!(events.windows(2).all(|w| w[0].cycle <= w[1].cycle));
}
