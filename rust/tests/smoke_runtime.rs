//! Smoke test for the PJRT golden runtime against a known artifact.
use nexus::runtime::GoldenRuntime;

#[test]
fn load_and_run_pallas_artifact() {
    let dir = std::env::var("SMOKE_ART_DIR").unwrap_or_else(|_| "/tmp/artcheck".into());
    if !std::path::Path::new(&dir).join("fn.hlo.txt").exists() {
        eprintln!("skipping: no smoke artifact");
        return;
    }
    let mut rt = GoldenRuntime::new(&dir).unwrap();
    let x = [1f32, 2., 3., 4.];
    let y = [1f32, 1., 1., 1.];
    let outs = rt
        .run("fn", &[(&x[..], &[2, 2][..]), (&y[..], &[2, 2][..])])
        .unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0], vec![5f32, 5., 9., 9.]);
}
