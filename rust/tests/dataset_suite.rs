//! Dataset-subsystem integration suite: `.mtx` round-trip properties,
//! loader error-case coverage, scenario-corpus execution with bit-exact
//! validation, active-vs-dense cross-mode checks over the corpus (so the
//! irregular inputs also exercise the wake-list scheduler), and the
//! load-imbalance acceptance gate (hotspot/R-MAT op CoV >= 2x uniform at
//! matched density).
//!
//! Property case counts follow `NEXUS_PROP_CASES` like the other property
//! suites (default 200).

use nexus::dataset::{
    cross_check_corpus, glob_match, read_edge_list, read_mtx, run_corpus, write_edge_list,
    write_mtx, Corpus, EdgeListOptions, MtxError, RunOptions,
};
use nexus::tensor::{gen, Csr, CsrError, Graph};
use nexus::util::prop::{ensure, env_cases, forall_seeded};
use nexus::util::SplitMix64;
use nexus::workloads::Spec;

/// Randomized case count (env-tunable: `NEXUS_PROP_CASES=1000 cargo test`).
fn prop_cases() -> usize {
    env_cases(200)
}

/// A random matrix from a random generator family — the round-trip
/// property must hold for every source the corpus can build.
fn random_matrix(rng: &mut SplitMix64) -> Csr {
    let rows = 1 + rng.below_usize(20);
    let cols = 1 + rng.below_usize(20);
    match rng.below(6) {
        0 => gen::random_csr(rng, rows, cols, 0.3),
        1 => gen::skewed_csr(rng, rows, cols, 0.3),
        2 => {
            let target = (rows * cols) / 4;
            gen::rmat_csr(rng, rows, cols, target, gen::RMAT_PROBS)
        }
        3 => gen::hotspot_csr(rng, rows, cols, 0.25, 2, 0.8),
        4 => gen::banded_csr(rng, rows.max(cols), 2, 0.5),
        _ => gen::block_diag_csr(rng, rows.max(cols), 4, 0.5),
    }
}

#[test]
fn mtx_roundtrip_property() {
    forall_seeded(0xDA7A, prop_cases(), &mut |rng| {
        let m = random_matrix(rng);
        m.validate().map_err(|e| e.to_string())?;
        let text = write_mtx(&m);
        let back = read_mtx(&text).map_err(|e| format!("reread failed: {e}"))?;
        ensure(back == m, || {
            format!(
                "mtx roundtrip mismatch for {}x{} nnz={}",
                m.rows,
                m.cols,
                m.nnz()
            )
        })
    });
}

#[test]
fn edge_list_roundtrip_property() {
    forall_seeded(0xED6E, prop_cases(), &mut |rng| {
        // Contact graphs need enough vertices to reach their edge target.
        let n = 10 + rng.below_usize(40);
        let g = if rng.chance(0.5) {
            gen::rmat_graph(rng, n, 3 * n, gen::RMAT_PROBS)
        } else {
            Graph::synthetic_contact(rng, n, 3 * n)
        };
        let opts = EdgeListOptions {
            undirected: false,
            num_vertices: Some(g.num_vertices),
        };
        let back = read_edge_list(&write_edge_list(&g), opts)
            .map_err(|e| format!("reread failed: {e}"))?;
        ensure(back == g, || format!("edge-list roundtrip mismatch at n={n}"))
    });
}

#[test]
fn mtx_symmetric_and_pattern_fixtures() {
    // Symmetric integer: lower triangle stored, full matrix materialized.
    let sym = "%%MatrixMarket matrix coordinate integer symmetric\n\
               % infect-dublin-style fixture\n\
               4 4 4\n\
               1 1 2\n\
               3 1 -1\n\
               4 3 3\n\
               4 4 1\n";
    let m = read_mtx(sym).unwrap();
    assert_eq!(m.nnz(), 6, "two off-diagonal entries mirror");
    let d = m.to_dense();
    assert_eq!(d.get(2, 0), -1);
    assert_eq!(d.get(0, 2), -1);
    assert_eq!(d.get(3, 2), 3);
    assert_eq!(d.get(2, 3), 3);
    // Pattern symmetric: structure only, ones everywhere stored.
    let pat = "%%MatrixMarket matrix coordinate pattern symmetric\n\
               3 3 2\n\
               2 1\n\
               3 2\n";
    let p = read_mtx(pat).unwrap();
    assert_eq!(p.nnz(), 4);
    assert!(p.values.iter().all(|&v| v == 1));
    // Case-insensitive banner, real field quantization.
    let real = "%%matrixmarket MATRIX Coordinate REAL General\n\
                2 2 2\n\
                1 1 0.3\n\
                2 2 -100.25\n";
    let r = read_mtx(real).unwrap();
    assert_eq!(r.to_dense().get(0, 0), 1);
    assert_eq!(r.to_dense().get(1, 1), -4);
}

#[test]
fn mtx_malformed_inputs_are_typed_errors() {
    let cases: Vec<(&str, &str)> = vec![
        ("", "missing header"),
        ("3 3 1\n1 1 1\n", "no banner"),
        ("%%MatrixMarket matrix array integer general\n", "array format"),
        (
            "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1 0\n",
            "complex field",
        ),
        (
            "%%MatrixMarket matrix coordinate integer skew-symmetric\n1 1 0\n",
            "skew symmetry",
        ),
        (
            "%%MatrixMarket matrix coordinate integer general\n2 2\n",
            "short size line",
        ),
        (
            "%%MatrixMarket matrix coordinate integer general\n2 2 1\n1 1\n",
            "missing value token",
        ),
        (
            "%%MatrixMarket matrix coordinate integer general\n2 2 1\n9 1 1\n",
            "row out of range",
        ),
        (
            "%%MatrixMarket matrix coordinate integer general\n2 2 1\n0 1 1\n",
            "zero-based index",
        ),
        (
            "%%MatrixMarket matrix coordinate integer general\n2 2 3\n1 1 1\n2 2 1\n",
            "undershot entry count",
        ),
        (
            "%%MatrixMarket matrix coordinate integer general\n2 2 2\n1 2 1\n1 2 1\n",
            "duplicate entry",
        ),
        (
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 nan\n",
            "non-finite value",
        ),
        (
            "%%MatrixMarket matrix coordinate integer symmetric\n2 2 2\n2 1 1\n1 2 1\n",
            "explicit mirror of symmetric entry",
        ),
    ];
    for (text, what) in cases {
        assert!(read_mtx(text).is_err(), "{what} must fail");
    }
    // The duplicate case carries the structured Csr error.
    let dup = read_mtx("%%MatrixMarket matrix coordinate integer general\n2 2 2\n1 2 1\n1 2 1\n")
        .unwrap_err();
    assert!(
        matches!(
            dup,
            MtxError::Entry {
                source: CsrError::Duplicate { row: 0, col: 1 },
                ..
            }
        ),
        "{dup}"
    );
}

#[test]
fn corpus_filters_compose_with_globs() {
    let corpus = Corpus::builtin();
    assert!(glob_match("smoke/*", "smoke/bfs-rmat-4x4"));
    let smoke = corpus.filter("smoke/*");
    let spmv = corpus.filter("*/spmv-*");
    let all = corpus.filter("*");
    assert!(!smoke.is_empty());
    assert!(spmv.len() >= 12, "spmv family: {}", spmv.len());
    assert_eq!(all.len(), corpus.len());
    assert!(corpus.filter("nothing/*").is_empty());
    // Filters preserve registration order.
    let names: Vec<&str> = smoke.iter().map(|s| s.name.as_str()).collect();
    let mut sorted = names.clone();
    sorted.sort_unstable();
    assert_eq!(names.len(), sorted.len());
}

#[test]
fn smoke_corpus_validates_and_cross_checks_step_modes() {
    let corpus = Corpus::builtin();
    let smoke = corpus.filter("smoke/*");
    // Active-set sweep: everything validates bit-exactly.
    let runs = run_corpus(&smoke, RunOptions::default());
    for run in &runs {
        assert!(run.passed(), "{}: {:?}", run.scenario, run.outcome);
    }
    // Dense-oracle cross-check: identical outputs, cycles, and stats —
    // the irregular corpus inputs drive the wake-list scheduler through
    // the same differential gate as tests/step_equivalence.rs.
    cross_check_corpus(&smoke, 1).expect("smoke corpus cross-mode check");
}

#[test]
fn full_corpus_runs_validated() {
    let corpus = Corpus::builtin();
    let all: Vec<_> = corpus.scenarios().iter().collect();
    let runs = run_corpus(&all, RunOptions::default());
    assert_eq!(runs.len(), corpus.len());
    for run in &runs {
        assert!(run.passed(), "{}: {:?}", run.scenario, run.outcome);
    }
}

/// The acceptance gate for the whole subsystem: irregular inputs must
/// produce measurably imbalanced per-PE work. At matched density, the best
/// of the hotspot/R-MAT SpMV scenarios must show a per-PE committed-op CoV
/// at least 2x the uniform-random scenario's.
#[test]
fn irregular_scenarios_double_uniform_op_cv() {
    let corpus = Corpus::builtin();
    let names = [
        "matrix/spmv-uniform-d10-8x8",
        "matrix/spmv-hotspot-d10-8x8",
        "matrix/spmv-rmat-d10-8x8",
    ];
    let scenarios: Vec<_> = names
        .iter()
        .map(|n| corpus.find(n).expect("registered scenario"))
        .collect();
    let runs = run_corpus(&scenarios, RunOptions::default());
    let cv_of = |i: usize| -> f64 {
        match &runs[i].outcome {
            Ok(m) => {
                assert!(m.validated, "{} not validated", runs[i].scenario);
                m.op_cv
            }
            Err(e) => panic!("{} failed: {e}", runs[i].scenario),
        }
    };
    let uniform = cv_of(0);
    let hotspot = cv_of(1);
    let rmat = cv_of(2);
    let best = hotspot.max(rmat);
    assert!(
        best >= 2.0 * uniform,
        "irregular inputs must at least double per-PE op CoV: \
         uniform={uniform:.3} hotspot={hotspot:.3} rmat={rmat:.3}"
    );
}

/// Committed-op accounting invariant: the per-PE vector sums to the global
/// op counters, in both step modes.
#[test]
fn per_pe_committed_ops_sum_to_global_counters() {
    use nexus::config::{ArchConfig, StepMode};
    use nexus::machine::Machine;
    let mut rng = SplitMix64::new(5);
    let a = gen::hotspot_csr(&mut rng, 32, 32, 0.2, 2, 0.8);
    let x = gen::random_vec(&mut rng, 32, 3);
    for mode in [StepMode::ActiveSet, StepMode::DenseOracle] {
        let mut m = Machine::new(ArchConfig::nexus().with_step_mode(mode));
        let e = m
            .run(&Spec::Spmv {
                a: a.clone(),
                x: x.clone(),
            })
            .expect("spmv run");
        let s = e.stats.expect("fabric stats");
        let per_pe: u64 = s.per_pe_committed_ops.iter().sum();
        assert_eq!(
            per_pe,
            s.alu_ops + s.mem_ops,
            "committed-op conservation broke under {:?}",
            mode
        );
        assert!(s.op_max_mean() >= 1.0);
    }
}
