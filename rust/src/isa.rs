//! The Nexus Machine instruction set carried inside Active Messages.
//!
//! An AM carries a single opcode to perform at its next execution site
//! (Fig 7). Opcodes fall into two classes:
//!
//! - **ALU class** — pure INT16 arithmetic/logic on the message's operand
//!   values. These may execute *en-route* on any idle PE (opportunistic
//!   execution, §3.1.3) once both operands are values.
//! - **Memory class** — touch a PE-local data memory (dereference loads,
//!   streaming loads, stores, read-modify-write accumulations). These must
//!   execute at the PE that owns the addressed data, i.e. the message's head
//!   destination.
//!
//! After an opcode executes, the PE's (replicated) configuration memory is
//! indexed by the message's `N_PC` field to obtain the next
//! [`ConfigEntry`], morphing the message into the next dynamic AM (§3.1).

/// Operation carried by an Active Message.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    /// No-op / message termination.
    Halt = 0,
    // --- ALU class (en-route eligible) -----------------------------------
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    /// Set-less-than: op1 = (op1 < op2) as u16 (signed INT16 compare).
    Slt,
    // --- Memory class (execute at owner PE) ------------------------------
    /// Dereference load: `op2 <- dmem[op2]` (op2 field held an address).
    Load,
    /// Dereference load into op1: `op1 <- dmem[op1]`.
    LoadOp1,
    /// Streaming load (§3.3.1 decode streaming mode): walk `count = result`
    /// elements starting at base address `op2`, emitting one dynamic AM per
    /// element. Element records are (value, aux) pairs; see `pe/decode.rs`.
    Stream,
    /// Store: `dmem[result] <- op1`; terminal.
    Store,
    /// Accumulate: `dmem[result] += op1` (wrapping INT16); terminal.
    Accum,
    /// Min-update: if `op1 < dmem[result]` then write and *trigger* the next
    /// config entry (conditional re-emission — BFS/SSSP relaxation); else the
    /// message dies (early termination, §5.1).
    AccMin,
}

impl Opcode {
    /// True for opcodes an idle intermediate PE may execute en-route.
    #[inline]
    pub fn is_alu(self) -> bool {
        matches!(
            self,
            Opcode::Add
                | Opcode::Sub
                | Opcode::Mul
                | Opcode::Div
                | Opcode::Min
                | Opcode::Max
                | Opcode::And
                | Opcode::Or
                | Opcode::Xor
                | Opcode::Shl
                | Opcode::Shr
                | Opcode::Slt
        )
    }

    /// True for opcodes that must execute at the data-owner PE.
    #[inline]
    pub fn is_memory(self) -> bool {
        matches!(
            self,
            Opcode::Load
                | Opcode::LoadOp1
                | Opcode::Stream
                | Opcode::Store
                | Opcode::Accum
                | Opcode::AccMin
        )
    }

    /// True for terminal opcodes (message dies after execution unless the
    /// config chain re-triggers, as `AccMin` may).
    #[inline]
    pub fn is_terminal(self) -> bool {
        matches!(self, Opcode::Store | Opcode::Accum | Opcode::Halt)
    }

    /// Stable numeric encoding used by the packed AM format (5 bits; the
    /// paper's base format allocates 3 bits and notes extension modes).
    #[inline]
    pub fn encode(self) -> u8 {
        self as u8
    }

    /// Inverse of [`Opcode::encode`].
    pub fn decode(v: u8) -> Option<Opcode> {
        use Opcode::*;
        Some(match v {
            0 => Halt,
            1 => Add,
            2 => Sub,
            3 => Mul,
            4 => Div,
            5 => Min,
            6 => Max,
            7 => And,
            8 => Or,
            9 => Xor,
            10 => Shl,
            11 => Shr,
            12 => Slt,
            13 => Load,
            14 => LoadOp1,
            15 => Stream,
            16 => Store,
            17 => Accum,
            18 => AccMin,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        use Opcode::*;
        match self {
            Halt => "HALT",
            Add => "ADD",
            Sub => "SUB",
            Mul => "MUL",
            Div => "DIV",
            Min => "MIN",
            Max => "MAX",
            And => "AND",
            Or => "OR",
            Xor => "XOR",
            Shl => "SHL",
            Shr => "SHR",
            Slt => "SLT",
            Load => "LOAD",
            LoadOp1 => "LOAD1",
            Stream => "STREAM",
            Store => "STORE",
            Accum => "ACCUM",
            AccMin => "ACCMIN",
        }
    }
}

/// Execute an ALU-class opcode on INT16 operands (wrapping semantics, as in
/// the paper's 16-bit compute unit). Division by zero yields 0, the usual
/// convention for accelerator ALUs without trap support.
#[inline]
pub fn alu_eval(op: Opcode, a: u16, b: u16) -> u16 {
    let (sa, sb) = (a as i16, b as i16);
    match op {
        Opcode::Add => sa.wrapping_add(sb) as u16,
        Opcode::Sub => sa.wrapping_sub(sb) as u16,
        Opcode::Mul => sa.wrapping_mul(sb) as u16,
        Opcode::Div => {
            if sb == 0 {
                0
            } else {
                sa.wrapping_div(sb) as u16
            }
        }
        Opcode::Min => sa.min(sb) as u16,
        Opcode::Max => sa.max(sb) as u16,
        Opcode::And => a & b,
        Opcode::Or => a | b,
        Opcode::Xor => a ^ b,
        Opcode::Shl => a.wrapping_shl((b & 15) as u32),
        Opcode::Shr => a.wrapping_shr((b & 15) as u32),
        Opcode::Slt => u16::from(sa < sb),
        _ => panic!("alu_eval on non-ALU opcode {op:?}"),
    }
}

/// One entry of the per-PE configuration memory (§3.3.1: 10 bits wide, up to
/// 8 configurations). Configuration memories are *replicated* across PEs
/// (paper Fig 10 attributes +8% power to this replication) so a message can
/// be advanced by any PE it traverses — the enabler for en-route execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConfigEntry {
    /// Opcode the morphed (next) dynamic AM will carry.
    pub opcode: Opcode,
    /// Next value of the message's `N_PC` field.
    pub next_pc: u8,
    /// Res_c of the next dynamic AM: result field holds an address.
    pub res_is_addr: bool,
    /// Op1_c of the next dynamic AM.
    pub op1_is_addr: bool,
    /// Op2_c of the next dynamic AM.
    pub op2_is_addr: bool,
}

impl ConfigEntry {
    pub const HALT: ConfigEntry = ConfigEntry {
        opcode: Opcode::Halt,
        next_pc: 0,
        res_is_addr: false,
        op1_is_addr: false,
        op2_is_addr: false,
    };

    pub fn new(opcode: Opcode, next_pc: u8) -> Self {
        ConfigEntry {
            opcode,
            next_pc,
            res_is_addr: false,
            op1_is_addr: false,
            op2_is_addr: false,
        }
    }

    pub fn res_addr(mut self) -> Self {
        self.res_is_addr = true;
        self
    }

    pub fn op1_addr(mut self) -> Self {
        self.op1_is_addr = true;
        self
    }

    pub fn op2_addr(mut self) -> Self {
        self.op2_is_addr = true;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_encode_roundtrip() {
        for v in 0..32u8 {
            if let Some(op) = Opcode::decode(v) {
                assert_eq!(op.encode(), v);
            }
        }
        // All named opcodes roundtrip.
        use Opcode::*;
        for op in [
            Halt, Add, Sub, Mul, Div, Min, Max, And, Or, Xor, Shl, Shr, Slt, Load, LoadOp1,
            Stream, Store, Accum, AccMin,
        ] {
            assert_eq!(Opcode::decode(op.encode()), Some(op));
        }
    }

    #[test]
    fn class_partition() {
        use Opcode::*;
        for op in [
            Halt, Add, Sub, Mul, Div, Min, Max, And, Or, Xor, Shl, Shr, Slt, Load, LoadOp1,
            Stream, Store, Accum, AccMin,
        ] {
            // No opcode is both ALU- and memory-class.
            assert!(!(op.is_alu() && op.is_memory()), "{op:?}");
        }
        assert!(Mul.is_alu() && !Mul.is_memory());
        assert!(Load.is_memory() && !Load.is_alu());
        assert!(Accum.is_terminal());
        assert!(!AccMin.is_terminal()); // may re-trigger
    }

    #[test]
    fn alu_semantics() {
        assert_eq!(alu_eval(Opcode::Add, 3, 4), 7);
        assert_eq!(alu_eval(Opcode::Sub, 3, 4), (-1i16) as u16);
        assert_eq!(alu_eval(Opcode::Mul, 300, 300), (90000i32 as i16) as u16); // wraps
        assert_eq!(alu_eval(Opcode::Div, 12, 5), 2);
        assert_eq!(alu_eval(Opcode::Div, 12, 0), 0);
        assert_eq!(alu_eval(Opcode::Min, (-5i16) as u16, 3), (-5i16) as u16);
        assert_eq!(alu_eval(Opcode::Max, (-5i16) as u16, 3), 3);
        assert_eq!(alu_eval(Opcode::Slt, (-5i16) as u16, 3), 1);
        assert_eq!(alu_eval(Opcode::Slt, 3, 3), 0);
        assert_eq!(alu_eval(Opcode::Shl, 1, 4), 16);
        assert_eq!(alu_eval(Opcode::Shr, 16, 4), 1);
    }
}
