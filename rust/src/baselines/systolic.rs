//! The **systolic array** baseline (§4.1): a TPU-like 4×4 weight-stationary
//! MAC grid. It is the dense-GEMM specialist of the roster:
//!
//! - Dense MatMul / MV: near-peak efficiency (the paper's Fig 11/12 winner
//!   for MatMul and MV).
//! - Sparse workloads: **no sparsity support** — it executes the dense
//!   equivalent, so its useful-work performance collapses as sparsity
//!   rises.
//! - Conv: "inefficient ... due to im2col overhead and cannot execute Conv
//!   natively" (§5.1) — it pays the im2col expansion's memory traffic.
//! - Graph analytics: not executable — [`Backend::compile`] reports
//!   [`ExecError::Unsupported`].

use super::RunResult;
use crate::machine::{Artifact, Backend, Compiled, ExecError, Execution};
use crate::power::EnergyEvents;
use crate::workloads::Spec;

#[derive(Debug, Clone)]
pub struct Systolic {
    /// Grid dimension (4 => 4x4 = 16 MACs, matching the fabric's ALUs).
    pub dim: usize,
    pub axi_bytes_per_cycle: f64,
}

impl Default for Systolic {
    fn default() -> Self {
        Systolic {
            dim: 4,
            axi_bytes_per_cycle: 8.0,
        }
    }
}

/// Outcome of the analytical GEMM model.
#[derive(Debug, Clone, Copy)]
pub struct SystolicOutcome {
    pub cycles: u64,
    pub macs: u64,
    pub load_bytes: u64,
}

impl Systolic {
    /// Weight-stationary GEMM `M x K x N`: the output space is tiled into
    /// `ceil(M/dim) x ceil(N/dim)` tiles; each tile streams K operands
    /// through the grid plus 2*dim skew-in/skew-out cycles, with a K-cycle
    /// weight (re)load per tile column.
    pub fn gemm(&self, m: usize, k: usize, n: usize, extra_bytes: u64) -> SystolicOutcome {
        let d = self.dim;
        let tm = m.div_ceil(d).max(1);
        let tn = n.div_ceil(d).max(1);
        let per_tile = k as u64 + 2 * d as u64;
        let weight_loads = (tm * tn) as u64 * k as u64 / 2; // double-buffered
        let compute = (tm * tn) as u64 * per_tile + weight_loads;
        let data_bytes = 2 * (m * k + k * n + m * n) as u64 + extra_bytes;
        let load_cycles = (data_bytes as f64 / self.axi_bytes_per_cycle).ceil() as u64;
        SystolicOutcome {
            cycles: compute + load_cycles,
            macs: (m * k * n) as u64,
            load_bytes: data_bytes,
        }
    }

    /// Element-wise streaming (SpM+SpM executed dense): `dim*dim` lanes.
    pub fn elementwise(&self, elems: usize) -> SystolicOutcome {
        let lanes = (self.dim * self.dim) as u64;
        let compute = (elems as u64).div_ceil(lanes);
        let data_bytes = 2 * 3 * elems as u64; // two operands + result
        let load_cycles = (data_bytes as f64 / self.axi_bytes_per_cycle).ceil() as u64;
        SystolicOutcome {
            cycles: compute + load_cycles,
            macs: elems as u64,
            load_bytes: data_bytes,
        }
    }
}

impl Systolic {
    /// Evaluate the analytical model for one workload. `None` when a
    /// systolic dataflow cannot express it (graph analytics).
    pub fn model(&self, spec: &Spec) -> Option<RunResult> {
        let o = match spec {
            // Sparse executed as dense (no sparsity support).
            Spec::Spmv { a, .. } => self.gemm(a.rows, a.cols, 1, 0),
            Spec::SpMSpM { a, b, .. } => self.gemm(a.rows, a.cols, b.cols, 0),
            Spec::Sddmm { mask, a, b } => self.gemm(mask.rows, a.cols, b.cols, 0),
            Spec::SpAdd { a, .. } => self.elementwise(a.rows * a.cols),
            Spec::MatMul { a, b } => self.gemm(a.rows, a.cols, b.cols, 0),
            Spec::Mv { a, .. } => self.gemm(a.rows, a.cols, 1, 0),
            Spec::Conv { input, filter } => {
                // im2col: materialize an (oh*ow) x (fh*fw) patch matrix and
                // move it through memory — the §5.1 overhead.
                let oh = input.rows - filter.rows + 1;
                let ow = input.cols - filter.cols + 1;
                let patch = filter.rows * filter.cols;
                let im2col_bytes = 2 * (oh * ow * patch) as u64 * 2; // write + read back
                self.gemm(oh * ow, patch, 1, im2col_bytes)
            }
            // Graph analytics cannot be expressed as a systolic dataflow.
            Spec::Bfs { .. } | Spec::Sssp { .. } | Spec::PageRank { .. } => return None,
        };
        let pes = (self.dim * self.dim) as u64;
        // Utilization over compute cycles only (matching FabricStats).
        let load_cycles = (o.load_bytes as f64 / self.axi_bytes_per_cycle).ceil() as u64;
        let compute = o.cycles.saturating_sub(load_cycles).max(1);
        let utilization = if o.cycles == 0 {
            0.0
        } else {
            (o.macs as f64 / (pes * compute) as f64).min(1.0)
        };
        let mut events = EnergyEvents::default();
        events.alu_ops = o.macs;
        events.bank_accesses = o.macs / self.dim as u64; // edge-fed operands
        events.noc_hops = o.macs; // systolic register-to-register shifts
        events.offchip_bytes = o.load_bytes;
        events.cycles = o.cycles;
        Some(RunResult {
            arch: "Systolic",
            workload: spec.name(),
            cycles: o.cycles,
            work_ops: spec.build_work_ops(),
            utilization,
            in_network_frac: 0.0,
            congestion: [0.0; 5],
            offchip_bytes: o.load_bytes,
            events,
            validated: true,
        })
    }
}

impl Backend for Systolic {
    fn name(&self) -> &'static str {
        "Systolic"
    }

    fn compile(&self, spec: &Spec) -> Result<Artifact, ExecError> {
        match self.model(spec) {
            Some(r) => Ok(Artifact::Report(Box::new(r))),
            None => Err(ExecError::Unsupported {
                arch: self.name(),
                workload: spec.name(),
            }),
        }
    }

    fn execute(&mut self, compiled: &Compiled) -> Result<Execution, ExecError> {
        let Artifact::Report(r) = compiled.artifact() else {
            return Err(ExecError::ArtifactMismatch {
                backend: self.name(),
                workload: compiled.workload().to_string(),
            });
        };
        Ok(Execution {
            outputs: Vec::new(),
            stats: None,
            result: (**r).clone(),
            trace: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::SplitMix64;

    #[test]
    fn systolic_wins_dense_matmul_but_loses_sparse() {
        let sys = Systolic::default();
        let mut rng = SplitMix64::new(20);
        let a = gen::random_dense(&mut rng, 24, 24, 3);
        let b = gen::random_dense(&mut rng, 24, 24, 3);
        let dense = sys
            .model(&Spec::MatMul { a, b })
            .unwrap();
        // 90%-sparse SpMSpM: same dense dims, tiny useful work.
        let sa = gen::random_csr(&mut rng, 24, 24, 0.1);
        let sb = gen::random_csr(&mut rng, 24, 24, 0.1);
        let sparse = sys
            .model(&Spec::SpMSpM {
                a: sa,
                b: sb,
                regime: crate::tensor::gen::SparsityRegime::S4,
            })
            .unwrap();
        assert!(
            dense.perf() > 4.0 * sparse.perf(),
            "dense {} vs sparse {}",
            dense.perf(),
            sparse.perf()
        );
    }

    #[test]
    fn systolic_refuses_graph_workloads() {
        let sys = Systolic::default();
        let mut rng = SplitMix64::new(21);
        let g = crate::tensor::Graph::synthetic_contact(&mut rng, 32, 120);
        assert!(sys.model(&Spec::Bfs { g: g.clone(), src: 0 }).is_none());
        assert!(sys.model(&Spec::PageRank { g, iters: 2 }).is_none());
    }

    #[test]
    fn mv_underutilizes_the_grid() {
        let sys = Systolic::default();
        let mut rng = SplitMix64::new(22);
        let a = gen::random_dense(&mut rng, 48, 48, 3);
        let x = gen::random_vec(&mut rng, 48, 3);
        let r = sys.model(&Spec::Mv { a, x }).unwrap();
        // Single output column keeps most of the grid idle.
        assert!(r.utilization < 0.5, "utilization {}", r.utilization);
    }

    #[test]
    fn conv_pays_im2col() {
        let sys = Systolic::default();
        let mut rng = SplitMix64::new(23);
        let input = gen::random_dense(&mut rng, 12, 12, 3);
        let filter = gen::random_dense(&mut rng, 3, 3, 2);
        let spec = Spec::Conv { input, filter };
        let r = sys.model(&spec).unwrap();
        // im2col traffic: off-chip bytes exceed the raw tensor footprint.
        let raw = 2 * (12 * 12 + 9 + 10 * 10) as u64;
        assert!(r.offchip_bytes > raw, "{} <= {raw}", r.offchip_bytes);
    }
}
