//! The **Generic CGRA** baseline (§4.1): a HyCube-like spatio-temporal
//! CGRA with a *shared* global scratchpad of 8 banks along two edges.
//!
//! Per DESIGN.md's substitution table, the Morpher/LLVM toolchain is
//! replaced by an analytical modulo-scheduling model driven by the
//! workload's *actual* memory trace: the loop body DFG gives the initiation
//! interval (resource + recurrence bounds), iterations are unrolled
//! spatially to fill the fabric, and every II window's combined memory
//! accesses are mapped onto the banks — more than one access to a bank in a
//! window stalls the whole (synchronously scheduled) fabric until the bank
//! drains. This reproduces exactly the Fig 3(a) pathology: irregular index
//! streams produce conflict storms, regular streams do not.

use super::RunResult;
use crate::compiler::dfg::Dfg;
use crate::machine::{Artifact, Backend, Compiled, ExecError, Execution};
use crate::power::EnergyEvents;
use crate::tensor::{Csr, Dense, Graph};
use crate::workloads::Spec;

/// Number of shared memory banks (§4.1: "eight memory banks along two
/// edges to mitigate memory port limitations").
pub const BANKS: usize = 8;

/// One loop iteration's memory accesses (word addresses in the shared SPM).
pub type Iter = Vec<u32>;

#[derive(Debug, Clone)]
pub struct GenericCgra {
    pub pes: usize,
    pub banks: usize,
    /// Off-chip bandwidth in bytes/cycle (same AXI as the fabric).
    pub axi_bytes_per_cycle: f64,
}

impl Default for GenericCgra {
    fn default() -> Self {
        GenericCgra {
            pes: 16,
            banks: BANKS,
            axi_bytes_per_cycle: 8.0,
        }
    }
}

impl GenericCgra {
    /// Modulo-scheduled execution estimate over a memory trace.
    /// `ii_penalty` models the achieved-vs-minimum II gap of real CGRA
    /// mappers: Morpher-class tools reach the MII on regular kernels but
    /// typically pay one extra slot on kernels with indirection, where
    /// data-dependent routes constrain placement (cf. Morpher \[51\]).
    pub fn simulate(&self, dfg: &Dfg, trace: &[Iter], data_bytes: u64) -> CgraOutcome {
        self.simulate_with_penalty(dfg, trace, data_bytes, 0)
    }

    pub fn simulate_with_penalty(
        &self,
        dfg: &Dfg,
        trace: &[Iter],
        data_bytes: u64,
        ii_penalty: u64,
    ) -> CgraOutcome {
        self.simulate_full(dfg, trace, data_bytes, ii_penalty, true)
    }

    /// `unrollable = false` models loop-carried dependence through memory
    /// (worklist relaxations): a static schedule cannot map dependent
    /// iterations side by side, so the spatial unroll factor is 1.
    pub fn simulate_full(
        &self,
        dfg: &Dfg,
        trace: &[Iter],
        data_bytes: u64,
        ii_penalty: u64,
        unrollable: bool,
    ) -> CgraOutcome {
        let ii = dfg.mii(self.pes) as u64 + ii_penalty;
        let nodes = dfg.nodes.len().max(1);
        // Spatial unroll: copies of the loop body mapped side by side.
        let unroll = if unrollable {
            (self.pes / nodes).max(1)
        } else {
            1
        };
        let mut compute_cycles = dfg.depth() as u64; // pipeline fill
        let mut conflict_stalls = 0u64;
        let mut bank_accesses = 0u64;
        let mut counts = vec![0u32; self.banks];
        for chunk in trace.chunks(unroll) {
            counts.iter_mut().for_each(|c| *c = 0);
            for it in chunk {
                for &a in it {
                    counts[a as usize % self.banks] += 1;
                    bank_accesses += 1;
                }
            }
            let worst = *counts.iter().max().unwrap() as u64;
            // The synchronous fabric stalls until the hottest bank drains
            // (one access per bank per cycle).
            let window = ii.max(worst);
            conflict_stalls += window - ii.min(window);
            compute_cycles += window;
        }
        // Data loads to the edge banks + output writeback, at AXI rate.
        let load_cycles = (data_bytes as f64 / self.axi_bytes_per_cycle).ceil() as u64;
        // Predicated-off padding slots (empty access lists) consume their
        // schedule slot but perform no useful work.
        let useful = trace.iter().filter(|it| !it.is_empty()).count() as u64;
        CgraOutcome {
            cycles: compute_cycles + load_cycles,
            compute_cycles,
            conflict_stalls,
            bank_accesses,
            iterations: useful,
            alu_ops: useful * dfg.nodes.iter().filter(|n| !n.is_mem).count() as u64,
            load_cycles,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct CgraOutcome {
    pub cycles: u64,
    pub compute_cycles: u64,
    pub conflict_stalls: u64,
    pub bank_accesses: u64,
    pub iterations: u64,
    pub alu_ops: u64,
    pub load_cycles: u64,
}

impl GenericCgra {
    /// Evaluate the analytical model for one workload (the CGRA maps every
    /// suite kernel, so this never refuses).
    pub fn model(&self, spec: &Spec) -> RunResult {
        let dfg = spec.dfg();
        let (trace, data_bytes) = mem_trace(spec);
        // Regular kernels map at MII; indirection costs one extra II slot
        // in real mappers (see `simulate` docs). Worklist algorithms carry
        // dependences through memory and cannot be spatially unrolled.
        let penalty = u64::from(spec.class() != "dense");
        let unrollable = !matches!(spec, Spec::Bfs { .. } | Spec::Sssp { .. });
        let o = self.simulate_full(&dfg, &trace, data_bytes, penalty, unrollable);
        let nodes = dfg.nodes.len() as u64;
        let total_ops = o.iterations * nodes;
        // Utilization over compute cycles only (matching FabricStats).
        let utilization = if o.compute_cycles == 0 {
            0.0
        } else {
            (total_ops as f64 / (self.pes as u64 * o.compute_cycles) as f64).min(1.0)
        };
        let mut events = EnergyEvents::default();
        events.alu_ops = o.alu_ops;
        events.bank_accesses = o.bank_accesses;
        events.config_reads = o.iterations * nodes; // one fetch per op issue
        events.noc_hops = total_ops; // static NoC word movements
        events.offchip_bytes = data_bytes;
        events.cycles = o.cycles;
        RunResult {
            arch: "GenericCGRA",
            workload: spec.name(),
            cycles: o.cycles,
            work_ops: spec.build_work_ops(),
            utilization,
            in_network_frac: 0.0,
            congestion: [0.0; 5],
            offchip_bytes: data_bytes,
            events,
            validated: true,
        }
    }
}

impl Backend for GenericCgra {
    fn name(&self) -> &'static str {
        "GenericCGRA"
    }

    fn compile(&self, spec: &Spec) -> Result<Artifact, ExecError> {
        Ok(Artifact::Report(Box::new(self.model(spec))))
    }

    fn execute(&mut self, compiled: &Compiled) -> Result<Execution, ExecError> {
        let Artifact::Report(r) = compiled.artifact() else {
            return Err(ExecError::ArtifactMismatch {
                backend: self.name(),
                workload: compiled.workload().to_string(),
            });
        };
        Ok(Execution {
            outputs: Vec::new(),
            stats: None,
            result: (**r).clone(),
            trace: None,
        })
    }
}

impl Spec {
    /// Algorithmic work without compiling a fabric program (the analytical
    /// baselines need only the number).
    pub fn build_work_ops(&self) -> u64 {
        match self {
            Spec::Spmv { a, .. } => 2 * a.nnz() as u64,
            Spec::SpMSpM { a, b, .. } => {
                2 * (0..a.rows)
                    .flat_map(|i| a.row(i))
                    .map(|(k, _)| b.row_nnz(k) as u64)
                    .sum::<u64>()
            }
            Spec::SpAdd { a, b } => (a.nnz() + b.nnz()) as u64,
            Spec::Sddmm { mask, a, .. } => (mask.nnz() * a.cols * 2) as u64,
            Spec::MatMul { a, b } => 2 * (a.rows * a.cols * b.cols) as u64,
            Spec::Mv { a, .. } => 2 * (a.rows * a.cols) as u64,
            Spec::Conv { input, filter } => {
                let oh = input.rows - filter.rows + 1;
                let ow = input.cols - filter.cols + 1;
                2 * (oh * ow * filter.rows * filter.cols) as u64
            }
            Spec::Bfs { g, src } => crate::workloads::graphs::relaxation_work(g, *src, true),
            Spec::Sssp { g, src } => crate::workloads::graphs::relaxation_work(g, *src, false),
            Spec::PageRank { g, iters } => 2 * g.num_edges() as u64 * *iters as u64,
        }
    }
}

/// Build the iteration-level memory trace of a workload in the CGRA's
/// shared SPM address space, plus the bytes loaded/stored off-chip.
/// Tensors are laid out consecutively; addresses are word-granular and
/// interleave onto banks low-order, so the *index streams of the real
/// data* decide the conflict pattern.
pub fn mem_trace(spec: &Spec) -> (Vec<Iter>, u64) {
    match spec {
        Spec::Spmv { a, x } => spmv_trace(a, x.len()),
        Spec::Mv { a, x } => spmv_trace(&Csr::from_dense(a), x.len()),
        Spec::SpMSpM { a, b, .. } => spmspm_trace(a, b),
        Spec::MatMul { a, b } => spmspm_trace(&Csr::from_dense(a), &Csr::from_dense(b)),
        Spec::SpAdd { a, b } => spadd_trace(a, b),
        Spec::Sddmm { mask, a, b } => sddmm_trace(mask, a, b),
        Spec::Conv { input, filter } => conv_trace(input, filter),
        Spec::Bfs { g, src } => relax_trace(g, *src, true),
        Spec::Sssp { g, src } => relax_trace(g, *src, false),
        Spec::PageRank { g, iters } => pagerank_trace(g, *iters),
    }
}

fn spmv_trace(a: &Csr, xlen: usize) -> (Vec<Iter>, u64) {
    let val0 = 0u32;
    let col0 = val0 + a.nnz() as u32;
    let x0 = col0 + a.nnz() as u32;
    let y0 = x0 + xlen as u32;
    let mut t = Vec::with_capacity(a.nnz());
    for r in 0..a.rows {
        for k in a.rowptr[r]..a.rowptr[r + 1] {
            // The row accumulator lives in a PE register; y[r] is written
            // back once, on the row's last nonzero.
            let mut it = vec![
                val0 + k as u32,
                col0 + k as u32,
                x0 + a.colidx[k] as u32, // the irregular gather
            ];
            if k + 1 == a.rowptr[r + 1] {
                it.push(y0 + r as u32);
            }
            t.push(it);
        }
    }
    let bytes = 2 * (a.nnz() * 2 + xlen + a.rows) as u64;
    (t, bytes)
}

fn spmspm_trace(a: &Csr, b: &Csr) -> (Vec<Iter>, u64) {
    let aval0 = 0u32;
    let bval0 = aval0 + 2 * a.nnz() as u32;
    let c0 = bval0 + 2 * b.nnz() as u32;
    // Static scheduling of Gustavson's *dynamic* inner loop: the schedule
    // must provision every A-element's inner loop for the worst-case B-row
    // length; shorter rows execute predicated-off (empty) slots. This is
    // the §2.2 cost of compile-time mapping under irregular control flow.
    let max_brow = (0..b.rows).map(|k| b.row_nnz(k)).max().unwrap_or(0);
    let mut t = Vec::new();
    for i in 0..a.rows {
        for ka in a.rowptr[i]..a.rowptr[i + 1] {
            let k = a.colidx[ka];
            // A element fetch (value + colidx).
            t.push(vec![aval0 + 2 * ka as u32, aval0 + 2 * ka as u32 + 1]);
            for kb in b.rowptr[k]..b.rowptr[k + 1] {
                let j = b.colidx[kb];
                t.push(vec![
                    bval0 + 2 * kb as u32,
                    bval0 + 2 * kb as u32 + 1,
                    c0 + (i * b.cols + j) as u32, // irregular scatter
                ]);
            }
            // Predicated-off padding slots up to the scheduled bound.
            for _ in b.row_nnz(k)..max_brow {
                t.push(Vec::new());
            }
        }
    }
    let bytes = 2 * (2 * a.nnz() + 2 * b.nnz() + a.rows * b.cols) as u64;
    (t, bytes)
}

fn spadd_trace(a: &Csr, b: &Csr) -> (Vec<Iter>, u64) {
    let av0 = 0u32;
    let bv0 = av0 + 2 * a.nnz() as u32;
    let c0 = bv0 + 2 * b.nnz() as u32;
    let mut t = Vec::new();
    for (m, base) in [(a, av0), (b, bv0)] {
        for r in 0..m.rows {
            for k in m.rowptr[r]..m.rowptr[r + 1] {
                t.push(vec![
                    base + 2 * k as u32,
                    base + 2 * k as u32 + 1,
                    c0 + (r * m.cols + m.colidx[k]) as u32,
                ]);
            }
        }
    }
    let bytes = 2 * (2 * a.nnz() + 2 * b.nnz() + a.rows * a.cols) as u64;
    (t, bytes)
}

fn sddmm_trace(mask: &Csr, a: &Dense, b: &Dense) -> (Vec<Iter>, u64) {
    let a0 = 0u32;
    let b0 = a0 + (a.rows * a.cols) as u32;
    let c0 = b0 + (b.rows * b.cols) as u32;
    let mut t = Vec::new();
    let mut nz = 0u32;
    for i in 0..mask.rows {
        for (j, _) in mask.row(i) {
            for k in 0..a.cols {
                let mut it = vec![
                    a0 + (i * a.cols + k) as u32,
                    b0 + (k * b.cols + j) as u32, // column-strided access
                ];
                if k + 1 == a.cols {
                    it.push(c0 + nz); // dot accumulates in a register
                }
                t.push(it);
            }
            nz += 1;
        }
    }
    let bytes = 2 * (a.rows * a.cols + b.rows * b.cols + mask.nnz()) as u64;
    (t, bytes)
}

fn conv_trace(input: &Dense, filter: &Dense) -> (Vec<Iter>, u64) {
    let in0 = 0u32;
    let f0 = in0 + (input.rows * input.cols) as u32;
    let out0 = f0 + (filter.rows * filter.cols) as u32;
    let oh = input.rows - filter.rows + 1;
    let ow = input.cols - filter.cols + 1;
    let mut t = Vec::new();
    for h in 0..oh {
        for w in 0..ow {
            for i in 0..filter.rows {
                for j in 0..filter.cols {
                    let mut it = vec![
                        in0 + ((h + i) * input.cols + w + j) as u32,
                        f0 + (i * filter.cols + j) as u32,
                    ];
                    if i + 1 == filter.rows && j + 1 == filter.cols {
                        it.push(out0 + (h * ow + w) as u32);
                    }
                    t.push(it);
                }
            }
        }
    }
    let bytes =
        2 * (input.rows * input.cols + filter.rows * filter.cols + oh * ow) as u64;
    (t, bytes)
}

fn relax_trace(g: &Graph, src: usize, unit: bool) -> (Vec<Iter>, u64) {
    use crate::tensor::graph::INF;
    let dist0 = 0u32;
    let adj0 = dist0 + g.num_vertices as u32;
    let mut dist = vec![INF; g.num_vertices];
    dist[src] = 0;
    let mut work = std::collections::VecDeque::from([src]);
    let mut t = Vec::new();
    let mut eidx = 0u32;
    while let Some(u) = work.pop_front() {
        for &(v, w) in &g.adj[u] {
            t.push(vec![dist0 + u as u32, adj0 + eidx, dist0 + v as u32]);
            eidx = eidx.wrapping_add(2);
            let w = if unit { 1 } else { w };
            let nd = dist[u].saturating_add(w).min(INF);
            if nd < dist[v] {
                dist[v] = nd;
                work.push_back(v);
            }
        }
    }
    let bytes = 2 * (g.num_vertices + 2 * g.num_edges()) as u64;
    (t, bytes)
}

fn pagerank_trace(g: &Graph, iters: usize) -> (Vec<Iter>, u64) {
    let rank0 = 0u32;
    let deg0 = rank0 + g.num_vertices as u32;
    let next0 = deg0 + g.num_vertices as u32;
    let mut t = Vec::new();
    for _ in 0..iters {
        for u in 0..g.num_vertices {
            for &(v, _) in &g.adj[u] {
                t.push(vec![rank0 + u as u32, deg0 + u as u32, next0 + v as u32]);
            }
        }
    }
    let bytes = 2 * (3 * g.num_vertices * iters + g.num_edges()) as u64;
    (t, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::SplitMix64;
    use crate::workloads::suite;

    #[test]
    fn irregular_workload_suffers_more_conflicts_than_dense() {
        let cgra = GenericCgra::default();
        let mut rng = SplitMix64::new(9);
        // Sparse gather (irregular x accesses) vs dense MV (sequential).
        let a_sp = gen::skewed_csr(&mut rng, 48, 48, 0.25);
        let x = gen::random_vec(&mut rng, 48, 3);
        let sp = Spec::Spmv { a: a_sp, x: x.clone() };
        let a_d = gen::random_dense(&mut rng, 48, 48, 3);
        let dn = Spec::Mv { a: a_d, x };
        let (st, sb) = mem_trace(&sp);
        let (dt, db) = mem_trace(&dn);
        let so = cgra.simulate(&sp.dfg(), &st, sb);
        let do_ = cgra.simulate(&dn.dfg(), &dt, db);
        let s_rate = so.conflict_stalls as f64 / so.iterations as f64;
        let d_rate = do_.conflict_stalls as f64 / do_.iterations as f64;
        assert!(
            s_rate > d_rate,
            "sparse conflict rate {s_rate} should exceed dense {d_rate}"
        );
    }

    #[test]
    fn cgra_runs_every_suite_workload() {
        let cgra = GenericCgra::default();
        for spec in suite(3) {
            let r = cgra.model(&spec);
            assert!(r.cycles > 0, "{}", spec.name());
            assert!(r.work_ops > 0);
            assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        }
    }

    #[test]
    fn more_banks_reduce_stalls() {
        let mut rng = SplitMix64::new(10);
        let a = gen::skewed_csr(&mut rng, 48, 48, 0.3);
        let x = gen::random_vec(&mut rng, 48, 3);
        let spec = Spec::Spmv { a, x };
        let (t, b) = mem_trace(&spec);
        let few = GenericCgra {
            banks: 4,
            ..Default::default()
        }
        .simulate(&spec.dfg(), &t, b);
        let many = GenericCgra {
            banks: 32,
            ..Default::default()
        }
        .simulate(&spec.dfg(), &t, b);
        assert!(many.conflict_stalls <= few.conflict_stalls);
        assert!(many.cycles <= few.cycles);
    }
}
