//! The baseline architectures of §4.1, each a [`Backend`] behind the
//! unified [`crate::machine::Machine`] execution API so the coordinator can
//! sweep them uniformly:
//!
//! - **Nexus Machine / TIA / TIA-Valiant** — the same cycle-accurate fabric
//!   with the paper's ablation flags, behind
//!   [`FabricArch`](crate::machine::FabricArch) (re-exported here).
//! - **Generic CGRA** — an analytical modulo-scheduling model (HyCube-like,
//!   8 shared edge banks) driven by the workload's *actual* memory trace,
//!   so bank conflicts emerge from real access patterns ([`cgra`]).
//! - **Systolic array** — a TPU-like weight-stationary dense model that
//!   cannot exploit sparsity, pays im2col for Conv, and reports graph
//!   analytics as [`crate::machine::ExecError::Unsupported`] ([`systolic`]).

pub mod cgra;
pub mod systolic;

pub use crate::machine::{Backend, FabricArch};
use crate::power::EnergyEvents;

/// Outcome of running one workload on one architecture — the normalized
/// unit the evaluation matrix and the report renderers consume.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub arch: &'static str,
    pub workload: String,
    /// Total cycles (compute + data movement phases).
    pub cycles: u64,
    /// Algorithmic useful operations (identical across architectures for a
    /// given workload — the normalized-performance numerator).
    pub work_ops: u64,
    /// Fabric utilization in \[0,1\] (Fig 13).
    pub utilization: f64,
    /// Fraction of ALU ops executed in-network (Fig 11 right axis).
    pub in_network_frac: f64,
    /// Mean blocked fraction per input-port class (Fig 14); zeros for the
    /// analytical models (static routing has no dynamic congestion).
    pub congestion: [f64; 5],
    /// Bytes moved over the off-chip interface (Fig 16).
    pub offchip_bytes: u64,
    /// Event counts for the energy model (Figs 10, 12).
    pub events: EnergyEvents,
    /// True when outputs were validated against the reference (fabric
    /// architectures always validate; analytical models are trusted).
    pub validated: bool,
}

impl RunResult {
    /// Useful operations per cycle — the normalized-performance metric.
    pub fn perf(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.work_ops as f64 / self.cycles as f64
        }
    }

    /// Throughput in MOPS at `freq_mhz`.
    pub fn mops(&self, freq_mhz: f64) -> f64 {
        self.perf() * freq_mhz
    }
}

/// The full evaluation roster: systolic, Generic CGRA, TIA, TIA-Valiant,
/// Nexus — the order the paper's figures present them in. Wrap each entry
/// in a [`crate::machine::Machine`] to execute workloads.
pub fn roster() -> Vec<Box<dyn Backend>> {
    vec![
        Box::new(systolic::Systolic::default()),
        Box::new(cgra::GenericCgra::default()),
        Box::new(FabricArch::tia()),
        Box::new(FabricArch::tia_valiant()),
        Box::new(FabricArch::nexus()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArchConfig;
    use crate::machine::Machine;
    use crate::workloads::suite;

    #[test]
    fn fabric_archs_run_and_validate_spmv() {
        let specs = suite(1);
        let spmv = specs
            .iter()
            .find(|s| s.name().starts_with("SpMV"))
            .unwrap();
        for arch in FabricArch::variants() {
            let mut m = Machine::from_backend(Box::new(arch));
            let e = m.run(spmv).unwrap();
            assert!(e.validated());
            assert!(e.cycles() > 0);
            assert!(e.perf() > 0.0);
        }
    }

    #[test]
    fn nexus_beats_tia_on_skewed_sparse() {
        // The headline claim at small scale: en-route execution helps an
        // irregular, load-imbalanced workload.
        let specs = suite(2);
        let spmv = specs
            .iter()
            .find(|s| s.name().starts_with("SpMV"))
            .unwrap();
        let nexus = Machine::new(ArchConfig::nexus()).run(spmv).unwrap();
        let tia = Machine::new(ArchConfig::tia()).run(spmv).unwrap();
        assert!(
            nexus.perf() > tia.perf(),
            "Nexus {} vs TIA {}",
            nexus.perf(),
            tia.perf()
        );
        assert!(nexus.result.in_network_frac > 0.0);
        assert_eq!(tia.result.in_network_frac, 0.0);
    }

    #[test]
    fn roster_names_are_unique_and_ordered() {
        let names: Vec<&str> = roster().iter().map(|b| b.name()).collect();
        assert_eq!(
            names,
            vec!["Systolic", "GenericCGRA", "TIA", "TIA-Valiant", "Nexus"]
        );
    }
}
