//! The four baseline architectures of §4.1, behind one [`Architecture`]
//! trait so the coordinator can sweep them uniformly:
//!
//! - **Nexus Machine / TIA / TIA-Valiant** — the same cycle-accurate fabric
//!   with the paper's ablation flags ([`crate::config::ArchKind`]).
//! - **Generic CGRA** — an analytical modulo-scheduling model (HyCube-like,
//!   8 shared edge banks) driven by the workload's *actual* memory trace,
//!   so bank conflicts emerge from real access patterns ([`cgra`]).
//! - **Systolic array** — a TPU-like weight-stationary dense model that
//!   cannot exploit sparsity and pays im2col for Conv ([`systolic`]).

pub mod cgra;
pub mod systolic;

use crate::config::ArchConfig;
use crate::fabric::NexusFabric;
use crate::power::EnergyEvents;
use crate::workloads::{run_on_fabric, Spec};

/// Outcome of running one workload on one architecture.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub arch: &'static str,
    pub workload: String,
    /// Total cycles (compute + data movement phases).
    pub cycles: u64,
    /// Algorithmic useful operations (identical across architectures for a
    /// given workload — the normalized-performance numerator).
    pub work_ops: u64,
    /// Fabric utilization in \[0,1\] (Fig 13).
    pub utilization: f64,
    /// Fraction of ALU ops executed in-network (Fig 11 right axis).
    pub in_network_frac: f64,
    /// Mean blocked fraction per input-port class (Fig 14); zeros for the
    /// analytical models (static routing has no dynamic congestion).
    pub congestion: [f64; 5],
    /// Bytes moved over the off-chip interface (Fig 16).
    pub offchip_bytes: u64,
    /// Event counts for the energy model (Figs 10, 12).
    pub events: EnergyEvents,
    /// True when outputs were validated against the reference (fabric
    /// architectures always validate; analytical models are trusted).
    pub validated: bool,
}

impl RunResult {
    /// Useful operations per cycle — the normalized-performance metric.
    pub fn perf(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.work_ops as f64 / self.cycles as f64
        }
    }

    /// Throughput in MOPS at `freq_mhz`.
    pub fn mops(&self, freq_mhz: f64) -> f64 {
        self.perf() * freq_mhz
    }
}

/// An architecture that can execute evaluation workloads.
pub trait Architecture: Sync {
    fn name(&self) -> &'static str;
    /// Run a workload. `None` when the architecture cannot execute it
    /// (systolic arrays cannot run graph analytics).
    fn run(&self, spec: &Spec) -> Option<RunResult>;
}

/// Fabric-backed architecture (Nexus, TIA, TIA-Valiant).
pub struct FabricArch {
    pub name: &'static str,
    pub cfg: ArchConfig,
}

impl FabricArch {
    pub fn nexus() -> Self {
        FabricArch {
            name: "Nexus",
            cfg: ArchConfig::nexus(),
        }
    }

    pub fn tia() -> Self {
        FabricArch {
            name: "TIA",
            cfg: ArchConfig::tia(),
        }
    }

    pub fn tia_valiant() -> Self {
        FabricArch {
            name: "TIA-Valiant",
            cfg: ArchConfig::tia_valiant(),
        }
    }

    /// All three fabric variants.
    pub fn variants() -> Vec<FabricArch> {
        vec![Self::nexus(), Self::tia(), Self::tia_valiant()]
    }
}

impl Architecture for FabricArch {
    fn name(&self) -> &'static str {
        self.name
    }

    fn run(&self, spec: &Spec) -> Option<RunResult> {
        let built = spec.build(&self.cfg);
        let mut f = NexusFabric::new(self.cfg.clone());
        let out = run_on_fabric(&mut f, &built).expect("fabric deadlock");
        let validated = out == built.expected;
        assert!(
            validated,
            "{} produced wrong output for {}",
            self.name,
            built.name
        );
        let s = &f.stats;
        Some(RunResult {
            arch: self.name,
            workload: spec.name(),
            cycles: s.cycles,
            work_ops: built.work_ops,
            utilization: s.utilization(),
            in_network_frac: s.in_network_fraction(),
            congestion: std::array::from_fn(|p| s.port_congestion(p)),
            offchip_bytes: s.offchip_bytes,
            events: EnergyEvents::from_fabric(s, self.cfg.kind),
            validated,
        })
    }
}

/// The full evaluation roster: systolic, Generic CGRA, TIA, TIA-Valiant,
/// Nexus — the order the paper's figures present them in.
pub fn roster() -> Vec<Box<dyn Architecture>> {
    vec![
        Box::new(systolic::Systolic::default()),
        Box::new(cgra::GenericCgra::default()),
        Box::new(FabricArch::tia()),
        Box::new(FabricArch::tia_valiant()),
        Box::new(FabricArch::nexus()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::suite;

    #[test]
    fn fabric_archs_run_and_validate_spmv() {
        let specs = suite(1);
        let spmv = specs
            .iter()
            .find(|s| s.name().starts_with("SpMV"))
            .unwrap();
        for arch in FabricArch::variants() {
            let r = arch.run(spmv).unwrap();
            assert!(r.validated);
            assert!(r.cycles > 0);
            assert!(r.perf() > 0.0);
        }
    }

    #[test]
    fn nexus_beats_tia_on_skewed_sparse() {
        // The headline claim at small scale: en-route execution helps an
        // irregular, load-imbalanced workload.
        let specs = suite(2);
        let spmv = specs
            .iter()
            .find(|s| s.name().starts_with("SpMV"))
            .unwrap();
        let nexus = FabricArch::nexus().run(spmv).unwrap();
        let tia = FabricArch::tia().run(spmv).unwrap();
        assert!(
            nexus.perf() > tia.perf(),
            "Nexus {} vs TIA {}",
            nexus.perf(),
            tia.perf()
        );
        assert!(nexus.in_network_frac > 0.0);
        assert_eq!(tia.in_network_frac, 0.0);
    }
}
