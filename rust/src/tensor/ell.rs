//! ELLPACK (ELL) padded sparse format.
//!
//! The XLA/Pallas golden models need static shapes, so the CSR matrices the
//! fabric executes are padded to ELL — a fixed `width` of (value, colidx)
//! slots per row — before being fed to the AOT artifacts. See DESIGN.md
//! §Hardware-Adaptation: on a TPU the CSR gather becomes a dense
//! `take`-and-reduce over the ELL slabs, which vectorizes on the VPU.

use super::csr::Csr;

/// ELL-padded matrix: `rows x width` slabs of values and column indices.
/// Padding slots carry value 0 and column index 0 (harmless under
/// multiply-accumulate since the value is 0).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Ell {
    pub rows: usize,
    pub cols: usize,
    /// Slots per row (>= max row nnz of the source matrix).
    pub width: usize,
    /// Row-major `rows x width` values (f32-convertible i16).
    pub values: Vec<i16>,
    /// Row-major `rows x width` column indices.
    pub colidx: Vec<u32>,
}

impl Ell {
    /// Pad a CSR matrix to ELL with at least `min_width` slots per row
    /// (the artifact shapes fix the width at AOT time).
    /// Panics if any row has more nonzeros than the chosen width allows —
    /// callers pick `min_width >= max_row_nnz`.
    pub fn from_csr(m: &Csr, min_width: usize) -> Self {
        let max_nnz = (0..m.rows).map(|r| m.row_nnz(r)).max().unwrap_or(0);
        let width = min_width.max(max_nnz);
        let mut values = vec![0i16; m.rows * width];
        let mut colidx = vec![0u32; m.rows * width];
        for r in 0..m.rows {
            for (slot, (c, v)) in m.row(r).enumerate() {
                values[r * width + slot] = v;
                colidx[r * width + slot] = c as u32;
            }
        }
        Ell {
            rows: m.rows,
            cols: m.cols,
            width,
            values,
            colidx,
        }
    }

    /// Exact-width variant for fixed artifact shapes. Errors if a row
    /// overflows `width`.
    pub fn from_csr_exact(m: &Csr, width: usize) -> Result<Self, String> {
        let max_nnz = (0..m.rows).map(|r| m.row_nnz(r)).max().unwrap_or(0);
        if max_nnz > width {
            return Err(format!(
                "row nnz {max_nnz} exceeds ELL width {width}; regenerate with lower density"
            ));
        }
        let mut e = Self::from_csr(m, width);
        e.width = width;
        // from_csr may have chosen a smaller natural width; re-pad.
        if e.values.len() != m.rows * width {
            let mut values = vec![0i16; m.rows * width];
            let mut colidx = vec![0u32; m.rows * width];
            for r in 0..m.rows {
                for (slot, (c, v)) in m.row(r).enumerate() {
                    values[r * width + slot] = v;
                    colidx[r * width + slot] = c as u32;
                }
            }
            e.values = values;
            e.colidx = colidx;
        }
        Ok(e)
    }

    /// SpMV reference over the padded form (must equal the CSR SpMV).
    pub fn spmv(&self, x: &[i16]) -> Vec<i16> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0i16; self.rows];
        for r in 0..self.rows {
            let mut acc = 0i16;
            for s in 0..self.width {
                let v = self.values[r * self.width + s];
                let c = self.colidx[r * self.width + s] as usize;
                acc = acc.wrapping_add(v.wrapping_mul(x[c]));
            }
            y[r] = acc;
        }
        y
    }

    /// Values as f32 (for feeding the XLA golden model).
    pub fn values_f32(&self) -> Vec<f32> {
        self.values.iter().map(|&v| v as f32).collect()
    }

    /// Column indices as f32 (the artifact takes indices as i32; PJRT input
    /// helpers here use f32 buffers + cast inside the graph when needed).
    pub fn colidx_i32(&self) -> Vec<i32> {
        self.colidx.iter().map(|&c| c as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn ell_spmv_matches_csr_spmv() {
        forall(100, |rng| {
            let r = 1 + rng.below_usize(16);
            let c = 1 + rng.below_usize(16);
            let m = gen::random_csr(rng, r, c, 0.4);
            let e = Ell::from_csr(&m, 4);
            let x: Vec<i16> = (0..c).map(|_| rng.range_i64(-3, 3) as i16).collect();
            ensure(e.spmv(&x) == m.spmv(&x), || "ELL spmv != CSR spmv".into())
        });
    }

    #[test]
    fn exact_width_rejects_overflow() {
        let m = Csr::from_triplets(1, 8, (0..5).map(|c| (0usize, c, 1i16)));
        assert!(Ell::from_csr_exact(&m, 4).is_err());
        let e = Ell::from_csr_exact(&m, 8).unwrap();
        assert_eq!(e.width, 8);
        assert_eq!(e.values.len(), 8);
    }

    #[test]
    fn padding_is_zero_valued() {
        let m = Csr::from_triplets(2, 4, vec![(0, 1, 5)]);
        let e = Ell::from_csr(&m, 3);
        assert_eq!(e.width, 3);
        assert_eq!(&e.values[..3], &[5, 0, 0]);
        assert_eq!(&e.values[3..], &[0, 0, 0]);
    }
}
