//! Tensor substrate: sparse/dense matrix formats, reference kernels,
//! reproducible sparsity generators, ELL padding for the XLA golden models,
//! and graph structures for the analytics workloads.

pub mod csr;
pub mod dense;
pub mod ell;
pub mod gen;
pub mod graph;

pub use csr::{Csr, CsrError, DupPolicy};
pub use dense::Dense;
pub use ell::Ell;
pub use graph::Graph;
