//! Graph substrate for the analytics workloads (BFS, SSSP, PageRank):
//! weighted adjacency-list graphs, a synthetic infect-dublin-like contact
//! graph, reference algorithms, and a METIS-like balanced partitioner
//! (greedy BFS-grow — see `DESIGN.md` §3 substitutions).

use crate::util::SplitMix64;

/// Distance value used as "unreached" (fits INT16 with headroom for +w).
pub const INF: i16 = i16::MAX / 2;

/// Directed weighted graph in adjacency-list form. `PartialEq` compares
/// exact adjacency (order included) — what the edge-list round-trip tests
/// assert.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    pub num_vertices: usize,
    /// `adj[v]` = list of (neighbor, weight).
    pub adj: Vec<Vec<(usize, i16)>>,
}

impl Graph {
    pub fn new(num_vertices: usize) -> Self {
        Graph {
            num_vertices,
            adj: vec![Vec::new(); num_vertices],
        }
    }

    pub fn add_edge(&mut self, u: usize, v: usize, w: i16) {
        assert!(u < self.num_vertices && v < self.num_vertices);
        self.adj[u].push((v, w));
    }

    /// Add edges in both directions (contact graphs are undirected).
    pub fn add_undirected(&mut self, u: usize, v: usize, w: i16) {
        self.add_edge(u, v, w);
        self.add_edge(v, u, w);
    }

    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum()
    }

    pub fn out_degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    /// Synthetic stand-in for the infect-dublin contact network \[41\]:
    /// 410 vertices, ~2765 undirected contact edges. Construction: a ring
    /// lattice (small-world backbone, contacts are locally clustered) plus
    /// preferential-attachment shortcuts to a few hub individuals (the
    /// heavy-tailed contact distribution typical of face-to-face datasets).
    /// Weights are small positive "contact duration" integers.
    pub fn infect_dublin_like(rng: &mut SplitMix64) -> Graph {
        Self::synthetic_contact(rng, 410, 2765)
    }

    /// General synthetic contact graph with `n` vertices and ~`target_edges`
    /// directed edges (counting both directions of each contact).
    pub fn synthetic_contact(rng: &mut SplitMix64, n: usize, target_edges: usize) -> Graph {
        let mut g = Graph::new(n);
        let mut seen = std::collections::HashSet::new();
        let add = |g: &mut Graph,
                       seen: &mut std::collections::HashSet<(usize, usize)>,
                       rng: &mut SplitMix64,
                       u: usize,
                       v: usize| {
            if u == v {
                return;
            }
            let key = (u.min(v), u.max(v));
            if seen.insert(key) {
                let w = 1 + rng.below(7) as i16;
                g.add_undirected(u, v, w);
            }
        };
        // Ring lattice: each vertex contacts its 2 nearest neighbors.
        for u in 0..n {
            add(&mut g, &mut seen, rng, u, (u + 1) % n);
            add(&mut g, &mut seen, rng, u, (u + 2) % n);
        }
        // Hubs: 5% of vertices attract preferential shortcuts.
        let hubs: Vec<usize> = rng.sample_indices(n, (n / 20).max(1));
        while g.num_edges() < target_edges {
            let u = rng.below_usize(n);
            let v = if rng.chance(0.4) {
                hubs[rng.below_usize(hubs.len())]
            } else {
                rng.below_usize(n)
            };
            add(&mut g, &mut seen, rng, u, v);
        }
        g
    }

    // --- reference algorithms --------------------------------------------

    /// BFS levels from `src` (INF for unreachable).
    pub fn bfs(&self, src: usize) -> Vec<i16> {
        let mut level = vec![INF; self.num_vertices];
        level[src] = 0;
        let mut frontier = std::collections::VecDeque::from([src]);
        while let Some(u) = frontier.pop_front() {
            for &(v, _) in &self.adj[u] {
                if level[v] == INF {
                    level[v] = level[u] + 1;
                    frontier.push_back(v);
                }
            }
        }
        level
    }

    /// Single-source shortest paths (Bellman-Ford style; weights are
    /// positive small ints so this matches Dijkstra).
    pub fn sssp(&self, src: usize) -> Vec<i16> {
        let mut dist = vec![INF; self.num_vertices];
        dist[src] = 0;
        // Worklist relaxation, the same fixpoint the fabric computes.
        let mut work = std::collections::VecDeque::from([src]);
        while let Some(u) = work.pop_front() {
            for &(v, w) in &self.adj[u] {
                let nd = dist[u].saturating_add(w).min(INF);
                if nd < dist[v] {
                    dist[v] = nd;
                    work.push_back(v);
                }
            }
        }
        dist
    }

    /// Fixed-point integer PageRank: `iters` synchronous iterations of
    /// `rank'[v] = base + sum_{u->v} rank[u] / deg(u)` with ranks scaled by
    /// `SCALE` — integer arithmetic matching the INT16 fabric exactly.
    pub fn pagerank_int(&self, iters: usize) -> Vec<i16> {
        const SCALE: i32 = 4096; // fixed-point 1.0
        let n = self.num_vertices as i32;
        // damping 0.5 keeps everything well inside i16 at our graph sizes
        // while preserving the convergence structure.
        let base = (SCALE / 2) / n.max(1);
        let mut rank: Vec<i16> = vec![(SCALE / n.max(1)) as i16; self.num_vertices];
        for _ in 0..iters {
            let mut next = vec![base as i16; self.num_vertices];
            for u in 0..self.num_vertices {
                let deg = self.out_degree(u) as i16;
                if deg == 0 {
                    continue;
                }
                let contrib = (rank[u] / deg) / 2; // damping 0.5
                for &(v, _) in &self.adj[u] {
                    next[v] = next[v].wrapping_add(contrib);
                }
            }
            rank = next;
        }
        rank
    }

    // --- partitioning ------------------------------------------------------

    /// METIS-like balanced partitioner (substitution per DESIGN.md): greedy
    /// BFS-grow. Picks seed vertices spread across the graph, grows each
    /// part by BFS until it reaches `ceil(n/parts)` vertices, assigning
    /// leftover vertices round-robin. Returns `part[v] in [0, parts)`.
    pub fn partition(&self, parts: usize, rng: &mut SplitMix64) -> Vec<usize> {
        let n = self.num_vertices;
        let cap = crate::util::ceil_div(n, parts);
        let mut part = vec![usize::MAX; n];
        let mut sizes = vec![0usize; parts];
        let seeds = rng.sample_indices(n, parts.min(n));
        let mut frontiers: Vec<std::collections::VecDeque<usize>> = seeds
            .iter()
            .map(|&s| std::collections::VecDeque::from([s]))
            .collect();
        // Round-robin BFS growth keeps parts balanced and connected-ish.
        let mut progress = true;
        while progress {
            progress = false;
            for p in 0..frontiers.len() {
                if sizes[p] >= cap {
                    continue;
                }
                while let Some(v) = frontiers[p].pop_front() {
                    if part[v] != usize::MAX {
                        continue;
                    }
                    part[v] = p;
                    sizes[p] += 1;
                    for &(u, _) in &self.adj[v] {
                        if part[u] == usize::MAX {
                            frontiers[p].push_back(u);
                        }
                    }
                    progress = true;
                    break;
                }
            }
        }
        // Disconnected leftovers: round-robin into the lightest parts.
        for v in 0..n {
            if part[v] == usize::MAX {
                let p = (0..parts).min_by_key(|&p| sizes[p]).unwrap();
                part[v] = p;
                sizes[p] += 1;
            }
        }
        part
    }

    /// Edge-cut of a partition (diagnostics / partitioner quality tests).
    pub fn edge_cut(&self, part: &[usize]) -> usize {
        let mut cut = 0;
        for u in 0..self.num_vertices {
            for &(v, _) in &self.adj[u] {
                if part[u] != part[v] {
                    cut += 1;
                }
            }
        }
        cut
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn infect_dublin_like_matches_published_size() {
        let mut rng = SplitMix64::new(41);
        let g = Graph::infect_dublin_like(&mut rng);
        assert_eq!(g.num_vertices, 410);
        // 2765 contacts => ~5530 directed edges; builder may slightly
        // overshoot by one contact.
        assert!(g.num_edges() >= 2765, "edges {}", g.num_edges());
    }

    #[test]
    fn bfs_levels_on_path() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1);
        g.add_edge(1, 2, 1);
        g.add_edge(2, 3, 1);
        assert_eq!(g.bfs(0), vec![0, 1, 2, 3]);
        assert_eq!(g.bfs(3), vec![INF, INF, INF, 0]);
    }

    #[test]
    fn sssp_prefers_lighter_path() {
        let mut g = Graph::new(3);
        g.add_edge(0, 2, 10);
        g.add_edge(0, 1, 1);
        g.add_edge(1, 2, 2);
        assert_eq!(g.sssp(0), vec![0, 1, 3]);
    }

    #[test]
    fn sssp_triangle_inequality_property() {
        forall(30, |rng| {
            let g = Graph::synthetic_contact(rng, 40, 150);
            let dist = g.sssp(0);
            for u in 0..g.num_vertices {
                if dist[u] >= INF {
                    continue;
                }
                for &(v, w) in &g.adj[u] {
                    if dist[v] > dist[u].saturating_add(w) {
                        return Err(format!("relax violated at {u}->{v}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pagerank_conserves_positivity() {
        let mut rng = SplitMix64::new(5);
        let g = Graph::synthetic_contact(&mut rng, 64, 300);
        let r = g.pagerank_int(5);
        assert!(r.iter().all(|&x| x >= 0));
        assert!(r.iter().any(|&x| x > 0));
    }

    #[test]
    fn partition_is_balanced_and_total() {
        forall(20, |rng| {
            let g = Graph::synthetic_contact(rng, 100, 400);
            let parts = 16;
            let part = g.partition(parts, rng);
            ensure(part.iter().all(|&p| p < parts), || "part id range".into())?;
            let mut sizes = vec![0usize; parts];
            for &p in &part {
                sizes[p] += 1;
            }
            let cap = crate::util::ceil_div(100, parts);
            ensure(sizes.iter().all(|&s| s <= cap + 1), || {
                format!("unbalanced: {sizes:?}")
            })
        });
    }

    #[test]
    fn partition_beats_random_cut() {
        let mut rng = SplitMix64::new(77);
        let g = Graph::synthetic_contact(&mut rng, 200, 800);
        let part = g.partition(16, &mut rng);
        let cut = g.edge_cut(&part);
        // Random assignment cuts ~15/16 of edges; BFS-grow must do better.
        let mut rand_part = vec![0usize; 200];
        for p in rand_part.iter_mut() {
            *p = rng.below_usize(16);
        }
        let rand_cut = g.edge_cut(&rand_part);
        assert!(
            cut < rand_cut,
            "BFS-grow cut {cut} should beat random {rand_cut}"
        );
    }
}
