//! Reproducible workload generators.
//!
//! The paper evaluates on pruned+fine-tuned ResNet-50 layer matrices with
//! controlled sparsification (§4.2) and the infect-dublin contact graph. We
//! have neither the trained weights nor the dataset in this environment, so
//! (per DESIGN.md §3 substitutions) we generate:
//!
//! - unstructured-sparsity matrices at the paper's density bands, with
//!   values drawn small enough that INT16 arithmetic never saturates in the
//!   validation comparisons;
//! - the S1–S4 SpMSpM sparsity regimes of §4.2;
//! - ResNet-50-like layer shapes scaled to the fabric's SRAM;
//! - a synthetic contact graph with infect-dublin's published size
//!   (410 vertices / 2765 edges) and heavy-tailed degree skew.
//!
//! Everything is driven by an explicit [`SplitMix64`] seed.

use super::csr::Csr;
use super::dense::Dense;
use crate::util::SplitMix64;

/// Small nonzero value in `[-4, 4] \ {0}` — keeps INT16 results exact for
/// golden-model comparison at our workload sizes.
fn small_value(rng: &mut SplitMix64) -> i16 {
    loop {
        let v = rng.range_i64(-4, 4) as i16;
        if v != 0 {
            return v;
        }
    }
}

/// Random CSR with i.i.d. Bernoulli(density) nonzeros.
pub fn random_csr(rng: &mut SplitMix64, rows: usize, cols: usize, density: f64) -> Csr {
    let mut trip = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if rng.chance(density) {
                trip.push((r, c, small_value(rng)));
            }
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

/// Random CSR with a *skewed* (power-law-ish) row-nnz distribution: a few
/// heavy rows and many light rows. This is the shape that creates the load
/// imbalance of Fig 3(b) on data-local architectures.
pub fn skewed_csr(rng: &mut SplitMix64, rows: usize, cols: usize, density: f64) -> Csr {
    let target_nnz = ((rows * cols) as f64 * density).round() as usize;
    // Zipf-like row weights.
    let weights: Vec<f64> = (0..rows).map(|r| 1.0 / (1.0 + r as f64)).collect();
    let wsum: f64 = weights.iter().sum();
    let mut order: Vec<usize> = (0..rows).collect();
    rng.shuffle(&mut order);
    let mut trip = Vec::new();
    for (rank, &r) in order.iter().enumerate() {
        let quota =
            ((weights[rank] / wsum) * target_nnz as f64).round() as usize;
        let quota = quota.min(cols);
        for c in rng.sample_indices(cols, quota) {
            trip.push((r, c, small_value(rng)));
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

/// Random dense matrix with entries in `[-amp, amp]`.
pub fn random_dense(rng: &mut SplitMix64, rows: usize, cols: usize, amp: i64) -> Dense {
    let data = (0..rows * cols)
        .map(|_| rng.range_i64(-amp, amp) as i16)
        .collect();
    Dense::from_vec(rows, cols, data)
}

/// Random dense vector.
pub fn random_vec(rng: &mut SplitMix64, n: usize, amp: i64) -> Vec<i16> {
    (0..n).map(|_| rng.range_i64(-amp, amp) as i16).collect()
}

/// §4.2 SpMSpM sparsity regimes. Sparsity = fraction of *zeros*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparsityRegime {
    /// S1: both matrices moderately sparse (30–60% sparsity).
    S1,
    /// S2: A highly sparse (60–90%), B moderately sparse.
    S2,
    /// S3: A moderately sparse, B highly sparse.
    S3,
    /// S4: both highly sparse.
    S4,
}

impl SparsityRegime {
    pub fn name(self) -> &'static str {
        match self {
            SparsityRegime::S1 => "S1",
            SparsityRegime::S2 => "S2",
            SparsityRegime::S3 => "S3",
            SparsityRegime::S4 => "S4",
        }
    }

    /// Representative (sparsity_A, sparsity_B) midpoints of each band.
    pub fn sparsities(self) -> (f64, f64) {
        match self {
            SparsityRegime::S1 => (0.45, 0.45),
            SparsityRegime::S2 => (0.75, 0.45),
            SparsityRegime::S3 => (0.45, 0.75),
            SparsityRegime::S4 => (0.75, 0.75),
        }
    }

    pub fn all() -> [SparsityRegime; 4] {
        [
            SparsityRegime::S1,
            SparsityRegime::S2,
            SparsityRegime::S3,
            SparsityRegime::S4,
        ]
    }
}

/// Generate the (A, B) pair for an SpMSpM regime at the given square size.
pub fn spmspm_pair(rng: &mut SplitMix64, n: usize, regime: SparsityRegime) -> (Csr, Csr) {
    let (sa, sb) = regime.sparsities();
    let a = skewed_csr(rng, n, n, 1.0 - sa);
    let b = random_csr(rng, n, n, 1.0 - sb);
    (a, b)
}

/// A pruned-ResNet-50-like layer matrix: 64x64 at the requested sparsity,
/// with skewed rows (structured pruning leaves uneven row occupancy). 64x64
/// INT16 tiles are what fit the 16KB fabric after partitioning, mirroring
/// how the paper tiles ResNet-50 GEMMs onto the array (§3.1.1).
pub fn resnet_like_layer(rng: &mut SplitMix64, sparsity: f64) -> Csr {
    skewed_csr(rng, 64, 64, 1.0 - sparsity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn random_csr_density_tracks_request() {
        let mut rng = SplitMix64::new(1);
        let m = random_csr(&mut rng, 64, 64, 0.3);
        let d = m.density();
        assert!((d - 0.3).abs() < 0.06, "density {d}");
        m.validate().unwrap();
    }

    #[test]
    fn skewed_csr_is_skewed() {
        let mut rng = SplitMix64::new(2);
        let m = skewed_csr(&mut rng, 64, 64, 0.3);
        let nnzs: Vec<f64> = (0..m.rows).map(|r| m.row_nnz(r) as f64).collect();
        let cv = crate::util::cv(&nnzs);
        assert!(cv > 0.5, "expected heavy skew, cv={cv}");
        m.validate().unwrap();
    }

    #[test]
    fn regimes_order_sparsities() {
        let (a1, b1) = SparsityRegime::S1.sparsities();
        let (a2, _) = SparsityRegime::S2.sparsities();
        let (_, b3) = SparsityRegime::S3.sparsities();
        assert!(a2 > a1);
        assert!(b3 > b1);
    }

    #[test]
    fn generators_are_deterministic() {
        forall(10, |rng| {
            let seed = rng.next_u64();
            let a = random_csr(&mut SplitMix64::new(seed), 16, 16, 0.4);
            let b = random_csr(&mut SplitMix64::new(seed), 16, 16, 0.4);
            ensure(a == b, || "same seed must give same matrix".into())
        });
    }
}
