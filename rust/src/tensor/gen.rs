//! Reproducible workload generators.
//!
//! The paper evaluates on pruned+fine-tuned ResNet-50 layer matrices with
//! controlled sparsification (§4.2) and the infect-dublin contact graph. We
//! have neither the trained weights nor the dataset in this environment, so
//! (per DESIGN.md §3 substitutions) we generate:
//!
//! - unstructured-sparsity matrices at the paper's density bands, with
//!   values drawn small enough that INT16 arithmetic never saturates in the
//!   validation comparisons;
//! - the S1–S4 SpMSpM sparsity regimes of §4.2;
//! - ResNet-50-like layer shapes scaled to the fabric's SRAM;
//! - a synthetic contact graph with infect-dublin's published size
//!   (410 vertices / 2765 edges) and heavy-tailed degree skew.
//!
//! Everything is driven by an explicit [`SplitMix64`] seed.

use super::csr::Csr;
use super::dense::Dense;
use super::graph::Graph;
use crate::util::SplitMix64;
use std::collections::BTreeSet;

/// Small nonzero value in `[-4, 4] \ {0}` — keeps INT16 results exact for
/// golden-model comparison at our workload sizes.
fn small_value(rng: &mut SplitMix64) -> i16 {
    loop {
        let v = rng.range_i64(-4, 4) as i16;
        if v != 0 {
            return v;
        }
    }
}

/// Random CSR with i.i.d. Bernoulli(density) nonzeros.
pub fn random_csr(rng: &mut SplitMix64, rows: usize, cols: usize, density: f64) -> Csr {
    let mut trip = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if rng.chance(density) {
                trip.push((r, c, small_value(rng)));
            }
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

/// Random CSR with a *skewed* (power-law-ish) row-nnz distribution: a few
/// heavy rows and many light rows. This is the shape that creates the load
/// imbalance of Fig 3(b) on data-local architectures.
pub fn skewed_csr(rng: &mut SplitMix64, rows: usize, cols: usize, density: f64) -> Csr {
    let target_nnz = ((rows * cols) as f64 * density).round() as usize;
    // Zipf-like row weights.
    let weights: Vec<f64> = (0..rows).map(|r| 1.0 / (1.0 + r as f64)).collect();
    let wsum: f64 = weights.iter().sum();
    let mut order: Vec<usize> = (0..rows).collect();
    rng.shuffle(&mut order);
    let mut trip = Vec::new();
    for (rank, &r) in order.iter().enumerate() {
        let quota =
            ((weights[rank] / wsum) * target_nnz as f64).round() as usize;
        let quota = quota.min(cols);
        for c in rng.sample_indices(cols, quota) {
            trip.push((r, c, small_value(rng)));
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

/// Random dense matrix with entries in `[-amp, amp]`.
pub fn random_dense(rng: &mut SplitMix64, rows: usize, cols: usize, amp: i64) -> Dense {
    let data = (0..rows * cols)
        .map(|_| rng.range_i64(-amp, amp) as i16)
        .collect();
    Dense::from_vec(rows, cols, data)
}

/// Random dense vector.
pub fn random_vec(rng: &mut SplitMix64, n: usize, amp: i64) -> Vec<i16> {
    (0..n).map(|_| rng.range_i64(-amp, amp) as i16).collect()
}

// --- irregular generators (dataset/scenario corpus) ----------------------
//
// The i.i.d. Bernoulli generators above are the most *regular* kind of
// "sparse" there is: every row and column has the same expected occupancy,
// so per-PE load stays flat no matter how the tensor is partitioned. The
// generators below produce the heavy-tailed / clustered structure real
// irregular datasets have (and that DCRA / DPU-v2 evaluate on), which is
// what actually stresses the load-balancing story of the paper.

/// Graph500 R-MAT quadrant probabilities `(a, b, c, d)` — heavy-tailed on
/// both rows and columns.
pub const RMAT_PROBS: (f64, f64, f64, f64) = (0.57, 0.19, 0.19, 0.05);

/// Smallest `k` with `2^k >= n` (`n >= 1`).
fn log2_ceil(n: usize) -> u32 {
    let mut k = 0u32;
    while (1usize << k) < n {
        k += 1;
    }
    k
}

/// One R-MAT coordinate sample on the `side x side` recursive grid
/// (`side` a power of two): descend the quadtree, picking a quadrant per
/// level with probabilities `probs`.
fn rmat_coord(rng: &mut SplitMix64, side: usize, probs: (f64, f64, f64, f64)) -> (usize, usize) {
    let (a, b, c, _d) = probs;
    let (mut r, mut col) = (0usize, 0usize);
    let mut span = side;
    while span > 1 {
        span /= 2;
        let x = rng.f64();
        if x < a {
            // top-left: nothing to add
        } else if x < a + b {
            col += span;
        } else if x < a + b + c {
            r += span;
        } else {
            r += span;
            col += span;
        }
    }
    (r, col)
}

/// R-MAT sparse matrix: ~`target_nnz` distinct coordinates drawn by
/// recursive quadrant sampling (Graph500's generator), values small and
/// nonzero. Both row and column occupancies come out power-law-ish, which
/// is the degree structure of real graphs/matrices. Sampling is rejection-
/// based (distinct coordinates, in-range for non-power-of-two shapes) with
/// a bounded attempt budget, so very dense requests may undershoot.
pub fn rmat_csr(
    rng: &mut SplitMix64,
    rows: usize,
    cols: usize,
    target_nnz: usize,
    probs: (f64, f64, f64, f64),
) -> Csr {
    assert!(rows > 0 && cols > 0);
    let side = 1usize << log2_ceil(rows.max(cols));
    let mut coords: BTreeSet<(usize, usize)> = BTreeSet::new();
    let target = target_nnz.min(rows * cols);
    let budget = 20 * target.max(1);
    let mut attempts = 0usize;
    while coords.len() < target && attempts < budget {
        attempts += 1;
        let (r, c) = rmat_coord(rng, side, probs);
        if r < rows && c < cols {
            coords.insert((r, c));
        }
    }
    let trip: Vec<(usize, usize, i16)> = coords
        .into_iter()
        .map(|(r, c)| (r, c, small_value(rng)))
        .collect();
    Csr::from_triplets(rows, cols, trip)
}

/// R-MAT directed graph: ~`target_edges` distinct non-self-loop edges on
/// `n` vertices with small positive weights. The usual synthetic stand-in
/// for scale-free graph datasets.
pub fn rmat_graph(
    rng: &mut SplitMix64,
    n: usize,
    target_edges: usize,
    probs: (f64, f64, f64, f64),
) -> Graph {
    assert!(n > 1);
    let side = 1usize << log2_ceil(n);
    let mut edges: BTreeSet<(usize, usize)> = BTreeSet::new();
    let target = target_edges.min(n * (n - 1));
    let budget = 20 * target.max(1);
    let mut attempts = 0usize;
    while edges.len() < target && attempts < budget {
        attempts += 1;
        let (u, v) = rmat_coord(rng, side, probs);
        if u < n && v < n && u != v {
            edges.insert((u, v));
        }
    }
    let mut g = Graph::new(n);
    for (u, v) in edges {
        let w = 1 + rng.below(7) as i16;
        g.add_edge(u, v, w);
    }
    g
}

/// Chung-Lu power-law matrix: expected row occupancies follow
/// `w_k ∝ (k+1)^-alpha` over a random row permutation, and within each row
/// the column choices are themselves power-law weighted (a few popular
/// columns). `alpha` around 0.8–1.2 gives realistic heavy tails; 0 recovers
/// near-uniform occupancy.
pub fn chung_lu_csr(
    rng: &mut SplitMix64,
    rows: usize,
    cols: usize,
    density: f64,
    alpha: f64,
) -> Csr {
    assert!(rows > 0 && cols > 0);
    let target_nnz = ((rows * cols) as f64 * density).round() as usize;
    // Power-law weights over ranks; random permutations decouple rank from
    // index so the heavy rows/columns land anywhere.
    let rw: Vec<f64> = (0..rows).map(|k| ((k + 1) as f64).powf(-alpha)).collect();
    let rw_sum: f64 = rw.iter().sum();
    let cw: Vec<f64> = (0..cols).map(|k| ((k + 1) as f64).powf(-alpha)).collect();
    let mut col_cum = Vec::with_capacity(cols);
    let mut acc = 0.0;
    for &w in &cw {
        acc += w;
        col_cum.push(acc);
    }
    let mut row_order: Vec<usize> = (0..rows).collect();
    rng.shuffle(&mut row_order);
    let mut col_order: Vec<usize> = (0..cols).collect();
    rng.shuffle(&mut col_order);
    let mut trip = Vec::new();
    for (rank, &r) in row_order.iter().enumerate() {
        let quota = ((rw[rank] / rw_sum) * target_nnz as f64).round() as usize;
        let quota = quota.min(cols);
        let mut chosen: BTreeSet<usize> = BTreeSet::new();
        let mut attempts = 0usize;
        while chosen.len() < quota && attempts < 20 * quota.max(1) {
            attempts += 1;
            let x = rng.f64() * acc;
            // First cumulative weight >= x picks the column rank.
            let k = col_cum.partition_point(|&c| c < x).min(cols - 1);
            chosen.insert(col_order[k]);
        }
        for c in chosen {
            trip.push((r, c, small_value(rng)));
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

/// Banded matrix: Bernoulli(`density`) nonzeros confined to the diagonal
/// band `|r - c| <= halfband`. Clustered structure with strong data
/// locality — the opposite adversary to the hotspot generator.
pub fn banded_csr(rng: &mut SplitMix64, n: usize, halfband: usize, density: f64) -> Csr {
    let mut trip = Vec::new();
    for r in 0..n {
        let lo = r.saturating_sub(halfband);
        let hi = (r + halfband).min(n.saturating_sub(1));
        for c in lo..=hi {
            if rng.chance(density) {
                trip.push((r, c, small_value(rng)));
            }
        }
    }
    Csr::from_triplets(n, n, trip)
}

/// Block-diagonal matrix: Bernoulli(`density`) nonzeros inside
/// `block x block` diagonal blocks, zero elsewhere. Models clustered
/// community structure (each block is a dense-ish sub-problem).
pub fn block_diag_csr(rng: &mut SplitMix64, n: usize, block: usize, density: f64) -> Csr {
    assert!(block > 0);
    let mut trip = Vec::new();
    let mut base = 0usize;
    while base < n {
        let end = (base + block).min(n);
        for r in base..end {
            for c in base..end {
                if rng.chance(density) {
                    trip.push((r, c, small_value(rng)));
                }
            }
        }
        base = end;
    }
    Csr::from_triplets(n, n, trip)
}

/// Adversarial "hotspot rows" matrix: `hot_rows` randomly chosen rows carry
/// `hot_share` of the nnz budget (each capped at a full row); the remainder
/// spreads uniformly over the other rows. This is the worst case for
/// data-local architectures — a few PEs own nearly all the aggregation
/// work — and the generator the load-imbalance acceptance checks lean on.
pub fn hotspot_csr(
    rng: &mut SplitMix64,
    rows: usize,
    cols: usize,
    density: f64,
    hot_rows: usize,
    hot_share: f64,
) -> Csr {
    assert!(rows > 0 && cols > 0);
    let target_nnz = ((rows * cols) as f64 * density).round() as usize;
    let hot_rows = hot_rows.clamp(1, rows);
    let hot = rng.sample_indices(rows, hot_rows);
    let is_hot = {
        let mut v = vec![false; rows];
        for &r in &hot {
            v[r] = true;
        }
        v
    };
    let mut trip = Vec::new();
    let hot_budget = (target_nnz as f64 * hot_share.clamp(0.0, 1.0)).round() as usize;
    let per_hot = (hot_budget / hot_rows).min(cols);
    for &r in &hot {
        for c in rng.sample_indices(cols, per_hot) {
            trip.push((r, c, small_value(rng)));
        }
    }
    let cold_rows = rows - hot_rows;
    if cold_rows > 0 {
        let cold_budget = target_nnz.saturating_sub(per_hot * hot_rows);
        let per_cold = (cold_budget / cold_rows).min(cols);
        for r in 0..rows {
            if is_hot[r] || per_cold == 0 {
                continue;
            }
            for c in rng.sample_indices(cols, per_cold) {
                trip.push((r, c, small_value(rng)));
            }
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

/// §4.2 SpMSpM sparsity regimes. Sparsity = fraction of *zeros*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparsityRegime {
    /// S1: both matrices moderately sparse (30–60% sparsity).
    S1,
    /// S2: A highly sparse (60–90%), B moderately sparse.
    S2,
    /// S3: A moderately sparse, B highly sparse.
    S3,
    /// S4: both highly sparse.
    S4,
}

impl SparsityRegime {
    pub fn name(self) -> &'static str {
        match self {
            SparsityRegime::S1 => "S1",
            SparsityRegime::S2 => "S2",
            SparsityRegime::S3 => "S3",
            SparsityRegime::S4 => "S4",
        }
    }

    /// Representative (sparsity_A, sparsity_B) midpoints of each band.
    pub fn sparsities(self) -> (f64, f64) {
        match self {
            SparsityRegime::S1 => (0.45, 0.45),
            SparsityRegime::S2 => (0.75, 0.45),
            SparsityRegime::S3 => (0.45, 0.75),
            SparsityRegime::S4 => (0.75, 0.75),
        }
    }

    pub fn all() -> [SparsityRegime; 4] {
        [
            SparsityRegime::S1,
            SparsityRegime::S2,
            SparsityRegime::S3,
            SparsityRegime::S4,
        ]
    }
}

/// Generate the (A, B) pair for an SpMSpM regime at the given square size.
pub fn spmspm_pair(rng: &mut SplitMix64, n: usize, regime: SparsityRegime) -> (Csr, Csr) {
    let (sa, sb) = regime.sparsities();
    let a = skewed_csr(rng, n, n, 1.0 - sa);
    let b = random_csr(rng, n, n, 1.0 - sb);
    (a, b)
}

/// A pruned-ResNet-50-like layer matrix: 64x64 at the requested sparsity,
/// with skewed rows (structured pruning leaves uneven row occupancy). 64x64
/// INT16 tiles are what fit the 16KB fabric after partitioning, mirroring
/// how the paper tiles ResNet-50 GEMMs onto the array (§3.1.1).
pub fn resnet_like_layer(rng: &mut SplitMix64, sparsity: f64) -> Csr {
    skewed_csr(rng, 64, 64, 1.0 - sparsity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn random_csr_density_tracks_request() {
        let mut rng = SplitMix64::new(1);
        let m = random_csr(&mut rng, 64, 64, 0.3);
        let d = m.density();
        assert!((d - 0.3).abs() < 0.06, "density {d}");
        m.validate().unwrap();
    }

    #[test]
    fn skewed_csr_is_skewed() {
        let mut rng = SplitMix64::new(2);
        let m = skewed_csr(&mut rng, 64, 64, 0.3);
        let nnzs: Vec<f64> = (0..m.rows).map(|r| m.row_nnz(r) as f64).collect();
        let cv = crate::util::cv(&nnzs);
        assert!(cv > 0.5, "expected heavy skew, cv={cv}");
        m.validate().unwrap();
    }

    #[test]
    fn regimes_order_sparsities() {
        let (a1, b1) = SparsityRegime::S1.sparsities();
        let (a2, _) = SparsityRegime::S2.sparsities();
        let (_, b3) = SparsityRegime::S3.sparsities();
        assert!(a2 > a1);
        assert!(b3 > b1);
    }

    #[test]
    fn generators_are_deterministic() {
        forall(10, |rng| {
            let seed = rng.next_u64();
            let a = random_csr(&mut SplitMix64::new(seed), 16, 16, 0.4);
            let b = random_csr(&mut SplitMix64::new(seed), 16, 16, 0.4);
            ensure(a == b, || "same seed must give same matrix".into())
        });
    }

    #[test]
    fn rmat_csr_is_heavy_tailed() {
        let mut rng = SplitMix64::new(7);
        let m = rmat_csr(&mut rng, 64, 64, 400, RMAT_PROBS);
        m.validate().unwrap();
        assert!(m.nnz() >= 300, "undershoot: {}", m.nnz());
        let nnzs: Vec<f64> = (0..m.rows).map(|r| m.row_nnz(r) as f64).collect();
        let cv = crate::util::cv(&nnzs);
        assert!(cv > 0.7, "R-MAT rows should be heavy-tailed, cv={cv}");
    }

    #[test]
    fn rmat_csr_is_deterministic() {
        let a = rmat_csr(&mut SplitMix64::new(9), 32, 32, 200, RMAT_PROBS);
        let b = rmat_csr(&mut SplitMix64::new(9), 32, 32, 200, RMAT_PROBS);
        assert_eq!(a, b);
    }

    #[test]
    fn rmat_graph_shape_and_determinism() {
        let g = rmat_graph(&mut SplitMix64::new(5), 48, 180, RMAT_PROBS);
        assert_eq!(g.num_vertices, 48);
        assert!(g.num_edges() >= 120, "edges {}", g.num_edges());
        for (u, edges) in g.adj.iter().enumerate() {
            for &(v, w) in edges {
                assert!(v < 48 && v != u);
                assert!((1..=7).contains(&w));
            }
        }
        let h = rmat_graph(&mut SplitMix64::new(5), 48, 180, RMAT_PROBS);
        assert_eq!(g.adj, h.adj);
    }

    #[test]
    fn chung_lu_is_skewed_and_in_density_ballpark() {
        let mut rng = SplitMix64::new(11);
        let m = chung_lu_csr(&mut rng, 64, 64, 0.2, 1.0);
        m.validate().unwrap();
        let d = m.density();
        assert!(d > 0.05 && d < 0.35, "density {d}");
        let nnzs: Vec<f64> = (0..m.rows).map(|r| m.row_nnz(r) as f64).collect();
        assert!(crate::util::cv(&nnzs) > 0.5, "rows should be skewed");
    }

    #[test]
    fn banded_stays_in_band() {
        let mut rng = SplitMix64::new(13);
        let m = banded_csr(&mut rng, 48, 3, 0.6);
        m.validate().unwrap();
        assert!(m.nnz() > 0);
        for r in 0..m.rows {
            for (c, _) in m.row(r) {
                let dist = r.abs_diff(c);
                assert!(dist <= 3, "({r},{c}) outside band");
            }
        }
    }

    #[test]
    fn block_diag_stays_in_blocks() {
        let mut rng = SplitMix64::new(17);
        let m = block_diag_csr(&mut rng, 40, 8, 0.5);
        m.validate().unwrap();
        assert!(m.nnz() > 0);
        for r in 0..m.rows {
            for (c, _) in m.row(r) {
                assert_eq!(r / 8, c / 8, "({r},{c}) outside its diagonal block");
            }
        }
    }

    #[test]
    fn hotspot_concentrates_nnz() {
        let mut rng = SplitMix64::new(19);
        let m = hotspot_csr(&mut rng, 64, 64, 0.1, 4, 0.85);
        m.validate().unwrap();
        let mut nnzs: Vec<usize> = (0..m.rows).map(|r| m.row_nnz(r)).collect();
        nnzs.sort_unstable_by(|a, b| b.cmp(a));
        let top4: usize = nnzs[..4].iter().sum();
        assert!(
            top4 * 2 > m.nnz(),
            "4 hot rows should hold most nnz: {top4} of {}",
            m.nnz()
        );
        let all: Vec<f64> = (0..m.rows).map(|r| m.row_nnz(r) as f64).collect();
        assert!(crate::util::cv(&all) > 1.0, "hotspot cv too low");
    }
}
