//! Dense row-major i16 matrices and reference dense kernels (MatMul, MV,
//! Conv) matching the fabric's wrapping INT16 arithmetic.

/// Dense row-major matrix of i16.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dense {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i16>,
}

impl Dense {
    pub fn zero(rows: usize, cols: usize) -> Self {
        Dense {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<i16>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Dense { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i16 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i16) {
        self.data[r * self.cols + c] = v;
    }

    /// `C = self * other` with wrapping INT16 accumulate.
    pub fn matmul(&self, other: &Dense) -> Dense {
        assert_eq!(self.cols, other.rows);
        let mut c = Dense::zero(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0 {
                    continue;
                }
                for j in 0..other.cols {
                    let v = c.get(i, j).wrapping_add(a.wrapping_mul(other.get(k, j)));
                    c.set(i, j, v);
                }
            }
        }
        c
    }

    /// `y = self * x`.
    pub fn matvec(&self, x: &[i16]) -> Vec<i16> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0i16; self.rows];
        for r in 0..self.rows {
            let mut acc = 0i16;
            for c in 0..self.cols {
                acc = acc.wrapping_add(self.get(r, c).wrapping_mul(x[c]));
            }
            y[r] = acc;
        }
        y
    }

    /// Element-wise addition.
    pub fn add(&self, other: &Dense) -> Dense {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a.wrapping_add(*b))
            .collect();
        Dense::from_vec(self.rows, self.cols, data)
    }

    /// 2D valid convolution (single channel): `out[h,w] = sum_{i,j}
    /// input[h+i, w+j] * filter[i,j]`. This is the reference for the Conv
    /// workload; the fabric executes it by replicating the filter across PEs
    /// (§5.1: "Nexus Machine efficiently handles Conv by replicating filters
    /// across PEs"), without im2col.
    pub fn conv2d_valid(&self, filter: &Dense) -> Dense {
        assert!(filter.rows <= self.rows && filter.cols <= self.cols);
        let oh = self.rows - filter.rows + 1;
        let ow = self.cols - filter.cols + 1;
        let mut out = Dense::zero(oh, ow);
        for h in 0..oh {
            for w in 0..ow {
                let mut acc = 0i16;
                for i in 0..filter.rows {
                    for j in 0..filter.cols {
                        acc = acc
                            .wrapping_add(self.get(h + i, w + j).wrapping_mul(filter.get(i, j)));
                    }
                }
                out.set(h, w, acc);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Dense::from_vec(2, 2, vec![1, 2, 3, 4]);
        let id = Dense::from_vec(2, 2, vec![1, 0, 0, 1]);
        assert_eq!(a.matmul(&id), a);
        assert_eq!(id.matmul(&a), a);
    }

    #[test]
    fn matvec_known() {
        let a = Dense::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(a.matvec(&[1, 1, 1]), vec![6, 15]);
    }

    #[test]
    fn conv2d_known() {
        // 3x3 input, 2x2 filter of ones => 2x2 output of window sums.
        let x = Dense::from_vec(3, 3, vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
        let f = Dense::from_vec(2, 2, vec![1, 1, 1, 1]);
        let y = x.conv2d_valid(&f);
        assert_eq!(y.data, vec![12, 16, 24, 28]);
    }

    #[test]
    fn add_wraps() {
        let a = Dense::from_vec(1, 1, vec![i16::MAX]);
        let b = Dense::from_vec(1, 1, vec![1]);
        assert_eq!(a.add(&b).get(0, 0), i16::MIN);
    }
}
