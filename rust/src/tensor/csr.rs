//! Compressed Sparse Row matrices over i16 values (the fabric's INT16 word),
//! plus the pure-software reference kernels the simulator is validated
//! against (SpMV, SpGEMM via Gustavson, SpADD, SDDMM).

use super::dense::Dense;
use std::fmt;

/// Typed construction failure for the dataset-ingestion path: loaders turn
/// these into per-line parse errors instead of panicking mid-file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CsrError {
    /// A coordinate lies outside the declared matrix shape.
    OutOfBounds {
        row: usize,
        col: usize,
        rows: usize,
        cols: usize,
    },
    /// The same coordinate appeared twice under [`DupPolicy::Reject`].
    Duplicate { row: usize, col: usize },
}

impl fmt::Display for CsrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CsrError::OutOfBounds {
                row,
                col,
                rows,
                cols,
            } => write!(
                f,
                "coordinate ({row},{col}) outside the {rows}x{cols} matrix"
            ),
            CsrError::Duplicate { row, col } => {
                write!(f, "duplicate coordinate ({row},{col})")
            }
        }
    }
}

impl std::error::Error for CsrError {}

/// What [`Csr::try_from_triplets`] does with repeated coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DupPolicy {
    /// Merge duplicates by wrapping INT16 addition (the historical
    /// `from_triplets` behavior; right for COO accumulation).
    Sum,
    /// Fail with [`CsrError::Duplicate`] — dataset files that list the same
    /// coordinate twice are malformed, not accumulations.
    Reject,
}

/// CSR sparse matrix. Values are i16 (fabric word); all reference kernels
/// use wrapping INT16 arithmetic so they agree bit-for-bit with the fabric.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer array, length `rows + 1`.
    pub rowptr: Vec<usize>,
    /// Column indices of nonzeros, row-major-concatenated.
    pub colidx: Vec<usize>,
    /// Nonzero values, aligned with `colidx`.
    pub values: Vec<i16>,
}

impl Csr {
    /// Empty matrix with no nonzeros.
    pub fn zero(rows: usize, cols: usize) -> Self {
        Csr {
            rows,
            cols,
            rowptr: vec![0; rows + 1],
            colidx: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Build from COO triplets (row, col, value). Duplicates are summed
    /// (wrapping); explicit zeros are dropped. Panics on out-of-bounds
    /// coordinates — loaders use [`Csr::try_from_triplets`] instead.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: impl IntoIterator<Item = (usize, usize, i16)>,
    ) -> Self {
        match Self::try_from_triplets(rows, cols, triplets, DupPolicy::Sum) {
            Ok(m) => m,
            Err(e) => panic!("triplet {e}"),
        }
    }

    /// Fallible COO construction for the ingestion path: out-of-bounds
    /// coordinates are a typed error, and `dup` decides whether repeated
    /// coordinates merge (wrapping sum) or fail. Explicit zeros (and
    /// duplicates summing to zero under [`DupPolicy::Sum`]) are dropped.
    pub fn try_from_triplets(
        rows: usize,
        cols: usize,
        triplets: impl IntoIterator<Item = (usize, usize, i16)>,
        dup: DupPolicy,
    ) -> Result<Self, CsrError> {
        let mut per_row: Vec<Vec<(usize, i16)>> = vec![Vec::new(); rows];
        for (r, c, v) in triplets {
            if r >= rows || c >= cols {
                return Err(CsrError::OutOfBounds {
                    row: r,
                    col: c,
                    rows,
                    cols,
                });
            }
            per_row[r].push((c, v));
        }
        let mut rowptr = Vec::with_capacity(rows + 1);
        let mut colidx = Vec::new();
        let mut values = Vec::new();
        rowptr.push(0);
        for (r, row) in per_row.iter_mut().enumerate() {
            row.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < row.len() {
                let c = row[i].0;
                let mut v = 0i16;
                let mut n = 0usize;
                while i < row.len() && row[i].0 == c {
                    v = v.wrapping_add(row[i].1);
                    n += 1;
                    i += 1;
                }
                if n > 1 && dup == DupPolicy::Reject {
                    return Err(CsrError::Duplicate { row: r, col: c });
                }
                if v != 0 {
                    colidx.push(c);
                    values.push(v);
                }
            }
            rowptr.push(colidx.len());
        }
        Ok(Csr {
            rows,
            cols,
            rowptr,
            colidx,
            values,
        })
    }

    /// Build from a dense row-major matrix, dropping zeros.
    pub fn from_dense(d: &Dense) -> Self {
        let mut trip = Vec::new();
        for r in 0..d.rows {
            for c in 0..d.cols {
                let v = d.get(r, c);
                if v != 0 {
                    trip.push((r, c, v));
                }
            }
        }
        Csr::from_triplets(d.rows, d.cols, trip)
    }

    /// Materialize to dense.
    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zero(self.rows, self.cols);
        for r in 0..self.rows {
            for k in self.rowptr[r]..self.rowptr[r + 1] {
                d.set(r, self.colidx[k], self.values[k]);
            }
        }
        d
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Nonzeros in row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.rowptr[r + 1] - self.rowptr[r]
    }

    /// (colidx, value) pairs of row `r`.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (usize, i16)> + '_ {
        (self.rowptr[r]..self.rowptr[r + 1]).map(move |k| (self.colidx[k], self.values[k]))
    }

    /// Density = nnz / (rows*cols).
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows * self.cols) as f64
        }
    }

    /// Sparsity = 1 - density (the paper reports sparsity percentages).
    pub fn sparsity(&self) -> f64 {
        1.0 - self.density()
    }

    /// Transpose (CSR of the transpose).
    pub fn transpose(&self) -> Csr {
        let mut trip = Vec::with_capacity(self.nnz());
        for r in 0..self.rows {
            for (c, v) in self.row(r) {
                trip.push((c, r, v));
            }
        }
        Csr::from_triplets(self.cols, self.rows, trip)
    }

    /// Check structural invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.rowptr.len() != self.rows + 1 {
            return Err("rowptr length".into());
        }
        if *self.rowptr.last().unwrap() != self.nnz() {
            return Err("rowptr tail != nnz".into());
        }
        if self.colidx.len() != self.values.len() {
            return Err("colidx/values length".into());
        }
        for r in 0..self.rows {
            if self.rowptr[r] > self.rowptr[r + 1] {
                return Err(format!("rowptr not monotonic at {r}"));
            }
            let mut prev = None;
            for k in self.rowptr[r]..self.rowptr[r + 1] {
                if self.colidx[k] >= self.cols {
                    return Err(format!("colidx out of range at row {r}"));
                }
                if let Some(p) = prev {
                    if self.colidx[k] <= p {
                        return Err(format!("colidx not strictly increasing in row {r}"));
                    }
                }
                prev = Some(self.colidx[k]);
            }
        }
        Ok(())
    }

    // --- reference kernels (wrapping INT16, matching the fabric) ---------

    /// SpMV: `y = A * x` (Fig 4's kernel).
    pub fn spmv(&self, x: &[i16]) -> Vec<i16> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0i16; self.rows];
        for r in 0..self.rows {
            let mut acc = 0i16;
            for (c, v) in self.row(r) {
                acc = acc.wrapping_add(v.wrapping_mul(x[c]));
            }
            y[r] = acc;
        }
        y
    }

    /// SpGEMM via Gustavson's row-wise algorithm (§4.2: "We implement this
    /// using Gustavson's algorithm"): `C[i,:] = sum_k A[i,k] * B[k,:]`.
    pub fn spgemm(&self, b: &Csr) -> Csr {
        assert_eq!(self.cols, b.rows);
        let mut acc = vec![0i16; b.cols];
        let mut touched: Vec<usize> = Vec::new();
        let mut trip = Vec::new();
        for i in 0..self.rows {
            for (k, av) in self.row(i) {
                for (j, bv) in b.row(k) {
                    if acc[j] == 0 && !touched.contains(&j) {
                        touched.push(j);
                    }
                    acc[j] = acc[j].wrapping_add(av.wrapping_mul(bv));
                }
            }
            for &j in &touched {
                if acc[j] != 0 {
                    trip.push((i, j, acc[j]));
                }
                acc[j] = 0;
            }
            touched.clear();
        }
        Csr::from_triplets(self.rows, b.cols, trip)
    }

    /// Element-wise sparse addition (SpM+SpM, §4.2).
    pub fn spadd(&self, b: &Csr) -> Csr {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let mut trip = Vec::with_capacity(self.nnz() + b.nnz());
        for r in 0..self.rows {
            for (c, v) in self.row(r) {
                trip.push((r, c, v));
            }
            for (c, v) in b.row(r) {
                trip.push((r, c, v));
            }
        }
        Csr::from_triplets(self.rows, self.cols, trip)
    }

    /// SDDMM: `C[i,j] = mask[i,j] != 0 ? (A[i,:] . B[:,j]) * mask[i,j] : 0`
    /// where `self` is the sparse mask and A, B are dense (§4.2: "computes
    /// products only at sparse locations").
    pub fn sddmm(&self, a: &Dense, b: &Dense) -> Csr {
        assert_eq!(self.rows, a.rows);
        assert_eq!(self.cols, b.cols);
        assert_eq!(a.cols, b.rows);
        let mut trip = Vec::with_capacity(self.nnz());
        for r in 0..self.rows {
            for (c, m) in self.row(r) {
                let mut dot = 0i16;
                for k in 0..a.cols {
                    dot = dot.wrapping_add(a.get(r, k).wrapping_mul(b.get(k, c)));
                }
                let v = dot.wrapping_mul(m);
                if v != 0 {
                    trip.push((r, c, v));
                }
            }
        }
        Csr::from_triplets(self.rows, self.cols, trip)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn from_triplets_sums_duplicates_drops_zeros() {
        let m = Csr::from_triplets(2, 2, vec![(0, 0, 3), (0, 0, 4), (1, 1, 5), (1, 0, 0)]);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.to_dense().get(0, 0), 7);
        assert_eq!(m.to_dense().get(1, 1), 5);
        m.validate().unwrap();
    }

    #[test]
    fn try_from_triplets_rejects_out_of_bounds() {
        let e = Csr::try_from_triplets(2, 3, vec![(2, 0, 1)], DupPolicy::Sum).unwrap_err();
        assert_eq!(
            e,
            CsrError::OutOfBounds {
                row: 2,
                col: 0,
                rows: 2,
                cols: 3
            }
        );
        let e = Csr::try_from_triplets(2, 3, vec![(1, 3, 1)], DupPolicy::Sum).unwrap_err();
        assert!(matches!(e, CsrError::OutOfBounds { col: 3, .. }), "{e}");
    }

    #[test]
    fn try_from_triplets_duplicate_policy() {
        let trips = vec![(0, 1, 2), (0, 1, 3)];
        let merged = Csr::try_from_triplets(1, 2, trips.clone(), DupPolicy::Sum).unwrap();
        assert_eq!(merged.to_dense().get(0, 1), 5);
        let e = Csr::try_from_triplets(1, 2, trips, DupPolicy::Reject).unwrap_err();
        assert_eq!(e, CsrError::Duplicate { row: 0, col: 1 });
        // Duplicate detection fires even when the pair would sum to zero.
        let e = Csr::try_from_triplets(1, 2, vec![(0, 0, 4), (0, 0, -4)], DupPolicy::Reject)
            .unwrap_err();
        assert_eq!(e, CsrError::Duplicate { row: 0, col: 0 });
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn from_triplets_still_panics_out_of_bounds() {
        let _ = Csr::from_triplets(2, 2, vec![(5, 0, 1)]);
    }

    #[test]
    fn dense_roundtrip_property() {
        forall(100, |rng| {
            let r = 1 + rng.below_usize(12);
            let c = 1 + rng.below_usize(12);
            let m = gen::random_csr(rng, r, c, 0.4);
            m.validate().map_err(|e| e.to_string())?;
            let back = Csr::from_dense(&m.to_dense());
            ensure(back == m, || "dense roundtrip mismatch".into())
        });
    }

    #[test]
    fn spmv_matches_dense() {
        forall(100, |rng| {
            let r = 1 + rng.below_usize(16);
            let c = 1 + rng.below_usize(16);
            let m = gen::random_csr(rng, r, c, 0.3);
            let x: Vec<i16> = (0..c).map(|_| rng.range_i64(-4, 4) as i16).collect();
            let y = m.spmv(&x);
            let yd = m.to_dense().matvec(&x);
            ensure(y == yd, || "spmv != dense matvec".into())
        });
    }

    #[test]
    fn spgemm_matches_dense() {
        forall(60, |rng| {
            let m = 1 + rng.below_usize(10);
            let k = 1 + rng.below_usize(10);
            let n = 1 + rng.below_usize(10);
            let a = gen::random_csr(rng, m, k, 0.4);
            let b = gen::random_csr(rng, k, n, 0.4);
            let c = a.spgemm(&b);
            c.validate().map_err(|e| e.to_string())?;
            let cd = a.to_dense().matmul(&b.to_dense());
            ensure(c.to_dense() == cd, || "spgemm != dense matmul".into())
        });
    }

    #[test]
    fn spadd_matches_dense() {
        forall(60, |rng| {
            let r = 1 + rng.below_usize(12);
            let c = 1 + rng.below_usize(12);
            let a = gen::random_csr(rng, r, c, 0.3);
            let b = gen::random_csr(rng, r, c, 0.3);
            let s = a.spadd(&b);
            s.validate().map_err(|e| e.to_string())?;
            let sd = a.to_dense().add(&b.to_dense());
            ensure(s.to_dense() == sd, || "spadd != dense add".into())
        });
    }

    #[test]
    fn sddmm_matches_dense_definition() {
        forall(40, |rng| {
            let m = 1 + rng.below_usize(8);
            let k = 1 + rng.below_usize(8);
            let n = 1 + rng.below_usize(8);
            let mask = gen::random_csr(rng, m, n, 0.3);
            let a = gen::random_dense(rng, m, k, 4);
            let b = gen::random_dense(rng, k, n, 4);
            let c = mask.sddmm(&a, &b);
            let full = a.matmul(&b);
            for r in 0..m {
                for (j, mv) in mask.row(r) {
                    let want = full.get(r, j).wrapping_mul(mv);
                    if want != c.to_dense().get(r, j) {
                        return Err(format!("sddmm mismatch at ({r},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn transpose_involution() {
        forall(60, |rng| {
            let r = 1 + rng.below_usize(10);
            let c = 1 + rng.below_usize(10);
            let m = gen::random_csr(rng, r, c, 0.4);
            ensure(m.transpose().transpose() == m, || "transpose^2 != id".into())
        });
    }
}
