//! Small shared utilities: deterministic PRNG, a mini property-testing
//! harness (the offline build environment has no `proptest`), and numeric
//! helpers used across the simulator.

pub mod bench;
pub mod json;
pub mod prng;
pub mod prop;

pub use prng::SplitMix64;

/// Incremental FNV-1a 64-bit hasher — the crate's convention for cheap
/// content fingerprints (compile-cache keys, scenario-stream
/// decorrelation, serve-protocol digests).
#[derive(Debug, Clone, Copy)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    /// Fold one 64-bit word into the hash.
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.0 = (self.0 ^ v).wrapping_mul(0x0000_0100_0000_01b3);
        self
    }

    /// Fold a byte string in, one byte per round.
    pub fn bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.u64(b as u64);
        }
        self
    }

    /// Fold a length-prefixed i16 slice in (sign-preserving).
    pub fn i16s(&mut self, values: &[i16]) -> &mut Self {
        self.u64(values.len() as u64);
        for &v in values {
            self.u64(v as u16 as u64);
        }
        self
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// FNV-1a of a string — the one-shot form of [`Fnv64`].
pub fn fnv1a_str(s: &str) -> u64 {
    Fnv64::new().bytes(s.as_bytes()).finish()
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Mean of a slice of f64 (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean of strictly-positive values (0.0 for empty input).
/// Used for "average speedup" style summaries, matching common practice in
/// architecture evaluations.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (stddev / mean); 0 when mean is 0.
/// Used as the load-imbalance metric across PEs (Fig 3 / Fig 13 analysis).
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn geomean_of_constant_is_constant() {
        let v = vec![2.0; 8];
        assert!((geomean(&v) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_and_stddev() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&v) - 2.5).abs() < 1e-12);
        assert!((stddev(&v) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cv_zero_for_uniform() {
        assert_eq!(cv(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn fnv_is_deterministic_and_order_sensitive() {
        assert_eq!(fnv1a_str("abc"), fnv1a_str("abc"));
        assert_ne!(fnv1a_str("abc"), fnv1a_str("acb"));
        let mut a = Fnv64::new();
        a.u64(1).u64(2);
        let mut b = Fnv64::new();
        b.u64(2).u64(1);
        assert_ne!(a.finish(), b.finish());
        // i16s is length-prefixed: [] vs [0] must differ.
        let mut c = Fnv64::new();
        c.i16s(&[]);
        let mut d = Fnv64::new();
        d.i16s(&[0]);
        assert_ne!(c.finish(), d.finish());
    }
}
