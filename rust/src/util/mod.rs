//! Small shared utilities: deterministic PRNG, a mini property-testing
//! harness (the offline build environment has no `proptest`), and numeric
//! helpers used across the simulator.

pub mod bench;
pub mod prng;
pub mod prop;

pub use prng::SplitMix64;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Mean of a slice of f64 (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean of strictly-positive values (0.0 for empty input).
/// Used for "average speedup" style summaries, matching common practice in
/// architecture evaluations.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (stddev / mean); 0 when mean is 0.
/// Used as the load-imbalance metric across PEs (Fig 3 / Fig 13 analysis).
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn geomean_of_constant_is_constant() {
        let v = vec![2.0; 8];
        assert!((geomean(&v) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_and_stddev() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&v) - 2.5).abs() < 1e-12);
        assert!((stddev(&v) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cv_zero_for_uniform() {
        assert_eq!(cv(&[5.0, 5.0, 5.0]), 0.0);
    }
}
