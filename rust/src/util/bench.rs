//! Minimal benchmark support for the `cargo bench` targets.
//!
//! The offline build environment vendors no `criterion`, so the bench
//! binaries (`rust/benches/*.rs`, `harness = false`) use this helper: it
//! runs a closure a warmup + N measured iterations and prints
//! median/mean/min wall-times in criterion-like format.

use std::time::Instant;

/// Measure `f` over `iters` runs (after one warmup) and print a summary
/// line. Returns the median seconds per run.
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean: f64 = times.iter().sum::<f64>() / times.len() as f64;
    println!(
        "bench {name:<28} median {:>10.3} ms  mean {:>10.3} ms  min {:>10.3} ms  ({iters} runs)",
        median * 1e3,
        mean * 1e3,
        times[0] * 1e3
    );
    median
}

/// Format a throughput line (items per second).
pub fn throughput(name: &str, items: u64, secs: f64) {
    println!(
        "bench {name:<28} throughput {:>12.0} items/s ({items} items in {:.3} ms)",
        items as f64 / secs,
        secs * 1e3
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_median() {
        let m = bench("noop", 3, || {
            std::hint::black_box(1 + 1);
        });
        assert!(m >= 0.0);
    }
}
