//! Minimal hand-rolled JSON-line *emission* shared by everything that
//! prints machine-readable artifacts: the corpus runner
//! (`BENCH_CORPUS.json` lines), the bench binaries (`BENCH_*.json`
//! lines), and the `nexus serve` protocol (one response object per
//! request line).
//!
//! The offline build environment vendors no `serde`, and before this
//! module each emitter hand-rolled its own `format!` escaping — with
//! subtly different coverage (the runner escaped control bytes, the
//! benches escaped nothing). [`JsonObj`] centralizes the one part that is
//! easy to get wrong: string escaping (quotes, backslashes, control
//! characters) and field separation. It deliberately stays a *writer*,
//! not a data model — values go in typed, already computed, and come out
//! as one `{...}` line.

use std::fmt::Write as _;

/// Escape a string for embedding inside a JSON string literal (the
/// quotes are NOT added). Handles `"` `\`, named control escapes, and
/// `\u00XX` for the remaining control bytes.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    escape_into(&mut out, s);
    out
}

fn escape_into(out: &mut String, s: &str) {
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// Builder for one JSON object rendered as a single line. Field order is
/// insertion order; keys are escaped like values.
///
/// ```
/// use nexus::util::json::JsonObj;
/// let mut o = JsonObj::new();
/// o.str("scenario", "smoke/spmv-uniform-d30-4x4")
///     .u64("cycles", 1234)
///     .f64("utilization", 0.51239, 4)
///     .bool("validated", true);
/// assert_eq!(
///     o.build(),
///     "{\"scenario\":\"smoke/spmv-uniform-d30-4x4\",\"cycles\":1234,\
///      \"utilization\":0.5124,\"validated\":true}"
/// );
/// ```
#[derive(Debug, Default)]
pub struct JsonObj {
    buf: String,
}

impl JsonObj {
    pub fn new() -> Self {
        JsonObj { buf: String::new() }
    }

    fn key(&mut self, k: &str) -> &mut Self {
        if !self.buf.is_empty() {
            self.buf.push(',');
        }
        self.buf.push('"');
        escape_into(&mut self.buf, k);
        self.buf.push_str("\":");
        self
    }

    /// A string field (value escaped and quoted).
    pub fn str(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k);
        self.buf.push('"');
        escape_into(&mut self.buf, v);
        self.buf.push('"');
        self
    }

    /// An unsigned integer field.
    pub fn u64(&mut self, k: &str, v: u64) -> &mut Self {
        self.key(k);
        let _ = write!(self.buf, "{v}");
        self
    }

    /// A float field rendered with a fixed number of decimals (`null` for
    /// non-finite values, which raw JSON cannot carry).
    pub fn f64(&mut self, k: &str, v: f64, decimals: usize) -> &mut Self {
        self.key(k);
        if v.is_finite() {
            let _ = write!(self.buf, "{v:.decimals$}");
        } else {
            self.buf.push_str("null");
        }
        self
    }

    /// A boolean field.
    pub fn bool(&mut self, k: &str, v: bool) -> &mut Self {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// A `u64` rendered as the `"0x0123456789abcdef"` hex-string form the
    /// corpus artifacts use for fingerprints and digests (quoted: JSON
    /// numbers cannot carry 64-bit values exactly).
    pub fn hex(&mut self, k: &str, v: u64) -> &mut Self {
        self.key(k);
        let _ = write!(self.buf, "\"{v:#018x}\"");
        self
    }

    /// A field whose value is already-rendered JSON (nested arrays or
    /// objects). The caller guarantees `raw` is valid JSON.
    pub fn raw(&mut self, k: &str, raw: &str) -> &mut Self {
        self.key(k);
        self.buf.push_str(raw);
        self
    }

    /// Render the accumulated fields as one `{...}` line and reset the
    /// builder to empty.
    pub fn build(&mut self) -> String {
        let mut s = String::with_capacity(self.buf.len() + 2);
        s.push('{');
        s.push_str(&std::mem::take(&mut self.buf));
        s.push('}');
        s
    }
}

/// Render an iterator of already-rendered JSON values as a `[...]` array
/// (the companion of [`JsonObj::raw`] for nested lists).
pub fn array<I: IntoIterator<Item = String>>(items: I) -> String {
    let mut s = String::from("[");
    for (i, item) in items.into_iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&item);
    }
    s.push(']');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_covers_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("tab\there"), "tab\\there");
        assert_eq!(escape("cr\rlf"), "cr\\rlf");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
        // Non-ASCII passes through (JSON strings are UTF-8).
        assert_eq!(escape("héllo"), "héllo");
    }

    #[test]
    fn object_builds_in_insertion_order() {
        let mut o = JsonObj::new();
        o.str("name", "x\"y").u64("n", 7).bool("ok", true);
        assert_eq!(o.build(), "{\"name\":\"x\\\"y\",\"n\":7,\"ok\":true}");
        // The builder resets after build.
        o.u64("second", 1);
        assert_eq!(o.build(), "{\"second\":1}");
    }

    #[test]
    fn f64_precision_and_nonfinite() {
        let mut o = JsonObj::new();
        o.f64("a", 0.123456, 4).f64("b", f64::NAN, 2).f64("c", f64::INFINITY, 2);
        assert_eq!(o.build(), "{\"a\":0.1235,\"b\":null,\"c\":null}");
    }

    #[test]
    fn hex_and_raw_and_array() {
        let mut o = JsonObj::new();
        o.hex("fp", 0x1234).raw("links", &array(vec!["[1,2,3]".into(), "[4,5,6]".into()]));
        assert_eq!(
            o.build(),
            "{\"fp\":\"0x0000000000001234\",\"links\":[[1,2,3],[4,5,6]]}"
        );
        assert_eq!(array(Vec::<String>::new()), "[]");
    }

    #[test]
    fn empty_object() {
        assert_eq!(JsonObj::new().build(), "{}");
    }
}
