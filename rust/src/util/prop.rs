//! Mini property-based testing harness.
//!
//! The offline build environment ships no `proptest`/`quickcheck`, so this
//! module provides the 10% of those crates the test-suite needs: run a
//! property over many seeded random cases, and on failure report the seed
//! and a greedily-shrunk counterexample description.
//!
//! Usage:
//! ```ignore
//! use nexus::util::prop::forall;
//! forall(200, |rng| {
//!     let n = 1 + rng.below_usize(64);
//!     /* build case from rng */
//!     check(n) // -> Result<(), String>
//! });
//! ```

use super::prng::SplitMix64;

/// Run `cases` random trials of `property`. Each trial gets a PRNG derived
/// from a fixed master seed, so failures are reproducible: the panic message
/// names the failing case index and seed.
///
/// The property returns `Ok(())` on success, or `Err(description)` to fail.
pub fn forall<F>(cases: usize, mut property: F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    forall_seeded(0xA11CE, cases, &mut property)
}

/// As [`forall`] but with an explicit master seed (used by tests that want
/// several independent sweeps of the same property).
pub fn forall_seeded<F>(master_seed: u64, cases: usize, property: &mut F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = master_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = SplitMix64::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} (seed {seed:#x}): {msg}\n\
                 reproduce with SplitMix64::new({seed:#x})"
            );
        }
    }
}

/// Randomized case count from the `NEXUS_PROP_CASES` env var, falling back
/// to `default`. The shared knob of every property suite: CI raises it for
/// deeper release-mode sweeps (`NEXUS_PROP_CASES=500 cargo test --release`).
pub fn env_cases(default: usize) -> usize {
    std::env::var("NEXUS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Helper: assert two u16 slices are equal, reporting first mismatch index.
pub fn check_eq_u16(actual: &[u16], expected: &[u16], what: &str) -> Result<(), String> {
    if actual.len() != expected.len() {
        return Err(format!(
            "{what}: length mismatch {} vs {}",
            actual.len(),
            expected.len()
        ));
    }
    for (i, (a, e)) in actual.iter().zip(expected).enumerate() {
        if a != e {
            return Err(format!("{what}: mismatch at [{i}]: got {a}, want {e}"));
        }
    }
    Ok(())
}

/// Helper: assert `cond` with a lazily-formatted message.
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(64, |rng| {
            let x = rng.below(100);
            ensure(x < 100, || format!("x={x}"))
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(64, |rng| {
            let x = rng.below(100);
            ensure(x < 50, || format!("x={x} >= 50"))
        });
    }

    #[test]
    fn check_eq_u16_reports_index() {
        let e = check_eq_u16(&[1, 2, 3], &[1, 9, 3], "t").unwrap_err();
        assert!(e.contains("[1]"), "{e}");
    }
}
