//! SplitMix64: a tiny, fast, deterministic PRNG.
//!
//! Every stochastic element of the repository (sparsity generators, synthetic
//! graphs, Valiant intermediate-destination selection, property tests) is
//! seeded explicitly so each experiment is exactly reproducible.

/// SplitMix64 PRNG state. Passes BigCrush as a 64-bit generator and is more
/// than adequate for workload generation and routing randomization.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Expose the raw state (used by the fabric's `state_digest`, which
    /// must fold the *position* of every shard's PRNG stream into the
    /// digest without advancing it).
    #[inline]
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // bias is < 2^-32 for the small ranges we use.
        ((self.next_u64() >> 32).wrapping_mul(n)) >> 32
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform i64 in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Partial Fisher–Yates over an index vector: O(n) setup, fine at our
        // workload-generation scales.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Derive the seed of an independent PRNG stream `lane` from a base
/// `seed` (per-shard Valiant randomization in the sharded fabric).
///
/// Lane 0 returns the base seed unchanged so a one-shard fabric is
/// bit-identical to the historical unsharded simulator. Other lanes pass
/// `seed ^ lane·golden` through the SplitMix64 finalizer: a plain
/// `seed + lane` would hand SplitMix64 — whose state is a simple counter —
/// a family of *shifted* copies of the same stream, which is exactly the
/// correlation the scramble destroys.
pub fn stream_seed(seed: u64, lane: u64) -> u64 {
    if lane == 0 {
        return seed;
    }
    let mut z = seed ^ lane.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = SplitMix64::new(3);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }

    #[test]
    fn stream_seed_lane0_is_identity_and_lanes_decorrelate() {
        assert_eq!(stream_seed(42, 0), 42);
        // Distinct lanes must yield streams that are neither equal nor
        // shifted copies of one another (compare a window of draws).
        let window = |lane: u64| {
            let mut r = SplitMix64::new(stream_seed(42, lane));
            (0..32).map(|_| r.next_u64()).collect::<Vec<u64>>()
        };
        let (a, b, c) = (window(0), window(1), window(2));
        assert_ne!(a, b);
        assert_ne!(b, c);
        for shift in 1..8 {
            assert_ne!(a[shift..], b[..32 - shift], "lane 1 is a shifted lane 0");
        }
        // Determinism: same (seed, lane) -> same stream.
        assert_eq!(window(3), window(3));
    }

    #[test]
    fn chance_rough_frequency() {
        let mut r = SplitMix64::new(11);
        let hits = (0..20_000).filter(|_| r.chance(0.25)).count();
        let freq = hits as f64 / 20_000.0;
        assert!((freq - 0.25).abs() < 0.02, "freq={freq}");
    }
}
