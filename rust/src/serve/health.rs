//! `/health` and `/metrics` response rendering. Pure functions from the
//! observable state to one JSON line, so tests can assert the exact
//! shape without a socket.

use super::metrics::Metrics;
use crate::util::json::JsonObj;
use std::sync::atomic::Ordering;

/// The `GET /health` line: liveness plus the two numbers an operator
/// checks first.
pub fn health_line(m: &Metrics, queue_depth: usize, workers: usize, draining: bool) -> String {
    let mut o = JsonObj::new();
    o.str("status", if draining { "draining" } else { "ok" })
        .f64("uptime_secs", m.uptime_secs(), 3)
        .u64("workers", workers as u64)
        .u64("queue_depth", queue_depth as u64)
        .u64("completed", m.completed.load(Ordering::Relaxed));
    o.build()
}

/// The `GET /metrics` line: full lifecycle counters, throughput, latency
/// percentiles, queue occupancy, and shared-compile-cache hit rate.
#[allow(clippy::too_many_arguments)]
pub fn metrics_line(
    m: &Metrics,
    queue_depth: usize,
    queue_capacity: usize,
    workers: usize,
    cache: (u64, u64, usize, usize),
    draining: bool,
) -> String {
    let (hits, misses, entries, capacity) = cache;
    let lookups = hits + misses;
    let hit_rate = if lookups == 0 {
        0.0
    } else {
        hits as f64 / lookups as f64
    };
    let mut o = JsonObj::new();
    o.str("status", if draining { "draining" } else { "ok" })
        .f64("uptime_secs", m.uptime_secs(), 3)
        .u64("workers", workers as u64)
        .u64("received", m.received.load(Ordering::Relaxed))
        .u64("completed", m.completed.load(Ordering::Relaxed))
        .u64("errored", m.errored.load(Ordering::Relaxed))
        .u64("rejected", m.rejected.load(Ordering::Relaxed))
        .u64("malformed", m.malformed.load(Ordering::Relaxed))
        .f64("scenarios_per_sec", m.scenarios_per_sec(), 2)
        .u64("latency_p50_us", m.latency_percentile_us(50.0))
        .u64("latency_p99_us", m.latency_percentile_us(99.0))
        .u64("queue_depth", queue_depth as u64)
        .u64("queue_capacity", queue_capacity as u64)
        .u64("cache_hits", hits)
        .u64("cache_misses", misses)
        .f64("cache_hit_rate", hit_rate, 4)
        .u64("cache_entries", entries as u64)
        .u64("cache_capacity", capacity as u64);
    // Trace-derived gauges: simulated PE-cycle totals and the stall
    // attribution accumulated over every completed run.
    let pe_cycles = m.pe_cycles.load(Ordering::Relaxed);
    let frac = |n: u64| {
        if pe_cycles == 0 {
            0.0
        } else {
            n as f64 / pe_cycles as f64
        }
    };
    o.u64("sim_pe_cycles", pe_cycles)
        .f64("active_pe_frac", m.active_pe_fraction(), 4)
        .f64(
            "stall_operand_frac",
            frac(m.stall_operand.load(Ordering::Relaxed)),
            4,
        )
        .f64(
            "stall_backpressure_frac",
            frac(m.stall_backpressure.load(Ordering::Relaxed)),
            4,
        )
        .f64("stall_axi_frac", frac(m.stall_axi.load(Ordering::Relaxed)), 4)
        .f64(
            "stall_claim_frac",
            frac(m.stall_claim.load(Ordering::Relaxed)),
            4,
        );
    o.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::{parse_json, Json};

    #[test]
    fn health_line_shape() {
        let m = Metrics::new();
        m.completed.fetch_add(5, Ordering::Relaxed);
        let line = health_line(&m, 2, 4, false);
        let v = parse_json(&line).unwrap();
        assert_eq!(v.get("status").and_then(Json::as_str), Some("ok"));
        assert_eq!(v.get("workers").and_then(Json::as_u64), Some(4));
        assert_eq!(v.get("queue_depth").and_then(Json::as_u64), Some(2));
        assert_eq!(v.get("completed").and_then(Json::as_u64), Some(5));
        let drained = health_line(&m, 0, 4, true);
        let v = parse_json(&drained).unwrap();
        assert_eq!(v.get("status").and_then(Json::as_str), Some("draining"));
    }

    #[test]
    fn metrics_line_reports_cache_hit_rate() {
        let m = Metrics::new();
        m.received.fetch_add(10, Ordering::Relaxed);
        m.completed.fetch_add(8, Ordering::Relaxed);
        m.rejected.fetch_add(2, Ordering::Relaxed);
        m.record_latency_us(100);
        let line = metrics_line(&m, 1, 64, 4, (6, 2, 2, 256), false);
        let v = parse_json(&line).unwrap();
        assert_eq!(v.get("received").and_then(Json::as_u64), Some(10));
        assert_eq!(v.get("completed").and_then(Json::as_u64), Some(8));
        assert_eq!(v.get("rejected").and_then(Json::as_u64), Some(2));
        assert_eq!(v.get("cache_hits").and_then(Json::as_u64), Some(6));
        assert_eq!(v.get("cache_hit_rate").and_then(Json::as_f64), Some(0.75));
        assert_eq!(v.get("queue_capacity").and_then(Json::as_u64), Some(64));
        assert!(v.get("latency_p99_us").and_then(Json::as_u64).unwrap() >= 100);
    }

    #[test]
    fn metrics_line_carries_stall_gauges() {
        let m = Metrics::new();
        // No runs yet: every gauge is a well-formed zero.
        let v = parse_json(&metrics_line(&m, 0, 8, 1, (0, 0, 0, 8), false)).unwrap();
        assert_eq!(v.get("sim_pe_cycles").and_then(Json::as_u64), Some(0));
        assert_eq!(v.get("active_pe_frac").and_then(Json::as_f64), Some(0.0));
        // One completed run: 100 cycles x 4 PEs, half the PE-cycles
        // active, 40 operand-stalled, 10 AXI-stalled.
        let stats = crate::fabric::stats::FabricStats {
            cycles: 100,
            per_pe_busy_cycles: vec![0; 4],
            active_pe_cycles: 200,
            stall_operand_cycles: 40,
            stall_axi_cycles: 10,
            ..crate::fabric::stats::FabricStats::default()
        };
        m.record_run_stats(&stats);
        let v = parse_json(&metrics_line(&m, 0, 8, 1, (0, 0, 0, 8), false)).unwrap();
        assert_eq!(v.get("sim_pe_cycles").and_then(Json::as_u64), Some(400));
        assert_eq!(v.get("active_pe_frac").and_then(Json::as_f64), Some(0.5));
        assert_eq!(v.get("stall_operand_frac").and_then(Json::as_f64), Some(0.1));
        assert_eq!(v.get("stall_axi_frac").and_then(Json::as_f64), Some(0.025));
        assert_eq!(v.get("stall_claim_frac").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn zero_lookup_cache_hit_rate_is_zero() {
        let m = Metrics::new();
        let line = metrics_line(&m, 0, 8, 1, (0, 0, 0, 8), false);
        let v = parse_json(&line).unwrap();
        assert_eq!(v.get("cache_hit_rate").and_then(Json::as_f64), Some(0.0));
    }
}
