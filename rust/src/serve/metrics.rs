//! Live service counters behind `/metrics`: lock-free atomics for the
//! request lifecycle plus a fixed-bucket latency histogram for p50/p99.
//!
//! The histogram is 32 power-of-two microsecond buckets (bucket *i*
//! covers `[2^i, 2^(i+1))` µs, bucket 0 covers `[0, 2)` µs). Percentiles
//! come out as the upper bound of the bucket holding the requested rank —
//! coarse (within 2×) but constant-space, lock-free, and monotone, which
//! is what a hot-path service counter wants.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

const BUCKETS: usize = 32;

/// Shared service counters. All methods take `&self`; every field is an
/// atomic, so the hot path never contends on a lock.
pub struct Metrics {
    started: Instant,
    /// Request lines that parsed into a run request and were considered
    /// for admission.
    pub received: AtomicU64,
    /// Runs executed to completion (ok responses).
    pub completed: AtomicU64,
    /// Runs that failed in execution (`exec_failed` responses).
    pub errored: AtomicU64,
    /// Runs refused by backpressure (`overloaded` + `shutting_down`).
    pub rejected: AtomicU64,
    /// Lines that failed to parse at all (`malformed`, `oversized`,
    /// `bad_request`, `unknown_scenario`).
    pub malformed: AtomicU64,
    /// Simulated PE-cycles (cycles × PEs) accumulated over completed
    /// runs — the denominator of the live stall-attribution gauges.
    pub pe_cycles: AtomicU64,
    /// PE-cycles that committed ALU/decode work over completed runs.
    pub active_pe_cycles: AtomicU64,
    /// Stall-attributed PE-cycles: operand wait.
    pub stall_operand: AtomicU64,
    /// Stall-attributed PE-cycles: injection/buffer backpressure.
    pub stall_backpressure: AtomicU64,
    /// Stall-attributed cycles: AXI refill head-of-line wait.
    pub stall_axi: AtomicU64,
    /// Stall-attributed events: en-route claim misses.
    pub stall_claim: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            started: Instant::now(),
            received: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errored: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            malformed: AtomicU64::new(0),
            pe_cycles: AtomicU64::new(0),
            active_pe_cycles: AtomicU64::new(0),
            stall_operand: AtomicU64::new(0),
            stall_backpressure: AtomicU64::new(0),
            stall_axi: AtomicU64::new(0),
            stall_claim: AtomicU64::new(0),
            latency_us: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Fold one completed run's fabric counters into the live
    /// stall-attribution gauges (`/metrics` derives fractions from the
    /// accumulated totals, so they converge to the fleet-wide averages).
    pub fn record_run_stats(&self, s: &crate::fabric::stats::FabricStats) {
        self.pe_cycles
            .fetch_add(s.total_pe_cycles(), Ordering::Relaxed);
        self.active_pe_cycles
            .fetch_add(s.active_pe_cycles, Ordering::Relaxed);
        self.stall_operand
            .fetch_add(s.stall_operand_cycles, Ordering::Relaxed);
        self.stall_backpressure.fetch_add(
            s.stall_inject_cycles + s.stall_backpressure_cycles,
            Ordering::Relaxed,
        );
        self.stall_axi.fetch_add(s.stall_axi_cycles, Ordering::Relaxed);
        self.stall_claim
            .fetch_add(s.stall_claim_misses, Ordering::Relaxed);
    }

    /// Live active-PE fraction across all completed runs (0 with none).
    pub fn active_pe_fraction(&self) -> f64 {
        let total = self.pe_cycles.load(Ordering::Relaxed);
        if total == 0 {
            0.0
        } else {
            self.active_pe_cycles.load(Ordering::Relaxed) as f64 / total as f64
        }
    }

    /// Seconds since the service started.
    pub fn uptime_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Record one completed request's end-to-end latency (queue + exec).
    pub fn record_latency_us(&self, us: u64) {
        let bucket = if us < 2 {
            0
        } else {
            (63 - us.leading_zeros() as usize).min(BUCKETS - 1)
        };
        self.latency_us[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Latency percentile estimate in microseconds: the upper bound of
    /// the bucket containing rank `ceil(p/100 * n)`. Returns 0 with no
    /// samples.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let counts: Vec<u64> = self
            .latency_us
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    /// Completed-run throughput since start.
    pub fn scenarios_per_sec(&self) -> f64 {
        let up = self.uptime_secs();
        if up <= 0.0 {
            return 0.0;
        }
        self.completed.load(Ordering::Relaxed) as f64 / up
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_track_bucket_bounds() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(50.0), 0, "no samples yet");
        // 99 fast samples (~8µs → bucket 3, bound 16) and one slow
        // (~1000µs → bucket 9, bound 1024).
        for _ in 0..99 {
            m.record_latency_us(8);
        }
        m.record_latency_us(1000);
        assert_eq!(m.latency_percentile_us(50.0), 16);
        assert_eq!(m.latency_percentile_us(99.0), 16);
        assert_eq!(m.latency_percentile_us(100.0), 1024);
    }

    #[test]
    fn sub_two_micros_lands_in_bucket_zero() {
        let m = Metrics::new();
        m.record_latency_us(0);
        m.record_latency_us(1);
        assert_eq!(m.latency_percentile_us(100.0), 2);
    }

    #[test]
    fn huge_latencies_saturate_the_last_bucket() {
        let m = Metrics::new();
        m.record_latency_us(u64::MAX);
        // Saturates at the top bucket rather than indexing out of range.
        assert_eq!(m.latency_percentile_us(100.0), 1u64 << 32);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.received.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        m.rejected.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.received.load(Ordering::Relaxed), 3);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 1);
        assert!(m.uptime_secs() >= 0.0);
    }
}
