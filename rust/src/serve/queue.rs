//! A bounded multi-producer multi-consumer queue with *explicit*
//! backpressure: [`BoundedQueue::try_push`] fails immediately when the
//! queue is at capacity so the connection handler can answer
//! `{"error":"overloaded"}` right away — the service never blocks a
//! client on admission and never drops accepted work silently.
//!
//! std's `mpsc::sync_channel` is close but single-consumer; the serve
//! worker pool needs many consumers pulling from one queue, so this is
//! the classic mutex + condvar ring instead. Consumers block in
//! [`BoundedQueue::pop`] until work arrives or the queue is closed.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Why a [`BoundedQueue::try_push`] was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// At capacity: the caller should reject with backpressure.
    Full,
    /// Closed for shutdown: no new work is admitted.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// The bounded MPMC queue. Shared across threads behind an `Arc`.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    ready: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// A queue admitting at most `capacity` pending items (min 1).
    pub fn new(capacity: usize) -> Self {
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking push. On refusal the item comes back to the caller so
    /// it can be answered (rejection is a *response*, not a drop).
    pub fn try_push(&self, item: T) -> Result<(), (PushError, T)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((PushError::Closed, item));
        }
        if g.items.len() >= self.capacity {
            return Err((PushError::Full, item));
        }
        g.items.push_back(item);
        drop(g);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking pop: waits for an item or for close. Returns `None` only
    /// when the queue is closed AND drained — consumers therefore finish
    /// every admitted item before exiting, which is what makes shutdown
    /// lossless.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Close the queue: refuse new pushes, wake all blocked consumers.
    /// Items already admitted remain poppable.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    /// Current depth (snapshot; for metrics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn rejects_when_full_and_returns_item() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        match q.try_push(3) {
            Err((PushError::Full, item)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok(), "space frees after pop");
    }

    #[test]
    fn close_drains_then_ends() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        assert!(matches!(q.try_push(3), Err((PushError::Closed, 3))));
        // Admitted items still come out, in order, then None.
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(BoundedQueue::<u32>::new(1));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.pop());
        // Give the consumer time to block, then close.
        thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn mpmc_delivers_every_item_exactly_once() {
        let q = Arc::new(BoundedQueue::<u64>::new(8));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        let total = 200u64;
        let mut pushed = 0u64;
        while pushed < total {
            if q.try_push(pushed).is_ok() {
                pushed += 1;
            } else {
                thread::yield_now();
            }
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..total).collect::<Vec<_>>());
    }
}
