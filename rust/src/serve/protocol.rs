//! The `nexus serve` wire protocol: newline-delimited JSON, one request
//! per line in, exactly one JSON response line out, in request order.
//!
//! Requests (one object per line):
//!
//! - `{"scenario":"hotspot/spmv-rmat-d20-8x8","seed":7}` — run a named
//!   corpus scenario ([`crate::dataset::Corpus`]); `seed` defaults to 1.
//! - `{"spec":{"kernel":"spmv","source":"rmat","n":64,"density":0.2,
//!   "mesh":[8,8]},"seed":7}` — run an inline spec description
//!   ([`InlineSpec`]): the tensors are generated deterministically from
//!   the description and the seed, exactly as a direct in-process build
//!   would.
//! - `{"cmd":"health"}` / `{"cmd":"metrics"}` / `{"cmd":"shutdown"}` —
//!   service control. For curl-ability the literal lines `GET /health`
//!   and `GET /metrics` are accepted as aliases.
//!
//! Responses are single JSON objects: `{"status":"ok",...}` with the
//! execution summary (digest + cycles + stats), or
//! `{"status":"error","error":"<code>",...}` where `<code>` is one of
//! `malformed`, `unknown_scenario`, `oversized`, `bad_request`,
//! `overloaded`, `shutting_down`, `exec_failed`. Queue-full rejections
//! are *immediate* — `{"status":"error","error":"overloaded"}` — never
//! silent drops.
//!
//! Everything here is hand-rolled std-only: a recursive-descent JSON
//! parser ([`parse_json`]) with a depth bound, and emission through the
//! shared [`crate::util::json`] writer.

use crate::fabric::stats::FabricStats;
use crate::machine::Execution;
use crate::tensor::gen::{self, RMAT_PROBS};
use crate::util::json::JsonObj;
use crate::util::{fnv1a_str, Fnv64, SplitMix64};
use crate::workloads::Spec;
use std::fmt;

/// Maximum nesting depth [`parse_json`] accepts (requests are flat; the
/// bound exists so hostile input cannot overflow the parse stack).
const MAX_JSON_DEPTH: usize = 32;

// ---------------------------------------------------------------------------
// Typed protocol errors
// ---------------------------------------------------------------------------

/// Everything that can go wrong between reading a request line and
/// enqueueing (or executing) it. Each variant renders as a one-line JSON
/// error response with a stable `error` code.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The line was not valid JSON (detail names the position).
    Malformed(String),
    /// A syntactically valid request naming no registered scenario.
    UnknownScenario(String),
    /// The request line exceeded the configured size bound.
    Oversized { len: usize, max: usize },
    /// Valid JSON that is not a valid request (missing/invalid fields).
    BadRequest(String),
    /// The bounded queue was full: explicit backpressure.
    Overloaded,
    /// The service is draining; new work is rejected.
    ShuttingDown,
    /// The run itself failed (deadlock, validation mismatch, ...).
    ExecFailed(String),
}

impl ServeError {
    /// Stable machine-readable error code.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Malformed(_) => "malformed",
            ServeError::UnknownScenario(_) => "unknown_scenario",
            ServeError::Oversized { .. } => "oversized",
            ServeError::BadRequest(_) => "bad_request",
            ServeError::Overloaded => "overloaded",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::ExecFailed(_) => "exec_failed",
        }
    }

    /// Render the one-line JSON error response.
    pub fn to_line(&self) -> String {
        let mut o = JsonObj::new();
        o.str("status", "error").str("error", self.code());
        match self {
            ServeError::Malformed(d)
            | ServeError::UnknownScenario(d)
            | ServeError::BadRequest(d)
            | ServeError::ExecFailed(d) => {
                o.str("detail", d);
            }
            ServeError::Oversized { len, max } => {
                o.u64("len", *len as u64).u64("max", *max as u64);
            }
            ServeError::Overloaded | ServeError::ShuttingDown => {}
        }
        o.build()
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Malformed(d) => write!(f, "malformed request: {d}"),
            ServeError::UnknownScenario(n) => write!(f, "unknown scenario '{n}'"),
            ServeError::Oversized { len, max } => {
                write!(f, "request line of {len} bytes exceeds the {max}-byte bound")
            }
            ServeError::BadRequest(d) => write!(f, "bad request: {d}"),
            ServeError::Overloaded => write!(f, "queue full"),
            ServeError::ShuttingDown => write!(f, "shutting down"),
            ServeError::ExecFailed(d) => write!(f, "execution failed: {d}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Minimal JSON value model + parser
// ---------------------------------------------------------------------------

/// A parsed JSON value. Public so tests and benches can parse response
/// lines with the same parser the service uses for requests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integral number as u64 (rejects fractions and
    /// negatives; JSON numbers above 2^53 are not representable exactly,
    /// which the protocol sidesteps by carrying 64-bit digests as hex
    /// strings).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, String> {
        Err(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_JSON_DEPTH {
            return self.err("nesting too deep");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => self.err("unexpected character"),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        match text.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            _ => self.err("invalid number"),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let ch = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair: require the low half.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("unpaired surrogate");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(hi)
                        };
                        match ch {
                            Some(c) => out.push(c),
                            None => return self.err("invalid \\u escape"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(c) if c < 0x20 => return self.err("raw control byte in string"),
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: re-decode from the source slice.
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return self.err("invalid utf-8"),
                    };
                    let start = self.pos - 1;
                    if start + width > self.bytes.len() {
                        return self.err("truncated utf-8");
                    }
                    match std::str::from_utf8(&self.bytes[start..start + width]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = start + width;
                        }
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.bump() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return self.err("invalid \\u escape"),
            };
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or ']'");
                }
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value(depth + 1)?;
            fields.push((key, value));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or '}'");
                }
            }
        }
    }
}

/// Parse one JSON value from `s` (whole-string: trailing non-whitespace
/// is an error).
pub fn parse_json(s: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: s.as_bytes(),
        pos: 0,
    };
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// A parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Run(RunRequest),
    Health,
    Metrics,
    Shutdown,
}

/// One unit of executable work: what to run and the sweep seed.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRequest {
    pub target: RunTarget,
    pub seed: u64,
}

/// What a run request names: a registered corpus scenario or an inline
/// generated spec.
#[derive(Debug, Clone, PartialEq)]
pub enum RunTarget {
    Scenario(String),
    Inline(InlineSpec),
}

/// An inline spec description: a deterministic generator invocation the
/// client describes instead of naming. Restricted to SpMV over the
/// irregular matrix generators — enough to exercise arbitrary shapes
/// without widening the attack surface of a network-facing parser.
#[derive(Debug, Clone, PartialEq)]
pub struct InlineSpec {
    /// Kernel family; currently only `"spmv"`.
    pub kernel: String,
    /// Tensor source: `uniform`, `rmat`, or `hotspot`.
    pub source: String,
    /// Square matrix dimension (8..=512).
    pub n: usize,
    /// Nominal density in (0, 1].
    pub density: f64,
    /// Mesh `(width, height)`, each in 2..=32.
    pub mesh: (usize, usize),
}

impl InlineSpec {
    /// Canonical display name — also the decorrelation salt for the
    /// tensor stream, mirroring [`crate::dataset::Scenario::spec`].
    pub fn name(&self) -> String {
        format!(
            "inline/{}-{}-n{}-d{:.2}-{}x{}",
            self.kernel, self.source, self.n, self.density, self.mesh.0, self.mesh.1
        )
    }

    /// Build the workload deterministically from the description and the
    /// seed. Equal (description, seed) pairs give bit-identical tensors —
    /// the property the serve bit-identity tests rely on.
    pub fn spec(&self, seed: u64) -> Spec {
        let mut rng = SplitMix64::new(seed ^ fnv1a_str(&self.name()));
        let n = self.n;
        let a = match self.source.as_str() {
            "rmat" => {
                let target = ((n * n) as f64 * self.density).round().max(1.0) as usize;
                gen::rmat_csr(&mut rng, n, n, target, RMAT_PROBS)
            }
            "hotspot" => gen::hotspot_csr(&mut rng, n, n, self.density, 4, 0.85),
            _ => gen::random_csr(&mut rng, n, n, self.density),
        };
        let x = gen::random_vec(&mut rng, n, 3);
        Spec::Spmv { a, x }
    }

    fn from_json(v: &Json) -> Result<InlineSpec, ServeError> {
        let bad = |d: &str| ServeError::BadRequest(d.to_string());
        let kernel = v
            .get("kernel")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("spec.kernel missing"))?
            .to_string();
        if kernel != "spmv" {
            return Err(bad("spec.kernel must be \"spmv\""));
        }
        let source = v
            .get("source")
            .and_then(Json::as_str)
            .unwrap_or("uniform")
            .to_string();
        if !matches!(source.as_str(), "uniform" | "rmat" | "hotspot") {
            return Err(bad("spec.source must be uniform|rmat|hotspot"));
        }
        let n = match v.get("n") {
            None => 64,
            Some(j) => j.as_usize().ok_or_else(|| bad("spec.n must be an integer"))?,
        };
        if !(8..=512).contains(&n) {
            return Err(bad("spec.n must be in 8..=512"));
        }
        let density = match v.get("density") {
            None => 0.2,
            Some(j) => j.as_f64().ok_or_else(|| bad("spec.density must be a number"))?,
        };
        if !(density > 0.0 && density <= 1.0) {
            return Err(bad("spec.density must be in (0, 1]"));
        }
        let mesh = match v.get("mesh") {
            None => (8, 8),
            Some(Json::Arr(a)) if a.len() == 2 => {
                let w = a[0].as_usize().ok_or_else(|| bad("spec.mesh must be [w,h]"))?;
                let h = a[1].as_usize().ok_or_else(|| bad("spec.mesh must be [w,h]"))?;
                (w, h)
            }
            Some(_) => return Err(bad("spec.mesh must be a [w,h] array")),
        };
        if !(2..=32).contains(&mesh.0) || !(2..=32).contains(&mesh.1) {
            return Err(bad("spec.mesh sides must be in 2..=32"));
        }
        Ok(InlineSpec {
            kernel,
            source,
            n,
            density,
            mesh,
        })
    }
}

/// Parse one request line. `GET /health` / `GET /metrics` are accepted
/// verbatim; everything else must be a JSON object.
pub fn parse_request(line: &str) -> Result<Request, ServeError> {
    let t = line.trim();
    if t.starts_with("GET /health") {
        return Ok(Request::Health);
    }
    if t.starts_with("GET /metrics") {
        return Ok(Request::Metrics);
    }
    let v = parse_json(t).map_err(ServeError::Malformed)?;
    if !matches!(v, Json::Obj(_)) {
        return Err(ServeError::BadRequest("request must be a JSON object".into()));
    }
    if let Some(cmd) = v.get("cmd") {
        return match cmd.as_str() {
            Some("health") => Ok(Request::Health),
            Some("metrics") => Ok(Request::Metrics),
            Some("shutdown") => Ok(Request::Shutdown),
            _ => Err(ServeError::BadRequest(
                "cmd must be health|metrics|shutdown".into(),
            )),
        };
    }
    let seed = match v.get("seed") {
        None => 1,
        Some(j) => j
            .as_u64()
            .ok_or_else(|| ServeError::BadRequest("seed must be a non-negative integer".into()))?,
    };
    if let Some(name) = v.get("scenario") {
        let name = name
            .as_str()
            .ok_or_else(|| ServeError::BadRequest("scenario must be a string".into()))?;
        return Ok(Request::Run(RunRequest {
            target: RunTarget::Scenario(name.to_string()),
            seed,
        }));
    }
    if let Some(spec) = v.get("spec") {
        return Ok(Request::Run(RunRequest {
            target: RunTarget::Inline(InlineSpec::from_json(spec)?),
            seed,
        }));
    }
    Err(ServeError::BadRequest(
        "request needs a scenario, spec, or cmd field".into(),
    ))
}

// ---------------------------------------------------------------------------
// Bounded line reading
// ---------------------------------------------------------------------------

/// Read one `\n`-terminated line with a hard size bound.
///
/// - `Ok(None)` — clean EOF (no bytes before it).
/// - `Ok(Some(Ok(line)))` — a line within bounds (terminator and any
///   trailing `\r` stripped; a final unterminated line counts).
/// - `Ok(Some(Err(_)))` — the line exceeded `max` bytes
///   ([`ServeError::Oversized`]) or was not UTF-8
///   ([`ServeError::Malformed`]). The offending line is consumed through
///   its terminator either way, so the connection survives and the
///   *next* line parses normally — an oversized request costs one error
///   response, not the session.
pub fn read_line_bounded<R: std::io::BufRead>(
    r: &mut R,
    max: usize,
) -> std::io::Result<Option<Result<String, ServeError>>> {
    let mut buf: Vec<u8> = Vec::new();
    let mut total = 0usize;
    let mut saw_any = false;
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            if !saw_any {
                return Ok(None);
            }
            break;
        }
        saw_any = true;
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                total += i;
                if total <= max {
                    buf.extend_from_slice(&chunk[..i]);
                }
                r.consume(i + 1);
                break;
            }
            None => {
                let len = chunk.len();
                total += len;
                if total <= max {
                    buf.extend_from_slice(chunk);
                }
                r.consume(len);
            }
        }
    }
    if total > max {
        return Ok(Some(Err(ServeError::Oversized { len: total, max })));
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    match String::from_utf8(buf) {
        Ok(s) => Ok(Some(Ok(s))),
        Err(_) => Ok(Some(Err(ServeError::Malformed(
            "request line is not valid UTF-8".into(),
        )))),
    }
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// FNV-1a digest of an output tensor — the transportable bit-identity
/// witness (equal digests ⇔ equal outputs, up to hash collisions).
pub fn outputs_digest(outputs: &[i16]) -> u64 {
    Fnv64::new().i16s(outputs).finish()
}

/// FNV-1a digest over the cycle-accurate counter set: the scalar
/// counters plus the per-PE and per-link vectors. Two executions with
/// equal stats digests ran the same modeled schedule.
pub fn stats_digest(stats: &FabricStats) -> u64 {
    let mut h = Fnv64::new();
    h.u64(stats.cycles)
        .u64(stats.alu_ops)
        .u64(stats.enroute_ops)
        .u64(stats.mem_ops)
        .u64(stats.msgs_created)
        .u64(stats.msgs_retired)
        .u64(stats.flit_hops)
        .u64(stats.buf_writes)
        .u64(stats.dmem_reads)
        .u64(stats.dmem_writes)
        .u64(stats.offchip_bytes)
        .u64(stats.peak_link_demand);
    h.u64(stats.per_pe_busy_cycles.len() as u64);
    for &v in &stats.per_pe_busy_cycles {
        h.u64(v);
    }
    h.u64(stats.per_pe_committed_ops.len() as u64);
    for &v in &stats.per_pe_committed_ops {
        h.u64(v);
    }
    h.u64(stats.link_flits.len() as u64);
    for &v in &stats.link_flits {
        h.u64(v);
    }
    h.finish()
}

/// Render the success response for one executed run request.
#[allow(clippy::too_many_arguments)]
pub fn run_response_line(
    name: &str,
    fingerprint: u64,
    seed: u64,
    shards: usize,
    cache_hit: bool,
    exec: &Execution,
    queue_us: u64,
    exec_us: u64,
) -> String {
    let (op_cv, op_max_mean, sdigest) = match &exec.stats {
        Some(s) => (s.op_cv(), s.op_max_mean(), stats_digest(s)),
        None => (0.0, 0.0, 0),
    };
    let mut o = JsonObj::new();
    o.str("status", "ok")
        .str("scenario", name)
        .hex("fingerprint", fingerprint)
        .u64("seed", seed)
        .u64("shards", shards as u64)
        .str("cache", if cache_hit { "hit" } else { "miss" })
        .u64("cycles", exec.cycles())
        .u64("work_ops", exec.result.work_ops)
        .f64("utilization", exec.result.utilization, 4)
        .f64("op_cv", op_cv, 4)
        .f64("op_max_mean", op_max_mean, 4)
        .hex("outputs_digest", outputs_digest(&exec.outputs))
        .hex("stats_digest", sdigest)
        .bool("validated", exec.validated())
        .u64("queue_us", queue_us)
        .u64("exec_us", exec_us);
    o.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_json_roundtrips_basic_values() {
        assert_eq!(parse_json("null").unwrap(), Json::Null);
        assert_eq!(parse_json(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse_json("-12.5e1").unwrap(), Json::Num(-125.0));
        assert_eq!(
            parse_json("\"a\\n\\\"b\\u0041\"").unwrap(),
            Json::Str("a\n\"bA".into())
        );
        let v = parse_json("{\"a\":[1,2,{\"b\":false}],\"c\":\"x\"}").unwrap();
        assert_eq!(v.get("c").and_then(Json::as_str), Some("x"));
        match v.get("a") {
            Some(Json::Arr(items)) => assert_eq!(items.len(), 3),
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn parse_json_surrogate_pairs_and_unicode() {
        assert_eq!(
            parse_json("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("😀".into())
        );
        assert_eq!(parse_json("\"héllo\"").unwrap(), Json::Str("héllo".into()));
        assert!(parse_json("\"\\ud83d\"").is_err(), "unpaired surrogate");
    }

    #[test]
    fn parse_json_rejects_garbage() {
        for bad in ["", "{", "{\"a\":}", "[1,]", "tru", "1 2", "{\"a\" 1}", "nan", "{oops"] {
            assert!(parse_json(bad).is_err(), "{bad:?} must not parse");
        }
        // Depth bound: 40 nested arrays exceed MAX_JSON_DEPTH.
        let deep = "[".repeat(40) + &"]".repeat(40);
        assert!(parse_json(&deep).is_err());
    }

    #[test]
    fn parse_request_forms() {
        assert_eq!(parse_request("GET /health").unwrap(), Request::Health);
        assert_eq!(parse_request("GET /metrics HTTP/1.1").unwrap(), Request::Metrics);
        assert_eq!(parse_request("{\"cmd\":\"shutdown\"}").unwrap(), Request::Shutdown);
        match parse_request("{\"scenario\":\"smoke/bfs-rmat-4x4\",\"seed\":9}").unwrap() {
            Request::Run(r) => {
                assert_eq!(r.seed, 9);
                assert_eq!(r.target, RunTarget::Scenario("smoke/bfs-rmat-4x4".into()));
            }
            other => panic!("expected run, got {other:?}"),
        }
        // Seed defaults to 1.
        match parse_request("{\"scenario\":\"x\"}").unwrap() {
            Request::Run(r) => assert_eq!(r.seed, 1),
            other => panic!("expected run, got {other:?}"),
        }
    }

    #[test]
    fn parse_request_inline_spec() {
        let line = "{\"spec\":{\"kernel\":\"spmv\",\"source\":\"rmat\",\"n\":32,\
                    \"density\":0.25,\"mesh\":[4,4]},\"seed\":3}";
        match parse_request(line).unwrap() {
            Request::Run(RunRequest {
                target: RunTarget::Inline(s),
                seed,
            }) => {
                assert_eq!(seed, 3);
                assert_eq!((s.n, s.mesh), (32, (4, 4)));
                assert_eq!(s.name(), "inline/spmv-rmat-n32-d0.25-4x4");
                // Deterministic: equal (description, seed) → equal tensors.
                assert_eq!(
                    crate::machine::spec_fingerprint(&s.spec(3)),
                    crate::machine::spec_fingerprint(&s.spec(3))
                );
                assert_ne!(
                    crate::machine::spec_fingerprint(&s.spec(3)),
                    crate::machine::spec_fingerprint(&s.spec(4))
                );
            }
            other => panic!("expected inline run, got {other:?}"),
        }
        // Defaults: n=64, density=0.2, mesh 8x8, source uniform.
        match parse_request("{\"spec\":{\"kernel\":\"spmv\"}}").unwrap() {
            Request::Run(RunRequest {
                target: RunTarget::Inline(s),
                ..
            }) => assert_eq!((s.n, s.density, s.mesh), (64, 0.2, (8, 8))),
            other => panic!("expected inline run, got {other:?}"),
        }
    }

    #[test]
    fn parse_request_typed_errors() {
        assert!(matches!(
            parse_request("{oops"),
            Err(ServeError::Malformed(_))
        ));
        assert!(matches!(
            parse_request("[1,2]"),
            Err(ServeError::BadRequest(_))
        ));
        assert!(matches!(
            parse_request("{\"cmd\":\"explode\"}"),
            Err(ServeError::BadRequest(_))
        ));
        assert!(matches!(
            parse_request("{\"scenario\":\"x\",\"seed\":-1}"),
            Err(ServeError::BadRequest(_))
        ));
        assert!(matches!(
            parse_request("{\"spec\":{\"kernel\":\"spmv\",\"n\":4}}"),
            Err(ServeError::BadRequest(_))
        ));
        assert!(matches!(
            parse_request("{\"spec\":{\"kernel\":\"spmv\",\"mesh\":[64,64]}}"),
            Err(ServeError::BadRequest(_))
        ));
    }

    #[test]
    fn error_lines_are_stable_json() {
        let e = ServeError::Overloaded;
        assert_eq!(e.to_line(), "{\"status\":\"error\",\"error\":\"overloaded\"}");
        let e = ServeError::Oversized { len: 99, max: 10 };
        assert_eq!(
            e.to_line(),
            "{\"status\":\"error\",\"error\":\"oversized\",\"len\":99,\"max\":10}"
        );
        let e = ServeError::Malformed("quote \" here".into());
        let line = e.to_line();
        assert!(parse_json(&line).is_ok(), "error lines must reparse: {line}");
    }

    #[test]
    fn read_line_bounded_survives_oversized_lines() {
        use std::io::BufReader;
        let input = format!("short\r\n{}\nafter\n", "x".repeat(100));
        let mut r = BufReader::with_capacity(16, input.as_bytes());
        assert_eq!(
            read_line_bounded(&mut r, 32).unwrap(),
            Some(Ok("short".to_string()))
        );
        match read_line_bounded(&mut r, 32).unwrap() {
            Some(Err(ServeError::Oversized { len, max })) => {
                assert_eq!((len, max), (100, 32));
            }
            other => panic!("expected oversized, got {other:?}"),
        }
        // The connection survives: the next line still parses.
        assert_eq!(
            read_line_bounded(&mut r, 32).unwrap(),
            Some(Ok("after".to_string()))
        );
        assert_eq!(read_line_bounded(&mut r, 32).unwrap(), None, "clean EOF");
    }

    #[test]
    fn read_line_bounded_final_unterminated_line_counts() {
        use std::io::BufReader;
        let mut r = BufReader::new("tail".as_bytes());
        assert_eq!(
            read_line_bounded(&mut r, 32).unwrap(),
            Some(Ok("tail".to_string()))
        );
        assert_eq!(read_line_bounded(&mut r, 32).unwrap(), None);
    }

    #[test]
    fn read_line_bounded_rejects_bad_utf8() {
        use std::io::BufReader;
        let bytes: &[u8] = b"\xff\xfe\n ok\n";
        let mut r = BufReader::new(bytes);
        assert!(matches!(
            read_line_bounded(&mut r, 32).unwrap(),
            Some(Err(ServeError::Malformed(_)))
        ));
        assert_eq!(
            read_line_bounded(&mut r, 32).unwrap(),
            Some(Ok(" ok".to_string()))
        );
    }

    #[test]
    fn digests_react_to_any_change() {
        assert_ne!(outputs_digest(&[1, 2, 3]), outputs_digest(&[1, 2, 4]));
        assert_ne!(outputs_digest(&[]), outputs_digest(&[0]));
        assert_eq!(outputs_digest(&[-5, 7]), outputs_digest(&[-5, 7]));
    }
}
