//! `nexus serve` — a long-running batch-execution daemon over plain TCP.
//!
//! # Architecture
//!
//! ```text
//!                 ┌────────────────────────────────────────────────┐
//!  TCP clients ──▶│ accept loop (nonblocking poll)                 │
//!                 │   └─ per-connection reader + ordered writer    │
//!                 │        │ parse line → Request                  │
//!                 │        │ control (health/metrics/shutdown):    │
//!                 │        │   answered inline                     │
//!                 │        ▼ runs:                                 │
//!                 │  BoundedQueue<Job> ── full? → "overloaded"     │
//!                 │        ▼                                       │
//!                 │  worker pool (N threads, reusable Machines)    │
//!                 │        │  SharedCompileCache (mutex + LRU)     │
//!                 │        ▼                                       │
//!                 │  response line → per-request reply channel     │
//!                 └────────────────────────────────────────────────┘
//! ```
//!
//! Design points, each load-bearing for the acceptance tests:
//!
//! - **Determinism.** A served run is the same compile + execute a direct
//!   [`Machine::run`] performs for the same (spec, seed, shards): the
//!   response carries FNV digests of the outputs and the full counter
//!   set, and the test suite asserts they are bit-identical to an
//!   in-process run.
//! - **Ordered pipelining.** Clients may pipeline many request lines;
//!   responses always come back in request order. The reader thread
//!   enqueues one single-use reply channel per request into an in-order
//!   stream; the connection's writer thread drains them sequentially
//!   while workers fill them concurrently.
//! - **Explicit backpressure.** Admission is [`BoundedQueue::try_push`]:
//!   when the queue is full the client gets `{"error":"overloaded"}`
//!   immediately. Nothing admitted is ever dropped.
//! - **Graceful shutdown.** A `{"cmd":"shutdown"}` request (or closing
//!   the listener) flips the draining flag and closes the queue: new
//!   runs are refused with `shutting_down`, admitted runs complete and
//!   their responses flush, workers join, and the process exits 0.
//!
//! Everything is std-only: no async runtime, no serde — threads, a
//! mutex-and-condvar queue, and the hand-rolled [`protocol`] JSON.

pub mod health;
pub mod metrics;
pub mod protocol;
pub mod queue;

use crate::config::{ArchConfig, StepMode, TopologyKind};
use crate::dataset::runner::effective_shards;
use crate::dataset::Corpus;
use crate::machine::{config_tag, spec_fingerprint, Machine, SharedCompileCache};
use metrics::Metrics;
use protocol::{
    parse_request, read_line_bounded, run_response_line, Request, RunRequest, RunTarget,
    ServeError,
};
use queue::{BoundedQueue, PushError};
use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Tuning knobs for one server instance. `Default` is a sensible local
/// deployment; the CLI maps flags onto the fields it exposes.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Listen address, e.g. `127.0.0.1:7077` (`:0` picks a free port).
    pub addr: String,
    /// Worker threads executing runs (0 → available parallelism).
    pub workers: usize,
    /// Requested shard count applied to every run (folded to a divisor
    /// of each mesh height, exactly like the corpus runner).
    pub shards: usize,
    /// OS threads per sharded step.
    pub threads: usize,
    /// NoC topology for every run.
    pub topology: TopologyKind,
    /// Fabric stepping mode for every run.
    pub step_mode: StepMode,
    /// Bounded run-queue capacity (admission control).
    pub queue_capacity: usize,
    /// Shared compile-cache capacity (artifacts, LRU-evicted).
    pub cache_capacity: usize,
    /// Hard per-line size bound for requests.
    pub max_line_bytes: usize,
    /// How long shutdown waits for open connections to finish before
    /// forcing their sockets closed.
    pub drain_grace_ms: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7077".to_string(),
            workers: 0,
            shards: 1,
            threads: 1,
            topology: TopologyKind::Mesh2D,
            step_mode: StepMode::ActiveSet,
            queue_capacity: 64,
            cache_capacity: 256,
            max_line_bytes: 64 * 1024,
            drain_grace_ms: 3000,
        }
    }
}

impl ServeOptions {
    /// Resolve `workers == 0` to the host's available parallelism.
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        thread::available_parallelism().map(|n| n.get()).unwrap_or(2)
    }
}

/// One admitted run: the request, its admission time (for `queue_us`),
/// and the single-use channel its response line goes down.
struct Job {
    request: RunRequest,
    enqueued: Instant,
    reply: mpsc::Sender<String>,
}

/// State shared by the accept loop, connection handlers, and workers.
struct ServerState {
    opts: ServeOptions,
    corpus: Corpus,
    queue: BoundedQueue<Job>,
    metrics: Metrics,
    cache: SharedCompileCache,
    draining: AtomicBool,
    active_conns: AtomicUsize,
    /// Clones of every accepted stream, so shutdown can force-close
    /// stragglers after the drain grace period.
    conn_streams: Mutex<Vec<TcpStream>>,
}

impl ServerState {
    /// Flip into drain mode: refuse new work, let admitted work finish.
    fn begin_shutdown(&self) {
        self.draining.store(true, Ordering::SeqCst);
        self.queue.close();
    }

    fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }
}

/// A bound-but-not-yet-running server. Splitting bind from run lets
/// tests and benches bind port 0 and read [`Server::local_addr`] before
/// serving.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind the listen socket and build the shared state.
    pub fn bind(opts: ServeOptions) -> io::Result<Server> {
        let listener = TcpListener::bind(&opts.addr)?;
        let state = Arc::new(ServerState {
            queue: BoundedQueue::new(opts.queue_capacity),
            metrics: Metrics::new(),
            cache: SharedCompileCache::new(opts.cache_capacity),
            corpus: Corpus::builtin(),
            draining: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            conn_streams: Mutex::new(Vec::new()),
            opts,
        });
        Ok(Server { listener, state })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Serve until a shutdown request arrives, then drain and return.
    /// Returning `Ok(())` is the exit-0 path.
    pub fn run(self) -> io::Result<()> {
        let Server { listener, state } = self;
        listener.set_nonblocking(true)?;
        let workers = state.opts.effective_workers();
        let worker_handles: Vec<_> = (0..workers)
            .map(|_| {
                let state = Arc::clone(&state);
                thread::spawn(move || worker_loop(&state))
            })
            .collect();

        // Accept loop: nonblocking poll so the draining flag is observed
        // promptly — this is the listener-close path of shutdown.
        let mut conn_handles = Vec::new();
        while !state.draining() {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if let Ok(clone) = stream.try_clone() {
                        state.conn_streams.lock().unwrap().push(clone);
                    }
                    state.active_conns.fetch_add(1, Ordering::SeqCst);
                    let state = Arc::clone(&state);
                    conn_handles.push(thread::spawn(move || {
                        let _ = handle_conn(stream, &state);
                        state.active_conns.fetch_sub(1, Ordering::SeqCst);
                    }));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(2));
                }
                Err(_) => break,
            }
        }
        drop(listener);

        // Drain: workers finish every admitted job (the queue is closed,
        // so pop() returns None once empty), then exit.
        for h in worker_handles {
            let _ = h.join();
        }

        // Give open connections a grace period to flush and hang up, then
        // force-close the stragglers so their reader threads unblock.
        let deadline = Instant::now() + Duration::from_millis(state.opts.drain_grace_ms);
        while state.active_conns.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(10));
        }
        for s in state.conn_streams.lock().unwrap().iter() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        for h in conn_handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Bind and serve with the given options (the CLI entry point).
pub fn run(opts: ServeOptions) -> io::Result<()> {
    Server::bind(opts)?.run()
}

/// Per-connection protocol loop. The calling thread reads and parses
/// request lines; a paired writer thread emits responses strictly in
/// request order while workers fill them out of order.
fn handle_conn(stream: TcpStream, state: &Arc<ServerState>) -> io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let write_half = stream.try_clone()?;

    // Ordered pipelining: a channel of single-use reply channels. The
    // reader pushes one receiver per request, in order; the writer drains
    // them sequentially, blocking on whichever response is next due.
    let (slot_tx, slot_rx) = mpsc::channel::<mpsc::Receiver<String>>();
    let writer = thread::spawn(move || {
        let mut w = BufWriter::new(write_half);
        for slot in slot_rx {
            // A dropped sender (worker gone without replying) is skipped;
            // admitted jobs normally always reply.
            if let Ok(line) = slot.recv() {
                if writeln!(w, "{line}").and_then(|_| w.flush()).is_err() {
                    break;
                }
            }
        }
    });

    // Answer an inline (non-queued) response while preserving order.
    let ready = |line: String| {
        let (tx, rx) = mpsc::channel();
        let _ = tx.send(line);
        rx
    };

    loop {
        let line = match read_line_bounded(&mut reader, state.opts.max_line_bytes)? {
            None => break,
            Some(Err(e)) => {
                state.metrics.malformed.fetch_add(1, Ordering::Relaxed);
                let _ = slot_tx.send(ready(e.to_line()));
                continue;
            }
            Some(Ok(l)) => l,
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Err(e) => {
                state.metrics.malformed.fetch_add(1, Ordering::Relaxed);
                let _ = slot_tx.send(ready(e.to_line()));
            }
            Ok(Request::Health) => {
                let _ = slot_tx.send(ready(health::health_line(
                    &state.metrics,
                    state.queue.len(),
                    state.opts.effective_workers(),
                    state.draining(),
                )));
            }
            Ok(Request::Metrics) => {
                let _ = slot_tx.send(ready(health::metrics_line(
                    &state.metrics,
                    state.queue.len(),
                    state.queue.capacity(),
                    state.opts.effective_workers(),
                    state.cache.stats(),
                    state.draining(),
                )));
            }
            Ok(Request::Shutdown) => {
                state.begin_shutdown();
                let mut o = crate::util::json::JsonObj::new();
                o.str("status", "ok").bool("shutdown", true);
                let _ = slot_tx.send(ready(o.build()));
                break;
            }
            Ok(Request::Run(request)) => {
                state.metrics.received.fetch_add(1, Ordering::Relaxed);
                if state.draining() {
                    state.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = slot_tx.send(ready(ServeError::ShuttingDown.to_line()));
                    continue;
                }
                let (reply_tx, reply_rx) = mpsc::channel();
                let job = Job {
                    request,
                    enqueued: Instant::now(),
                    reply: reply_tx,
                };
                match state.queue.try_push(job) {
                    Ok(()) => {
                        let _ = slot_tx.send(reply_rx);
                    }
                    Err((kind, _job)) => {
                        state.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        let e = match kind {
                            PushError::Full => ServeError::Overloaded,
                            PushError::Closed => ServeError::ShuttingDown,
                        };
                        let _ = slot_tx.send(ready(e.to_line()));
                    }
                }
            }
        }
    }

    // EOF (or shutdown): stop accepting slots and let the writer drain
    // the responses still owed — this is what makes pipelined shutdowns
    // lossless — then hang up.
    drop(slot_tx);
    let _ = writer.join();
    Ok(())
}

/// Worker thread: pull jobs until the queue closes and drains, keeping
/// one reusable [`Machine`] per mesh geometry.
fn worker_loop(state: &Arc<ServerState>) {
    let mut machines: HashMap<(usize, usize), Machine> = HashMap::new();
    while let Some(job) = state.queue.pop() {
        let queue_us = job.enqueued.elapsed().as_micros() as u64;
        let line = match execute_job(state, &mut machines, &job.request, queue_us) {
            Ok(line) => {
                state.metrics.completed.fetch_add(1, Ordering::Relaxed);
                line
            }
            Err(e) => {
                state.metrics.errored.fetch_add(1, Ordering::Relaxed);
                e.to_line()
            }
        };
        // End-to-end latency: queue wait + execution.
        state
            .metrics
            .record_latency_us(job.enqueued.elapsed().as_micros() as u64);
        let _ = job.reply.send(line);
    }
}

/// Resolve, compile (through the shared cache), and execute one run.
/// The compile + execute pair is exactly what a direct
/// [`Machine::run`] does, which is what keeps served results
/// bit-identical to in-process ones.
fn execute_job(
    state: &Arc<ServerState>,
    machines: &mut HashMap<(usize, usize), Machine>,
    request: &RunRequest,
    queue_us: u64,
) -> Result<String, ServeError> {
    let (name, mesh, spec) = match &request.target {
        RunTarget::Scenario(name) => {
            let sc = state
                .corpus
                .find(name)
                .ok_or_else(|| ServeError::UnknownScenario(name.clone()))?;
            (sc.name.clone(), sc.mesh, sc.spec(request.seed))
        }
        RunTarget::Inline(inline) => (inline.name(), inline.mesh, inline.spec(request.seed)),
    };
    let opts = &state.opts;
    let shards = effective_shards(opts.shards, mesh.1);
    let cfg = ArchConfig::nexus()
        .with_array(mesh.0, mesh.1)
        .with_topology(opts.topology)
        .with_step_mode(opts.step_mode)
        .with_shards(shards)
        .with_threads(opts.threads);
    let machine = machines.entry(mesh).or_insert_with(|| {
        Machine::new(cfg.clone()).with_cache_capacity(opts.cache_capacity.max(1))
    });
    let started = Instant::now();
    let (compiled, cache_hit) = state
        .cache
        .get_or_compile(config_tag(&cfg), machine, &spec)
        .map_err(|e| ServeError::ExecFailed(e.to_string()))?;
    let exec = machine
        .execute(&compiled)
        .map_err(|e| ServeError::ExecFailed(e.to_string()))?;
    let exec_us = started.elapsed().as_micros() as u64;
    // Feed the live stall-attribution gauges behind `GET /metrics`.
    if let Some(stats) = &exec.stats {
        state.metrics.record_run_stats(stats);
    }
    Ok(run_response_line(
        &name,
        spec_fingerprint(&spec),
        request.seed,
        shards,
        cache_hit,
        &exec,
        queue_us,
        exec_us,
    ))
}
