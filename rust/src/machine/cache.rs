//! Bounded LRU compile caches: the per-[`Machine`](super::Machine) cache
//! and the process-wide [`SharedCompileCache`] the `nexus serve` workers
//! feed from.
//!
//! Both hold [`Compiled`] artifacts (cheap clones — the program is behind
//! an `Arc`) keyed by content, and both are *bounded*: a long-running
//! service that compiles an unbounded stream of distinct specs must not
//! grow its cache without limit. Eviction is least-recently-used; an
//! evicted entry simply recompiles on its next request, which is
//! bit-identical by construction (compilation is deterministic in the
//! spec and the architecture — asserted by the unit tests below).

use super::{spec_fingerprint, Compiled, ExecError, Machine};
use crate::config::ArchConfig;
use crate::workloads::Spec;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Mutex;

/// Default per-machine cache capacity: generous — a sweep over the whole
/// corpus plus the 13-workload suite fits many times over — but finite.
pub const DEFAULT_CACHE_CAPACITY: usize = 1024;

/// A bounded LRU map from cache key to [`Compiled`] artifact with
/// hit/miss accounting. Not thread-safe by itself; [`SharedCompileCache`]
/// wraps it in a mutex for cross-worker sharing.
#[derive(Debug)]
pub struct CompileCache<K: Hash + Eq + Clone> {
    map: HashMap<K, (Compiled, u64)>,
    capacity: usize,
    /// Monotonic use counter: the LRU stamp. Eviction scans for the
    /// minimum — O(n), fine at the capacities involved (eviction is the
    /// rare path; lookups stay O(1)).
    clock: u64,
    hits: u64,
    misses: u64,
}

impl<K: Hash + Eq + Clone> CompileCache<K> {
    /// A cache holding at most `capacity` artifacts (min 1).
    pub fn new(capacity: usize) -> Self {
        CompileCache {
            map: HashMap::new(),
            capacity: capacity.max(1),
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Look up a key, refreshing its recency on hit. Counts hit/miss.
    pub fn get(&mut self, key: &K) -> Option<Compiled> {
        self.clock += 1;
        match self.map.get_mut(key) {
            Some(entry) => {
                entry.1 = self.clock;
                self.hits += 1;
                Some(entry.0.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used one
    /// first when the cache is at capacity.
    pub fn insert(&mut self, key: K, value: Compiled) {
        self.clock += 1;
        if !self.map.contains_key(&key) && self.map.len() >= self.capacity {
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&lru);
            }
        }
        self.map.insert(key, (value, self.clock));
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Replace the capacity, evicting LRU entries until the new bound
    /// holds.
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity.max(1);
        while self.map.len() > self.capacity {
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&lru);
            } else {
                break;
            }
        }
    }

    /// `(hits, misses)` since construction.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

/// Fingerprint of the *architecture* side of a compile key: every
/// [`ArchConfig`] field the compile path (partitioning + static-AM
/// codegen) depends on. Two configs with equal tags produce bit-identical
/// artifacts for equal specs, so a shared cache may serve either.
pub fn config_tag(cfg: &ArchConfig) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut u = |v: u64| h = (h ^ v).wrapping_mul(0x0000_0100_0000_01b3);
    u(cfg.width as u64);
    u(cfg.height as u64);
    u(cfg.dmem_words as u64);
    for b in cfg.kind.name().bytes() {
        u(b as u64);
    }
    // Placement changes the row -> PE mapping and hence the compiled
    // static-AM program; claim policy is runtime-only and deliberately
    // excluded (all claim policies share one artifact).
    for b in cfg.placement.name().bytes() {
        u(b as u64);
    }
    h
}

/// Key of one shared-cache entry: (architecture tag, workload name,
/// tensor-content fingerprint).
pub type SharedKey = (u64, String, u64);

/// The process-wide compile cache behind `nexus serve`: one mutex-guarded
/// bounded LRU shared by every worker, so a scenario compiled by any
/// worker is a cache hit for all of them. Hit/miss counters feed the
/// service's `/metrics` cache-hit-rate.
pub struct SharedCompileCache {
    inner: Mutex<CompileCache<SharedKey>>,
}

impl SharedCompileCache {
    pub fn new(capacity: usize) -> Self {
        SharedCompileCache {
            inner: Mutex::new(CompileCache::new(capacity)),
        }
    }

    /// Fetch the artifact for `spec` on the architecture tagged `tag`,
    /// compiling on `machine` on a miss. Returns the artifact and whether
    /// it was a shared-cache hit. The mutex is NOT held across the
    /// compile, so concurrent workers missing on the same key may both
    /// compile — both artifacts are bit-identical, the last insert wins.
    pub fn get_or_compile(
        &self,
        tag: u64,
        machine: &mut Machine,
        spec: &Spec,
    ) -> Result<(Compiled, bool), ExecError> {
        let key: SharedKey = (tag, spec.name(), spec_fingerprint(spec));
        if let Some(c) = self.inner.lock().unwrap().get(&key) {
            return Ok((c, true));
        }
        let compiled = machine.compile(spec)?;
        self.inner.lock().unwrap().insert(key, compiled.clone());
        Ok((compiled, false))
    }

    /// `(hits, misses, entries, capacity)` — the `/metrics` cache block.
    pub fn stats(&self) -> (u64, u64, usize, usize) {
        let c = self.inner.lock().unwrap();
        let (h, m) = c.counters();
        (h, m, c.len(), c.capacity())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::SplitMix64;

    fn spmv_spec(seed: u64) -> Spec {
        let mut rng = SplitMix64::new(seed);
        let a = gen::random_csr(&mut rng, 16, 16, 0.3);
        let x = gen::random_vec(&mut rng, 16, 3);
        Spec::Spmv { a, x }
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        // Three distinct specs through a capacity-2 per-machine cache:
        // compiling C must evict A (the LRU), not B (refreshed by a get).
        let mut m = Machine::new(ArchConfig::nexus()).with_cache_capacity(2);
        let (a, b, c) = (spmv_spec(1), spmv_spec(2), spmv_spec(3));
        m.compile(&a).unwrap();
        m.compile(&b).unwrap();
        assert_eq!(m.cached_programs(), 2);
        m.compile(&a).unwrap(); // refresh A: B becomes the LRU
        m.compile(&c).unwrap(); // evicts B
        assert_eq!(m.cached_programs(), 2);
        // A stays shared (cache hit — same Arc), B was evicted.
        let a1 = m.compile(&a).unwrap();
        let a2 = m.compile(&a).unwrap();
        assert!(std::sync::Arc::ptr_eq(&a1.artifact, &a2.artifact));
    }

    #[test]
    fn eviction_plus_recompile_is_bit_identical() {
        // A capacity-1 cache thrashes between two specs; every execution
        // must stay bit-identical to an unbounded-cache machine's.
        let cfg = ArchConfig::nexus();
        let mut bounded = Machine::new(cfg.clone()).with_cache_capacity(1);
        let mut unbounded = Machine::new(cfg);
        let (a, b) = (spmv_spec(11), spmv_spec(12));
        for _ in 0..3 {
            for spec in [&a, &b] {
                let eb = bounded.run(spec).unwrap();
                let eu = unbounded.run(spec).unwrap();
                assert_eq!(eb.outputs, eu.outputs);
                assert_eq!(eb.cycles(), eu.cycles());
                assert_eq!(eb.stats, eu.stats, "full counter set must match");
            }
            assert_eq!(bounded.cached_programs(), 1, "capacity bound violated");
        }
        assert_eq!(unbounded.cached_programs(), 2);
    }

    #[test]
    fn shared_cache_hits_across_machines() {
        let cfg = ArchConfig::nexus();
        let tag = config_tag(&cfg);
        let cache = SharedCompileCache::new(8);
        let spec = spmv_spec(5);
        let mut m1 = Machine::new(cfg.clone());
        let mut m2 = Machine::new(cfg);
        let (c1, hit1) = cache.get_or_compile(tag, &mut m1, &spec).unwrap();
        let (c2, hit2) = cache.get_or_compile(tag, &mut m2, &spec).unwrap();
        assert!(!hit1 && hit2, "second worker must hit the shared cache");
        assert!(std::sync::Arc::ptr_eq(&c1.artifact, &c2.artifact));
        // And the shared artifact executes on both machines.
        let e1 = m1.execute(&c1).unwrap();
        let e2 = m2.execute(&c2).unwrap();
        assert_eq!(e1.outputs, e2.outputs);
        assert_eq!(e1.cycles(), e2.cycles());
        let (h, miss, len, cap) = cache.stats();
        assert_eq!((h, miss, len, cap), (1, 1, 1, 8));
    }

    #[test]
    fn config_tag_distinguishes_geometry() {
        let a = config_tag(&ArchConfig::nexus());
        let b = config_tag(&ArchConfig::nexus().with_array(8, 8));
        assert_ne!(a, b);
        assert_eq!(a, config_tag(&ArchConfig::nexus()));
    }

    #[test]
    fn config_tag_covers_placement_but_not_claim() {
        use crate::config::{ClaimPolicy, PlacementPolicy};
        let base = config_tag(&ArchConfig::nexus());
        for p in PlacementPolicy::ALL {
            let t = config_tag(&ArchConfig::nexus().with_placement(p));
            assert_eq!(t == base, p == PlacementPolicy::default());
        }
        // Claim is a runtime schedule choice: same compiled artifact.
        for c in ClaimPolicy::ALL {
            assert_eq!(base, config_tag(&ArchConfig::nexus().with_claim(c)));
        }
    }

    #[test]
    fn set_capacity_shrinks() {
        let mut m = Machine::new(ArchConfig::nexus());
        for s in 0..4 {
            m.compile(&spmv_spec(s + 20)).unwrap();
        }
        assert_eq!(m.cached_programs(), 4);
        m.set_cache_capacity(2);
        assert_eq!(m.cached_programs(), 2);
    }
}
