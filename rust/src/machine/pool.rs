//! [`MachinePool`] — the one threaded fan-out for every experiment sweep.
//!
//! The coordinator used to hand-roll four identical `Mutex` +
//! `thread::scope` patterns (matrix, suite validation, bandwidth sweep,
//! scalability sweep), each spawning one OS thread per job and each
//! allocating a fresh fabric per run. The pool replaces them with a fixed
//! worker count and per-worker reusable state (typically a
//! [`crate::machine::Machine`], so fabric allocations and compile caches
//! survive across the jobs a worker executes). Results always come back in
//! job order, independent of scheduling, which keeps sweeps deterministic.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A fixed-size worker pool for batch execution of independent jobs.
pub struct MachinePool {
    workers: usize,
}

impl MachinePool {
    /// Pool sized to the host's available parallelism.
    pub fn new() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::with_workers(workers)
    }

    /// Pool with an explicit worker count (min 1).
    pub fn with_workers(workers: usize) -> Self {
        MachinePool {
            workers: workers.max(1),
        }
    }

    /// Pool sized for jobs that each run a fabric on `threads_per_job`
    /// worker threads ([`crate::config::ArchConfig::threads`]): the host's
    /// available parallelism divided by the per-job thread count, so a
    /// sweep of multi-threaded simulations does not oversubscribe cores.
    pub fn for_threads(threads_per_job: usize) -> Self {
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::with_workers(avail / threads_per_job.max(1))
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f` over every job, fanning out across the pool's workers.
    /// Returns one result per job, in job order.
    pub fn run_batch<J, R, F>(&self, jobs: &[J], f: F) -> Vec<R>
    where
        J: Sync,
        R: Send,
        F: Fn(&J) -> R + Sync,
    {
        self.run_batch_with(|| (), jobs, |_, job| f(job))
    }

    /// As [`MachinePool::run_batch`], with one reusable per-worker state
    /// created by `init` and threaded through every job the worker executes
    /// — e.g. a `Machine` whose fabric and compile cache are reused across
    /// a whole sweep.
    pub fn run_batch_with<S, J, R, I, F>(&self, init: I, jobs: &[J], f: F) -> Vec<R>
    where
        J: Sync,
        R: Send,
        I: Fn() -> S + Sync,
        F: Fn(&mut S, &J) -> R + Sync,
    {
        if jobs.is_empty() {
            return Vec::new();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
        let workers = self.workers.min(jobs.len());
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let mut state = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        let r = f(&mut state, &jobs[i]);
                        *slots[i].lock().unwrap() = Some(r);
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .unwrap()
                    .expect("pool worker exited before filling its slot")
            })
            .collect()
    }
}

impl Default for MachinePool {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_preserve_job_order() {
        let jobs: Vec<usize> = (0..100).collect();
        let out = MachinePool::with_workers(7).run_batch(&jobs, |&j| j * 2);
        assert_eq!(out, (0..100).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn per_worker_state_is_reused() {
        // Each worker counts the jobs it ran; the counts must sum to the
        // batch size (every job ran exactly once, on some worker's state).
        let total = AtomicUsize::new(0);
        let jobs: Vec<u32> = (0..64).collect();
        let out = MachinePool::with_workers(4).run_batch_with(
            || 0usize,
            &jobs,
            |count, &j| {
                *count += 1;
                total.fetch_add(1, Ordering::Relaxed);
                j
            },
        );
        assert_eq!(out, jobs);
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn for_threads_divides_parallelism() {
        // threads_per_job = 1 must match the default sizing; huge
        // per-job thread counts must still leave one worker.
        assert_eq!(MachinePool::for_threads(1).workers(), MachinePool::new().workers());
        assert_eq!(MachinePool::for_threads(usize::MAX).workers(), 1);
        assert!(MachinePool::for_threads(2).workers() <= MachinePool::new().workers());
    }

    #[test]
    fn empty_batch_returns_empty() {
        let out = MachinePool::new().run_batch(&[] as &[u8], |_| 0u8);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs_is_fine() {
        let out = MachinePool::with_workers(32).run_batch(&[1, 2, 3], |&j| j + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn batch_results_are_identical_across_step_modes() {
        // The step mode threads through pooled sweeps untouched: a batch of
        // per-worker Machines in ActiveSet mode and one in DenseOracle mode
        // must produce identical cycle counts and outputs job for job.
        use crate::config::{ArchConfig, StepMode};
        use crate::machine::Machine;
        let specs: Vec<_> = crate::workloads::suite(1)
            .into_iter()
            .filter(|s| {
                let n = s.name();
                n.starts_with("SpMV") || n == "BFS"
            })
            .collect();
        assert!(!specs.is_empty());
        let run_all = |mode: StepMode| {
            MachinePool::with_workers(2).run_batch_with(
                || Machine::new(ArchConfig::nexus().with_step_mode(mode)),
                &specs,
                |m, spec| {
                    let e = m.run(spec).expect("pooled run");
                    (e.outputs.clone(), e.cycles())
                },
            )
        };
        assert_eq!(run_all(StepMode::ActiveSet), run_all(StepMode::DenseOracle));
    }
}
