//! The [`Backend`] trait — one execution contract for every architecture —
//! and [`FabricArch`], the cycle-accurate fabric backend behind the Nexus,
//! TIA and TIA-Valiant roster entries.
//!
//! A backend separates *compilation* (spec → [`Artifact`]) from *execution*
//! (artifact → [`Execution`]) so that sweeps which rerun a workload pay the
//! compile cost once. Fabric backends compile to a real [`Built`] program
//! and execute it on a reusable [`NexusFabric`] (reset between runs, not
//! reallocated); analytical backends (systolic array, Generic CGRA) evaluate
//! their closed-form model at compile time and replay the report at execute
//! time.

use super::{Compiled, ExecError, Execution};
use crate::baselines::RunResult;
use crate::compiler::Program;
use crate::config::{ArchConfig, ArchKind, StepMode};
use crate::fabric::NexusFabric;
use crate::power::EnergyEvents;
use crate::workloads::{Built, Spec, Tiles};

/// What a backend's compile step produces.
pub enum Artifact {
    /// A compiled fabric program together with its reference output
    /// (cycle-accurate backends).
    Program(Box<Built>),
    /// Analytical backends evaluate their model at compile time; execution
    /// replays the report.
    Report(Box<RunResult>),
}

/// An architecture that can compile and execute evaluation workloads.
pub trait Backend: Send {
    /// Roster display name ("Nexus", "TIA", "Systolic", …) — also the key
    /// the power/area models and [`crate::coordinator::Matrix`] use.
    fn name(&self) -> &'static str;

    /// Compile a workload spec into an executable artifact.
    fn compile(&self, spec: &Spec) -> Result<Artifact, ExecError>;

    /// Execute a previously compiled artifact.
    fn execute(&mut self, compiled: &Compiled) -> Result<Execution, ExecError>;
}

/// Execute a built workload on a fabric, returning the final outputs in the
/// program's logical order. This is the only place in the crate that drives
/// `NexusFabric` with a [`Built`] program.
pub(crate) fn run_built(f: &mut NexusFabric, built: &Built) -> Result<Vec<i16>, ExecError> {
    match &built.tiles {
        Tiles::Static(tiles) => {
            let mut out = Vec::new();
            for t in tiles {
                out.extend(run_tile(f, t)?);
            }
            Ok(out)
        }
        Tiles::Iterative { iters, gen } => {
            let mut prev: Vec<i16> = Vec::new();
            for i in 0..*iters {
                let p = gen(&prev, i);
                prev = run_tile(f, &p)?;
            }
            Ok(prev)
        }
    }
}

/// Run one tile, turning a program/architecture mismatch (e.g. an artifact
/// compiled under a different `ArchConfig`) into a typed error instead of
/// the fabric's internal panic.
fn run_tile(f: &mut NexusFabric, prog: &Program) -> Result<Vec<i16>, ExecError> {
    prog.validate(&f.cfg)
        .map_err(|reason| ExecError::IncompatibleProgram { reason })?;
    f.run_program(prog).map_err(ExecError::Deadlock)
}

/// Compare fabric outputs against the reference, as a typed error.
pub(crate) fn validate_outputs(out: &[i16], expected: &[i16]) -> Result<(), ExecError> {
    if out.len() != expected.len() {
        return Err(ExecError::OutputLength {
            got: out.len(),
            expected: expected.len(),
        });
    }
    for (index, (&got, &expected)) in out.iter().zip(expected).enumerate() {
        if got != expected {
            return Err(ExecError::ValidationMismatch {
                index,
                got,
                expected,
            });
        }
    }
    Ok(())
}

/// Fabric-backed architecture (Nexus, TIA, TIA-Valiant): a thin [`Backend`]
/// over one reusable [`NexusFabric`] instance, constructed lazily on the
/// first execution so that name-only uses of the roster (e.g.
/// `coordinator::arch_names`) stay allocation-free.
pub struct FabricArch {
    name: &'static str,
    cfg: ArchConfig,
    fabric: Option<NexusFabric>,
}

impl FabricArch {
    /// Wrap a fabric configuration under an explicit roster name.
    pub fn new(name: &'static str, cfg: ArchConfig) -> Self {
        cfg.validate().expect("invalid ArchConfig");
        FabricArch {
            name,
            cfg,
            fabric: None,
        }
    }

    /// Derive the roster name from the config's [`ArchKind`].
    pub fn from_config(cfg: ArchConfig) -> Self {
        let name = match cfg.kind {
            ArchKind::Nexus => "Nexus",
            ArchKind::Tia => "TIA",
            ArchKind::TiaValiant => "TIA-Valiant",
        };
        Self::new(name, cfg)
    }

    pub fn nexus() -> Self {
        Self::from_config(ArchConfig::nexus())
    }

    pub fn tia() -> Self {
        Self::from_config(ArchConfig::tia())
    }

    pub fn tia_valiant() -> Self {
        Self::from_config(ArchConfig::tia_valiant())
    }

    /// All three fabric variants.
    pub fn variants() -> Vec<FabricArch> {
        vec![Self::nexus(), Self::tia(), Self::tia_valiant()]
    }

    /// The architectural configuration this fabric models.
    pub fn cfg(&self) -> &ArchConfig {
        &self.cfg
    }

    /// Override the simulator scheduling mode ([`StepMode`]) for this
    /// backend. Host-side only — executions are bit-identical across modes;
    /// `DenseOracle` exists for differential testing and debugging. Drops
    /// any fabric built under the previous mode so the next execution
    /// constructs one with the requested scheduler.
    pub fn with_step_mode(mut self, mode: StepMode) -> Self {
        self.cfg.step_mode = mode;
        self.fabric = None;
        self
    }

    /// The simulator scheduling mode this backend's fabric will use.
    pub fn step_mode(&self) -> StepMode {
        self.cfg.step_mode
    }
}

impl Backend for FabricArch {
    fn name(&self) -> &'static str {
        self.name
    }

    fn compile(&self, spec: &Spec) -> Result<Artifact, ExecError> {
        Ok(Artifact::Program(Box::new(spec.build(&self.cfg))))
    }

    fn execute(&mut self, compiled: &Compiled) -> Result<Execution, ExecError> {
        let Artifact::Program(built) = compiled.artifact() else {
            return Err(ExecError::ArtifactMismatch {
                backend: self.name,
                workload: compiled.workload().to_string(),
            });
        };
        // First execution builds the fabric; afterwards it is reset (not
        // reallocated), which is bit-identical to a fresh instance.
        let fabric = self
            .fabric
            .get_or_insert_with(|| NexusFabric::new(self.cfg.clone()));
        fabric.reset();
        let outputs = run_built(fabric, built)?;
        validate_outputs(&outputs, &built.expected)?;
        let s = &fabric.stats;
        let result = RunResult {
            arch: self.name,
            workload: compiled.workload().to_string(),
            cycles: s.cycles,
            work_ops: built.work_ops,
            utilization: s.utilization(),
            in_network_frac: s.in_network_fraction(),
            congestion: std::array::from_fn(|p| s.port_congestion(p)),
            offchip_bytes: s.offchip_bytes,
            events: EnergyEvents::from_fabric(s, self.cfg.kind),
            validated: true,
        };
        let trace = if self.cfg.trace.enabled {
            Some(fabric.trace_events())
        } else {
            None
        };
        Ok(Execution {
            outputs,
            stats: Some(s.clone()),
            result,
            trace,
        })
    }
}
