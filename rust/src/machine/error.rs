//! Typed execution errors for the [`crate::machine`] API.
//!
//! Every failure mode that used to surface as a `panic!`, an `Option`, or a
//! stringly `Result<_, String>` is a variant here, so sweep harnesses can
//! report, count, and retry per-workload failures instead of dying.

use crate::fabric::DeadlockError;
use std::fmt;

/// Failure of a [`crate::machine::Machine`] compile or execute step.
#[derive(Debug, Clone)]
pub enum ExecError {
    /// The fabric did not drain within its cycle budget (`max_cycles`).
    /// Carries the full per-PE / per-port forensic report.
    Deadlock(DeadlockError),
    /// The backend cannot express this workload at all — e.g. a systolic
    /// array asked to run graph analytics.
    Unsupported {
        arch: &'static str,
        workload: String,
    },
    /// An output element disagreed with the software reference.
    ValidationMismatch {
        index: usize,
        got: i16,
        expected: i16,
    },
    /// The output tensor had the wrong number of elements.
    OutputLength { got: usize, expected: usize },
    /// A [`crate::machine::Compiled`] artifact was handed to a backend of a
    /// different kind than the one that produced it (e.g. an analytical
    /// report executed on a fabric machine).
    ArtifactMismatch {
        backend: &'static str,
        workload: String,
    },
    /// A fabric program does not fit the executing machine's architecture
    /// (different mesh geometry, SRAM size, config-memory capacity, …) —
    /// typically a [`crate::machine::Compiled`] compiled under one
    /// `ArchConfig` and executed under another.
    IncompatibleProgram { reason: String },
    /// A failure annotated with the workload it occurred in — sweep
    /// harnesses attach this so batch errors stay localizable.
    InWorkload {
        workload: String,
        source: Box<ExecError>,
    },
}

impl ExecError {
    /// Wrap an error with the workload it occurred in.
    pub fn in_workload(workload: impl Into<String>, source: ExecError) -> Self {
        ExecError::InWorkload {
            workload: workload.into(),
            source: Box::new(source),
        }
    }
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::Deadlock(e) => write!(f, "{e}"),
            ExecError::Unsupported { arch, workload } => {
                write!(f, "{arch} cannot execute {workload}")
            }
            ExecError::ValidationMismatch {
                index,
                got,
                expected,
            } => write!(
                f,
                "output mismatch at [{index}]: fabric {got}, reference {expected}"
            ),
            ExecError::OutputLength { got, expected } => {
                write!(f, "output length {got} != expected {expected}")
            }
            ExecError::ArtifactMismatch { backend, workload } => write!(
                f,
                "{backend} cannot execute the {workload} artifact: it was \
                 compiled by a different backend kind"
            ),
            ExecError::IncompatibleProgram { reason } => {
                write!(f, "program/architecture mismatch: {reason}")
            }
            ExecError::InWorkload { workload, source } => write!(f, "{workload}: {source}"),
        }
    }
}

impl std::error::Error for ExecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ExecError::Deadlock(e) => Some(e),
            ExecError::InWorkload { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<DeadlockError> for ExecError {
    fn from(e: DeadlockError) -> Self {
        ExecError::Deadlock(e)
    }
}
