//! The unified execution API: **compile once, run many**.
//!
//! Everything in the crate that executes a workload — the CLI, the
//! coordinator sweeps, the examples, the benches — goes through a
//! [`Machine`]:
//!
//! ```text
//! Machine::new(ArchConfig)              // owns one reusable NexusFabric
//! Machine::from_backend(Box<dyn Backend>) // or any roster architecture
//!   .compile(&Spec)  -> Compiled        // cached: recompiles are free
//!   .execute(&Compiled) -> Execution    // outputs + stats + energy events
//! ```
//!
//! A [`Machine`] owns a [`Backend`] (a reusable simulator instance or an
//! analytical model) plus a *bounded LRU* compile cache keyed by workload
//! and tensor content ([`cache::CompileCache`]; capacity via
//! [`Machine::with_cache_capacity`]), so sweeps that rerun a workload skip
//! recompilation and fabric executions reuse the fabric's allocations via
//! [`NexusFabric::reset`](crate::fabric::NexusFabric::reset)
//! instead of rebuilding a simulator per run. Long-running services share
//! artifacts *across* machines through the process-wide
//! [`cache::SharedCompileCache`]. Every failure mode is a typed
//! [`ExecError`] — deadlocks surface as `Err`, not `panic!`; unsupported
//! (architecture, workload) pairs as [`ExecError::Unsupported`]; reference
//! mismatches as [`ExecError::ValidationMismatch`].
//!
//! Batch fan-out lives in [`MachinePool`]: one worker pool with per-worker
//! reusable `Machine`s replaces the coordinator's four hand-rolled
//! `Mutex` + `thread::scope` patterns.
//!
//! The simulator scheduling mode threads through here untouched: a machine
//! built from an [`ArchConfig`] with
//! [`StepMode::DenseOracle`](crate::config::StepMode) runs the dense
//! reference scan, while the default `ActiveSet` mode runs the event-driven
//! scheduler — bit-identical results either way (see
//! `tests/step_equivalence.rs`), so sweeps can mix modes freely.

mod backend;
pub mod cache;
mod error;
mod pool;

pub use backend::{Artifact, Backend, FabricArch};
pub use cache::{config_tag, CompileCache, SharedCompileCache, DEFAULT_CACHE_CAPACITY};
pub use error::ExecError;
pub use pool::MachinePool;

use crate::baselines::RunResult;
use crate::config::ArchConfig;
use crate::fabric::stats::FabricStats;
use crate::workloads::{Built, Spec, Tiles};
use std::sync::Arc;

/// A workload compiled by (and executable on) one backend. Cheap to clone:
/// the artifact is shared behind an [`Arc`], which is how the compile cache
/// hands the same program to many executions.
#[derive(Clone)]
pub struct Compiled {
    workload: String,
    artifact: Arc<Artifact>,
}

impl Compiled {
    pub(crate) fn new(workload: String, artifact: Artifact) -> Self {
        Compiled {
            workload,
            artifact: Arc::new(artifact),
        }
    }

    /// Wrap an already-built fabric program (escape hatch for hand-built
    /// programs: the workload compilers' own tests, custom sweeps). The
    /// program must target the same [`ArchConfig`] as the machine that
    /// executes it.
    pub fn from_built(built: Built) -> Self {
        Compiled::new(built.name.clone(), Artifact::Program(Box::new(built)))
    }

    /// Display name of the workload this artifact computes.
    pub fn workload(&self) -> &str {
        &self.workload
    }

    /// Name of the underlying compiled program (fabric artifacts carry the
    /// compiler's program name, e.g. `spmspm-S1`; analytical artifacts fall
    /// back to the workload name).
    pub fn program_name(&self) -> &str {
        match self.artifact() {
            Artifact::Program(b) => &b.name,
            Artifact::Report(_) => &self.workload,
        }
    }

    pub(crate) fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Algorithmic useful operations of the compiled workload.
    pub fn work_ops(&self) -> u64 {
        match self.artifact() {
            Artifact::Program(b) => b.work_ops,
            Artifact::Report(r) => r.work_ops,
        }
    }

    /// Number of static AMs the compiler emitted across all tiles (0 for
    /// analytical artifacts, which have no AM program). Iterative workloads
    /// report tile 0's count.
    pub fn static_am_count(&self) -> usize {
        match self.artifact() {
            Artifact::Program(b) => match &b.tiles {
                Tiles::Static(tiles) => tiles.iter().map(|t| t.num_static_ams()).sum(),
                Tiles::Iterative { gen, .. } => gen(&[], 0).num_static_ams(),
            },
            Artifact::Report(_) => 0,
        }
    }

    /// Number of execution tiles (iterative workloads count iterations).
    pub fn tile_count(&self) -> usize {
        match self.artifact() {
            Artifact::Program(b) => match &b.tiles {
                Tiles::Static(tiles) => tiles.len(),
                Tiles::Iterative { iters, .. } => *iters,
            },
            Artifact::Report(_) => 1,
        }
    }

    /// Reference output the execution is validated against (fabric
    /// artifacts only).
    pub fn expected(&self) -> Option<&[i16]> {
        match self.artifact() {
            Artifact::Program(b) => Some(&b.expected),
            Artifact::Report(_) => None,
        }
    }
}

/// Outcome of one [`Machine::execute`]: the output tensor, the normalized
/// per-run report (cycles, utilization, congestion, energy events, the
/// validated flag), and — for fabric backends — the full cycle-accurate
/// counter set.
#[derive(Clone)]
pub struct Execution {
    /// Final outputs in the program's logical order (empty for analytical
    /// backends, which model timing but compute no values).
    pub outputs: Vec<i16>,
    /// Normalized per-run report, the unit the evaluation matrix collects.
    pub result: RunResult,
    /// Full cycle-accurate counters (fabric backends only).
    pub stats: Option<FabricStats>,
    /// Cycle-resolved trace events, present only when the executing
    /// machine's [`ArchConfig`] enabled tracing
    /// ([`crate::trace::TraceConfig`]) and the backend is cycle-accurate.
    /// Events are in deterministic epoch-merge order; export with
    /// [`crate::trace::chrome_trace_json`].
    pub trace: Option<Vec<crate::trace::Event>>,
}

impl Execution {
    pub fn cycles(&self) -> u64 {
        self.result.cycles
    }

    /// Useful operations per cycle.
    pub fn perf(&self) -> f64 {
        self.result.perf()
    }

    /// True when the outputs were checked against the software reference.
    pub fn validated(&self) -> bool {
        self.result.validated
    }
}

/// A reusable execution session for one architecture: a [`Backend`] plus a
/// bounded LRU compile cache. See the [module docs](self) for the API
/// shape.
pub struct Machine {
    backend: Box<dyn Backend>,
    cache: CompileCache<(String, u64)>,
}

impl Machine {
    /// A machine over the cycle-accurate fabric configured by `cfg`
    /// (Nexus / TIA / TIA-Valiant by [`crate::config::ArchKind`]).
    pub fn new(cfg: ArchConfig) -> Self {
        Self::from_backend(Box::new(FabricArch::from_config(cfg)))
    }

    /// A machine over any backend — fabric variants or the analytical
    /// systolic / Generic-CGRA models.
    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        Machine {
            backend,
            cache: CompileCache::new(DEFAULT_CACHE_CAPACITY),
        }
    }

    /// Replace the compile-cache capacity (builder form). The default
    /// ([`DEFAULT_CACHE_CAPACITY`]) is generous; long-running services
    /// that compile an open-ended stream of specs lower it to bound
    /// memory. Shrinking evicts least-recently-used artifacts, which
    /// recompile bit-identically on their next request.
    pub fn with_cache_capacity(mut self, capacity: usize) -> Self {
        self.cache.set_capacity(capacity);
        self
    }

    /// As [`Machine::with_cache_capacity`], in place.
    pub fn set_cache_capacity(&mut self, capacity: usize) {
        self.cache.set_capacity(capacity);
    }

    /// Roster name of the underlying architecture.
    pub fn name(&self) -> &'static str {
        self.backend.name()
    }

    /// Compile `spec` for this machine's architecture. Results are cached
    /// by (workload name, tensor-content fingerprint): recompiling the same
    /// workload instance returns the cached artifact, while equal-named
    /// specs with different data never collide.
    pub fn compile(&mut self, spec: &Spec) -> Result<Compiled, ExecError> {
        let key = (spec.name(), spec_fingerprint(spec));
        if let Some(c) = self.cache.get(&key) {
            return Ok(c);
        }
        let artifact = self.backend.compile(spec)?;
        let compiled = Compiled::new(key.0.clone(), artifact);
        self.cache.insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Execute a compiled artifact. Fabric machines reset (not reallocate)
    /// their fabric, run to drain, and validate outputs against the
    /// reference; analytical machines replay their model report.
    pub fn execute(&mut self, compiled: &Compiled) -> Result<Execution, ExecError> {
        self.backend.execute(compiled)
    }

    /// Compile-and-execute in one step (still hits the compile cache).
    pub fn run(&mut self, spec: &Spec) -> Result<Execution, ExecError> {
        let compiled = self.compile(spec)?;
        self.execute(&compiled)
    }

    /// Number of distinct programs held by the compile cache.
    pub fn cached_programs(&self) -> usize {
        self.cache.len()
    }
}

/// Order-sensitive FNV-1a content fingerprint of a spec's tensors — the
/// compile-cache key, so two specs that share a display name but carry
/// different data never alias each other's programs. Public because the
/// dataset scenario corpus reports it per scenario: equal fingerprints
/// guarantee a sweep re-hits the same cached program, and a fingerprint
/// drift across seeds/toolchains flags a generator determinism bug.
pub fn spec_fingerprint(spec: &Spec) -> u64 {
    struct Fp(u64);
    impl Fp {
        fn u(&mut self, v: u64) {
            self.0 = (self.0 ^ v).wrapping_mul(0x0000_0100_0000_01b3);
        }
        fn i16s(&mut self, v: &[i16]) {
            self.u(v.len() as u64);
            for &x in v {
                self.u(x as u16 as u64);
            }
        }
        fn idxs(&mut self, v: &[usize]) {
            self.u(v.len() as u64);
            for &x in v {
                self.u(x as u64);
            }
        }
        fn csr(&mut self, c: &crate::tensor::Csr) {
            self.u(c.rows as u64);
            self.u(c.cols as u64);
            self.idxs(&c.rowptr);
            self.idxs(&c.colidx);
            self.i16s(&c.values);
        }
        fn dense(&mut self, d: &crate::tensor::Dense) {
            self.u(d.rows as u64);
            self.u(d.cols as u64);
            self.i16s(&d.data);
        }
        fn graph(&mut self, g: &crate::tensor::Graph) {
            self.u(g.num_vertices as u64);
            for edges in &g.adj {
                self.u(edges.len() as u64);
                for &(v, w) in edges {
                    self.u(v as u64);
                    self.u(w as u16 as u64);
                }
            }
        }
    }
    let mut h = Fp(0xcbf2_9ce4_8422_2325);
    match spec {
        Spec::Spmv { a, x } => {
            h.u(1);
            h.csr(a);
            h.i16s(x);
        }
        Spec::SpMSpM { a, b, regime } => {
            h.u(2);
            h.csr(a);
            h.csr(b);
            for byte in regime.name().bytes() {
                h.u(byte as u64);
            }
        }
        Spec::SpAdd { a, b } => {
            h.u(3);
            h.csr(a);
            h.csr(b);
        }
        Spec::Sddmm { mask, a, b } => {
            h.u(4);
            h.csr(mask);
            h.dense(a);
            h.dense(b);
        }
        Spec::MatMul { a, b } => {
            h.u(5);
            h.dense(a);
            h.dense(b);
        }
        Spec::Mv { a, x } => {
            h.u(6);
            h.dense(a);
            h.i16s(x);
        }
        Spec::Conv { input, filter } => {
            h.u(7);
            h.dense(input);
            h.dense(filter);
        }
        Spec::Bfs { g, src } => {
            h.u(8);
            h.graph(g);
            h.u(*src as u64);
        }
        Spec::Sssp { g, src } => {
            h.u(9);
            h.graph(g);
            h.u(*src as u64);
        }
        Spec::PageRank { g, iters } => {
            h.u(10);
            h.graph(g);
            h.u(*iters as u64);
        }
    }
    h.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::Message;
    use crate::compiler::ProgramBuilder;
    use crate::isa::{ConfigEntry, Opcode};
    use crate::workloads::suite;

    /// One static AM that stores `val` at a remote PE, as a `Built`.
    fn store_built(cfg: &ArchConfig, val: i16, expected: Vec<i16>) -> Built {
        let mut b = ProgramBuilder::new("store1", cfg);
        let addr = b.alloc(15, 1);
        let mut am = Message::new();
        am.opcode = Opcode::Store;
        am.op1 = val as u16;
        am.result = addr;
        am.res_is_addr = true;
        am.push_dest(15);
        b.static_am(0, am);
        b.output(15, addr);
        Built {
            name: "store1".into(),
            tiles: Tiles::Static(vec![b.build()]),
            expected,
            work_ops: 1,
        }
    }

    #[test]
    fn execute_validates_and_returns_outputs() {
        let cfg = ArchConfig::nexus();
        let built = store_built(&cfg, -7, vec![-7]);
        let mut m = Machine::new(cfg);
        let e = m.execute(&Compiled::from_built(built)).unwrap();
        assert_eq!(e.outputs, vec![-7]);
        assert!(e.validated());
        assert!(e.cycles() > 0);
        assert!(e.stats.is_some());
    }

    #[test]
    fn validation_mismatch_is_typed() {
        let cfg = ArchConfig::nexus();
        let built = store_built(&cfg, -7, vec![9]);
        let mut m = Machine::new(cfg);
        match m.execute(&Compiled::from_built(built)) {
            Err(ExecError::ValidationMismatch {
                index,
                got,
                expected,
            }) => {
                assert_eq!((index, got, expected), (0, -7, 9));
            }
            other => panic!("expected ValidationMismatch, got {:?}", other.err()),
        }
    }

    #[test]
    fn output_length_mismatch_is_typed() {
        let cfg = ArchConfig::nexus();
        let built = store_built(&cfg, 1, vec![1, 2]);
        let mut m = Machine::new(cfg);
        assert!(matches!(
            m.execute(&Compiled::from_built(built)),
            Err(ExecError::OutputLength {
                got: 1,
                expected: 2
            })
        ));
    }

    #[test]
    fn deadlock_surfaces_as_err_not_panic() {
        // A config chain that self-loops (MUL whose next entry is itself)
        // never becomes terminal: `execute` must return the typed error.
        let mut cfg = ArchConfig::nexus();
        cfg.max_cycles = 500;
        let mut b = ProgramBuilder::new("livelock", &cfg);
        let pc = b.config(ConfigEntry::new(Opcode::Mul, 0));
        let mut am = Message::new();
        am.opcode = Opcode::Mul;
        am.n_pc = pc;
        am.op1 = 1;
        am.op2 = 1;
        am.push_dest(15);
        b.static_am(0, am);
        let built = Built {
            name: "livelock".into(),
            tiles: Tiles::Static(vec![b.build()]),
            expected: Vec::new(),
            work_ops: 0,
        };
        let mut m = Machine::new(cfg);
        match m.execute(&Compiled::from_built(built)) {
            Err(ExecError::Deadlock(e)) => assert!(e.in_flight >= 1),
            other => panic!("expected Deadlock, got {:?}", other.err()),
        }
    }

    #[test]
    fn cross_config_artifact_is_a_typed_error() {
        // A program compiled for the 4x4 fabric executed on an 8x8 machine
        // must surface as IncompatibleProgram, not a panic.
        let nexus = ArchConfig::nexus();
        let built = store_built(&nexus, 1, vec![1]);
        let mut big = Machine::new(ArchConfig::nexus().with_array(8, 8));
        match big.execute(&Compiled::from_built(built)) {
            Err(ExecError::IncompatibleProgram { reason }) => {
                assert!(!reason.is_empty());
            }
            other => panic!("expected IncompatibleProgram, got {:?}", other.err()),
        }
    }

    #[test]
    fn compile_cache_returns_shared_artifact() {
        let specs = suite(1);
        let spmv = specs.iter().find(|s| s.name().starts_with("SpMV")).unwrap();
        let mut m = Machine::new(ArchConfig::nexus());
        let a = m.compile(spmv).unwrap();
        let b = m.compile(spmv).unwrap();
        assert!(Arc::ptr_eq(&a.artifact, &b.artifact), "second compile must hit the cache");
        assert_eq!(m.cached_programs(), 1);
        // And the cached artifact executes fine, twice.
        m.execute(&a).unwrap();
        m.execute(&b).unwrap();
    }

    #[test]
    fn compile_cache_distinguishes_same_name_different_data() {
        // Two SpMV instances with the same matrix (same display name, same
        // work-ops) but different vectors must not alias in the cache: the
        // second run has to compute A*x2, not replay A*x1.
        let mut rng = crate::util::SplitMix64::new(77);
        let a = crate::tensor::gen::random_csr(&mut rng, 16, 16, 0.3);
        let x1 = crate::tensor::gen::random_vec(&mut rng, 16, 3);
        let mut x2 = x1.clone();
        x2[0] = x2[0].wrapping_add(1);
        let mut m = Machine::new(ArchConfig::nexus());
        let e1 = m.run(&Spec::Spmv { a: a.clone(), x: x1.clone() }).unwrap();
        let e2 = m.run(&Spec::Spmv { a: a.clone(), x: x2.clone() }).unwrap();
        assert_eq!(m.cached_programs(), 2, "distinct data must compile twice");
        assert_eq!(e1.outputs, a.spmv(&x1));
        assert_eq!(e2.outputs, a.spmv(&x2));
    }

    #[test]
    fn step_modes_are_bit_identical_through_machine() {
        use crate::config::StepMode;
        let specs = suite(1);
        let spmv = specs.iter().find(|s| s.name().starts_with("SpMV")).unwrap();
        let mut active = Machine::new(ArchConfig::nexus());
        let mut dense = Machine::new(ArchConfig::nexus().with_step_mode(StepMode::DenseOracle));
        let ea = active.run(spmv).unwrap();
        let ed = dense.run(spmv).unwrap();
        assert_eq!(ea.outputs, ed.outputs);
        assert_eq!(ea.cycles(), ed.cycles());
        assert_eq!(ea.stats, ed.stats, "full counter set must match");
    }

    #[test]
    fn static_am_count_matches_program() {
        let specs = suite(1);
        let spmv = specs.iter().find(|s| s.name().starts_with("SpMV")).unwrap();
        let mut m = Machine::new(ArchConfig::nexus());
        let c = m.compile(spmv).unwrap();
        assert!(c.static_am_count() > 0);
        assert!(c.tile_count() >= 1);
        assert!(c.work_ops() > 0);
        assert!(c.expected().is_some());
    }
}
