//! Sparse metadata scanner (§3.3.4): bit-vector hardware that assists
//! "efficient iteration over sparse data, providing coordinates within
//! compressed vectors" (after Capstan \[42\]). The paper's unit decodes
//! "vectors of 16 non-zeros and more within 128 elements", i.e. it
//! handles densities above 16/128 = 12.5% at full rate.
//!
//! The compile path uses this model to turn bit-vector-encoded rows into
//! stream-element coordinate lists, and the fabric charges one
//! `scanner_op` per decoded element; [`ScanCost`] exposes the cycle cost
//! a real scanner would add so the energy model and docs stay honest.

/// Scanner block parameters (§3.3.4).
pub const SCAN_WINDOW: usize = 128;
/// Coordinates extracted per window pass at full rate.
pub const SCAN_RATE: usize = 16;

/// A bit-vector-encoded sparse row: one bit per column, plus the packed
/// nonzero values in column order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitVecRow {
    pub cols: usize,
    /// Bit i set iff column i holds a nonzero.
    pub bits: Vec<u64>,
    /// Values of the set bits, in ascending column order.
    pub values: Vec<i16>,
}

impl BitVecRow {
    /// Encode a (column, value) list (columns strictly ascending).
    pub fn encode(cols: usize, entries: &[(usize, i16)]) -> Self {
        let mut bits = vec![0u64; cols.div_ceil(64)];
        let mut values = Vec::with_capacity(entries.len());
        let mut prev = None;
        for &(c, v) in entries {
            assert!(c < cols, "column out of range");
            assert!(prev.map_or(true, |p| c > p), "columns must ascend");
            prev = Some(c);
            bits[c / 64] |= 1 << (c % 64);
            values.push(v);
        }
        BitVecRow { cols, bits, values }
    }

    /// Number of nonzeros (population count).
    pub fn nnz(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Density (nnz / cols).
    pub fn density(&self) -> f64 {
        if self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.cols as f64
        }
    }

    /// Storage footprint in 16-bit words (bit mask + values) — the reason
    /// bit-vector beats coordinate lists above ~6% density.
    pub fn words(&self) -> usize {
        self.cols.div_ceil(16) + self.values.len()
    }
}

/// Decoded coordinate stream + the cycle cost the scanner hardware spends.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScanOut {
    /// (column, value) pairs in ascending column order.
    pub coords: Vec<(u16, i16)>,
    pub cost: ScanCost,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ScanCost {
    /// Window passes over the bit vector.
    pub passes: u64,
    /// Total scanner cycles: each pass extracts up to [`SCAN_RATE`]
    /// coordinates per [`SCAN_WINDOW`]-bit window.
    pub cycles: u64,
}

/// Decode a bit-vector row into its coordinate stream, modeling the
/// windowed scanner: each pass covers [`SCAN_WINDOW`] bits and emits up to
/// [`SCAN_RATE`] coordinates; denser windows need extra passes (the >12%
/// densities of §3.3.4 take one extra pass per additional 16 nonzeros).
pub fn scan(row: &BitVecRow) -> ScanOut {
    let mut coords = Vec::with_capacity(row.values.len());
    let mut vi = 0usize;
    let mut cost = ScanCost::default();
    let mut window_start = 0usize;
    while window_start < row.cols {
        let window_end = (window_start + SCAN_WINDOW).min(row.cols);
        let mut in_window = 0usize;
        for c in window_start..window_end {
            if row.bits[c / 64] >> (c % 64) & 1 == 1 {
                coords.push((c as u16, row.values[vi]));
                vi += 1;
                in_window += 1;
            }
        }
        // One pass per SCAN_RATE coordinates (minimum one per window).
        let passes = in_window.div_ceil(SCAN_RATE).max(1) as u64;
        cost.passes += passes;
        cost.cycles += passes;
        window_start = window_end;
    }
    debug_assert_eq!(vi, row.values.len(), "value stream exhausted");
    ScanOut { coords, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn roundtrip_encode_scan() {
        let entries = vec![(0usize, 5i16), (3, -2), (63, 7), (64, 1), (127, -9)];
        let row = BitVecRow::encode(128, &entries);
        assert_eq!(row.nnz(), 5);
        let out = scan(&row);
        let got: Vec<(usize, i16)> = out.coords.iter().map(|&(c, v)| (c as usize, v)).collect();
        assert_eq!(got, entries);
        assert_eq!(out.cost.passes, 1, "5 nnz in one 128-bit window");
    }

    #[test]
    fn dense_windows_need_extra_passes() {
        // 40 nonzeros in one 128-element window: ceil(40/16) = 3 passes.
        let entries: Vec<(usize, i16)> = (0..40).map(|c| (c * 3, 1i16)).collect();
        let row = BitVecRow::encode(128, &entries);
        assert!(row.density() > 0.125, "above the §3.3.4 rate point");
        let out = scan(&row);
        assert_eq!(out.cost.passes, 3);
        assert_eq!(out.coords.len(), 40);
    }

    #[test]
    fn scan_property_roundtrip_and_cost_bounds() {
        forall(100, |rng| {
            let cols = 1 + rng.below_usize(512);
            let mut entries = Vec::new();
            for c in 0..cols {
                if rng.chance(0.2) {
                    entries.push((c, rng.range_i64(-9, 9) as i16));
                }
            }
            let row = BitVecRow::encode(cols, &entries);
            let out = scan(&row);
            ensure(out.coords.len() == entries.len(), || "count".into())?;
            for (&(c, v), &(ec, ev)) in out.coords.iter().zip(&entries) {
                ensure(c as usize == ec && v == ev, || "coord mismatch".into())?;
            }
            // Cost bounds: at least one pass per window, at most one per
            // SCAN_RATE coords plus one per window.
            let windows = cols.div_ceil(SCAN_WINDOW) as u64;
            let max = windows + (entries.len() as u64).div_ceil(SCAN_RATE as u64);
            ensure(out.cost.passes >= windows, || "too few passes".into())?;
            ensure(out.cost.passes <= max, || {
                format!("too many passes: {} > {max}", out.cost.passes)
            })
        });
    }

    #[test]
    fn bitvector_beats_coordinates_above_six_percent() {
        // Storage crossover: coordinate list = 2 words/nnz; bit vector =
        // cols/16 + 1 word/nnz.
        let cols = 128;
        for density_pct in [3usize, 12, 50] {
            let nnz = cols * density_pct / 100;
            let entries: Vec<(usize, i16)> = (0..nnz).map(|i| (i * cols / nnz.max(1), 1)).collect();
            let row = BitVecRow::encode(cols, &entries);
            let coord_words = 2 * nnz;
            if density_pct >= 12 {
                assert!(row.words() <= coord_words, "bitvec should win at {density_pct}%");
            }
        }
    }
}
