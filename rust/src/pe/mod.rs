//! Processing Element state (§3.3.1, Fig 8b): data memory, decode unit with
//! dereference + streaming modes, Input Network Interface (inbox), and the
//! AM Network Interface (AM-queue window + dynamic-AM output queue).
//!
//! The PE's per-cycle *behaviour* lives in `fabric/mod.rs` (it needs
//! whole-fabric context: router buffers for en-route claims, the replicated
//! config memory, global stats); this module owns the per-PE data.

pub mod scanner;

use crate::am::Message;
use std::collections::VecDeque;

/// Emission mode of a stream element — how the decode unit assembles the
/// outgoing dynamic AM from the element record and the triggering message.
/// See `fabric::NexusFabric::start_stream` for the exact field mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamMode {
    /// SpMSpM-style (Gustavson): `result = msg.result + aux` (output row
    /// base + column index), `op2 = value`, destinations inherited from the
    /// triggering message.
    OffsetResult,
    /// Graph-style (BFS/SSSP/PageRank/Conv): each element names its own
    /// destination PE and address: `dests = [dest_pe]`, `result = aux`,
    /// `op2 = value`.
    PerDest,
    /// SDDMM-style: `op1 = msg.op1 + aux` becomes an *address* into the next
    /// destination's memory (dense A-row base + k), `op2 = value`,
    /// `result = msg.result`, destinations inherited.
    OffsetOp1,
}

/// One element record walked by the decode unit's streaming mode. In
/// hardware these are (value, metadata) pairs in the PE's SRAM decoded with
/// scanner assistance (§3.3.4); the simulator stores them unpacked.
/// Capacity accounting charges [`STREAM_ELEM_WORDS`] SRAM words per element.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamElem {
    /// Data word (INT16 fabric value).
    pub value: i16,
    /// Mode-dependent metadata: column index, target address, …
    pub aux: u16,
    /// Destination PE for `PerDest` mode (ignored otherwise).
    pub dest_pe: u16,
    pub mode: StreamMode,
}

/// SRAM words charged per stream element (value + aux + packed pe/mode).
pub const STREAM_ELEM_WORDS: usize = 3;

/// An in-progress streaming decode (§3.3.1 streaming mode): walks
/// `count` elements from `base`, emitting one dynamic AM per cycle.
#[derive(Debug, Clone)]
pub struct ActiveStream {
    /// Start index into `stream_mem`.
    pub base: u32,
    /// Elements remaining.
    pub remaining: u16,
    /// Current position (index into `stream_mem`).
    pub pos: u32,
    /// The triggering message after config advance: supplies carried fields
    /// (op1, result, remaining destinations) and the opcode/flags/PC that
    /// every emitted AM starts with.
    pub template: Message,
}

/// Capacity of the dynamic-AM output queue in the AM NIC. Small, as in the
/// paper's NIC (the backpressure it exerts on the decode unit is part of
/// the flow-control story).
pub const OUTQ_CAP: usize = 4;

/// Per-PE statistics (fabric utilization, load-balance heatmaps).
#[derive(Debug, Clone, Copy, Default)]
pub struct PeStats {
    /// Cycles the PE did useful work on any unit (ALU op local or en-route,
    /// decode-unit memory op, or stream emission) — Fig 13's utilization
    /// numerator.
    pub busy_cycles: u64,
    /// Cycles the ALU performed an operation (local or en-route claimed).
    pub alu_busy_cycles: u64,
    /// ALU operations executed for messages in transit (en-route).
    pub enroute_ops: u64,
    /// Memory operations (loads/stores/accumulates) performed locally.
    pub mem_ops: u64,
    /// Dynamic AMs emitted by streaming decode.
    pub stream_emissions: u64,
    /// Static AMs injected from this PE's AM queue.
    pub static_injected: u64,
    /// Data-memory reads/writes (energy accounting).
    pub dmem_reads: u64,
    pub dmem_writes: u64,
    /// Config-memory reads (every morph/advance reads one entry).
    pub config_reads: u64,
}

/// Processing element state.
#[derive(Debug, Clone)]
pub struct Pe {
    /// Data memory (u16 words; Table 1: 1KB = 512 words).
    pub dmem: Vec<u16>,
    /// Stream element records (charged against the same SRAM budget).
    pub stream_mem: Vec<StreamElem>,
    /// Trigger table: maps a dmem address to a (base, count) stream descriptor.
    /// Used by `Stream` ops (keyed by op2) and by `AccMin` conditional
    /// re-emission (keyed by result). Sparse; None for non-trigger addresses.
    pub trigger: Vec<Option<(u32, u16)>>,
    /// Input Network Interface: single-message inbox from the router's
    /// LOCAL output port.
    pub inbox: Option<Message>,
    /// Message whose next (local) operation executes next cycle — the
    /// decode/ALU handoff inside a PE.
    pub local_redo: Option<Message>,
    /// TIA trigger-scheduler countdown before `inbox` may be processed.
    pub trigger_wait: u64,
    /// AM NIC: dynamic AMs awaiting injection.
    pub outq: VecDeque<Message>,
    /// AM NIC: on-chip window of the static-AM queue (refilled from
    /// "off-chip" by the AXI model).
    pub am_window: VecDeque<Message>,
    /// Active streaming decode, if any.
    pub stream: Option<ActiveStream>,
    /// Streams waiting for the stream engine (a second `Stream` trigger or
    /// an `AccMin` re-emission arriving while one is active). Draining the
    /// inbox every cycle — instead of stalling it on a busy stream engine —
    /// keeps the ejection port live and breaks the NIC↔stream-engine
    /// deadlock cycle (§3.4 scenario 3).
    pub stream_q: VecDeque<ActiveStream>,
    /// ALU claimed this cycle (local work or en-route execution).
    pub alu_busy: bool,
    /// Decode unit performed a memory op or stream emission this cycle.
    pub decode_busy: bool,
    /// Cycle of this PE's last en-route claim (`None` = never). Read by
    /// [`crate::config::ClaimPolicy::CreditBased`]; written only at claim
    /// events so both step modes observe identical policy state.
    pub last_claim_cycle: Option<u64>,
    pub stats: PeStats,
}

impl Pe {
    pub fn new(dmem_words: usize) -> Self {
        Pe {
            dmem: vec![0; dmem_words],
            stream_mem: Vec::new(),
            trigger: Vec::new(),
            inbox: None,
            local_redo: None,
            trigger_wait: 0,
            outq: VecDeque::with_capacity(OUTQ_CAP),
            am_window: VecDeque::new(),
            stream: None,
            stream_q: VecDeque::new(),
            alu_busy: false,
            decode_busy: false,
            last_claim_cycle: None,
            stats: PeStats::default(),
        }
    }

    /// Messages currently held by this PE (for termination/conservation).
    pub fn held_messages(&self) -> usize {
        usize::from(self.inbox.is_some())
            + usize::from(self.local_redo.is_some())
            + usize::from(self.stream.is_some())
            + self.stream_q.len()
            + self.outq.len()
    }

    /// True when the PE has no pending work at all (termination detector
    /// input; the AM window is tracked separately by the fabric).
    pub fn is_idle(&self) -> bool {
        self.held_messages() == 0 && self.am_window.is_empty()
    }

    /// True when the PE's per-cycle phase would do *anything*: it holds a
    /// message anywhere, has static AMs windowed on-chip, or its trigger
    /// scheduler is still cooling down. This is the wake-list residency
    /// predicate for [`crate::config::StepMode::ActiveSet`] stepping — a PE
    /// for which this is false is skipped by the scheduler, which is safe
    /// exactly because `fabric::NexusFabric::pe_phase` is a no-op on it.
    /// Unlike [`Pe::is_idle`], a `trigger_wait` cooldown counts as work
    /// (the countdown must tick every cycle).
    #[inline]
    pub fn has_pending_work(&self) -> bool {
        self.local_redo.is_some()
            || self.inbox.is_some()
            || self.trigger_wait > 0
            || self.stream.is_some()
            || !self.stream_q.is_empty()
            || !self.outq.is_empty()
            || !self.am_window.is_empty()
    }

    /// SRAM words used by the loaded image (capacity checks, Fig 16).
    pub fn sram_words_used(&self) -> usize {
        self.dmem.len() + self.stream_mem.len() * STREAM_ELEM_WORDS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_pe_is_idle() {
        let pe = Pe::new(512);
        assert!(pe.is_idle());
        assert!(!pe.has_pending_work());
        assert_eq!(pe.held_messages(), 0);
        assert_eq!(pe.dmem.len(), 512);
    }

    #[test]
    fn trigger_cooldown_is_pending_work_but_not_held() {
        // A PE whose only activity is the TIA trigger-scheduler countdown is
        // "idle" for the termination detector but must stay on the wake-list
        // so the countdown ticks.
        let mut pe = Pe::new(16);
        pe.trigger_wait = 2;
        assert!(pe.is_idle());
        assert!(pe.has_pending_work());
        pe.trigger_wait = 0;
        assert!(!pe.has_pending_work());
        pe.am_window.push_back(Message::new());
        assert!(pe.has_pending_work());
    }

    #[test]
    fn held_messages_counts_all_stations() {
        let mut pe = Pe::new(16);
        pe.inbox = Some(Message::new());
        pe.outq.push_back(Message::new());
        pe.stream = Some(ActiveStream {
            base: 0,
            remaining: 1,
            pos: 0,
            template: Message::new(),
        });
        assert_eq!(pe.held_messages(), 3);
        assert!(!pe.is_idle());
    }

    #[test]
    fn sram_accounting_includes_stream_elems() {
        let mut pe = Pe::new(100);
        pe.stream_mem = vec![
            StreamElem {
                value: 0,
                aux: 0,
                dest_pe: 0,
                mode: StreamMode::PerDest,
            };
            10
        ];
        assert_eq!(pe.sram_words_used(), 100 + 30);
    }
}
