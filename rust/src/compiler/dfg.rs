//! Dataflow-graph construction and ASAP scheduling (§3.6: "a custom pass
//! builds a DFG by identifying instruction dependencies and backedges. The
//! DFG is scheduled using ASAP ordering").
//!
//! The DFG serves two purposes in this repository:
//!
//! 1. It produces the per-workload configuration-memory chains (the opcodes
//!    the morphing dynamic AMs step through).
//! 2. It feeds the *Generic CGRA* baseline's modulo-scheduling model
//!    ([`crate::baselines::cgra`]): the initiation interval II is bounded
//!    below by `ceil(ops / PEs)` (resource bound) and by the longest cycle
//!    through backedges (recurrence bound).

use crate::isa::Opcode;

/// A DFG node: one instruction of the loop body.
#[derive(Debug, Clone)]
pub struct Node {
    pub op: Opcode,
    /// Human-readable tag for dumps ("load vec\[col\]").
    pub tag: &'static str,
    /// Indices of predecessor nodes (dataflow dependencies).
    pub preds: Vec<usize>,
    /// True if this node is a memory access (occupies a memory port in the
    /// CGRA model and contributes to the bank-conflict trace).
    pub is_mem: bool,
}

/// A loop-body dataflow graph with optional inter-iteration backedges.
#[derive(Debug, Clone, Default)]
pub struct Dfg {
    pub nodes: Vec<Node>,
    /// Backedges (from, to): value produced by `from` in iteration i is
    /// consumed by `to` in iteration i+1 (e.g. an accumulator).
    pub backedges: Vec<(usize, usize)>,
}

impl Dfg {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a node; returns its index.
    pub fn node(&mut self, op: Opcode, tag: &'static str, preds: &[usize]) -> usize {
        for &p in preds {
            assert!(p < self.nodes.len(), "pred out of range");
        }
        self.nodes.push(Node {
            op,
            tag,
            preds: preds.to_vec(),
            is_mem: op.is_memory(),
        });
        self.nodes.len() - 1
    }

    pub fn backedge(&mut self, from: usize, to: usize) {
        assert!(from < self.nodes.len() && to < self.nodes.len());
        self.backedges.push((from, to));
    }

    /// ASAP schedule: level of each node = 1 + max(level of preds), with
    /// sources at level 0. Backedges are excluded (they cross iterations).
    pub fn asap(&self) -> Vec<usize> {
        let mut level = vec![0usize; self.nodes.len()];
        // Nodes are appended in dependency order (preds < index), so one
        // forward pass suffices.
        for (i, n) in self.nodes.iter().enumerate() {
            level[i] = n.preds.iter().map(|&p| level[p] + 1).max().unwrap_or(0);
        }
        level
    }

    /// Critical-path length in cycles (depth of the ASAP schedule).
    pub fn depth(&self) -> usize {
        self.asap().into_iter().max().map_or(0, |d| d + 1)
    }

    /// Number of memory-class nodes per iteration.
    pub fn mem_ops(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_mem).count()
    }

    /// Resource-bound initiation interval on `pes` processing elements:
    /// `ceil(|nodes| / pes)` (each PE issues one op per II window).
    pub fn res_mii(&self, pes: usize) -> usize {
        crate::util::ceil_div(self.nodes.len(), pes.max(1)).max(1)
    }

    /// Recurrence-bound II: the longest dependence cycle through a backedge,
    /// computed as `asap(from) - asap(to) + 1` per backedge (distance-1
    /// recurrences, which is all our kernels have).
    pub fn rec_mii(&self) -> usize {
        let asap = self.asap();
        self.backedges
            .iter()
            .map(|&(from, to)| asap[from].saturating_sub(asap[to]) + 1)
            .max()
            .unwrap_or(1)
    }

    /// Modulo-scheduling II estimate: max of resource and recurrence bounds.
    pub fn mii(&self, pes: usize) -> usize {
        self.res_mii(pes).max(self.rec_mii())
    }
}

/// The SpMV loop body of Fig 4(a): load col, load vec\[col\], load matrix
/// value, multiply, accumulate into output (recurrence on the accumulator).
pub fn spmv_dfg() -> Dfg {
    let mut g = Dfg::new();
    let col = g.node(Opcode::Load, "load col[k]", &[]);
    let mval = g.node(Opcode::Load, "load matrix[k]", &[]);
    let vec = g.node(Opcode::Load, "load vec[col]", &[col]);
    let mul = g.node(Opcode::Mul, "matrix * vec", &[mval, vec]);
    let acc = g.node(Opcode::Accum, "output[row] +=", &[mul]);
    g.backedge(acc, acc);
    g
}

/// Gustavson SpMSpM inner body: load A value + B row element, multiply,
/// accumulate into the output row accumulator.
pub fn spmspm_dfg() -> Dfg {
    let mut g = Dfg::new();
    let a = g.node(Opcode::Load, "load A[i,k]", &[]);
    let bcol = g.node(Opcode::Load, "load B.col[p]", &[]);
    let bval = g.node(Opcode::Load, "load B.val[p]", &[bcol]);
    let mul = g.node(Opcode::Mul, "A*B", &[a, bval]);
    let acc = g.node(Opcode::Accum, "C[i,j] +=", &[mul, bcol]);
    g.backedge(acc, acc);
    g
}

/// SpM+SpM body: two loads and a store per merged element.
pub fn spadd_dfg() -> Dfg {
    let mut g = Dfg::new();
    let a = g.node(Opcode::Load, "load A[k]", &[]);
    let b = g.node(Opcode::Load, "load B[k]", &[]);
    let s = g.node(Opcode::Add, "A+B", &[a, b]);
    g.node(Opcode::Store, "store C", &[s]);
    g
}

/// SDDMM inner body: load mask coordinate, stream A row and B column,
/// multiply-accumulate the dot product.
pub fn sddmm_dfg() -> Dfg {
    let mut g = Dfg::new();
    let a = g.node(Opcode::Load, "load A[i,k]", &[]);
    let b = g.node(Opcode::Load, "load B[k,j]", &[a]);
    let mul = g.node(Opcode::Mul, "A*B", &[a, b]);
    let acc = g.node(Opcode::Accum, "dot +=", &[mul]);
    g.backedge(acc, acc);
    g
}

/// Dense MatMul/MV inner body.
pub fn matmul_dfg() -> Dfg {
    let mut g = Dfg::new();
    let a = g.node(Opcode::Load, "load A[i,k]", &[]);
    let b = g.node(Opcode::Load, "load B[k,j]", &[]);
    let mul = g.node(Opcode::Mul, "A*B", &[a, b]);
    let acc = g.node(Opcode::Accum, "C[i,j] +=", &[mul]);
    g.backedge(acc, acc);
    g
}

/// Conv body (per tap): load pixel, multiply by filter coefficient,
/// accumulate into the output pixel.
pub fn conv_dfg() -> Dfg {
    let mut g = Dfg::new();
    let x = g.node(Opcode::Load, "load in[h+i,w+j]", &[]);
    let f = g.node(Opcode::Load, "load f[i,j]", &[]);
    let mul = g.node(Opcode::Mul, "x*f", &[x, f]);
    let acc = g.node(Opcode::Accum, "out[h,w] +=", &[mul]);
    g.backedge(acc, acc);
    g
}

/// Graph relaxation body (BFS/SSSP): load neighbor distance, add weight,
/// conditional min-update (recurrence through the distance array).
pub fn relax_dfg() -> Dfg {
    let mut g = Dfg::new();
    let d = g.node(Opcode::Load, "load dist[u]", &[]);
    let w = g.node(Opcode::Load, "load w(u,v)", &[]);
    let nd = g.node(Opcode::Add, "dist+w", &[d, w]);
    let upd = g.node(Opcode::AccMin, "min-update dist[v]", &[nd]);
    g.backedge(upd, d);
    g
}

/// PageRank body: load rank, divide by degree, accumulate into `next[v]`.
pub fn pagerank_dfg() -> Dfg {
    let mut g = Dfg::new();
    let r = g.node(Opcode::Load, "load rank[u]", &[]);
    let d = g.node(Opcode::Load, "load 2*deg[u]", &[]);
    let c = g.node(Opcode::Div, "rank/2deg", &[r, d]);
    let acc = g.node(Opcode::Accum, "next[v] +=", &[c]);
    g.backedge(acc, acc);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asap_levels_respect_dependencies() {
        let g = spmv_dfg();
        let asap = g.asap();
        for (i, n) in g.nodes.iter().enumerate() {
            for &p in &n.preds {
                assert!(asap[i] > asap[p], "node {i} not after pred {p}");
            }
        }
    }

    #[test]
    fn spmv_depth_matches_hand_count() {
        // col -> vec -> mul -> acc is the longest chain: depth 4.
        assert_eq!(spmv_dfg().depth(), 4);
    }

    #[test]
    fn mii_bounds() {
        let g = spmv_dfg();
        // 5 nodes on 16 PEs: resource bound 1; accumulator recurrence 1.
        assert_eq!(g.mii(16), 1);
        // 5 nodes on 2 PEs: resource bound ceil(5/2)=3.
        assert_eq!(g.mii(2), 3);
    }

    #[test]
    fn all_kernel_dfgs_are_well_formed() {
        for g in [
            spmv_dfg(),
            spmspm_dfg(),
            spadd_dfg(),
            sddmm_dfg(),
            matmul_dfg(),
            conv_dfg(),
            relax_dfg(),
            pagerank_dfg(),
        ] {
            assert!(!g.nodes.is_empty());
            assert!(g.depth() >= 1);
            assert!(g.mem_ops() >= 1);
            assert!(g.mii(16) >= 1);
            // preds must precede their consumers (append order invariant).
            for (i, n) in g.nodes.iter().enumerate() {
                assert!(n.preds.iter().all(|&p| p < i));
            }
        }
    }

    #[test]
    fn recurrence_raises_mii() {
        let mut g = Dfg::new();
        let a = g.node(Opcode::Load, "a", &[]);
        let b = g.node(Opcode::Add, "b", &[a]);
        let c = g.node(Opcode::Add, "c", &[b]);
        g.backedge(c, a);
        // Cycle spans levels 0..2 => rec MII = 3.
        assert_eq!(g.rec_mii(), 3);
        assert_eq!(g.mii(16), 3);
    }
}
