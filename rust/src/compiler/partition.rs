//! Data partitioning (§3.1.1 and §3.6, Algorithm 1).
//!
//! Three row → PE strategies, selectable at compile time via
//! [`PlacementPolicy`] (see [`place_rows`]):
//!
//! - **nnz-balanced row partitioning**: split a CSR matrix's rows into `N`
//!   contiguous groups such that each group holds ≈ `nnz/N` nonzeros,
//!   computed "via a linear scan of the row pointer array, with complexity
//!   O(m)" (§3.1.1).
//! - **dissimilarity-aware mapping** (Algorithm 1): rows are described by the
//!   set of memory banks their column indices touch; rows with *similar*
//!   bank sets cluster onto the same PE (their accesses serialize locally
//!   instead of contending), while dissimilar rows spread out. We implement
//!   the clustering step greedily: seeds are picked far apart by bank-set
//!   distance, rows join the nearest under-capacity cluster. The default.
//! - **hotspot splitting** ([`hotspot_split`]): greedy LPT scheduling of
//!   rows by descending nnz onto the lightest PE, spreading heavy rows
//!   (power-law hubs, hotspot blocks) across the fabric — the degree-aware
//!   placement DCRA uses for irregular applications.
//!
//! Dense 1-D tensors are partitioned into contiguous equal blocks aligned
//!   with the matrix partition ("Y and Z are partitioned correspondingly").

use crate::config::PlacementPolicy;
use crate::tensor::Csr;

/// Row → PE mapping under the selected [`PlacementPolicy`]. `banks` feeds
/// the dissimilarity policy's bank-set signatures and is ignored by the
/// other two.
pub fn place_rows(m: &Csr, parts: usize, banks: usize, policy: PlacementPolicy) -> Vec<usize> {
    match policy {
        PlacementPolicy::NnzBalanced => nnz_balanced(m, parts),
        PlacementPolicy::DissimilarityAware => dissimilarity_aware(m, parts, banks),
        PlacementPolicy::HotspotSplit => hotspot_split(m, parts),
    }
}

/// Contiguous nnz-balanced row partition: returns `part[r] in [0, parts)`,
/// non-decreasing in `r`, with each part's nonzero total ≈ `nnz/parts`.
pub fn nnz_balanced(m: &Csr, parts: usize) -> Vec<usize> {
    assert!(parts > 0);
    let total = m.nnz();
    let mut part = vec![0usize; m.rows];
    let mut p = 0usize;
    let mut acc = 0usize;
    // Ideal cumulative boundary after part p is (p+1) * total / parts.
    for r in 0..m.rows {
        // Advance to the next part when we've met this part's quota and
        // there are still parts left for the remaining rows.
        let quota_met = acc * parts >= (p + 1) * total;
        let rows_left = m.rows - r;
        let parts_left = parts - p;
        if (quota_met || rows_left == parts_left) && p + 1 < parts && rows_left > 1 {
            // only advance if remaining rows can still cover remaining parts
            if quota_met || rows_left <= parts_left {
                p += 1;
            }
        }
        part[r] = p;
        acc += m.row_nnz(r);
    }
    part
}

/// Bank-set signature of a row: bit `b` set iff the row touches bank `b`
/// (column index modulo `banks`, the usual low-order interleave).
fn bank_set(m: &Csr, r: usize, banks: usize) -> u64 {
    debug_assert!(banks <= 64);
    let mut s = 0u64;
    for (c, _) in m.row(r) {
        s |= 1 << (c % banks);
    }
    s
}

/// Symmetric-difference distance between two bank sets (Algorithm 1,
/// line 5: `d(i,j) = |L_i Δ L_j|`).
#[inline]
pub fn bank_distance(a: u64, b: u64) -> u32 {
    (a ^ b).count_ones()
}

/// Algorithm 1: dissimilarity-aware row → PE mapping. Groups rows with
/// similar bank-access sets onto the same PE (so their conflicting accesses
/// serialize locally) under an nnz capacity bound per PE, spreading
/// dissimilar rows across PEs.
pub fn dissimilarity_aware(m: &Csr, parts: usize, banks: usize) -> Vec<usize> {
    assert!(parts > 0 && banks > 0 && banks <= 64);
    if m.rows == 0 {
        return Vec::new();
    }
    let sets: Vec<u64> = (0..m.rows).map(|r| bank_set(m, r, banks)).collect();
    let nnz: Vec<usize> = (0..m.rows).map(|r| m.row_nnz(r)).collect();
    let cap = (m.nnz() + parts - 1) / parts; // nnz budget per PE (±1 row)

    // Seed selection: first seed = heaviest row; each further seed maximizes
    // its minimum distance to existing seeds (k-center style), so clusters
    // start maximally dissimilar.
    let mut seeds: Vec<usize> = Vec::with_capacity(parts);
    let first = (0..m.rows).max_by_key(|&r| nnz[r]).unwrap();
    seeds.push(first);
    while seeds.len() < parts.min(m.rows) {
        let next = (0..m.rows)
            .filter(|r| !seeds.contains(r))
            .max_by_key(|&r| {
                seeds
                    .iter()
                    .map(|&s| bank_distance(sets[r], sets[s]))
                    .min()
                    .unwrap_or(0)
            })
            .unwrap();
        seeds.push(next);
    }

    let mut part = vec![usize::MAX; m.rows];
    let mut load = vec![0usize; parts];
    for (k, &s) in seeds.iter().enumerate() {
        part[s] = k;
        load[k] = nnz[s];
    }
    // Assign remaining rows, heaviest first (greedy bin packing): nearest
    // cluster by bank distance among those whose load would stay within the
    // nnz budget; ties broken by lighter load. Seedless clusters (only when
    // `parts > m.rows`) have no bank signature to compare against, so they
    // compete on load alone, behind every seeded cluster.
    let mut order: Vec<usize> = (0..m.rows).filter(|&r| part[r] == usize::MAX).collect();
    order.sort_unstable_by_key(|&r| std::cmp::Reverse(nnz[r]));
    for r in order {
        let k = (0..parts)
            .filter(|&k| load[k] + nnz[r] <= cap) // hard nnz budget
            .min_by_key(|&k| match seeds.get(k) {
                Some(&s) => (bank_distance(sets[r], sets[s]), load[k]),
                None => (u32::MAX, load[k]),
            })
            // Every cluster full: fall back to the lightest, overshooting
            // by at most this one row (the documented ±1-row bound).
            .unwrap_or_else(|| (0..parts).min_by_key(|&k| load[k]).unwrap());
        part[r] = k;
        load[k] += nnz[r];
    }
    part
}

/// Greedy LPT (longest-processing-time) row → PE mapping: rows sorted by
/// descending nnz, each assigned to the currently lightest PE (ties to the
/// lowest PE index). Spreads heavy rows — power-law hubs, hotspot blocks —
/// across the fabric, bounding any PE's load at `ideal + max_row_nnz`.
pub fn hotspot_split(m: &Csr, parts: usize) -> Vec<usize> {
    assert!(parts > 0);
    let mut order: Vec<usize> = (0..m.rows).collect();
    order.sort_unstable_by_key(|&r| std::cmp::Reverse(m.row_nnz(r)));
    let mut part = vec![0usize; m.rows];
    let mut load = vec![0usize; parts];
    for r in order {
        let k = (0..parts).min_by_key(|&k| load[k]).unwrap();
        part[r] = k;
        load[k] += m.row_nnz(r);
    }
    part
}

/// Uniform contiguous block partition of a length-`n` 1-D tensor into
/// `parts` blocks ("for dense tensors, uniform segmentation into k equal
/// parts"). Returns `part[i] in [0, parts)`, non-decreasing.
pub fn uniform_blocks(n: usize, parts: usize) -> Vec<usize> {
    assert!(parts > 0);
    (0..n).map(|i| (i * parts / n.max(1)).min(parts - 1)).collect()
}

/// Maximum per-part nonzero count under a partition (balance diagnostics).
pub fn max_part_nnz(m: &Csr, part: &[usize], parts: usize) -> usize {
    let mut load = vec![0usize; parts];
    for r in 0..m.rows {
        load[part[r]] += m.row_nnz(r);
    }
    load.into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::prop::{ensure, forall};
    use crate::util::SplitMix64;

    #[test]
    fn nnz_balanced_is_contiguous_and_total() {
        forall(50, |rng| {
            let rows = 4 + rng.below_usize(60);
            let m = gen::skewed_csr(rng, rows, 32, 0.3);
            let parts = 1 + rng.below_usize(16);
            let part = nnz_balanced(&m, parts);
            ensure(part.len() == rows, || "length".into())?;
            for w in part.windows(2) {
                ensure(w[1] == w[0] || w[1] == w[0] + 1, || {
                    "parts must be contiguous non-decreasing".into()
                })?;
            }
            ensure(part.iter().all(|&p| p < parts), || "range".into())
        });
    }

    #[test]
    fn nnz_balanced_balances_skewed_matrix() {
        let mut rng = SplitMix64::new(7);
        let m = gen::skewed_csr(&mut rng, 64, 64, 0.3);
        let parts = 8;
        let part = nnz_balanced(&m, parts);
        let worst = max_part_nnz(&m, &part, parts);
        let ideal = m.nnz() / parts;
        // Against a *row-uniform* split of a skewed matrix, the nnz split
        // must be far closer to ideal.
        let uniform = uniform_blocks(64, parts);
        let worst_uniform = max_part_nnz(&m, &uniform, parts);
        assert!(
            worst <= worst_uniform,
            "nnz-balanced {worst} vs uniform {worst_uniform} (ideal {ideal})"
        );
    }

    #[test]
    fn dissimilarity_covers_all_rows_in_range() {
        forall(30, |rng| {
            let rows = 2 + rng.below_usize(60);
            let m = gen::random_csr(rng, rows, 32, 0.3);
            let parts = 1 + rng.below_usize(16);
            let part = dissimilarity_aware(&m, parts, 8);
            ensure(part.len() == rows, || "length".into())?;
            ensure(part.iter().all(|&p| p < parts), || "range".into())
        });
    }

    /// Regression for the vacuous "soft cap": the old filter
    /// `load[k] + nnz[r] <= cap + nnz[r].min(cap)` reduced to
    /// `load[k] <= cap`, so a full cluster could absorb a whole extra heavy
    /// row. Two identical heavy rows plus one empty row: the heavy rows
    /// share a bank set, so bank distance pulls the second heavy row onto
    /// the first's cluster — the old code let it in (one part at 2H), the
    /// hard budget forces it to the empty-seeded part (both parts at H).
    #[test]
    fn dissimilarity_respects_nnz_budget_on_tied_heavy_rows() {
        let h = 4usize;
        let m = Csr::from_triplets(3, 8, (0..h).flat_map(|c| [(0, c, 1i16), (1, c, 1i16)]));
        assert_eq!(m.nnz(), 2 * h);
        let part = dissimilarity_aware(&m, 2, 8);
        assert_ne!(part[0], part[1], "heavy rows must split across parts");
        assert_eq!(max_part_nnz(&m, &part, 2), h, "each part holds one heavy row");
    }

    /// The documented ±1-row bound, as a property: no cluster exceeds the
    /// nnz budget `cap` by a full row, i.e. worst < cap + max_row_nnz.
    #[test]
    fn dissimilarity_bounds_overshoot_to_less_than_one_row() {
        forall(100, |rng| {
            let rows = 1 + rng.below_usize(60);
            let m = gen::skewed_csr(rng, rows, 32, 0.3);
            let parts = 1 + rng.below_usize(16);
            let part = dissimilarity_aware(&m, parts, 8);
            let cap = (m.nnz() + parts - 1) / parts;
            let max_nnz = (0..rows).map(|r| m.row_nnz(r)).max().unwrap_or(0);
            let worst = max_part_nnz(&m, &part, parts);
            if m.nnz() == 0 {
                ensure(worst == 0, || "zero-nnz matrix must have zero loads".into())
            } else {
                ensure(worst < cap + max_nnz, || {
                    format!("worst {worst} >= cap {cap} + max row {max_nnz}")
                })
            }
        });
    }

    /// Regression for the wrong-seed distance `seeds[k.min(seeds.len()-1)]`:
    /// with `rows < parts` every row is its own seed, and clusters beyond
    /// `seeds.len()` have no signature to compare against. The defect was
    /// latent (the greedy loop body is empty exactly when seedless clusters
    /// exist), so this pins the intended behavior: each row keeps its own
    /// distinct in-range cluster and seedless clusters stay empty.
    #[test]
    fn dissimilarity_with_fewer_rows_than_parts_keeps_rows_on_own_seeds() {
        let m = Csr::from_triplets(3, 8, [(0, 0, 1i16), (1, 3, 2i16), (2, 6, 3i16)]);
        let parts = 8;
        let part = dissimilarity_aware(&m, parts, 8);
        assert_eq!(part.len(), 3);
        assert!(part.iter().all(|&p| p < parts));
        assert_ne!(part[0], part[1]);
        assert_ne!(part[0], part[2]);
        assert_ne!(part[1], part[2]);
    }

    #[test]
    fn nnz_balanced_never_leaves_a_part_empty_when_rows_suffice() {
        forall(100, |rng| {
            let parts = 1 + rng.below_usize(16);
            let rows = parts + rng.below_usize(60);
            // Exercise degenerate distributions too: all-zero matrices and
            // a single heavy row among empties.
            let m = match rng.below_usize(3) {
                0 => gen::skewed_csr(rng, rows, 32, 0.3),
                1 => Csr::zero(rows, 32),
                _ => {
                    let r = rng.below_usize(rows);
                    Csr::from_triplets(rows, 32, (0..16).map(|c| (r, c, 1i16)))
                }
            };
            let part = nnz_balanced(&m, parts);
            let mut seen = vec![false; parts];
            for &p in &part {
                seen[p] = true;
            }
            ensure(seen.iter().all(|&s| s), || {
                format!("empty part with {rows} rows over {parts} parts")
            })
        });
    }

    #[test]
    fn hotspot_split_spreads_heavy_rows() {
        forall(50, |rng| {
            let rows = 1 + rng.below_usize(60);
            let m = gen::skewed_csr(rng, rows, 32, 0.3);
            let parts = 1 + rng.below_usize(16);
            let part = hotspot_split(&m, parts);
            ensure(part.len() == rows, || "length".into())?;
            ensure(part.iter().all(|&p| p < parts), || "range".into())?;
            // LPT's makespan bound: no PE exceeds ideal + one row.
            let max_nnz = (0..rows).map(|r| m.row_nnz(r)).max().unwrap_or(0);
            let worst = max_part_nnz(&m, &part, parts);
            ensure(worst <= m.nnz() / parts + max_nnz, || {
                format!("LPT bound violated: {worst}")
            })
        });
    }

    #[test]
    fn place_rows_dispatches_per_policy() {
        let mut rng = SplitMix64::new(11);
        let m = gen::hotspot_csr(&mut rng, 48, 48, 0.2, 4, 0.85);
        for policy in PlacementPolicy::ALL {
            let part = place_rows(&m, 8, 8, policy);
            assert_eq!(part.len(), m.rows);
            assert!(part.iter().all(|&p| p < 8));
        }
        assert_eq!(
            place_rows(&m, 8, 8, PlacementPolicy::DissimilarityAware),
            dissimilarity_aware(&m, 8, 8),
        );
        assert_eq!(
            place_rows(&m, 8, 8, PlacementPolicy::HotspotSplit),
            hotspot_split(&m, 8),
        );
    }

    #[test]
    fn bank_distance_is_metric_like() {
        assert_eq!(bank_distance(0b1010, 0b1010), 0);
        assert_eq!(bank_distance(0b1010, 0b0101), 4);
        assert_eq!(bank_distance(0b1010, 0b1000), 1);
    }

    #[test]
    fn uniform_blocks_are_balanced() {
        forall(50, |rng| {
            let n = 1 + rng.below_usize(100);
            let parts = 1 + rng.below_usize(16);
            let part = uniform_blocks(n, parts);
            let mut sizes = vec![0usize; parts];
            for &p in &part {
                sizes[p] += 1;
            }
            let max = *sizes.iter().max().unwrap();
            let min = *sizes.iter().filter(|&&s| s > 0).min().unwrap_or(&0);
            ensure(max - min <= 1, || format!("unbalanced {sizes:?}"))?;
            for w in part.windows(2) {
                ensure(w[1] >= w[0], || "non-decreasing".into())?;
            }
            Ok(())
        });
    }
}
