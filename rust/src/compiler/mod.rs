//! The Nexus Machine compiler (§3.6): transforms workload kernels and their
//! tensors into the per-PE images the fabric executes.
//!
//! The static compiler side — DFG construction and ASAP scheduling — lives
//! in [`dfg`]; the data-placement side — the
//! [`crate::config::PlacementPolicy`]-selected partitioners (nnz-balanced,
//! dissimilarity-aware Algorithm 1, hotspot-splitting), dispatched by
//! [`partition::place_rows`] — in [`partition`]. This module owns the
//! output artifact: a [`Program`] of per-PE data-memory images, stream
//! tables, trigger tables, static-AM queues, and the replicated
//! configuration memory, produced through the [`ProgramBuilder`].
//!
//! The *lightweight runtime manager* of §3.6 corresponds to the workload
//! builders in [`crate::workloads`]: they walk the partitioned tensors and
//! emit one static AM per element of the first operand, exactly as the
//! paper describes ("For every element in the first operand, the runtime
//! manager generates a static AM containing information about the operands
//! and the result").

pub mod dfg;
pub mod partition;

use crate::am::Message;
use crate::config::ArchConfig;
use crate::isa::ConfigEntry;
use crate::pe::StreamElem;

/// Per-PE load image.
#[derive(Debug, Clone, Default)]
pub struct PeImage {
    /// Initial data-memory contents as (address, value) words.
    pub dmem_init: Vec<(u16, u16)>,
    /// Stream element records (the decode unit's streaming-mode tables).
    pub stream_elems: Vec<StreamElem>,
    /// Trigger descriptors: (dmem address, stream base, element count).
    /// `Stream` opcodes key on `op2`; `AccMin` re-emission keys on `result`.
    pub triggers: Vec<(u16, u32, u16)>,
    /// Precompiled static AMs, in injection order (the AM queue image).
    pub static_ams: Vec<Message>,
}

/// A compiled program: everything the fabric needs to run one tile.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    /// Replicated configuration memory (identical in every PE — the +8%
    /// power of Fig 10 pays for exactly this replication).
    pub config: Vec<ConfigEntry>,
    /// One image per PE.
    pub pes: Vec<PeImage>,
    /// Output locations in logical order: `outputs[i]` = (pe, dmem address)
    /// of the i-th element of the result tensor.
    pub outputs: Vec<(usize, u16)>,
}

impl Program {
    /// Total static AMs across all queues.
    pub fn num_static_ams(&self) -> usize {
        self.pes.iter().map(|p| p.static_ams.len()).sum()
    }

    /// Off-chip bytes needed to load this program: AM-queue entries
    /// (9 bytes each, the byte-aligned 70-bit format), data-memory words,
    /// and stream-element records (3 words each).
    pub fn load_bytes(&self) -> u64 {
        let mut bytes = 0u64;
        for pe in &self.pes {
            bytes += pe.static_ams.len() as u64 * crate::am::packed::AM_BYTES as u64;
            bytes += pe.dmem_init.len() as u64 * 2;
            bytes += pe.stream_elems.len() as u64 * crate::pe::STREAM_ELEM_WORDS as u64 * 2;
        }
        bytes
    }

    /// Bytes written back off-chip at tile end (the output tensor).
    pub fn writeback_bytes(&self) -> u64 {
        self.outputs.len() as u64 * 2
    }

    /// Validate the program against an architecture: config fits the config
    /// memory, every PE image fits its SRAM, destinations are in range.
    pub fn validate(&self, cfg: &ArchConfig) -> Result<(), String> {
        if self.config.len() > cfg.config_entries {
            return Err(format!(
                "{}: {} config entries > {} available",
                self.name,
                self.config.len(),
                cfg.config_entries
            ));
        }
        if self.pes.len() != cfg.num_pes() {
            return Err(format!(
                "{}: image for {} PEs, fabric has {}",
                self.name,
                self.pes.len(),
                cfg.num_pes()
            ));
        }
        for (id, pe) in self.pes.iter().enumerate() {
            let words_used = pe
                .dmem_init
                .iter()
                .map(|&(a, _)| a as usize + 1)
                .max()
                .unwrap_or(0);
            let stream_words = pe.stream_elems.len() * crate::pe::STREAM_ELEM_WORDS;
            if words_used + stream_words > cfg.dmem_words {
                return Err(format!(
                    "{}: PE{} SRAM overflow: {} dmem + {} stream words > {}",
                    self.name, id, words_used, stream_words, cfg.dmem_words
                ));
            }
            for (addr, base, count) in &pe.triggers {
                if *addr as usize >= cfg.dmem_words {
                    return Err(format!("{}: PE{id} trigger addr {addr} out of range", self.name));
                }
                if *base as usize + *count as usize > pe.stream_elems.len() {
                    return Err(format!("{}: PE{id} trigger overruns stream table", self.name));
                }
            }
            for am in &pe.static_ams {
                for d in 0..am.ndests as usize {
                    if am.dests[d] as usize >= cfg.num_pes() {
                        return Err(format!(
                            "{}: PE{id} static AM dest {} out of range",
                            self.name, am.dests[d]
                        ));
                    }
                }
                if am.n_pc as usize >= self.config.len().max(1) {
                    return Err(format!("{}: PE{id} static AM N_PC out of range", self.name));
                }
            }
        }
        for &(pe, addr) in &self.outputs {
            if pe >= cfg.num_pes() || addr as usize >= cfg.dmem_words {
                return Err(format!("{}: output location ({pe},{addr}) out of range", self.name));
            }
        }
        Ok(())
    }
}

/// Incremental builder for [`Program`]s: bump-allocates data memory per PE,
/// interns config entries, and collects static AMs / stream tables.
pub struct ProgramBuilder {
    name: String,
    dmem_words: usize,
    config: Vec<ConfigEntry>,
    pes: Vec<PeImage>,
    /// Per-PE data-memory bump pointer.
    cursor: Vec<u16>,
    outputs: Vec<(usize, u16)>,
}

impl ProgramBuilder {
    pub fn new(name: &str, cfg: &ArchConfig) -> Self {
        ProgramBuilder {
            name: name.to_string(),
            dmem_words: cfg.dmem_words,
            config: Vec::new(),
            pes: vec![PeImage::default(); cfg.num_pes()],
            cursor: vec![0; cfg.num_pes()],
            outputs: Vec::new(),
        }
    }

    /// Append a config entry, returning its PC. Identical entries are
    /// interned (the config memory has only 8 slots).
    pub fn config(&mut self, entry: ConfigEntry) -> u8 {
        if let Some(pos) = self.config.iter().position(|e| *e == entry) {
            return pos as u8;
        }
        self.config.push(entry);
        (self.config.len() - 1) as u8
    }

    /// Reserve `n` words of PE `pe`'s data memory, returning the base
    /// address. Panics on SRAM overflow (workloads are sized to fit;
    /// `Program::validate` re-checks including stream tables).
    pub fn alloc(&mut self, pe: usize, n: usize) -> u16 {
        self.try_alloc(pe, n).unwrap_or_else(|| {
            panic!(
                "{}: PE{pe} dmem overflow ({} words requested at {})",
                self.name, n, self.cursor[pe]
            )
        })
    }

    /// Fallible [`ProgramBuilder::alloc`] for capacity-probing compilers
    /// (the tiled SpMSpM grows tiles until allocation fails).
    pub fn try_alloc(&mut self, pe: usize, n: usize) -> Option<u16> {
        let base = self.cursor[pe];
        let end = base as usize + n;
        if end > self.dmem_words {
            return None;
        }
        self.cursor[pe] = end as u16;
        Some(base)
    }

    /// Fallible [`ProgramBuilder::place`].
    pub fn try_place(&mut self, pe: usize, values: &[i16]) -> Option<u16> {
        let base = self.try_alloc(pe, values.len())?;
        for (i, &v) in values.iter().enumerate() {
            self.pes[pe].dmem_init.push((base + i as u16, v as u16));
        }
        Some(base)
    }

    /// Place an array of words in PE `pe`'s data memory; returns base addr.
    pub fn place(&mut self, pe: usize, values: &[i16]) -> u16 {
        self.try_place(pe, values).unwrap_or_else(|| {
            panic!(
                "{}: PE{pe} dmem overflow ({} words requested at {})",
                self.name,
                values.len(),
                self.cursor[pe]
            )
        })
    }

    /// Words still free in PE `pe`'s data memory (before stream accounting).
    pub fn free_words(&self, pe: usize) -> usize {
        self.dmem_words - self.cursor[pe] as usize
    }

    /// Append stream elements to PE `pe`'s stream table; returns the base
    /// index for a trigger descriptor.
    pub fn stream(&mut self, pe: usize, elems: &[StreamElem]) -> u32 {
        let base = self.pes[pe].stream_elems.len() as u32;
        self.pes[pe].stream_elems.extend_from_slice(elems);
        base
    }

    /// Register a trigger: messages keying `addr` at PE `pe` start a
    /// streaming decode of `count` elements at `base`. Returns `addr`.
    pub fn trigger(&mut self, pe: usize, addr: u16, base: u32, count: u16) -> u16 {
        self.pes[pe].triggers.push((addr, base, count));
        addr
    }

    /// Allocate a fresh key address and register a trigger on it in one step
    /// (for streams not anchored to a data word, e.g. Conv tap tables).
    pub fn keyed_trigger(&mut self, pe: usize, base: u32, count: u16) -> u16 {
        let addr = self.alloc(pe, 1);
        self.trigger(pe, addr, base, count)
    }

    /// Queue a static AM on PE `pe`.
    pub fn static_am(&mut self, pe: usize, am: Message) {
        self.pes[pe].static_ams.push(am);
    }

    /// Record that logical output element `outputs.len()` lives at
    /// (`pe`, `addr`). Call in logical order.
    pub fn output(&mut self, pe: usize, addr: u16) {
        self.outputs.push((pe, addr));
    }

    pub fn build(self) -> Program {
        Program {
            name: self.name,
            config: self.config,
            pes: self.pes,
            outputs: self.outputs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Opcode;

    fn cfg() -> ArchConfig {
        ArchConfig::nexus()
    }

    #[test]
    fn builder_places_and_allocates() {
        let mut b = ProgramBuilder::new("t", &cfg());
        let a0 = b.place(0, &[1, 2, 3]);
        let a1 = b.place(0, &[9]);
        assert_eq!(a0, 0);
        assert_eq!(a1, 3);
        assert_eq!(b.free_words(0), 512 - 4);
        let p = b.build();
        assert_eq!(p.pes[0].dmem_init.len(), 4);
        assert_eq!(p.pes[0].dmem_init[3], (3, 9));
    }

    #[test]
    fn config_interning_dedupes() {
        let mut b = ProgramBuilder::new("t", &cfg());
        let e = ConfigEntry::new(Opcode::Mul, 2);
        let p0 = b.config(e);
        let p1 = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
        let p2 = b.config(e);
        assert_eq!(p0, p2);
        assert_ne!(p0, p1);
        assert_eq!(b.build().config.len(), 2);
    }

    #[test]
    fn validate_catches_sram_overflow() {
        let c = cfg();
        let mut b = ProgramBuilder::new("t", &c);
        b.place(0, &vec![0i16; 500]);
        // 500 dmem + 10 stream elems * 3 words = 530 > 512.
        b.stream(
            0,
            &vec![
                StreamElem {
                    value: 0,
                    aux: 0,
                    dest_pe: 0,
                    mode: crate::pe::StreamMode::PerDest,
                };
                10
            ],
        );
        assert!(b.build().validate(&c).is_err());
    }

    #[test]
    fn validate_catches_bad_dest() {
        let c = cfg();
        let mut b = ProgramBuilder::new("t", &c);
        let mut am = Message::new();
        am.push_dest(99); // > 15 PEs
        b.static_am(0, am);
        assert!(b.build().validate(&c).is_err());
    }

    #[test]
    #[should_panic(expected = "dmem overflow")]
    fn alloc_panics_past_capacity() {
        let mut b = ProgramBuilder::new("t", &cfg());
        b.alloc(0, 513);
    }

    #[test]
    fn load_bytes_accounting() {
        let c = cfg();
        let mut b = ProgramBuilder::new("t", &c);
        b.place(0, &[1, 2]);
        b.static_am(0, Message::new());
        let p = b.build();
        assert_eq!(p.load_bytes(), 2 * 2 + 9);
    }
}
