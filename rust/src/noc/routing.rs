//! Routing functions for the mesh.
//!
//! The paper's NoC uses turn-model routing \[31\] ("dynamic turn model routing
//! protocol", §3.1) with congestion awareness. We implement **west-first**:
//! a packet that must travel west does so first and deterministically;
//! east/north/south moves may then be chosen adaptively (by downstream
//! congestion) without ever making a prohibited turn — the classic
//! deadlock-free adaptive turn model.
//!
//! Mesh coordinates: x grows east, y grows south; PE id = y * width + x.

/// Output direction from a router.
///
/// The first five variants are the paper's 2D-mesh ports. The `Ruche*`
/// variants are the long-range skip links a [`super::topology::Ruche`]
/// network adds on top of the mesh (same compass heading, stride-length
/// jump); mesh/torus/chiplet fabrics never produce them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Local,
    North,
    East,
    South,
    West,
    RucheNorth,
    RucheEast,
    RucheSouth,
    RucheWest,
}

impl Dir {
    /// Port index used by [`super::router::Router`].
    #[inline]
    pub fn port(self) -> usize {
        match self {
            Dir::Local => 0,
            Dir::North => 1,
            Dir::East => 2,
            Dir::South => 3,
            Dir::West => 4,
            Dir::RucheNorth => 5,
            Dir::RucheEast => 6,
            Dir::RucheSouth => 7,
            Dir::RucheWest => 8,
        }
    }

    /// Inverse of [`Dir::port`].
    #[inline]
    pub fn from_port(port: usize) -> Dir {
        match port {
            0 => Dir::Local,
            1 => Dir::North,
            2 => Dir::East,
            3 => Dir::South,
            4 => Dir::West,
            5 => Dir::RucheNorth,
            6 => Dir::RucheEast,
            7 => Dir::RucheSouth,
            8 => Dir::RucheWest,
            _ => panic!("invalid port index {port}"),
        }
    }

    /// The reverse heading (N↔S, E↔W, ruche likewise; Local is its own
    /// opposite).
    #[inline]
    pub fn opposite(self) -> Dir {
        match self {
            Dir::Local => Dir::Local,
            Dir::North => Dir::South,
            Dir::East => Dir::West,
            Dir::South => Dir::North,
            Dir::West => Dir::East,
            Dir::RucheNorth => Dir::RucheSouth,
            Dir::RucheEast => Dir::RucheWest,
            Dir::RucheSouth => Dir::RucheNorth,
            Dir::RucheWest => Dir::RucheEast,
        }
    }

    /// The input port on the *neighbor* router that a flit leaving through
    /// this output arrives on (N exits arrive on the neighbor's S input).
    #[inline]
    pub fn opposite_port(self) -> usize {
        self.opposite().port()
    }
}

/// Candidate output directions for a hop from `(x, y)` toward `(tx, ty)`
/// under the west-first turn model. Returns 1–2 candidates in `out`, with
/// `out[0..n]` valid; `n == 0` means the packet has arrived (Local).
///
/// West-first rule: if the destination is to the west, the only candidate is
/// West. Otherwise any productive direction among {East, North, South} is
/// permitted, and the router picks adaptively (congestion-aware).
#[inline]
pub fn route_ports(x: usize, y: usize, tx: usize, ty: usize, out: &mut [Dir; 2]) -> usize {
    if tx < x {
        // Must go west first; no adaptivity allowed (west-first invariant).
        out[0] = Dir::West;
        return 1;
    }
    let mut n = 0;
    if tx > x {
        out[n] = Dir::East;
        n += 1;
    }
    if ty < y {
        out[n] = Dir::North;
        n += 1;
    } else if ty > y {
        out[n] = Dir::South;
        n += 1;
    }
    n
}

/// Deterministic XY (dimension-order) routing: X first, then Y.
#[inline]
pub fn route_xy(x: usize, y: usize, tx: usize, ty: usize) -> Dir {
    if tx > x {
        Dir::East
    } else if tx < x {
        Dir::West
    } else if ty > y {
        Dir::South
    } else if ty < y {
        Dir::North
    } else {
        Dir::Local
    }
}

/// Minimal-path hop count between two PEs.
#[inline]
pub fn manhattan(x: usize, y: usize, tx: usize, ty: usize) -> usize {
    x.abs_diff(tx) + y.abs_diff(ty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn west_first_is_deterministic_westward() {
        let mut out = [Dir::Local; 2];
        let n = route_ports(3, 2, 0, 0, &mut out);
        assert_eq!(n, 1);
        assert_eq!(out[0], Dir::West);
    }

    #[test]
    fn eastward_offers_adaptive_choices() {
        let mut out = [Dir::Local; 2];
        let n = route_ports(0, 0, 2, 2, &mut out);
        assert_eq!(n, 2);
        assert!(out.contains(&Dir::East));
        assert!(out.contains(&Dir::South));
    }

    #[test]
    fn arrival_yields_zero_candidates() {
        let mut out = [Dir::Local; 2];
        assert_eq!(route_ports(1, 1, 1, 1, &mut out), 0);
    }

    #[test]
    fn candidates_are_always_productive() {
        // Property: every candidate strictly reduces Manhattan distance.
        forall(300, |rng| {
            let w = 2 + rng.below_usize(7);
            let h = 2 + rng.below_usize(7);
            let (x, y) = (rng.below_usize(w), rng.below_usize(h));
            let (tx, ty) = (rng.below_usize(w), rng.below_usize(h));
            let mut out = [Dir::Local; 2];
            let n = route_ports(x, y, tx, ty, &mut out);
            let d0 = manhattan(x, y, tx, ty);
            if d0 == 0 {
                return ensure(n == 0, || "arrived but candidates remain".into());
            }
            ensure(n >= 1, || "no candidate while not arrived".into())?;
            for &dir in &out[..n] {
                let (nx, ny) = match dir {
                    Dir::North => (x, y - 1),
                    Dir::South => (x, y + 1),
                    Dir::East => (x + 1, y),
                    Dir::West => (x - 1, y),
                    _ => unreachable!("mesh route_ports never emits {dir:?}"),
                };
                ensure(manhattan(nx, ny, tx, ty) == d0 - 1, || {
                    format!("unproductive candidate {dir:?} from ({x},{y}) to ({tx},{ty})")
                })?;
            }
            Ok(())
        });
    }

    #[test]
    fn west_first_never_turns_from_ns_to_west() {
        // The turn-model invariant: once a packet has moved N/S (meaning
        // tx >= x at that point), route_ports never returns West again for
        // any position reachable by following candidates.
        forall(200, |rng| {
            let w = 2 + rng.below_usize(7);
            let h = 2 + rng.below_usize(7);
            let (mut x, mut y) = (rng.below_usize(w), rng.below_usize(h));
            let (tx, ty) = (rng.below_usize(w), rng.below_usize(h));
            let mut moved_ns = false;
            let mut out = [Dir::Local; 2];
            for _ in 0..(w + h) {
                let n = route_ports(x, y, tx, ty, &mut out);
                if n == 0 {
                    break;
                }
                // Take an arbitrary candidate (rng-chosen) to explore paths.
                let dir = out[rng.below_usize(n)];
                if dir == Dir::West && moved_ns {
                    return Err(format!("illegal S/N->W turn at ({x},{y})"));
                }
                match dir {
                    Dir::North => {
                        y -= 1;
                        moved_ns = true;
                    }
                    Dir::South => {
                        y += 1;
                        moved_ns = true;
                    }
                    Dir::East => x += 1,
                    Dir::West => x -= 1,
                    _ => {}
                }
            }
            Ok(())
        });
    }

    #[test]
    fn xy_routes_reach_destination() {
        forall(200, |rng| {
            let w = 2 + rng.below_usize(7);
            let h = 2 + rng.below_usize(7);
            let (mut x, mut y) = (rng.below_usize(w), rng.below_usize(h));
            let (tx, ty) = (rng.below_usize(w), rng.below_usize(h));
            for _ in 0..(w + h) {
                match route_xy(x, y, tx, ty) {
                    Dir::Local => break,
                    Dir::North => y -= 1,
                    Dir::South => y += 1,
                    Dir::East => x += 1,
                    Dir::West => x -= 1,
                    other => unreachable!("route_xy never emits {other:?}"),
                }
            }
            ensure((x, y) == (tx, ty), || "XY did not arrive".into())
        });
    }

    #[test]
    fn dir_port_roundtrip() {
        for port in 0..9 {
            assert_eq!(Dir::from_port(port).port(), port);
            // opposite is an involution and preserves the ruche/mesh class.
            let d = Dir::from_port(port);
            assert_eq!(d.opposite().opposite(), d);
        }
        assert_eq!(Dir::North.opposite_port(), Dir::South.port());
        assert_eq!(Dir::RucheEast.opposite_port(), Dir::RucheWest.port());
    }

    #[test]
    fn one_wide_meshes_route_pure_axis() {
        // Degenerate 1xN / Nx1 meshes: route_ports must emit only moves
        // along the existing axis (never a direction that would leave the
        // strip), and reach the destination.
        let mut out = [Dir::Local; 2];
        for n in 2..=8 {
            // 1-wide (single column): only N/S moves are meaningful.
            for (y, ty) in [(0usize, n - 1), (n - 1, 0), (1, n - 2)] {
                let (mut y, ty) = (y, ty);
                for _ in 0..n {
                    let c = route_ports(0, y, 0, ty, &mut out);
                    if c == 0 {
                        break;
                    }
                    for &d in &out[..c] {
                        assert!(
                            matches!(d, Dir::North | Dir::South),
                            "1-wide mesh offered {d:?}"
                        );
                    }
                    match out[0] {
                        Dir::North => y -= 1,
                        Dir::South => y += 1,
                        _ => unreachable!(),
                    }
                }
                assert_eq!(y, ty, "1-wide mesh did not arrive");
            }
            // 1-tall (single row): only E/W moves are meaningful.
            for (x, tx) in [(0usize, n - 1), (n - 1, 0)] {
                let (mut x, tx) = (x, tx);
                for _ in 0..n {
                    let c = route_ports(x, 0, tx, 0, &mut out);
                    if c == 0 {
                        break;
                    }
                    assert_eq!(c, 1, "1-tall mesh must be deterministic");
                    match out[0] {
                        Dir::East => x += 1,
                        Dir::West => x -= 1,
                        d => panic!("1-tall mesh offered {d:?}"),
                    }
                }
                assert_eq!(x, tx, "1-tall mesh did not arrive");
            }
        }
    }
}
