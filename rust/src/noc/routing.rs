//! Routing functions for the mesh.
//!
//! The paper's NoC uses turn-model routing \[31\] ("dynamic turn model routing
//! protocol", §3.1) with congestion awareness. We implement **west-first**:
//! a packet that must travel west does so first and deterministically;
//! east/north/south moves may then be chosen adaptively (by downstream
//! congestion) without ever making a prohibited turn — the classic
//! deadlock-free adaptive turn model.
//!
//! Mesh coordinates: x grows east, y grows south; PE id = y * width + x.

/// Output direction from a router.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Local,
    North,
    East,
    South,
    West,
}

impl Dir {
    /// Port index used by [`super::router::Router`].
    #[inline]
    pub fn port(self) -> usize {
        match self {
            Dir::Local => 0,
            Dir::North => 1,
            Dir::East => 2,
            Dir::South => 3,
            Dir::West => 4,
        }
    }

    /// The input port on the *neighbor* router that a flit leaving through
    /// this output arrives on (N exits arrive on the neighbor's S input).
    #[inline]
    pub fn opposite_port(self) -> usize {
        match self {
            Dir::Local => 0,
            Dir::North => Dir::South.port(),
            Dir::East => Dir::West.port(),
            Dir::South => Dir::North.port(),
            Dir::West => Dir::East.port(),
        }
    }
}

/// Candidate output directions for a hop from `(x, y)` toward `(tx, ty)`
/// under the west-first turn model. Returns 1–2 candidates in `out`, with
/// `out[0..n]` valid; `n == 0` means the packet has arrived (Local).
///
/// West-first rule: if the destination is to the west, the only candidate is
/// West. Otherwise any productive direction among {East, North, South} is
/// permitted, and the router picks adaptively (congestion-aware).
#[inline]
pub fn route_ports(x: usize, y: usize, tx: usize, ty: usize, out: &mut [Dir; 2]) -> usize {
    if tx < x {
        // Must go west first; no adaptivity allowed (west-first invariant).
        out[0] = Dir::West;
        return 1;
    }
    let mut n = 0;
    if tx > x {
        out[n] = Dir::East;
        n += 1;
    }
    if ty < y {
        out[n] = Dir::North;
        n += 1;
    } else if ty > y {
        out[n] = Dir::South;
        n += 1;
    }
    n
}

/// Deterministic XY (dimension-order) routing: X first, then Y.
#[inline]
pub fn route_xy(x: usize, y: usize, tx: usize, ty: usize) -> Dir {
    if tx > x {
        Dir::East
    } else if tx < x {
        Dir::West
    } else if ty > y {
        Dir::South
    } else if ty < y {
        Dir::North
    } else {
        Dir::Local
    }
}

/// Minimal-path hop count between two PEs.
#[inline]
pub fn manhattan(x: usize, y: usize, tx: usize, ty: usize) -> usize {
    x.abs_diff(tx) + y.abs_diff(ty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn west_first_is_deterministic_westward() {
        let mut out = [Dir::Local; 2];
        let n = route_ports(3, 2, 0, 0, &mut out);
        assert_eq!(n, 1);
        assert_eq!(out[0], Dir::West);
    }

    #[test]
    fn eastward_offers_adaptive_choices() {
        let mut out = [Dir::Local; 2];
        let n = route_ports(0, 0, 2, 2, &mut out);
        assert_eq!(n, 2);
        assert!(out.contains(&Dir::East));
        assert!(out.contains(&Dir::South));
    }

    #[test]
    fn arrival_yields_zero_candidates() {
        let mut out = [Dir::Local; 2];
        assert_eq!(route_ports(1, 1, 1, 1, &mut out), 0);
    }

    #[test]
    fn candidates_are_always_productive() {
        // Property: every candidate strictly reduces Manhattan distance.
        forall(300, |rng| {
            let w = 2 + rng.below_usize(7);
            let h = 2 + rng.below_usize(7);
            let (x, y) = (rng.below_usize(w), rng.below_usize(h));
            let (tx, ty) = (rng.below_usize(w), rng.below_usize(h));
            let mut out = [Dir::Local; 2];
            let n = route_ports(x, y, tx, ty, &mut out);
            let d0 = manhattan(x, y, tx, ty);
            if d0 == 0 {
                return ensure(n == 0, || "arrived but candidates remain".into());
            }
            ensure(n >= 1, || "no candidate while not arrived".into())?;
            for &dir in &out[..n] {
                let (nx, ny) = match dir {
                    Dir::North => (x, y - 1),
                    Dir::South => (x, y + 1),
                    Dir::East => (x + 1, y),
                    Dir::West => (x - 1, y),
                    Dir::Local => unreachable!(),
                };
                ensure(manhattan(nx, ny, tx, ty) == d0 - 1, || {
                    format!("unproductive candidate {dir:?} from ({x},{y}) to ({tx},{ty})")
                })?;
            }
            Ok(())
        });
    }

    #[test]
    fn west_first_never_turns_from_ns_to_west() {
        // The turn-model invariant: once a packet has moved N/S (meaning
        // tx >= x at that point), route_ports never returns West again for
        // any position reachable by following candidates.
        forall(200, |rng| {
            let w = 2 + rng.below_usize(7);
            let h = 2 + rng.below_usize(7);
            let (mut x, mut y) = (rng.below_usize(w), rng.below_usize(h));
            let (tx, ty) = (rng.below_usize(w), rng.below_usize(h));
            let mut moved_ns = false;
            let mut out = [Dir::Local; 2];
            for _ in 0..(w + h) {
                let n = route_ports(x, y, tx, ty, &mut out);
                if n == 0 {
                    break;
                }
                // Take an arbitrary candidate (rng-chosen) to explore paths.
                let dir = out[rng.below_usize(n)];
                if dir == Dir::West && moved_ns {
                    return Err(format!("illegal S/N->W turn at ({x},{y})"));
                }
                match dir {
                    Dir::North => {
                        y -= 1;
                        moved_ns = true;
                    }
                    Dir::South => {
                        y += 1;
                        moved_ns = true;
                    }
                    Dir::East => x += 1,
                    Dir::West => x -= 1,
                    Dir::Local => {}
                }
            }
            Ok(())
        });
    }

    #[test]
    fn xy_routes_reach_destination() {
        forall(200, |rng| {
            let w = 2 + rng.below_usize(7);
            let h = 2 + rng.below_usize(7);
            let (mut x, mut y) = (rng.below_usize(w), rng.below_usize(h));
            let (tx, ty) = (rng.below_usize(w), rng.below_usize(h));
            for _ in 0..(w + h) {
                match route_xy(x, y, tx, ty) {
                    Dir::Local => break,
                    Dir::North => y -= 1,
                    Dir::South => y += 1,
                    Dir::East => x += 1,
                    Dir::West => x -= 1,
                }
            }
            ensure((x, y) == (tx, ty), || "XY did not arrive".into())
        });
    }
}
