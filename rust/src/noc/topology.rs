//! Network topologies: the link structure connecting the PE routers.
//!
//! The paper evaluates a fixed 2D mesh, but en-route execution is
//! fundamentally a *network* story — where messages travel determines which
//! idle PEs can claim work — so the fabric abstracts the link structure
//! behind the [`Topology`] trait. Four implementations share the same
//! router microarchitecture (input buffers, On/Off flow control, separable
//! allocator) over different link sets:
//!
//! - [`Mesh2D`] — the paper's mesh. The default, and **bit-identical** to
//!   the pre-topology simulator: its routing methods delegate verbatim to
//!   [`route_ports`] / [`route_xy`].
//! - [`Torus2D`] — mesh plus wraparound links on both axes. Routed with
//!   shortest-wrap dimension-order routing; the rings are kept
//!   deadlock-free with bubble flow control (see
//!   [`Topology::requires_bubble`]).
//! - [`Ruche`] — mesh plus long-range skip links of a configurable stride
//!   in all four compass directions (ports 5–8), the ruche-network idea:
//!   express physical channels that cut hop counts for long flows. Routing
//!   stays west-first (all westward motion — short or long — happens first
//!   and deterministically), so the turn-model deadlock-freedom argument
//!   carries over unchanged.
//! - [`Chiplet2L`] — the mesh partitioned into chiplet tiles
//!   (DCRA-style): links crossing a tile boundary pay a configurable
//!   multi-cycle latency, modeling slower inter-chip SerDes hops. The link
//!   *structure* and routing are the mesh's; only per-hop latency differs.
//!
//! Deadlock freedom per variant:
//!
//! - mesh / ruche / chiplet: west-first turn model (the prohibited
//!   N/S→W turns are never taken because all westward motion is emitted
//!   first and deterministically; ruche west skips are part of that same
//!   westward phase).
//! - torus: dimension-order (X then Y) shortest-wrap routing removes
//!   cross-dimension cycles; within each unidirectional ring, bubble flow
//!   control — a flit *entering* a ring needs two free slots downstream,
//!   a flit *continuing* along a ring needs one — guarantees the ring can
//!   never fill completely, so some flit can always advance.

use crate::config::{ArchConfig, TopologyKind};
use crate::noc::router::MAX_PORTS;
use crate::noc::routing::{manhattan, route_ports, route_xy, Dir};

/// Directed links per PE in the flattened per-link stats table: one slot
/// per non-local output port (ports `1..MAX_PORTS`), whether or not the
/// topology wires it.
pub const LINKS_PER_PE: usize = MAX_PORTS - 1;

/// Index of the directed link leaving PE `from` through `dir` in a flat
/// `num_pes * LINKS_PER_PE` table (see
/// [`crate::fabric::stats::FabricStats::link_flits`]).
#[inline]
pub fn link_index(from: usize, dir: Dir) -> usize {
    debug_assert!(dir != Dir::Local, "local port is not a link");
    from * LINKS_PER_PE + (dir.port() - 1)
}

/// One directed link of a topology, as enumerated by [`Topology::links`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Link {
    /// Source PE id.
    pub from: usize,
    /// Destination PE id.
    pub to: usize,
    /// Output direction at the source router.
    pub dir: Dir,
    /// Traversal latency in cycles (>= 1).
    pub latency: usize,
}

/// The link structure connecting the routers, plus the (topology-specific)
/// route computation over it.
///
/// Implementations are pure geometry: no per-flit state lives here, so a
/// single instance serves the whole fabric and the fabric can precompute
/// neighbor/latency tables from it at construction.
pub trait Topology: Send + Sync {
    /// Which [`TopologyKind`] this instance implements.
    fn kind(&self) -> TopologyKind;

    /// Number of PEs (routers) in the fabric.
    fn num_pes(&self) -> usize;

    /// Number of router ports this topology wires (local port included).
    /// The mesh family uses 5; ruche adds four skip ports for 9.
    fn num_ports(&self) -> usize;

    /// The PE reached by leaving `id` through `dir`, or `None` when that
    /// output is not wired (mesh boundary, unwired ruche port, degenerate
    /// torus axis of extent 1).
    fn neighbor(&self, id: usize, dir: Dir) -> Option<usize>;

    /// Candidate output directions for one hop from `from` toward `to`,
    /// written to `out[..n]`. `n == 0` means the packet has arrived. Every
    /// candidate is strictly productive (reduces [`Topology::distance`])
    /// and points at a wired link; with `n == 2` the router picks
    /// adaptively by downstream congestion.
    fn route_candidates(&self, from: usize, to: usize, out: &mut [Dir; 2]) -> usize;

    /// Deterministic (dimension-order) route for one hop, used by
    /// [`crate::config::RoutingPolicy::Xy`] and the Valiant legs.
    /// Returns [`Dir::Local`] on arrival.
    fn route_deterministic(&self, from: usize, to: usize) -> Dir;

    /// Traversal latency in cycles of the link leaving `id` through `dir`
    /// (meaningful only for wired links; >= 1).
    fn hop_latency(&self, _id: usize, _dir: Dir) -> usize {
        1
    }

    /// Minimal hop count from `from` to `to` over this topology's links.
    fn distance(&self, from: usize, to: usize) -> usize;

    /// Whether the fabric must apply bubble flow control (ring entries
    /// need two free downstream slots; in-ring continuations need one and
    /// bypass On/Off backpressure). Only the torus sets this.
    fn requires_bubble(&self) -> bool {
        false
    }

    /// Enumerate every directed link, in `(pe id, port)` order.
    fn links(&self) -> Vec<Link> {
        let mut out = Vec::new();
        for id in 0..self.num_pes() {
            for port in 1..self.num_ports() {
                let dir = Dir::from_port(port);
                if let Some(to) = self.neighbor(id, dir) {
                    out.push(Link { from: id, to, dir, latency: self.hop_latency(id, dir) });
                }
            }
        }
        out
    }
}

/// Build the topology selected by `cfg.topology` over `cfg`'s array
/// geometry. The config must already be validated.
pub fn build_topology(cfg: &ArchConfig) -> Box<dyn Topology> {
    match cfg.topology {
        TopologyKind::Mesh2D => Box::new(Mesh2D::new(cfg.width, cfg.height)),
        TopologyKind::Torus2D => Box::new(Torus2D::new(cfg.width, cfg.height)),
        TopologyKind::Ruche => Box::new(Ruche::new(cfg.width, cfg.height, cfg.ruche_stride)),
        TopologyKind::Chiplet2L => Box::new(Chiplet2L::new(
            cfg.width,
            cfg.height,
            cfg.chiplet_dims,
            cfg.inter_chiplet_latency,
        )),
    }
}

/// Shared geometry helpers for the grid-based implementations.
#[derive(Debug, Clone, Copy)]
struct Grid {
    width: usize,
    height: usize,
}

impl Grid {
    #[inline]
    fn xy(&self, id: usize) -> (usize, usize) {
        (id % self.width, id / self.width)
    }

    #[inline]
    fn id(&self, x: usize, y: usize) -> usize {
        y * self.width + x
    }

    /// Mesh neighbor (boundary-checked) for the five mesh directions;
    /// `None` for ruche ports.
    fn mesh_neighbor(&self, id: usize, dir: Dir) -> Option<usize> {
        let (x, y) = self.xy(id);
        match dir {
            Dir::North if y > 0 => Some(self.id(x, y - 1)),
            Dir::South if y + 1 < self.height => Some(self.id(x, y + 1)),
            Dir::East if x + 1 < self.width => Some(self.id(x + 1, y)),
            Dir::West if x > 0 => Some(self.id(x - 1, y)),
            _ => None,
        }
    }
}

/// The paper's 2D mesh (bit-identical to the pre-topology simulator: the
/// routing methods delegate to the original [`route_ports`] /
/// [`route_xy`] functions).
pub struct Mesh2D {
    grid: Grid,
}

impl Mesh2D {
    pub fn new(width: usize, height: usize) -> Self {
        Self { grid: Grid { width, height } }
    }
}

impl Topology for Mesh2D {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Mesh2D
    }

    fn num_pes(&self) -> usize {
        self.grid.width * self.grid.height
    }

    fn num_ports(&self) -> usize {
        5
    }

    fn neighbor(&self, id: usize, dir: Dir) -> Option<usize> {
        self.grid.mesh_neighbor(id, dir)
    }

    fn route_candidates(&self, from: usize, to: usize, out: &mut [Dir; 2]) -> usize {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        route_ports(x, y, tx, ty, out)
    }

    fn route_deterministic(&self, from: usize, to: usize) -> Dir {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        route_xy(x, y, tx, ty)
    }

    fn distance(&self, from: usize, to: usize) -> usize {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        manhattan(x, y, tx, ty)
    }
}

/// 2D torus: the mesh plus wraparound links on both axes.
///
/// Routing is shortest-wrap dimension-order (X fully, then Y): each axis
/// moves in the direction of fewer wrap hops, ties broken toward
/// East/South. Re-computed per hop this is monotone — the chosen direction
/// never flips mid-axis — so the route is a minimal dimension-ordered
/// path. Deadlock freedom comes from bubble flow control on the rings
/// ([`Topology::requires_bubble`]), enforced by the fabric's crossbar.
pub struct Torus2D {
    grid: Grid,
}

impl Torus2D {
    pub fn new(width: usize, height: usize) -> Self {
        Self { grid: Grid { width, height } }
    }

    /// Direction of the shorter wrap along one axis of extent `n`, from
    /// coordinate `c` to `t` (`None` when already aligned). Returns
    /// `(positive, hops)` where `positive` means +1 steps (East/South).
    #[inline]
    fn axis_dir(n: usize, c: usize, t: usize) -> Option<(bool, usize)> {
        if c == t || n < 2 {
            return None;
        }
        let fwd = (t + n - c) % n; // hops moving +1 (East/South)
        let back = n - fwd; // hops moving -1 (West/North)
        if fwd <= back {
            Some((true, fwd))
        } else {
            Some((false, back))
        }
    }
}

impl Topology for Torus2D {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Torus2D
    }

    fn num_pes(&self) -> usize {
        self.grid.width * self.grid.height
    }

    fn num_ports(&self) -> usize {
        5
    }

    fn neighbor(&self, id: usize, dir: Dir) -> Option<usize> {
        let Grid { width: w, height: h } = self.grid;
        let (x, y) = self.grid.xy(id);
        // Axes of extent 1 have no links (a self-loop would be degenerate).
        match dir {
            Dir::North if h > 1 => Some(self.grid.id(x, (y + h - 1) % h)),
            Dir::South if h > 1 => Some(self.grid.id(x, (y + 1) % h)),
            Dir::East if w > 1 => Some(self.grid.id((x + 1) % w, y)),
            Dir::West if w > 1 => Some(self.grid.id((x + w - 1) % w, y)),
            _ => None,
        }
    }

    fn route_candidates(&self, from: usize, to: usize, out: &mut [Dir; 2]) -> usize {
        // Dimension-order shortest-wrap: a single deterministic candidate
        // (adaptivity on torus rings is not covered by the turn-model
        // deadlock argument, so none is offered).
        let d = self.route_deterministic(from, to);
        if d == Dir::Local {
            0
        } else {
            out[0] = d;
            1
        }
    }

    fn route_deterministic(&self, from: usize, to: usize) -> Dir {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        if let Some((positive, _)) = Self::axis_dir(self.grid.width, x, tx) {
            return if positive { Dir::East } else { Dir::West };
        }
        if let Some((positive, _)) = Self::axis_dir(self.grid.height, y, ty) {
            return if positive { Dir::South } else { Dir::North };
        }
        Dir::Local
    }

    fn distance(&self, from: usize, to: usize) -> usize {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        let dx = Self::axis_dir(self.grid.width, x, tx).map_or(0, |(_, d)| d);
        let dy = Self::axis_dir(self.grid.height, y, ty).map_or(0, |(_, d)| d);
        dx + dy
    }

    fn requires_bubble(&self) -> bool {
        true
    }
}

/// Ruche network: the mesh plus skip links of stride `stride` in all four
/// compass directions (router ports 5–8).
///
/// Routing extends west-first: when the remaining distance along an axis
/// is at least the stride, the long link is taken (the stride-length jump
/// is then guaranteed to stay inside the array); otherwise the mesh link.
/// All westward motion — short or long — remains first and deterministic,
/// so the adaptive set never contains a westward move after a N/S move
/// and the turn-model deadlock-freedom argument is unchanged.
pub struct Ruche {
    grid: Grid,
    stride: usize,
}

impl Ruche {
    pub fn new(width: usize, height: usize, stride: usize) -> Self {
        debug_assert!(stride >= 2, "stride 1 is a plain mesh link");
        Self { grid: Grid { width, height }, stride }
    }

    /// Hops to cover `d` positions along one axis: long links for the
    /// quotient, mesh links for the remainder.
    #[inline]
    fn axis_hops(&self, d: usize) -> usize {
        d / self.stride + d % self.stride
    }
}

impl Topology for Ruche {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Ruche
    }

    fn num_pes(&self) -> usize {
        self.grid.width * self.grid.height
    }

    fn num_ports(&self) -> usize {
        MAX_PORTS
    }

    fn neighbor(&self, id: usize, dir: Dir) -> Option<usize> {
        let Grid { width: w, height: h } = self.grid;
        let (x, y) = self.grid.xy(id);
        let s = self.stride;
        match dir {
            Dir::RucheNorth if y >= s => Some(self.grid.id(x, y - s)),
            Dir::RucheSouth if y + s < h => Some(self.grid.id(x, y + s)),
            Dir::RucheEast if x + s < w => Some(self.grid.id(x + s, y)),
            Dir::RucheWest if x >= s => Some(self.grid.id(x - s, y)),
            Dir::RucheNorth | Dir::RucheSouth | Dir::RucheEast | Dir::RucheWest => None,
            _ => self.grid.mesh_neighbor(id, dir),
        }
    }

    fn route_candidates(&self, from: usize, to: usize, out: &mut [Dir; 2]) -> usize {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        let s = self.stride;
        if tx < x {
            // Westward motion first and deterministically (west-first);
            // x - tx >= s implies x >= s, so the long link exists.
            out[0] = if x - tx >= s { Dir::RucheWest } else { Dir::West };
            return 1;
        }
        let mut n = 0;
        if tx > x {
            // tx - x >= s implies x + s <= tx < width: link exists.
            out[n] = if tx - x >= s { Dir::RucheEast } else { Dir::East };
            n += 1;
        }
        if ty < y {
            out[n] = if y - ty >= s { Dir::RucheNorth } else { Dir::North };
            n += 1;
        } else if ty > y {
            out[n] = if ty - y >= s { Dir::RucheSouth } else { Dir::South };
            n += 1;
        }
        n
    }

    fn route_deterministic(&self, from: usize, to: usize) -> Dir {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        let s = self.stride;
        if tx > x {
            if tx - x >= s {
                Dir::RucheEast
            } else {
                Dir::East
            }
        } else if tx < x {
            if x - tx >= s {
                Dir::RucheWest
            } else {
                Dir::West
            }
        } else if ty > y {
            if ty - y >= s {
                Dir::RucheSouth
            } else {
                Dir::South
            }
        } else if ty < y {
            if y - ty >= s {
                Dir::RucheNorth
            } else {
                Dir::North
            }
        } else {
            Dir::Local
        }
    }

    fn distance(&self, from: usize, to: usize) -> usize {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        self.axis_hops(x.abs_diff(tx)) + self.axis_hops(y.abs_diff(ty))
    }
}

/// Two-level chiplet hierarchy: the mesh partitioned into `cw x ch` tiles
/// (DCRA-style), with links crossing a tile boundary paying `latency`
/// cycles per hop instead of 1.
///
/// Link structure and routing are exactly the mesh's (so the west-first
/// deadlock argument applies verbatim); the slower boundary links model
/// inter-chip SerDes and also throttle boundary *bandwidth* to one flit
/// per `latency` cycles, since a router input's staging slot stays held
/// for the whole traversal.
pub struct Chiplet2L {
    grid: Grid,
    tile: (usize, usize),
    latency: usize,
}

impl Chiplet2L {
    pub fn new(width: usize, height: usize, tile: (usize, usize), latency: usize) -> Self {
        debug_assert!(tile.0 > 0 && tile.1 > 0 && width % tile.0 == 0 && height % tile.1 == 0);
        debug_assert!(latency >= 1);
        Self { grid: Grid { width, height }, tile, latency }
    }

    /// Chiplet tile coordinates of a PE.
    #[inline]
    fn tile_of(&self, id: usize) -> (usize, usize) {
        let (x, y) = self.grid.xy(id);
        (x / self.tile.0, y / self.tile.1)
    }
}

impl Topology for Chiplet2L {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Chiplet2L
    }

    fn num_pes(&self) -> usize {
        self.grid.width * self.grid.height
    }

    fn num_ports(&self) -> usize {
        5
    }

    fn neighbor(&self, id: usize, dir: Dir) -> Option<usize> {
        self.grid.mesh_neighbor(id, dir)
    }

    fn route_candidates(&self, from: usize, to: usize, out: &mut [Dir; 2]) -> usize {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        route_ports(x, y, tx, ty, out)
    }

    fn route_deterministic(&self, from: usize, to: usize) -> Dir {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        route_xy(x, y, tx, ty)
    }

    fn hop_latency(&self, id: usize, dir: Dir) -> usize {
        match self.neighbor(id, dir) {
            Some(to) if self.tile_of(id) != self.tile_of(to) => self.latency,
            _ => 1,
        }
    }

    fn distance(&self, from: usize, to: usize) -> usize {
        let (x, y) = self.grid.xy(from);
        let (tx, ty) = self.grid.xy(to);
        manhattan(x, y, tx, ty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    fn follow(topo: &dyn Topology, from: usize, to: usize, adaptive: bool) -> Result<usize, String> {
        // Walk route candidates (first candidate, or deterministic route)
        // until arrival; returns hop count, errs on unproductive steps.
        let mut at = from;
        let mut hops = 0;
        let mut out = [Dir::Local; 2];
        let bound = topo.distance(from, to);
        while at != to {
            let dir = if adaptive {
                let n = topo.route_candidates(at, to, &mut out);
                ensure(n >= 1, || format!("no candidate at {at} toward {to}"))?;
                for &d in &out[..n] {
                    let nb = topo
                        .neighbor(at, d)
                        .ok_or_else(|| format!("candidate {d:?} at {at} is unwired"))?;
                    ensure(topo.distance(nb, to) < topo.distance(at, to), || {
                        format!("unproductive candidate {d:?} at {at} toward {to}")
                    })?;
                }
                out[0]
            } else {
                topo.route_deterministic(at, to)
            };
            ensure(dir != Dir::Local, || format!("stalled at {at} toward {to}"))?;
            at = topo.neighbor(at, dir).ok_or_else(|| format!("unwired {dir:?} at {at}"))?;
            hops += 1;
            ensure(hops <= bound, || format!("route {from}->{to} exceeded distance {bound}"))?;
        }
        Ok(hops)
    }

    /// Every topology, every (src, dst) pair on small arrays: both the
    /// adaptive candidates and the deterministic route arrive within
    /// exactly `distance()` hops, and all candidates are productive.
    #[test]
    fn all_topologies_route_minimally() {
        let dims = [(1, 6), (6, 1), (2, 2), (4, 4), (5, 3)];
        for (w, h) in dims {
            let topos: Vec<Box<dyn Topology>> = vec![
                Box::new(Mesh2D::new(w, h)),
                Box::new(Torus2D::new(w, h)),
                Box::new(Ruche::new(w, h, 2)),
                Box::new(Chiplet2L::new(w, h, (w, h), 4)),
            ];
            for topo in &topos {
                for from in 0..topo.num_pes() {
                    for to in 0..topo.num_pes() {
                        for adaptive in [true, false] {
                            let hops = follow(topo.as_ref(), from, to, adaptive)
                                .unwrap_or_else(|e| {
                                    panic!("{:?} {w}x{h}: {e}", topo.kind());
                                });
                            assert_eq!(
                                hops,
                                topo.distance(from, to),
                                "{:?} {w}x{h} {from}->{to} not minimal",
                                topo.kind()
                            );
                        }
                    }
                }
            }
        }
    }

    /// The mesh implementation is the pre-refactor router: candidates and
    /// deterministic routes match the free functions exactly, and
    /// neighbors match the original boundary arithmetic.
    #[test]
    fn mesh_matches_pre_refactor_functions() {
        forall(200, |rng| {
            let w = 1 + rng.below_usize(8);
            let h = 1 + rng.below_usize(8);
            let topo = Mesh2D::new(w, h);
            for id in 0..w * h {
                let (x, y) = (id % w, id / w);
                for to in 0..w * h {
                    let (tx, ty) = (to % w, to / w);
                    let mut a = [Dir::Local; 2];
                    let mut b = [Dir::Local; 2];
                    let na = topo.route_candidates(id, to, &mut a);
                    let nb = route_ports(x, y, tx, ty, &mut b);
                    ensure(na == nb && a == b, || format!("route_ports diverged {id}->{to}"))?;
                    ensure(topo.route_deterministic(id, to) == route_xy(x, y, tx, ty), || {
                        format!("route_xy diverged {id}->{to}")
                    })?;
                }
                for (dir, wired) in [
                    (Dir::North, y > 0),
                    (Dir::South, y + 1 < h),
                    (Dir::East, x + 1 < w),
                    (Dir::West, x > 0),
                ] {
                    ensure(topo.neighbor(id, dir).is_some() == wired, || {
                        format!("mesh neighbor {dir:?} at ({x},{y}) wiring diverged")
                    })?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn torus_wraps_and_shortens() {
        let t = Torus2D::new(4, 4);
        // Wraparound links exist at the boundary.
        assert_eq!(t.neighbor(0, Dir::West), Some(3));
        assert_eq!(t.neighbor(0, Dir::North), Some(12));
        assert_eq!(t.neighbor(3, Dir::East), Some(0));
        assert_eq!(t.neighbor(12, Dir::South), Some(0));
        // Corner-to-corner is 2 hops on the torus vs 6 on the mesh.
        let m = Mesh2D::new(4, 4);
        assert_eq!(t.distance(0, 15), 2);
        assert_eq!(m.distance(0, 15), 6);
        // Ties break East/South (deterministic, monotone).
        let t2 = Torus2D::new(4, 1);
        assert_eq!(t2.route_deterministic(0, 2), Dir::East);
        assert!(t.requires_bubble() && !m.requires_bubble());
    }

    #[test]
    fn ruche_skips_cut_hops() {
        let r = Ruche::new(8, 8, 3);
        // Long links exist exactly where a stride jump stays in-array.
        assert_eq!(r.neighbor(0, Dir::RucheEast), Some(3));
        assert_eq!(r.neighbor(0, Dir::RucheWest), None);
        assert_eq!(r.neighbor(63, Dir::RucheWest), Some(60));
        assert_eq!(r.neighbor(63, Dir::RucheSouth), None);
        // 7 east + 7 south = (2 long + 1 short) * 2 axes = 6 hops vs 14.
        assert_eq!(r.distance(0, 63), 6);
        assert_eq!(Mesh2D::new(8, 8).distance(0, 63), 14);
        // Westward routing is still single-candidate (west-first).
        let mut out = [Dir::Local; 2];
        assert_eq!(r.route_candidates(7, 0, &mut out), 1);
        assert_eq!(out[0], Dir::RucheWest);
        assert_eq!(r.route_candidates(1, 0, &mut out), 1);
        assert_eq!(out[0], Dir::West);
    }

    #[test]
    fn chiplet_boundary_links_are_slow() {
        let c = Chiplet2L::new(8, 8, (4, 4), 5);
        // PE 3 -> PE 4 crosses the vertical tile boundary.
        assert_eq!(c.hop_latency(3, Dir::East), 5);
        assert_eq!(c.hop_latency(4, Dir::West), 5);
        // Interior hops stay single-cycle.
        assert_eq!(c.hop_latency(0, Dir::East), 1);
        assert_eq!(c.hop_latency(3, Dir::South), 1);
        // PE 27 (x=3,y=3) -> South crosses the horizontal boundary.
        assert_eq!(c.hop_latency(27, Dir::South), 5);
        // Routing itself is the mesh's.
        assert_eq!(c.distance(0, 63), 14);
    }

    #[test]
    fn link_enumeration_counts() {
        // Directed mesh links: 2 per interior edge.
        let m = Mesh2D::new(4, 3);
        assert_eq!(m.links().len(), 2 * (4 * 2 + 3 * 3));
        // Torus (extent >= 2 both axes): every PE has 4 out-links.
        assert_eq!(Torus2D::new(4, 3).links().len(), 4 * 12);
        // Degenerate 1-wide torus: only the N/S ring remains.
        assert_eq!(Torus2D::new(1, 4).links().len(), 2 * 4);
        // Ruche = mesh links + skip links.
        let r = Ruche::new(4, 4, 2);
        let mesh_links = 2 * (4 * 3 + 4 * 3);
        let skip_links = 2 * (4 * 2 + 4 * 2); // 2 east starts per row, etc.
        assert_eq!(r.links().len(), mesh_links + skip_links);
        // Every enumerated link is wired, latency >= 1, and indexable.
        for topo in [
            Box::new(Chiplet2L::new(4, 4, (2, 2), 3)) as Box<dyn Topology>,
            Box::new(r),
        ] {
            for l in topo.links() {
                assert_eq!(topo.neighbor(l.from, l.dir), Some(l.to));
                assert!(l.latency >= 1);
                assert!(link_index(l.from, l.dir) < topo.num_pes() * LINKS_PER_PE);
            }
        }
    }

    #[test]
    fn build_topology_respects_config() {
        let mut cfg = ArchConfig::nexus().with_array(8, 8);
        for kind in TopologyKind::ALL {
            cfg.topology = kind;
            cfg.validate().unwrap();
            let topo = build_topology(&cfg);
            assert_eq!(topo.kind(), kind);
            assert_eq!(topo.num_pes(), 64);
        }
    }
}
