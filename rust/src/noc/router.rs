//! The mesh router of §3.3.2, generalized to a configurable port count.
//!
//! In the paper's mesh each router has five input ports (Local/injection,
//! N, E, S, W) and five output ports. Every input port buffers up to
//! `depth` (default 3) single-flit messages — "each input port has a buffer
//! comprising three registers", chosen to minimize power. Route computation
//! compares the head flit's target with the router's position; a separable
//! allocator (input-first then output arbitration with rotating priority)
//! resolves conflicts; winners traverse the crossbar.
//!
//! Non-mesh topologies (see [`super::topology`]) reuse the identical
//! microarchitecture over different link sets: a ruche network wires four
//! extra skip ports (up to [`MAX_PORTS`] total), a chiplet hierarchy
//! delivers staged flits after a multi-cycle link latency
//! ([`Router::stage_delayed`]), and a torus keeps its rings deadlock-free
//! with bubble flow control built on [`Router::can_transit`].
//!
//! **On/Off congestion control** (§3.3.2): a port advertises OFF when its
//! free space drops to `T_off = 1` and ON again at `T_on = 2`; upstream
//! routers only forward to ON ports. The hysteresis state is updated at
//! cycle commit and consumed the next cycle, modeling one cycle of signal
//! latency.
//!
//! **Bubble rule** (§3.4): new injections from the AM NIC must leave one
//! buffer slot free (injection requires 2 free slots; transit needs 1), the
//! bubble-flow-control condition that keeps the ring of buffer dependencies
//! from ever filling completely.

use crate::am::Message;

pub const PORT_LOCAL: usize = 0;
pub const PORT_N: usize = 1;
pub const PORT_E: usize = 2;
pub const PORT_S: usize = 3;
pub const PORT_W: usize = 4;
pub const NUM_PORTS: usize = 5;

/// Largest port count any topology wires: the 5 mesh ports plus 4 ruche
/// skip ports (see [`super::routing::Dir`]).
pub const MAX_PORTS: usize = 9;

/// Port names for reports (Fig 14's x-axis categories).
pub const PORT_NAMES: [&str; NUM_PORTS] = ["NIC", "North", "East", "South", "West"];

/// Fold a physical port index into one of the [`NUM_PORTS`] report
/// categories: ruche skip ports count toward their compass heading
/// (RucheNorth -> North, ...), so Fig 14's per-port congestion series keep
/// their meaning on every topology.
#[inline]
pub fn port_class(port: usize) -> usize {
    if port >= NUM_PORTS {
        port - 4
    } else {
        port
    }
}

/// Maximum supported buffer depth (fixed-capacity ring, no heap in the hot
/// loop). Config depth must be <= this.
pub const MAX_DEPTH: usize = 8;

/// Fixed-capacity message ring buffer (one per input port).
#[derive(Debug, Clone)]
pub struct FlitBuf {
    slots: [Option<Message>; MAX_DEPTH],
    head: usize,
    len: usize,
    depth: usize,
}

impl FlitBuf {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1 && depth <= MAX_DEPTH);
        FlitBuf {
            slots: [None; MAX_DEPTH],
            head: 0,
            len: 0,
            depth,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn free(&self) -> usize {
        self.depth - self.len
    }

    #[inline]
    pub fn push(&mut self, m: Message) -> bool {
        if self.len == self.depth {
            return false;
        }
        let tail = (self.head + self.len) % self.depth;
        self.slots[tail] = Some(m);
        self.len += 1;
        true
    }

    #[inline]
    pub fn head_msg(&self) -> Option<&Message> {
        if self.len == 0 {
            None
        } else {
            self.slots[self.head].as_ref()
        }
    }

    #[inline]
    pub fn head_msg_mut(&mut self) -> Option<&mut Message> {
        if self.len == 0 {
            None
        } else {
            self.slots[self.head].as_mut()
        }
    }

    #[inline]
    pub fn pop(&mut self) -> Option<Message> {
        if self.len == 0 {
            return None;
        }
        let m = self.slots[self.head].take();
        self.head = (self.head + 1) % self.depth;
        self.len -= 1;
        m
    }

    /// Iterate over buffered messages (head first) — used by conservation
    /// checks and the termination detector.
    pub fn iter(&self) -> impl Iterator<Item = &Message> {
        (0..self.len).map(move |i| {
            self.slots[(self.head + i) % self.depth]
                .as_ref()
                .expect("ring invariant")
        })
    }
}

/// Per-input-port congestion counters (Fig 14).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PortStats {
    /// Cycles in which this port held at least one flit.
    pub occupied_cycles: u64,
    /// Cycles in which the head flit failed to win allocation (or its
    /// downstream was OFF/full) — the congestion signal of Fig 14.
    pub blocked_cycles: u64,
    /// Flits accepted into this port.
    pub flits_in: u64,
}

/// Epoch-start snapshot of one input port's acceptance state, taken at
/// cycle commit for ports that terminate a **shard-crossing** link in the
/// sharded fabric. During the next cycle's phase pass, the upstream shard
/// scores and admits boundary flits against this snapshot instead of the
/// neighbor's live state, so boundary decisions are independent of the
/// order (and thread interleaving) in which shards step.
///
/// Using a snapshot is conservative-safe: mid-cycle the destination port's
/// occupancy can only *shrink* (its own route phase pops flits; the unique
/// upstream router for the port is the snapshot reader itself), so a flit
/// admitted against the snapshot always finds the space the snapshot
/// promised at the epoch barrier.
#[derive(Debug, Clone, Copy)]
pub struct PortSnap {
    /// Advertised On/Off state ([`Router::on_state`]).
    pub on: bool,
    /// Staging slot held (in-flight or landing flit).
    pub staged: bool,
    /// Free buffer slots ([`FlitBuf::free`]; fits u8 since depth <=
    /// [`MAX_DEPTH`]).
    pub free: u8,
}

impl PortSnap {
    /// Snapshot of a port on a fresh (empty, ON) router of `depth` buffers.
    pub fn fresh(depth: usize) -> Self {
        PortSnap {
            on: true,
            staged: false,
            free: depth as u8,
        }
    }

    /// [`Router::can_accept`] evaluated against the snapshot.
    #[inline]
    pub fn can_accept(&self) -> bool {
        self.on && !self.staged && self.free >= 1
    }

    /// [`Router::can_transit`] evaluated against the snapshot.
    #[inline]
    pub fn can_transit(&self) -> bool {
        !self.staged && self.free >= 1
    }

    /// [`Router::effective_free`] evaluated against the snapshot.
    #[inline]
    pub fn effective_free(&self) -> usize {
        self.free as usize - usize::from(self.staged)
    }
}

/// One router (mesh or extended-port variant).
#[derive(Debug, Clone)]
pub struct Router {
    /// Input buffers indexed by port (`Dir::port()` order; length is the
    /// topology's port count).
    pub inputs: Vec<FlitBuf>,
    /// On/Off state advertised to upstream for each *input* port, as sampled
    /// at the end of the previous cycle. `true` = ON (may receive).
    pub on_state: Vec<bool>,
    /// Rotating-priority pointer for output arbitration (separable
    /// allocator's second stage).
    pub rr_ptr: Vec<usize>,
    /// Staged incoming flits (one per input port) applied at commit — links
    /// deliver at most one flit per cycle.
    pub staging: Vec<Option<Message>>,
    /// Remaining cycles before the staged flit on each port lands in its
    /// buffer (0 = lands at the next commit; multi-cycle chiplet links
    /// stage with a positive wait). While positive, the staging slot stays
    /// held, which also throttles the link to one flit per `latency`.
    pub staging_wait: Vec<u8>,
    /// Per-port congestion stats.
    pub stats: Vec<PortStats>,
    /// Head-of-line flit locked this cycle by en-route execution (port id).
    pub locked_port: Option<usize>,
    /// Occupancy changed since the last commit (push or pop); lets commit
    /// skip the hysteresis scan for quiescent routers (EXPERIMENTS.md §Perf).
    pub dirty: bool,
    /// On/Off thresholds from the config.
    t_off: usize,
    t_on: usize,
}

impl Router {
    pub fn new(num_ports: usize, depth: usize, t_off: usize, t_on: usize) -> Self {
        assert!((NUM_PORTS..=MAX_PORTS).contains(&num_ports));
        Router {
            inputs: (0..num_ports).map(|_| FlitBuf::new(depth)).collect(),
            on_state: vec![true; num_ports],
            rr_ptr: vec![0; num_ports],
            staging: vec![None; num_ports],
            staging_wait: vec![0; num_ports],
            stats: vec![PortStats::default(); num_ports],
            locked_port: None,
            dirty: false,
            t_off,
            t_on,
        }
    }

    /// Number of ports this router wires (set by the topology).
    #[inline]
    pub fn num_ports(&self) -> usize {
        self.inputs.len()
    }

    /// Effective free space of an input port including its staged flit.
    #[inline]
    pub fn effective_free(&self, port: usize) -> usize {
        self.inputs[port].free() - usize::from(self.staging[port].is_some())
    }

    /// Can a neighbor forward a flit into `port` this cycle? Requires the
    /// advertised ON state and physical space (link delivers one per cycle).
    #[inline]
    pub fn can_accept(&self, port: usize) -> bool {
        self.on_state[port] && self.staging[port].is_none() && self.inputs[port].free() >= 1
    }

    /// Can the AM NIC inject this cycle? Bubble rule: keep one slot free
    /// after injection.
    #[inline]
    pub fn can_inject(&self) -> bool {
        self.staging[PORT_LOCAL].is_none() && self.inputs[PORT_LOCAL].free() >= 2
    }

    /// Physical-space-only acceptance test, ignoring the advertised On/Off
    /// state. Used by torus bubble flow control: a flit *continuing* along
    /// a ring may advance whenever there is space, because ring entries
    /// (which respect both On/Off and the two-slot bubble condition)
    /// guarantee the ring never fills.
    #[inline]
    pub fn can_transit(&self, port: usize) -> bool {
        self.staging[port].is_none() && self.inputs[port].free() >= 1
    }

    /// Stage a flit arriving on `port` (from a neighbor or the NIC).
    /// Caller must have checked `can_accept` / `can_inject`.
    #[inline]
    pub fn stage(&mut self, port: usize, m: Message) {
        debug_assert!(self.staging[port].is_none());
        self.staging[port] = Some(m);
        self.staging_wait[port] = 0;
        self.dirty = true;
    }

    /// Stage a flit that lands after `wait` further commits (multi-cycle
    /// chiplet links: a latency-L hop stages with `wait = L - 1`). The
    /// staging slot stays held for the whole traversal, so the link also
    /// carries at most one flit per L cycles.
    #[inline]
    pub fn stage_delayed(&mut self, port: usize, m: Message, wait: u8) {
        debug_assert!(self.staging[port].is_none());
        self.staging[port] = Some(m);
        self.staging_wait[port] = wait;
        self.dirty = true;
    }

    /// Pop the head flit of an input port, marking the router dirty so the
    /// next commit refreshes the On/Off hysteresis. Always use this (not
    /// `inputs[p].pop()`) when dequeuing.
    #[inline]
    pub fn pop_port(&mut self, port: usize) -> Option<Message> {
        let m = self.inputs[port].pop();
        if m.is_some() {
            self.dirty = true;
        }
        m
    }

    /// Commit staged flits into buffers and refresh the On/Off hysteresis
    /// for the next cycle. Called once per cycle by the fabric. A staged
    /// flit still in flight on a slow link (positive `staging_wait`) ticks
    /// down instead of landing, and keeps the router dirty (and hence
    /// awake) until it arrives.
    pub fn commit(&mut self) {
        if !self.dirty {
            self.locked_port = None;
            return;
        }
        self.dirty = false;
        for port in 0..self.inputs.len() {
            if self.staging[port].is_some() && self.staging_wait[port] > 0 {
                self.staging_wait[port] -= 1;
                self.dirty = true;
            } else if let Some(m) = self.staging[port].take() {
                let ok = self.inputs[port].push(m);
                debug_assert!(ok, "staging over full buffer");
                self.stats[port].flits_in += 1;
            }
            // Hysteresis: OFF when free <= T_off, ON when free >= T_on.
            let free = self.inputs[port].free();
            if free <= self.t_off {
                self.on_state[port] = false;
            } else if free >= self.t_on {
                self.on_state[port] = true;
            }
        }
        self.locked_port = None;
    }

    /// Snapshot one input port's acceptance state (see [`PortSnap`]).
    #[inline]
    pub fn port_snap(&self, port: usize) -> PortSnap {
        PortSnap {
            on: self.on_state[port],
            staged: self.staging[port].is_some(),
            free: self.inputs[port].free() as u8,
        }
    }

    /// Total flits currently buffered (for termination detection).
    pub fn occupancy(&self) -> usize {
        self.inputs.iter().map(|b| b.len()).sum::<usize>()
            + self.staging.iter().filter(|s| s.is_some()).count()
    }

    /// Record per-port occupancy/blocked stats for this cycle. `moved[p]`
    /// is true if port p's head flit departed this cycle.
    pub fn sample_stats(&mut self, moved: &[bool]) {
        for port in 0..self.inputs.len() {
            if !self.inputs[port].is_empty() {
                self.stats[port].occupied_cycles += 1;
                if !moved[port] {
                    self.stats[port].blocked_cycles += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::Message;

    fn msg(id: u64) -> Message {
        Message {
            id,
            ..Message::new()
        }
    }

    #[test]
    fn flitbuf_fifo_order() {
        let mut b = FlitBuf::new(3);
        assert!(b.push(msg(1)));
        assert!(b.push(msg(2)));
        assert!(b.push(msg(3)));
        assert!(!b.push(msg(4)), "over capacity");
        assert_eq!(b.pop().unwrap().id, 1);
        assert!(b.push(msg(4)));
        assert_eq!(b.pop().unwrap().id, 2);
        assert_eq!(b.pop().unwrap().id, 3);
        assert_eq!(b.pop().unwrap().id, 4);
        assert!(b.pop().is_none());
    }

    #[test]
    fn on_off_hysteresis() {
        let mut r = Router::new(NUM_PORTS, 3, 1, 2);
        assert!(r.can_accept(PORT_N));
        // Fill to 2 occupied (free = 1 <= T_off) => OFF after commit.
        r.stage(PORT_N, msg(1));
        r.commit();
        r.stage(PORT_N, msg(2));
        r.commit();
        assert_eq!(r.inputs[PORT_N].free(), 1);
        assert!(!r.on_state[PORT_N], "must advertise OFF at free=1");
        assert!(!r.can_accept(PORT_N));
        // Drain one (free = 2 >= T_on) => ON after commit.
        r.pop_port(PORT_N);
        r.commit();
        assert!(r.on_state[PORT_N]);
        assert!(r.can_accept(PORT_N));
    }

    #[test]
    fn bubble_rule_for_injection() {
        let mut r = Router::new(NUM_PORTS, 3, 1, 2);
        assert!(r.can_inject());
        r.stage(PORT_LOCAL, msg(1));
        assert!(!r.can_inject(), "one staged flit per cycle");
        r.commit();
        assert!(r.can_inject()); // 1 occupied, 2 free
        r.stage(PORT_LOCAL, msg(2));
        r.commit();
        // 2 occupied, 1 free: transit could still enter, injection cannot.
        assert!(!r.can_inject(), "bubble rule: need 2 free slots");
    }

    #[test]
    fn occupancy_counts_staging() {
        let mut r = Router::new(NUM_PORTS, 3, 1, 2);
        r.stage(PORT_E, msg(1));
        assert_eq!(r.occupancy(), 1);
        r.commit();
        assert_eq!(r.occupancy(), 1);
        r.pop_port(PORT_E);
        assert_eq!(r.occupancy(), 0);
    }

    #[test]
    fn delayed_staging_lands_after_wait() {
        // A latency-4 chiplet hop: stage with wait=3, flit lands on the
        // 4th commit; the staging slot is held (and the input refuses new
        // arrivals) for the whole traversal.
        let mut r = Router::new(NUM_PORTS, 3, 1, 2);
        r.stage_delayed(PORT_W, msg(7), 3);
        for step in 0..3 {
            assert!(!r.can_accept(PORT_W), "slot held in flight (step {step})");
            assert!(!r.can_transit(PORT_W));
            assert_eq!(r.occupancy(), 1);
            r.commit();
            assert!(r.inputs[PORT_W].is_empty(), "landed early at step {step}");
            assert!(r.dirty || step == 2, "in-flight flit must keep the router dirty");
        }
        r.commit();
        assert_eq!(r.inputs[PORT_W].len(), 1);
        assert_eq!(r.inputs[PORT_W].head_msg().unwrap().id, 7);
        assert_eq!(r.stats[PORT_W].flits_in, 1, "counted once, on landing");
        // wait=0 is exactly `stage`: lands at the next commit.
        let mut r2 = Router::new(NUM_PORTS, 3, 1, 2);
        r2.stage_delayed(PORT_N, msg(8), 0);
        r2.commit();
        assert_eq!(r2.inputs[PORT_N].len(), 1);
    }

    #[test]
    fn extended_ports_and_classes() {
        let mut r = Router::new(MAX_PORTS, 3, 1, 2);
        assert_eq!(r.num_ports(), MAX_PORTS);
        // Ruche ports behave like any other input.
        let ruche_n = 5;
        assert!(r.can_accept(ruche_n));
        r.stage(ruche_n, msg(1));
        r.commit();
        assert_eq!(r.inputs[ruche_n].len(), 1);
        // Report classes fold skip ports onto their compass heading.
        assert_eq!(port_class(PORT_LOCAL), PORT_LOCAL);
        assert_eq!(port_class(PORT_W), PORT_W);
        assert_eq!(port_class(5), PORT_N);
        assert_eq!(port_class(6), PORT_E);
        assert_eq!(port_class(7), PORT_S);
        assert_eq!(port_class(8), PORT_W);
    }

    #[test]
    fn port_snap_mirrors_live_acceptance_checks() {
        let mut r = Router::new(NUM_PORTS, 3, 1, 2);
        assert!(PortSnap::fresh(3).can_accept());
        assert_eq!(PortSnap::fresh(3).effective_free(), 3);
        // Walk the port through staged / filling / OFF states and require
        // the snapshot to agree with the live predicates at every step.
        for step in 0..4 {
            let s = r.port_snap(PORT_E);
            assert_eq!(s.can_accept(), r.can_accept(PORT_E), "step {step}");
            assert_eq!(s.can_transit(), r.can_transit(PORT_E), "step {step}");
            assert_eq!(s.effective_free(), r.effective_free(PORT_E), "step {step}");
            if r.can_accept(PORT_E) {
                r.stage(PORT_E, msg(step as u64));
                let staged = r.port_snap(PORT_E);
                assert!(staged.staged && !staged.can_accept(), "step {step}");
            }
            r.commit();
        }
        assert!(!r.port_snap(PORT_E).on, "filled port must snapshot OFF");
    }

    #[test]
    fn can_transit_ignores_on_state() {
        // Bubble continuation: physical space only. Fill to free=1 (OFF).
        let mut r = Router::new(NUM_PORTS, 3, 1, 2);
        r.stage(PORT_S, msg(1));
        r.commit();
        r.stage(PORT_S, msg(2));
        r.commit();
        assert!(!r.on_state[PORT_S], "free=1 advertises OFF");
        assert!(!r.can_accept(PORT_S), "entries respect On/Off");
        assert!(r.can_transit(PORT_S), "continuations only need space");
        r.stage(PORT_S, msg(3));
        r.commit();
        assert!(!r.can_transit(PORT_S), "full buffer blocks even transit");
    }

    #[test]
    fn flitbuf_matches_fifo_model_under_random_ops() {
        // Differential property: FlitBuf (fixed-capacity ring) must behave
        // exactly like an unbounded FIFO truncated at `depth`, with
        // push/pop conservation and free()+len()==depth at every step.
        use crate::util::prop::{ensure, forall};
        forall(256, |rng| {
            let depth = 1 + rng.below_usize(MAX_DEPTH);
            let mut buf = FlitBuf::new(depth);
            let mut model: std::collections::VecDeque<u64> = Default::default();
            let mut next_id = 1u64;
            let (mut pushed, mut popped) = (0u64, 0u64);
            for _ in 0..64 {
                if rng.chance(0.55) {
                    let ok = buf.push(msg(next_id));
                    let model_ok = model.len() < depth;
                    ensure(ok == model_ok, || {
                        format!("push acceptance diverged at len {}", model.len())
                    })?;
                    if ok {
                        model.push_back(next_id);
                        pushed += 1;
                    }
                    next_id += 1;
                } else {
                    let got = buf.pop().map(|m| m.id);
                    let want = model.pop_front();
                    ensure(got == want, || format!("pop diverged: {got:?} vs {want:?}"))?;
                    if got.is_some() {
                        popped += 1;
                    }
                }
                ensure(buf.len() == model.len(), || {
                    format!("len {} vs model {}", buf.len(), model.len())
                })?;
                ensure(buf.free() + buf.len() == depth, || {
                    format!("free {} + len {} != depth {depth}", buf.free(), buf.len())
                })?;
                ensure(
                    buf.head_msg().map(|m| m.id) == model.front().copied(),
                    || "head diverged from model".to_string(),
                )?;
                ensure(buf.iter().map(|m| m.id).eq(model.iter().copied()), || {
                    "iteration order diverged from model".to_string()
                })?;
            }
            ensure(pushed - popped == model.len() as u64, || {
                format!("conservation: pushed {pushed} - popped {popped} != held {}", model.len())
            })
        });
    }

    #[test]
    fn on_off_hysteresis_invariants_under_random_traffic() {
        // At every post-commit boundary: free <= T_off forces OFF, free >=
        // T_on forces ON, and inside the hysteresis band the advertised
        // state must hold its previous value (the memory that damps
        // ON/OFF oscillation, §3.3.2).
        use crate::util::prop::{ensure, forall};
        forall(256, |rng| {
            let depth = 2 + rng.below_usize(MAX_DEPTH - 1);
            let t_off = 1;
            let t_on = 2 + rng.below_usize(depth - 1); // 2..=depth
            let mut r = Router::new(NUM_PORTS, depth, t_off, t_on);
            let port = rng.below_usize(NUM_PORTS);
            let mut id = 1u64;
            let mut prev_on = true; // fresh routers advertise ON
            for _ in 0..48 {
                if rng.chance(0.6) && r.staging[port].is_none() && r.inputs[port].free() >= 1 {
                    r.stage(port, msg(id));
                    id += 1;
                }
                if rng.chance(0.4) {
                    r.pop_port(port);
                }
                r.commit();
                let free = r.inputs[port].free();
                let on = r.on_state[port];
                if free <= t_off {
                    ensure(!on, || format!("free={free} <= T_off={t_off} must be OFF"))?;
                } else if free >= t_on {
                    ensure(on, || format!("free={free} >= T_on={t_on} must be ON"))?;
                } else {
                    ensure(on == prev_on, || {
                        format!("free={free} in band ({t_off},{t_on}): state must hold")
                    })?;
                }
                // can_accept never contradicts the advertised state.
                ensure(!r.can_accept(port) || on, || "accepting while OFF".to_string())?;
                prev_on = on;
            }
            Ok(())
        });
    }
}
