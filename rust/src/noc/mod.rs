//! Network-on-Chip substrate: topology-parametric link geometry
//! ([`topology`]: mesh, torus, ruche, chiplet), routing functions
//! (west-first turn model with congestion-aware adaptivity, XY, Valiant,
//! shortest-wrap DOR for the torus), and the router of §3.3.2 with 3-flit
//! input buffers, a separable allocator, a crossbar abstraction, and
//! On/Off congestion control — generalized from five fixed mesh ports to
//! the topology's port count.

pub mod router;
pub mod routing;
pub mod topology;

pub use router::{Router, PORT_E, PORT_LOCAL, PORT_N, PORT_S, PORT_W};
pub use routing::{route_ports, Dir};
pub use topology::{build_topology, link_index, Link, Topology, LINKS_PER_PE};
