//! Network-on-Chip substrate: mesh geometry, routing functions (west-first
//! turn model with congestion-aware adaptivity, XY, Valiant), and the
//! five-port router of §3.3.2 with 3-flit input buffers, a separable
//! allocator, a 6x5 crossbar abstraction, and On/Off congestion control.

pub mod router;
pub mod routing;

pub use router::{Router, PORT_E, PORT_LOCAL, PORT_N, PORT_S, PORT_W};
pub use routing::{route_ports, Dir};
