//! Active Messages: the single-flit packets that carry instructions, operand
//! values/addresses, and a multi-destination route (Fig 7).
//!
//! Two representations exist:
//!
//! - [`Message`] — the unpacked struct the simulator moves around. It also
//!   carries simulator-only metadata (id, birth cycle, hop count) that has no
//!   hardware counterpart and is excluded from the packed format.
//! - [`packed`] — the 70-bit wire format of Fig 7, with exact field widths,
//!   used by the codegen (AM-queue images are 70-bit entries, Table 1) and
//!   round-trip tested against the unpacked form.

pub mod packed;

use crate::isa::{ConfigEntry, Opcode};

/// Maximum intermediate destinations in one message (Fig 7: R1, R2, R3 —
/// "as SDDMM has three inputs, destinations correspond to two inputs and one
/// output tensor").
pub const MAX_DESTS: usize = 3;

/// Sentinel for an empty destination slot. Destination fields are 16-bit
/// in the unpacked [`Message`] so fig17-scale meshes (64×64 and beyond,
/// up to the 16384-PE config cap) are addressable; the packed Fig 7 wire
/// format keeps its 4-bit fields and remains defined for Table 1-sized
/// fabrics only (see [`packed`]).
pub const NO_DEST: u16 = 0xFFFF;

/// An Active Message in flight. `Copy`: the struct is a few dozen bytes of
/// plain data and the simulator moves it by value through router buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Message {
    /// Destination list (PE ids). `dests[0]` is the current head destination:
    /// the owner PE of the next memory-class operation. Consumed (rotated)
    /// when that operation executes. ALU-class opcodes do not consume
    /// destinations — they may run anywhere along the route.
    pub dests: [u16; MAX_DESTS],
    /// Number of valid destinations remaining.
    pub ndests: u8,
    /// Program counter into the replicated configuration memory: selects the
    /// entry that morphs this message after its current opcode executes.
    pub n_pc: u8,
    /// Operation to perform at the next execution site.
    pub opcode: Opcode,
    /// Res_c: `result` holds an address (into the owner PE's data memory).
    pub res_is_addr: bool,
    /// Op1_c: `op1` holds an address rather than a value.
    pub op1_is_addr: bool,
    /// Op2_c: `op2` holds an address rather than a value.
    pub op2_is_addr: bool,
    /// Result field: final-store/accumulate address (Res_c=1) or a value.
    /// For `Stream` it carries the element count.
    pub result: u16,
    /// Operand 1 (value or address per `op1_is_addr`).
    pub op1: u16,
    /// Operand 2 (value or address per `op2_is_addr`).
    pub op2: u16,

    // --- simulator-only metadata (not part of the 70-bit format) ---------
    /// Unique id for tracing/conservation checks.
    pub id: u64,
    /// Cycle the message was injected.
    pub birth: u64,
    /// Router hops traversed so far.
    pub hops: u16,
    /// Valiant intermediate destination, if routing policy is Valiant and the
    /// first phase is still in progress.
    pub valiant_hop: Option<u16>,
    /// Set when an intermediate PE executed this message's opcode en-route
    /// (for the Fig 11 right-axis "% computations in-network" series).
    pub executed_enroute: bool,
}

impl Message {
    /// A blank message; codegen fills in fields.
    pub fn new() -> Self {
        Message {
            dests: [NO_DEST; MAX_DESTS],
            ndests: 0,
            n_pc: 0,
            opcode: Opcode::Halt,
            res_is_addr: false,
            op1_is_addr: false,
            op2_is_addr: false,
            result: 0,
            op1: 0,
            op2: 0,
            id: 0,
            birth: 0,
            hops: 0,
            valiant_hop: None,
            executed_enroute: false,
        }
    }

    /// Current head destination PE, if any destinations remain.
    #[inline]
    pub fn head_dest(&self) -> Option<u16> {
        if self.ndests > 0 {
            Some(self.dests[0])
        } else {
            None
        }
    }

    /// Routing target for this cycle: the Valiant intermediate hop when one
    /// is pending, else the head destination.
    #[inline]
    pub fn route_target(&self) -> Option<u16> {
        self.valiant_hop.or_else(|| self.head_dest())
    }

    /// Consume the head destination, cyclically rotating the remainder
    /// (§3.2: "the remaining destinations are cyclically rotated, making R2
    /// the first and R3 the second").
    pub fn rotate_dests(&mut self) {
        if self.ndests == 0 {
            return;
        }
        for i in 0..MAX_DESTS - 1 {
            self.dests[i] = self.dests[i + 1];
        }
        self.dests[MAX_DESTS - 1] = NO_DEST;
        self.ndests -= 1;
    }

    /// Push a destination onto the list (codegen helper).
    pub fn push_dest(&mut self, pe: u16) {
        assert!((self.ndests as usize) < MAX_DESTS, "too many destinations");
        self.dests[self.ndests as usize] = pe;
        self.ndests += 1;
    }

    /// Morph this message after its current opcode produced `result_value`:
    /// load the next [`ConfigEntry`], place the output in `op1` (§3.3.1:
    /// "generates an output that is combined with the original AM, replacing
    /// the Op1 field"), and adopt the entry's opcode/flags/PC. The `result`
    /// (store-address) field and destination list are preserved.
    pub fn morph(&mut self, result_value: u16, entry: &ConfigEntry) {
        self.op1 = result_value;
        self.op1_is_addr = entry.op1_is_addr;
        self.op2_is_addr = entry.op2_is_addr;
        // res_is_addr is sticky once set by codegen (the final store address
        // travels with the message); the config entry can still clear it for
        // value-carrying responses.
        self.res_is_addr = entry.res_is_addr || self.res_is_addr;
        self.opcode = entry.opcode;
        self.n_pc = entry.next_pc;
    }

    /// True if the current opcode can execute right now on an arbitrary ALU:
    /// ALU-class with both operands resolved to values.
    #[inline]
    pub fn alu_ready(&self) -> bool {
        self.opcode.is_alu() && !self.op1_is_addr && !self.op2_is_addr
    }

    /// Advance this message to the next [`ConfigEntry`] *without* replacing
    /// an operand — the decode-unit path. Memory-class operations (Load,
    /// Stream, AccMin re-trigger) write their own operand field and then
    /// adopt the entry's opcode/flags/PC; ALU-class operations use
    /// [`Message::morph`] instead, which additionally places the ALU output
    /// into `op1`.
    pub fn advance(&mut self, entry: &ConfigEntry) {
        self.opcode = entry.opcode;
        self.n_pc = entry.next_pc;
        self.op1_is_addr = entry.op1_is_addr;
        self.op2_is_addr = entry.op2_is_addr;
        self.res_is_addr = entry.res_is_addr || self.res_is_addr;
    }
}

impl Default for Message {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::ConfigEntry;

    #[test]
    fn rotation_consumes_in_order() {
        let mut m = Message::new();
        m.push_dest(3);
        m.push_dest(7);
        m.push_dest(11);
        assert_eq!(m.head_dest(), Some(3));
        m.rotate_dests();
        assert_eq!(m.head_dest(), Some(7));
        m.rotate_dests();
        assert_eq!(m.head_dest(), Some(11));
        m.rotate_dests();
        assert_eq!(m.head_dest(), None);
        m.rotate_dests(); // no-op on empty
        assert_eq!(m.ndests, 0);
    }

    #[test]
    fn morph_replaces_op1_and_adopts_config() {
        let mut m = Message::new();
        m.opcode = Opcode::Mul;
        m.op1 = 6;
        m.op2 = 7;
        m.result = 0x55; // store address placed by codegen
        m.res_is_addr = true;
        let next = ConfigEntry::new(Opcode::Accum, 3).res_addr();
        m.morph(42, &next);
        assert_eq!(m.op1, 42);
        assert_eq!(m.opcode, Opcode::Accum);
        assert_eq!(m.n_pc, 3);
        assert!(m.res_is_addr);
        assert_eq!(m.result, 0x55, "store address must survive morphing");
    }

    #[test]
    fn alu_ready_requires_value_operands() {
        let mut m = Message::new();
        m.opcode = Opcode::Add;
        m.op1_is_addr = false;
        m.op2_is_addr = true;
        assert!(!m.alu_ready());
        m.op2_is_addr = false;
        assert!(m.alu_ready());
        m.opcode = Opcode::Load;
        assert!(!m.alu_ready());
    }

    #[test]
    fn valiant_hop_takes_routing_priority() {
        let mut m = Message::new();
        m.push_dest(9);
        assert_eq!(m.route_target(), Some(9));
        m.valiant_hop = Some(2);
        assert_eq!(m.route_target(), Some(2));
        m.valiant_hop = None;
        assert_eq!(m.route_target(), Some(9));
    }
}
