//! The 70-bit packed Active Message wire format (Fig 7), as stored in the
//! per-PE AM queues (Table 1: "1KB FIFO with 70 bits per entry").
//!
//! Field layout, LSB-first:
//!
//! | bits   | field | meaning |
//! |--------|-------|---------|
//! | 0..12  | R1,R2,R3 | three 4-bit intermediate destinations |
//! | 12..16 | N_PC  | next-instruction program counter |
//! | 16..21 | opcode | 5 bits (paper: 3 bits base + extension modes) |
//! | 21     | Res_c | result field holds an address |
//! | 22     | Op1_c | op1 holds an address |
//! | 23     | Op2_c | op2 holds an address |
//! | 24..40 | Result | result value or address (or stream count) |
//! | 40..56 | Op1   | operand 1 |
//! | 56..72 | Op2   | operand 2 |
//!
//! The 4-bit destination fields address 16 PEs (the Table 1 array). For
//! Fig 17 scalability sweeps (up to 8x8) the simulator uses the unpacked
//! [`Message`]; packing is defined — and asserted — only for fabrics of at
//! most 15 PEs + the no-dest sentinel. Total: 72 bits allocated, 70 used by
//! the paper's fields (our opcode is 2 bits wider to name every workload op
//! distinctly; DESIGN.md notes this substitution).

use super::{Message, MAX_DESTS, NO_DEST};
use crate::isa::Opcode;

/// 4-bit destination sentinel for "no destination" in the packed format.
/// (Typed to match the unpacked `Message::dests` words; the value still
/// fits the 4-bit field.)
const PACKED_NO_DEST: u16 = 0xF;

/// Number of payload bits in a packed AM (for bandwidth accounting).
pub const AM_BITS: u32 = 70;

/// Bytes moved per AM over the off-chip AXI interface (§3.3.3 streams AM
/// queues from off-chip memory); entries are byte-aligned in DRAM.
pub const AM_BYTES: u32 = 9; // ceil(70 / 8)

/// Pack a message into the 70-bit wire format. Panics (debug) if a PE id
/// does not fit the 4-bit destination field; the compiler only emits packed
/// images for Table 1-sized fabrics.
pub fn pack(m: &Message) -> u128 {
    let mut w: u128 = 0;
    for i in 0..MAX_DESTS {
        let d = if i < m.ndests as usize {
            debug_assert!(m.dests[i] < 15, "packed format addresses <= 15 PEs");
            m.dests[i] & 0xF
        } else {
            PACKED_NO_DEST
        };
        w |= (d as u128) << (4 * i);
    }
    w |= ((m.n_pc & 0xF) as u128) << 12;
    w |= ((m.opcode.encode() & 0x1F) as u128) << 16;
    w |= (m.res_is_addr as u128) << 21;
    w |= (m.op1_is_addr as u128) << 22;
    w |= (m.op2_is_addr as u128) << 23;
    w |= (m.result as u128) << 24;
    w |= (m.op1 as u128) << 40;
    w |= (m.op2 as u128) << 56;
    w
}

/// Unpack a 70-bit word into a [`Message`] (simulator metadata zeroed).
/// Returns `None` for an invalid opcode encoding.
pub fn unpack(w: u128) -> Option<Message> {
    let mut m = Message::new();
    for i in 0..MAX_DESTS {
        let d = ((w >> (4 * i)) & 0xF) as u16;
        if d != PACKED_NO_DEST {
            // Destinations must be contiguous from slot 0.
            if i != m.ndests as usize {
                return None;
            }
            m.dests[i] = d;
            m.ndests += 1;
        }
    }
    for i in m.ndests as usize..MAX_DESTS {
        m.dests[i] = NO_DEST;
    }
    m.n_pc = ((w >> 12) & 0xF) as u8;
    m.opcode = Opcode::decode(((w >> 16) & 0x1F) as u8)?;
    m.res_is_addr = (w >> 21) & 1 == 1;
    m.op1_is_addr = (w >> 22) & 1 == 1;
    m.op2_is_addr = (w >> 23) & 1 == 1;
    m.result = ((w >> 24) & 0xFFFF) as u16;
    m.op1 = ((w >> 40) & 0xFFFF) as u16;
    m.op2 = ((w >> 56) & 0xFFFF) as u16;
    Some(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};
    use crate::util::SplitMix64;

    fn random_message(rng: &mut SplitMix64) -> Message {
        let mut m = Message::new();
        let nd = rng.below_usize(MAX_DESTS + 1);
        for _ in 0..nd {
            m.push_dest(rng.below(15) as u16);
        }
        m.n_pc = rng.below(16) as u8;
        m.opcode = loop {
            if let Some(op) = Opcode::decode(rng.below(19) as u8) {
                break op;
            }
        };
        m.res_is_addr = rng.chance(0.5);
        m.op1_is_addr = rng.chance(0.5);
        m.op2_is_addr = rng.chance(0.5);
        m.result = rng.next_u64() as u16;
        m.op1 = rng.next_u64() as u16;
        m.op2 = rng.next_u64() as u16;
        m
    }

    #[test]
    fn pack_unpack_roundtrip_property() {
        forall(500, |rng| {
            let m = random_message(rng);
            let w = pack(&m);
            let back = unpack(w).ok_or("unpack failed")?;
            ensure(back == m, || format!("roundtrip mismatch: {m:?} vs {back:?}"))
        });
    }

    #[test]
    fn packed_fits_72_bits() {
        forall(200, |rng| {
            let m = random_message(rng);
            let w = pack(&m);
            ensure(w >> 72 == 0, || format!("overflow: {w:#x}"))
        });
    }

    #[test]
    fn invalid_opcode_rejected() {
        // opcode field = 31 is undefined
        let w = 31u128 << 16;
        assert!(unpack(w).is_none());
    }

    #[test]
    fn am_bytes_matches_bits() {
        assert_eq!(AM_BYTES, (AM_BITS + 7) / 8);
    }
}
