//! `nexus` — the Nexus Machine evaluation CLI.
//!
//! Regenerates every figure and table of the paper's evaluation (§5) from
//! the cycle-accurate simulator, validates the fabric against software
//! references and the XLA golden models, and exposes one-off runs.

use nexus::config::{ArchConfig, ClaimPolicy, PlacementPolicy, StepMode, TopologyKind};
use nexus::coordinator::{self, report};
use nexus::dataset::RunOptions;

/// Parse `--flag N` from the argument list, with a default.
fn flag_value<T: std::str::FromStr>(args: &[String], flag: &str, default: T) -> T {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let seed = flag_value(&args, "--seed", 1u64);
    // Sharded stepping: `--shards N` partitions each fabric into N row
    // bands (part of the modeled schedule — must divide the mesh height,
    // corpus runs clamp per scenario); `--threads N` steps the shards on N
    // worker threads (host-side only; bit-identical at any thread count).
    let shards = flag_value(&args, "--shards", 1usize).max(1);
    let threads = flag_value(&args, "--threads", 1usize).max(1);
    // Simulator scheduling mode: active-set by default; `--dense-oracle`
    // re-runs on the dense reference scan (bit-identical, slower) to
    // cross-check the event-driven scheduler on real workloads.
    let step_mode = if args.iter().any(|a| a == "--dense-oracle") {
        StepMode::DenseOracle
    } else {
        StepMode::ActiveSet
    };
    // NoC topology: 2D mesh unless `--topology <mesh|torus|ruche|chiplet>`.
    let topology = match args
        .iter()
        .position(|a| a == "--topology")
        .and_then(|i| args.get(i + 1))
    {
        None => TopologyKind::Mesh2D,
        Some(name) => match TopologyKind::parse(name) {
            Some(kind) => kind,
            None => {
                eprintln!(
                    "unknown topology '{name}' (use: {})",
                    TopologyKind::ALL.map(|k| k.name()).join("|")
                );
                std::process::exit(2);
            }
        },
    };

    // Data placement: dissimilarity-aware unless
    // `--placement <nnz-balanced|dissimilarity|hotspot-split>`.
    let placement = match args
        .iter()
        .position(|a| a == "--placement")
        .and_then(|i| args.get(i + 1))
    {
        None => PlacementPolicy::default(),
        Some(name) => match PlacementPolicy::parse(name) {
            Some(p) => p,
            None => {
                eprintln!(
                    "unknown placement '{name}' (use: {})",
                    PlacementPolicy::ALL.map(|p| p.name()).join("|")
                );
                std::process::exit(2);
            }
        },
    };
    // En-route claiming: eager unless `--claim <eager|locality|credit|steal>`.
    let claim = match args
        .iter()
        .position(|a| a == "--claim")
        .and_then(|i| args.get(i + 1))
    {
        None => ClaimPolicy::default(),
        Some(name) => match ClaimPolicy::parse(name) {
            Some(c) => c,
            None => {
                eprintln!(
                    "unknown claim policy '{name}' (use: {})",
                    ClaimPolicy::ALL.map(|c| c.name()).join("|")
                );
                std::process::exit(2);
            }
        },
    };

    let opts = RunOptions {
        seed,
        step_mode,
        topology,
        shards,
        threads,
        placement,
        claim,
    };

    match cmd {
        "corpus" => corpus(&args, opts),
        "serve" => serve(&args, step_mode, topology, shards, threads),
        "trace" => trace_cmd(&args, opts),
        "validate" => validate(&opts),
        "golden" => golden(seed),
        "fig10" => with_matrix(seed, report::fig10),
        "fig11" => with_matrix(seed, report::fig11),
        "fig12" => with_matrix(seed, report::fig12),
        "fig13" => with_matrix(seed, report::fig13),
        "fig14" => with_matrix(seed, report::fig14),
        "fig15" => println!("{}", report::fig15()),
        "fig16" => {
            let pts = coordinator::bandwidth_sweep(seed);
            println!("{}", report::fig16(&pts));
        }
        "fig17" => {
            let pts = coordinator::scalability_sweep(seed, &[2, 4, 6, 8]);
            println!("{}", report::fig17(&pts));
        }
        "table1" | "config" => println!("{}", report::table1()),
        "ablate" => println!("{}", coordinator::ablation::report(seed)),
        "fig3" => fig3(seed),
        "table2" => with_matrix(seed, report::table2),
        "compile-time" => compile_time(seed),
        "all" => {
            validate(&opts);
            let m = coordinator::run_matrix(seed);
            println!("{}", report::fig10(&m));
            println!("{}", report::fig11(&m));
            println!("{}", report::fig12(&m));
            println!("{}", report::fig13(&m));
            println!("{}", report::fig14(&m));
            println!("{}", report::fig15());
            let pts = coordinator::bandwidth_sweep(seed);
            println!("{}", report::fig16(&pts));
            let pts = coordinator::scalability_sweep(seed, &[2, 4, 6, 8]);
            println!("{}", report::fig17(&pts));
            println!("{}", report::table1());
            println!("{}", report::table2(&m));
        }
        _ => {
            println!(
                "nexus — Nexus Machine reproduction CLI\n\n\
                 usage: nexus <command> [--seed N] [--dense-oracle] [--topology T]\n\
                 \x20             [--placement P] [--claim C] [--shards N] [--threads N]\n\n\
                 commands:\n\
                 \x20 corpus        dataset/scenario corpus: `corpus list` enumerates the\n\
                 \x20               registered scenarios, `corpus run` executes them with\n\
                 \x20               bit-exact validation, one JSON line per scenario\n\
                 \x20               (--filter GLOB selects, e.g. --filter 'smoke/*';\n\
                 \x20               --topology mesh|torus|ruche|chiplet picks the NoC —\n\
                 \x20               JSON lines report per-link flits, peak demand, GB/s;\n\
                 \x20               --placement nnz-balanced|dissimilarity|hotspot-split\n\
                 \x20               picks the compile-time row placement;\n\
                 \x20               --claim eager|locality|credit|steal picks the\n\
                 \x20               en-route claim policy — both echo into the JSON;\n\
                 \x20               --stall-summary also prints a per-scenario stall-\n\
                 \x20               attribution breakdown to stderr)\n\
                 \x20 trace         run one corpus scenario with cycle-resolved tracing\n\
                 \x20               and export Chrome/Perfetto trace-event JSON\n\
                 \x20               (--scenario NAME picks it, --out FILE, default\n\
                 \x20               trace.json; load in ui.perfetto.dev — tracing is\n\
                 \x20               zero-perturbation, cycles match an untraced run)\n\
                 \x20 validate      run the 13-workload suite on Nexus/TIA/TIA-Valiant,\n\
                 \x20               checking fabric outputs against software references\n\
                 \x20               (--dense-oracle: use the dense reference scheduler\n\
                 \x20               instead of active-set stepping; results are identical;\n\
                 \x20               --topology also applies here)\n\
                 \x20               (--shards N: partition each fabric into N row bands —\n\
                 \x20               part of the modeled schedule; --threads N: step the\n\
                 \x20               shards on N worker threads, bit-identical at any N;\n\
                 \x20               --placement / --claim apply here too)\n\
                 \x20 serve         long-running batch-execution daemon: NDJSON over TCP\n\
                 \x20               (--addr HOST:PORT, default 127.0.0.1:7077;\n\
                 \x20               --workers N execution threads; --queue-cap N bounded\n\
                 \x20               admission queue; --cache-cap N compile-cache entries;\n\
                 \x20               --shards/--threads/--topology/--dense-oracle apply to\n\
                 \x20               every served run; GET /health + GET /metrics for\n\
                 \x20               liveness; {\"cmd\":\"shutdown\"} drains and exits 0)\n\
                 \x20 golden        additionally check against the XLA/PJRT golden models\n\
                 \x20               (requires `make artifacts`)\n\
                 \x20 fig10..fig17  regenerate the corresponding paper figure\n\
                 \x20 table1 table2 regenerate the corresponding paper table\n\
                 \x20 ablate        design-choice ablations (routing, buffers, placement)\n\
                 \x20 fig3          per-PE load-balance heatmaps (Nexus vs TIA)\n\
                 \x20 compile-time  Nexus vs Generic-CGRA compile-path timing (§4)\n\
                 \x20 all           everything above in sequence"
            );
        }
    }
}

/// `nexus corpus list|run [--filter GLOB] [--seed N] [--dense-oracle]
/// [--topology T] [--shards N] [--threads N]`: the dataset/scenario corpus
/// surface. `run` prints exactly one JSON line per scenario on stdout (the
/// CI smoke job tees this into `BENCH_CORPUS.json`); human-readable
/// summaries go to stderr.
fn corpus(args: &[String], opts: RunOptions) {
    let sub = args.get(1).map(String::as_str).unwrap_or("list");
    let filter = args
        .iter()
        .position(|a| a == "--filter")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str);
    match sub {
        "list" => println!("{}", coordinator::corpus_list(filter)),
        "run" => {
            let stall_summary = args.iter().any(|a| a == "--stall-summary");
            let (runs, ok) = coordinator::corpus_run_full(filter, opts);
            for run in &runs {
                println!("{}", run.json_line());
            }
            if stall_summary && !runs.is_empty() {
                eprintln!("stall attribution (percent of PE-cycles per class):");
                for run in &runs {
                    eprintln!("  {}", run.stall_summary_line());
                }
            }
            if !ok {
                eprintln!(
                    "corpus run FAILED ({})",
                    if runs.is_empty() {
                        "no scenario matched the filter".to_string()
                    } else {
                        format!(
                            "{} scenario(s) errored or failed validation",
                            runs.iter().filter(|r| !r.passed()).count()
                        )
                    }
                );
                std::process::exit(1);
            }
            eprintln!(
                "corpus run OK: {} scenario(s) validated ({} stepping, {} topology, \
                 {} placement, {} claiming, {} shard(s) x {} thread(s), seed {})",
                runs.len(),
                opts.step_mode.name(),
                opts.topology.name(),
                opts.placement.name(),
                opts.claim.name(),
                opts.shards,
                opts.threads,
                opts.seed
            );
        }
        other => {
            eprintln!("unknown corpus subcommand '{other}' (use: corpus list|run)");
            std::process::exit(2);
        }
    }
}

/// `nexus trace --scenario NAME [--out FILE]` plus the global run flags:
/// run one corpus scenario with full tracing and write the Chrome
/// trace-event JSON document (Perfetto-loadable). The JSON goes to the
/// file; the one-line summary goes to stderr.
fn trace_cmd(args: &[String], opts: RunOptions) {
    let Some(name) = args
        .iter()
        .position(|a| a == "--scenario")
        .and_then(|i| args.get(i + 1))
    else {
        eprintln!("usage: nexus trace --scenario NAME [--out FILE]  (see `nexus corpus list`)");
        std::process::exit(2);
    };
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("trace.json");
    match coordinator::trace_scenario(name, opts) {
        Ok(t) => {
            if let Err(e) = std::fs::write(out, &t.json) {
                eprintln!("trace: cannot write {out}: {e}");
                std::process::exit(1);
            }
            eprintln!(
                "trace: {} — {} event(s) over {} cycles -> {out} \
                 (load in ui.perfetto.dev or chrome://tracing)",
                t.scenario, t.events, t.cycles
            );
        }
        Err(e) => {
            eprintln!("trace failed: {e}");
            std::process::exit(1);
        }
    }
}

/// `nexus serve [--addr HOST:PORT] [--workers N] [--queue-cap N]
/// [--cache-cap N]` plus the global run flags: start the batch-execution
/// daemon and block until a shutdown request drains it.
fn serve(
    args: &[String],
    step_mode: StepMode,
    topology: TopologyKind,
    shards: usize,
    threads: usize,
) {
    let defaults = nexus::serve::ServeOptions::default();
    let opts = nexus::serve::ServeOptions {
        addr: args
            .iter()
            .position(|a| a == "--addr")
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or_else(|| defaults.addr.clone()),
        workers: flag_value(args, "--workers", defaults.workers),
        queue_capacity: flag_value(args, "--queue-cap", defaults.queue_capacity).max(1),
        cache_capacity: flag_value(args, "--cache-cap", defaults.cache_capacity).max(1),
        shards,
        threads,
        topology,
        step_mode,
        ..defaults
    };
    if let Err(e) = coordinator::serve(opts) {
        eprintln!("serve failed: {e}");
        std::process::exit(1);
    }
}

fn with_matrix(seed: u64, f: impl Fn(&coordinator::Matrix) -> String) {
    let m = coordinator::run_matrix(seed);
    println!("{}", f(&m));
}

fn validate(opts: &RunOptions) {
    for cfg in [
        ArchConfig::nexus(),
        ArchConfig::tia(),
        ArchConfig::tia_valiant(),
    ] {
        let cfg = cfg
            .with_step_mode(opts.step_mode)
            .with_topology(opts.topology)
            .with_placement(opts.placement)
            .with_claim(opts.claim);
        let shards = nexus::dataset::effective_shards(opts.shards, cfg.height);
        let cfg = cfg.with_shards(shards).with_threads(opts.threads);
        let kind = cfg.kind.name();
        match coordinator::validate_suite(&cfg, opts.seed) {
            Ok(rows) => {
                println!(
                    "[{kind}] all {} workloads validated ({} stepping, {} shard(s)):",
                    rows.len(),
                    opts.step_mode.name(),
                    shards
                );
                let peak = rows
                    .iter()
                    .max_by(|a, b| a.peak_link_gbps.total_cmp(&b.peak_link_gbps));
                for r in &rows {
                    println!(
                        "  {:<14} {:>9} cycles  peak link {:>7.2} GB/s  OK",
                        r.program, r.cycles, r.peak_link_gbps
                    );
                }
                if let Some(p) = peak {
                    println!(
                        "  peak link demand: {:.2} GB/s ({} flits/cycle, on {})",
                        p.peak_link_gbps, p.peak_link_demand, p.program
                    );
                }
            }
            Err(e) => {
                eprintln!("[{kind}] VALIDATION FAILED: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// Validate the fabric against the XLA golden models (L2 artifacts).
fn golden(seed: u64) {
    let dir = nexus::runtime::artifacts_dir();
    println!("artifacts: {}", dir.display());
    match nexus::golden::check_all(&dir, seed) {
        Ok(rows) => {
            for (name, status) in rows {
                println!("  {name:<14} {status}");
            }
        }
        Err(e) => {
            eprintln!("golden validation failed: {e}");
            std::process::exit(1);
        }
    }
}

/// Fig 3's bottom panels: per-PE busy-cycle heatmaps on SpMV, showing the
/// load imbalance of data-local execution (TIA) vs the uniform balance of
/// en-route execution (Nexus).
fn fig3(seed: u64) {
    let specs = nexus::workloads::suite(seed);
    let spec = specs.iter().find(|s| s.name().starts_with("SpMV")).unwrap();
    for cfg in [ArchConfig::tia(), ArchConfig::nexus()] {
        let kind = cfg.kind.name();
        let mut m = nexus::machine::Machine::new(cfg.clone());
        let exec = m.run(spec).expect("fig3 run");
        let stats = exec.stats.expect("fabric stats");
        let busy = &stats.per_pe_busy_cycles;
        let max = *busy.iter().max().unwrap() as f64;
        println!("[{kind}] per-PE busy cycles (load CV {:.3}):", stats.load_cv());
        for y in 0..cfg.height {
            print!("  ");
            for x in 0..cfg.width {
                let b = busy[cfg.pe_id(x, y)] as f64;
                let shade = [" .", " -", " =", " #", " @"][(4.0 * b / max.max(1.0)) as usize % 5];
                print!("{shade}{:>5}", busy[cfg.pe_id(x, y)]);
            }
            println!();
        }
    }
}

/// §4's compile-time comparison: the Nexus compile path (partition +
/// static-AM codegen; routing is dynamic in hardware) vs the Generic CGRA
/// path (modulo schedule + full static route/trace resolution).
fn compile_time(seed: u64) {
    use std::time::Instant;
    let specs = nexus::workloads::suite(seed);
    let cfg = ArchConfig::nexus();
    let t0 = Instant::now();
    for s in &specs {
        let _ = s.build(&cfg);
    }
    let nexus_t = t0.elapsed();
    let t1 = Instant::now();
    for s in &specs {
        let dfg = s.dfg();
        let (trace, bytes) = nexus::baselines::cgra::mem_trace(s);
        let _ = nexus::baselines::cgra::GenericCgra::default().simulate(&dfg, &trace, bytes);
    }
    let cgra_t = t1.elapsed();
    println!(
        "compile path, full suite: Nexus {:.3}s (dynamic routing in hw)  vs  \
         Generic CGRA {:.3}s (static route resolution)\n\
         paper anchors: 0.55s vs 7.22s",
        nexus_t.as_secs_f64(),
        cgra_t.as_secs_f64()
    );
}
