//! 22nm-calibrated area and power models (Figs 10, 12, 15; Table 2).
//!
//! Per DESIGN.md's substitution table, Cadence Genus + SRAM-compiler
//! characterization is replaced by an **event-energy accounting model**:
//! the simulator's event counters (ALU ops, SRAM accesses, router hops,
//! config reads, scanner/trigger activity) are multiplied by per-event
//! energies, plus per-component leakage, with the constants calibrated so
//! the *published* anchors hold — Table 2's absolute figures (Nexus
//! 3.865 mW / 748 MOPS / 194 MOPS/mW at 588 MHz; TIA 4.626 mW) and the
//! Fig 10/15 relative breakdowns (Nexus ≈ +17% power / +17.3% area over
//! the Generic CGRA; TIA pays comparators, Nexus pays AM queues +
//! scanners; both pay dynamic routers).

pub mod area;

use crate::config::ArchKind;
use crate::fabric::stats::FabricStats;

/// Event counts feeding the energy model, normalized across architectures.
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyEvents {
    pub alu_ops: u64,
    /// Local (distributed) SRAM accesses — Nexus/TIA data memories.
    pub dmem_accesses: u64,
    /// Shared edge-bank accesses — CGRA/systolic global SPM.
    pub bank_accesses: u64,
    pub config_reads: u64,
    /// Dynamic-router hops (Nexus/TIA) or static-NoC word moves (CGRA,
    /// systolic shifts).
    pub noc_hops: u64,
    pub buf_writes: u64,
    pub scanner_ops: u64,
    pub trigger_checks: u64,
    pub stream_emissions: u64,
    pub offchip_bytes: u64,
    pub cycles: u64,
}

impl EnergyEvents {
    /// Extract events from a fabric run.
    pub fn from_fabric(s: &FabricStats, _kind: ArchKind) -> Self {
        EnergyEvents {
            alu_ops: s.alu_ops,
            dmem_accesses: s.dmem_reads + s.dmem_writes,
            bank_accesses: 0,
            config_reads: s.config_reads,
            noc_hops: s.flit_hops,
            buf_writes: s.buf_writes,
            scanner_ops: s.scanner_ops,
            trigger_checks: s.trigger_checks,
            stream_emissions: s.stream_emissions,
            offchip_bytes: s.offchip_bytes,
            cycles: s.cycles,
        }
    }
}

/// Power breakdown by component, in mW (Fig 10's categories).
#[derive(Debug, Clone, Copy, Default)]
pub struct PowerBreakdown {
    pub alu: f64,
    pub data_mem: f64,
    pub config_mem: f64,
    pub noc: f64,
    /// AM NIC (queues + injection logic) for Nexus; trigger
    /// scheduler/comparators for TIA; zero for CGRA.
    pub nic: f64,
    pub scanners: f64,
    pub control: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.alu + self.data_mem + self.config_mem + self.noc + self.nic + self.scanners
            + self.control
    }

    pub fn components(&self) -> [(&'static str, f64); 7] {
        [
            ("ALU", self.alu),
            ("DataMem", self.data_mem),
            ("ConfigMem", self.config_mem),
            ("NoC", self.noc),
            ("NIC", self.nic),
            ("Scanners", self.scanners),
            ("Control", self.control),
        ]
    }
}

/// Per-event energies (pJ) and per-component leakage (mW), 22nm FDSOI
/// calibration. One model instance serves all architectures; architecture
/// identity selects which leakage terms apply.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    // Dynamic energies, pJ/event.
    pub e_alu: f64,
    pub e_dmem: f64,
    pub e_bank: f64, // shared edge banks: longer wires, bigger arrays
    pub e_config: f64,
    pub e_hop_dynamic: f64,
    pub e_hop_static: f64,
    pub e_buf: f64,
    pub e_scanner: f64,
    pub e_trigger: f64,
    // Leakage / clock-tree, mW per component (whole fabric).
    pub l_alu: f64,
    pub l_dmem: f64,
    pub l_config_replicated: f64,
    pub l_config_central: f64,
    pub l_noc_dynamic: f64,
    pub l_noc_static: f64,
    pub l_nic: f64,
    pub l_comparators: f64,
    pub l_scanners: f64,
    pub l_control: f64,
}

impl EnergyModel {
    /// The 22nm calibration (see module docs for anchors).
    pub fn cal22nm() -> Self {
        EnergyModel {
            e_alu: 0.30,
            e_dmem: 0.22,
            e_bank: 0.75,
            e_config: 0.18,
            e_hop_dynamic: 0.18,
            e_hop_static: 0.12,
            e_buf: 0.08,
            e_scanner: 0.30,
            e_trigger: 0.35,
            l_alu: 0.20,
            l_dmem: 0.35,
            l_config_replicated: 0.40,
            l_config_central: 0.30,
            l_noc_dynamic: 0.30,
            l_noc_static: 0.18,
            l_nic: 0.30,
            l_comparators: 2.00,
            l_scanners: 0.02,
            l_control: 0.30,
        }
    }

    /// Power breakdown for an architecture's run. `freq_mhz` converts
    /// events/cycle into watts: `P_dyn = (pJ/event) * events/cycle * f`.
    pub fn power(&self, arch: &str, ev: &EnergyEvents, freq_mhz: f64) -> PowerBreakdown {
        let cyc = ev.cycles.max(1) as f64;
        // pJ/cycle * MHz = microW... : pJ * 1e-12 J * f(1e6/s) = 1e-6 W = mW*1e-3.
        let to_mw = freq_mhz * 1e-6 * 1e3; // pJ/cycle -> mW
        let dyn_mw = |events: u64, pj: f64| (events as f64 / cyc) * pj * to_mw;
        let is_fabric = matches!(arch, "Nexus" | "TIA" | "TIA-Valiant");
        let is_tia = matches!(arch, "TIA" | "TIA-Valiant");
        let mut p = PowerBreakdown::default();
        p.alu = self.l_alu + dyn_mw(ev.alu_ops, self.e_alu);
        p.data_mem = self.l_dmem
            + dyn_mw(ev.dmem_accesses, self.e_dmem)
            + dyn_mw(ev.bank_accesses, self.e_bank);
        p.config_mem = if is_fabric && !is_tia {
            // Nexus: replicated config memories, but no comparators.
            self.l_config_replicated + dyn_mw(ev.config_reads, self.e_config)
        } else if is_tia {
            // TIA: replicated config + tag-match comparators (the +12%
            // config-path power Nexus saves, §5.2).
            self.l_config_replicated
                + self.l_comparators
                + dyn_mw(ev.config_reads, self.e_config)
                + dyn_mw(ev.trigger_checks, self.e_trigger)
        } else {
            self.l_config_central + dyn_mw(ev.config_reads, self.e_config)
        };
        p.noc = if is_fabric {
            self.l_noc_dynamic
                + dyn_mw(ev.noc_hops, self.e_hop_dynamic)
                + dyn_mw(ev.buf_writes, self.e_buf)
        } else {
            self.l_noc_static + dyn_mw(ev.noc_hops, self.e_hop_static)
        };
        p.nic = if arch == "Nexus" { self.l_nic } else { 0.0 };
        p.scanners = if arch == "Nexus" {
            self.l_scanners + dyn_mw(ev.scanner_ops, self.e_scanner)
        } else {
            0.0
        };
        p.control = self.l_control * if is_fabric { 1.15 } else { 1.0 };
        p
    }
}

/// Peak NoC link bandwidth demand in GB/s: the busiest cycle's link
/// traversal count ([`FabricStats::peak_link_demand`]) times the packed AM
/// flit size (9 bytes) times the clock. Converts the simulator's abstract
/// flits/cycle peak into the physical provisioning number reported by the
/// corpus runner's per-scenario JSON.
pub fn link_demand_gbps(peak_link_demand: u64, freq_mhz: f64) -> f64 {
    peak_link_demand as f64 * crate::am::packed::AM_BYTES as f64 * freq_mhz * 1e6 / 1e9
}

/// Performance-per-watt (Fig 12): useful MOPS / mW.
pub fn perf_per_watt(work_ops: u64, cycles: u64, power_mw: f64, freq_mhz: f64) -> f64 {
    if cycles == 0 || power_mw <= 0.0 {
        return 0.0;
    }
    let mops = work_ops as f64 / cycles as f64 * freq_mhz;
    mops / power_mw
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_events(n: u64) -> EnergyEvents {
        EnergyEvents {
            alu_ops: n,
            dmem_accesses: n,
            config_reads: n,
            noc_hops: n / 2,
            buf_writes: n / 2,
            scanner_ops: n / 8,
            cycles: n,
            ..Default::default()
        }
    }

    #[test]
    fn nexus_total_power_lands_near_table2() {
        let m = EnergyModel::cal22nm();
        // Representative peak activity: ~1.3 useful ops/cycle fabric-wide.
        let ev = busy_events(100_000);
        let p = m.power("Nexus", &ev, 588.0);
        let total = p.total();
        assert!(
            (1.5..6.0).contains(&total),
            "Nexus power {total} mW should be in Table 2's neighborhood"
        );
    }

    #[test]
    fn tia_pays_comparators_nexus_pays_queues() {
        let m = EnergyModel::cal22nm();
        let mut ev = busy_events(100_000);
        ev.trigger_checks = 50_000;
        let tia = m.power("TIA", &ev, 588.0);
        ev.trigger_checks = 0;
        let nexus = m.power("Nexus", &ev, 588.0);
        // §5.2: Nexus benefits from a config-path power reduction vs TIA.
        assert!(nexus.config_mem < tia.config_mem);
        // Nexus carries NIC + scanners that TIA lacks.
        assert!(nexus.nic > 0.0 && tia.nic == 0.0);
    }

    #[test]
    fn fabric_power_exceeds_cgra_modestly() {
        let m = EnergyModel::cal22nm();
        let ev_fab = busy_events(100_000);
        let mut ev_cgra = busy_events(100_000);
        ev_cgra.bank_accesses = ev_cgra.dmem_accesses;
        ev_cgra.dmem_accesses = 0;
        let nexus = m.power("Nexus", &ev_fab, 588.0).total();
        let cgra = m.power("GenericCGRA", &ev_cgra, 588.0).total();
        let ratio = nexus / cgra;
        // Fig 10: ~+17% power at iso-activity; allow a band.
        assert!(
            (0.95..1.45).contains(&ratio),
            "Nexus/CGRA power ratio {ratio}"
        );
    }

    #[test]
    fn link_demand_gbps_matches_hand_computation() {
        // 100 flits in the busiest cycle × 9 bytes × 588 MHz
        //   = 100 * 9 * 588e6 B/s = 529.2 GB/s.
        let got = link_demand_gbps(100, 588.0);
        assert!((got - 529.2).abs() < 1e-9, "{got}");
        assert_eq!(link_demand_gbps(0, 588.0), 0.0);
        // Linear in both the peak and the clock.
        assert!((link_demand_gbps(200, 588.0) - 2.0 * got).abs() < 1e-9);
        assert!((link_demand_gbps(100, 1176.0) - 2.0 * got).abs() < 1e-9);
    }

    #[test]
    fn perf_per_watt_scales() {
        let a = perf_per_watt(1000, 1000, 4.0, 588.0);
        let b = perf_per_watt(2000, 1000, 4.0, 588.0);
        assert!((b / a - 2.0).abs() < 1e-9);
        assert_eq!(perf_per_watt(1000, 0, 4.0, 588.0), 0.0);
    }
}
