//! Area model (Fig 15): per-component area in normalized units (Generic
//! CGRA total = 100), calibrated to the paper's reported deltas —
//! Nexus +17.3% over Generic CGRA (8% AM queues + logic, 3% scanners, 6%
//! dynamic routers); TIA +8% comparators +6% routers over Generic CGRA.
//!
//! All three designs carry 2KB of on-chip memory per PE (§4.1: the
//! baselines get 2KB unified SRAM; Nexus splits it 1KB data + 1KB AM
//! queue), synthesized with the same SRAM compiler.

/// Component areas in normalized units.
#[derive(Debug, Clone, Copy, Default)]
pub struct AreaBreakdown {
    pub alu: f64,
    pub data_mem: f64,
    pub config_mem: f64,
    pub noc: f64,
    pub am_queue: f64,
    pub scanners: f64,
    pub comparators: f64,
    pub control: f64,
}

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.alu
            + self.data_mem
            + self.config_mem
            + self.noc
            + self.am_queue
            + self.scanners
            + self.comparators
            + self.control
    }

    pub fn components(&self) -> [(&'static str, f64); 8] {
        [
            ("ALU", self.alu),
            ("DataMem", self.data_mem),
            ("ConfigMem", self.config_mem),
            ("NoC", self.noc),
            ("AMQueue", self.am_queue),
            ("Scanners", self.scanners),
            ("Comparators", self.comparators),
            ("Control", self.control),
        ]
    }
}

/// Area for one architecture (normalized: Generic CGRA == 100).
pub fn area_of(arch: &str) -> AreaBreakdown {
    // Generic CGRA reference: 16 ALUs, 32KB equivalent SRAM in edge banks,
    // central config, static NoC, control.
    let cgra = AreaBreakdown {
        alu: 22.0,
        data_mem: 38.0,
        config_mem: 12.0,
        noc: 14.0,
        am_queue: 0.0,
        scanners: 0.0,
        comparators: 0.0,
        control: 14.0,
    };
    match arch {
        "GenericCGRA" | "Systolic" => cgra,
        "TIA" | "TIA-Valiant" => AreaBreakdown {
            // Same memory budget (2KB/PE, distributed), dynamic routers
            // (+6), tag-match comparators (+8).
            noc: cgra.noc + 6.0,
            comparators: 8.0,
            ..cgra
        },
        "Nexus" => AreaBreakdown {
            // 1KB data + 1KB AM queue per PE (same SRAM total), dynamic
            // routers (+6), AM queues + injection logic (+8), scanners (+3).
            data_mem: cgra.data_mem - 8.0, // half the SRAM moves to queues
            am_queue: 8.0 + 8.0,           // queue SRAM + NIC logic
            noc: cgra.noc + 6.0,
            scanners: 3.0,
            control: cgra.control + 0.3,
            ..cgra
        },
        other => panic!("unknown architecture {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cgra_reference_is_100() {
        assert!((area_of("GenericCGRA").total() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn nexus_overhead_matches_fig15() {
        let nexus = area_of("Nexus").total();
        let cgra = area_of("GenericCGRA").total();
        let tia = area_of("TIA").total();
        let vs_cgra = nexus / cgra - 1.0;
        let vs_tia = nexus / tia - 1.0;
        // Paper: +17.3% vs CGRA, +5.2% vs TIA.
        assert!((0.12..0.22).contains(&vs_cgra), "vs CGRA {vs_cgra}");
        assert!((0.01..0.09).contains(&vs_tia), "vs TIA {vs_tia}");
    }

    #[test]
    fn tia_overhead_matches_fig15() {
        let tia = area_of("TIA").total();
        let cgra = area_of("GenericCGRA").total();
        let vs = tia / cgra - 1.0;
        // Paper: +8% comparators +6% routers = +14%.
        assert!((0.10..0.18).contains(&vs), "TIA vs CGRA {vs}");
    }

    #[test]
    fn components_sum_to_total() {
        for arch in ["Nexus", "TIA", "GenericCGRA"] {
            let a = area_of(arch);
            let sum: f64 = a.components().iter().map(|(_, v)| v).sum();
            assert!((sum - a.total()).abs() < 1e-9);
        }
    }
}
