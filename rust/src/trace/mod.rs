//! Cycle-resolved event tracing for the Nexus fabric.
//!
//! The simulator's end-of-run aggregates (`FabricStats`) say *how much*
//! happened; this module records *when* and *where*: message-lifecycle
//! events (inject → hop → en-route claim → commit → retire) and PE state
//! transitions (idle / compute / blocked), each stamped with the cycle it
//! occurred on.
//!
//! # Zero perturbation
//!
//! Tracing is **provably inert**: event emission reads simulator state but
//! never writes it, draws no PRNG values, and the trace buffers live
//! outside the [`crate::fabric::NexusFabric::state_digest`] and
//! [`crate::fabric::stats::FabricStats`] comparison surfaces. A traced run
//! is bit-identical to an untraced one — same outputs, cycles, stats, and
//! per-cycle digest trace — a property enforced across all topologies ×
//! step modes × shard counts × claim policies by
//! `tests/step_equivalence.rs` (every differential comparison traces
//! exactly one side).
//!
//! # Sharding
//!
//! Each shard band records into its own [`TraceBuffer`] ring (no locks,
//! no cross-thread writes); at every epoch barrier the coordinator drains
//! the shard rings **in shard index order** into the fabric-owned sink, so
//! the merged stream is deterministic at any thread count and
//! nondecreasing in cycle.
//!
//! # Flight recorder
//!
//! With a bounded sink capacity ([`TraceConfig::sink_capacity`] > 0) the
//! sink keeps only the most recent events, ring-buffer style — a flight
//! recorder whose contents are dumped into
//! [`crate::fabric::DeadlockError::flight`] when a run times out, turning
//! deadlock reports into replayable forensics.
//!
//! # Export
//!
//! [`chrome_trace_json`] renders an event slice in the Chrome trace-event
//! JSON format: load the file in `about:tracing` or
//! <https://ui.perfetto.dev> to see per-PE utilization waterfalls and
//! claim migrations. One instant event per fabric event, one track (tid)
//! per PE.

use crate::util::json::{array, JsonObj};

/// What a trace sink records. Carried on
/// [`ArchConfig::trace`](crate::config::ArchConfig::trace); the default
/// is fully disabled and costs one predictable branch per would-be event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceConfig {
    /// Master switch. When false nothing is recorded.
    pub enabled: bool,
    /// Per-shard ring capacity in events. Each shard ring is drained into
    /// the sink every epoch, so this only needs to hold one epoch's worth
    /// of events per shard; on overflow the *oldest* events of the epoch
    /// are dropped (counted, never silently).
    pub shard_capacity: usize,
    /// Merged-sink bound: `0` keeps every event (full tracing, for
    /// export); `> 0` keeps only the most recent N (flight recorder).
    pub sink_capacity: usize,
    /// Record message-lifecycle events (inject / hop / claim / commit /
    /// retire).
    pub lifecycle: bool,
    /// Record PE state-transition events (idle / compute / blocked).
    pub pe_states: bool,
}

impl TraceConfig {
    /// Fully disabled (the [`Default`]): zero events, zero allocation.
    pub fn off() -> Self {
        TraceConfig {
            enabled: false,
            shard_capacity: 0,
            sink_capacity: 0,
            lifecycle: false,
            pe_states: false,
        }
    }

    /// Full tracing for export: everything recorded, unbounded sink.
    pub fn full() -> Self {
        TraceConfig {
            enabled: true,
            shard_capacity: 1 << 14,
            sink_capacity: 0,
            lifecycle: true,
            pe_states: true,
        }
    }

    /// Flight recorder: everything recorded, only the most recent
    /// `last_n` events kept.
    pub fn flight_recorder(last_n: usize) -> Self {
        TraceConfig {
            sink_capacity: last_n.max(1),
            ..Self::full()
        }
    }

    /// Validate internal consistency (mirrors `ArchConfig::validate`).
    pub fn validate(&self) -> Result<(), String> {
        if self.enabled && self.shard_capacity == 0 {
            return Err("trace shard_capacity must be >= 1 when tracing is enabled".into());
        }
        if self.enabled && !self.lifecycle && !self.pe_states {
            return Err("enabled trace must record lifecycle and/or pe_states".into());
        }
        Ok(())
    }
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self::off()
    }
}

/// The event vocabulary. Discriminants are stable: they appear in
/// exported traces and flight-recorder dumps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A PE injected a message into its router's local port.
    Inject,
    /// A flit crossed a link (router → router, or into a PE inbox);
    /// `arg` is the output port index.
    Hop,
    /// An idle PE claimed a buffered flit for en-route execution; `arg`
    /// is the claimed input port.
    Claim,
    /// A PE latched an ALU operation this cycle (the commit-side busy
    /// latch). Per PE, `AluCommit + MemOp` event counts equal
    /// `FabricStats::per_pe_committed_ops` exactly.
    AluCommit,
    /// A PE executed a memory operation (load/store/accumulate/stream).
    MemOp,
    /// A message retired (reached terminal execution).
    Retire,
    /// A PE changed state; `arg` is the new [`PeTraceState`] code.
    PeState,
}

impl EventKind {
    /// Stable display name (Perfetto event name / flight-recorder tag).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Inject => "inject",
            EventKind::Hop => "hop",
            EventKind::Claim => "claim",
            EventKind::AluCommit => "alu_commit",
            EventKind::MemOp => "mem_op",
            EventKind::Retire => "retire",
            EventKind::PeState => "pe_state",
        }
    }
}

/// PE activity classification recorded by [`EventKind::PeState`] events,
/// derived at commit time from the busy latches and pending work:
/// compute when an ALU/decode latch fired, blocked when the PE holds
/// pending work but executed nothing, idle otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeTraceState {
    Idle = 0,
    Compute = 1,
    Blocked = 2,
}

impl PeTraceState {
    pub fn name(self) -> &'static str {
        match self {
            PeTraceState::Idle => "idle",
            PeTraceState::Compute => "compute",
            PeTraceState::Blocked => "blocked",
        }
    }

    /// Decode an `Event::arg` code (defaults to `Idle` for unknown codes).
    pub fn from_code(code: u32) -> Self {
        match code {
            1 => PeTraceState::Compute,
            2 => PeTraceState::Blocked,
            _ => PeTraceState::Idle,
        }
    }
}

/// One trace event: 24 bytes, `Copy`, no heap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Cycle the event occurred on.
    pub cycle: u64,
    /// Message id (0 for events without a message, e.g. PE states).
    pub msg: u64,
    /// PE / router id the event is anchored to.
    pub pe: u32,
    /// Kind-specific argument: port index for hops/claims, state code for
    /// PE states, destination for injects.
    pub arg: u16,
    pub kind: EventKind,
}

/// A fixed-capacity event ring. With `capacity == 0` it is an unbounded
/// append log (the full-tracing sink); with `capacity > 0` pushing into a
/// full ring drops the **oldest** event and counts it in
/// [`TraceBuffer::dropped`] — never a silent loss, never a reorder: FIFO
/// order of the survivors is preserved.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TraceBuffer {
    buf: Vec<Event>,
    /// Index of the oldest event when the ring has wrapped.
    head: usize,
    len: usize,
    capacity: usize,
    /// Events dropped to overflow since the last [`TraceBuffer::clear`].
    pub dropped: u64,
}

impl TraceBuffer {
    /// `capacity == 0` → unbounded append log; otherwise a ring keeping
    /// the most recent `capacity` events.
    pub fn new(capacity: usize) -> Self {
        TraceBuffer {
            buf: Vec::new(),
            head: 0,
            len: 0,
            capacity,
            dropped: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append an event, dropping the oldest one when a bounded ring is
    /// full.
    #[inline]
    pub fn push(&mut self, ev: Event) {
        if self.capacity == 0 {
            self.buf.push(ev);
            self.len += 1;
            return;
        }
        if self.len < self.capacity {
            if self.buf.len() < self.capacity {
                self.buf.push(ev);
            } else {
                let idx = (self.head + self.len) % self.capacity;
                self.buf[idx] = ev;
            }
            self.len += 1;
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.capacity;
            self.dropped += 1;
        }
    }

    /// The events in FIFO order (oldest first).
    pub fn iter(&self) -> impl Iterator<Item = &Event> {
        let (a, b) = if self.capacity == 0 || self.head == 0 {
            (&self.buf[..self.len.min(self.buf.len())], &self.buf[0..0])
        } else {
            (&self.buf[self.head..], &self.buf[..self.head])
        };
        a.iter().chain(b.iter())
    }

    /// Copy out the events in FIFO order.
    pub fn to_vec(&self) -> Vec<Event> {
        self.iter().copied().collect()
    }

    /// Drain every event in FIFO order into `sink`, leaving this buffer
    /// empty (capacity and drop count retained). This is the epoch-barrier
    /// merge: called per shard in shard index order.
    pub fn drain_into(&mut self, sink: &mut TraceBuffer) {
        if self.len == 0 {
            return;
        }
        if self.capacity == 0 || self.head == 0 {
            for &ev in &self.buf[..self.len.min(self.buf.len())] {
                sink.push(ev);
            }
        } else {
            for i in 0..self.len {
                sink.push(self.buf[(self.head + i) % self.capacity]);
            }
        }
        self.buf.clear();
        self.head = 0;
        self.len = 0;
    }

    /// Empty the buffer and reset the drop count. Capacity is retained;
    /// for unbounded logs the backing allocation is kept for reuse.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.len = 0;
        self.dropped = 0;
    }
}

/// Render events as Chrome trace-event JSON (the `about:tracing` /
/// Perfetto format): one metadata event naming each PE track, then one
/// instant event per fabric event (`ph: "i"`, thread-scoped), with the
/// cycle as the microsecond timestamp so the timeline reads in cycles.
pub fn chrome_trace_json(events: &[Event], width: usize, height: usize) -> String {
    let mut items: Vec<String> = Vec::with_capacity(events.len() + width * height);
    for id in 0..width * height {
        let (x, y) = (id % width.max(1), id / width.max(1));
        let mut args = JsonObj::new();
        args.str("name", &format!("PE {id} ({x},{y})"));
        let mut o = JsonObj::new();
        o.str("name", "thread_name")
            .str("ph", "M")
            .u64("pid", 0)
            .u64("tid", id as u64)
            .raw("args", &args.build());
        items.push(o.build());
    }
    for ev in events {
        let mut args = JsonObj::new();
        if ev.msg != 0 {
            args.hex("msg", ev.msg);
        }
        match ev.kind {
            EventKind::Hop | EventKind::Claim => {
                args.u64("port", ev.arg as u64);
            }
            EventKind::PeState => {
                args.str("state", PeTraceState::from_code(ev.arg as u32).name());
            }
            EventKind::Inject => {
                args.u64("dest", ev.arg as u64);
            }
            _ => {}
        }
        let mut o = JsonObj::new();
        o.str("name", ev.kind.name())
            .str("ph", "i")
            .str("s", "t")
            .u64("ts", ev.cycle)
            .u64("pid", 0)
            .u64("tid", ev.pe as u64)
            .raw("args", &args.build());
        items.push(o.build());
    }
    let mut root = JsonObj::new();
    root.raw("traceEvents", &array(items))
        .str("displayTimeUnit", "ms")
        .u64("eventCount", events.len() as u64);
    root.build()
}

/// Format the most recent `last_n` events as human-readable lines (the
/// flight-recorder dump attached to deadlock reports), newest last.
pub fn flight_lines(events: &[Event], last_n: usize) -> Vec<String> {
    let start = events.len().saturating_sub(last_n);
    events[start..]
        .iter()
        .map(|ev| {
            let mut line = format!("cycle {} PE{} {}", ev.cycle, ev.pe, ev.kind.name());
            if ev.msg != 0 {
                line.push_str(&format!(" msg={:#x}", ev.msg));
            }
            match ev.kind {
                EventKind::Hop | EventKind::Claim => line.push_str(&format!(" port={}", ev.arg)),
                EventKind::PeState => line.push_str(&format!(
                    " -> {}",
                    PeTraceState::from_code(ev.arg as u32).name()
                )),
                EventKind::Inject => line.push_str(&format!(" dest={}", ev.arg)),
                _ => {}
            }
            line
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycle: u64, pe: u32) -> Event {
        Event {
            cycle,
            msg: 0,
            pe,
            arg: 0,
            kind: EventKind::Hop,
        }
    }

    #[test]
    fn unbounded_buffer_keeps_everything_in_order() {
        let mut b = TraceBuffer::new(0);
        for i in 0..100 {
            b.push(ev(i, 0));
        }
        assert_eq!(b.len(), 100);
        assert_eq!(b.dropped, 0);
        let v = b.to_vec();
        assert!(v.windows(2).all(|w| w[0].cycle + 1 == w[1].cycle));
    }

    #[test]
    fn bounded_overflow_drops_oldest_and_counts() {
        let mut b = TraceBuffer::new(4);
        for i in 0..10 {
            b.push(ev(i, 0));
        }
        assert_eq!(b.len(), 4);
        assert_eq!(b.dropped, 6);
        // Survivors are the most recent four, still FIFO-ordered.
        let cycles: Vec<u64> = b.to_vec().iter().map(|e| e.cycle).collect();
        assert_eq!(cycles, vec![6, 7, 8, 9]);
    }

    #[test]
    fn overflow_preserves_epoch_merge_order() {
        // Two shard rings, one of which overflows mid-epoch: the merged
        // sink must stay FIFO within each shard and ordered by shard
        // index across shards — overflow never corrupts the merge.
        let mut shard0 = TraceBuffer::new(3);
        let mut shard1 = TraceBuffer::new(3);
        for i in 0..5 {
            shard0.push(ev(7, i)); // overflows: keeps pe 2,3,4
        }
        for i in 0..2 {
            shard1.push(ev(7, 100 + i));
        }
        let mut sink = TraceBuffer::new(0);
        shard0.drain_into(&mut sink);
        shard1.drain_into(&mut sink);
        let pes: Vec<u32> = sink.to_vec().iter().map(|e| e.pe).collect();
        assert_eq!(pes, vec![2, 3, 4, 100, 101]);
        assert_eq!(shard0.dropped, 2);
        assert_eq!(sink.dropped, 0);
        assert!(shard0.is_empty() && shard1.is_empty());
        // Next epoch reuses the rings from a clean state.
        shard0.push(ev(8, 9));
        shard0.drain_into(&mut sink);
        assert_eq!(sink.to_vec().last().map(|e| e.pe), Some(9));
    }

    #[test]
    fn bounded_sink_is_a_flight_recorder() {
        let mut sink = TraceBuffer::new(8);
        for i in 0..100 {
            sink.push(ev(i, 0));
        }
        let cycles: Vec<u64> = sink.to_vec().iter().map(|e| e.cycle).collect();
        assert_eq!(cycles, (92..100).collect::<Vec<u64>>());
        assert_eq!(sink.dropped, 92);
    }

    #[test]
    fn clear_resets_state_but_keeps_capacity() {
        let mut b = TraceBuffer::new(2);
        b.push(ev(0, 0));
        b.push(ev(1, 0));
        b.push(ev(2, 0));
        assert_eq!(b.dropped, 1);
        b.clear();
        assert_eq!((b.len(), b.dropped, b.capacity()), (0, 0, 2));
        b.push(ev(5, 1));
        assert_eq!(b.to_vec()[0].cycle, 5);
    }

    #[test]
    fn chrome_json_counts_match() {
        let events = vec![
            Event {
                cycle: 3,
                msg: 0x1_0001,
                pe: 2,
                arg: 1,
                kind: EventKind::Hop,
            },
            Event {
                cycle: 4,
                msg: 0,
                pe: 2,
                arg: PeTraceState::Compute as u16,
                kind: EventKind::PeState,
            },
        ];
        let json = chrome_trace_json(&events, 2, 2);
        assert!(json.contains("\"traceEvents\":["));
        assert_eq!(json.matches("\"ph\":\"i\"").count(), 2);
        assert_eq!(json.matches("\"ph\":\"M\"").count(), 4); // one per PE
        assert!(json.contains("\"eventCount\":2"));
        assert!(json.contains("\"state\":\"compute\""));
    }

    #[test]
    fn flight_lines_take_the_tail() {
        let events: Vec<Event> = (0..10).map(|i| ev(i, 1)).collect();
        let lines = flight_lines(&events, 3);
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("cycle 7"));
        assert!(lines[2].starts_with("cycle 9"));
    }

    #[test]
    fn config_presets_validate() {
        TraceConfig::off().validate().unwrap();
        TraceConfig::full().validate().unwrap();
        TraceConfig::flight_recorder(64).validate().unwrap();
        let bad = TraceConfig {
            shard_capacity: 0,
            ..TraceConfig::full()
        };
        assert!(bad.validate().is_err());
        let bad = TraceConfig {
            lifecycle: false,
            pe_states: false,
            ..TraceConfig::full()
        };
        assert!(bad.validate().is_err());
    }
}
