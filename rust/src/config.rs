//! Architectural configuration for the Nexus Machine fabric and its
//! ablation variants (TIA, TIA-Valiant), mirroring Table 1 of the paper.
//!
//! The same cycle-accurate fabric executes Nexus Machine, TIA and
//! TIA-Valiant: the three differ only in the [`ExecPolicy`] /
//! [`RoutingPolicy`] feature flags, which is exactly the paper's ablation
//! framing (§5.1: "TIA and TIA-Valiant ... serve as ablation points to
//! distinguish the benefits of en-route computation").

/// Which architecture variant a fabric instance models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArchKind {
    /// Full Nexus Machine: AMs carry instructions; idle PEs execute en-route.
    Nexus,
    /// Triggered-Instruction baseline: data-local execution only; AMs carry
    /// operands, instructions are anchored at the destination PE.
    Tia,
    /// TIA + Valiant randomized minimal-path load balancing: each message is
    /// first routed to a random intermediate PE, then to its destination.
    TiaValiant,
}

impl ArchKind {
    pub fn name(self) -> &'static str {
        match self {
            ArchKind::Nexus => "NexusMachine",
            ArchKind::Tia => "TIA",
            ArchKind::TiaValiant => "TIA-Valiant",
        }
    }
}

/// Whether in-network (en-route) execution of AMs on idle PEs is enabled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPolicy {
    /// Opportunistic execution: the paper's contribution.
    EnRoute,
    /// Execute only at the destination PE (TIA-style).
    DestinationOnly,
}

/// How the simulator schedules per-cycle work — a *simulator host* choice
/// with zero architectural meaning: both modes produce bit-identical
/// outputs, cycle counts, and [`crate::fabric::stats::FabricStats`].
///
/// The paper's whole premise (§3) is that irregular workloads leave most
/// PEs idle most cycles; [`StepMode::ActiveSet`] makes the *simulation*
/// cost track that activity instead of the mesh size, while
/// [`StepMode::DenseOracle`] keeps the obviously-correct dense scan around
/// as the differential-testing reference (`rust/tests/step_equivalence.rs`
/// asserts the equivalence property-by-property).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StepMode {
    /// Event-driven scheduling: each cycle visits only PEs/routers on the
    /// wake-list (woken by message commits, AXI refills, stream emissions,
    /// trigger-timer cooldowns, and en-route claims). The default.
    #[default]
    ActiveSet,
    /// The original dense scan: every phase visits all `width × height`
    /// components every cycle. O(PEs · cycles) regardless of activity —
    /// slow, simple, and the oracle the active-set core is checked against.
    DenseOracle,
}

impl StepMode {
    pub fn name(self) -> &'static str {
        match self {
            StepMode::ActiveSet => "active-set",
            StepMode::DenseOracle => "dense-oracle",
        }
    }
}

/// Network topology connecting the PE routers. See
/// [`crate::noc::topology`] for the link-level semantics of each variant.
///
/// The default [`TopologyKind::Mesh2D`] reproduces the paper's fabric
/// bit-identically; the other variants reuse the same router microarchitecture
/// (buffers, On/Off flow control, separable allocator) over different link
/// sets, so congestion behavior — where en-route execution lives — can be
/// compared across network shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TopologyKind {
    /// The paper's 2D mesh (default; bit-identical to the pre-topology
    /// simulator).
    #[default]
    Mesh2D,
    /// 2D torus: the mesh plus wraparound links on both axes. Shorter
    /// average distance; routed with shortest-wrap dimension-order routing
    /// plus bubble flow control for deadlock freedom on the rings.
    Torus2D,
    /// Ruche network: the mesh plus long-range skip links of stride
    /// [`ArchConfig::ruche_stride`] in all four directions.
    Ruche,
    /// Two-level chiplet hierarchy (DCRA-style): the mesh partitioned into
    /// [`ArchConfig::chiplet_dims`] tiles, with boundary-crossing links
    /// paying [`ArchConfig::inter_chiplet_latency`] cycles per hop.
    Chiplet2L,
}

impl TopologyKind {
    /// All variants, in CLI/report order.
    pub const ALL: [TopologyKind; 4] = [
        TopologyKind::Mesh2D,
        TopologyKind::Torus2D,
        TopologyKind::Ruche,
        TopologyKind::Chiplet2L,
    ];

    /// CLI / report name (`--topology <name>`).
    pub fn name(self) -> &'static str {
        match self {
            TopologyKind::Mesh2D => "mesh",
            TopologyKind::Torus2D => "torus",
            TopologyKind::Ruche => "ruche",
            TopologyKind::Chiplet2L => "chiplet",
        }
    }

    /// Parse a CLI name (as printed by [`TopologyKind::name`]).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// Data-placement (row → PE mapping) policy used by the compile path for
/// the row-partitioned sparse workloads (SpMV, SpMSpM's A operand). See
/// [`crate::compiler::partition`] for the algorithms.
///
/// Placement is a *compile-time* choice: it changes which PE owns each row
/// (and hence the static-AM program), so it is part of the compile-cache
/// key ([`crate::machine::cache::config_tag`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum PlacementPolicy {
    /// Contiguous nnz-balanced row split (§3.1.1; linear scan).
    NnzBalanced,
    /// Algorithm 1's dissimilarity-aware clustering: rows with similar
    /// bank-access sets share a PE under an nnz capacity bound (default;
    /// bit-identical to the pre-policy compiler).
    #[default]
    DissimilarityAware,
    /// Degree/nnz-aware hotspot splitting (DCRA-style): rows sorted by
    /// descending nnz, each assigned to the currently lightest PE (greedy
    /// LPT), spreading heavy rows across the fabric.
    HotspotSplit,
}

impl PlacementPolicy {
    /// All variants, in CLI/report order.
    pub const ALL: [PlacementPolicy; 3] = [
        PlacementPolicy::NnzBalanced,
        PlacementPolicy::DissimilarityAware,
        PlacementPolicy::HotspotSplit,
    ];

    /// CLI / report name (`--placement <name>`).
    pub fn name(self) -> &'static str {
        match self {
            PlacementPolicy::NnzBalanced => "nnz-balanced",
            PlacementPolicy::DissimilarityAware => "dissimilarity",
            PlacementPolicy::HotspotSplit => "hotspot-split",
        }
    }

    /// Parse a CLI name (as printed by [`PlacementPolicy::name`]).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|p| p.name() == s)
    }
}

/// En-route claim policy: when an idle PE's router holds a ready AM flit,
/// which (if any) flit does the PE claim for en-route execution this cycle?
///
/// Claiming is a *runtime* choice — it never changes the compiled program,
/// only the dynamic schedule — so it is not part of the compile-cache key.
/// All policies are deterministic and step-mode/shard invariant: they read
/// only per-cycle router state (plus, for [`ClaimPolicy::CreditBased`],
/// per-PE state that mutates *only at claim events*), so active-set and
/// dense-oracle stepping stay bit-identical under every policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ClaimPolicy {
    /// Claim the first ready flit in cycle-rotated port order (default;
    /// bit-identical to the pre-policy fabric).
    #[default]
    Eager,
    /// Among all ready flits, claim the one farthest from its destination
    /// (by topology hop distance): far-from-home flits gain the most from
    /// en-route execution, nearly-home flits ride to their owner PE.
    LocalityBiased,
    /// Rate-limit claims per PE: a PE claims at most one flit every
    /// [`ArchConfig::claim_credit_period`] cycles, spreading en-route work
    /// across more PEs instead of letting hot routers monopolize it.
    CreditBased,
    /// Congestion-gated stealing: claim only when the router's total input
    /// occupancy is at least [`ArchConfig::claim_steal_threshold`] flits,
    /// so lightly-loaded routers let traffic flow through untouched.
    StealK,
}

impl ClaimPolicy {
    /// All variants, in CLI/report order.
    pub const ALL: [ClaimPolicy; 4] = [
        ClaimPolicy::Eager,
        ClaimPolicy::LocalityBiased,
        ClaimPolicy::CreditBased,
        ClaimPolicy::StealK,
    ];

    /// CLI / report name (`--claim <name>`).
    pub fn name(self) -> &'static str {
        match self {
            ClaimPolicy::Eager => "eager",
            ClaimPolicy::LocalityBiased => "locality",
            ClaimPolicy::CreditBased => "credit",
            ClaimPolicy::StealK => "steal",
        }
    }

    /// Parse a CLI name (as printed by [`ClaimPolicy::name`]).
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|p| p.name() == s)
    }
}

/// NoC routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// West-first turn-model routing with congestion-aware adaptivity in the
    /// permitted quadrant (the paper's "dynamic turn model routing").
    TurnModelAdaptive,
    /// Deterministic XY dimension-order routing (used for sensitivity tests).
    Xy,
    /// Valiant: route to a random intermediate PE with XY, then XY to the
    /// real destination.
    Valiant,
}

/// Full architectural parameter set (Table 1 defaults).
#[derive(Debug, Clone)]
pub struct ArchConfig {
    /// Which variant this configuration models (sets defaults for flags).
    pub kind: ArchKind,
    /// Mesh width (PEs per row). Table 1: 4.
    pub width: usize,
    /// Mesh height (PEs per column). Table 1: 4.
    pub height: usize,
    /// Data-memory words (u16) per PE. Table 1: 1KB per PE = 512 words.
    pub dmem_words: usize,
    /// AM-queue capacity in entries of 70 bits. Table 1: 1KB -> 114 entries.
    /// This is the *on-chip window*; the logical queue streams from off-chip
    /// memory (§3.3.3) at AXI bandwidth, hiding load latency.
    pub am_queue_entries: usize,
    /// Configuration-memory entries per PE (§3.3.1: up to 8 configurations).
    pub config_entries: usize,
    /// Router input-buffer depth in flits (§3.3.2: three registers).
    pub router_buf_depth: usize,
    /// On/Off flow-control OFF threshold (free slots <= T_off => OFF).
    pub t_off: usize,
    /// On/Off flow-control ON threshold (free slots >= T_on => ON).
    pub t_on: usize,
    /// Execution policy (en-route vs destination-only).
    pub exec: ExecPolicy,
    /// Routing policy.
    pub routing: RoutingPolicy,
    /// Clock frequency in MHz (paper: synthesized at up to 588 MHz).
    pub freq_mhz: f64,
    /// Off-chip AXI bandwidth in bytes/cycle aggregated over the west-edge
    /// ports (Table 1: 4.7 GB/s at 588 MHz ~= 8 bytes/cycle).
    pub axi_bytes_per_cycle: f64,
    /// Latency (cycles) of the global idle/termination AND-tree (§3.1.4).
    pub idle_tree_latency: u64,
    /// Extra scheduler latency per triggered instruction for the TIA
    /// baseline's tag-matching/priority-encoder path (§1: "runtime scheduler
    /// for tag matching and a priority encoder ... adding significant
    /// hardware overhead"). 0 for Nexus.
    pub trigger_latency: u64,
    /// Safety net: simulation aborts (reporting deadlock) past this many
    /// cycles. Property tests rely on this to prove liveness.
    pub max_cycles: u64,
    /// Seed for any randomized behavior (Valiant intermediate selection).
    pub seed: u64,
    /// Simulator scheduling mode (host-side only; does not change modeled
    /// behavior). See [`StepMode`].
    pub step_mode: StepMode,
    /// Network topology connecting the routers. See [`TopologyKind`].
    pub topology: TopologyKind,
    /// Skip-link stride for [`TopologyKind::Ruche`] (ignored otherwise).
    /// A ruche link jumps `ruche_stride` routers along one axis.
    pub ruche_stride: usize,
    /// Chiplet tile dimensions (width, height) for
    /// [`TopologyKind::Chiplet2L`] (ignored otherwise). Must divide the
    /// array dimensions.
    pub chiplet_dims: (usize, usize),
    /// Per-hop latency in cycles of a link that crosses a chiplet boundary
    /// ([`TopologyKind::Chiplet2L`] only; intra-chiplet hops stay 1 cycle).
    pub inter_chiplet_latency: usize,
    /// Number of horizontal row-band shards the fabric is partitioned into
    /// for sharded stepping (must divide `height`). Each shard owns a
    /// contiguous band of rows with its own wake-lists, PRNG stream
    /// (`util::prng::stream_seed(seed, shard)`), message-id space, and
    /// stats delta; cross-shard flits travel through per-epoch mailboxes.
    /// `shards == 1` is bit-identical to the historical unsharded
    /// simulator. Like [`StepMode`], the *thread count* below is host-side
    /// only; the shard count is part of the modeled schedule (boundary
    /// routing decisions read epoch-start snapshots), so results are
    /// reproducible per `(seed, shards)` at **any** thread count.
    pub shards: usize,
    /// Worker threads stepping the shards in parallel (host-side only;
    /// clamped to `shards`). `1` steps every shard on the caller's thread;
    /// any value yields bit-identical results for a fixed shard count.
    pub threads: usize,
    /// Data-placement policy for row-partitioned sparse workloads
    /// (compile-time; part of the compile-cache key). See
    /// [`PlacementPolicy`].
    pub placement: PlacementPolicy,
    /// En-route claim policy (runtime-only schedule choice). See
    /// [`ClaimPolicy`]. Ignored when `exec` is
    /// [`ExecPolicy::DestinationOnly`].
    pub claim: ClaimPolicy,
    /// Minimum cycles between en-route claims per PE for
    /// [`ClaimPolicy::CreditBased`] (ignored otherwise).
    pub claim_credit_period: u64,
    /// Minimum router input occupancy (flits across all input buffers)
    /// before a PE claims for [`ClaimPolicy::StealK`] (ignored otherwise).
    pub claim_steal_threshold: usize,
    /// Event-tracing configuration ([`crate::trace::TraceConfig`]).
    /// Host-side observability only: tracing is provably inert — a traced
    /// run is bit-identical (outputs, cycles, stats, state digests) to an
    /// untraced one — and the field is deliberately excluded from the
    /// compile-cache key ([`crate::machine::cache::config_tag`]). Default
    /// off.
    pub trace: crate::trace::TraceConfig,
}

impl ArchConfig {
    /// Table 1 Nexus Machine configuration: 4x4 INT16 array, 1KB SRAM +
    /// 1KB AM queue per PE, 3-flit router buffers, T_off=1 / T_on=2.
    pub fn nexus() -> Self {
        Self {
            kind: ArchKind::Nexus,
            width: 4,
            height: 4,
            dmem_words: 512,
            am_queue_entries: 114, // 1KB / 70 bits
            config_entries: 8,
            router_buf_depth: 3,
            t_off: 1,
            t_on: 2,
            exec: ExecPolicy::EnRoute,
            routing: RoutingPolicy::TurnModelAdaptive,
            freq_mhz: 588.0,
            axi_bytes_per_cycle: 8.0,
            idle_tree_latency: 4,
            trigger_latency: 0,
            max_cycles: 2_000_000,
            seed: 0xA3C5,
            step_mode: StepMode::ActiveSet,
            topology: TopologyKind::Mesh2D,
            ruche_stride: 2,
            chiplet_dims: (4, 4),
            inter_chiplet_latency: 4,
            shards: 1,
            threads: 1,
            placement: PlacementPolicy::DissimilarityAware,
            claim: ClaimPolicy::Eager,
            claim_credit_period: 4,
            claim_steal_threshold: 2,
            trace: crate::trace::TraceConfig::off(),
        }
    }

    /// TIA baseline: identical fabric, destination-only execution, and one
    /// extra cycle of triggered-scheduler latency per instruction launch.
    /// Paper §4.1 gives TIA 2KB unified SRAM per PE; we keep the same split
    /// so data capacity matches.
    pub fn tia() -> Self {
        Self {
            kind: ArchKind::Tia,
            exec: ExecPolicy::DestinationOnly,
            routing: RoutingPolicy::TurnModelAdaptive,
            trigger_latency: 1,
            ..Self::nexus()
        }
    }

    /// TIA-Valiant: TIA with Valiant randomized minimal-path routing.
    pub fn tia_valiant() -> Self {
        Self {
            kind: ArchKind::TiaValiant,
            exec: ExecPolicy::DestinationOnly,
            routing: RoutingPolicy::Valiant,
            trigger_latency: 1,
            ..Self::nexus()
        }
    }

    /// Configuration for an `n x n` array (Fig 17 scalability sweeps).
    pub fn with_array(mut self, width: usize, height: usize) -> Self {
        self.width = width;
        self.height = height;
        self
    }

    /// Override the per-PE data memory (Fig 16 SRAM sweeps). `bytes` is the
    /// per-PE SRAM size in bytes; words are u16.
    pub fn with_dmem_bytes(mut self, bytes: usize) -> Self {
        self.dmem_words = bytes / 2;
        self
    }

    /// Override the aggregate off-chip bandwidth in bytes/cycle.
    pub fn with_axi_bandwidth(mut self, bytes_per_cycle: f64) -> Self {
        self.axi_bytes_per_cycle = bytes_per_cycle;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Override the simulator scheduling mode ([`StepMode`]). Both modes are
    /// bit-identical in outputs, cycles, and stats; `DenseOracle` exists for
    /// differential testing and debugging of the active-set scheduler.
    pub fn with_step_mode(mut self, mode: StepMode) -> Self {
        self.step_mode = mode;
        self
    }

    /// Override the network topology ([`TopologyKind`]).
    pub fn with_topology(mut self, topology: TopologyKind) -> Self {
        self.topology = topology;
        self
    }

    /// Override the ruche skip-link stride (implies nothing about topology;
    /// combine with [`Self::with_topology`]).
    pub fn with_ruche_stride(mut self, stride: usize) -> Self {
        self.ruche_stride = stride;
        self
    }

    /// Override the chiplet tile dimensions and inter-chiplet hop latency.
    pub fn with_chiplet(mut self, dims: (usize, usize), latency: usize) -> Self {
        self.chiplet_dims = dims;
        self.inter_chiplet_latency = latency;
        self
    }

    /// Override the shard count for sharded stepping (`--shards`). Must
    /// divide `height`; `1` (the default) is the unsharded simulator.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Override the worker-thread count for sharded stepping
    /// (`--threads`). Host-side only: results are bit-identical for a
    /// fixed shard count at any thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Override the data-placement policy ([`PlacementPolicy`]). Changes
    /// the compiled row → PE mapping for SpMV / SpMSpM-A; all other
    /// workloads keep their structural partitions.
    pub fn with_placement(mut self, placement: PlacementPolicy) -> Self {
        self.placement = placement;
        self
    }

    /// Override the en-route claim policy ([`ClaimPolicy`]). Runtime-only:
    /// the compiled program is unchanged, only the dynamic schedule moves.
    pub fn with_claim(mut self, claim: ClaimPolicy) -> Self {
        self.claim = claim;
        self
    }

    /// Override the event-tracing configuration
    /// ([`crate::trace::TraceConfig`]). Observability-only: results stay
    /// bit-identical to an untraced run.
    pub fn with_trace(mut self, trace: crate::trace::TraceConfig) -> Self {
        self.trace = trace;
        self
    }

    /// Number of PEs in the fabric.
    #[inline]
    pub fn num_pes(&self) -> usize {
        self.width * self.height
    }

    /// Total on-chip data SRAM in bytes across the array.
    pub fn total_dmem_bytes(&self) -> usize {
        self.num_pes() * self.dmem_words * 2
    }

    /// PE id for mesh coordinates.
    #[inline]
    pub fn pe_id(&self, x: usize, y: usize) -> usize {
        y * self.width + x
    }

    /// Mesh coordinates for a PE id.
    #[inline]
    pub fn pe_xy(&self, id: usize) -> (usize, usize) {
        (id % self.width, id / self.width)
    }

    /// Validate internal consistency; returns a human-readable error.
    pub fn validate(&self) -> Result<(), String> {
        if self.width == 0 || self.height == 0 {
            return Err("array dimensions must be nonzero".into());
        }
        if self.router_buf_depth < 2 {
            return Err("router buffers need >= 2 slots for the bubble rule".into());
        }
        if self.t_on <= self.t_off {
            return Err("T_on must exceed T_off for hysteresis".into());
        }
        if self.t_on > self.router_buf_depth {
            return Err("T_on cannot exceed buffer depth".into());
        }
        if self.config_entries == 0 || self.config_entries > 16 {
            return Err("config entries must be in 1..=16 (4-bit N_PC)".into());
        }
        if self.num_pes() > 16_384 {
            return Err("destination fields are 16-bit; at most 16384 PEs".into());
        }
        if self.shards == 0 {
            return Err("shard count must be >= 1".into());
        }
        if self.height % self.shards != 0 {
            return Err(format!(
                "shard count {} must divide the array height {}",
                self.shards, self.height
            ));
        }
        if self.threads == 0 {
            return Err("thread count must be >= 1".into());
        }
        if self.claim == ClaimPolicy::CreditBased && self.claim_credit_period == 0 {
            return Err("credit-based claim period must be >= 1 cycle".into());
        }
        if self.claim == ClaimPolicy::StealK && self.claim_steal_threshold == 0 {
            return Err("steal-K claim threshold must be >= 1 flit".into());
        }
        self.trace.validate()?;
        match self.topology {
            TopologyKind::Mesh2D | TopologyKind::Torus2D => {}
            TopologyKind::Ruche => {
                if self.ruche_stride < 2 {
                    return Err("ruche stride must be >= 2 (1 is a plain mesh link)".into());
                }
            }
            TopologyKind::Chiplet2L => {
                let (cw, ch) = self.chiplet_dims;
                if cw == 0 || ch == 0 || self.width % cw != 0 || self.height % ch != 0 {
                    return Err(format!(
                        "chiplet dims {cw}x{ch} must divide the {}x{} array",
                        self.width, self.height
                    ));
                }
                if self.inter_chiplet_latency == 0 || self.inter_chiplet_latency > 255 {
                    return Err("inter-chiplet latency must be in 1..=255 cycles".into());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let c = ArchConfig::nexus();
        assert_eq!(c.num_pes(), 16);
        assert_eq!(c.dmem_words * 2, 1024); // 1KB per PE
        assert_eq!(c.total_dmem_bytes(), 16 * 1024); // 16KB overall
        assert_eq!(c.am_queue_entries, 114);
        assert_eq!(c.router_buf_depth, 3);
        assert_eq!(c.t_off, 1);
        assert_eq!(c.t_on, 2);
        assert_eq!(c.step_mode, StepMode::ActiveSet);
        c.validate().unwrap();
    }

    #[test]
    fn step_mode_override_is_host_side_only() {
        let c = ArchConfig::nexus().with_step_mode(StepMode::DenseOracle);
        assert_eq!(c.step_mode, StepMode::DenseOracle);
        assert_eq!(c.step_mode.name(), "dense-oracle");
        // Everything architectural is untouched.
        assert_eq!(c.num_pes(), 16);
        c.validate().unwrap();
    }

    #[test]
    fn variant_flags() {
        assert_eq!(ArchConfig::nexus().exec, ExecPolicy::EnRoute);
        assert_eq!(ArchConfig::tia().exec, ExecPolicy::DestinationOnly);
        assert_eq!(ArchConfig::tia_valiant().routing, RoutingPolicy::Valiant);
        ArchConfig::tia().validate().unwrap();
        ArchConfig::tia_valiant().validate().unwrap();
    }

    #[test]
    fn xy_roundtrip() {
        let c = ArchConfig::nexus().with_array(5, 3);
        for id in 0..c.num_pes() {
            let (x, y) = c.pe_xy(id);
            assert_eq!(c.pe_id(x, y), id);
        }
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(ArchConfig::nexus().with_array(0, 4).validate().is_err());
        let mut c = ArchConfig::nexus();
        c.t_on = 1; // == t_off
        assert!(c.validate().is_err());
        let mut c = ArchConfig::nexus();
        c.router_buf_depth = 1;
        assert!(c.validate().is_err());
        // 20x20 = 400 PEs is now in range (16-bit destinations); the cap
        // rejects arrays past 16384 PEs.
        ArchConfig::nexus().with_array(20, 20).validate().unwrap();
        assert!(ArchConfig::nexus().with_array(200, 200).validate().is_err());
    }

    #[test]
    fn shard_and_thread_knobs_validated() {
        let c = ArchConfig::nexus();
        assert_eq!((c.shards, c.threads), (1, 1));
        c.with_shards(4).with_threads(8).validate().unwrap(); // 4 divides height 4
        ArchConfig::nexus().with_shards(2).validate().unwrap();
        assert!(ArchConfig::nexus().with_shards(0).validate().is_err());
        assert!(ArchConfig::nexus().with_threads(0).validate().is_err());
        // 3 does not divide the default height of 4.
        assert!(ArchConfig::nexus().with_shards(3).validate().is_err());
        ArchConfig::nexus()
            .with_array(8, 6)
            .with_shards(3)
            .validate()
            .unwrap();
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in PlacementPolicy::ALL {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        for c in ClaimPolicy::ALL {
            assert_eq!(ClaimPolicy::parse(c.name()), Some(c));
        }
        assert_eq!(PlacementPolicy::parse("round-robin"), None);
        assert_eq!(ClaimPolicy::parse("greedy"), None);
        // Defaults are bit-identical to the pre-policy simulator.
        assert_eq!(PlacementPolicy::default(), PlacementPolicy::DissimilarityAware);
        assert_eq!(ClaimPolicy::default(), ClaimPolicy::Eager);
        assert_eq!(ArchConfig::nexus().placement, PlacementPolicy::DissimilarityAware);
        assert_eq!(ArchConfig::nexus().claim, ClaimPolicy::Eager);
    }

    #[test]
    fn claim_knobs_validated() {
        ArchConfig::nexus()
            .with_claim(ClaimPolicy::CreditBased)
            .validate()
            .unwrap();
        let mut c = ArchConfig::nexus().with_claim(ClaimPolicy::CreditBased);
        c.claim_credit_period = 0;
        assert!(c.validate().is_err());
        let mut c = ArchConfig::nexus().with_claim(ClaimPolicy::StealK);
        c.claim_steal_threshold = 0;
        assert!(c.validate().is_err());
        // The knobs are ignored (and unvalidated) under other policies.
        let mut c = ArchConfig::nexus();
        c.claim_credit_period = 0;
        c.claim_steal_threshold = 0;
        c.validate().unwrap();
    }

    #[test]
    fn trace_config_off_by_default_and_validated() {
        use crate::trace::TraceConfig;
        let c = ArchConfig::nexus();
        assert_eq!(c.trace, TraceConfig::off());
        ArchConfig::nexus().with_trace(TraceConfig::full()).validate().unwrap();
        ArchConfig::nexus()
            .with_trace(TraceConfig::flight_recorder(128))
            .validate()
            .unwrap();
        let bad = TraceConfig {
            shard_capacity: 0,
            ..TraceConfig::full()
        };
        assert!(ArchConfig::nexus().with_trace(bad).validate().is_err());
    }

    #[test]
    fn topology_names_roundtrip() {
        for kind in TopologyKind::ALL {
            assert_eq!(TopologyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(TopologyKind::parse("hypercube"), None);
        assert_eq!(TopologyKind::default(), TopologyKind::Mesh2D);
        assert_eq!(ArchConfig::nexus().topology, TopologyKind::Mesh2D);
    }

    #[test]
    fn topology_configs_validated() {
        ArchConfig::nexus().with_topology(TopologyKind::Torus2D).validate().unwrap();
        ArchConfig::nexus()
            .with_topology(TopologyKind::Ruche)
            .with_ruche_stride(2)
            .validate()
            .unwrap();
        assert!(ArchConfig::nexus()
            .with_topology(TopologyKind::Ruche)
            .with_ruche_stride(1)
            .validate()
            .is_err());
        ArchConfig::nexus()
            .with_array(8, 8)
            .with_topology(TopologyKind::Chiplet2L)
            .with_chiplet((4, 4), 4)
            .validate()
            .unwrap();
        // Tile dims must divide the array; latency must be nonzero.
        assert!(ArchConfig::nexus()
            .with_array(8, 8)
            .with_topology(TopologyKind::Chiplet2L)
            .with_chiplet((3, 4), 4)
            .validate()
            .is_err());
        assert!(ArchConfig::nexus()
            .with_topology(TopologyKind::Chiplet2L)
            .with_chiplet((4, 4), 0)
            .validate()
            .is_err());
    }
}
