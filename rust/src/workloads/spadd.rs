//! SpM+SpM: element-wise sparse addition `C = A + B` (common in CNNs,
//! §4.2).
//!
//! Both operand matrices are *entirely* converted into static AMs — every
//! nonzero carries its value straight to the owner of the corresponding
//! (dense-accumulator) output row, where the decode unit merges it with a
//! local read-modify-write `ACCUM`. There is no ALU-class work in this
//! kernel: it is pure data movement + local aggregation, which is exactly
//! why data-local architectures beat shared-memory CGRAs on it (every CGRA
//! access to C is an indirect, conflict-prone bank access).
//!
//! C is partitioned aligned with A's rows; A's AMs are therefore PE-local
//! while B's traverse the network.

use super::{Built, Tiles};
use crate::am::Message;
use crate::compiler::{partition, ProgramBuilder};
use crate::config::ArchConfig;
use crate::isa::Opcode;
use crate::tensor::Csr;

pub fn build(a: &Csr, b_mat: &Csr, cfg: &ArchConfig) -> Built {
    assert_eq!((a.rows, a.cols), (b_mat.rows, b_mat.cols));
    let p = cfg.num_pes();
    // Balance the *merged* nonzero load across PEs.
    let merged = a.spadd(b_mat);
    let row_part = partition::nnz_balanced(&merged, p);

    let mut b = ProgramBuilder::new("spadd", cfg);
    let mut c_base = vec![0u16; a.rows];
    for r in 0..a.rows {
        c_base[r] = b.place(row_part[r], &vec![0i16; a.cols]);
    }

    let emit = |b: &mut ProgramBuilder, m: &Csr, src_of: &dyn Fn(usize) -> usize| {
        for r in 0..m.rows {
            for (c, v) in m.row(r) {
                let mut am = Message::new();
                am.opcode = Opcode::Accum; // terminal local aggregation
                am.op1 = v as u16;
                am.result = c_base[r] + c as u16;
                am.res_is_addr = true;
                am.push_dest(row_part[r] as u16);
                b.static_am(src_of(r), am);
            }
        }
    };
    // A's AMs live with C (data-local); B's are spread by its own rows so
    // they travel — the realistic placement when B arrives from elsewhere.
    emit(&mut b, a, &|r| row_part[r]);
    let brow_part = partition::nnz_balanced(b_mat, p);
    emit(&mut b, b_mat, &|r| brow_part[r]);

    for r in 0..a.rows {
        for c in 0..a.cols {
            b.output(row_part[r], c_base[r] + c as u16);
        }
    }

    Built {
        name: "spadd".into(),
        tiles: Tiles::Static(vec![b.build()]),
        expected: merged.to_dense().data,
        work_ops: (a.nnz() + b_mat.nnz()) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::prop::forall;
    use crate::util::SplitMix64;
    use crate::workloads::testutil::{check_built, exec_built};

    #[test]
    fn spadd_matches_reference() {
        let mut rng = SplitMix64::new(21);
        let a = gen::random_csr(&mut rng, 32, 32, 0.3);
        let b = gen::random_csr(&mut rng, 32, 32, 0.3);
        let cfg = ArchConfig::nexus();
        let built = build(&a, &b, &cfg);
        check_built(cfg, built);
    }

    #[test]
    fn spadd_cancellation_produces_zero() {
        // A + (-A) = 0 exercises wrapping RMW merges on every element.
        let mut rng = SplitMix64::new(22);
        let a = gen::random_csr(&mut rng, 16, 16, 0.4);
        let neg = Csr::from_triplets(
            16,
            16,
            (0..16).flat_map(|r| a.row(r).map(move |(c, v)| (r, c, -v))).collect::<Vec<_>>(),
        );
        let cfg = ArchConfig::nexus();
        let built = build(&a, &neg, &cfg);
        assert!(built.expected.iter().all(|&v| v == 0));
        exec_built(cfg, built).unwrap();
    }

    #[test]
    fn spadd_property_random_instances() {
        forall(6, |rng| {
            let r = 4 + rng.below_usize(20);
            let c = 4 + rng.below_usize(20);
            let a = gen::random_csr(rng, r, c, 0.35);
            let b = gen::random_csr(rng, r, c, 0.35);
            for cfg in [ArchConfig::nexus(), ArchConfig::tia()] {
                let built = build(&a, &b, &cfg);
                exec_built(cfg, built).map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }
}
