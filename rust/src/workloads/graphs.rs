//! Graph analytics: BFS, SSSP, PageRank (§4.2), on adjacency lists
//! partitioned with the METIS-substitute BFS-grow partitioner.
//!
//! BFS and SSSP use the fabric's *conditional re-emission* path: every
//! vertex's distance word carries a trigger descriptor pointing at its
//! out-edge stream table. An `ACCMIN` AM that improves `dist[v]` re-fires
//! the stream, fanning `ADD(dist, w)` AMs to the neighbors' owners
//! (PerDest mode); failed relaxations die silently — the asynchronous,
//! data-driven fixpoint the paper's execution model is built for.
//!
//! PageRank is host-iterated (§3.1.4 tile synchronization): each iteration
//! is a tile whose static AMs carry one edge's contribution
//! `rank[u] / (2·deg(u))` into `next[v]`, with ranks reloaded from the
//! previous tile's output by the lightweight runtime manager.

use super::{Built, Tiles};
use crate::am::Message;
use crate::compiler::{Program, ProgramBuilder};
use crate::config::ArchConfig;
use crate::isa::{ConfigEntry, Opcode};
use crate::pe::{StreamElem, StreamMode};
use crate::tensor::graph::INF;
use crate::tensor::Graph;
use crate::util::SplitMix64;

/// Shared BFS/SSSP builder: BFS is SSSP with unit weights.
fn build_relax(name: &str, g: &Graph, src: usize, unit_weights: bool, cfg: &ArchConfig) -> Built {
    let p = cfg.num_pes();
    let mut rng = SplitMix64::new(cfg.seed ^ 0x9A4B);
    let part = g.partition(p, &mut rng);

    let mut b = ProgramBuilder::new(name, cfg);
    // dist[v] at its owner, INF-initialized, with the out-edge trigger.
    let mut dist_addr = vec![0u16; g.num_vertices];
    for v in 0..g.num_vertices {
        dist_addr[v] = b.place(part[v], &[INF]);
    }
    for u in 0..g.num_vertices {
        let elems: Vec<StreamElem> = g.adj[u]
            .iter()
            .map(|&(v, w)| StreamElem {
                value: if unit_weights { 1 } else { w },
                aux: dist_addr[v],
                dest_pe: part[v] as u16,
                mode: StreamMode::PerDest,
            })
            .collect();
        if elems.is_empty() {
            continue;
        }
        let base = b.stream(part[u], &elems);
        b.trigger(part[u], dist_addr[u], base, elems.len() as u16);
    }

    // Config ring: ACCMIN improvement -> stream emits ADD -> ACCMIN -> ...
    let pc_min = b.config(ConfigEntry::new(Opcode::AccMin, 0).res_addr());
    let pc_add = b.config(ConfigEntry::new(Opcode::Add, pc_min));

    // Seed AM: relax dist[src] to 0.
    let mut am = Message::new();
    am.opcode = Opcode::AccMin;
    am.n_pc = pc_add;
    am.op1 = 0;
    am.result = dist_addr[src];
    am.res_is_addr = true;
    am.push_dest(part[src] as u16);
    b.static_am(part[src], am);

    for v in 0..g.num_vertices {
        b.output(part[v], dist_addr[v]);
    }
    let mut prog = b.build();
    // Close the config ring: AccMin's next entry is the ADD the re-fired
    // stream emits. (Entries were interned before the ring closed.)
    prog.config[pc_min as usize] = ConfigEntry::new(Opcode::AccMin, pc_add).res_addr();

    let expected = if unit_weights { g.bfs(src) } else { g.sssp(src) };
    Built {
        name: name.to_string(),
        tiles: Tiles::Static(vec![prog]),
        expected,
        work_ops: relaxation_work(g, src, unit_weights),
    }
}

/// Algorithmic work of the asynchronous relaxation: one ADD + one compare
/// per edge relaxation attempt in the reference worklist algorithm.
pub fn relaxation_work(g: &Graph, src: usize, unit_weights: bool) -> u64 {
    let mut dist = vec![INF; g.num_vertices];
    dist[src] = 0;
    let mut work = std::collections::VecDeque::from([src]);
    let mut attempts = 0u64;
    while let Some(u) = work.pop_front() {
        for &(v, w) in &g.adj[u] {
            attempts += 1;
            let w = if unit_weights { 1 } else { w };
            let nd = dist[u].saturating_add(w).min(INF);
            if nd < dist[v] {
                dist[v] = nd;
                work.push_back(v);
            }
        }
    }
    2 * attempts
}

pub fn build_bfs(g: &Graph, src: usize, cfg: &ArchConfig) -> Built {
    build_relax("bfs", g, src, true, cfg)
}

pub fn build_sssp(g: &Graph, src: usize, cfg: &ArchConfig) -> Built {
    build_relax("sssp", g, src, false, cfg)
}

/// Fixed-point integer PageRank, `iters` host-synchronized tiles.
pub fn build_pagerank(g: &Graph, iters: usize, cfg: &ArchConfig) -> Built {
    const SCALE: i32 = 4096;
    let n = g.num_vertices as i32;
    let base = ((SCALE / 2) / n.max(1)) as i16;
    let init = vec![(SCALE / n.max(1)) as i16; g.num_vertices];

    let p = cfg.num_pes();
    let mut rng = SplitMix64::new(cfg.seed ^ 0x77C1);
    let part = g.partition(p, &mut rng);

    // Pre-compute degrees; vertices with deg 0 contribute nothing.
    let deg: Vec<u16> = (0..g.num_vertices).map(|u| g.out_degree(u) as u16).collect();

    let g = g.clone();
    let cfg2 = cfg.clone();
    let gen = move |prev: &[i16], _iter: usize| -> Program {
        let rank: &[i16] = if prev.is_empty() { &init } else { prev };
        let mut b = ProgramBuilder::new("pagerank", &cfg2);
        // rank[u] and next[v] at the partition owners.
        let mut rank_addr = vec![0u16; g.num_vertices];
        let mut next_addr = vec![0u16; g.num_vertices];
        for v in 0..g.num_vertices {
            rank_addr[v] = b.place(part[v], &[rank[v]]);
        }
        for v in 0..g.num_vertices {
            next_addr[v] = b.place(part[v], &[base]);
        }
        // Config chain: LOAD1(static) -> DIV -> ACCUM.
        let pc_acc = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
        let pc_div = b.config(ConfigEntry::new(Opcode::Div, pc_acc));
        for u in 0..g.num_vertices {
            if deg[u] == 0 {
                continue;
            }
            for &(v, _) in &g.adj[u] {
                let mut am = Message::new();
                am.opcode = Opcode::LoadOp1; // op1 <- rank[u]
                am.n_pc = pc_div;
                am.op1 = rank_addr[u];
                am.op1_is_addr = true;
                am.op2 = 2 * deg[u]; // damping 0.5: divide by 2*deg
                am.result = next_addr[v];
                am.res_is_addr = true;
                am.push_dest(part[u] as u16);
                am.push_dest(part[v] as u16);
                b.static_am(part[u], am);
            }
        }
        for v in 0..g.num_vertices {
            b.output(part[v], next_addr[v]);
        }
        b.build()
    };

    let expected = g_ref_pagerank(&gen, iters);
    // 1 DIV + 1 add per edge per iteration.
    let edges: u64 = expected_edges(&gen);
    Built {
        name: "pagerank".into(),
        tiles: Tiles::Iterative {
            iters,
            gen: Box::new(gen),
        },
        expected,
        work_ops: 2 * edges * iters as u64,
    }
}

/// Reference PageRank via the same generator shapes (avoids re-deriving the
/// graph): runs `Graph::pagerank_int` on a reconstructed graph is not
/// possible from the closure, so this helper just replays the integer
/// recurrence the tiles encode. Kept separate for clarity.
fn g_ref_pagerank(
    gen: &(dyn Fn(&[i16], usize) -> Program + Send + Sync),
    iters: usize,
) -> Vec<i16> {
    // Execute the tiles *functionally*: interpret each program's static AMs
    // directly (LOAD1 -> DIV -> ACCUM is a pure reduction).
    let mut prev: Vec<i16> = Vec::new();
    for i in 0..iters {
        let prog = gen(&prev, i);
        // Collect per-(pe,addr) memory images.
        let mut mem: std::collections::HashMap<(usize, u16), i16> = std::collections::HashMap::new();
        for (pe, img) in prog.pes.iter().enumerate() {
            for &(addr, val) in &img.dmem_init {
                mem.insert((pe, addr), val as i16);
            }
        }
        for (_pe, img) in prog.pes.iter().enumerate() {
            for am in &img.static_ams {
                // LOAD1 at dest[0], DIV by op2, ACCUM at dest[1]/result.
                let rank = mem[&(am.dests[0] as usize, am.op1)];
                let contrib = if am.op2 == 0 { 0 } else { rank / am.op2 as i16 };
                let key = (am.dests[1] as usize, am.result);
                *mem.get_mut(&key).unwrap() = mem[&key].wrapping_add(contrib);
            }
        }
        prev = prog
            .outputs
            .iter()
            .map(|&(pe, addr)| mem[&(pe, addr)])
            .collect();
    }
    prev
}

fn expected_edges(gen: &(dyn Fn(&[i16], usize) -> Program + Send + Sync)) -> u64 {
    gen(&[], 0).num_static_ams() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{check_built, exec_built};

    fn small_graph(seed: u64, n: usize, contacts: usize) -> Graph {
        let mut rng = SplitMix64::new(seed);
        Graph::synthetic_contact(&mut rng, n, contacts)
    }

    #[test]
    fn bfs_matches_reference() {
        let g = small_graph(51, 48, 180);
        let cfg = ArchConfig::nexus();
        let built = build_bfs(&g, 0, &cfg);
        check_built(cfg, built);
    }

    #[test]
    fn sssp_matches_reference() {
        let g = small_graph(52, 48, 180);
        let cfg = ArchConfig::nexus();
        let built = build_sssp(&g, 3, &cfg);
        exec_built(cfg, built).unwrap();
    }

    #[test]
    fn sssp_on_tia_matches() {
        let g = small_graph(53, 32, 120);
        let cfg = ArchConfig::tia();
        let built = build_sssp(&g, 0, &cfg);
        exec_built(cfg, built).unwrap();
    }

    #[test]
    fn bfs_unreachable_vertices_stay_inf() {
        // Two disconnected cliques: vertices in the far clique keep INF.
        let mut g = Graph::new(8);
        for u in 0..4 {
            for v in (u + 1)..4 {
                g.add_undirected(u, v, 1);
                g.add_undirected(u + 4, v + 4, 1);
            }
        }
        let cfg = ArchConfig::nexus();
        let built = build_bfs(&g, 0, &cfg);
        let out = exec_built(cfg, built).unwrap().outputs;
        assert!(out[4..].iter().all(|&d| d == INF));
        assert_eq!(out[0], 0);
    }

    #[test]
    fn pagerank_matches_reference_integer_recurrence() {
        let g = small_graph(54, 40, 150);
        let cfg = ArchConfig::nexus();
        let built = build_pagerank(&g, 2, &cfg);
        // Cross-check the functional reference against Graph::pagerank_int.
        assert_eq!(built.expected, g.pagerank_int(2));
        exec_built(cfg, built).unwrap();
    }

    #[test]
    fn relaxation_work_positive_on_connected_graph() {
        let g = small_graph(55, 24, 100);
        assert!(relaxation_work(&g, 0, true) > 0);
    }
}
