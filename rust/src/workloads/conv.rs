//! Conv: single-channel 2-D valid convolution.
//!
//! §5.1: "Nexus Machine efficiently handles Conv by replicating filters
//! across PEs with minimal overhead" — no im2col. Each input pixel's owner
//! PE holds a *tap table*: the filter coefficients paired with the output
//! pixels that this input contributes to (the filter is thereby replicated
//! in every PE's local memory). A pixel's static AM triggers a PerDest
//! streaming decode that fans `MUL(pixel, f[i,j])` AMs out to the owners
//! of the affected outputs, where they accumulate.

use super::{Built, Tiles};
use crate::am::Message;
use crate::compiler::{partition, ProgramBuilder};
use crate::config::ArchConfig;
use crate::isa::{ConfigEntry, Opcode};
use crate::pe::{StreamElem, StreamMode};
use crate::tensor::Dense;

pub fn build(input: &Dense, filter: &Dense, cfg: &ArchConfig) -> Built {
    assert!(filter.rows <= input.rows && filter.cols <= input.cols);
    let oh = input.rows - filter.rows + 1;
    let ow = input.cols - filter.cols + 1;
    let p = cfg.num_pes();
    let inrow_part = partition::uniform_blocks(input.rows, p);
    let outrow_part = partition::uniform_blocks(oh, p);

    let mut b = ProgramBuilder::new("conv", cfg);

    // Output pixels, dense rows at their owners.
    let mut out_addr = vec![0u16; oh * ow];
    for h in 0..oh {
        let base = b.place(outrow_part[h], &vec![0i16; ow]);
        for w in 0..ow {
            out_addr[h * ow + w] = base + w as u16;
        }
    }

    // Config chain: Stream(static) -> MUL -> ACCUM.
    let pc_acc = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
    let pc_mul = b.config(ConfigEntry::new(Opcode::Mul, pc_acc));

    // Tap tables + one static AM per input pixel.
    let mut work_taps = 0u64;
    for h in 0..input.rows {
        for w in 0..input.cols {
            let mut taps = Vec::new();
            for i in 0..filter.rows {
                for j in 0..filter.cols {
                    // input(h,w) contributes to out(h-i, w-j) when valid.
                    let (Some(ohh), Some(oww)) = (h.checked_sub(i), w.checked_sub(j)) else {
                        continue;
                    };
                    if ohh >= oh || oww >= ow {
                        continue;
                    }
                    taps.push(StreamElem {
                        value: filter.get(i, j),
                        aux: out_addr[ohh * ow + oww],
                        dest_pe: outrow_part[ohh] as u16,
                        mode: StreamMode::PerDest,
                    });
                }
            }
            if taps.is_empty() {
                continue;
            }
            work_taps += taps.len() as u64;
            let pe = inrow_part[h];
            let base = b.stream(pe, &taps);
            let key = b.keyed_trigger(pe, base, taps.len() as u16);
            let mut am = Message::new();
            am.opcode = Opcode::Stream;
            am.n_pc = pc_mul;
            am.op1 = input.get(h, w) as u16; // the pixel value rides along
            am.op2 = key;
            am.op2_is_addr = true;
            am.res_is_addr = true; // emitted AMs' result is an address
            am.push_dest(pe as u16); // stream decodes locally
            b.static_am(pe, am);
        }
    }

    for h in 0..oh {
        for w in 0..ow {
            b.output(outrow_part[h], out_addr[h * ow + w]);
        }
    }

    Built {
        name: "conv".into(),
        tiles: Tiles::Static(vec![b.build()]),
        expected: input.conv2d_valid(filter).data,
        work_ops: 2 * work_taps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::SplitMix64;
    use crate::workloads::testutil::{check_built, exec_built};

    #[test]
    fn conv_matches_reference() {
        let mut rng = SplitMix64::new(41);
        let input = gen::random_dense(&mut rng, 10, 10, 3);
        let filter = gen::random_dense(&mut rng, 3, 3, 2);
        let cfg = ArchConfig::nexus();
        let built = build(&input, &filter, &cfg);
        check_built(cfg, built);
    }

    #[test]
    fn conv_identity_filter_is_copy() {
        let mut rng = SplitMix64::new(42);
        let input = gen::random_dense(&mut rng, 8, 8, 3);
        let filter = Dense::from_vec(1, 1, vec![1]);
        let cfg = ArchConfig::nexus();
        let built = build(&input, &filter, &cfg);
        assert_eq!(built.expected, input.data);
        exec_built(cfg, built).unwrap();
    }

    #[test]
    fn conv_on_tia() {
        let mut rng = SplitMix64::new(43);
        let input = gen::random_dense(&mut rng, 9, 9, 3);
        let filter = gen::random_dense(&mut rng, 2, 2, 2);
        let cfg = ArchConfig::tia();
        let built = build(&input, &filter, &cfg);
        exec_built(cfg, built).unwrap();
    }
}
