//! SDDMM: `C[i,j] = A[i,:] · B[:,j]` computed **only** at the nonzero
//! positions of a sparse binary mask (§4.2: "computes products only at
//! sparse locations, useful in sparse attention and graph neural
//! networks"; masks are ViTCoD-style attention patterns, i.e. binary).
//!
//! This is the kernel the paper's three-destination AM format was sized
//! for (§3.2: "as SDDMM has three inputs, destinations correspond to two
//! inputs and one output tensor"): each mask nonzero's static AM routes
//!
//!   R1 = owner of A row i   — streaming decode of the K elements `A[i,k]`
//!   R2 = owner of B col j   — each emitted AM dereferences `B[k,j]`
//!                             (OffsetOp1 mode: column base + k)
//!   R3 = owner of `C[i,j]`  — MUL en-route, local accumulation
//!
//! A rows live as stream tables; B is stored column-major so each column is
//! a contiguous, locally addressable K-vector.

use super::{Built, Tiles};
use crate::am::Message;
use crate::compiler::{partition, ProgramBuilder};
use crate::config::ArchConfig;
use crate::isa::{ConfigEntry, Opcode};
use crate::pe::{StreamElem, StreamMode};
use crate::tensor::{Csr, Dense};

pub fn build(mask: &Csr, a: &Dense, b_mat: &Dense, cfg: &ArchConfig) -> Built {
    assert_eq!(mask.rows, a.rows);
    assert_eq!(mask.cols, b_mat.cols);
    assert_eq!(a.cols, b_mat.rows);
    assert!(
        mask.values.iter().all(|&v| v == 1),
        "SDDMM masks are binary sparsity patterns"
    );
    let p = cfg.num_pes();
    let k_dim = a.cols;
    // Mask rows (and C, aligned) by nnz balance; A rows / B cols uniform.
    let mask_part = partition::nnz_balanced(mask, p);
    let arow_part = partition::uniform_blocks(a.rows, p);
    let bcol_part = partition::uniform_blocks(b_mat.cols, p);

    let mut bld = ProgramBuilder::new("sddmm", cfg);

    // A rows as stream tables (value = A[i,k], aux = k).
    let mut a_key = vec![0u16; a.rows];
    for i in 0..a.rows {
        let elems: Vec<StreamElem> = (0..k_dim)
            .map(|k| StreamElem {
                value: a.get(i, k),
                aux: k as u16,
                dest_pe: 0,
                mode: StreamMode::OffsetOp1,
            })
            .collect();
        let base = bld.stream(arow_part[i], &elems);
        a_key[i] = bld.keyed_trigger(arow_part[i], base, k_dim as u16);
    }
    // B columns as contiguous K-vectors.
    let mut bcol_base = vec![0u16; b_mat.cols];
    for j in 0..b_mat.cols {
        let col: Vec<i16> = (0..k_dim).map(|k| b_mat.get(k, j)).collect();
        bcol_base[j] = bld.place(bcol_part[j], &col);
    }
    // C: one accumulator word per mask nonzero, at the mask row's owner.
    let mut c_loc = Vec::with_capacity(mask.nnz());
    for i in 0..mask.rows {
        for (_j, _) in mask.row(i) {
            c_loc.push((mask_part[i], bld.place(mask_part[i], &[0])));
        }
    }

    // Config chain: Stream(static) -> LOAD1(B deref) -> MUL -> ACCUM.
    let pc_acc = bld.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
    let pc_mul = bld.config(ConfigEntry::new(Opcode::Mul, pc_acc));
    let pc_ld1 = bld.config(ConfigEntry::new(Opcode::LoadOp1, pc_mul).op1_addr());

    let mut nz = 0usize;
    for i in 0..mask.rows {
        for (j, _) in mask.row(i) {
            let (c_pe, c_addr) = c_loc[nz];
            nz += 1;
            let mut am = Message::new();
            am.opcode = Opcode::Stream;
            am.n_pc = pc_ld1;
            am.op1 = bcol_base[j]; // B column base; emission adds k
            am.op2 = a_key[i];
            am.op2_is_addr = true;
            am.result = c_addr;
            am.res_is_addr = true;
            am.push_dest(arow_part[i] as u16); // R1: A row stream
            am.push_dest(bcol_part[j] as u16); // R2: B column deref
            am.push_dest(c_pe as u16); // R3: C accumulate
            bld.static_am(mask_part[i], am);
        }
    }
    for &(pe, addr) in &c_loc {
        bld.output(pe, addr);
    }

    // Reference: dot products at mask positions, in mask row-major order.
    let mut expected = Vec::with_capacity(mask.nnz());
    for i in 0..mask.rows {
        for (j, _) in mask.row(i) {
            let mut dot = 0i16;
            for k in 0..k_dim {
                dot = dot.wrapping_add(a.get(i, k).wrapping_mul(b_mat.get(k, j)));
            }
            expected.push(dot);
        }
    }

    Built {
        name: "sddmm".into(),
        tiles: Tiles::Static(vec![bld.build()]),
        expected,
        work_ops: (mask.nnz() * k_dim * 2) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::SplitMix64;
    use crate::workloads::binary_mask;
    use crate::workloads::testutil::{check_built, exec_built};

    #[test]
    fn sddmm_matches_reference() {
        let mut rng = SplitMix64::new(31);
        let mask = binary_mask(&mut rng, 16, 16, 0.3);
        let a = gen::random_dense(&mut rng, 16, 8, 3);
        let b = gen::random_dense(&mut rng, 8, 16, 3);
        let cfg = ArchConfig::nexus();
        let built = build(&mask, &a, &b, &cfg);
        check_built(cfg, built);
    }

    #[test]
    fn sddmm_uses_three_destinations() {
        let mut rng = SplitMix64::new(32);
        let mask = binary_mask(&mut rng, 8, 8, 0.4);
        let a = gen::random_dense(&mut rng, 8, 4, 3);
        let b = gen::random_dense(&mut rng, 4, 8, 3);
        let cfg = ArchConfig::nexus();
        let built = build(&mask, &a, &b, &cfg);
        if let Tiles::Static(ts) = &built.tiles {
            let any3 = ts[0]
                .pes
                .iter()
                .flat_map(|p| &p.static_ams)
                .any(|am| am.ndests == 3);
            assert!(any3, "SDDMM static AMs must carry R1,R2,R3");
        }
        exec_built(cfg, built).unwrap();
    }

    #[test]
    fn sddmm_on_tia_and_valiant() {
        let mut rng = SplitMix64::new(33);
        let mask = binary_mask(&mut rng, 12, 12, 0.3);
        let a = gen::random_dense(&mut rng, 12, 6, 3);
        let b = gen::random_dense(&mut rng, 6, 12, 3);
        for cfg in [ArchConfig::tia(), ArchConfig::tia_valiant()] {
            let built = build(&mask, &a, &b, &cfg);
            exec_built(cfg, built).unwrap();
        }
    }

    #[test]
    fn empty_mask_produces_no_outputs() {
        let mask = Csr::zero(8, 8);
        let mut rng = SplitMix64::new(34);
        let a = gen::random_dense(&mut rng, 8, 4, 3);
        let b = gen::random_dense(&mut rng, 4, 8, 3);
        let cfg = ArchConfig::nexus();
        let built = build(&mask, &a, &b, &cfg);
        let out = exec_built(cfg, built).unwrap().outputs;
        assert!(out.is_empty());
    }
}
