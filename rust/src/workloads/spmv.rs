//! SpMV: `y = A * x` with sparse A (Fig 4/Fig 5's running example).
//!
//! Choreography (the three tasks of Fig 4a):
//!
//! - **T1** is folded into the static AM itself: the compiler has already
//!   paired each matrix nonzero `A[r,c]` (carried as `Op1`) with the
//!   location of `x[c]` (R1 + `Op2` address) and of `y[r]` (R2 + `Result`
//!   address), exactly as §3.6 describes.
//! - **T2**: at `x[c]`'s owner the decode unit dereferences `Op2`; the AM
//!   morphs to `MUL` and is sent toward `y[r]`, executing *en-route* on the
//!   first idle ALU (§3.1.3).
//! - **T3**: at `y[r]`'s owner the decode unit performs the local
//!   aggregation (`ACCUM`).

use super::{place_vector, Built, Tiles};
use crate::am::Message;
use crate::compiler::{partition, ProgramBuilder};
use crate::config::ArchConfig;
use crate::isa::{ConfigEntry, Opcode};
use crate::tensor::Csr;

/// Build SpMV (or dense MV via a dense-as-CSR matrix; `name` labels it).
pub fn build(name: &str, a: &Csr, x: &[i16], cfg: &ArchConfig) -> Built {
    assert_eq!(x.len(), a.cols);
    let p = cfg.num_pes();
    // Primary tensor: row mapping under the configured placement policy
    // (default: Algorithm 1's dissimilarity-aware clustering); the 1-D
    // tensors partition correspondingly (§3.1.1).
    let row_part = partition::place_rows(a, p, 8, cfg.placement);
    let col_part = partition::uniform_blocks(a.cols, p);

    let mut b = ProgramBuilder::new(name, cfg);
    let xs = place_vector(&mut b, &col_part, x);
    let ys = place_vector(&mut b, &row_part[..a.rows], &vec![0i16; a.rows]);

    // Config chain: Load(static AM) -> MUL -> ACCUM.
    let pc_acc = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
    let pc_mul = b.config(ConfigEntry::new(Opcode::Mul, pc_acc));

    for r in 0..a.rows {
        for (c, v) in a.row(r) {
            let mut am = Message::new();
            am.opcode = Opcode::Load; // T2's dereference at x[c]'s owner
            am.n_pc = pc_mul;
            am.op1 = v as u16;
            am.op2 = xs.addr[c];
            am.op2_is_addr = true;
            am.result = ys.addr[r];
            am.res_is_addr = true;
            am.push_dest(xs.pe[c] as u16);
            am.push_dest(ys.pe[r] as u16);
            b.static_am(row_part[r], am);
        }
    }
    for r in 0..a.rows {
        b.output(ys.pe[r], ys.addr[r]);
    }

    let expected = a.spmv(x);
    let work_ops = 2 * a.nnz() as u64; // one MUL + one add per nonzero
    Built {
        name: name.to_string(),
        tiles: Tiles::Static(vec![b.build()]),
        expected,
        work_ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen;
    use crate::util::prop::forall;
    use crate::util::SplitMix64;
    use crate::workloads::testutil::{check_built, exec_built};

    #[test]
    fn spmv_matches_reference_on_nexus() {
        let mut rng = SplitMix64::new(11);
        let a = gen::skewed_csr(&mut rng, 32, 32, 0.25);
        let x = gen::random_vec(&mut rng, 32, 3);
        let cfg = ArchConfig::nexus();
        let built = build("spmv", &a, &x, &cfg);
        check_built(cfg, built);
    }

    #[test]
    fn spmv_matches_reference_on_tia_and_valiant() {
        let mut rng = SplitMix64::new(12);
        let a = gen::random_csr(&mut rng, 24, 24, 0.3);
        let x = gen::random_vec(&mut rng, 24, 3);
        for cfg in [ArchConfig::tia(), ArchConfig::tia_valiant()] {
            let built = build("spmv", &a, &x, &cfg);
            exec_built(cfg, built).unwrap();
        }
    }

    #[test]
    fn spmv_property_random_instances() {
        forall(8, |rng| {
            let rows = 4 + rng.below_usize(24);
            let cols = 4 + rng.below_usize(24);
            let density = 0.2 + rng.f64() * 0.3;
            let a = gen::random_csr(rng, rows, cols, density);
            let x = gen::random_vec(rng, cols, 3);
            let cfg = ArchConfig::nexus();
            let built = build("spmv", &a, &x, &cfg);
            exec_built(cfg, built)
                .map(|_| ())
                .map_err(|e| e.to_string())
        });
    }

    #[test]
    fn empty_matrix_yields_zero_vector() {
        let a = Csr::zero(8, 8);
        let x = vec![1i16; 8];
        let cfg = ArchConfig::nexus();
        let built = build("spmv", &a, &x, &cfg);
        assert_eq!(built.expected, vec![0i16; 8]);
        exec_built(cfg, built).unwrap();
    }

    #[test]
    fn spmv_counts_work_ops() {
        let mut rng = SplitMix64::new(13);
        let a = gen::random_csr(&mut rng, 16, 16, 0.3);
        let built = build("spmv", &a, &gen::random_vec(&mut rng, 16, 3), &ArchConfig::nexus());
        assert_eq!(built.work_ops, 2 * a.nnz() as u64);
    }
}
