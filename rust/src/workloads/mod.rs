//! The evaluation workloads (§4.2): sparse (SpMV, SpMSpM S1–S4, SpM+SpM,
//! SDDMM), dense (MatMul, MV, Conv), and graph (BFS, SSSP, PageRank).
//!
//! Each workload module is the paper's *lightweight runtime manager* (§3.6)
//! for that kernel: it walks the partitioned tensors and emits one static AM
//! per element of the first operand, together with the per-PE data images,
//! stream tables, trigger descriptors, and the replicated config-memory
//! chain that the dynamic AMs morph through.
//!
//! A [`Spec`] describes a workload instance (the tensors); [`Spec::build`]
//! compiles it for a fabric configuration into a [`Built`] program-with-
//! expected-output. Execution goes through [`crate::machine::Machine`],
//! which compiles specs (with caching), runs them on a reusable fabric, and
//! validates outputs against the reference — this module only *builds*.

pub mod conv;
pub mod graphs;
pub mod sddmm;
pub mod spadd;
pub mod spmspm;
pub mod spmv;

use crate::compiler::{Program, ProgramBuilder};
use crate::config::ArchConfig;
use crate::tensor::gen::SparsityRegime;
use crate::tensor::{Csr, Dense, Graph};
use crate::util::SplitMix64;

/// Tile sequence of a compiled workload.
pub enum Tiles {
    /// Independent tiles, executed in order (most workloads: one tile).
    Static(Vec<Program>),
    /// Host-managed iterative tiles (PageRank): the runtime manager
    /// regenerates tile `i` from tile `i-1`'s output — §3.1.4's "data tiles
    /// are executed sequentially in a global synchronized manner".
    Iterative {
        iters: usize,
        gen: Box<dyn Fn(&[i16], usize) -> Program + Send + Sync>,
    },
}

/// A workload compiled for one fabric configuration.
pub struct Built {
    pub name: String,
    pub tiles: Tiles,
    /// Reference output (the simulator must match this bit-for-bit).
    pub expected: Vec<i16>,
    /// Algorithmic useful operations (multiplies + adds + compares the
    /// *kernel* requires), identical across architectures — the numerator
    /// for normalized performance and MOPS comparisons.
    pub work_ops: u64,
}

/// A workload instance: the kernel plus its concrete tensors.
pub enum Spec {
    Spmv { a: Csr, x: Vec<i16> },
    SpMSpM { a: Csr, b: Csr, regime: SparsityRegime },
    SpAdd { a: Csr, b: Csr },
    Sddmm { mask: Csr, a: Dense, b: Dense },
    MatMul { a: Dense, b: Dense },
    Mv { a: Dense, x: Vec<i16> },
    Conv { input: Dense, filter: Dense },
    Bfs { g: Graph, src: usize },
    Sssp { g: Graph, src: usize },
    PageRank { g: Graph, iters: usize },
}

impl Spec {
    /// Display name, with the sparsity annotation of Fig 11's x-axis.
    pub fn name(&self) -> String {
        match self {
            Spec::Spmv { a, .. } => format!("SpMV({:.0}%)", a.sparsity() * 100.0),
            Spec::SpMSpM { regime, .. } => format!("SpMSpM-{}", regime.name()),
            Spec::SpAdd { a, .. } => format!("SpM+SpM({:.0}%)", a.sparsity() * 100.0),
            Spec::Sddmm { mask, .. } => format!("SDDMM({:.0}%)", mask.sparsity() * 100.0),
            Spec::MatMul { .. } => "MatMul".into(),
            Spec::Mv { .. } => "MV".into(),
            Spec::Conv { .. } => "Conv".into(),
            Spec::Bfs { .. } => "BFS".into(),
            Spec::Sssp { .. } => "SSSP".into(),
            Spec::PageRank { .. } => "PageRank".into(),
        }
    }

    /// Workload class (sparse / dense / graph) for report grouping.
    pub fn class(&self) -> &'static str {
        match self {
            Spec::Spmv { .. } | Spec::SpMSpM { .. } | Spec::SpAdd { .. } | Spec::Sddmm { .. } => {
                "sparse"
            }
            Spec::MatMul { .. } | Spec::Mv { .. } | Spec::Conv { .. } => "dense",
            Spec::Bfs { .. } | Spec::Sssp { .. } | Spec::PageRank { .. } => "graph",
        }
    }

    /// Compile for a fabric configuration.
    pub fn build(&self, cfg: &ArchConfig) -> Built {
        match self {
            Spec::Spmv { a, x } => spmv::build("spmv", a, x, cfg),
            Spec::SpMSpM { a, b, regime } => {
                spmspm::build_tiled(&format!("spmspm-{}", regime.name()), a, b, cfg)
            }
            Spec::SpAdd { a, b } => spadd::build(a, b, cfg),
            Spec::Sddmm { mask, a, b } => sddmm::build(mask, a, b, cfg),
            Spec::MatMul { a, b } => {
                spmspm::build_tiled("matmul", &Csr::from_dense(a), &Csr::from_dense(b), cfg)
            }
            Spec::Mv { a, x } => spmv::build("mv", &Csr::from_dense(a), x, cfg),
            Spec::Conv { input, filter } => conv::build(input, filter, cfg),
            Spec::Bfs { g, src } => graphs::build_bfs(g, *src, cfg),
            Spec::Sssp { g, src } => graphs::build_sssp(g, *src, cfg),
            Spec::PageRank { g, iters } => graphs::build_pagerank(g, *iters, cfg),
        }
    }

    /// The loop-body dataflow graph (feeds the Generic-CGRA baseline model
    /// and the compile-time experiment).
    pub fn dfg(&self) -> crate::compiler::dfg::Dfg {
        use crate::compiler::dfg;
        match self {
            Spec::Spmv { .. } | Spec::Mv { .. } => dfg::spmv_dfg(),
            Spec::SpMSpM { .. } | Spec::MatMul { .. } => dfg::spmspm_dfg(),
            Spec::SpAdd { .. } => dfg::spadd_dfg(),
            Spec::Sddmm { .. } => dfg::sddmm_dfg(),
            Spec::Conv { .. } => dfg::conv_dfg(),
            Spec::Bfs { .. } | Spec::Sssp { .. } => dfg::relax_dfg(),
            Spec::PageRank { .. } => dfg::pagerank_dfg(),
        }
    }
}

/// The full Fig 11 evaluation suite at fabric-sized workloads: SpMSpM
/// S1–S4, SpMV, SpM+SpM, SDDMM, MatMul, MV, Conv, BFS, SSSP, PageRank.
/// Deterministic in `seed`.
pub fn suite(seed: u64) -> Vec<Spec> {
    let mut rng = SplitMix64::new(seed);
    let mut v = Vec::new();
    for regime in SparsityRegime::all() {
        let (a, b) = crate::tensor::gen::spmspm_pair(&mut rng, 48, regime);
        v.push(Spec::SpMSpM { a, b, regime });
    }
    // SpMV on a pruned-ResNet-50-like layer (80% sparsity).
    let a = crate::tensor::gen::skewed_csr(&mut rng, 64, 64, 0.2);
    let x = crate::tensor::gen::random_vec(&mut rng, 64, 3);
    v.push(Spec::Spmv { a, x });
    // SpM+SpM at 70% sparsity.
    let a = crate::tensor::gen::random_csr(&mut rng, 64, 64, 0.3);
    let b = crate::tensor::gen::random_csr(&mut rng, 64, 64, 0.3);
    v.push(Spec::SpAdd { a, b });
    // SDDMM with a ViTCoD-like 70%-sparse binary mask.
    let mask = binary_mask(&mut rng, 32, 32, 0.3);
    let a = crate::tensor::gen::random_dense(&mut rng, 32, 16, 3);
    let b = crate::tensor::gen::random_dense(&mut rng, 16, 32, 3);
    v.push(Spec::Sddmm { mask, a, b });
    // Dense: MatMul, MV, Conv.
    let a = crate::tensor::gen::random_dense(&mut rng, 24, 24, 3);
    let b = crate::tensor::gen::random_dense(&mut rng, 24, 24, 3);
    v.push(Spec::MatMul { a, b });
    let a = crate::tensor::gen::random_dense(&mut rng, 48, 48, 3);
    let x = crate::tensor::gen::random_vec(&mut rng, 48, 3);
    v.push(Spec::Mv { a, x });
    let input = crate::tensor::gen::random_dense(&mut rng, 12, 12, 3);
    let filter = crate::tensor::gen::random_dense(&mut rng, 3, 3, 2);
    v.push(Spec::Conv { input, filter });
    // Graph analytics on an infect-dublin-like contact graph scaled to the
    // fabric's distributed SRAM.
    let g = Graph::synthetic_contact(&mut rng, 96, 420);
    v.push(Spec::Bfs { g: g.clone(), src: 0 });
    v.push(Spec::Sssp { g: g.clone(), src: 0 });
    v.push(Spec::PageRank { g, iters: 2 });
    v
}

/// Random binary (all-ones) sparse mask — SDDMM masks are sparsity
/// *patterns* (ViTCoD-style attention masks), not weighted values.
pub fn binary_mask(rng: &mut SplitMix64, rows: usize, cols: usize, density: f64) -> Csr {
    let mut trip = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if rng.chance(density) {
                trip.push((r, c, 1i16));
            }
        }
    }
    Csr::from_triplets(rows, cols, trip)
}

/// Place one element per index of a logical 1-D tensor across PEs:
/// `part[i]` names the owner PE. Returns the (pe, dmem address) of every
/// element.
pub struct Placed {
    pub pe: Vec<usize>,
    pub addr: Vec<u16>,
}

pub fn place_vector(b: &mut ProgramBuilder, part: &[usize], values: &[i16]) -> Placed {
    assert_eq!(part.len(), values.len());
    let mut pe = Vec::with_capacity(values.len());
    let mut addr = Vec::with_capacity(values.len());
    for (i, &v) in values.iter().enumerate() {
        pe.push(part[i]);
        addr.push(b.place(part[i], &[v]));
    }
    Placed { pe, addr }
}

/// Test support: execute hand-built programs through the `Machine` API so
/// the workload compilers' unit tests exercise the same path as production
/// callers (no test-only fabric plumbing).
#[cfg(test)]
pub(crate) mod testutil {
    use super::Built;
    use crate::config::ArchConfig;
    use crate::machine::{Compiled, ExecError, Execution, Machine};

    /// Execute `built` on a fresh fabric machine for `cfg`, validating the
    /// outputs against the program's reference.
    pub fn exec_built(cfg: ArchConfig, built: Built) -> Result<Execution, ExecError> {
        let mut m = Machine::new(cfg);
        m.execute(&Compiled::from_built(built))
    }

    /// As [`exec_built`], also asserting message conservation.
    pub fn check_built(cfg: ArchConfig, built: Built) -> Execution {
        let e = exec_built(cfg, built).unwrap();
        let s = e.stats.as_ref().expect("fabric execution has stats");
        assert_eq!(
            s.msgs_created, s.msgs_retired,
            "conservation violated: created {} != retired {}",
            s.msgs_created, s.msgs_retired
        );
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_thirteen_workloads() {
        let s = suite(1);
        assert_eq!(s.len(), 13);
        let names: Vec<String> = s.iter().map(|w| w.name()).collect();
        assert!(names.iter().any(|n| n.starts_with("SpMSpM-S1")));
        assert!(names.iter().any(|n| n.starts_with("SpMSpM-S4")));
        assert!(names.iter().any(|n| n == "MatMul"));
        assert!(names.iter().any(|n| n == "PageRank"));
    }

    #[test]
    fn suite_is_deterministic() {
        let a = suite(7);
        let b = suite(7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name(), y.name());
        }
    }

    #[test]
    fn binary_mask_values_are_one() {
        let mut rng = SplitMix64::new(3);
        let m = binary_mask(&mut rng, 16, 16, 0.4);
        assert!(m.values.iter().all(|&v| v == 1));
        m.validate().unwrap();
    }

    #[test]
    fn classes_cover_three_groups() {
        let s = suite(1);
        for class in ["sparse", "dense", "graph"] {
            assert!(s.iter().any(|w| w.class() == class), "missing {class}");
        }
    }
}
