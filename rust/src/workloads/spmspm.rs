//! SpMSpM: `C = A * B`, both sparse, via Gustavson's row-wise algorithm
//! (§4.2): `C[i,:] += A[i,k] * B[k,:]` for every nonzero `A[i,k]`.
//!
//! Choreography: each `A[i,k]` becomes a static AM carrying the value and
//! targeting the PE that owns **B row k**, where it triggers a *streaming
//! decode* (§3.3.1) of that row. Each streamed element `B[k,j]` produces a
//! dynamic AM `MUL(A[i,k], B[k,j])` addressed at `C[i,j]` (OffsetResult
//! mode: output-row base + column index), executed en-route, and finally
//! accumulated at C row i's owner.
//!
//! Empty B rows emit nothing — the "AMs terminate early when they do not
//! find corresponding elements in the other matrices" effect that makes
//! performance *improve* with B's sparsity (§5.1).
//!
//! Output rows are held dense (Gustavson's row accumulator) and written
//! back at tile end. [`build_tiled`] splits A's rows into tiles whose
//! footprint (full B stream tables + the tile's C rows) fits the per-PE
//! SRAM — the Fig 16 capacity/bandwidth trade-off.

use super::{Built, Tiles};
use crate::am::Message;
use crate::compiler::{partition, Program, ProgramBuilder};
use crate::config::ArchConfig;
use crate::isa::{ConfigEntry, Opcode};
use crate::pe::{StreamElem, StreamMode};
use crate::tensor::Csr;

/// Build single-tile SpMSpM (also used for dense MatMul via dense-as-CSR).
/// Panics if the instance does not fit the fabric — use [`build_tiled`]
/// for capacity-adaptive compilation.
pub fn build(name: &str, a: &Csr, b_mat: &Csr, cfg: &ArchConfig) -> Built {
    let tiles = vec![build_tile(name, a, 0..a.rows, b_mat, cfg)];
    let pairs: u64 = (0..a.rows)
        .flat_map(|i| a.row(i))
        .map(|(k, _)| b_mat.row_nnz(k) as u64)
        .sum();
    Built {
        name: name.to_string(),
        tiles: Tiles::Static(tiles),
        expected: a.spgemm(b_mat).to_dense().data,
        work_ops: 2 * pairs,
    }
}

/// Build SpMSpM split into 2-D (A-row × B-column) tiles sized to the
/// per-PE SRAM (§3.1.1: "for large tensors exceeding local capacity,
/// tiling decomposes the computation into smaller sub-tensors").
///
/// Column tiling keeps each tile self-contained — `C[rc, jc] = A[rc,:] ·
/// B[:, jc]` needs no cross-tile partial sums — while the per-tile reload
/// of B's column block is exactly the off-chip-traffic term Fig 16 sweeps
/// against on-chip capacity. Outputs (and `expected`) are emitted in tile
/// order: column blocks outermost, row blocks inner, row-major inside.
pub fn build_tiled(name: &str, a: &Csr, b_mat: &Csr, cfg: &ArchConfig) -> Built {
    // Choose the column-block width: halve until B's column block leaves
    // at least half the SRAM for A's rows and C, or a single column left.
    let mut width = b_mat.cols;
    let budget_words = cfg.num_pes() * cfg.dmem_words;
    loop {
        let bblock_words = 3 * (b_mat.nnz() * width).div_ceil(b_mat.cols) + b_mat.rows;
        if bblock_words * 2 <= budget_words || width == 1 {
            break;
        }
        width = width.div_ceil(2);
    }

    let mut tiles = Vec::new();
    let mut expected = Vec::new();
    let c_full = a.spgemm(b_mat).to_dense();
    let mut jc = 0usize;
    while jc < b_mat.cols {
        let jend = (jc + width).min(b_mat.cols);
        // B column block, columns remapped to 0..(jend-jc).
        let b_block = Csr::from_triplets(
            b_mat.rows,
            jend - jc,
            (0..b_mat.rows).flat_map(|k| {
                b_mat
                    .row(k)
                    .filter(move |&(j, _)| j >= jc && j < jend)
                    .map(move |(j, v)| (k, j - jc, v))
            }),
        );
        // Grow A-row tiles until validation would overflow a PE's SRAM.
        let mut start = 0usize;
        while start < a.rows {
            let mut end = start + 1;
            let mut last_good: Option<(usize, Program)> = None;
            while end <= a.rows {
                let probe = try_build_tile(name, a, start..end, &b_block, cfg);
                if let Some(p) = probe.filter(|p| p.validate(cfg).is_ok()) {
                    let step = ((end - start) / 2).max(1);
                    last_good = Some((end, p));
                    end += step;
                } else {
                    break;
                }
            }
            let (end, prog) = last_good.unwrap_or_else(|| {
                panic!(
                    "{name}: one A row with a {}-column B block overflows \
                     {}B/PE SRAM; fabric too small for this workload",
                    jend - jc,
                    cfg.dmem_words * 2
                )
            });
            for i in start..end {
                for j in jc..jend {
                    expected.push(c_full.get(i, j));
                }
            }
            tiles.push(prog);
            start = end;
        }
        jc = jend;
    }

    // One MUL + one add per (A[i,k], B[k,j]) pair.
    let pairs: u64 = (0..a.rows)
        .flat_map(|i| a.row(i))
        .map(|(k, _)| b_mat.row_nnz(k) as u64)
        .sum();
    Built {
        name: name.to_string(),
        tiles: Tiles::Static(tiles),
        expected,
        work_ops: 2 * pairs,
    }
}

/// Compile the rows `rows` of A against the whole of B into one tile.
/// Panics on SRAM overflow; use [`try_build_tile`] when probing capacity.
fn build_tile(
    name: &str,
    a: &Csr,
    rows: std::ops::Range<usize>,
    b_mat: &Csr,
    cfg: &ArchConfig,
) -> Program {
    try_build_tile(name, a, rows, b_mat, cfg)
        .unwrap_or_else(|| panic!("{name}: tile overflows the fabric SRAM"))
}

/// Fallible tile compiler: `None` when the tile's data does not fit.
fn try_build_tile(
    name: &str,
    a: &Csr,
    rows: std::ops::Range<usize>,
    b_mat: &Csr,
    cfg: &ArchConfig,
) -> Option<Program> {
    assert_eq!(a.cols, b_mat.rows);
    let p = cfg.num_pes();
    // A (and C, aligned with it) by the configured placement policy over
    // the tile's rows; B rows nnz-balanced so stream tables spread evenly.
    let a_tile = Csr::from_triplets(
        rows.len(),
        a.cols,
        rows.clone()
            .flat_map(|r| a.row(r).map(move |(c, v)| (r - rows.start, c, v))),
    );
    let arow_part = partition::place_rows(&a_tile, p, 8, cfg.placement);
    let brow_part = partition::nnz_balanced(b_mat, p);

    let mut b = ProgramBuilder::new(name, cfg);

    // C rows: dense accumulators at A's owners.
    let mut c_base = vec![0u16; rows.len()];
    for i in 0..rows.len() {
        c_base[i] = b.try_place(arow_part[i], &vec![0i16; b_mat.cols])?;
    }
    // B rows: stream tables at their owners, with a trigger key each.
    let mut b_key = vec![0u16; b_mat.rows];
    for k in 0..b_mat.rows {
        let elems: Vec<StreamElem> = b_mat
            .row(k)
            .map(|(j, v)| StreamElem {
                value: v,
                aux: j as u16,
                dest_pe: 0,
                mode: StreamMode::OffsetResult,
            })
            .collect();
        let base = b.stream(brow_part[k], &elems);
        let key = b.try_alloc(brow_part[k], 1)?;
        b_key[k] = b.trigger(brow_part[k], key, base, elems.len() as u16);
    }

    // Config chain: Stream(static) -> MUL -> ACCUM.
    let pc_acc = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
    let pc_mul = b.config(ConfigEntry::new(Opcode::Mul, pc_acc));

    for i in 0..rows.len() {
        for (k, av) in a_tile.row(i) {
            let mut am = Message::new();
            am.opcode = Opcode::Stream;
            am.n_pc = pc_mul; // emitted AMs carry MUL
            am.op1 = av as u16; // A value rides along
            am.op2 = b_key[k];
            am.op2_is_addr = true;
            am.result = c_base[i]; // output row base; emission adds j
            am.res_is_addr = true;
            am.push_dest(brow_part[k] as u16);
            am.push_dest(arow_part[i] as u16); // C row owner
            b.static_am(arow_part[i], am);
        }
    }
    for i in 0..rows.len() {
        for j in 0..b_mat.cols {
            b.output(arow_part[i], c_base[i] + j as u16);
        }
    }
    Some(b.build())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gen::{self, SparsityRegime};
    use crate::util::prop::forall;
    use crate::util::SplitMix64;
    use crate::workloads::testutil::{check_built, exec_built};

    #[test]
    fn spmspm_matches_reference_all_regimes() {
        for (i, regime) in SparsityRegime::all().into_iter().enumerate() {
            let mut rng = SplitMix64::new(100 + i as u64);
            let (a, b) = gen::spmspm_pair(&mut rng, 24, regime);
            let cfg = ArchConfig::nexus();
            let built = build("spmspm", &a, &b, &cfg);
            check_built(cfg, built);
        }
    }

    #[test]
    fn spmspm_on_tia_matches_too() {
        let mut rng = SplitMix64::new(5);
        let (a, b) = gen::spmspm_pair(&mut rng, 20, SparsityRegime::S1);
        let cfg = ArchConfig::tia();
        let built = build("spmspm", &a, &b, &cfg);
        exec_built(cfg, built).unwrap();
    }

    #[test]
    fn dense_matmul_via_spmspm() {
        let mut rng = SplitMix64::new(6);
        let a = gen::random_dense(&mut rng, 12, 12, 3);
        let b = gen::random_dense(&mut rng, 12, 12, 3);
        let cfg = ArchConfig::nexus();
        let built = build(
            "matmul",
            &Csr::from_dense(&a),
            &Csr::from_dense(&b),
            &cfg,
        );
        let out = exec_built(cfg, built).unwrap().outputs;
        assert_eq!(out, a.matmul(&b).data);
    }

    #[test]
    fn tiled_matches_single_tile_output() {
        let mut rng = SplitMix64::new(8);
        let (a, b) = gen::spmspm_pair(&mut rng, 32, SparsityRegime::S1);
        // Force tiling with a small SRAM.
        let cfg = ArchConfig::nexus().with_dmem_bytes(700);
        let built = build_tiled("spmspm-tiled", &a, &b, &cfg);
        if let Tiles::Static(ts) = &built.tiles {
            assert!(ts.len() > 1, "expected multiple tiles");
        }
        exec_built(cfg, built).unwrap();
    }

    #[test]
    fn empty_b_rows_terminate_early() {
        forall(6, |rng| {
            let a = gen::random_csr(rng, 16, 16, 0.4);
            let b = gen::random_csr(rng, 16, 16, 0.08); // mostly empty rows
            let cfg = ArchConfig::nexus();
            let built = build("spmspm", &a, &b, &cfg);
            exec_built(cfg, built)
                .map(|_| ())
                .map_err(|e| e.to_string())
        });
    }
}
