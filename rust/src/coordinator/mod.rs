//! Experiment coordinator: runs the (architecture × workload) evaluation
//! matrix across OS threads and renders every figure/table of §5 as an
//! aligned text report (and CSV for plotting).
//!
//! Each figure has a `figNN` function that returns the report as a
//! `String`; the `nexus` CLI and the criterion benches print them, and the
//! integration tests assert their headline shapes (who wins, by roughly
//! what factor).

pub mod ablation;
pub mod report;

use crate::baselines::{roster, RunResult};
use crate::config::ArchConfig;
use crate::workloads::suite;
use std::sync::Mutex;

/// Run every architecture on every suite workload, in parallel across
/// workloads. Returns results grouped by workload (suite order), each with
/// the roster's architectures in order (None where not executable).
pub fn run_matrix(seed: u64) -> Matrix {
    let specs = suite(seed);
    let archs = roster();
    let results: Mutex<Vec<(usize, Vec<Option<RunResult>>)>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for (wi, spec) in specs.iter().enumerate() {
            let archs = &archs;
            let results = &results;
            scope.spawn(move || {
                let row: Vec<Option<RunResult>> = archs.iter().map(|a| a.run(spec)).collect();
                results.lock().unwrap().push((wi, row));
            });
        }
    });
    let mut rows = results.into_inner().unwrap();
    rows.sort_by_key(|(wi, _)| *wi);
    Matrix {
        workloads: specs.iter().map(|s| s.name()).collect(),
        classes: specs.iter().map(|s| s.class()).collect(),
        arch_names: arch_names(),
        rows: rows.into_iter().map(|(_, r)| r).collect(),
    }
}

pub fn arch_names() -> Vec<&'static str> {
    vec!["Systolic", "GenericCGRA", "TIA", "TIA-Valiant", "Nexus"]
}

/// The full evaluation matrix: `rows[workload][arch]`.
pub struct Matrix {
    pub workloads: Vec<String>,
    pub classes: Vec<&'static str>,
    pub arch_names: Vec<&'static str>,
    pub rows: Vec<Vec<Option<RunResult>>>,
}

impl Matrix {
    /// Result for (workload index, arch name).
    pub fn get(&self, wi: usize, arch: &str) -> Option<&RunResult> {
        let ai = self.arch_names.iter().position(|a| *a == arch)?;
        self.rows[wi][ai].as_ref()
    }

    /// Normalized performance of `arch` vs `base` on workload `wi`
    /// (useful-ops/cycle ratio), if both ran it.
    pub fn speedup(&self, wi: usize, arch: &str, base: &str) -> Option<f64> {
        let a = self.get(wi, arch)?;
        let b = self.get(wi, base)?;
        if b.perf() == 0.0 {
            return None;
        }
        Some(a.perf() / b.perf())
    }

    /// Geometric-mean speedup of `arch` over `base` across a workload
    /// class (or all workloads when `class` is `None`).
    pub fn geomean_speedup(&self, arch: &str, base: &str, class: Option<&str>) -> f64 {
        let mut v = Vec::new();
        for wi in 0..self.workloads.len() {
            if let Some(c) = class {
                if self.classes[wi] != c {
                    continue;
                }
            }
            if let Some(s) = self.speedup(wi, arch, base) {
                v.push(s);
            }
        }
        crate::util::geomean(&v)
    }
}

/// One-shot validation of the full suite on a fabric configuration: every
/// workload's fabric output must equal its reference. Returns per-workload
/// (name, cycles) on success.
pub fn validate_suite(cfg: &ArchConfig, seed: u64) -> Result<Vec<(String, u64)>, String> {
    let specs = suite(seed);
    let results: Mutex<Vec<(usize, Result<(String, u64), String>)>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for (wi, spec) in specs.iter().enumerate() {
            let results = &results;
            let cfg = cfg.clone();
            scope.spawn(move || {
                let built = spec.build(&cfg);
                let mut f = crate::fabric::NexusFabric::new(cfg);
                let r = crate::workloads::validate_on_fabric(&mut f, &built)
                    .map(|_| (built.name.clone(), f.stats.cycles));
                results.lock().unwrap().push((wi, r));
            });
        }
    });
    let mut rows = results.into_inner().unwrap();
    rows.sort_by_key(|(wi, _)| *wi);
    rows.into_iter().map(|(_, r)| r).collect()
}

/// Fig 16 data point: one (sparsity, SRAM size) cell of the bandwidth
/// trade-off sweep.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthPoint {
    pub sparsity: f64,
    pub total_sram_bytes: usize,
    pub tiles: usize,
    /// Required off-chip bandwidth, bytes per *compute* cycle, to sustain
    /// the achieved throughput.
    pub bytes_per_cycle: f64,
    /// Useful ops per compute cycle (throughput).
    pub ops_per_cycle: f64,
}

/// Run the Fig 16 sweep: SpMSpM at several sparsities × on-chip SRAM
/// capacities, measuring off-chip traffic per cycle.
pub fn bandwidth_sweep(seed: u64) -> Vec<BandwidthPoint> {
    let sparsities = [0.3, 0.5, 0.7, 0.85, 0.95];
    let per_pe_bytes = [512usize, 1024, 2048, 4096, 8192, 16384, 32768];
    let points: Mutex<Vec<BandwidthPoint>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for &sp in &sparsities {
            for &bytes in &per_pe_bytes {
                let points = &points;
                scope.spawn(move || {
                    let mut rng = crate::util::SplitMix64::new(seed ^ (bytes as u64));
                    let n = 96;
                    let a = crate::tensor::gen::skewed_csr(&mut rng, n, n, 1.0 - sp);
                    let b = crate::tensor::gen::random_csr(&mut rng, n, n, 1.0 - sp);
                    let cfg = ArchConfig::nexus().with_dmem_bytes(bytes);
                    let built =
                        crate::workloads::spmspm::build_tiled("fig16", &a, &b, &cfg);
                    let ntiles = match &built.tiles {
                        crate::workloads::Tiles::Static(t) => t.len(),
                        _ => unreachable!(),
                    };
                    let mut f = crate::fabric::NexusFabric::new(cfg.clone());
                    crate::workloads::run_on_fabric(&mut f, &built).expect("fig16 run");
                    let s = &f.stats;
                    let compute_cycles = (s.cycles - s.load_cycles).max(1);
                    points.lock().unwrap().push(BandwidthPoint {
                        sparsity: sp,
                        total_sram_bytes: bytes * cfg.num_pes(),
                        tiles: ntiles,
                        bytes_per_cycle: s.offchip_bytes as f64 / compute_cycles as f64,
                        ops_per_cycle: (s.alu_ops + s.mem_ops) as f64 / compute_cycles as f64,
                    });
                });
            }
        }
    });
    let mut v = points.into_inner().unwrap();
    v.sort_by(|a, b| {
        a.sparsity
            .partial_cmp(&b.sparsity)
            .unwrap()
            .then(a.total_sram_bytes.cmp(&b.total_sram_bytes))
    });
    v
}

/// Fig 17 data point: one (array size, workload) cell.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub dim: usize,
    pub workload: String,
    pub perf: f64,
    pub utilization: f64,
}

/// Run the Fig 17 scalability sweep over array sizes.
pub fn scalability_sweep(seed: u64, dims: &[usize]) -> Vec<ScalePoint> {
    let points: Mutex<Vec<ScalePoint>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for &d in dims {
            let points = &points;
            scope.spawn(move || {
                let cfg = ArchConfig::nexus().with_array(d, d);
                // A representative subset: sparse, dense, graph.
                let specs = suite(seed);
                for spec in specs.iter().filter(|s| {
                    let n = s.name();
                    n.starts_with("SpMV")
                        || n.starts_with("SpMSpM-S1")
                        || n == "MatMul"
                        || n == "BFS"
                }) {
                    let built = spec.build(&cfg);
                    let mut f = crate::fabric::NexusFabric::new(cfg.clone());
                    crate::workloads::run_on_fabric(&mut f, &built).expect("fig17 run");
                    points.lock().unwrap().push(ScalePoint {
                        dim: d,
                        workload: spec.name(),
                        perf: built.work_ops as f64 / f.stats.cycles.max(1) as f64,
                        utilization: f.stats.utilization(),
                    });
                }
            });
        }
    });
    let mut v = points.into_inner().unwrap();
    v.sort_by(|a, b| a.dim.cmp(&b.dim).then(a.workload.cmp(&b.workload)));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_suite_passes_on_all_fabric_variants() {
        for cfg in [
            ArchConfig::nexus(),
            ArchConfig::tia(),
            ArchConfig::tia_valiant(),
        ] {
            let rows = validate_suite(&cfg, 1).unwrap();
            assert_eq!(rows.len(), 13);
            assert!(rows.iter().all(|(_, c)| *c > 0));
        }
    }

    #[test]
    fn matrix_headline_shapes_hold() {
        let m = run_matrix(1);
        // Nexus beats Generic CGRA on sparse+graph (paper: ~1.9x average).
        let sparse = m.geomean_speedup("Nexus", "GenericCGRA", Some("sparse"));
        let graph = m.geomean_speedup("Nexus", "GenericCGRA", Some("graph"));
        assert!(sparse > 1.0, "Nexus/CGRA sparse geomean {sparse}");
        assert!(graph > 1.0, "Nexus/CGRA graph geomean {graph}");
        // Nexus >= TIA overall; TIA-Valiant between TIA and Nexus-ish.
        let vs_tia = m.geomean_speedup("Nexus", "TIA", None);
        assert!(vs_tia > 1.0, "Nexus/TIA geomean {vs_tia}");
        // Systolic wins dense MatMul.
        let mm = m.workloads.iter().position(|w| w == "MatMul").unwrap();
        let sys = m.get(mm, "Systolic").unwrap().perf();
        let nexus = m.get(mm, "Nexus").unwrap().perf();
        assert!(sys > nexus, "systolic should win dense MatMul");
    }
}
