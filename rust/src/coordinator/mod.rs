//! Experiment coordinator: runs the (architecture × workload) evaluation
//! matrix and renders every figure/table of §5 as an aligned text report
//! (and CSV for plotting).
//!
//! All sweeps fan out through one [`MachinePool`]: each worker owns a
//! reusable [`Machine`] (or one per roster architecture), so fabric
//! allocations and compile caches persist across the jobs a worker runs —
//! no per-run simulator construction, no hand-rolled thread plumbing.
//!
//! Each figure has a `figNN` function that returns the report as a
//! `String`; the `nexus` CLI and the bench binaries print them, and the
//! integration tests assert their headline shapes (who wins, by roughly
//! what factor).

pub mod ablation;
pub mod report;

use crate::baselines::{roster, RunResult};
use crate::config::ArchConfig;
use crate::dataset::{effective_shards, run_corpus, Corpus, RunOptions};
use crate::machine::{Compiled, ExecError, Machine, MachinePool};
use crate::workloads::suite;

/// Run every architecture on every suite workload, fanned out across the
/// pool. Returns results grouped by workload (suite order), each with the
/// roster's architectures in order (`None` where not executable).
pub fn run_matrix(seed: u64) -> Matrix {
    let specs = suite(seed);
    let pool = MachinePool::new();
    let rows = pool.run_batch_with(
        || {
            roster()
                .into_iter()
                .map(Machine::from_backend)
                .collect::<Vec<Machine>>()
        },
        &specs,
        |machines, spec| {
            machines
                .iter_mut()
                .map(|m| match m.run(spec) {
                    Ok(e) => Some(e.result),
                    Err(ExecError::Unsupported { .. }) => None,
                    Err(e) => panic!("{} on {}: {e}", m.name(), spec.name()),
                })
                .collect::<Vec<Option<RunResult>>>()
        },
    );
    Matrix {
        workloads: specs.iter().map(|s| s.name()).collect(),
        classes: specs.iter().map(|s| s.class()).collect(),
        arch_names: arch_names(),
        rows,
    }
}

/// Roster architecture names, in roster order — derived from
/// [`roster`] itself so the list can never drift from it.
pub fn arch_names() -> Vec<&'static str> {
    roster().iter().map(|b| b.name()).collect()
}

/// The full evaluation matrix: `rows[workload][arch]`.
pub struct Matrix {
    pub workloads: Vec<String>,
    pub classes: Vec<&'static str>,
    pub arch_names: Vec<&'static str>,
    pub rows: Vec<Vec<Option<RunResult>>>,
}

impl Matrix {
    /// Result for (workload index, arch name).
    pub fn get(&self, wi: usize, arch: &str) -> Option<&RunResult> {
        let ai = self.arch_names.iter().position(|a| *a == arch)?;
        self.rows[wi][ai].as_ref()
    }

    /// Normalized performance of `arch` vs `base` on workload `wi`
    /// (useful-ops/cycle ratio), if both ran it.
    pub fn speedup(&self, wi: usize, arch: &str, base: &str) -> Option<f64> {
        let a = self.get(wi, arch)?;
        let b = self.get(wi, base)?;
        if b.perf() == 0.0 {
            return None;
        }
        Some(a.perf() / b.perf())
    }

    /// Geometric-mean speedup of `arch` over `base` across a workload
    /// class (or all workloads when `class` is `None`).
    pub fn geomean_speedup(&self, arch: &str, base: &str, class: Option<&str>) -> f64 {
        let mut v = Vec::new();
        for wi in 0..self.workloads.len() {
            if let Some(c) = class {
                if self.classes[wi] != c {
                    continue;
                }
            }
            if let Some(s) = self.speedup(wi, arch, base) {
                v.push(s);
            }
        }
        crate::util::geomean(&v)
    }
}

/// One validated workload from [`validate_suite`]: the compiled program
/// name, its cycle count, and the NoC link-demand peak the run induced —
/// both as a raw flit-traversal count
/// ([`crate::fabric::stats::FabricStats::peak_link_demand`]) and converted
/// to physical GB/s at the configured clock via
/// [`crate::power::link_demand_gbps`].
#[derive(Debug, Clone)]
pub struct ValidatedRun {
    pub program: String,
    pub cycles: u64,
    pub peak_link_demand: u64,
    pub peak_link_gbps: f64,
}

/// One-shot validation of the full suite on a fabric configuration: every
/// workload's fabric output must equal its reference. Returns one
/// [`ValidatedRun`] per workload on success, the first typed failure
/// otherwise.
pub fn validate_suite(cfg: &ArchConfig, seed: u64) -> Result<Vec<ValidatedRun>, ExecError> {
    let specs = suite(seed);
    // Each Machine may itself step shards on `cfg.threads` workers.
    let pool = MachinePool::for_threads(cfg.threads);
    let freq_mhz = cfg.freq_mhz;
    pool.run_batch_with(
        || Machine::new(cfg.clone()),
        &specs,
        |m, spec| -> Result<ValidatedRun, ExecError> {
            let compiled = match m.compile(spec) {
                Ok(c) => c,
                Err(e) => return Err(ExecError::in_workload(spec.name(), e)),
            };
            match m.execute(&compiled) {
                Ok(exec) => {
                    let peak = exec.stats.as_ref().map_or(0, |s| s.peak_link_demand);
                    Ok(ValidatedRun {
                        program: compiled.program_name().to_string(),
                        cycles: exec.result.cycles,
                        peak_link_demand: peak,
                        peak_link_gbps: crate::power::link_demand_gbps(peak, freq_mhz),
                    })
                }
                Err(e) => Err(ExecError::in_workload(spec.name(), e)),
            }
        },
    )
    .into_iter()
    .collect()
}

/// Render `nexus corpus list`: the registered scenarios (optionally
/// filtered by glob) as an aligned table.
pub fn corpus_list(filter: Option<&str>) -> String {
    use std::fmt::Write as _;
    let corpus = Corpus::builtin();
    let scenarios = corpus.select(filter);
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<34} {:<10} {:<10} {:>5} {:>8}",
        "scenario", "kernel", "source", "mesh", "density"
    );
    for sc in &scenarios {
        let _ = writeln!(
            s,
            "{:<34} {:<10} {:<10} {:>5} {:>8.2}",
            sc.name,
            sc.kernel,
            sc.source,
            sc.mesh_name(),
            sc.density
        );
    }
    let _ = write!(
        s,
        "{} scenario(s){}",
        scenarios.len(),
        match filter {
            Some(glob) => format!(" matching '{glob}' (of {})", corpus.len()),
            None => String::new(),
        }
    );
    s
}

/// Run `nexus corpus run`: execute the (filtered) corpus across the pool
/// with bit-exact validation. `opts` carries the sweep seed, step mode,
/// topology, and the sharding knobs (`--shards`/`--threads`). Returns the
/// per-scenario JSON lines (the `BENCH_CORPUS.json` artifact body) plus a
/// success flag that is `false` if any scenario failed or no scenario
/// matched.
pub fn corpus_run(filter: Option<&str>, opts: RunOptions) -> (String, bool) {
    let (runs, ok) = corpus_run_full(filter, opts);
    let lines: Vec<String> = runs.iter().map(|r| r.json_line()).collect();
    (lines.join("\n"), ok)
}

/// As [`corpus_run`], returning the structured per-scenario outcomes
/// instead of pre-rendered JSON lines — the CLI uses this when it also
/// needs the human-readable stall summary (`--stall-summary`), and the
/// trace exporter reuses it to resolve a scenario by name.
pub fn corpus_run_full(
    filter: Option<&str>,
    opts: RunOptions,
) -> (Vec<crate::dataset::ScenarioRun>, bool) {
    let corpus = Corpus::builtin();
    let scenarios = corpus.select(filter);
    if scenarios.is_empty() {
        return (Vec::new(), false);
    }
    let runs = run_corpus(&scenarios, opts);
    let ok = runs.iter().all(|r| r.passed());
    (runs, ok)
}

/// Outcome of [`trace_scenario`]: the Chrome-trace JSON body plus the
/// summary numbers the `nexus trace` CLI prints to stderr.
pub struct TraceExport {
    /// Name of the scenario that was traced.
    pub scenario: String,
    /// Number of trace events captured (instant events in the JSON).
    pub events: usize,
    /// Cycles the traced run took.
    pub cycles: u64,
    /// The Chrome trace-event JSON document (loadable in Perfetto /
    /// `chrome://tracing`).
    pub json: String,
}

/// Run one corpus scenario with full lifecycle + PE-state tracing
/// ([`crate::trace::TraceConfig::full`]) and export the event stream as
/// Chrome trace-event JSON — the engine behind `nexus trace --scenario
/// NAME --out FILE`. `name` may be an exact scenario name or a glob; the
/// first match is traced. Tracing never perturbs the simulation, so the
/// run's cycle count equals an untraced run of the same scenario.
pub fn trace_scenario(name: &str, opts: RunOptions) -> Result<TraceExport, String> {
    let corpus = Corpus::builtin();
    let scenarios = corpus.select(Some(name));
    let Some(sc) = scenarios.first() else {
        return Err(format!(
            "no corpus scenario matches '{name}' (see `nexus corpus list`)"
        ));
    };
    let shards = effective_shards(opts.shards, sc.mesh.1);
    let cfg = sc
        .config()
        .with_topology(opts.topology)
        .with_step_mode(opts.step_mode)
        .with_shards(shards)
        .with_threads(opts.threads)
        .with_placement(opts.placement)
        .with_claim(opts.claim)
        .with_trace(crate::trace::TraceConfig::full());
    let mut m = Machine::new(cfg.clone());
    let exec = m
        .run(&sc.spec(opts.seed))
        .map_err(|e| format!("{}: {e}", sc.name))?;
    let events = exec.trace.unwrap_or_default();
    Ok(TraceExport {
        scenario: sc.name.clone(),
        events: events.len(),
        cycles: exec.result.cycles,
        json: crate::trace::chrome_trace_json(&events, cfg.width, cfg.height),
    })
}

/// Run `nexus serve`: print a startup banner to stderr (stdout stays
/// clean for tooling) and block in the server's accept loop until a
/// shutdown request drains it. Returning `Ok(())` is the exit-0 path.
pub fn serve(opts: crate::serve::ServeOptions) -> std::io::Result<()> {
    let server = crate::serve::Server::bind(opts.clone())?;
    eprintln!(
        "nexus serve: listening on {} ({} worker(s), queue {}, cache {}, \
         {} stepping, {} topology, {} shard(s) x {} thread(s))",
        server.local_addr()?,
        opts.effective_workers(),
        opts.queue_capacity,
        opts.cache_capacity,
        opts.step_mode.name(),
        opts.topology.name(),
        opts.shards,
        opts.threads,
    );
    server.run()
}

/// Fig 16 data point: one (sparsity, SRAM size) cell of the bandwidth
/// trade-off sweep.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthPoint {
    pub sparsity: f64,
    pub total_sram_bytes: usize,
    pub tiles: usize,
    /// Required off-chip bandwidth, bytes per *compute* cycle, to sustain
    /// the achieved throughput.
    pub bytes_per_cycle: f64,
    /// Useful ops per compute cycle (throughput).
    pub ops_per_cycle: f64,
}

/// Run the Fig 16 sweep: SpMSpM at several sparsities × on-chip SRAM
/// capacities, measuring off-chip traffic per cycle.
pub fn bandwidth_sweep(seed: u64) -> Vec<BandwidthPoint> {
    let sparsities = [0.3, 0.5, 0.7, 0.85, 0.95];
    let per_pe_bytes = [512usize, 1024, 2048, 4096, 8192, 16384, 32768];
    let jobs: Vec<(f64, usize)> = sparsities
        .iter()
        .flat_map(|&sp| per_pe_bytes.iter().map(move |&b| (sp, b)))
        .collect();
    let pool = MachinePool::new();
    let mut v = pool.run_batch(&jobs, |&(sp, bytes)| {
        let mut rng = crate::util::SplitMix64::new(seed ^ (bytes as u64));
        let n = 96;
        let a = crate::tensor::gen::skewed_csr(&mut rng, n, n, 1.0 - sp);
        let b = crate::tensor::gen::random_csr(&mut rng, n, n, 1.0 - sp);
        let cfg = ArchConfig::nexus().with_dmem_bytes(bytes);
        let compiled = Compiled::from_built(crate::workloads::spmspm::build_tiled(
            "fig16", &a, &b, &cfg,
        ));
        let mut m = Machine::new(cfg.clone());
        let exec = m.execute(&compiled).expect("fig16 run");
        let s = exec.stats.as_ref().expect("fabric stats");
        let compute_cycles = (s.cycles - s.load_cycles).max(1);
        BandwidthPoint {
            sparsity: sp,
            total_sram_bytes: bytes * cfg.num_pes(),
            tiles: compiled.tile_count(),
            bytes_per_cycle: s.offchip_bytes as f64 / compute_cycles as f64,
            ops_per_cycle: (s.alu_ops + s.mem_ops) as f64 / compute_cycles as f64,
        }
    });
    v.sort_by(|a, b| {
        a.sparsity
            .partial_cmp(&b.sparsity)
            .unwrap()
            .then(a.total_sram_bytes.cmp(&b.total_sram_bytes))
    });
    v
}

/// Fig 17 data point: one (array size, workload) cell.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub dim: usize,
    pub workload: String,
    pub perf: f64,
    pub utilization: f64,
}

/// Run the Fig 17 scalability sweep over array sizes (Nexus baseline
/// configuration, active-set stepping).
pub fn scalability_sweep(seed: u64, dims: &[usize]) -> Vec<ScalePoint> {
    scalability_sweep_with(&ArchConfig::nexus(), seed, dims)
}

/// As [`scalability_sweep`], parameterized over the base configuration —
/// the fig17 bench uses this to time the sweep under both
/// [`crate::config::StepMode`]s (the results are bit-identical; only the
/// host wall-clock differs).
pub fn scalability_sweep_with(base: &ArchConfig, seed: u64, dims: &[usize]) -> Vec<ScalePoint> {
    let pool = MachinePool::new();
    let rows = pool.run_batch(dims, |&d| {
        let cfg = base.clone().with_array(d, d);
        let mut m = Machine::new(cfg);
        // A representative subset: sparse, dense, graph.
        let specs = suite(seed);
        let mut pts = Vec::new();
        for spec in specs.iter().filter(|s| {
            let n = s.name();
            n.starts_with("SpMV") || n.starts_with("SpMSpM-S1") || n == "MatMul" || n == "BFS"
        }) {
            let exec = m.run(spec).expect("fig17 run");
            pts.push(ScalePoint {
                dim: d,
                workload: spec.name(),
                perf: exec.result.work_ops as f64 / exec.result.cycles.max(1) as f64,
                utilization: exec.result.utilization,
            });
        }
        pts
    });
    let mut v: Vec<ScalePoint> = rows.into_iter().flatten().collect();
    v.sort_by(|a, b| a.dim.cmp(&b.dim).then(a.workload.cmp(&b.workload)));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_suite_passes_on_all_fabric_variants() {
        for cfg in [
            ArchConfig::nexus(),
            ArchConfig::tia(),
            ArchConfig::tia_valiant(),
        ] {
            let rows = validate_suite(&cfg, 1).unwrap();
            assert_eq!(rows.len(), 13);
            assert!(rows.iter().all(|r| r.cycles > 0));
            // The GB/s figure is derived from the raw peak: zero iff the
            // raw count is zero, and at least one suite workload must
            // actually stress the links.
            assert!(rows.iter().any(|r| r.peak_link_demand > 0));
            assert!(rows
                .iter()
                .all(|r| (r.peak_link_gbps > 0.0) == (r.peak_link_demand > 0)));
        }
    }

    #[test]
    fn matrix_headline_shapes_hold() {
        let m = run_matrix(1);
        // Nexus beats Generic CGRA on sparse+graph (paper: ~1.9x average).
        let sparse = m.geomean_speedup("Nexus", "GenericCGRA", Some("sparse"));
        let graph = m.geomean_speedup("Nexus", "GenericCGRA", Some("graph"));
        assert!(sparse > 1.0, "Nexus/CGRA sparse geomean {sparse}");
        assert!(graph > 1.0, "Nexus/CGRA graph geomean {graph}");
        // Nexus >= TIA overall; TIA-Valiant between TIA and Nexus-ish.
        let vs_tia = m.geomean_speedup("Nexus", "TIA", None);
        assert!(vs_tia > 1.0, "Nexus/TIA geomean {vs_tia}");
        // Systolic wins dense MatMul.
        let mm = m.workloads.iter().position(|w| w == "MatMul").unwrap();
        let sys = m.get(mm, "Systolic").unwrap().perf();
        let nexus = m.get(mm, "Nexus").unwrap().perf();
        assert!(sys > nexus, "systolic should win dense MatMul");
    }

    #[test]
    fn corpus_cli_surfaces_work() {
        let listing = corpus_list(Some("smoke/*"));
        assert!(listing.contains("smoke/spmv-uniform-d30-4x4"), "{listing}");
        let (lines, ok) = corpus_run(Some("smoke/spmv-*"), RunOptions::default());
        assert!(ok, "{lines}");
        assert!(lines.lines().count() >= 2);
        assert!(lines.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
        let (empty, ok) = corpus_run(Some("no-such/*"), RunOptions::default());
        assert!(!ok && empty.is_empty(), "unmatched filter must fail");
        // The sharded path surfaces through the same entry point and still
        // validates bit-exactly.
        let (sharded, ok) = corpus_run(
            Some("smoke/spmv-*"),
            RunOptions {
                shards: 2,
                threads: 2,
                ..RunOptions::default()
            },
        );
        assert!(ok, "{sharded}");
        assert!(sharded.lines().all(|l| l.contains("\"shards\":2")), "{sharded}");
    }

    #[test]
    fn trace_scenario_exports_loadable_json() {
        let t = trace_scenario("smoke/spmv-uniform-d30-4x4", RunOptions::default()).unwrap();
        assert!(t.events > 0, "a validated run must emit trace events");
        assert!(t.cycles > 0);
        assert!(t.json.starts_with("{\"traceEvents\":["), "{}", &t.json[..60]);
        assert!(t.json.contains("\"thread_name\""), "PE tracks must be named");
        // And the untraced run takes exactly the same number of cycles —
        // tracing is observability, not a schedule change.
        let (runs, ok) = corpus_run_full(Some("smoke/spmv-uniform-d30-4x4"), RunOptions::default());
        assert!(ok);
        assert_eq!(runs[0].outcome.as_ref().unwrap().cycles, t.cycles);
        assert!(trace_scenario("no-such/*", RunOptions::default()).is_err());
    }

    #[test]
    fn arch_names_match_roster_order() {
        assert_eq!(
            arch_names(),
            roster().iter().map(|b| b.name()).collect::<Vec<_>>()
        );
        assert_eq!(arch_names().len(), 5);
    }
}
