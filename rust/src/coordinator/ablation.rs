//! Ablation studies of the Nexus Machine's design choices — the knobs §3
//! fixes and §5 motivates: en-route execution, routing policy, router
//! buffer depth (the paper picks 3 registers "to minimize overall power
//! consumption"), On/Off thresholds, the data-placement strategy
//! (Algorithm 1), and the on-chip AM-queue window.
//!
//! Regenerate with `nexus ablate` or `cargo bench --bench ablations`.

use crate::config::{ArchConfig, ExecPolicy, RoutingPolicy};
use crate::machine::{Machine, MachinePool};
use crate::workloads::{suite, Spec};

/// One ablation point: a named configuration delta and its suite outcome.
#[derive(Debug, Clone)]
pub struct AblationPoint {
    pub knob: &'static str,
    pub setting: String,
    /// Geomean useful-ops/cycle over the sparse+graph suite.
    pub perf: f64,
    /// Mean fabric utilization.
    pub utilization: f64,
    /// Mean NoC congestion (blocked fraction).
    pub congestion: f64,
}

/// Run the irregular (sparse + graph) suite under one configuration.
fn run_config(cfg: &ArchConfig, specs: &[Spec]) -> (f64, f64, f64) {
    let irregular: Vec<&Spec> = specs.iter().filter(|s| s.class() != "dense").collect();
    let pool = MachinePool::new();
    let v = pool.run_batch_with(
        || Machine::new(cfg.clone()),
        &irregular,
        |m, spec| {
            let e = m.run(spec).expect("ablation run");
            let r = &e.result;
            let cong: f64 = r.congestion.iter().sum::<f64>() / 5.0;
            (
                r.work_ops as f64 / r.cycles.max(1) as f64,
                r.utilization,
                cong,
            )
        },
    );
    let perfs: Vec<f64> = v.iter().map(|r| r.0).collect();
    let utils: Vec<f64> = v.iter().map(|r| r.1).collect();
    let congs: Vec<f64> = v.iter().map(|r| r.2).collect();
    (
        crate::util::geomean(&perfs),
        crate::util::mean(&utils),
        crate::util::mean(&congs),
    )
}

fn point(knob: &'static str, setting: String, cfg: &ArchConfig, specs: &[Spec]) -> AblationPoint {
    let (perf, utilization, congestion) = run_config(cfg, specs);
    AblationPoint {
        knob,
        setting,
        perf,
        utilization,
        congestion,
    }
}

/// The full ablation matrix over the irregular suite.
pub fn run_all(seed: u64) -> Vec<AblationPoint> {
    let specs = suite(seed);
    let mut pts = Vec::new();

    // 1. En-route execution (the contribution itself).
    for (name, exec) in [
        ("on (Nexus)", ExecPolicy::EnRoute),
        ("off (TIA-like)", ExecPolicy::DestinationOnly),
    ] {
        let mut cfg = ArchConfig::nexus();
        cfg.exec = exec;
        pts.push(point("en-route", name.into(), &cfg, &specs));
    }

    // 2. Routing policy.
    for (name, routing) in [
        ("west-first adaptive", RoutingPolicy::TurnModelAdaptive),
        ("deterministic XY", RoutingPolicy::Xy),
        ("Valiant/ROMM", RoutingPolicy::Valiant),
    ] {
        let mut cfg = ArchConfig::nexus();
        cfg.routing = routing;
        pts.push(point("routing", name.into(), &cfg, &specs));
    }

    // 3. Router buffer depth (paper: 3, for power).
    for depth in [2usize, 3, 5, 8] {
        let mut cfg = ArchConfig::nexus();
        cfg.router_buf_depth = depth;
        cfg.t_on = 2.min(depth - 1).max(cfg.t_off + 1);
        pts.push(point("buf depth", format!("{depth} flits"), &cfg, &specs));
    }

    // 4. AM-queue on-chip window (Table 1: 114 entries = 1KB).
    for window in [16usize, 57, 114, 228] {
        let mut cfg = ArchConfig::nexus();
        cfg.am_queue_entries = window;
        pts.push(point("AM window", format!("{window} entries"), &cfg, &specs));
    }

    pts
}

/// Data-placement ablation (Algorithm 1): dissimilarity-aware vs a plain
/// uniform row split, on SpMV where placement dominates. Returns
/// (dissimilarity cycles, uniform cycles).
pub fn placement_ablation(seed: u64) -> (u64, u64) {
    use crate::am::Message;
    use crate::compiler::{partition, ProgramBuilder};
    use crate::isa::{ConfigEntry, Opcode};

    let mut rng = crate::util::SplitMix64::new(seed);
    let a = crate::tensor::gen::skewed_csr(&mut rng, 64, 64, 0.2);
    let x = crate::tensor::gen::random_vec(&mut rng, 64, 3);
    let cfg = ArchConfig::nexus();

    // Build SpMV with an arbitrary row->PE map.
    let build_with = |row_part: &[usize]| {
        let p = cfg.num_pes();
        let col_part = partition::uniform_blocks(a.cols, p);
        let mut b = ProgramBuilder::new("placement", &cfg);
        let xs = crate::workloads::place_vector(&mut b, &col_part, &x);
        let ys = crate::workloads::place_vector(&mut b, row_part, &vec![0i16; a.rows]);
        let pc_acc = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
        let pc_mul = b.config(ConfigEntry::new(Opcode::Mul, pc_acc));
        for r in 0..a.rows {
            for (c, v) in a.row(r) {
                let mut am = Message::new();
                am.opcode = Opcode::Load;
                am.n_pc = pc_mul;
                am.op1 = v as u16;
                am.op2 = xs.addr[c];
                am.op2_is_addr = true;
                am.result = ys.addr[r];
                am.res_is_addr = true;
                am.push_dest(xs.pe[c] as u16);
                am.push_dest(ys.pe[r] as u16);
                b.static_am(row_part[r], am);
            }
        }
        for r in 0..a.rows {
            b.output(ys.pe[r], ys.addr[r]);
        }
        b.build()
    };

    let run = |row_part: &[usize]| {
        // Wrap the hand-built program as a compiled artifact; the machine
        // validates the outputs against the software reference.
        let built = crate::workloads::Built {
            name: "placement".into(),
            tiles: crate::workloads::Tiles::Static(vec![build_with(row_part)]),
            expected: a.spmv(&x),
            work_ops: 2 * a.nnz() as u64,
        };
        let mut m = Machine::new(cfg.clone());
        let e = m
            .execute(&crate::machine::Compiled::from_built(built))
            .expect("placement must not change results");
        e.result.cycles
    };

    let dis = run(&partition::dissimilarity_aware(&a, cfg.num_pes(), 8));
    let uni = run(&partition::uniform_blocks(a.rows, cfg.num_pes()));
    (dis, uni)
}

/// Render the ablation report.
pub fn report(seed: u64) -> String {
    let pts = run_all(seed);
    let mut s = String::from(
        "Ablations — design-choice sweeps over the irregular (sparse+graph) suite\n\
         =========================================================================\n",
    );
    s += &format!(
        "{:<12}{:<22}{:>12}{:>14}{:>13}\n",
        "knob", "setting", "perf", "utilization", "congestion"
    );
    let mut last = "";
    for p in &pts {
        if p.knob != last {
            last = p.knob;
            s += &"-".repeat(73);
            s += "\n";
        }
        s += &format!(
            "{:<12}{:<22}{:>12.3}{:>13.1}%{:>13.3}\n",
            p.knob,
            p.setting,
            p.perf,
            p.utilization * 100.0,
            p.congestion
        );
    }
    let (dis, uni) = placement_ablation(seed);
    s += &"-".repeat(73);
    s += &format!(
        "\nplacement   Algorithm 1 (dissimilarity-aware) {} cycles vs uniform rows {} cycles ({:+.1}%)\n",
        dis,
        uni,
        100.0 * (uni as f64 - dis as f64) / uni as f64
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enroute_ablation_shows_the_contribution() {
        let specs = suite(1);
        let mut on = ArchConfig::nexus();
        on.exec = ExecPolicy::EnRoute;
        let mut off = ArchConfig::nexus();
        off.exec = ExecPolicy::DestinationOnly;
        let (p_on, u_on, _) = run_config(&on, &specs);
        let (p_off, u_off, _) = run_config(&off, &specs);
        assert!(p_on > p_off, "en-route must improve perf: {p_on} vs {p_off}");
        assert!(u_on > u_off, "en-route must improve utilization");
    }

    #[test]
    fn deeper_buffers_do_not_hurt_performance() {
        let specs = suite(1);
        let mut d3 = ArchConfig::nexus();
        d3.router_buf_depth = 3;
        let mut d8 = ArchConfig::nexus();
        d8.router_buf_depth = 8;
        let (p3, ..) = run_config(&d3, &specs);
        let (p8, ..) = run_config(&d8, &specs);
        // Depth 8 buys little perf (>= 0.9x of depth 3 at most a bit more):
        // the paper's power argument for 3 registers.
        assert!(p8 >= p3 * 0.9, "depth-8 {p8} vs depth-3 {p3}");
    }

    #[test]
    fn placement_ablation_validates_and_reports() {
        let (dis, uni) = placement_ablation(1);
        assert!(dis > 0 && uni > 0);
    }
}
