//! Report renderers: one function per paper figure/table, producing the
//! same rows/series the paper plots, as aligned text.

use super::{BandwidthPoint, Matrix, ScalePoint};
use crate::power::{area::area_of, perf_per_watt, EnergyModel};

const FREQ_MHZ: f64 = 588.0;

fn header(title: &str) -> String {
    format!("{}\n{}\n", title, "=".repeat(title.len()))
}

/// Fig 11: normalized performance vs baselines + % in-network compute.
pub fn fig11(m: &Matrix) -> String {
    let mut s = header("Fig 11 — Normalized performance (vs Generic CGRA) + % in-network");
    s += &format!("{:<14}", "workload");
    for a in &m.arch_names {
        s += &format!("{a:>13}");
    }
    s += &format!("{:>12}\n", "in-net %");
    for wi in 0..m.workloads.len() {
        s += &format!("{:<14}", m.workloads[wi]);
        for a in &m.arch_names {
            match m.speedup(wi, a, "GenericCGRA") {
                Some(x) => s += &format!("{x:>12.2}x"),
                None => s += &format!("{:>13}", "n/a"),
            }
        }
        let innet = m
            .get(wi, "Nexus")
            .map(|r| r.in_network_frac * 100.0)
            .unwrap_or(0.0);
        s += &format!("{innet:>11.1}%\n");
    }
    s += &format!(
        "\ngeomean Nexus/CGRA: sparse {:.2}x  dense {:.2}x  graph {:.2}x  all {:.2}x\n",
        m.geomean_speedup("Nexus", "GenericCGRA", Some("sparse")),
        m.geomean_speedup("Nexus", "GenericCGRA", Some("dense")),
        m.geomean_speedup("Nexus", "GenericCGRA", Some("graph")),
        m.geomean_speedup("Nexus", "GenericCGRA", None),
    );
    s += &format!(
        "geomean Nexus/TIA: {:.2}x   Nexus/TIA-Valiant: {:.2}x\n",
        m.geomean_speedup("Nexus", "TIA", None),
        m.geomean_speedup("Nexus", "TIA-Valiant", None),
    );
    s
}

/// Fig 12: normalized performance-per-watt.
pub fn fig12(m: &Matrix) -> String {
    let model = EnergyModel::cal22nm();
    let mut s = header("Fig 12 — Performance per watt (MOPS/mW), normalized to Generic CGRA");
    s += &format!("{:<14}", "workload");
    for a in &m.arch_names {
        s += &format!("{a:>13}");
    }
    s += "\n";
    for wi in 0..m.workloads.len() {
        s += &format!("{:<14}", m.workloads[wi]);
        let base = m.get(wi, "GenericCGRA").map(|r| {
            let p = model.power(r.arch, &r.events, FREQ_MHZ).total();
            perf_per_watt(r.work_ops, r.cycles, p, FREQ_MHZ)
        });
        for a in &m.arch_names {
            match (m.get(wi, a), base) {
                (Some(r), Some(b)) if b > 0.0 => {
                    let p = model.power(r.arch, &r.events, FREQ_MHZ).total();
                    let ppw = perf_per_watt(r.work_ops, r.cycles, p, FREQ_MHZ);
                    s += &format!("{:>12.2}x", ppw / b);
                }
                _ => s += &format!("{:>13}", "n/a"),
            }
        }
        s += "\n";
    }
    s
}

/// Fig 13: fabric utilization (%).
pub fn fig13(m: &Matrix) -> String {
    let mut s = header("Fig 13 — Fabric utilization (%)");
    s += &format!("{:<14}", "workload");
    for a in &m.arch_names {
        s += &format!("{a:>13}");
    }
    s += "\n";
    let mut sums = vec![(0.0f64, 0usize); m.arch_names.len()];
    for wi in 0..m.workloads.len() {
        s += &format!("{:<14}", m.workloads[wi]);
        for (ai, a) in m.arch_names.iter().enumerate() {
            match m.get(wi, a) {
                Some(r) => {
                    s += &format!("{:>12.1}%", r.utilization * 100.0);
                    sums[ai].0 += r.utilization;
                    sums[ai].1 += 1;
                }
                None => s += &format!("{:>13}", "n/a"),
            }
        }
        s += "\n";
    }
    s += &format!("{:<14}", "mean");
    for (sum, n) in &sums {
        s += &format!("{:>12.1}%", 100.0 * sum / (*n).max(1) as f64);
    }
    s += "\n";
    s
}

/// Fig 14: per-input-port congestion, Nexus vs TIA, sparse + graph only.
pub fn fig14(m: &Matrix) -> String {
    let mut s = header("Fig 14 — NoC congestion per input port (blocked fraction), Nexus vs TIA");
    s += &format!(
        "{:<14}{:>8}{:>8}{:>8}{:>8}{:>8}   {:>8}{:>8}{:>8}{:>8}{:>8}\n",
        "workload", "NIC", "N", "E", "S", "W", "NIC", "N", "E", "S", "W"
    );
    s += &format!("{:<14}{:^40}   {:^40}\n", "", "Nexus", "TIA");
    for wi in 0..m.workloads.len() {
        if m.classes[wi] == "dense" {
            continue; // "dense workloads are omitted" (Fig 14 caption)
        }
        let (Some(nx), Some(tia)) = (m.get(wi, "Nexus"), m.get(wi, "TIA")) else {
            continue;
        };
        s += &format!("{:<14}", m.workloads[wi]);
        for c in nx.congestion {
            s += &format!("{:>8.3}", c);
        }
        s += "   ";
        for c in tia.congestion {
            s += &format!("{:>8.3}", c);
        }
        s += "\n";
    }
    // Mean congestion comparison (the figure's takeaway).
    let mean = |arch: &str| {
        let mut v = Vec::new();
        for wi in 0..m.workloads.len() {
            if m.classes[wi] == "dense" {
                continue;
            }
            if let Some(r) = m.get(wi, arch) {
                v.extend(r.congestion.iter().copied());
            }
        }
        crate::util::mean(&v)
    };
    s += &format!(
        "\nmean congestion: Nexus {:.3}  TIA {:.3}\n",
        mean("Nexus"),
        mean("TIA")
    );
    s
}

/// Fig 10: power ablation/breakdown vs baselines at iso-workload activity.
pub fn fig10(m: &Matrix) -> String {
    let model = EnergyModel::cal22nm();
    let mut s = header("Fig 10 — Power breakdown (mW) at suite-average activity");
    // Use the workload-summed event counts per architecture.
    s += &format!(
        "{:<13}{:>8}{:>9}{:>11}{:>8}{:>8}{:>10}{:>9}{:>9}\n",
        "arch", "ALU", "DataMem", "ConfigMem", "NoC", "NIC", "Scanners", "Control", "TOTAL"
    );
    for a in &m.arch_names {
        let mut ev = crate::power::EnergyEvents::default();
        let mut n = 0u64;
        for wi in 0..m.workloads.len() {
            if let Some(r) = m.get(wi, a) {
                let e = &r.events;
                ev.alu_ops += e.alu_ops;
                ev.dmem_accesses += e.dmem_accesses;
                ev.bank_accesses += e.bank_accesses;
                ev.config_reads += e.config_reads;
                ev.noc_hops += e.noc_hops;
                ev.buf_writes += e.buf_writes;
                ev.scanner_ops += e.scanner_ops;
                ev.trigger_checks += e.trigger_checks;
                ev.cycles += e.cycles;
                n += 1;
            }
        }
        if n == 0 {
            continue;
        }
        let p = model.power(a, &ev, FREQ_MHZ);
        s += &format!(
            "{:<13}{:>8.2}{:>9.2}{:>11.2}{:>8.2}{:>8.2}{:>10.2}{:>9.2}{:>9.2}\n",
            a, p.alu, p.data_mem, p.config_mem, p.noc, p.nic, p.scanners, p.control,
            p.total()
        );
    }
    // The paper's headline ratios.
    let total = |arch: &str| {
        let mut ev = crate::power::EnergyEvents::default();
        for wi in 0..m.workloads.len() {
            if let Some(r) = m.get(wi, arch) {
                let e = &r.events;
                ev.alu_ops += e.alu_ops;
                ev.dmem_accesses += e.dmem_accesses;
                ev.bank_accesses += e.bank_accesses;
                ev.config_reads += e.config_reads;
                ev.noc_hops += e.noc_hops;
                ev.buf_writes += e.buf_writes;
                ev.scanner_ops += e.scanner_ops;
                ev.trigger_checks += e.trigger_checks;
                ev.cycles += e.cycles;
            }
        }
        model.power(arch, &ev, FREQ_MHZ).total()
    };
    s += &format!(
        "\nNexus/CGRA power: {:.2}x (paper ~1.17x)   Nexus/TIA: {:.2}x (paper <1: config-path savings)\n",
        total("Nexus") / total("GenericCGRA"),
        total("Nexus") / total("TIA"),
    );
    s
}

/// Fig 15: area breakdown.
pub fn fig15() -> String {
    let mut s = header("Fig 15 — Area breakdown (normalized, Generic CGRA = 100)");
    s += &format!(
        "{:<13}{:>7}{:>9}{:>11}{:>7}{:>9}{:>10}{:>13}{:>9}{:>9}\n",
        "arch", "ALU", "DataMem", "ConfigMem", "NoC", "AMQueue", "Scanners", "Comparators",
        "Control", "TOTAL"
    );
    for arch in ["GenericCGRA", "TIA", "Nexus"] {
        let a = area_of(arch);
        s += &format!(
            "{:<13}{:>7.1}{:>9.1}{:>11.1}{:>7.1}{:>9.1}{:>10.1}{:>13.1}{:>9.1}{:>9.1}\n",
            arch,
            a.alu,
            a.data_mem,
            a.config_mem,
            a.noc,
            a.am_queue,
            a.scanners,
            a.comparators,
            a.control,
            a.total()
        );
    }
    let (n, c, t) = (
        area_of("Nexus").total(),
        area_of("GenericCGRA").total(),
        area_of("TIA").total(),
    );
    s += &format!(
        "\nNexus vs CGRA: +{:.1}% (paper +17.3%)   Nexus vs TIA: +{:.1}% (paper +5.2%)\n",
        100.0 * (n / c - 1.0),
        100.0 * (n / t - 1.0)
    );
    s
}

/// Fig 16: off-chip bandwidth vs on-chip SRAM across sparsities.
pub fn fig16(points: &[BandwidthPoint]) -> String {
    let mut s = header("Fig 16 — Off-chip bandwidth (B/cycle) to sustain throughput vs on-chip SRAM");
    s += &format!(
        "{:<10}{:>12}{:>8}{:>14}{:>14}\n",
        "sparsity", "SRAM(KB)", "tiles", "BW (B/cyc)", "ops/cycle"
    );
    for p in points {
        s += &format!(
            "{:<10.2}{:>12}{:>8}{:>14.2}{:>14.2}\n",
            p.sparsity,
            p.total_sram_bytes / 1024,
            p.tiles,
            p.bytes_per_cycle,
            p.ops_per_cycle
        );
    }
    s
}

/// Fig 17: scalability across array sizes.
pub fn fig17(points: &[ScalePoint]) -> String {
    let mut s = header("Fig 17 — Scalability across array sizes (ops/cycle, utilization)");
    s += &format!(
        "{:<8}{:<14}{:>12}{:>14}\n",
        "array", "workload", "perf", "utilization"
    );
    for p in points {
        s += &format!(
            "{}x{:<6}{:<14}{:>12.3}{:>13.1}%\n",
            p.dim,
            p.dim,
            p.workload,
            p.perf,
            p.utilization * 100.0
        );
    }
    s
}

/// Table 2: SOTA comparison. Published rows are reproduced verbatim; the
/// Nexus and TIA rows are measured on this simulator + energy model.
pub fn table2(m: &Matrix) -> String {
    let model = EnergyModel::cal22nm();
    let mut s = header("Table 2 — Comparison with state-of-the-art edge CGRAs");
    s += &format!(
        "{:<22}{:>10}{:>12}{:>12}{:>16}\n",
        "design", "power mW", "MOPS", "MOPS/mW", "source"
    );
    s += &format!(
        "{:<22}{:>10}{:>12}{:>12}{:>16}\n",
        "UE-CGRA [47]", "14.0", "625", "45", "published"
    );
    s += &format!(
        "{:<22}{:>10}{:>12}{:>12}{:>16}\n",
        "Pipestitch [44]", "3.33", "558", "167", "published"
    );
    for arch in ["TIA", "Nexus"] {
        // Peak-throughput operating point: best *useful* MOPS across the
        // suite (work_ops/cycle, the cross-design comparable metric).
        let mut best_mops = 0.0f64;
        let mut power = 0.0f64;
        for wi in 0..m.workloads.len() {
            if let Some(r) = m.get(wi, arch) {
                let ops_mops = r.mops(FREQ_MHZ);
                if ops_mops > best_mops {
                    best_mops = ops_mops;
                    power = model.power(arch, &r.events, FREQ_MHZ).total();
                }
            }
        }
        s += &format!(
            "{:<22}{:>10.3}{:>12.0}{:>12.0}{:>16}\n",
            format!("{arch} (ours)"),
            power,
            best_mops,
            best_mops / power,
            "measured"
        );
    }
    s += "\npaper anchors: TIA 4.626 mW / 490 MOPS / 106 MOPS/mW; Nexus 3.865 mW / 748 MOPS / 194 MOPS/mW\n";
    s
}

/// Table 1: architectural parameters (from the live ArchConfig).
pub fn table1() -> String {
    let c = crate::config::ArchConfig::nexus();
    let mut s = header("Table 1 — Nexus Machine architectural parameters");
    s += &format!("Array          {}x{} INT16 PEs\n", c.width, c.height);
    s += &format!(
        "SRAM           {}B per PE; {}KB overall\n",
        c.dmem_words * 2,
        c.total_dmem_bytes() / 1024
    );
    s += &format!(
        "AM Queue       1KB FIFO, 70b entries ({} on-chip window entries)\n",
        c.am_queue_entries
    );
    s += &format!("Config memory  {} entries per PE (replicated)\n", c.config_entries);
    s += &format!(
        "Router         {} flit buffers/port, T_off={}, T_on={}\n",
        c.router_buf_depth, c.t_off, c.t_on
    );
    s += &format!(
        "Main memory    {:.1} GB/s AXI4 ({} B/cycle @ {} MHz)\n",
        c.axi_bytes_per_cycle * c.freq_mhz * 1e6 / 1e9,
        c.axi_bytes_per_cycle,
        c.freq_mhz
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_matrix;

    #[test]
    fn fig15_and_table1_render() {
        let s = fig15();
        assert!(s.contains("Nexus vs CGRA"));
        let t = table1();
        assert!(t.contains("4x4"));
        assert!(t.contains("T_off=1"));
    }

    #[test]
    fn full_reports_render_with_expected_shapes() {
        let m = run_matrix(1);
        let f11 = fig11(&m);
        assert!(f11.contains("geomean Nexus/CGRA"));
        let f13 = fig13(&m);
        assert!(f13.contains("%"));
        let f14 = fig14(&m);
        assert!(!f14.contains("MatMul"), "dense omitted from Fig 14");
        let t2 = table2(&m);
        assert!(t2.contains("Pipestitch"));
        let f10 = fig10(&m);
        assert!(f10.contains("TOTAL"));
        let f12 = fig12(&m);
        assert!(f12.contains("workload"));
    }
}
