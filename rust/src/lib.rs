//! # Nexus Machine
//!
//! A production-quality reproduction of *Nexus Machine: An Active Message
//! Inspired Reconfigurable Architecture for Irregular Workloads* (Juneja,
//! Dangi, Bandara, Mitra, Peh — NUS, 2025).
//!
//! ## Quickstart: the `Machine` session API
//!
//! All execution goes through [`machine::Machine`] — compile once, run
//! many, every failure typed:
//!
//! ```no_run
//! use nexus::machine::Machine;
//! use nexus::workloads::Spec;
//! use nexus::{ArchConfig, tensor::gen, util::SplitMix64};
//!
//! let mut rng = SplitMix64::new(42);
//! let a = gen::skewed_csr(&mut rng, 32, 32, 0.25);
//! let x = gen::random_vec(&mut rng, 32, 3);
//!
//! // One reusable fabric session (Table 1 configuration).
//! let mut machine = Machine::new(ArchConfig::nexus());
//! // Compile (cached): tensors partitioned, static AMs generated.
//! let compiled = machine.compile(&Spec::Spmv { a, x })?;
//! println!("{} static AMs", compiled.static_am_count());
//! // Execute on the reset (not reallocated) fabric; outputs validated.
//! let exec = machine.execute(&compiled)?;
//! println!("{} cycles, {:.2} ops/cycle", exec.cycles(), exec.perf());
//! # Ok::<(), nexus::machine::ExecError>(())
//! ```
//!
//! Sweeps fan out with [`machine::MachinePool`], which gives each worker a
//! reusable `Machine`; deadlocks, unsupported (arch, workload) pairs, and
//! reference mismatches surface as [`machine::ExecError`] values.
//!
//! ## Simulator performance: `StepMode`
//!
//! The cycle-accurate fabric schedules per-cycle work in one of two modes
//! ([`config::StepMode`], selected per [`ArchConfig`]):
//!
//! - **`ActiveSet`** (default) — event-driven stepping over wake-lists:
//!   each cycle visits only PEs/routers with pending work, so host cost
//!   tracks fabric *activity* instead of mesh size. This is the mode to use
//!   everywhere; on the irregular workloads the paper targets (where most
//!   PEs idle most cycles, §3) it is several times faster than the dense
//!   scan, and the gap grows with the mesh (Fig 17 sweeps).
//! - **`DenseOracle`** — the original scan of all `width × height`
//!   components every cycle. Keep it for differential testing and for
//!   debugging scheduler suspicions: both modes are **bit-identical** in
//!   outputs, cycle counts, and [`fabric::stats::FabricStats`], a property
//!   enforced by the randomized equivalence suite in
//!   `tests/step_equivalence.rs` (case count tunable via the
//!   `NEXUS_PROP_CASES` env var) and auditable on any fabric via
//!   [`fabric::NexusFabric::check_wake_consistency`] /
//!   [`fabric::NexusFabric::state_digest`].
//!
//! `cargo bench --bench hotpath` reports the dense-vs-active wall-clock
//! ratio on a sparse workload at 16×16 as a `BENCH_STEP_MODE.json` line;
//! `cargo run --release -- validate --dense-oracle` re-validates the whole
//! suite under the oracle scheduler. Either mode additionally maintains
//! per-directed-link flit counters and the peak per-cycle link demand
//! ([`fabric::stats::FabricStats::link_flits`] /
//! [`fabric::stats::FabricStats::peak_link_demand`], indexed via
//! [`noc::link_index`]) — congestion localized to individual links, at a
//! vector-increment per crossing, included in the bit-identity contract.
//! [`power::link_demand_gbps`] converts the peak into physical GB/s at the
//! configured clock (reported per scenario by the corpus runner).
//!
//! ## Simulator performance: sharded parallel stepping
//!
//! Orthogonal to the step mode, the fabric can be partitioned into
//! [`ArchConfig::shards`] horizontal row bands and stepped by
//! [`ArchConfig::threads`] worker threads under deterministic epoch
//! barriers (`--shards`/`--threads` on the CLI). The shard count is part
//! of the *modeled schedule* — boundary links switch to epoch-start
//! snapshot acceptance and each shard owns a private PRNG stream
//! ([`util::prng::stream_seed`]), message-id space, and wake-lists — while
//! the thread count is host-side only: for a fixed `(seed, shards)`,
//! outputs, cycle counts, stats, and the per-cycle
//! [`fabric::NexusFabric::state_digest`] trace are **bit-identical at any
//! thread count** (`shards = 1` reproduces the historical simulator
//! exactly). Enforced by the `sharded_*` lockstep suites in
//! `tests/step_equivalence.rs`; [`fabric::NexusFabric::run_cycles_parallel`]
//! exposes the digest trace the suites compare. `cargo bench --bench
//! fig17_scalability` measures the wall-clock scaling on 32×32 and 64×64
//! meshes (`BENCH_SHARDED.json` lines).
//!
//! ## Topologies
//!
//! The fabric's link geometry is a runtime parameter: [`noc::Topology`]
//! implementations behind [`ArchConfig::topology`]
//! ([`config::TopologyKind`]) — the default 2D **mesh** (bit-identical to
//! the original hardwired fabric), the wraparound **torus**
//! (shortest-wrap dimension-order routing + bubble flow control), a
//! **ruche** mesh (long-range skip links every
//! [`ArchConfig::ruche_stride`] hops), and a two-level **chiplet** array
//! (mesh tiles whose boundary crossings cost
//! [`ArchConfig::inter_chiplet_latency`] cycles and proportionally less
//! bandwidth). CLI: `--topology mesh|torus|ruche|chiplet` on `corpus run`
//! and `validate`; `cargo bench --bench topology_sweep` sweeps all four
//! on skewed SpMV traffic (`BENCH_TOPOLOGY.json`).
//!
//! ## Datasets and scenarios
//!
//! The [`dataset`] subsystem feeds the machine *irregular* inputs instead
//! of the i.i.d. Bernoulli tensors the generators default to:
//!
//! - Matrix Market `.mtx` / whitespace edge-list loaders with typed parse
//!   errors and INT16-exact value quantization ([`dataset::mtx`],
//!   [`dataset::edgelist`]);
//! - heavy-tailed generators in [`tensor::gen`] (R-MAT, Chung-Lu
//!   power-law, banded, block-diagonal, adversarial hotspot rows);
//! - a named, glob-filterable scenario [`dataset::Corpus`] (kernel ×
//!   source × sparsity regime × mesh) and a pooled corpus runner that
//!   validates every scenario and emits one JSON line each, including the
//!   per-PE work-imbalance metrics
//!   [`fabric::stats::FabricStats::op_cv`] /
//!   [`fabric::stats::FabricStats::op_max_mean`].
//!
//! CLI: `nexus corpus list [--filter GLOB]` and
//! `nexus corpus run [--filter GLOB] [--seed N] [--dense-oracle]`;
//! `cargo bench --bench corpus` compares uniform vs R-MAT vs hotspot
//! inputs at 8×8/16×16.
//!
//! ## Placement & claim policies
//!
//! The two anti-imbalance levers are runtime-selectable policies on
//! [`ArchConfig`]: [`config::PlacementPolicy`] picks the row→PE
//! partitioner ([`compiler::partition::place_rows`] — Algorithm 1's
//! dissimilarity-aware clustering by default, plain nnz-balancing, or
//! hotspot-splitting that scatters the heaviest rows), and
//! [`config::ClaimPolicy`] decides when a PE claims a buffered en-route
//! AM (eager, locality-biased, credit-gated, or steal-K). Placement is a
//! compile-time choice (part of the compile-cache key); claim policies
//! are runtime-only, so one compiled artifact serves all of them. Both
//! are inside the bit-identity contract: every combination passes the
//! active-vs-dense and sharded lockstep-digest equivalence suites. CLI:
//! `--placement` / `--claim` on `corpus run` and `validate`;
//! `cargo bench --bench placement_sweep` grids policy × input source
//! (`BENCH_PLACEMENT.json`).
//!
//! ## Serving
//!
//! `nexus serve --addr 127.0.0.1:7077 --workers N` runs the simulator as
//! a long-lived batch-execution daemon ([`serve`]): newline-delimited
//! JSON requests over plain TCP (a corpus scenario name or an inline
//! spec, plus a seed), one JSON response line per request, in request
//! order. The service keeps per-worker reusable [`machine::Machine`]s
//! fed from a process-wide bounded-LRU compile cache
//! ([`machine::SharedCompileCache`]), admits work through a bounded
//! queue with explicit backpressure (`{"error":"overloaded"}` instead of
//! silent drops), answers `GET /health` / `GET /metrics` with live
//! counters (throughput, p50/p99 latency, cache hit rate), and drains
//! gracefully on `{"cmd":"shutdown"}`. Served results are bit-identical
//! to direct [`machine::Machine::run`] calls — the response carries
//! output and counter digests, and `tests/serve_suite.rs` holds the
//! equivalence. `cargo bench --bench serve_throughput` drives a
//! heavy-tailed request mix against an in-process server
//! (`BENCH_SERVE.json`).
//!
//! ## Observability & tracing
//!
//! The [`trace`] subsystem makes the fabric's cycle-level behavior
//! inspectable without perturbing it. [`config::ArchConfig::with_trace`]
//! ([`trace::TraceConfig`]) turns on structured event capture — message
//! lifecycle (inject → hop → en-route claim → commit → retire) and PE
//! state transitions (idle / compute / blocked) — into per-shard ring
//! buffers merged deterministically at the epoch barriers, so the merged
//! stream is identical at any thread count. Tracing is **provably
//! inert**: it draws no PRNG values, is excluded from
//! [`fabric::NexusFabric::state_digest`] and the compile-cache key, and a
//! traced run is bit-identical to an untraced one in outputs, cycles, and
//! stats — enforced by `tests/step_equivalence.rs` (every randomized case
//! runs one side under a random `TraceConfig`) and `tests/trace_suite.rs`
//! (which also proves event-count conservation: per-PE commit events
//! exactly equal [`fabric::stats::FabricStats::per_pe_committed_ops`]).
//!
//! Stall attribution is always on, trace or no trace:
//! [`fabric::stats::FabricStats`] counts blocked PE-cycles by cause
//! (operand wait / buffer backpressure / AXI refill / claim contention,
//! [`fabric::stats::FabricStats::stall_fractions`]) plus a windowed
//! time-series ([`fabric::stats::FabricStats::series`], one cumulative
//! sample every [`fabric::stats::SERIES_WINDOW`] cycles). Surfaces:
//! `nexus trace --scenario NAME --out trace.json` exports a
//! Chrome/Perfetto trace-event file ([`trace::chrome_trace_json`], one
//! track per PE); `nexus corpus run --stall-summary` prints a one-line
//! stall breakdown per scenario (the JSON lines always carry
//! `active_pe_frac` and the four `stall_*_frac` fields); `nexus validate`
//! reports peak link demand in GB/s; `nexus serve`'s `/metrics` exposes
//! live trace-derived gauges; and [`trace::TraceConfig::flight_recorder`]
//! keeps the last N events to dump into deadlock reports
//! ([`fabric::DeadlockError`]). `cargo bench --bench trace_overhead`
//! bounds the host-side cost (`BENCH_TRACE.json`; full capture targets
//! < 2× wall-clock).
//!
//! ## Module map
//!
//! The crate contains, from the bottom up:
//!
//! - [`util`] — deterministic PRNG, a mini property-testing harness, stats.
//! - [`config`] — Table 1 architectural parameters and ablation presets.
//! - [`isa`] — the opcode set carried inside Active Messages.
//! - [`am`] — the 70-bit Active Message format (Fig 7) and its packed form.
//! - [`tensor`] — CSR/ELL/dense formats, sparsity generators, graphs.
//! - [`dataset`] — `.mtx`/edge-list ingestion, the scenario corpus, and
//!   the corpus sweep runner (see "Datasets and scenarios" above).
//! - [`noc`] — routers, the [`noc::Topology`] layer (mesh / torus / ruche
//!   / chiplet), turn-model/XY/Valiant routing, On/Off control.
//! - [`pe`] — per-PE state: data memory, decode unit, AM NIC.
//! - [`fabric`] — the cycle-accurate simulator: Data-Driven execution and
//!   In-Network (en-route) computing, the paper's contribution.
//! - [`compiler`] — DFG scheduling, Algorithm-1 dissimilarity-aware data
//!   partitioning, static-AM codegen.
//! - [`workloads`] — the twelve evaluation kernels (sparse, dense, graph),
//!   compiled to programs by [`workloads::Spec::build`].
//! - [`baselines`] — systolic array, Generic CGRA, TIA, TIA-Valiant.
//! - [`machine`] — the unified execution API: [`machine::Machine`]
//!   sessions (compile-once/run-many over any [`machine::Backend`]), typed
//!   [`machine::ExecError`]s, and the [`machine::MachinePool`] batch
//!   executor every sweep fans out through.
//! - [`trace`] — zero-perturbation event tracing: per-shard ring buffers,
//!   deterministic epoch merge, Chrome/Perfetto export, flight recorder
//!   (see "Observability & tracing" above).
//! - [`power`] — 22nm-calibrated area/energy models (Figs 10/15, Table 2).
//! - [`runtime`] — PJRT golden-model runtime (loads `artifacts/*.hlo.txt`;
//!   the XLA client is gated behind the `pjrt` cargo feature).
//! - [`serve`] — the `nexus serve` TCP daemon: NDJSON protocol, bounded
//!   work queue, worker pool over the shared compile cache, live
//!   `/health` + `/metrics` (see "Serving" above).
//! - [`coordinator`] — pooled experiment sweeps and report printers.
//!
//! Python (JAX + Pallas) appears only at build time: `make artifacts` lowers
//! the golden models to HLO text which [`runtime`] loads; the `nexus` binary
//! is self-contained.

pub mod am;
pub mod baselines;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod fabric;
pub mod golden;
pub mod isa;
pub mod machine;
pub mod noc;
pub mod pe;
pub mod power;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod trace;
pub mod util;
pub mod workloads;

pub use config::{ArchConfig, ArchKind};
pub use fabric::NexusFabric;
pub use machine::{Compiled, ExecError, Execution, Machine, MachinePool};
