//! # Nexus Machine
//!
//! A production-quality reproduction of *Nexus Machine: An Active Message
//! Inspired Reconfigurable Architecture for Irregular Workloads* (Juneja,
//! Dangi, Bandara, Mitra, Peh — NUS, 2025).
//!
//! The crate contains, from the bottom up:
//!
//! - [`util`] — deterministic PRNG, a mini property-testing harness, stats.
//! - [`config`] — Table 1 architectural parameters and ablation presets.
//! - [`isa`] — the opcode set carried inside Active Messages.
//! - [`am`] — the 70-bit Active Message format (Fig 7) and its packed form.
//! - [`tensor`] — CSR/ELL/dense formats, sparsity generators, graphs.
//! - [`noc`] — mesh routers, turn-model/XY/Valiant routing, On/Off control.
//! - [`pe`] — per-PE state: data memory, decode unit, AM NIC.
//! - [`fabric`] — the cycle-accurate simulator: Data-Driven execution and
//!   In-Network (en-route) computing, the paper's contribution.
//! - [`compiler`] — DFG scheduling, Algorithm-1 dissimilarity-aware data
//!   partitioning, static-AM codegen.
//! - [`workloads`] — the twelve evaluation kernels (sparse, dense, graph).
//! - [`baselines`] — systolic array, Generic CGRA, TIA, TIA-Valiant.
//! - [`power`] — 22nm-calibrated area/energy models (Figs 10/15, Table 2).
//! - [`runtime`] — PJRT golden-model runtime (loads `artifacts/*.hlo.txt`).
//! - [`coordinator`] — threaded experiment sweeps and report printers.
//!
//! Python (JAX + Pallas) appears only at build time: `make artifacts` lowers
//! the golden models to HLO text which [`runtime`] loads; the `nexus` binary
//! is self-contained.

pub mod am;
pub mod baselines;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod fabric;
pub mod golden;
pub mod isa;
pub mod noc;
pub mod pe;
pub mod power;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod workloads;

pub use config::{ArchConfig, ArchKind};
pub use fabric::NexusFabric;
