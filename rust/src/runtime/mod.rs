//! Golden-model runtime: loads AOT-compiled XLA artifacts (HLO text produced
//! by `python/compile/aot.py`) and executes them on the PJRT CPU client.
//!
//! This is the only place the repository touches XLA at run time. Python is
//! never on the request path: `make artifacts` lowers the JAX/Pallas golden
//! models once, and this module loads the resulting `artifacts/*.hlo.txt`
//! files, compiles them with PJRT, and executes them with concrete inputs.
//!
//! The simulator (the paper's contribution) computes in INT16 on the fabric;
//! the golden model computes the same workload in f32 on XLA. The
//! [`GoldenRuntime`] provides f32 in/out; callers are responsible for keeping
//! inputs small enough that the two agree exactly after rounding.
//!
//! ## The `pjrt` feature
//!
//! The default build is offline and dependency-free, so the PJRT-backed
//! implementation is gated behind the `pjrt` cargo feature; enabling it
//! requires adding the external `xla` crate (and its `xla_extension` C++
//! distribution) to `rust/Cargo.toml`. Without the feature, a stub
//! [`GoldenRuntime`] with the same API reports artifacts on disk but
//! returns a descriptive error from [`GoldenRuntime::run`], and the golden
//! checks skip exactly as they do when artifacts are absent.
//!
//! Interchange format is HLO *text*, not serialized `HloModuleProto`:
//! jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.

use std::path::PathBuf;

/// Boxed error used across the golden-model path (keeps the default build
/// free of external error-handling crates).
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::Result;
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A compiled XLA executable wrapper for one golden model artifact.
    pub struct GoldenModel {
        exe: xla::PjRtLoadedExecutable,
        /// Artifact path, for error messages.
        pub path: PathBuf,
    }

    impl GoldenModel {
        /// Execute the model on f32 inputs. Each input is a `(data, shape)`
        /// pair; shapes use row-major layout. Returns every output of the
        /// (tupled) result, flattened to `Vec<f32>` each.
        pub fn run(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| format!("reshape input to {dims:?}: {e}"))?;
                literals.push(lit);
            }
            let mut result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| format!("execute {}: {e}", self.path.display()))?[0][0]
                .to_literal_sync()
                .map_err(|e| e.to_string())?;
            // aot.py lowers with return_tuple=True, so outputs are always a
            // tuple.
            let tuple = result.decompose_tuple().map_err(|e| e.to_string())?;
            let mut outs = Vec::with_capacity(tuple.len());
            for lit in tuple {
                outs.push(lit.to_vec::<f32>().map_err(|e| e.to_string())?);
            }
            Ok(outs)
        }
    }

    /// Loads and caches golden models from an artifacts directory.
    pub struct GoldenRuntime {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: HashMap<String, GoldenModel>,
    }

    impl GoldenRuntime {
        /// Create a runtime backed by the PJRT CPU client, loading artifacts
        /// from `dir` (usually `artifacts/`).
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| format!("create PJRT CPU client: {e}"))?;
            Ok(Self {
                client,
                dir: dir.as_ref().to_path_buf(),
                cache: HashMap::new(),
            })
        }

        /// Platform name of the underlying PJRT client (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// True when a real PJRT client backs this runtime.
        pub fn available(&self) -> bool {
            true
        }

        /// Load (and cache) the artifact `<dir>/<name>.hlo.txt`.
        pub fn load(&mut self, name: &str) -> Result<&GoldenModel> {
            if !self.cache.contains_key(name) {
                let path = self.dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or("artifact path not utf-8")?,
                )
                .map_err(|e| format!("parse HLO text {}: {e}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| format!("compile {}: {e}", path.display()))?;
                self.cache
                    .insert(name.to_string(), GoldenModel { exe, path });
            }
            Ok(&self.cache[name])
        }

        /// Convenience: load `name` and run it in one call.
        pub fn run(&mut self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            self.load(name)?;
            self.cache[name].run(inputs)
        }

        /// True if the artifact file for `name` exists on disk.
        pub fn has_artifact(&self, name: &str) -> bool {
            self.dir.join(format!("{name}.hlo.txt")).exists()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{GoldenModel, GoldenRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::Result;
    use std::path::{Path, PathBuf};

    /// API-compatible stand-in for the PJRT runtime in default (offline)
    /// builds: artifact presence checks work, execution reports why it
    /// cannot run.
    pub struct GoldenRuntime {
        dir: PathBuf,
    }

    impl GoldenRuntime {
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            Ok(Self {
                dir: dir.as_ref().to_path_buf(),
            })
        }

        /// Platform name of the underlying PJRT client.
        pub fn platform(&self) -> String {
            "unavailable (built without the `pjrt` feature)".to_string()
        }

        /// Always false: the stub cannot execute models, so golden checks
        /// skip instead of failing.
        pub fn available(&self) -> bool {
            false
        }

        /// Execution requires the real PJRT client.
        pub fn run(&mut self, name: &str, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            Err(format!(
                "cannot execute golden model {name:?}: built without the `pjrt` \
                 feature (see rust/src/runtime/mod.rs)"
            )
            .into())
        }

        /// True if the artifact file for `name` exists on disk.
        pub fn has_artifact(&self, name: &str) -> bool {
            self.dir.join(format!("{name}.hlo.txt")).exists()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::GoldenRuntime;

/// Locate the artifacts directory: `$NEXUS_ARTIFACTS` if set, else
/// `artifacts/` relative to the workspace root (walking up from cwd).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("NEXUS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
