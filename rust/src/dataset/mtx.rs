//! Matrix Market (`.mtx`) coordinate-format reader/writer.
//!
//! Supports the subset real sparse-matrix collections (SuiteSparse, the
//! matrices DCRA and DPU-v2 evaluate on) actually use for our kernels:
//! `matrix coordinate {integer|real|pattern} {general|symmetric}`.
//! Symmetric inputs are expanded (off-diagonal entries mirrored) so the
//! result is always a fully materialized [`Csr`]. Array format, complex
//! fields, and skew-symmetric/hermitian symmetry are rejected with typed
//! [`MtxError::Unsupported`] errors rather than misparsed.
//!
//! ## Value quantization
//!
//! The fabric validates every run bit-for-bit against wrapping-INT16
//! software references, which stays exact only while operand magnitudes are
//! small (see `tensor/gen.rs`). Ingested values are therefore quantized by
//! [`quantize_value`]: nonzero inputs map to the nearest integer in
//! `[-4, 4]` with the sign preserved and never to zero (`|v| < 0.5` rounds
//! to ±1); exact zeros are dropped from the sparse structure. The structure
//! — which is what irregularity is about — survives untouched.

use crate::tensor::{Csr, CsrError, DupPolicy};
use std::collections::HashSet;
use std::fmt;
use std::path::Path;

/// Value field of a Matrix Market header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MtxField {
    Integer,
    Real,
    /// Structure only; every stored entry gets value 1.
    Pattern,
}

/// Symmetry of a Matrix Market header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MtxSymmetry {
    General,
    /// One triangle stored; off-diagonal entries are mirrored on read.
    Symmetric,
}

/// Typed `.mtx` parse failure. Line numbers are 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MtxError {
    /// The file does not start with a `%%MatrixMarket` banner.
    MissingHeader,
    /// The banner exists but a token is not valid Matrix Market.
    BadHeader { line: usize, what: String },
    /// Valid Matrix Market, but a variant this loader does not handle
    /// (array format, complex field, skew-symmetric/hermitian symmetry).
    Unsupported { line: usize, what: String },
    /// A size or entry line failed to parse.
    Malformed { line: usize, what: String },
    /// An entry was structurally invalid (out of bounds, duplicate).
    Entry { line: usize, source: CsrError },
    /// Fewer/more entry lines than the size line declared.
    WrongEntryCount { expected: usize, got: usize },
    /// Underlying I/O failure (file variants only).
    Io(String),
}

impl fmt::Display for MtxError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MtxError::MissingHeader => {
                write!(f, "missing %%MatrixMarket header on line 1")
            }
            MtxError::BadHeader { line, what } => {
                write!(f, "line {line}: bad MatrixMarket header: {what}")
            }
            MtxError::Unsupported { line, what } => {
                write!(f, "line {line}: unsupported MatrixMarket variant: {what}")
            }
            MtxError::Malformed { line, what } => write!(f, "line {line}: {what}"),
            MtxError::Entry { line, source } => write!(f, "line {line}: {source}"),
            MtxError::WrongEntryCount { expected, got } => {
                write!(f, "size line declared {expected} entries, file has {got}")
            }
            MtxError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for MtxError {}

/// Quantize a source value into the INT16-exact band the golden comparison
/// needs: nearest integer in `[-4, 4]`, sign preserved, nonzero inputs
/// never collapse to zero; exact zeros stay zero (and are dropped from the
/// sparse structure by the loaders).
pub fn quantize_value(v: f64) -> i16 {
    if v == 0.0 {
        return 0;
    }
    let q = v.abs().round().clamp(1.0, 4.0) as i16;
    if v < 0.0 {
        -q
    } else {
        q
    }
}

/// Parsed header of a `.mtx` file.
struct Header {
    field: MtxField,
    symmetry: MtxSymmetry,
}

fn parse_header(line: &str) -> Result<Header, MtxError> {
    if !line.to_ascii_lowercase().starts_with("%%matrixmarket") {
        return Err(MtxError::MissingHeader);
    }
    let toks: Vec<String> = line
        .split_whitespace()
        .map(|t| t.to_ascii_lowercase())
        .collect();
    if toks.len() != 5 {
        return Err(MtxError::BadHeader {
            line: 1,
            what: format!("expected 5 header tokens, found {}", toks.len()),
        });
    }
    if toks[1] != "matrix" {
        return Err(MtxError::Unsupported {
            line: 1,
            what: format!("object '{}' (only 'matrix')", toks[1]),
        });
    }
    match toks[2].as_str() {
        "coordinate" => {}
        "array" => {
            return Err(MtxError::Unsupported {
                line: 1,
                what: "'array' format (only 'coordinate')".into(),
            })
        }
        other => {
            return Err(MtxError::BadHeader {
                line: 1,
                what: format!("format '{other}'"),
            })
        }
    }
    let field = match toks[3].as_str() {
        "integer" => MtxField::Integer,
        "real" => MtxField::Real,
        "pattern" => MtxField::Pattern,
        "complex" => {
            return Err(MtxError::Unsupported {
                line: 1,
                what: "'complex' field".into(),
            })
        }
        other => {
            return Err(MtxError::BadHeader {
                line: 1,
                what: format!("field '{other}'"),
            })
        }
    };
    let symmetry = match toks[4].as_str() {
        "general" => MtxSymmetry::General,
        "symmetric" => MtxSymmetry::Symmetric,
        "skew-symmetric" | "hermitian" => {
            return Err(MtxError::Unsupported {
                line: 1,
                what: format!("'{}' symmetry", toks[4]),
            })
        }
        other => {
            return Err(MtxError::BadHeader {
                line: 1,
                what: format!("symmetry '{other}'"),
            })
        }
    };
    Ok(Header { field, symmetry })
}

/// Sanity caps on header-declared sizes, so a corrupt size line yields a
/// typed error instead of an enormous allocation (the construction path
/// allocates per-row state eagerly). Far beyond anything the fabric can
/// ever tile.
const MAX_DIM: usize = 1 << 20;
const MAX_NNZ: usize = 1 << 26;

/// Parse one 1-based index token.
fn parse_index(tok: &str, line: usize, what: &str) -> Result<usize, MtxError> {
    let v: usize = tok.parse().map_err(|_| MtxError::Malformed {
        line,
        what: format!("{what} '{tok}' is not an unsigned integer"),
    })?;
    if v == 0 {
        return Err(MtxError::Malformed {
            line,
            what: format!("{what} is 0 (Matrix Market indices are 1-based)"),
        });
    }
    Ok(v)
}

/// Read a Matrix Market coordinate matrix from text into a quantized
/// [`Csr`]. See the module docs for the accepted subset and quantization
/// rules.
pub fn read_mtx(text: &str) -> Result<Csr, MtxError> {
    let mut lines = text.lines().enumerate();
    let header = match lines.next() {
        Some((_, first)) => parse_header(first)?,
        None => return Err(MtxError::MissingHeader),
    };
    // Size line: first non-comment, non-blank line after the banner.
    let mut size: Option<(usize, usize, usize)> = None;
    let mut size_line = 0usize;
    for (i, raw) in &mut lines {
        let line_no = i + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() != 3 {
            return Err(MtxError::Malformed {
                line: line_no,
                what: format!("size line needs 'rows cols nnz', found {} tokens", toks.len()),
            });
        }
        let rows = parse_index(toks[0], line_no, "row count")?;
        let cols = parse_index(toks[1], line_no, "col count")?;
        let nnz: usize = toks[2].parse().map_err(|_| MtxError::Malformed {
            line: line_no,
            what: format!("entry count '{}' is not an unsigned integer", toks[2]),
        })?;
        if rows > MAX_DIM || cols > MAX_DIM || nnz > MAX_NNZ {
            return Err(MtxError::Unsupported {
                line: line_no,
                what: format!(
                    "matrix size {rows}x{cols} with {nnz} entries exceeds the \
                     supported bounds ({MAX_DIM}x{MAX_DIM}, {MAX_NNZ} entries)"
                ),
            });
        }
        if nnz > rows.saturating_mul(cols) {
            return Err(MtxError::Malformed {
                line: line_no,
                what: format!("entry count {nnz} exceeds rows*cols = {}", rows * cols),
            });
        }
        size = Some((rows, cols, nnz));
        size_line = line_no;
        break;
    }
    let (rows, cols, declared) = size.ok_or_else(|| MtxError::Malformed {
        line: size_line.max(1),
        what: "missing size line".into(),
    })?;

    let expected_tokens = match header.field {
        MtxField::Pattern => 2,
        _ => 3,
    };
    // Capacity is a hint only: cap it so a corrupt (but in-bounds) declared
    // count cannot force a giant up-front allocation before any entry parses.
    let cap = (declared * 2).min(1 << 22);
    let mut seen: HashSet<(usize, usize)> = HashSet::with_capacity(cap);
    let mut trip: Vec<(usize, usize, i16)> = Vec::with_capacity(cap);
    let mut got = 0usize;
    for (i, raw) in lines {
        let line_no = i + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() != expected_tokens {
            return Err(MtxError::Malformed {
                line: line_no,
                what: format!(
                    "entry needs {expected_tokens} tokens for this field, found {}",
                    toks.len()
                ),
            });
        }
        got += 1;
        let r = parse_index(toks[0], line_no, "row index")? - 1;
        let c = parse_index(toks[1], line_no, "col index")? - 1;
        if r >= rows || c >= cols {
            return Err(MtxError::Entry {
                line: line_no,
                source: CsrError::OutOfBounds {
                    row: r,
                    col: c,
                    rows,
                    cols,
                },
            });
        }
        let v = match header.field {
            MtxField::Pattern => 1i16,
            MtxField::Integer => {
                let x: i64 = toks[2].parse().map_err(|_| MtxError::Malformed {
                    line: line_no,
                    what: format!("value '{}' is not an integer", toks[2]),
                })?;
                quantize_value(x as f64)
            }
            MtxField::Real => {
                let x: f64 = toks[2].parse().map_err(|_| MtxError::Malformed {
                    line: line_no,
                    what: format!("value '{}' is not a number", toks[2]),
                })?;
                if !x.is_finite() {
                    return Err(MtxError::Malformed {
                        line: line_no,
                        what: format!("value '{}' is not finite", toks[2]),
                    });
                }
                quantize_value(x)
            }
        };
        // Duplicate coordinates (including an explicit mirror of an already
        // expanded symmetric entry) are malformed input, caught here so the
        // error can name the offending line.
        if !seen.insert((r, c)) {
            return Err(MtxError::Entry {
                line: line_no,
                source: CsrError::Duplicate { row: r, col: c },
            });
        }
        if v != 0 {
            trip.push((r, c, v));
        }
        if header.symmetry == MtxSymmetry::Symmetric && r != c {
            if !seen.insert((c, r)) {
                return Err(MtxError::Entry {
                    line: line_no,
                    source: CsrError::Duplicate { row: c, col: r },
                });
            }
            if v != 0 {
                trip.push((c, r, v));
            }
        }
    }
    if got != declared {
        return Err(MtxError::WrongEntryCount {
            expected: declared,
            got,
        });
    }
    // The duplicate set above already guarantees uniqueness; Reject is a
    // belt-and-suspenders audit that construction stays consistent.
    Csr::try_from_triplets(rows, cols, trip, DupPolicy::Reject)
        .map_err(|source| MtxError::Entry { line: 0, source })
}

/// Write a [`Csr`] as `matrix coordinate integer general` text. Values in
/// `[-4, 4]` (everything the in-repo generators produce) round-trip
/// bit-identically through [`read_mtx`].
pub fn write_mtx(m: &Csr) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(64 + 16 * m.nnz());
    s.push_str("%%MatrixMarket matrix coordinate integer general\n");
    let _ = writeln!(s, "{} {} {}", m.rows, m.cols, m.nnz());
    for r in 0..m.rows {
        for (c, v) in m.row(r) {
            let _ = writeln!(s, "{} {} {}", r + 1, c + 1, v);
        }
    }
    s
}

/// [`read_mtx`] from a file path.
pub fn read_mtx_file(path: impl AsRef<Path>) -> Result<Csr, MtxError> {
    let text = std::fs::read_to_string(path).map_err(|e| MtxError::Io(e.to_string()))?;
    read_mtx(&text)
}

/// [`write_mtx`] to a file path.
pub fn write_mtx_file(path: impl AsRef<Path>, m: &Csr) -> Result<(), MtxError> {
    std::fs::write(path, write_mtx(m)).map_err(|e| MtxError::Io(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_value_rules() {
        assert_eq!(quantize_value(0.0), 0);
        assert_eq!(quantize_value(0.4), 1);
        assert_eq!(quantize_value(-0.001), -1);
        assert_eq!(quantize_value(2.5), 3);
        assert_eq!(quantize_value(-3.7), -4);
        assert_eq!(quantize_value(9000.0), 4);
        assert_eq!(quantize_value(-123.0), -4);
    }

    #[test]
    fn reads_general_integer() {
        let text = "%%MatrixMarket matrix coordinate integer general\n\
                    % a comment\n\
                    3 4 3\n\
                    1 1 2\n\
                    2 3 -1\n\
                    3 4 4\n";
        let m = read_mtx(text).unwrap();
        assert_eq!((m.rows, m.cols, m.nnz()), (3, 4, 3));
        assert_eq!(m.to_dense().get(0, 0), 2);
        assert_eq!(m.to_dense().get(1, 2), -1);
        assert_eq!(m.to_dense().get(2, 3), 4);
        m.validate().unwrap();
    }

    #[test]
    fn symmetric_expands_off_diagonal() {
        let text = "%%MatrixMarket matrix coordinate integer symmetric\n\
                    3 3 3\n\
                    1 1 1\n\
                    2 1 2\n\
                    3 2 3\n";
        let m = read_mtx(text).unwrap();
        assert_eq!(m.nnz(), 5, "two off-diagonal entries mirror");
        let d = m.to_dense();
        assert_eq!(d.get(1, 0), 2);
        assert_eq!(d.get(0, 1), 2);
        assert_eq!(d.get(2, 1), 3);
        assert_eq!(d.get(1, 2), 3);
        assert_eq!(d.get(0, 0), 1);
    }

    #[test]
    fn pattern_entries_become_one() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n\
                    2 2 2\n\
                    1 2\n\
                    2 1\n";
        let m = read_mtx(text).unwrap();
        assert!(m.values.iter().all(|&v| v == 1));
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn real_values_quantize_and_zeros_drop() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    2 2 3\n\
                    1 1 0.25\n\
                    1 2 -7.9\n\
                    2 2 0.0\n";
        let m = read_mtx(text).unwrap();
        assert_eq!(m.nnz(), 2, "explicit zero dropped");
        assert_eq!(m.to_dense().get(0, 0), 1);
        assert_eq!(m.to_dense().get(0, 1), -4);
    }

    #[test]
    fn writer_reader_roundtrip() {
        let mut rng = crate::util::SplitMix64::new(21);
        let m = crate::tensor::gen::random_csr(&mut rng, 9, 7, 0.35);
        let back = read_mtx(&write_mtx(&m)).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn error_cases_are_typed() {
        assert_eq!(read_mtx(""), Err(MtxError::MissingHeader));
        assert_eq!(read_mtx("1 1 1\n"), Err(MtxError::MissingHeader));
        assert!(matches!(
            read_mtx("%%MatrixMarket matrix array real general\n"),
            Err(MtxError::Unsupported { .. })
        ));
        assert!(matches!(
            read_mtx("%%MatrixMarket matrix coordinate complex general\n2 2 1\n1 1 1 0\n"),
            Err(MtxError::Unsupported { .. })
        ));
        assert!(matches!(
            read_mtx("%%MatrixMarket matrix coordinate integer general\nnot a size line\n"),
            Err(MtxError::Malformed { line: 2, .. })
        ));
        // 0-based index.
        assert!(matches!(
            read_mtx("%%MatrixMarket matrix coordinate integer general\n2 2 1\n0 1 3\n"),
            Err(MtxError::Malformed { line: 3, .. })
        ));
        // Out-of-bounds index.
        assert!(matches!(
            read_mtx("%%MatrixMarket matrix coordinate integer general\n2 2 1\n3 1 3\n"),
            Err(MtxError::Entry {
                line: 3,
                source: CsrError::OutOfBounds { .. }
            })
        ));
        // Duplicate coordinate.
        assert!(matches!(
            read_mtx("%%MatrixMarket matrix coordinate integer general\n2 2 2\n1 1 3\n1 1 2\n"),
            Err(MtxError::Entry {
                line: 4,
                source: CsrError::Duplicate { row: 0, col: 0 }
            })
        ));
        // Declared 2 entries, provided 1.
        assert_eq!(
            read_mtx("%%MatrixMarket matrix coordinate integer general\n2 2 2\n1 1 3\n"),
            Err(MtxError::WrongEntryCount {
                expected: 2,
                got: 1
            })
        );
        // Bad value token.
        assert!(matches!(
            read_mtx("%%MatrixMarket matrix coordinate integer general\n2 2 1\n1 1 x\n"),
            Err(MtxError::Malformed { line: 3, .. })
        ));
        // Corrupt size line must be a typed error, not a huge allocation.
        assert!(matches!(
            read_mtx("%%MatrixMarket matrix coordinate integer general\n99999999999999 1 0\n"),
            Err(MtxError::Unsupported { line: 2, .. })
        ));
        assert!(matches!(
            read_mtx(
                "%%MatrixMarket matrix coordinate integer general\n1 1 18446744073709551615\n"
            ),
            Err(MtxError::Unsupported { line: 2, .. })
        ));
        // Entry count larger than the matrix can hold.
        assert!(matches!(
            read_mtx("%%MatrixMarket matrix coordinate integer general\n2 2 5\n"),
            Err(MtxError::Malformed { line: 2, .. })
        ));
    }
}
