//! Whitespace edge-list reader/writer for graph datasets (SNAP-style
//! `u v [w]` lines) producing the analytics [`Graph`].
//!
//! Accepted lines: blank, comments starting with `#` or `%`, or an edge
//! `u v` / `u v w` with 0-based vertex ids. Weights are quantized into the
//! positive band the INT16 graph kernels need (`[1, 7]`, matching the
//! synthetic contact graphs): `w` maps to `clamp(round(|w|), 1, 7)`, and a
//! missing weight means 1. Vertex count is the maximum id + 1 unless
//! [`EdgeListOptions::num_vertices`] pins it; ids at or above the pinned
//! count (or a generous built-in cap when inferring) are a typed error.

use crate::tensor::Graph;
use std::fmt;
use std::path::Path;

/// Typed edge-list parse failure. Line numbers are 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EdgeListError {
    /// A non-comment line was not `u v` or `u v w`.
    Malformed { line: usize, what: String },
    /// A vertex id >= the pinned vertex count (or the built-in cap when
    /// the count is inferred).
    VertexOutOfRange {
        line: usize,
        vertex: usize,
        num_vertices: usize,
    },
    /// No edges and no pinned vertex count: the graph shape is undefined.
    Empty,
    /// Underlying I/O failure (file variant only).
    Io(String),
}

impl fmt::Display for EdgeListError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EdgeListError::Malformed { line, what } => write!(f, "line {line}: {what}"),
            EdgeListError::VertexOutOfRange {
                line,
                vertex,
                num_vertices,
            } => write!(
                f,
                "line {line}: vertex {vertex} outside the declared {num_vertices} vertices"
            ),
            EdgeListError::Empty => {
                write!(f, "edge list has no edges and no declared vertex count")
            }
            EdgeListError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for EdgeListError {}

/// Options for [`read_edge_list`].
#[derive(Debug, Clone, Copy, Default)]
pub struct EdgeListOptions {
    /// Add each edge in both directions (contact graphs are undirected;
    /// self-loops are added once).
    pub undirected: bool,
    /// Pin the vertex count instead of inferring max-id + 1.
    pub num_vertices: Option<usize>,
}

/// Sanity cap on vertex ids when the count is inferred, so a corrupt line
/// yields a typed error instead of a giant adjacency allocation (or an id
/// overflow). Far beyond anything the fabric can partition.
const MAX_VERTICES: usize = 1 << 24;

/// Quantize an edge weight into the positive `[1, 7]` band the INT16 graph
/// kernels (SSSP relaxation headroom, contact durations) expect.
pub fn quantize_weight(w: f64) -> i16 {
    w.abs().round().clamp(1.0, 7.0) as i16
}

/// Read a whitespace edge list into a [`Graph`]. See the module docs for
/// the accepted grammar and weight quantization.
pub fn read_edge_list(text: &str, opts: EdgeListOptions) -> Result<Graph, EdgeListError> {
    let mut edges: Vec<(usize, usize, i16)> = Vec::new();
    let mut max_id = 0usize;
    let mut any = false;
    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() != 2 && toks.len() != 3 {
            return Err(EdgeListError::Malformed {
                line: line_no,
                what: format!("expected 'u v [w]', found {} tokens", toks.len()),
            });
        }
        let parse_vertex = |tok: &str| -> Result<usize, EdgeListError> {
            tok.parse().map_err(|_| EdgeListError::Malformed {
                line: line_no,
                what: format!("vertex id '{tok}' is not an unsigned integer"),
            })
        };
        let u = parse_vertex(toks[0])?;
        let v = parse_vertex(toks[1])?;
        let w = if toks.len() == 3 {
            let x: f64 = toks[2].parse().map_err(|_| EdgeListError::Malformed {
                line: line_no,
                what: format!("weight '{}' is not a number", toks[2]),
            })?;
            if !x.is_finite() {
                return Err(EdgeListError::Malformed {
                    line: line_no,
                    what: format!("weight '{}' is not finite", toks[2]),
                });
            }
            quantize_weight(x)
        } else {
            1
        };
        let bound = opts.num_vertices.unwrap_or(MAX_VERTICES);
        for vertex in [u, v] {
            if vertex >= bound {
                return Err(EdgeListError::VertexOutOfRange {
                    line: line_no,
                    vertex,
                    num_vertices: bound,
                });
            }
        }
        max_id = max_id.max(u).max(v);
        any = true;
        edges.push((u, v, w));
    }
    let n = match opts.num_vertices {
        Some(n) => n,
        None if any => max_id + 1,
        None => return Err(EdgeListError::Empty),
    };
    let mut g = Graph::new(n);
    for (u, v, w) in edges {
        if opts.undirected && u != v {
            g.add_undirected(u, v, w);
        } else {
            g.add_edge(u, v, w);
        }
    }
    Ok(g)
}

/// Write a [`Graph`] as one `u v w` line per directed edge. Graphs with
/// weights already in `[1, 7]` round-trip bit-identically through
/// [`read_edge_list`] with the same vertex count pinned.
pub fn write_edge_list(g: &Graph) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(16 * g.num_edges() + 32);
    let _ = writeln!(
        s,
        "# {} vertices, {} directed edges",
        g.num_vertices,
        g.num_edges()
    );
    for (u, edges) in g.adj.iter().enumerate() {
        for &(v, w) in edges {
            let _ = writeln!(s, "{u} {v} {w}");
        }
    }
    s
}

/// [`read_edge_list`] from a file path.
pub fn read_edge_list_file(
    path: impl AsRef<Path>,
    opts: EdgeListOptions,
) -> Result<Graph, EdgeListError> {
    let text = std::fs::read_to_string(path).map_err(|e| EdgeListError::Io(e.to_string()))?;
    read_edge_list(&text, opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_directed_with_default_weight() {
        let g = read_edge_list("# comment\n0 1\n1 2 3\n", EdgeListOptions::default()).unwrap();
        assert_eq!(g.num_vertices, 3);
        assert_eq!(g.adj[0], vec![(1, 1)]);
        assert_eq!(g.adj[1], vec![(2, 3)]);
    }

    #[test]
    fn undirected_mirrors_edges_once() {
        let opts = EdgeListOptions {
            undirected: true,
            num_vertices: Some(4),
        };
        let g = read_edge_list("0 1 2\n2 2 5\n", opts).unwrap();
        assert_eq!(g.num_vertices, 4);
        assert_eq!(g.adj[0], vec![(1, 2)]);
        assert_eq!(g.adj[1], vec![(0, 2)]);
        // Self-loop added once, not twice.
        assert_eq!(g.adj[2], vec![(2, 5)]);
    }

    #[test]
    fn weights_quantize_into_band() {
        let g = read_edge_list("0 1 0.2\n0 1 -9.5\n", EdgeListOptions::default()).unwrap();
        assert_eq!(g.adj[0], vec![(1, 1), (1, 7)]);
    }

    #[test]
    fn error_cases_are_typed() {
        assert!(matches!(
            read_edge_list("0\n", EdgeListOptions::default()),
            Err(EdgeListError::Malformed { line: 1, .. })
        ));
        assert!(matches!(
            read_edge_list("0 x\n", EdgeListOptions::default()),
            Err(EdgeListError::Malformed { line: 1, .. })
        ));
        let opts = EdgeListOptions {
            undirected: false,
            num_vertices: Some(2),
        };
        assert_eq!(
            read_edge_list("0 5\n", opts),
            Err(EdgeListError::VertexOutOfRange {
                line: 1,
                vertex: 5,
                num_vertices: 2
            })
        );
        assert_eq!(
            read_edge_list("# only comments\n", EdgeListOptions::default()),
            Err(EdgeListError::Empty)
        );
        // Corrupt huge ids on the inferred-count path are typed errors, not
        // giant allocations.
        assert!(matches!(
            read_edge_list("18446744073709551615 0\n", EdgeListOptions::default()),
            Err(EdgeListError::VertexOutOfRange { line: 1, .. })
        ));
        assert!(matches!(
            read_edge_list("0 1000000000000\n", EdgeListOptions::default()),
            Err(EdgeListError::VertexOutOfRange { line: 1, .. })
        ));
    }

    #[test]
    fn writer_reader_roundtrip() {
        let mut rng = crate::util::SplitMix64::new(33);
        let g = Graph::synthetic_contact(&mut rng, 30, 120);
        let opts = EdgeListOptions {
            undirected: false,
            num_vertices: Some(g.num_vertices),
        };
        let back = read_edge_list(&write_edge_list(&g), opts).unwrap();
        assert_eq!(back, g);
    }
}
