//! The scenario corpus: a named, enumerable, glob-filterable registry of
//! (kernel × tensor source × sparsity regime × mesh size) execution
//! scenarios the corpus runner sweeps.
//!
//! Scenario names are paths — `group/kernel-source-regime-mesh`, e.g.
//! `matrix/spmv-hotspot-d10-8x8` — so shell-style globs select coherent
//! slices: `smoke/*` (the CI smoke set), `*/spmv-*`, `graph/*-rmat-*`.
//! Every scenario builds its [`Spec`] deterministically from a sweep seed
//! (decorrelated per scenario by hashing the name), and exposes the same
//! content fingerprint the [`crate::machine::Machine`] compile cache keys
//! on, so repeated runs of one scenario inside a sweep recompile nothing.

use crate::config::ArchConfig;
use crate::machine::spec_fingerprint;
use crate::tensor::gen::{self, SparsityRegime, RMAT_PROBS};
use crate::tensor::Graph;
use crate::util::SplitMix64;
use crate::workloads::{binary_mask, Spec};

/// Shell-style glob match supporting `*` (any run, possibly empty) and `?`
/// (exactly one byte). Anchored at both ends, case-sensitive.
pub fn glob_match(pattern: &str, text: &str) -> bool {
    let p = pattern.as_bytes();
    let t = text.as_bytes();
    let (mut pi, mut ti) = (0usize, 0usize);
    let mut star: Option<usize> = None;
    let mut star_t = 0usize;
    while ti < t.len() {
        if pi < p.len() && (p[pi] == b'?' || p[pi] == t[ti]) {
            pi += 1;
            ti += 1;
        } else if pi < p.len() && p[pi] == b'*' {
            star = Some(pi);
            star_t = ti;
            pi += 1;
        } else if let Some(sp) = star {
            // Backtrack: let the last `*` swallow one more byte.
            pi = sp + 1;
            star_t += 1;
            ti = star_t;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == b'*' {
        pi += 1;
    }
    pi == p.len()
}

/// One registered execution scenario: a deterministic workload builder plus
/// the fabric geometry it targets.
pub struct Scenario {
    /// Path-style unique name, e.g. `matrix/spmv-rmat-d10-8x8`.
    pub name: String,
    /// Kernel family (`spmv`, `spmspm`, `spadd`, `sddmm`, `bfs`, ...).
    pub kernel: &'static str,
    /// Tensor source (`uniform`, `rmat`, `hotspot`, `banded`, `blockdiag`,
    /// `chunglu`, `contact`).
    pub source: &'static str,
    /// Mesh (width, height) the scenario runs on.
    pub mesh: (usize, usize),
    /// Nominal density of the primary tensor (1.0 for dense-ish graphs'
    /// placeholder; informational only).
    pub density: f64,
    build: Box<dyn Fn(&mut SplitMix64) -> Spec + Send + Sync>,
}

impl Scenario {
    fn new(
        name: impl Into<String>,
        kernel: &'static str,
        source: &'static str,
        mesh: (usize, usize),
        density: f64,
        build: impl Fn(&mut SplitMix64) -> Spec + Send + Sync + 'static,
    ) -> Self {
        Scenario {
            name: name.into(),
            kernel,
            source,
            mesh,
            density,
            build: Box::new(build),
        }
    }

    /// Build the workload instance for a sweep seed. Deterministic: equal
    /// seeds give bit-identical tensors; different scenarios draw from
    /// decorrelated streams (the seed is XORed with a hash of the name).
    pub fn spec(&self, seed: u64) -> Spec {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in self.name.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        let mut rng = SplitMix64::new(seed ^ h);
        (self.build)(&mut rng)
    }

    /// Content fingerprint of the scenario's tensors at this seed — the
    /// same value the [`crate::machine::Machine`] compile cache keys on.
    pub fn fingerprint(&self, seed: u64) -> u64 {
        spec_fingerprint(&self.spec(seed))
    }

    /// Fabric configuration this scenario targets (Nexus at the scenario's
    /// mesh; callers layer step mode / variant overrides on top).
    pub fn config(&self) -> ArchConfig {
        ArchConfig::nexus().with_array(self.mesh.0, self.mesh.1)
    }

    /// `"WxH"` display form of the mesh.
    pub fn mesh_name(&self) -> String {
        format!("{}x{}", self.mesh.0, self.mesh.1)
    }
}

/// An ordered collection of uniquely named scenarios.
pub struct Corpus {
    scenarios: Vec<Scenario>,
}

impl Corpus {
    /// The built-in corpus: smoke set (tiny tensors, 4x4 mesh — the CI
    /// gate), the matrix sweep (8x8, every irregular generator against the
    /// uniform baseline at matched densities), the graph sweep (8x8,
    /// R-MAT vs contact-network inputs), and the hotspot set (8x8, the
    /// traffic-concentrating inputs used by the topology congestion gate).
    pub fn builtin() -> Self {
        let mut c = Corpus {
            scenarios: Vec::new(),
        };
        c.register_smoke();
        c.register_matrix();
        c.register_graph();
        c.register_hotspot();
        c
    }

    fn add(&mut self, s: Scenario) {
        debug_assert!(
            self.scenarios.iter().all(|x| x.name != s.name),
            "duplicate scenario name {}",
            s.name
        );
        self.scenarios.push(s);
    }

    fn register_smoke(&mut self) {
        let mesh = (4, 4);
        self.add(Scenario::new(
            "smoke/spmv-uniform-d30-4x4",
            "spmv",
            "uniform",
            mesh,
            0.30,
            |rng| {
                let a = gen::random_csr(rng, 24, 24, 0.30);
                let x = gen::random_vec(rng, 24, 3);
                Spec::Spmv { a, x }
            },
        ));
        self.add(Scenario::new(
            "smoke/spmv-hotspot-d30-4x4",
            "spmv",
            "hotspot",
            mesh,
            0.30,
            |rng| {
                let a = gen::hotspot_csr(rng, 24, 24, 0.30, 2, 0.8);
                let x = gen::random_vec(rng, 24, 3);
                Spec::Spmv { a, x }
            },
        ));
        self.add(Scenario::new(
            "smoke/spmspm-rmat-s4-4x4",
            "spmspm",
            "rmat",
            mesh,
            0.25,
            |rng| {
                let a = gen::rmat_csr(rng, 24, 24, 144, RMAT_PROBS);
                let b = gen::random_csr(rng, 24, 24, 0.25);
                Spec::SpMSpM {
                    a,
                    b,
                    regime: SparsityRegime::S4,
                }
            },
        ));
        self.add(Scenario::new(
            "smoke/spadd-banded-4x4",
            "spadd",
            "banded",
            mesh,
            // In-band rate 0.6 over a 7-wide band of a 24x24 matrix:
            // ~0.17 overall.
            0.17,
            |rng| {
                let a = gen::banded_csr(rng, 24, 3, 0.6);
                let b = gen::banded_csr(rng, 24, 3, 0.6);
                Spec::SpAdd { a, b }
            },
        ));
        self.add(Scenario::new(
            "smoke/bfs-rmat-4x4",
            "bfs",
            "rmat",
            mesh,
            1.0,
            |rng| {
                let g = gen::rmat_graph(rng, 48, 180, RMAT_PROBS);
                Spec::Bfs { g, src: 0 }
            },
        ));
        self.add(Scenario::new(
            "smoke/pagerank-contact-4x4",
            "pagerank",
            "contact",
            mesh,
            1.0,
            |rng| {
                let g = Graph::synthetic_contact(rng, 48, 200);
                Spec::PageRank { g, iters: 2 }
            },
        ));
    }

    fn register_matrix(&mut self) {
        let mesh = (8, 8);
        let n = 64usize;
        // SpMV across every source at two density bands. The d10 pair
        // (uniform vs hotspot/rmat) is the load-imbalance acceptance gate.
        for &(tag, density) in &[("d10", 0.10), ("d30", 0.30)] {
            let target = ((n * n) as f64 * density).round() as usize;
            self.add(Scenario::new(
                format!("matrix/spmv-uniform-{tag}-8x8"),
                "spmv",
                "uniform",
                mesh,
                density,
                move |rng| {
                    let a = gen::random_csr(rng, n, n, density);
                    let x = gen::random_vec(rng, n, 3);
                    Spec::Spmv { a, x }
                },
            ));
            self.add(Scenario::new(
                format!("matrix/spmv-rmat-{tag}-8x8"),
                "spmv",
                "rmat",
                mesh,
                density,
                move |rng| {
                    let a = gen::rmat_csr(rng, n, n, target, RMAT_PROBS);
                    let x = gen::random_vec(rng, n, 3);
                    Spec::Spmv { a, x }
                },
            ));
            self.add(Scenario::new(
                format!("matrix/spmv-hotspot-{tag}-8x8"),
                "spmv",
                "hotspot",
                mesh,
                density,
                move |rng| {
                    let a = gen::hotspot_csr(rng, n, n, density, 4, 0.85);
                    let x = gen::random_vec(rng, n, 3);
                    Spec::Spmv { a, x }
                },
            ));
            self.add(Scenario::new(
                format!("matrix/spmv-chunglu-{tag}-8x8"),
                "spmv",
                "chunglu",
                mesh,
                density,
                move |rng| {
                    let a = gen::chung_lu_csr(rng, n, n, density, 1.0);
                    let x = gen::random_vec(rng, n, 3);
                    Spec::Spmv { a, x }
                },
            ));
            self.add(Scenario::new(
                format!("matrix/spmv-banded-{tag}-8x8"),
                "spmv",
                "banded",
                mesh,
                density,
                move |rng| {
                    // Band wide enough that the in-band Bernoulli rate that
                    // reproduces the nominal *overall* density stays < 1.
                    let halfband = if density < 0.2 { 8 } else { 16 };
                    let band_cells: usize = (0..n)
                        .map(|r| (r + halfband).min(n - 1) + 1 - r.saturating_sub(halfband))
                        .sum();
                    let p = ((n * n) as f64 * density / band_cells as f64).min(1.0);
                    let a = gen::banded_csr(rng, n, halfband, p);
                    let x = gen::random_vec(rng, n, 3);
                    Spec::Spmv { a, x }
                },
            ));
            self.add(Scenario::new(
                format!("matrix/spmv-blockdiag-{tag}-8x8"),
                "spmv",
                "blockdiag",
                mesh,
                density,
                move |rng| {
                    // `block` divides n, so the blocks hold n*block cells;
                    // the in-block rate reproduces the nominal density.
                    let block = if density < 0.2 { 8 } else { 32 };
                    let p = (n as f64 * density / block as f64).min(1.0);
                    let a = gen::block_diag_csr(rng, n, block, p);
                    let x = gen::random_vec(rng, n, 3);
                    Spec::Spmv { a, x }
                },
            ));
        }
        // SpMSpM: the paper's S1/S4 regimes, standard skewed pair vs R-MAT.
        for regime in [SparsityRegime::S1, SparsityRegime::S4] {
            let rname = regime.name().to_ascii_lowercase();
            self.add(Scenario::new(
                format!("matrix/spmspm-uniform-{rname}-8x8"),
                "spmspm",
                "uniform",
                mesh,
                1.0 - regime.sparsities().0,
                move |rng| {
                    let (a, b) = gen::spmspm_pair(rng, 48, regime);
                    Spec::SpMSpM { a, b, regime }
                },
            ));
            self.add(Scenario::new(
                format!("matrix/spmspm-rmat-{rname}-8x8"),
                "spmspm",
                "rmat",
                mesh,
                1.0 - regime.sparsities().0,
                move |rng| {
                    let (sa, sb) = regime.sparsities();
                    let nnz_a = ((48 * 48) as f64 * (1.0 - sa)).round() as usize;
                    let a = gen::rmat_csr(rng, 48, 48, nnz_a, RMAT_PROBS);
                    let b = gen::random_csr(rng, 48, 48, 1.0 - sb);
                    Spec::SpMSpM { a, b, regime }
                },
            ));
        }
        self.add(Scenario::new(
            "matrix/spadd-blockdiag-8x8",
            "spadd",
            "blockdiag",
            mesh,
            // In-block rate 0.5 over 8-blocks of a 64x64 matrix: ~0.06
            // overall (the B operand uses 16-blocks at 0.3, ~0.075).
            0.06,
            move |rng| {
                let a = gen::block_diag_csr(rng, n, 8, 0.5);
                let b = gen::block_diag_csr(rng, n, 16, 0.3);
                Spec::SpAdd { a, b }
            },
        ));
        self.add(Scenario::new(
            "matrix/sddmm-hotspot-d30-8x8",
            "sddmm",
            "hotspot",
            mesh,
            0.30,
            |rng| {
                // Binary hotspot mask: structure from the hotspot generator,
                // values forced to 1 (SDDMM masks are patterns).
                let pat = gen::hotspot_csr(rng, 32, 32, 0.30, 2, 0.8);
                let mut trip = Vec::with_capacity(pat.nnz());
                for r in 0..pat.rows {
                    for (c, _) in pat.row(r) {
                        trip.push((r, c, 1i16));
                    }
                }
                let mask = crate::tensor::Csr::from_triplets(32, 32, trip);
                let a = gen::random_dense(rng, 32, 16, 3);
                let b = gen::random_dense(rng, 16, 32, 3);
                Spec::Sddmm { mask, a, b }
            },
        ));
        self.add(Scenario::new(
            "matrix/sddmm-uniform-d30-8x8",
            "sddmm",
            "uniform",
            mesh,
            0.30,
            |rng| {
                let mask = binary_mask(rng, 32, 32, 0.30);
                let a = gen::random_dense(rng, 32, 16, 3);
                let b = gen::random_dense(rng, 16, 32, 3);
                Spec::Sddmm { mask, a, b }
            },
        ));
    }

    fn register_graph(&mut self) {
        fn graph_spec(kernel: &str, g: Graph) -> Spec {
            match kernel {
                "bfs" => Spec::Bfs { g, src: 0 },
                "sssp" => Spec::Sssp { g, src: 0 },
                _ => Spec::PageRank { g, iters: 2 },
            }
        }
        let mesh = (8, 8);
        for kernel in ["bfs", "sssp", "pagerank"] {
            self.add(Scenario::new(
                format!("graph/{kernel}-rmat-8x8"),
                kernel,
                "rmat",
                mesh,
                1.0,
                move |rng| graph_spec(kernel, gen::rmat_graph(rng, 96, 420, RMAT_PROBS)),
            ));
            self.add(Scenario::new(
                format!("graph/{kernel}-contact-8x8"),
                kernel,
                "contact",
                mesh,
                1.0,
                move |rng| graph_spec(kernel, Graph::synthetic_contact(rng, 96, 420)),
            ));
        }
    }

    /// Traffic-concentrating scenarios: skewed tensors whose AM streams
    /// converge on a few owner PEs, saturating the links into the hot
    /// region. This is the group the `--topology` congestion comparisons
    /// (and the CI torus acceptance run) sweep, since wraparound/skip links
    /// change its per-link flit distribution the most.
    fn register_hotspot(&mut self) {
        let mesh = (8, 8);
        self.add(Scenario::new(
            "hotspot/spmv-hotspot-d20-8x8",
            "spmv",
            "hotspot",
            mesh,
            0.20,
            |rng| {
                let a = gen::hotspot_csr(rng, 64, 64, 0.20, 2, 0.9);
                let x = gen::random_vec(rng, 64, 3);
                Spec::Spmv { a, x }
            },
        ));
        self.add(Scenario::new(
            "hotspot/spmv-rmat-d20-8x8",
            "spmv",
            "rmat",
            mesh,
            0.20,
            |rng| {
                let a = gen::rmat_csr(rng, 64, 64, 819, RMAT_PROBS);
                let x = gen::random_vec(rng, 64, 3);
                Spec::Spmv { a, x }
            },
        ));
        self.add(Scenario::new(
            "hotspot/bfs-rmat-8x8",
            "bfs",
            "rmat",
            mesh,
            1.0,
            |rng| {
                let g = gen::rmat_graph(rng, 96, 400, RMAT_PROBS);
                Spec::Bfs { g, src: 0 }
            },
        ));
        // 16x16-mesh variants: the heavy tail of the `nexus serve`
        // throughput mix. Tensors stay modest (n=96, ~6% density) so the
        // full-corpus debug-mode validation sweep stays fast — the point
        // is the 4x-larger fabric, not a bigger matrix.
        let big = (16, 16);
        self.add(Scenario::new(
            "hotspot/spmv-rmat-d6-16x16",
            "spmv",
            "rmat",
            big,
            0.06,
            |rng| {
                let a = gen::rmat_csr(rng, 96, 96, 553, RMAT_PROBS);
                let x = gen::random_vec(rng, 96, 3);
                Spec::Spmv { a, x }
            },
        ));
        self.add(Scenario::new(
            "hotspot/spmv-hotspot-d6-16x16",
            "spmv",
            "hotspot",
            big,
            0.06,
            |rng| {
                let a = gen::hotspot_csr(rng, 96, 96, 0.06, 3, 0.85);
                let x = gen::random_vec(rng, 96, 3);
                Spec::Spmv { a, x }
            },
        ));
    }

    /// All scenarios, registration order.
    pub fn scenarios(&self) -> &[Scenario] {
        &self.scenarios
    }

    /// Scenarios whose name matches the glob, registration order.
    pub fn filter(&self, pattern: &str) -> Vec<&Scenario> {
        self.scenarios
            .iter()
            .filter(|s| glob_match(pattern, &s.name))
            .collect()
    }

    /// [`Corpus::filter`] with an optional glob: every scenario when `None`
    /// (the CLI's `--filter` dispatch).
    pub fn select(&self, filter: Option<&str>) -> Vec<&Scenario> {
        match filter {
            Some(glob) => self.filter(glob),
            None => self.scenarios.iter().collect(),
        }
    }

    /// Look up one scenario by exact name.
    pub fn find(&self, name: &str) -> Option<&Scenario> {
        self.scenarios.iter().find(|s| s.name == name)
    }

    pub fn len(&self) -> usize {
        self.scenarios.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glob_match_basics() {
        assert!(glob_match("smoke/*", "smoke/spmv-uniform-d30-4x4"));
        assert!(!glob_match("smoke/*", "matrix/spmv-uniform-d10-8x8"));
        assert!(glob_match("*/spmv-*", "matrix/spmv-rmat-d10-8x8"));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("", ""));
        assert!(!glob_match("", "x"));
        assert!(glob_match("a?c", "abc"));
        assert!(!glob_match("a?c", "ac"));
        assert!(glob_match("*-8x8", "graph/bfs-rmat-8x8"));
        assert!(!glob_match("*-4x4", "graph/bfs-rmat-8x8"));
        assert!(glob_match("a*b*c", "aXbYc"));
        assert!(!glob_match("a*b*c", "aXcYb"));
    }

    #[test]
    fn builtin_corpus_is_well_formed() {
        let c = Corpus::builtin();
        assert!(c.len() >= 24, "corpus too small: {}", c.len());
        // Unique names.
        let mut names: Vec<&str> = c.scenarios().iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), c.len(), "duplicate scenario names");
        // Every group populated; smoke stays small enough for CI.
        let smoke = c.filter("smoke/*");
        assert!(!smoke.is_empty() && smoke.len() <= 8);
        assert!(!c.filter("matrix/*").is_empty());
        assert!(!c.filter("graph/*").is_empty());
        assert!(!c.filter("hotspot/*").is_empty());
        // Valid meshes.
        for s in c.scenarios() {
            s.config().validate().expect("scenario config");
        }
    }

    #[test]
    fn scenario_specs_are_deterministic_and_decorrelated() {
        let c = Corpus::builtin();
        let a = c.find("smoke/spmv-uniform-d30-4x4").unwrap();
        assert_eq!(a.fingerprint(7), a.fingerprint(7), "same seed, same data");
        assert_ne!(a.fingerprint(7), a.fingerprint(8), "seed must matter");
        let b = c.find("smoke/spmv-hotspot-d30-4x4").unwrap();
        assert_ne!(
            a.fingerprint(7),
            b.fingerprint(7),
            "scenarios must draw decorrelated streams"
        );
    }

    #[test]
    fn hotspot_scenario_is_actually_irregular() {
        let c = Corpus::builtin();
        let hot = c.find("matrix/spmv-hotspot-d10-8x8").unwrap().spec(1);
        let uni = c.find("matrix/spmv-uniform-d10-8x8").unwrap().spec(1);
        let (hot_a, uni_a) = match (&hot, &uni) {
            (Spec::Spmv { a: h, .. }, Spec::Spmv { a: u, .. }) => (h.clone(), u.clone()),
            _ => panic!("spmv scenarios must build Spmv specs"),
        };
        // Matched density band...
        let dh = hot_a.density();
        let du = uni_a.density();
        assert!((dh - du).abs() < 0.05, "densities diverged: {dh} vs {du}");
        // ...but very different row-occupancy tails.
        let cv = |m: &crate::tensor::Csr| {
            let v: Vec<f64> = (0..m.rows).map(|r| m.row_nnz(r) as f64).collect();
            crate::util::cv(&v)
        };
        assert!(cv(&hot_a) > 2.0 * cv(&uni_a), "hotspot rows not skewed");
    }
}
