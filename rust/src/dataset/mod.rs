//! Dataset ingestion + the scenario corpus: getting *real* and
//! *adversarial* irregular tensors into the machine, at sweep scale.
//!
//! The paper's argument is about irregular workloads, but i.i.d. Bernoulli
//! tensors (`tensor/gen.rs`'s `random_csr`) are the most regular kind of
//! "sparse" there is — every row has the same expected occupancy, so load
//! imbalance barely exists. This module closes that gap with three layers:
//!
//! - **Loaders** ([`mtx`], [`edgelist`]) — Matrix Market coordinate files
//!   (integer/real/pattern; general + symmetric with expansion) into
//!   [`crate::tensor::Csr`], and whitespace edge lists into
//!   [`crate::tensor::Graph`], both with typed per-line parse errors and
//!   value quantization into the INT16-exact band the bit-exact golden
//!   comparison needs.
//! - **Scenario registry** ([`corpus`]) — a [`Corpus`] of named
//!   [`Scenario`]s (kernel × tensor source × sparsity regime × mesh),
//!   enumerable, glob-filterable (`smoke/*`, `*/spmv-*`), and
//!   content-fingerprinted with the same key the
//!   [`crate::machine::Machine`] compile cache uses.
//! - **Runner** ([`runner`]) — sweeps a scenario set over the
//!   [`crate::machine::MachinePool`], validates every output, and emits one
//!   JSON line per scenario including the per-PE load-imbalance metrics
//!   (`op_cv`, `op_max_mean`) that make the irregularity story measurable.
//!
//! The irregular *generators* (R-MAT, Chung-Lu, banded, block-diagonal,
//! hotspot rows) live with the other generators in
//! [`crate::tensor::gen`]. The CLI surface is `nexus corpus list|run`.

pub mod corpus;
pub mod edgelist;
pub mod mtx;
pub mod runner;

pub use corpus::{glob_match, Corpus, Scenario};
pub use edgelist::{
    read_edge_list, read_edge_list_file, write_edge_list, EdgeListError, EdgeListOptions,
};
pub use mtx::{
    quantize_value, read_mtx, read_mtx_file, write_mtx, write_mtx_file, MtxError, MtxField,
    MtxSymmetry,
};
pub use runner::{
    cross_check_corpus, effective_shards, run_corpus, RunOptions, ScenarioMetrics, ScenarioRun,
};
