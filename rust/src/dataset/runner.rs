//! The corpus runner: execute a set of [`Scenario`]s across the
//! [`MachinePool`], validate every output against the software reference,
//! and emit one JSON line per scenario (cycles, utilization, congestion,
//! and the per-PE load-imbalance metrics `op_cv` / `op_max_mean`).
//!
//! Workers key reusable [`Machine`]s by mesh geometry, so a sweep reuses
//! fabric allocations and compile caches across every scenario sharing a
//! mesh. Failures (deadlock, validation mismatch) do not abort the sweep:
//! they surface as `"status":"error"` lines so a corpus regression names
//! exactly which scenarios broke.

use super::corpus::Scenario;
use crate::config::{ClaimPolicy, PlacementPolicy, StepMode, TopologyKind};
use crate::machine::{Machine, MachinePool};
use crate::noc::{build_topology, LINKS_PER_PE};
use crate::noc::routing::Dir;
use std::collections::HashMap;

/// Options for [`run_corpus`].
#[derive(Debug, Clone, Copy)]
pub struct RunOptions {
    /// Sweep seed: every scenario derives its tensors from this.
    pub seed: u64,
    /// Simulator scheduling mode (results are bit-identical either way).
    pub step_mode: StepMode,
    /// NoC topology the sweep runs on (`--topology`; default 2D mesh).
    pub topology: TopologyKind,
    /// Requested row-band shard count (`--shards`). Scenario meshes vary,
    /// so each run uses the largest divisor of its mesh height that does
    /// not exceed this (`effective_shards`); `1` is the unsharded
    /// simulator. The shard count is part of the modeled schedule, so it
    /// appears in every JSON line.
    pub shards: usize,
    /// Worker threads per simulation (`--threads`; host-side only, results
    /// are bit-identical at any thread count for a fixed shard count).
    pub threads: usize,
    /// Data-placement policy (`--placement`; compile-time row → PE
    /// mapping for the row-partitioned kernels).
    pub placement: PlacementPolicy,
    /// En-route claim policy (`--claim`; runtime schedule choice).
    pub claim: ClaimPolicy,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            seed: 1,
            step_mode: StepMode::ActiveSet,
            topology: TopologyKind::Mesh2D,
            shards: 1,
            threads: 1,
            placement: PlacementPolicy::default(),
            claim: ClaimPolicy::default(),
        }
    }
}

/// Largest divisor of `height` that does not exceed `requested` — the
/// per-scenario shard count a sweep-wide `--shards` request resolves to
/// (shards must divide the mesh height; see
/// [`crate::config::ArchConfig::shards`]).
pub fn effective_shards(requested: usize, height: usize) -> usize {
    let cap = requested.clamp(1, height.max(1));
    (1..=cap).rev().find(|s| height % s == 0).unwrap_or(1)
}

/// Metrics of one successfully executed scenario.
#[derive(Debug, Clone)]
pub struct ScenarioMetrics {
    pub cycles: u64,
    pub work_ops: u64,
    pub utilization: f64,
    /// Mean blocked fraction over the five router port classes.
    pub congestion: f64,
    /// Coefficient of variation of per-PE busy cycles.
    pub load_cv: f64,
    /// Coefficient of variation of per-PE committed ops (work imbalance).
    pub op_cv: f64,
    /// Max/mean of per-PE committed ops.
    pub op_max_mean: f64,
    /// Total flits over all directed links (== `flit_hops`).
    pub link_flits_total: u64,
    /// Most flits any single cycle moved across the whole NoC.
    pub peak_link_demand: u64,
    /// `peak_link_demand` converted to physical GB/s at the configured
    /// clock ([`crate::power::link_demand_gbps`]).
    pub peak_link_gbps: f64,
    /// Per-directed-link flit counts, nonzero links only, as
    /// `(from_pe, to_pe, flits)` sorted hottest-first.
    pub links: Vec<(usize, usize, u64)>,
    /// Fraction of PE-cycles that committed ALU or decode work
    /// ([`crate::fabric::stats::FabricStats::active_pe_fraction`]).
    pub active_pe_frac: f64,
    /// Stall attribution as `(class, fraction of PE-cycles)` in the fixed
    /// order operand / backpressure / axi / claim
    /// ([`crate::fabric::stats::FabricStats::stall_fractions`]).
    pub stall_fractions: [(&'static str, f64); 4],
    pub validated: bool,
}

/// Outcome of one scenario in a corpus sweep.
#[derive(Debug, Clone)]
pub struct ScenarioRun {
    pub scenario: String,
    pub kernel: &'static str,
    pub source: &'static str,
    pub mesh: String,
    /// Topology name the run used (`mesh`, `torus`, `ruche`, `chiplet`).
    pub topology: &'static str,
    /// Shard count the run actually used ([`effective_shards`] of the
    /// requested `--shards` for this scenario's mesh height).
    pub shards: usize,
    /// Placement-policy name the run compiled with (`--placement`).
    pub placement: &'static str,
    /// En-route claim-policy name the run executed with (`--claim`).
    pub claim_policy: &'static str,
    pub seed: u64,
    /// Content fingerprint of the scenario's tensors (compile-cache key).
    pub fingerprint: u64,
    /// Metrics on success, rendered error on failure.
    pub outcome: Result<ScenarioMetrics, String>,
}

impl ScenarioRun {
    /// One machine-readable JSON line (the `BENCH_CORPUS.json` artifact
    /// format; every value is a JSON number, string, or bool), emitted
    /// through the shared [`crate::util::json`] writer — the same escaping
    /// the `nexus serve` protocol uses.
    pub fn json_line(&self) -> String {
        let mut o = crate::util::json::JsonObj::new();
        o.str("scenario", &self.scenario)
            .str("kernel", self.kernel)
            .str("source", self.source)
            .str("mesh", &self.mesh)
            .str("topology", self.topology)
            .u64("shards", self.shards as u64)
            .str("placement", self.placement)
            .str("claim_policy", self.claim_policy)
            .u64("seed", self.seed)
            .hex("fingerprint", self.fingerprint);
        match &self.outcome {
            Ok(m) => {
                let links = crate::util::json::array(
                    m.links
                        .iter()
                        .map(|&(from, to, flits)| format!("[{from},{to},{flits}]")),
                );
                o.str("status", "ok")
                    .u64("cycles", m.cycles)
                    .u64("work_ops", m.work_ops)
                    .f64("utilization", m.utilization, 4)
                    .f64("congestion", m.congestion, 4)
                    .f64("load_cv", m.load_cv, 4)
                    .f64("op_cv", m.op_cv, 4)
                    .f64("op_max_mean", m.op_max_mean, 4)
                    .u64("link_flits", m.link_flits_total)
                    .u64("peak_link_demand", m.peak_link_demand)
                    .f64("peak_link_gbps", m.peak_link_gbps, 3)
                    .f64("active_pe_frac", m.active_pe_frac, 4);
                for (class, frac) in m.stall_fractions {
                    o.f64(&format!("stall_{class}_frac"), frac, 4);
                }
                o.raw("links", &links).bool("validated", m.validated);
            }
            Err(e) => {
                o.str("status", "error").str("error", e);
            }
        }
        o.build()
    }

    /// True when the scenario executed and validated bit-exactly.
    pub fn passed(&self) -> bool {
        matches!(&self.outcome, Ok(m) if m.validated)
    }

    /// One aligned human-readable line for `nexus corpus run
    /// --stall-summary`: the scenario name, the active-PE fraction, and
    /// the percentage of PE-cycles attributed to each stall class.
    pub fn stall_summary_line(&self) -> String {
        match &self.outcome {
            Ok(m) => {
                let mut s = format!(
                    "{:<34} active {:>5.1}%",
                    self.scenario,
                    100.0 * m.active_pe_frac
                );
                for (class, frac) in m.stall_fractions {
                    s.push_str(&format!("  {class} {:>5.1}%", 100.0 * frac));
                }
                s
            }
            Err(e) => format!("{:<34} ERROR: {e}", self.scenario),
        }
    }
}

/// Execute scenarios across the pool, one reusable machine per mesh per
/// worker. Results come back in scenario order.
pub fn run_corpus(scenarios: &[&Scenario], opts: RunOptions) -> Vec<ScenarioRun> {
    // Each simulation may itself run `opts.threads` shard workers; divide
    // the host's cores between the two levels of parallelism.
    let pool = MachinePool::for_threads(opts.threads);
    pool.run_batch_with(
        HashMap::<(usize, usize), Machine>::new,
        scenarios,
        |machines, sc| run_one(machines, sc, opts),
    )
}

/// Decode a raw `link_flits` vector into `(from, to, flits)` triples for
/// the links the topology actually wires, nonzero only, hottest-first.
fn decode_links(cfg: &crate::config::ArchConfig, link_flits: &[u64]) -> Vec<(usize, usize, u64)> {
    let topo = build_topology(cfg);
    let mut links: Vec<(usize, usize, u64)> = link_flits
        .iter()
        .enumerate()
        .filter(|&(_, &f)| f > 0)
        .filter_map(|(idx, &f)| {
            let from = idx / LINKS_PER_PE;
            let dir = Dir::from_port(idx % LINKS_PER_PE + 1);
            topo.neighbor(from, dir).map(|to| (from, to, f))
        })
        .collect();
    links.sort_by(|a, b| b.2.cmp(&a.2).then(a.0.cmp(&b.0)).then(a.1.cmp(&b.1)));
    links
}

fn run_one(
    machines: &mut HashMap<(usize, usize), Machine>,
    sc: &Scenario,
    opts: RunOptions,
) -> ScenarioRun {
    let shards = effective_shards(opts.shards, sc.mesh.1);
    let cfg = sc
        .config()
        .with_topology(opts.topology)
        .with_step_mode(opts.step_mode)
        .with_shards(shards)
        .with_threads(opts.threads)
        .with_placement(opts.placement)
        .with_claim(opts.claim);
    let m = machines
        .entry(sc.mesh)
        .or_insert_with(|| Machine::new(cfg.clone()));
    let spec = sc.spec(opts.seed);
    let fingerprint = crate::machine::spec_fingerprint(&spec);
    let outcome = match m.run(&spec) {
        Ok(e) => {
            let (load_cv, op_cv, op_max_mean) = match &e.stats {
                Some(s) => (s.load_cv(), s.op_cv(), s.op_max_mean()),
                None => (0.0, 0.0, 0.0),
            };
            let (link_flits_total, peak_link_demand, links) = match &e.stats {
                Some(s) => (
                    s.link_flits_total(),
                    s.peak_link_demand,
                    decode_links(&cfg, &s.link_flits),
                ),
                None => (0, 0, Vec::new()),
            };
            let peak_link_gbps = crate::power::link_demand_gbps(peak_link_demand, cfg.freq_mhz);
            let (active_pe_frac, stall_fractions) = match &e.stats {
                Some(s) => (s.active_pe_fraction(), s.stall_fractions()),
                None => (
                    0.0,
                    [
                        ("operand", 0.0),
                        ("backpressure", 0.0),
                        ("axi", 0.0),
                        ("claim", 0.0),
                    ],
                ),
            };
            let congestion =
                e.result.congestion.iter().sum::<f64>() / e.result.congestion.len() as f64;
            Ok(ScenarioMetrics {
                cycles: e.result.cycles,
                work_ops: e.result.work_ops,
                utilization: e.result.utilization,
                congestion,
                load_cv,
                op_cv,
                op_max_mean,
                link_flits_total,
                peak_link_demand,
                peak_link_gbps,
                links,
                active_pe_frac,
                stall_fractions,
                validated: e.result.validated,
            })
        }
        Err(err) => Err(err.to_string()),
    };
    ScenarioRun {
        scenario: sc.name.clone(),
        kernel: sc.kernel,
        source: sc.source,
        mesh: sc.mesh_name(),
        topology: opts.topology.name(),
        shards,
        placement: opts.placement.name(),
        claim_policy: opts.claim.name(),
        seed: opts.seed,
        fingerprint,
        outcome,
    }
}

/// `step_equivalence`-style cross-mode audit over scenarios: run each one
/// under both [`StepMode`]s and require identical outputs, cycle counts,
/// and the full [`crate::fabric::stats::FabricStats`] counter set. Returns
/// the first divergence (scenario name plus the first differing counter)
/// as `Err`.
pub fn cross_check_corpus(scenarios: &[&Scenario], seed: u64) -> Result<(), String> {
    let pool = MachinePool::new();
    let results: Vec<Result<(), String>> = pool.run_batch(scenarios, |sc| {
        let spec = sc.spec(seed);
        let mut active = Machine::new(sc.config().with_step_mode(StepMode::ActiveSet));
        let mut dense = Machine::new(sc.config().with_step_mode(StepMode::DenseOracle));
        let ea = active
            .run(&spec)
            .map_err(|e| format!("{}: active-set failed: {e}", sc.name))?;
        let ed = dense
            .run(&spec)
            .map_err(|e| format!("{}: dense-oracle failed: {e}", sc.name))?;
        if ea.outputs != ed.outputs {
            return Err(format!("{}: outputs diverge across step modes", sc.name));
        }
        if ea.cycles() != ed.cycles() {
            return Err(format!(
                "{}: cycles diverge: active {} vs dense {}",
                sc.name,
                ea.cycles(),
                ed.cycles()
            ));
        }
        match (&ea.stats, &ed.stats) {
            (Some(sa), Some(sd)) => {
                if let Some(diff) = sa.diff(sd) {
                    return Err(format!("{}: stats diverge: {diff}", sc.name));
                }
            }
            _ => return Err(format!("{}: missing fabric stats", sc.name)),
        }
        Ok(())
    });
    for r in results {
        r?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::Corpus;

    #[test]
    fn json_lines_reparse_with_the_serve_parser() {
        // The runner emits through util::json and the serve protocol
        // parses with its own hand-rolled parser; a line that round-trips
        // through both proves the two ends of the shared emitter agree.
        let run = ScenarioRun {
            scenario: "weird/\"quoted\"-name".to_string(),
            kernel: "spmv",
            source: "rmat",
            mesh: "8x8".to_string(),
            topology: "mesh",
            shards: 2,
            placement: "dissimilarity",
            claim_policy: "eager",
            seed: 7,
            fingerprint: 0xdead_beef,
            outcome: Err("tab\there \"and\" newline\nthere".to_string()),
        };
        let line = run.json_line();
        let v = crate::serve::protocol::parse_json(&line).expect("line must reparse");
        assert_eq!(
            v.get("scenario").and_then(|j| j.as_str()),
            Some("weird/\"quoted\"-name")
        );
        assert_eq!(
            v.get("error").and_then(|j| j.as_str()),
            Some("tab\there \"and\" newline\nthere")
        );
    }

    #[test]
    fn smoke_scenarios_run_validated_with_imbalance_metrics() {
        let corpus = Corpus::builtin();
        let smoke = corpus.filter("smoke/*");
        assert!(!smoke.is_empty());
        let runs = run_corpus(&smoke, RunOptions::default());
        assert_eq!(runs.len(), smoke.len());
        for run in &runs {
            match &run.outcome {
                Ok(m) => {
                    assert!(m.validated, "{} not validated", run.scenario);
                    assert!(m.cycles > 0);
                    assert!(m.op_max_mean >= 1.0, "{}: max/mean < 1", run.scenario);
                    assert!(m.link_flits_total > 0, "{}: no link traffic", run.scenario);
                    assert!(m.peak_link_demand >= 1, "{}", run.scenario);
                    assert!(!m.links.is_empty(), "{}", run.scenario);
                    let line = run.json_line();
                    assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
                    assert!(line.contains("\"status\":\"ok\""), "{line}");
                    assert!(line.contains("\"topology\":\"mesh\""), "{line}");
                    assert!(line.contains("\"shards\":1"), "{line}");
                    assert!(line.contains("\"placement\":\"dissimilarity\""), "{line}");
                    assert!(line.contains("\"claim_policy\":\"eager\""), "{line}");
                    assert!(line.contains("\"peak_link_demand\":"), "{line}");
                    assert!(line.contains("\"peak_link_gbps\":"), "{line}");
                    assert!(
                        m.peak_link_gbps
                            == crate::power::link_demand_gbps(m.peak_link_demand, 588.0),
                        "{}",
                        run.scenario
                    );
                    assert!(line.contains("\"links\":[["), "{line}");
                    // Stall attribution rides along in every line, and the
                    // fractions are well-formed (in [0,1], active nonzero
                    // for a validated run that committed work).
                    assert!(line.contains("\"active_pe_frac\":"), "{line}");
                    assert!(line.contains("\"stall_operand_frac\":"), "{line}");
                    assert!(line.contains("\"stall_backpressure_frac\":"), "{line}");
                    assert!(line.contains("\"stall_axi_frac\":"), "{line}");
                    assert!(line.contains("\"stall_claim_frac\":"), "{line}");
                    assert!(
                        m.active_pe_frac > 0.0 && m.active_pe_frac <= 1.0,
                        "{}: active_pe_frac {}",
                        run.scenario,
                        m.active_pe_frac
                    );
                    for (class, frac) in m.stall_fractions {
                        assert!(
                            (0.0..=1.0).contains(&frac),
                            "{}: stall class {class} fraction {frac}",
                            run.scenario
                        );
                    }
                    let summary = run.stall_summary_line();
                    assert!(summary.contains(&run.scenario), "{summary}");
                    assert!(summary.contains("active"), "{summary}");
                    assert!(summary.contains("operand"), "{summary}");
                }
                Err(e) => panic!("{} failed: {e}", run.scenario),
            }
        }
    }

    #[test]
    fn torus_hotspot_sweep_validates_and_reports_links() {
        // The acceptance path behind `nexus corpus run --topology torus
        // --filter 'hotspot/*'`: every scenario validates and its JSON line
        // carries per-directed-link flit counts and peak link demand.
        let corpus = Corpus::builtin();
        let hot = corpus.filter("hotspot/*");
        assert!(!hot.is_empty());
        let runs = run_corpus(
            &hot,
            RunOptions {
                topology: crate::config::TopologyKind::Torus2D,
                ..RunOptions::default()
            },
        );
        for run in &runs {
            let m = run
                .outcome
                .as_ref()
                .unwrap_or_else(|e| panic!("{} failed: {e}", run.scenario));
            assert!(m.validated, "{} not validated", run.scenario);
            // Every reported link must be between torus neighbours; total
            // must partition into the per-link counts.
            assert_eq!(
                m.links.iter().map(|&(_, _, f)| f).sum::<u64>(),
                m.link_flits_total,
                "{}",
                run.scenario
            );
            let line = run.json_line();
            assert!(line.contains("\"topology\":\"torus\""), "{line}");
        }
    }

    #[test]
    fn every_policy_combination_validates_on_smoke_scenarios() {
        // The tentpole's safety net: all placement x claim combinations
        // must still produce bit-exact validated outputs on the smoke
        // corpus (the sweep bench only compares *validated* runs).
        let corpus = Corpus::builtin();
        let smoke = corpus.filter("smoke/spmv-*");
        assert!(!smoke.is_empty());
        for placement in PlacementPolicy::ALL {
            for claim in ClaimPolicy::ALL {
                let runs = run_corpus(
                    &smoke,
                    RunOptions {
                        placement,
                        claim,
                        ..RunOptions::default()
                    },
                );
                for run in &runs {
                    let m = run.outcome.as_ref().unwrap_or_else(|e| {
                        panic!(
                            "{} failed under {}/{}: {e}",
                            run.scenario,
                            placement.name(),
                            claim.name()
                        )
                    });
                    assert!(
                        m.validated,
                        "{} not validated under {}/{}",
                        run.scenario,
                        placement.name(),
                        claim.name()
                    );
                    let line = run.json_line();
                    assert!(
                        line.contains(&format!("\"placement\":\"{}\"", placement.name())),
                        "{line}"
                    );
                    assert!(
                        line.contains(&format!("\"claim_policy\":\"{}\"", claim.name())),
                        "{line}"
                    );
                }
            }
        }
    }

    #[test]
    fn effective_shards_picks_largest_divisor() {
        assert_eq!(effective_shards(1, 8), 1);
        assert_eq!(effective_shards(8, 8), 8);
        assert_eq!(effective_shards(3, 8), 2); // 3 does not divide 8
        assert_eq!(effective_shards(8, 6), 6); // capped at the height
        assert_eq!(effective_shards(4, 6), 3);
        assert_eq!(effective_shards(0, 4), 1); // degenerate requests clamp
        assert_eq!(effective_shards(5, 0), 1);
    }

    #[test]
    fn sharded_corpus_run_is_thread_count_invariant() {
        // `threads` is host-side only: a sharded sweep must validate and
        // produce identical metrics at 1 and 4 worker threads.
        let corpus = Corpus::builtin();
        let smoke = corpus.filter("smoke/*");
        let opts = |threads| RunOptions {
            shards: 2,
            threads,
            ..RunOptions::default()
        };
        let serial = run_corpus(&smoke, opts(1));
        let threaded = run_corpus(&smoke, opts(4));
        for (a, b) in serial.iter().zip(&threaded) {
            assert_eq!(a.scenario, b.scenario);
            assert!(a.shards >= 2, "{}: shards {}", a.scenario, a.shards);
            let (ma, mb) = (a.outcome.as_ref().unwrap(), b.outcome.as_ref().unwrap());
            assert!(ma.validated && mb.validated, "{}", a.scenario);
            assert_eq!(ma.cycles, mb.cycles, "{}", a.scenario);
            assert_eq!(ma.link_flits_total, mb.link_flits_total, "{}", a.scenario);
            assert_eq!(ma.peak_link_demand, mb.peak_link_demand, "{}", a.scenario);
            assert_eq!(a.json_line(), b.json_line(), "{}", a.scenario);
        }
    }

    #[test]
    fn run_corpus_results_follow_input_order_and_seed() {
        let corpus = Corpus::builtin();
        let smoke = corpus.filter("smoke/*");
        let a = run_corpus(&smoke, RunOptions::default());
        let b = run_corpus(&smoke, RunOptions::default());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.scenario, y.scenario);
            assert_eq!(x.fingerprint, y.fingerprint);
            assert_eq!(
                x.outcome.as_ref().unwrap().cycles,
                y.outcome.as_ref().unwrap().cycles,
                "{} must be reproducible",
                x.scenario
            );
        }
        let c = run_corpus(
            &smoke,
            RunOptions {
                seed: 99,
                ..RunOptions::default()
            },
        );
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.fingerprint != y.fingerprint),
            "different seed must change at least one tensor"
        );
    }
}
