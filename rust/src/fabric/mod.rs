//! The cycle-accurate Nexus Machine fabric simulator — the paper's
//! contribution (§3): Data-Driven execution of Active Messages over a mesh
//! of PEs, with In-Network (en-route, opportunistic) computing on idle ALUs.
//!
//! One [`NexusFabric::step`] models one clock cycle in four phases, each
//! visiting only the components on its *wake-list* (see below):
//!
//! 1. **PE phase** — each awake PE processes at most one message locally
//!    (ALU op on its compute unit, or a memory op on its decode unit),
//!    advances its streaming decode by one emission, and injects one AM into
//!    its router (dynamic AMs first, else the next static AM — §3.3.1).
//! 2. **En-route phase** (Nexus only) — a PE whose ALU went unused this
//!    cycle scans its router's input buffers for a head flit whose opcode is
//!    ALU-class with both operands resolved, executes it *in place*, and
//!    morphs the message (§3.1.3). The flit is locked for the cycle (one
//!    ALU latency) and continues toward its destination next cycle. Only
//!    routers holding flits are scanned.
//! 3. **Route phase** — per occupied router: west-first turn-model route
//!    computation with congestion-aware adaptive choice (or XY / Valiant),
//!    separable allocation with rotating priority, and crossbar traversal
//!    into neighbor staging registers or the local PE's inbox.
//! 4. **Commit** — staged flits land in buffers; On/Off hysteresis updates
//!    (§3.3.2: T_off = 1, T_on = 2); busy-cycle statistics latch; components
//!    with no remaining work leave the wake-lists.
//!
//! ## Active-set scheduling
//!
//! The paper's premise is that irregular workloads keep most PEs idle most
//! cycles — so simulating every PE every cycle wastes almost all of the
//! host's work on no-ops. The fabric therefore keeps two
//! [`active::WakeList`]s (PEs and routers): a component enters on an
//! activation event — a flit staged into its buffers, an AXI static-AM
//! refill, a stream emission or dispatch, a trigger-timer cooldown, an
//! en-route claim — and leaves at commit when it has no pending work.
//! Phases iterate the wake-lists in the same rotated service order the
//! dense scan uses, which (together with commit-time hysteresis) makes the
//! two schedules **bit-identical**: same outputs, same cycle counts, same
//! [`FabricStats`], same PRNG draws. The original dense scan survives as
//! [`StepMode::DenseOracle`] — selectable per [`ArchConfig`] — and
//! `rust/tests/step_equivalence.rs` property-checks the equivalence across
//! random meshes, policies, buffer depths, and workload densities.
//! [`NexusFabric::check_conservation`] additionally asserts the wake-list
//! invariants (no awake-but-idle leaks, no asleep-but-pending components).
//!
//! The same fabric executes the TIA and TIA-Valiant baselines by flag:
//! [`ExecPolicy::DestinationOnly`] disables phase 2, `trigger_latency`
//! charges the triggered-instruction scheduler cost, and
//! [`RoutingPolicy::Valiant`] adds randomized intermediate destinations.
//!
//! Off-chip traffic is modeled with a byte-credit AXI model (§3.3.3): data
//! memories load before a tile executes (counted as `load_cycles`), while
//! AM queues stream *during* execution, hiding their latency.

pub mod active;
pub mod stats;

use crate::am::Message;
use crate::compiler::Program;
use crate::config::{ArchConfig, ExecPolicy, RoutingPolicy, StepMode, TopologyKind};
use crate::isa::{alu_eval, ConfigEntry, Opcode};
use crate::noc::router::{port_class, Router, MAX_PORTS, PORT_LOCAL};
use crate::noc::routing::Dir;
use crate::noc::topology::{build_topology, link_index, Topology, LINKS_PER_PE};
use crate::pe::{ActiveStream, Pe, StreamMode, OUTQ_CAP};
use crate::util::SplitMix64;
use active::WakeList;
use stats::FabricStats;
use std::collections::VecDeque;

/// Simulation failure: the fabric did not drain within `max_cycles`.
#[derive(Debug, Clone)]
pub struct DeadlockError {
    pub cycle: u64,
    pub in_flight: usize,
    /// Which components still hold work, one entry per non-idle PE/router —
    /// e.g. `"PE5 inbox=1 outq=2"` or `"R9 occ=3"`. Never empty for a real
    /// timeout: something must be holding the messages that did not drain.
    pub culprits: Vec<String>,
    /// Full forensic dump: conservation counters, per-PE queue occupancy,
    /// and per-port head-flit routing state (what each stuck head wants and
    /// what its downstream advertises).
    pub detail: String,
}

impl std::fmt::Display for DeadlockError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fabric did not drain by cycle {} ({} messages in flight; {} culprit components: {}): {}",
            self.cycle,
            self.in_flight,
            self.culprits.len(),
            self.culprits.join(", "),
            self.detail
        )
    }
}

impl std::error::Error for DeadlockError {}

/// The Nexus Machine fabric: a `width x height` array of PEs + routers,
/// connected by the [`Topology`] selected in the config (mesh by default).
pub struct NexusFabric {
    pub cfg: ArchConfig,
    pes: Vec<Pe>,
    routers: Vec<Router>,
    /// Replicated configuration memory (identical across PEs, §3.3.1).
    config_mem: Vec<ConfigEntry>,
    /// Off-chip reservoir of static AMs per PE, streamed into the on-chip
    /// `am_window` at AXI bandwidth during execution.
    pending_static: Vec<VecDeque<Message>>,
    /// Fractional AXI byte credit accumulated per cycle.
    axi_credit: f64,
    /// Round-robin pointer for AXI refill fairness.
    axi_rr: usize,
    /// Static AMs still waiting off-chip (refill fast-path counter).
    pending_remaining: usize,
    /// The link structure (route computation + geometry).
    topo: Box<dyn Topology>,
    /// Precomputed neighbor table: `nbr_tab[id][port]` is the PE reached by
    /// leaving `id` through that output port, `u16::MAX` when unwired
    /// (route-phase hot path; PE ids fit in u16 — the config caps at 256).
    nbr_tab: Vec<[u16; MAX_PORTS]>,
    /// Precomputed per-link traversal latencies (1 except chiplet-boundary
    /// hops).
    lat_tab: Vec<[u8; MAX_PORTS]>,
    /// Ports wired per router (5 for the mesh family, 9 for ruche).
    nports: usize,
    /// Torus bubble flow control active (see [`Topology::requires_bubble`]).
    torus_bubble: bool,
    /// Link traversals in the current cycle (peak-demand accumulator).
    link_demand: u64,
    rng: SplitMix64,
    /// Global cycle counter (includes inter-tile load cycles).
    cycle: u64,
    next_msg_id: u64,
    /// PEs with pending work (see [`Pe::has_pending_work`]). Maintained in
    /// both step modes; consulted by the scheduler only in `ActiveSet`.
    awake_pes: WakeList,
    /// Routers holding at least one flit (buffered or staged).
    awake_routers: WakeList,
    /// Per-cycle iteration scratch (reused to keep `step()` allocation-free).
    scratch_pes: Vec<usize>,
    scratch_routers: Vec<usize>,
    pub stats: FabricStats,
}

impl NexusFabric {
    pub fn new(cfg: ArchConfig) -> Self {
        cfg.validate().expect("invalid ArchConfig");
        let n = cfg.num_pes();
        let topo = build_topology(&cfg);
        let nports = topo.num_ports();
        let mut nbr_tab = vec![[u16::MAX; MAX_PORTS]; n];
        let mut lat_tab = vec![[1u8; MAX_PORTS]; n];
        for (id, (nbrs, lats)) in nbr_tab.iter_mut().zip(lat_tab.iter_mut()).enumerate() {
            for port in 1..nports {
                let dir = Dir::from_port(port);
                if let Some(to) = topo.neighbor(id, dir) {
                    nbrs[port] = to as u16;
                    lats[port] = topo.hop_latency(id, dir) as u8;
                }
            }
        }
        let torus_bubble = topo.requires_bubble();
        let mut stats = FabricStats::default();
        stats.per_pe_busy_cycles = vec![0; n];
        stats.per_pe_committed_ops = vec![0; n];
        stats.link_flits = vec![0; n * LINKS_PER_PE];
        NexusFabric {
            pes: (0..n).map(|_| Pe::new(cfg.dmem_words)).collect(),
            routers: (0..n)
                .map(|_| Router::new(nports, cfg.router_buf_depth, cfg.t_off, cfg.t_on))
                .collect(),
            config_mem: Vec::new(),
            pending_static: vec![VecDeque::new(); n],
            axi_credit: 0.0,
            axi_rr: 0,
            pending_remaining: 0,
            topo,
            nbr_tab,
            lat_tab,
            nports,
            torus_bubble,
            link_demand: 0,
            rng: SplitMix64::new(cfg.seed),
            cycle: 0,
            next_msg_id: 1,
            awake_pes: WakeList::new(n),
            awake_routers: WakeList::new(n),
            scratch_pes: Vec::with_capacity(n),
            scratch_routers: Vec::with_capacity(n),
            stats,
            cfg,
        }
    }

    /// Total cycles elapsed (all tiles, including load phases).
    pub fn cycles(&self) -> u64 {
        self.cycle
    }

    /// Reset the fabric to its just-constructed state, reusing allocations,
    /// so one instance can execute many programs back to back. A reset
    /// fabric behaves bit-identically to a freshly constructed one: the
    /// cycle counter, message ids, AXI round-robin pointer, RNG, and all
    /// statistics return to their initial values (per-tile PE/router state
    /// is rebuilt by `load_tile` anyway). [`crate::machine::Machine`] calls
    /// this before every execution instead of building a new fabric.
    pub fn reset(&mut self) {
        self.cycle = 0;
        self.next_msg_id = 1;
        self.rng = SplitMix64::new(self.cfg.seed);
        self.axi_credit = 0.0;
        self.axi_rr = 0;
        self.pending_remaining = 0;
        for q in &mut self.pending_static {
            q.clear();
        }
        self.awake_pes.clear();
        self.awake_routers.clear();
        self.config_mem.clear();
        self.link_demand = 0;
        // Reset every counter but keep the per-PE/per-link vector allocations.
        let mut per_pe = std::mem::take(&mut self.stats.per_pe_busy_cycles);
        per_pe.fill(0);
        let mut per_pe_ops = std::mem::take(&mut self.stats.per_pe_committed_ops);
        per_pe_ops.fill(0);
        let mut link_flits = std::mem::take(&mut self.stats.link_flits);
        link_flits.fill(0);
        self.stats = FabricStats {
            per_pe_busy_cycles: per_pe,
            per_pe_committed_ops: per_pe_ops,
            link_flits,
            ..FabricStats::default()
        };
    }

    /// Run one tile: load its images (charging AXI load cycles), execute to
    /// drain + idle-tree latency, write back outputs. Returns the output
    /// tensor in the program's logical order.
    pub fn run_program(&mut self, prog: &Program) -> Result<Vec<i16>, DeadlockError> {
        self.begin_program(prog);
        self.execute()?;
        // Writeback: outputs stream off-chip at AXI bandwidth (Fig 16's
        // "increased output movement" term).
        let wb = prog.writeback_bytes();
        let wb_cycles = (wb as f64 / self.cfg.axi_bytes_per_cycle).ceil() as u64;
        self.cycle += wb_cycles;
        self.stats.load_cycles += wb_cycles;
        self.stats.offchip_bytes += wb;
        self.collect_tile_stats();
        Ok(prog
            .outputs
            .iter()
            .map(|&(pe, addr)| self.pes[pe].dmem[addr as usize] as i16)
            .collect())
    }

    /// Validate and load a program's images *without* running it — the
    /// manual-stepping entry point used by lockstep differential tests and
    /// debugging harnesses: call [`NexusFabric::step`] to advance one cycle,
    /// [`NexusFabric::is_drained`] to detect completion, and
    /// [`NexusFabric::state_digest`] to compare two fabrics cycle by cycle.
    /// [`NexusFabric::run_program`] remains the normal path (it adds the
    /// idle-tree drain loop and the off-chip writeback accounting).
    pub fn begin_program(&mut self, prog: &Program) {
        prog.validate(&self.cfg).expect("program/arch mismatch");
        self.load_tile(prog);
    }

    /// Reset all per-tile state and load a program's images.
    fn load_tile(&mut self, prog: &Program) {
        let n = self.cfg.num_pes();
        self.config_mem = prog.config.clone();
        let mut data_bytes = 0u64;
        for id in 0..n {
            let mut pe = Pe::new(self.cfg.dmem_words);
            let img = &prog.pes[id];
            for &(addr, val) in &img.dmem_init {
                pe.dmem[addr as usize] = val;
            }
            pe.stream_mem = img.stream_elems.clone();
            pe.trigger = vec![None; self.cfg.dmem_words];
            for &(addr, base, count) in &img.triggers {
                pe.trigger[addr as usize] = Some((base, count));
            }
            data_bytes += img.dmem_init.len() as u64 * 2
                + img.stream_elems.len() as u64 * crate::pe::STREAM_ELEM_WORDS as u64 * 2;
            self.pending_static[id] = img.static_ams.iter().copied().collect();
            // Preload the on-chip AM-queue window (its fill overlaps the
            // data-memory load; §3.3.3 hides AM streaming behind execution).
            let preload = self.cfg.am_queue_entries.min(self.pending_static[id].len());
            for _ in 0..preload {
                let m = self.pending_static[id].pop_front().unwrap();
                pe.am_window.push_back(m);
                self.stats.offchip_bytes += crate::am::packed::AM_BYTES as u64;
            }
            self.pes[id] = pe;
            self.routers[id] =
                Router::new(self.nports, self.cfg.router_buf_depth, self.cfg.t_off, self.cfg.t_on);
        }
        // Data memories load *before* execution (§3.3.3: "data loading into
        // data memories occurs after each tile execution is complete").
        let load_cycles = (data_bytes as f64 / self.cfg.axi_bytes_per_cycle).ceil() as u64;
        self.cycle += load_cycles;
        self.stats.load_cycles += load_cycles;
        self.stats.offchip_bytes += data_bytes;
        self.axi_credit = 0.0;
        self.pending_remaining = self.pending_static.iter().map(|q| q.len()).sum();
        // Initial wake-lists: routers start empty; a PE starts awake iff its
        // on-chip AM window was preloaded (everything else activates later —
        // AXI refills, message deliveries, stream triggers).
        self.awake_pes.clear();
        self.awake_routers.clear();
        for id in 0..n {
            if self.pes[id].has_pending_work() {
                self.awake_pes.wake(id);
            }
        }
    }

    /// Cycle loop until the global idle detector fires.
    fn execute(&mut self) -> Result<(), DeadlockError> {
        let start = self.cycle;
        let mut idle_streak = 0u64;
        loop {
            self.step();
            if self.is_drained() {
                idle_streak += 1;
                if idle_streak > self.cfg.idle_tree_latency {
                    return Ok(());
                }
            } else {
                idle_streak = 0;
            }
            if self.cycle - start > self.cfg.max_cycles {
                return Err(self.deadlock_report());
            }
        }
    }

    /// Detailed diagnostics for a timeout (used in the DeadlockError).
    fn deadlock_report(&self) -> DeadlockError {
        let in_flight: usize = self.pes.iter().map(|p| p.held_messages()).sum::<usize>()
            + self.routers.iter().map(|r| r.occupancy()).sum::<usize>();
        let mut detail = format!(
            "created {} retired {}; ",
            self.stats.msgs_created, self.stats.msgs_retired
        );
        // One culprit entry per component still holding work, naming exactly
        // which queues are non-empty (the error's machine-usable form; the
        // free-text detail below carries the same data plus head-flit
        // routing forensics).
        let mut culprits = Vec::new();
        for (id, pe) in self.pes.iter().enumerate() {
            let mut parts = Vec::new();
            if pe.inbox.is_some() {
                parts.push("inbox=1".to_string());
            }
            if pe.local_redo.is_some() {
                parts.push("redo=1".to_string());
            }
            if !pe.outq.is_empty() {
                parts.push(format!("outq={}", pe.outq.len()));
            }
            if pe.stream.is_some() {
                parts.push("stream=1".to_string());
            }
            if !pe.stream_q.is_empty() {
                parts.push(format!("stream_q={}", pe.stream_q.len()));
            }
            if !pe.am_window.is_empty() {
                parts.push(format!("am_window={}", pe.am_window.len()));
            }
            if !self.pending_static[id].is_empty() {
                parts.push(format!("pending_static={}", self.pending_static[id].len()));
            }
            if !parts.is_empty() {
                culprits.push(format!("PE{id} {}", parts.join(" ")));
            }
            if self.routers[id].occupancy() > 0 {
                culprits.push(format!("R{id} occ={}", self.routers[id].occupancy()));
            }
        }
        // Saturated-link culprits: a receiving input port advertising OFF
        // with flits queued names the directed link feeding it. (Under
        // On/Off flow control buffers hover at one free slot rather than
        // filling completely, so OFF-with-occupancy is the saturation
        // signal, not `free() == 0`.)
        for (id, r) in self.routers.iter().enumerate() {
            for p in 1..r.num_ports() {
                if !r.on_state[p] && !r.inputs[p].is_empty() {
                    let from = self.nbr_tab[id][p];
                    if from != u16::MAX {
                        let dir = Dir::from_port(p).opposite();
                        culprits.push(format!(
                            "link R{from}->R{id} {dir:?} occ={}",
                            r.inputs[p].len()
                        ));
                    }
                }
            }
        }
        for (id, pe) in self.pes.iter().enumerate() {
            if !pe.is_idle() || self.routers[id].occupancy() > 0 {
                detail += &format!(
                    "PE{id}[inbox:{} redo:{} outq:{} stream:{} sq:{} win:{} pend:{} rtr:{}] ",
                    u8::from(pe.inbox.is_some()),
                    u8::from(pe.local_redo.is_some()),
                    pe.outq.len(),
                    u8::from(pe.stream.is_some()),
                    pe.stream_q.len(),
                    pe.am_window.len(),
                    self.pending_static[id].len(),
                    self.routers[id].occupancy(),
                );
            }
        }
        // Per-port head-flit forensics: what does each stuck head want?
        // Topology-aware: enumerate the ports this router actually wires
        // instead of assuming four mesh directions.
        for id in 0..self.cfg.num_pes() {
            for p in 0..self.routers[id].num_ports() {
                let Some(m) = self.routers[id].inputs[p].head_msg() else {
                    continue;
                };
                let tgt = m.route_target();
                let acc: Vec<String> = (1..self.nports)
                    .filter_map(|port| {
                        let nbr = self.nbr_tab[id][port];
                        if nbr == u16::MAX {
                            return None;
                        }
                        let d = Dir::from_port(port);
                        Some(format!(
                            "{d:?}:{}{}",
                            u8::from(self.routers[nbr as usize].on_state[d.opposite_port()]),
                            self.routers[nbr as usize].inputs[d.opposite_port()].free()
                        ))
                    })
                    .collect();
                detail += &format!(
                    "\nR{id}.p{p} head op={:?} dests={:?}/{} vh={:?} tgt={tgt:?} nbrs[ON+free]={:?}",
                    m.opcode, &m.dests[..m.ndests as usize], m.ndests, m.valiant_hop, acc
                );
            }
        }
        DeadlockError {
            cycle: self.cycle,
            in_flight,
            culprits,
            detail,
        }
    }

    /// Global idle condition (§3.1.4): all PEs inactive, no messages in
    /// transit, no static AMs left to stream.
    ///
    /// In `ActiveSet` mode this is O(active): only wake-list members can
    /// hold work (every sleeping component is empty by the commit-time sleep
    /// invariant, which [`NexusFabric::check_wake_consistency`] verifies),
    /// and off-chip static AMs are tracked by the `pending_remaining`
    /// counter. `DenseOracle` keeps the full O(PEs) scan as the reference.
    pub fn is_drained(&self) -> bool {
        match self.cfg.step_mode {
            StepMode::DenseOracle => {
                self.pending_static.iter().all(|q| q.is_empty())
                    && self.pes.iter().all(|p| p.is_idle())
                    && self.routers.iter().all(|r| r.occupancy() == 0)
            }
            StepMode::ActiveSet => {
                // Awake routers always hold flits; an awake PE may be merely
                // cooling down its trigger timer, which `is_idle` (and the
                // dense scan) ignores.
                self.pending_remaining == 0
                    && self.awake_routers.is_empty()
                    && self.awake_pes.iter().all(|id| self.pes[id].is_idle())
            }
        }
    }

    /// One clock cycle. Dispatches on [`StepMode`]; both schedules are
    /// bit-identical (see the module docs and `tests/step_equivalence.rs`).
    pub fn step(&mut self) {
        self.link_demand = 0;
        self.axi_refill();
        match self.cfg.step_mode {
            StepMode::DenseOracle => self.step_dense(),
            StepMode::ActiveSet => self.step_active(),
        }
        self.stats.peak_link_demand = self.stats.peak_link_demand.max(self.link_demand);
        self.cycle += 1;
    }

    /// The dense oracle: every phase scans all `width × height` components.
    fn step_dense(&mut self) {
        let n = self.cfg.num_pes();
        // Rotate the PE service order each cycle so no PE gets systematic
        // priority from simulation artifacts.
        let start = (self.cycle as usize) % n;
        for k in 0..n {
            self.pe_phase((start + k) % n);
        }
        if self.cfg.exec == ExecPolicy::EnRoute {
            for k in 0..n {
                self.enroute_phase((start + k) % n);
            }
        }
        for k in 0..n {
            self.route_phase((start + k) % n);
        }
        for id in 0..n {
            self.commit_router(id);
            self.commit_pe(id);
        }
    }

    /// Event-driven scheduling: phases visit wake-list members only, in the
    /// same rotated service order the dense scan uses. Bit-identity holds
    /// because every skipped component is one on which the corresponding
    /// dense phase is a no-op: `pe_phase` does nothing without pending work,
    /// and the en-route/route phases do nothing on empty routers.
    fn step_active(&mut self) {
        let n = self.cfg.num_pes();
        let start = (self.cycle as usize) % n;
        // Snapshot the awake PEs: wakes during the cycle (inbox deliveries,
        // en-route claims) take effect in the commit pass below, matching
        // the dense scan, where a PE's phase has already run by the time a
        // later phase hands it new work.
        let mut pe_order = std::mem::take(&mut self.scratch_pes);
        pe_order.clear();
        self.awake_pes.rotated_into(start, &mut pe_order);
        for &id in &pe_order {
            self.pe_phase(id);
        }
        // Snapshot the awake routers once for both network phases: the set
        // of routers with *buffered* flits cannot grow mid-cycle (injections
        // and crossbar traversals only stage; staged flits land at commit),
        // so a router staged-into this cycle no-ops both phases — exactly
        // like the dense scan's empty-input fast path.
        let mut router_order = std::mem::take(&mut self.scratch_routers);
        router_order.clear();
        self.awake_routers.rotated_into(start, &mut router_order);
        if self.cfg.exec == ExecPolicy::EnRoute {
            for &id in &router_order {
                self.enroute_phase(id);
            }
        }
        for &id in &router_order {
            self.route_phase(id);
        }
        // Commit runs over the *current* wake-lists — including components
        // woken this cycle (their staged flits must land, their busy flags
        // must latch into stats) — and retires anything left with no work.
        router_order.clear();
        self.awake_routers.snapshot_into(&mut router_order);
        for &id in &router_order {
            self.commit_router(id);
        }
        pe_order.clear();
        self.awake_pes.snapshot_into(&mut pe_order);
        for &id in &pe_order {
            self.commit_pe(id);
        }
        self.scratch_pes = pe_order;
        self.scratch_routers = router_order;
    }

    /// Commit one router and update its wake-list residency.
    #[inline]
    fn commit_router(&mut self, id: usize) {
        self.routers[id].commit();
        if self.routers[id].occupancy() == 0 {
            self.awake_routers.sleep(id);
        }
    }

    /// Latch one PE's busy flags into its statistics, clear them for the
    /// next cycle, and update its wake-list residency.
    #[inline]
    fn commit_pe(&mut self, id: usize) {
        {
            let pe = &mut self.pes[id];
            if pe.alu_busy {
                pe.stats.alu_busy_cycles += 1;
            }
            if pe.alu_busy || pe.decode_busy {
                pe.stats.busy_cycles += 1;
            }
            pe.alu_busy = false;
            pe.decode_busy = false;
        }
        if !self.pes[id].has_pending_work() {
            self.awake_pes.sleep(id);
        }
    }

    /// Wake a PE on an activation event (message delivery, AXI refill,
    /// stream/dispatch handoff, en-route claim).
    #[inline]
    fn wake_pe(&mut self, id: usize) {
        self.awake_pes.wake(id);
    }

    /// Wake a router when a flit is staged into it.
    #[inline]
    fn wake_router(&mut self, id: usize) {
        self.awake_routers.wake(id);
    }

    // --- phase 1: PE-local work -------------------------------------------

    fn pe_phase(&mut self, id: usize) {
        // Fast path: fully idle PE — only reachable from the dense oracle;
        // the active-set scheduler never visits sleeping PEs. Busy flags are
        // always clear here: `commit_pe` latched and cleared them at the end
        // of the previous cycle (so an en-route claim never lingers).
        if !self.pes[id].has_pending_work() {
            return;
        }
        // Pick at most one message: the decode/ALU handoff (local_redo) has
        // priority; otherwise the inbox, gated by the TIA trigger scheduler.
        let msg = {
            let pe = &mut self.pes[id];
            if let Some(m) = pe.local_redo.take() {
                Some(m)
            } else if pe.trigger_wait > 0 {
                pe.trigger_wait -= 1;
                None
            } else if let Some(m) = pe.inbox.take() {
                if self.cfg.trigger_latency > 0 {
                    // Triggered-instruction tag match + priority encode: the
                    // scheduler is busy for trigger_latency further cycles.
                    pe.trigger_wait = self.cfg.trigger_latency;
                    self.stats.trigger_checks += 1;
                }
                Some(m)
            } else {
                None
            }
        };
        if let Some(m) = msg {
            self.process_at(id, m);
        }
        self.stream_phase(id);
        self.inject_phase(id);
    }

    /// Execute a message's current opcode at PE `id` (local work).
    fn process_at(&mut self, id: usize, mut m: Message) {
        let op = m.opcode;
        if op == Opcode::Halt {
            self.retire(m);
            return;
        }
        if op.is_alu() {
            debug_assert!(
                !m.op1_is_addr && !m.op2_is_addr,
                "ALU op with unresolved operand at PE{id}: {m:?}"
            );
            let v = alu_eval(op, m.op1, m.op2);
            let entry = self.config_entry(m.n_pc);
            m.morph(v, &entry);
            self.pes[id].alu_busy = true;
            self.stats.alu_ops += 1;
            self.stats.config_reads += 1;
            self.dispatch(id, m);
        } else {
            self.exec_memory(id, m);
        }
    }

    #[inline]
    fn config_entry(&self, n_pc: u8) -> ConfigEntry {
        *self
            .config_mem
            .get(n_pc as usize)
            .unwrap_or(&ConfigEntry::HALT)
    }

    /// Execute a memory-class opcode on PE `id`'s decode unit (§3.3.1).
    fn exec_memory(&mut self, id: usize, mut m: Message) {
        debug_assert_eq!(
            m.head_dest(),
            Some(id as u8),
            "memory op {:?} at non-owner PE{id}",
            m.opcode
        );
        self.stats.mem_ops += 1;
        self.pes[id].stats.mem_ops += 1;
        self.pes[id].decode_busy = true;
        match m.opcode {
            Opcode::Load => {
                m.op2 = self.pes[id].dmem[m.op2 as usize];
                self.pes[id].stats.dmem_reads += 1;
                self.stats.dmem_reads += 1;
                m.rotate_dests();
                let e = self.config_entry(m.n_pc);
                m.advance(&e);
                self.stats.config_reads += 1;
                self.dispatch(id, m);
            }
            Opcode::LoadOp1 => {
                m.op1 = self.pes[id].dmem[m.op1 as usize];
                self.pes[id].stats.dmem_reads += 1;
                self.stats.dmem_reads += 1;
                m.rotate_dests();
                let e = self.config_entry(m.n_pc);
                m.advance(&e);
                self.stats.config_reads += 1;
                self.dispatch(id, m);
            }
            Opcode::Store => {
                self.pes[id].dmem[m.result as usize] = m.op1;
                self.pes[id].stats.dmem_writes += 1;
                self.stats.dmem_writes += 1;
                self.retire(m);
            }
            Opcode::Accum => {
                let a = m.result as usize;
                let cur = self.pes[id].dmem[a];
                self.pes[id].dmem[a] = (cur as i16).wrapping_add(m.op1 as i16) as u16;
                self.pes[id].stats.dmem_reads += 1;
                self.pes[id].stats.dmem_writes += 1;
                self.stats.dmem_reads += 1;
                self.stats.dmem_writes += 1;
                self.retire(m);
            }
            Opcode::AccMin => {
                let a = m.result as usize;
                let cur = self.pes[id].dmem[a] as i16;
                self.pes[id].stats.dmem_reads += 1;
                self.stats.dmem_reads += 1;
                if (m.op1 as i16) < cur {
                    self.pes[id].dmem[a] = m.op1;
                    self.pes[id].stats.dmem_writes += 1;
                    self.stats.dmem_writes += 1;
                    // Conditional re-emission (§3.1: BFS/SSSP relaxation).
                    if let Some((base, count)) = self.pes[id].trigger[a] {
                        let mut t = m;
                        t.rotate_dests();
                        let e = self.config_entry(t.n_pc);
                        t.advance(&e);
                        self.stats.config_reads += 1;
                        self.queue_stream(id, base, count, t);
                    }
                }
                // The message itself always dies; only the stream (if
                // triggered) carries the update onward. Failed relaxations
                // are the paper's "AMs terminate early" case.
                self.retire(m);
            }
            Opcode::Stream => {
                let key = m.op2 as usize;
                let desc = self.pes[id].trigger[key];
                debug_assert!(desc.is_some(), "Stream op with no trigger at PE{id}[{key}]");
                if let Some((base, count)) = desc {
                    m.rotate_dests();
                    let e = self.config_entry(m.n_pc);
                    m.advance(&e);
                    self.stats.config_reads += 1;
                    self.queue_stream(id, base, count, m);
                }
                // The triggering message is consumed by the stream engine.
                self.stats.msgs_retired += 1;
            }
            _ => unreachable!("non-memory opcode {:?} in exec_memory", m.opcode),
        }
    }

    /// Route a message after its op completed: locally (next op owned by
    /// this PE) or out through the AM NIC.
    fn dispatch(&mut self, id: usize, m: Message) {
        if m.opcode == Opcode::Halt || m.ndests == 0 {
            self.retire(m);
            return;
        }
        let pe = &mut self.pes[id];
        if m.head_dest() == Some(id as u8) && pe.local_redo.is_none() {
            // Next op executes here: skip the network (decode/ALU handoff).
            pe.local_redo = Some(m);
        } else {
            pe.outq.push_back(m);
        }
        self.wake_pe(id);
    }

    fn retire(&mut self, _m: Message) {
        self.stats.msgs_retired += 1;
    }

    /// Install a streaming decode, or queue it if the engine is busy.
    fn queue_stream(&mut self, id: usize, base: u32, count: u16, template: Message) {
        if count == 0 {
            // Empty stream: the AM "terminates early when it does not find
            // corresponding elements" (§5.1).
            return;
        }
        let s = ActiveStream {
            base,
            remaining: count,
            pos: base,
            template,
        };
        let pe = &mut self.pes[id];
        if pe.stream.is_none() {
            pe.stream = Some(s);
        } else {
            pe.stream_q.push_back(s);
        }
        self.wake_pe(id);
    }

    /// Advance the streaming decode by one emission (§3.3.1 streaming mode:
    /// "the message initiates the loading of multiple elements from memory,
    /// generating multiple output AMs").
    fn stream_phase(&mut self, id: usize) {
        if self.pes[id].stream.is_none() {
            let next = self.pes[id].stream_q.pop_front();
            self.pes[id].stream = next;
        }
        if self.pes[id].stream.is_none() || self.pes[id].outq.len() >= OUTQ_CAP {
            return;
        }
        let (elem, template, done) = {
            let pe = &mut self.pes[id];
            let s = pe.stream.as_mut().unwrap();
            let elem = pe.stream_mem[s.pos as usize];
            s.pos += 1;
            s.remaining -= 1;
            let done = s.remaining == 0;
            (elem, s.template, done)
        };
        if done {
            self.pes[id].stream = None;
        }
        let mut m = template;
        m.id = self.next_msg_id;
        self.next_msg_id += 1;
        m.birth = self.cycle;
        m.hops = 0;
        m.executed_enroute = false;
        match elem.mode {
            StreamMode::OffsetResult => {
                // Gustavson: output row base + column index; B value in op2.
                m.result = template.result.wrapping_add(elem.aux);
                m.op2 = elem.value as u16;
            }
            StreamMode::PerDest => {
                // Graph/Conv: element names its own destination + address.
                m.dests = [elem.dest_pe, crate::am::NO_DEST, crate::am::NO_DEST];
                m.ndests = 1;
                m.result = elem.aux;
                m.op2 = elem.value as u16;
            }
            StreamMode::OffsetOp1 => {
                // SDDMM: op1 becomes an address (B-column base + k).
                m.op1 = template.op1.wrapping_add(elem.aux);
                m.op2 = elem.value as u16;
            }
        }
        self.stats.stream_emissions += 1;
        self.stats.scanner_ops += 1;
        self.stats.msgs_created += 1;
        self.stats.dmem_reads += 1; // element record fetch
        self.pes[id].stats.stream_emissions += 1;
        self.pes[id].decode_busy = true;
        self.dispatch(id, m);
    }

    /// AM NIC injection (§3.3.1): dynamic AMs first; otherwise the next
    /// static AM from the queue window, gated by router backpressure
    /// (bubble rule: injection keeps one buffer slot free).
    fn inject_phase(&mut self, id: usize) {
        if !self.routers[id].can_inject() {
            return;
        }
        let m = if let Some(m) = self.pes[id].outq.pop_front() {
            Some(m)
        } else if let Some(mut m) = self.pes[id].am_window.pop_front() {
            m.id = self.next_msg_id;
            self.next_msg_id += 1;
            m.birth = self.cycle;
            self.stats.static_injections += 1;
            self.stats.msgs_created += 1;
            self.pes[id].stats.static_injected += 1;
            Some(m)
        } else {
            None
        };
        let Some(mut m) = m else { return };
        if self.cfg.routing == RoutingPolicy::Valiant && m.valiant_hop.is_none() {
            if self.cfg.topology == TopologyKind::Torus2D {
                // Torus Valiant: classic uniformly random intermediate node
                // (VAL [32]); both legs follow shortest-wrap DOR and the
                // bubble flow control keeps each ring deadlock-free, so no
                // rectangle constraint is needed or meaningful on a torus.
                if let Some(dst) = m.head_dest() {
                    let hop = self.rng.below_usize(self.cfg.num_pes()) as u8;
                    if hop != dst && hop as usize != id {
                        m.valiant_hop = Some(hop);
                    }
                }
            }
            // Randomized *minimal-path* load balancing (ROMM [33], the
            // scheme the paper's TIA-Valiant cites): the intermediate hop
            // is drawn inside the minimal rectangle between source and
            // destination, constrained so the composite (src -> hop -> dst)
            // path is monotone in both dimensions AND a legal west-first
            // path — no U-turns, no {N,S}->W turns — which keeps the
            // two-phase route deadlock-free without virtual channels.
            // (Ruche and chiplet fabrics reuse it unchanged: their
            // candidate sets still shrink the same rectangle.)
            else if let Some(dst) = m.head_dest() {
                let (sx, sy) = self.cfg.pe_xy(id);
                let (dx, dy) = self.cfg.pe_xy(dst as usize);
                let (ylo, yhi) = (sy.min(dy), sy.max(dy));
                let rand_y = yhi - ylo; // exclusive range helper below
                let (hx, hy) = if dx >= sx {
                    // Eastbound (or same column): any hop in the rectangle.
                    (
                        sx + self.rng.below_usize(dx - sx + 1),
                        ylo + self.rng.below_usize(rand_y + 1),
                    )
                } else if self.rng.chance(0.5) {
                    // Westbound, X-randomized leg: keep y = sy so phase 1
                    // is pure-W and phase 2 (west-first) does W then Y.
                    (dx + self.rng.below_usize(sx - dx + 1), sy)
                } else {
                    // Westbound, Y-randomized leg: all W moves in phase 1,
                    // phase 2 is pure Y.
                    (dx, ylo + self.rng.below_usize(rand_y + 1))
                };
                let hop = self.cfg.pe_id(hx, hy) as u8;
                if hop != dst {
                    m.valiant_hop = Some(hop);
                }
            }
        }
        self.routers[id].stage(PORT_LOCAL, m);
        self.wake_router(id);
        self.stats.buf_writes += 1;
    }

    // --- phase 2: en-route (opportunistic) execution ------------------------

    /// In-Network Computing (§3.1.3): a PE whose ALU is idle executes the
    /// head flit of one of its router's input ports, if that flit carries an
    /// ALU-class opcode with both operands resolved to values.
    fn enroute_phase(&mut self, id: usize) {
        if self.pes[id].alu_busy
            || self.routers[id].locked_port.is_some()
            || self.routers[id].inputs.iter().all(|b| b.is_empty())
        {
            return;
        }
        let start = (self.cycle as usize) % self.nports;
        for k in 0..self.nports {
            let p = (start + k) % self.nports;
            let ready = self.routers[id].inputs[p]
                .head_msg()
                .map(|m| m.alu_ready() && m.head_dest() != Some(id as u8))
                .unwrap_or(false);
            if !ready {
                continue;
            }
            let entry_pc = self.routers[id].inputs[p].head_msg().unwrap().n_pc;
            let entry = self.config_entry(entry_pc);
            let m = self.routers[id].inputs[p].head_msg_mut().unwrap();
            let v = alu_eval(m.opcode, m.op1, m.op2);
            m.morph(v, &entry);
            m.executed_enroute = true;
            self.routers[id].locked_port = Some(p);
            self.pes[id].alu_busy = true;
            // The claim must reach this cycle's commit pass (to latch the
            // busy flag into stats and clear it), so the PE joins the
            // wake-list even if it holds no messages of its own.
            self.wake_pe(id);
            self.pes[id].stats.enroute_ops += 1;
            self.stats.alu_ops += 1;
            self.stats.enroute_ops += 1;
            self.stats.config_reads += 1;
            return;
        }
    }

    // --- phase 3: routing ---------------------------------------------------

    fn route_phase(&mut self, id: usize) {
        // Fast path: nothing buffered, nothing to route (the common case on
        // a partially loaded fabric — see EXPERIMENTS.md §Perf).
        if self.routers[id].inputs.iter().all(|b| b.is_empty()) {
            return;
        }
        let nports = self.nports;
        // Clear Valiant hops that reached their intermediate router.
        if self.cfg.routing == RoutingPolicy::Valiant {
            for p in 0..nports {
                if let Some(m) = self.routers[id].inputs[p].head_msg_mut() {
                    if m.valiant_hop == Some(id as u8) {
                        m.valiant_hop = None;
                    }
                }
            }
        }
        // Route computation: desired output direction per input port, asked
        // of the topology (the mesh path delegates to the original
        // west-first/XY functions bit-for-bit).
        let mut want: [Option<Dir>; MAX_PORTS] = [None; MAX_PORTS];
        for p in 0..nports {
            if self.routers[id].locked_port == Some(p) {
                continue; // being executed en-route this cycle
            }
            let Some(m) = self.routers[id].inputs[p].head_msg() else {
                continue;
            };
            let Some(target) = m.route_target() else {
                // No destination left: drop defensively (should not happen).
                debug_assert!(false, "routed message without destination");
                continue;
            };
            let t = target as usize;
            if t == id {
                want[p] = Some(Dir::Local);
                continue;
            }
            let dir = match self.cfg.routing {
                RoutingPolicy::Xy => self.topo.route_deterministic(id, t),
                // Valiant phases ride the same turn rules; with the hop
                // constraint above, the composite path stays legal.
                RoutingPolicy::Valiant | RoutingPolicy::TurnModelAdaptive => {
                    let mut cands = [Dir::Local; 2];
                    let n = self.topo.route_candidates(id, t, &mut cands);
                    debug_assert!(n >= 1);
                    // Congestion-aware adaptive choice: among permitted
                    // turns, prefer a downstream that can accept now, then
                    // the one with more free buffer space.
                    let score = |d: Dir| {
                        let nbr = self.nbr_tab[id][d.port()] as usize;
                        let port = d.opposite_port();
                        let acc = self.routers[nbr].can_accept(port);
                        (acc, self.routers[nbr].effective_free(port))
                    };
                    if n == 1 {
                        cands[0]
                    } else {
                        let (s0, s1) = (score(cands[0]), score(cands[1]));
                        if s1 > s0 {
                            cands[1]
                        } else {
                            cands[0]
                        }
                    }
                }
            };
            want[p] = Some(dir);
        }
        // Separable allocation: each output port arbitrates among requesting
        // input ports with a rotating priority pointer (Fig 8d). A request
        // mask skips output ports nobody asked for.
        let mut requested = [false; MAX_PORTS];
        for w in want.iter().flatten() {
            requested[w.port()] = true;
        }
        let mut moved = [false; MAX_PORTS];
        for out in 0..nports {
            if !requested[out] {
                continue;
            }
            let start = self.routers[id].rr_ptr[out];
            let mut winner = None;
            for k in 0..nports {
                let p = (start + k) % nports;
                if want[p].map(|d| d.port()) == Some(out) {
                    winner = Some(p);
                    break;
                }
            }
            let Some(p) = winner else { continue };
            let dir = want[p].unwrap();
            // Crossbar traversal if downstream accepts. On a torus the
            // bubble rule applies: a flit continuing along the same
            // direction may transit into any non-full buffer (ignoring
            // On/Off), while a flit *entering* a ring (injection or turn)
            // must leave one extra slot free — the classic bubble flow
            // control that keeps each wraparound ring deadlock-free.
            let ok = if out == PORT_LOCAL {
                self.pes[id].inbox.is_none()
            } else {
                let nbr = self.nbr_tab[id][dir.port()] as usize;
                let dport = dir.opposite_port();
                if self.torus_bubble && p == dport {
                    self.routers[nbr].can_transit(dport)
                } else if self.torus_bubble {
                    self.routers[nbr].can_accept(dport)
                        && self.routers[nbr].effective_free(dport) >= 2
                } else {
                    self.routers[nbr].can_accept(dport)
                }
            };
            if !ok {
                continue;
            }
            let mut m = self.routers[id].pop_port(p).unwrap();
            m.hops += 1;
            if out == PORT_LOCAL {
                self.pes[id].inbox = Some(m);
                self.wake_pe(id);
            } else {
                let nbr = self.nbr_tab[id][dir.port()] as usize;
                let dport = dir.opposite_port();
                // Multi-cycle links (chiplet crossings) park the flit in the
                // staging slot for `latency - 1` extra commits, modelling
                // both the added latency and the reduced link bandwidth.
                let lat = self.lat_tab[id][dir.port()];
                if lat > 1 {
                    self.routers[nbr].stage_delayed(dport, m, lat - 1);
                } else {
                    self.routers[nbr].stage(dport, m);
                }
                self.wake_router(nbr);
                self.stats.flit_hops += 1;
                self.stats.buf_writes += 1;
                self.stats.link_flits[link_index(id, dir)] += 1;
                self.link_demand += 1;
            }
            self.routers[id].rr_ptr[out] = (p + 1) % nports;
            moved[p] = true;
        }
        self.routers[id].sample_stats(&moved);
    }

    // --- off-chip AXI model --------------------------------------------------

    /// Stream static AMs from the off-chip reservoir into on-chip AM-queue
    /// windows at AXI bandwidth (round-robin across PEs).
    fn axi_refill(&mut self) {
        if self.pending_remaining == 0 {
            return;
        }
        self.axi_credit += self.cfg.axi_bytes_per_cycle;
        let n = self.cfg.num_pes();
        let am_bytes = crate::am::packed::AM_BYTES as f64;
        let mut scanned = 0;
        while self.axi_credit >= am_bytes && scanned < n {
            let id = self.axi_rr;
            self.axi_rr = (self.axi_rr + 1) % n;
            if self.pending_static[id].is_empty()
                || self.pes[id].am_window.len() >= self.cfg.am_queue_entries
            {
                scanned += 1;
                continue;
            }
            scanned = 0;
            let m = self.pending_static[id].pop_front().unwrap();
            self.pending_remaining -= 1;
            self.pes[id].am_window.push_back(m);
            self.wake_pe(id);
            self.axi_credit -= am_bytes;
            self.stats.offchip_bytes += crate::am::packed::AM_BYTES as u64;
        }
        // Credit does not bank across idle periods beyond one burst.
        self.axi_credit = self.axi_credit.min(self.cfg.axi_bytes_per_cycle * 16.0);
    }

    // --- stats ----------------------------------------------------------------

    /// Fold per-PE and per-router counters into the aggregate stats at the
    /// end of a tile (PEs and routers are re-created per tile).
    fn collect_tile_stats(&mut self) {
        self.stats.cycles = self.cycle;
        for (id, pe) in self.pes.iter().enumerate() {
            self.stats.per_pe_busy_cycles[id] += pe.stats.busy_cycles;
            // At most one ALU op (local or en-route claim) and one decode
            // memory op commit per PE per cycle, so busy-cycle counts *are*
            // op counts; summed over PEs this equals alu_ops + mem_ops.
            self.stats.per_pe_committed_ops[id] += pe.stats.alu_busy_cycles + pe.stats.mem_ops;
        }
        for r in &self.routers {
            for p in 0..r.num_ports() {
                // Ruche ports fold onto their mesh direction's class so the
                // Fig-14 per-port breakdown keeps its five columns.
                self.stats.absorb_port(port_class(p), &r.stats[p]);
            }
        }
    }

    /// The topology this fabric was built on (runtime-selected via
    /// [`ArchConfig::topology`]).
    pub fn topology(&self) -> &dyn Topology {
        self.topo.as_ref()
    }

    /// Message conservation at drain: everything created was retired — plus
    /// the wake-list consistency invariants (so every conservation check in
    /// the test-suite also audits the active-set scheduler).
    pub fn check_conservation(&self) -> Result<(), String> {
        if !self.is_drained() {
            return Err("fabric not drained".into());
        }
        if self.stats.msgs_created != self.stats.msgs_retired {
            return Err(format!(
                "conservation violated: created {} != retired {}",
                self.stats.msgs_created, self.stats.msgs_retired
            ));
        }
        self.check_wake_consistency()
    }

    /// Audit the wake-lists against a full dense scan. Valid at any cycle
    /// boundary (between [`NexusFabric::step`] calls), in both step modes
    /// (the lists are maintained identically; only the scheduler differs):
    ///
    /// - **no asleep-but-pending component** — a PE with work or a router
    ///   with flits missing from its wake-list would never be scheduled
    ///   again: a simulator-induced deadlock;
    /// - **no awake-but-idle leak** — a workless component still on a list
    ///   would erode the O(active) bound back toward O(PEs);
    /// - **no stale busy flags** — a sleeping PE's flags must be clear, or
    ///   an en-route claim would be wrongly suppressed and busy-cycle stats
    ///   double-counted.
    pub fn check_wake_consistency(&self) -> Result<(), String> {
        for id in 0..self.cfg.num_pes() {
            let has = self.pes[id].has_pending_work();
            let awake = self.awake_pes.is_awake(id);
            if has && !awake {
                return Err(format!("PE{id} asleep but has pending work (scheduler deadlock)"));
            }
            if awake && !has {
                return Err(format!("PE{id} awake but idle (wake-list leak)"));
            }
            if !awake && (self.pes[id].alu_busy || self.pes[id].decode_busy) {
                return Err(format!("PE{id} asleep with busy flags set"));
            }
            let occ = self.routers[id].occupancy();
            let r_awake = self.awake_routers.is_awake(id);
            if occ > 0 && !r_awake {
                return Err(format!("router {id} asleep holding {occ} flits (scheduler deadlock)"));
            }
            if r_awake && occ == 0 {
                return Err(format!("router {id} awake but empty (wake-list leak)"));
            }
        }
        Ok(())
    }

    /// Number of components currently on the wake-lists, `(PEs, routers)` —
    /// the quantity active-set stepping is O of. Exposed for benches and
    /// scheduler tests; not a statistic (identical workloads produce
    /// identical sequences in both step modes, since the lists are
    /// maintained identically).
    pub fn awake_counts(&self) -> (usize, usize) {
        (self.awake_pes.len(), self.awake_routers.len())
    }

    /// Order-sensitive FNV-1a digest of the complete mutable simulator
    /// state: PE memories/queues/flags, router buffers/staging/hysteresis,
    /// AXI and cycle counters, in-flight message contents. Two fabrics
    /// executing bit-identically produce equal digests at every cycle
    /// boundary — the lockstep divergence probe used by
    /// `tests/step_equivalence.rs` to report the *first diverging cycle* on
    /// an equivalence failure.
    pub fn state_digest(&self) -> u64 {
        #[inline]
        fn mix(h: &mut u64, v: u64) {
            *h = (*h ^ v).wrapping_mul(0x0000_0100_0000_01b3);
        }
        fn mix_msg(h: &mut u64, m: &Message) {
            mix(
                h,
                u64::from_le_bytes([
                    m.dests[0],
                    m.dests[1],
                    m.dests[2],
                    m.ndests,
                    m.n_pc,
                    m.opcode.encode(),
                    u8::from(m.res_is_addr),
                    u8::from(m.op1_is_addr) | (u8::from(m.op2_is_addr) << 1),
                ]),
            );
            mix(h, ((m.result as u64) << 32) | ((m.op1 as u64) << 16) | m.op2 as u64);
            mix(h, m.id);
            mix(h, m.birth);
            mix(
                h,
                ((m.hops as u64) << 16) | m.valiant_hop.map_or(0xFFFF, |v| 0x100 | v as u64),
            );
            mix(h, u64::from(m.executed_enroute));
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        mix(&mut h, self.cycle);
        mix(&mut h, self.next_msg_id);
        mix(&mut h, self.pending_remaining as u64);
        mix(&mut h, self.axi_rr as u64);
        mix(&mut h, self.axi_credit.to_bits());
        mix(&mut h, self.rng.clone().next_u64());
        for (id, pe) in self.pes.iter().enumerate() {
            mix(&mut h, id as u64);
            for &w in &pe.dmem {
                mix(&mut h, w as u64);
            }
            mix(&mut h, pe.trigger_wait);
            mix(&mut h, u64::from(pe.alu_busy) | (u64::from(pe.decode_busy) << 1));
            for m in pe.inbox.iter().chain(pe.local_redo.iter()) {
                mix_msg(&mut h, m);
            }
            for m in pe.outq.iter().chain(pe.am_window.iter()) {
                mix_msg(&mut h, m);
            }
            for s in pe.stream.iter().chain(pe.stream_q.iter()) {
                mix(&mut h, s.base as u64);
                mix(&mut h, s.remaining as u64);
                mix(&mut h, s.pos as u64);
                mix_msg(&mut h, &s.template);
            }
            mix(&mut h, self.pending_static[id].len() as u64);
        }
        for r in &self.routers {
            for p in 0..r.num_ports() {
                mix(&mut h, r.inputs[p].len() as u64);
                for m in r.inputs[p].iter() {
                    mix_msg(&mut h, m);
                }
                if let Some(m) = &r.staging[p] {
                    mix_msg(&mut h, m);
                }
                mix(&mut h, r.staging_wait[p] as u64);
                mix(&mut h, u64::from(r.on_state[p]));
                mix(&mut h, r.rr_ptr[p] as u64);
            }
            mix(&mut h, r.locked_port.map_or(u64::MAX, |p| p as u64));
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::Message;
    use crate::compiler::ProgramBuilder;
    use crate::isa::ConfigEntry;

    fn nexus() -> ArchConfig {
        ArchConfig::nexus()
    }

    /// Smallest possible program: one static AM stores a constant remotely.
    fn store_program(cfg: &ArchConfig, src: usize, dst: usize, val: i16) -> crate::compiler::Program {
        let mut b = ProgramBuilder::new("store1", cfg);
        let addr = b.alloc(dst, 1);
        let mut am = Message::new();
        am.opcode = Opcode::Store;
        am.op1 = val as u16;
        am.result = addr;
        am.res_is_addr = true;
        am.push_dest(dst as u8);
        b.static_am(src, am);
        b.output(dst, addr);
        b.build()
    }

    #[test]
    fn single_store_reaches_remote_pe() {
        let cfg = nexus();
        let mut f = NexusFabric::new(cfg.clone());
        let prog = store_program(&cfg, 0, 15, -7);
        let out = f.run_program(&prog).unwrap();
        assert_eq!(out, vec![-7]);
        f.check_conservation().unwrap();
        assert!(f.stats.cycles > 0);
        assert_eq!(f.stats.mem_ops, 1);
    }

    /// Load + Mul + Accum chain: the Fig 5 SpMV choreography for a single
    /// nonzero, hand-built.
    fn mac_program(cfg: &ArchConfig) -> crate::compiler::Program {
        let mut b = ProgramBuilder::new("mac1", cfg);
        // x[0] = 6 lives on PE 5; y[0] (init 10) lives on PE 10.
        let xa = b.place(5, &[6]);
        let ya = b.place(10, &[10]);
        let pc_mul = b.config(ConfigEntry::new(Opcode::Mul, 0)); // placeholder pc
        let pc_acc = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
        // Fix the chain: Mul's entry must point at the Accum entry.
        // (ProgramBuilder interns by value, so re-add with correct next_pc.)
        assert_eq!(pc_mul, 0);
        assert_eq!(pc_acc, 1);
        let mut am = Message::new();
        am.opcode = Opcode::Load; // op2 <- dmem[op2] at PE 5
        am.n_pc = pc_mul;
        am.op1 = 7; // matrix value
        am.op2 = xa;
        am.op2_is_addr = true;
        am.result = ya;
        am.res_is_addr = true;
        am.push_dest(5);
        am.push_dest(10);
        b.static_am(0, am);
        b.output(10, ya);
        let mut p = b.build();
        // Mul entry chains to Accum entry.
        p.config[0] = ConfigEntry::new(Opcode::Mul, 1);
        p.config[1] = ConfigEntry::new(Opcode::Accum, 1).res_addr();
        p
    }

    #[test]
    fn load_mul_accum_chain_computes_mac() {
        let cfg = nexus();
        let mut f = NexusFabric::new(cfg.clone());
        let prog = mac_program(&cfg);
        let out = f.run_program(&prog).unwrap();
        assert_eq!(out, vec![10 + 7 * 6]);
        f.check_conservation().unwrap();
        assert_eq!(f.stats.alu_ops, 1, "exactly one Mul");
        assert_eq!(f.stats.mem_ops, 2, "Load + Accum");
    }

    #[test]
    fn enroute_execution_happens_on_nexus_not_tia() {
        // Many independent MACs flowing between distant PEs: Nexus should
        // execute a good fraction en-route; TIA none.
        let run = |cfg: ArchConfig| {
            let mut b = ProgramBuilder::new("macs", &cfg);
            let pc_acc;
            {
                let mul = b.config(ConfigEntry::new(Opcode::Mul, 1));
                pc_acc = b.config(ConfigEntry::new(Opcode::Accum, 1).res_addr());
                assert_eq!(mul, 0);
            }
            let _ = pc_acc;
            for i in 0..40u16 {
                let src = (i as usize) % 4; // inject from west column
                let data_pe = 4 + (i as usize) % 8;
                let out_pe = 12 + (i as usize) % 4;
                let xa = b.place(data_pe, &[2]);
                let ya = b.place(out_pe, &[0]);
                let mut am = Message::new();
                am.opcode = Opcode::Load;
                am.n_pc = 0;
                am.op1 = 3;
                am.op2 = xa;
                am.op2_is_addr = true;
                am.result = ya;
                am.res_is_addr = true;
                am.push_dest(data_pe as u8);
                am.push_dest(out_pe as u8);
                b.static_am(src, am);
                b.output(out_pe, ya);
            }
            let mut p = b.build();
            p.config[0] = ConfigEntry::new(Opcode::Mul, 1);
            p.config[1] = ConfigEntry::new(Opcode::Accum, 1).res_addr();
            let mut f = NexusFabric::new(cfg);
            let out = f.run_program(&p).unwrap();
            assert!(out.iter().all(|&v| v == 6), "{out:?}");
            f.check_conservation().unwrap();
            f.stats
        };
        let nexus_stats = run(ArchConfig::nexus());
        let tia_stats = run(ArchConfig::tia());
        assert!(nexus_stats.enroute_ops > 0, "Nexus must compute en-route");
        assert_eq!(tia_stats.enroute_ops, 0, "TIA must not compute en-route");
        assert_eq!(nexus_stats.alu_ops, tia_stats.alu_ops, "same work");
    }

    #[test]
    fn valiant_routes_still_deliver() {
        let cfg = ArchConfig::tia_valiant();
        let mut f = NexusFabric::new(cfg.clone());
        let prog = store_program(&cfg, 3, 12, 99);
        let out = f.run_program(&prog).unwrap();
        assert_eq!(out, vec![99]);
        f.check_conservation().unwrap();
    }

    #[test]
    fn stream_perdest_fans_out() {
        // One Stream trigger fans out adds to 4 different PEs.
        let cfg = nexus();
        let mut b = ProgramBuilder::new("fanout", &cfg);
        let pc_noop = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
        assert_eq!(pc_noop, 0);
        let mut elems = Vec::new();
        let mut outs = Vec::new();
        for k in 0..4u16 {
            let pe = 12 + k as usize;
            // place target word (init 100) on each PE
            let addr = b.place(pe, &[100]);
            outs.push((pe, addr));
            elems.push(crate::pe::StreamElem {
                value: (k as i16 + 1) as u16 as i16,
                aux: addr,
                dest_pe: pe as u8,
                mode: StreamMode::PerDest,
            });
        }
        let base = b.stream(0, &elems);
        let key = b.keyed_trigger(0, base, 4);
        let mut am = Message::new();
        am.opcode = Opcode::Stream;
        am.n_pc = 0; // emitted AMs carry Accum (terminal at dest)
        am.op2 = key;
        am.op2_is_addr = true;
        am.push_dest(0); // stream trigger at PE0 itself
        b.static_am(0, am);
        for &(pe, addr) in &outs {
            b.output(pe, addr);
        }
        let mut p = b.build();
        // Emitted AMs: opcode Accum — but Accum takes op1; stream puts the
        // element value in op2. Use Add->Accum? Simpler: Store op1? For this
        // test make the emitted opcode Add with op1=0 then Accum.
        p.config[0] = ConfigEntry::new(Opcode::Add, 1).res_addr();
        p.config.push(ConfigEntry::new(Opcode::Accum, 1).res_addr());
        let mut f = NexusFabric::new(cfg);
        let out = f.run_program(&p).unwrap();
        // Each target: 100 + (0 + value).
        assert_eq!(out, vec![101, 102, 103, 104]);
        f.check_conservation().unwrap();
        assert_eq!(f.stats.stream_emissions, 4);
    }

    #[test]
    fn accmin_relaxation_triggers_and_settles() {
        // Two-vertex SSSP: dist[a]=0 relaxes dist[b] via an edge of weight 3.
        let cfg = nexus();
        let mut b = ProgramBuilder::new("relax", &cfg);
        let pe_a = 0usize;
        let pe_b = 15usize;
        let da = b.place(pe_a, &[crate::tensor::graph::INF]);
        let db = b.place(pe_b, &[crate::tensor::graph::INF]);
        // Edge a->b, weight 3: stream element at PE a.
        let e = crate::pe::StreamElem {
            value: 3,
            aux: db,
            dest_pe: pe_b as u8,
            mode: StreamMode::PerDest,
        };
        let base = b.stream(pe_a, &[e]);
        b.trigger(pe_a, da, base, 1);
        // Config: emitted AM carries Add (dist + w), then AccMin.
        // Entry 0: Add -> 1 ; entry 1: AccMin (res_addr), next 0 (emitted
        // streams restart at entry 0).
        // Static AM: AccMin dist[a] with op1 = 0.
        let mut am = Message::new();
        am.opcode = Opcode::AccMin;
        am.n_pc = 0;
        am.op1 = 0;
        am.result = da;
        am.res_is_addr = true;
        am.push_dest(pe_a as u8);
        b.static_am(pe_a, am);
        b.output(pe_a, da);
        b.output(pe_b, db);
        let mut p = b.build();
        p.config = vec![
            ConfigEntry::new(Opcode::Add, 1).res_addr(),
            ConfigEntry::new(Opcode::AccMin, 0).res_addr(),
        ];
        let mut f = NexusFabric::new(cfg);
        let out = f.run_program(&p).unwrap();
        assert_eq!(out, vec![0, 3]);
        f.check_conservation().unwrap();
    }

    #[test]
    fn valiant_storm_drains_without_deadlock() {
        // Regression for the two-phase-Valiant deadlock: a storm of
        // random-destination stores on TIA-Valiant must drain. The ROMM
        // hop constraint (minimal rectangle, west-first-legal composite)
        // is what makes this hold with 3-flit buffers and no VCs.
        let mut cfg = ArchConfig::tia_valiant();
        cfg.max_cycles = 200_000;
        let mut b = ProgramBuilder::new("storm", &cfg);
        let mut rng = crate::util::SplitMix64::new(0xF00D);
        let mut targets = Vec::new();
        for i in 0..400u16 {
            let src = rng.below_usize(16);
            let dst = rng.below_usize(16);
            let addr = b.alloc(dst, 1);
            let mut am = Message::new();
            am.opcode = Opcode::Store;
            am.op1 = i;
            am.result = addr;
            am.res_is_addr = true;
            am.push_dest(dst as u8);
            b.static_am(src, am);
            targets.push((dst, addr, i));
        }
        for &(dst, addr, _) in &targets {
            b.output(dst, addr);
        }
        let prog = b.build();
        let mut f = NexusFabric::new(cfg);
        let out = f.run_program(&prog).expect("storm must drain");
        for (k, &(_, _, v)) in targets.iter().enumerate() {
            assert_eq!(out[k], v as i16);
        }
        f.check_conservation().unwrap();
    }

    #[test]
    fn fabric_reports_deadlock_instead_of_hanging() {
        // A config chain that self-loops (MUL whose next entry is itself)
        // produces a message that never becomes terminal: the fabric must
        // report the timeout as an error instead of spinning forever.
        let mut cfg = nexus();
        cfg.max_cycles = 500;
        let mut b = ProgramBuilder::new("livelock", &cfg);
        let pc = b.config(ConfigEntry::new(Opcode::Mul, 0));
        let mut am = Message::new();
        am.opcode = Opcode::Mul;
        am.n_pc = pc;
        am.op1 = 1;
        am.op2 = 1;
        am.push_dest(15);
        b.static_am(0, am);
        let prog = b.build();
        let mut f = NexusFabric::new(cfg);
        let r = f.run_program(&prog);
        assert!(r.is_err(), "expected timeout error");
        let e = r.unwrap_err();
        assert!(e.in_flight >= 1, "stuck message should be reported");
        assert!(
            !e.culprits.is_empty(),
            "a timeout must name the components holding work"
        );
        assert!(
            e.culprits.iter().any(|c| c.starts_with("PE") || c.starts_with('R')),
            "culprits must identify PEs/routers: {:?}",
            e.culprits
        );
    }

    #[test]
    fn reset_fabric_is_bit_identical_to_fresh_in_both_modes() {
        for mode in [StepMode::ActiveSet, StepMode::DenseOracle] {
            let cfg = nexus().with_step_mode(mode);
            let prog = mac_program(&cfg);
            let mut fresh = NexusFabric::new(cfg.clone());
            let out_fresh = fresh.run_program(&prog).unwrap();
            let mut reused = NexusFabric::new(cfg);
            // Dirty the instance with a different program first, then reset.
            let store = store_program(&reused.cfg, 0, 15, -7);
            reused.run_program(&store).unwrap();
            reused.reset();
            let out_reused = reused.run_program(&prog).unwrap();
            assert_eq!(out_fresh, out_reused, "{mode:?}");
            assert_eq!(fresh.stats, reused.stats, "{mode:?}");
            assert_eq!(fresh.state_digest(), reused.state_digest(), "{mode:?}");
        }
    }

    #[test]
    fn dense_oracle_matches_active_set_on_fabric_programs() {
        // The two schedulers must be bit-identical: same outputs, same
        // cycle counts, same stats. (The broad randomized version lives in
        // tests/step_equivalence.rs; this is the in-crate smoke check.)
        let base = nexus();
        for prog in [
            store_program(&base, 0, 15, -7),
            mac_program(&base),
        ] {
            let mut fa = NexusFabric::new(base.clone().with_step_mode(StepMode::ActiveSet));
            let mut fd = NexusFabric::new(base.clone().with_step_mode(StepMode::DenseOracle));
            let oa = fa.run_program(&prog).unwrap();
            let od = fd.run_program(&prog).unwrap();
            assert_eq!(oa, od);
            assert_eq!(fa.cycles(), fd.cycles());
            assert_eq!(fa.stats, fd.stats);
            fa.check_conservation().unwrap();
            fd.check_conservation().unwrap();
        }
    }

    #[test]
    fn lockstep_digests_agree_cycle_by_cycle() {
        // Manual-stepping both schedulers over the same program: the full
        // state digest must match at *every* cycle boundary, and the wake
        // lists must satisfy their invariants throughout.
        let base = nexus();
        let prog = mac_program(&base);
        let mut fa = NexusFabric::new(base.clone().with_step_mode(StepMode::ActiveSet));
        let mut fd = NexusFabric::new(base.with_step_mode(StepMode::DenseOracle));
        fa.begin_program(&prog);
        fd.begin_program(&prog);
        assert_eq!(fa.state_digest(), fd.state_digest(), "post-load");
        for cycle in 0..200 {
            fa.step();
            fd.step();
            assert_eq!(
                fa.state_digest(),
                fd.state_digest(),
                "diverged at cycle {cycle}"
            );
            fa.check_wake_consistency().unwrap();
            fd.check_wake_consistency().unwrap();
            assert_eq!(fa.is_drained(), fd.is_drained(), "cycle {cycle}");
            if fa.is_drained() {
                return;
            }
        }
        panic!("program did not drain within 200 cycles");
    }

    #[test]
    fn sleeping_fabric_steps_are_cheap_and_safe() {
        // After drain the wake-lists empty out; stepping an empty fabric
        // must stay a no-op in both modes (cycle advances, nothing else).
        for mode in [StepMode::ActiveSet, StepMode::DenseOracle] {
            let cfg = nexus().with_step_mode(mode);
            let prog = store_program(&cfg, 0, 15, 3);
            let mut f = NexusFabric::new(cfg);
            f.run_program(&prog).unwrap();
            let (awake_pes, awake_routers) = f.awake_counts();
            assert_eq!((awake_pes, awake_routers), (0, 0), "{mode:?}");
            let before = f.stats.clone();
            let c0 = f.cycles();
            for _ in 0..8 {
                f.step();
            }
            assert_eq!(f.cycles(), c0 + 8);
            assert_eq!(f.stats, before, "{mode:?}: idle steps must not mutate stats");
            f.check_wake_consistency().unwrap();
        }
    }

    #[test]
    fn utilization_and_innetwork_metrics_populate() {
        let cfg = nexus();
        let mut f = NexusFabric::new(cfg.clone());
        let prog = mac_program(&cfg);
        f.run_program(&prog).unwrap();
        assert!(f.stats.utilization() > 0.0);
        assert!(f.stats.cycles >= f.stats.load_cycles);
        assert!(f.stats.offchip_bytes > 0);
    }

    /// Topology-variant config with non-trivial geometry on the 4x4 array:
    /// 2x2 chiplets (so boundary crossings exist) with a 3-cycle crossing.
    fn topo_cfg(kind: crate::config::TopologyKind) -> ArchConfig {
        nexus().with_topology(kind).with_chiplet((2, 2), 3)
    }

    #[test]
    fn every_topology_delivers_and_conserves() {
        use crate::config::TopologyKind;
        for kind in TopologyKind::ALL {
            for mode in [StepMode::ActiveSet, StepMode::DenseOracle] {
                let cfg = topo_cfg(kind).with_step_mode(mode);
                let mut f = NexusFabric::new(cfg.clone());
                let prog = store_program(&cfg, 0, 15, -7);
                let out = f.run_program(&prog).unwrap();
                assert_eq!(out, vec![-7], "{kind:?}/{mode:?}");
                f.check_conservation().unwrap();
                let prog = mac_program(&cfg);
                f.reset();
                let out = f.run_program(&prog).unwrap();
                assert_eq!(out, vec![10 + 7 * 6], "{kind:?}/{mode:?}");
                f.check_conservation().unwrap();
            }
        }
    }

    #[test]
    fn link_flit_counters_sum_to_flit_hops() {
        use crate::config::TopologyKind;
        for kind in TopologyKind::ALL {
            let cfg = topo_cfg(kind);
            let mut f = NexusFabric::new(cfg.clone());
            let prog = mac_program(&cfg);
            f.run_program(&prog).unwrap();
            assert_eq!(
                f.stats.link_flits_total(),
                f.stats.flit_hops,
                "{kind:?}: per-link counters must partition flit_hops"
            );
            assert!(f.stats.flit_hops > 0, "{kind:?}: MAC program crosses links");
            assert!(
                f.stats.peak_link_demand >= 1,
                "{kind:?}: some cycle moved at least one flit"
            );
            // Every counted link must be one the topology actually wires.
            for (idx, &flits) in f.stats.link_flits.iter().enumerate() {
                if flits == 0 {
                    continue;
                }
                let from = idx / crate::noc::LINKS_PER_PE;
                let dir = Dir::from_port(idx % crate::noc::LINKS_PER_PE + 1);
                assert!(
                    f.topology().neighbor(from, dir).is_some(),
                    "{kind:?}: flits counted on unwired link {from}/{dir:?}"
                );
            }
        }
    }

    #[test]
    fn torus_storm_drains_under_bubble_flow_control() {
        // The torus analogue of `valiant_storm_drains_without_deadlock`:
        // wraparound rings deadlock classic credit flow control, so this
        // regression pins the bubble rule (ring continuation may transit,
        // ring entry leaves a free slot).
        let mut cfg = nexus().with_topology(crate::config::TopologyKind::Torus2D);
        cfg.max_cycles = 200_000;
        let mut b = ProgramBuilder::new("torus-storm", &cfg);
        let mut rng = crate::util::SplitMix64::new(0xBEEF);
        let mut targets = Vec::new();
        for i in 0..400u16 {
            let src = rng.below_usize(16);
            let dst = rng.below_usize(16);
            let addr = b.alloc(dst, 1);
            let mut am = Message::new();
            am.opcode = Opcode::Store;
            am.op1 = i;
            am.result = addr;
            am.res_is_addr = true;
            am.push_dest(dst as u8);
            b.static_am(src, am);
            targets.push((dst, addr, i));
        }
        for &(dst, addr, _) in &targets {
            b.output(dst, addr);
        }
        let prog = b.build();
        let mut f = NexusFabric::new(cfg);
        let out = f.run_program(&prog).expect("torus storm must drain");
        for (k, &(_, _, v)) in targets.iter().enumerate() {
            assert_eq!(out[k], v as i16);
        }
        f.check_conservation().unwrap();
    }

    #[test]
    fn deadlock_report_names_saturated_links() {
        // Storm every PE's stores at PE0 with a tiny cycle budget: the
        // hotspot's input ports sit OFF with flits queued, so the timeout
        // report must include `link R<from>->R0 ...` culprits.
        let mut cfg = nexus();
        cfg.max_cycles = 40;
        let mut b = ProgramBuilder::new("hotspot-links", &cfg);
        let addr = b.alloc(0, 1);
        for i in 0..240u16 {
            let src = 1 + (i as usize) % 15;
            let mut am = Message::new();
            am.opcode = Opcode::Store;
            am.op1 = i;
            am.result = addr;
            am.res_is_addr = true;
            am.push_dest(0);
            b.static_am(src, am);
        }
        b.output(0, addr);
        let prog = b.build();
        let mut f = NexusFabric::new(cfg);
        let e = f.run_program(&prog).expect_err("40 cycles cannot drain 240 stores");
        assert!(
            e.culprits.iter().any(|c| c.starts_with("link R")),
            "timeout under congestion must name saturated links: {:?}",
            e.culprits
        );
    }
}
