//! The cycle-accurate Nexus Machine fabric simulator — the paper's
//! contribution (§3): Data-Driven execution of Active Messages over a mesh
//! of PEs, with In-Network (en-route, opportunistic) computing on idle ALUs.
//!
//! One [`NexusFabric::step`] models one clock cycle in four phases, each
//! visiting only the components on its *wake-list* (see below):
//!
//! 1. **PE phase** — each awake PE processes at most one message locally
//!    (ALU op on its compute unit, or a memory op on its decode unit),
//!    advances its streaming decode by one emission, and injects one AM into
//!    its router (dynamic AMs first, else the next static AM — §3.3.1).
//! 2. **En-route phase** (Nexus only) — a PE whose ALU went unused this
//!    cycle scans its router's input buffers for a head flit whose opcode is
//!    ALU-class with both operands resolved, executes it *in place*, and
//!    morphs the message (§3.1.3). The flit is locked for the cycle (one
//!    ALU latency) and continues toward its destination next cycle. Only
//!    routers holding flits are scanned.
//! 3. **Route phase** — per occupied router: west-first turn-model route
//!    computation with congestion-aware adaptive choice (or XY / Valiant),
//!    separable allocation with rotating priority, and crossbar traversal
//!    into neighbor staging registers or the local PE's inbox.
//! 4. **Commit** — staged flits land in buffers; On/Off hysteresis updates
//!    (§3.3.2: T_off = 1, T_on = 2); busy-cycle statistics latch; components
//!    with no remaining work leave the wake-lists.
//!
//! ## Active-set scheduling
//!
//! The paper's premise is that irregular workloads keep most PEs idle most
//! cycles — so simulating every PE every cycle wastes almost all of the
//! host's work on no-ops. The fabric therefore keeps two
//! [`active::WakeList`]s (PEs and routers): a component enters on an
//! activation event — a flit staged into its buffers, an AXI static-AM
//! refill, a stream emission or dispatch, a trigger-timer cooldown, an
//! en-route claim — and leaves at commit when it has no pending work.
//! Phases iterate the wake-lists in the same rotated service order the
//! dense scan uses, which (together with commit-time hysteresis) makes the
//! two schedules **bit-identical**: same outputs, same cycle counts, same
//! [`FabricStats`], same PRNG draws. The original dense scan survives as
//! [`StepMode::DenseOracle`] — selectable per [`ArchConfig`] — and
//! `rust/tests/step_equivalence.rs` property-checks the equivalence across
//! random meshes, policies, buffer depths, and workload densities.
//! [`NexusFabric::check_conservation`] additionally asserts the wake-list
//! invariants (no awake-but-idle leaks, no asleep-but-pending components).
//!
//! ## Sharded stepping
//!
//! The fabric is additionally partitioned into `cfg.shards` contiguous row
//! bands (see [`shard`]): every phase runs shard-locally, boundary flits
//! cross shards through per-shard outboxes drained at an epoch barrier, and
//! boundary routing decisions read commit-time [`PortSnap`] snapshots. With
//! `cfg.threads > 1` the shards step on persistent worker threads; results
//! are **bit-identical at any thread count** for a fixed shard count, and
//! `shards = 1` reproduces the historical unsharded simulator exactly.
//! [`NexusFabric::run_cycles_parallel`] exposes a per-cycle digest trace so
//! the equivalence suite can report the first diverging cycle.
//!
//! The same fabric executes the TIA and TIA-Valiant baselines by flag:
//! [`crate::config::ExecPolicy::DestinationOnly`] disables phase 2,
//! `trigger_latency` charges the triggered-instruction scheduler cost, and
//! [`crate::config::RoutingPolicy::Valiant`] adds randomized intermediate
//! destinations.
//!
//! Off-chip traffic is modeled with a byte-credit AXI model (§3.3.3): data
//! memories load before a tile executes (counted as `load_cycles`), while
//! AM queues stream *during* execution, hiding their latency.

pub mod active;
pub mod shard;
pub mod stats;

use crate::am::Message;
use crate::compiler::Program;
use crate::config::{ArchConfig, StepMode};
use crate::isa::ConfigEntry;
use crate::noc::router::{port_class, PortSnap, Router, MAX_PORTS};
use crate::noc::routing::Dir;
use crate::noc::topology::{build_topology, Topology, LINKS_PER_PE};
use crate::pe::Pe;
use crate::trace::TraceBuffer;
use shard::{CommitCtx, ShardCtx, ShardState, SpinBarrier};
use stats::{FabricStats, SERIES_WINDOW};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Simulation failure: the fabric did not drain within `max_cycles`.
#[derive(Debug, Clone)]
pub struct DeadlockError {
    pub cycle: u64,
    pub in_flight: usize,
    /// Which components still hold work, one entry per non-idle PE/router —
    /// e.g. `"PE5 inbox=1 outq=2"` or `"R9 occ=3"`. Never empty for a real
    /// timeout: something must be holding the messages that did not drain.
    pub culprits: Vec<String>,
    /// Full forensic dump: conservation counters, per-PE queue occupancy,
    /// and per-port head-flit routing state (what each stuck head wants and
    /// what its downstream advertises).
    pub detail: String,
    /// Flight-recorder dump: the most recent trace events before the
    /// timeout, one formatted line each (newest last). Empty unless the
    /// run had tracing enabled ([`crate::trace::TraceConfig`]).
    pub flight: Vec<String>,
}

impl std::fmt::Display for DeadlockError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fabric did not drain by cycle {} ({} messages in flight; {} culprit components: {}): {}",
            self.cycle,
            self.in_flight,
            self.culprits.len(),
            self.culprits.join(", "),
            self.detail
        )?;
        if !self.flight.is_empty() {
            write!(f, "\nflight recorder (last {} events):", self.flight.len())?;
            for line in &self.flight {
                write!(f, "\n  {line}")?;
            }
        }
        Ok(())
    }
}

impl std::error::Error for DeadlockError {}

/// The Nexus Machine fabric: a `width x height` array of PEs + routers,
/// connected by the [`Topology`] selected in the config (mesh by default).
pub struct NexusFabric {
    pub cfg: ArchConfig,
    pes: Vec<Pe>,
    routers: Vec<Router>,
    /// Replicated configuration memory (identical across PEs, §3.3.1).
    config_mem: Vec<ConfigEntry>,
    /// Off-chip reservoir of static AMs per PE, streamed into the on-chip
    /// `am_window` at AXI bandwidth during execution.
    pending_static: Vec<VecDeque<Message>>,
    /// Fractional AXI byte credit accumulated per cycle.
    axi_credit: f64,
    /// Round-robin pointer for AXI refill fairness.
    axi_rr: usize,
    /// Static AMs still waiting off-chip (refill fast-path counter).
    pending_remaining: usize,
    /// The link structure (route computation + geometry).
    topo: Box<dyn Topology>,
    /// Precomputed neighbor table: `nbr_tab[id][port]` is the PE reached by
    /// leaving `id` through that output port, `u16::MAX` when unwired
    /// (route-phase hot path; PE ids fit in u16 — the config caps at 16384).
    nbr_tab: Vec<[u16; MAX_PORTS]>,
    /// Precomputed per-link traversal latencies (1 except chiplet-boundary
    /// hops).
    lat_tab: Vec<[u8; MAX_PORTS]>,
    /// Ports wired per router (5 for the mesh family, 9 for ruche).
    nports: usize,
    /// Torus bubble flow control active (see [`Topology::requires_bubble`]).
    torus_bubble: bool,
    /// Owning shard per PE id (contiguous row bands).
    shard_of: Vec<u16>,
    /// Per-shard state: PRNG stream, message-id counter, wake-lists,
    /// boundary outbox, stat deltas. Always at least one; with
    /// `cfg.shards == 1`, shard 0 covers the whole fabric and stepping is
    /// bit-identical to the historical unsharded simulator.
    shards: Vec<ShardState>,
    /// Boundary port snapshots: commit-time acceptance state of every input
    /// port terminating a shard-crossing link, grouped by owner shard
    /// (see [`shard::ShardCtx::nbr_view`]).
    snap: Vec<PortSnap>,
    /// `(router id, port)` per `snap` entry (refresh bookkeeping).
    snap_src: Vec<(u16, u8)>,
    /// `snap` entry per `(router, port)`; `u32::MAX` for non-boundary ports.
    snap_idx: Vec<u32>,
    /// `snap` index range owned by each shard (its routers' entries).
    snap_ranges: Vec<(usize, usize)>,
    /// `snap` index range of each individual router's entries.
    snap_router_range: Vec<(u32, u32)>,
    /// Global cycle counter (includes inter-tile load cycles).
    cycle: u64,
    pub stats: FabricStats,
    /// Merged trace sink: per-shard rings drain here (in shard index
    /// order) at every epoch barrier. Bounded when the config asks for a
    /// flight recorder; not part of the digest or stats surfaces.
    trace_sink: TraceBuffer,
}

impl NexusFabric {
    pub fn new(cfg: ArchConfig) -> Self {
        cfg.validate().expect("invalid ArchConfig");
        let n = cfg.num_pes();
        let topo = build_topology(&cfg);
        let nports = topo.num_ports();
        let mut nbr_tab = vec![[u16::MAX; MAX_PORTS]; n];
        let mut lat_tab = vec![[1u8; MAX_PORTS]; n];
        for (id, (nbrs, lats)) in nbr_tab.iter_mut().zip(lat_tab.iter_mut()).enumerate() {
            for port in 1..nports {
                let dir = Dir::from_port(port);
                if let Some(to) = topo.neighbor(id, dir) {
                    nbrs[port] = to as u16;
                    lats[port] = topo.hop_latency(id, dir) as u8;
                }
            }
        }
        let torus_bubble = topo.requires_bubble();
        // Shard partition: contiguous bands of whole rows (`validate`
        // enforces `height % shards == 0`).
        let band = (cfg.height / cfg.shards) * cfg.width;
        let shard_of: Vec<u16> = (0..n).map(|id| (id / band) as u16).collect();
        let shards: Vec<ShardState> = (0..cfg.shards)
            .map(|s| {
                let mut sh = ShardState::new(s, n, s * band, band, cfg.seed);
                sh.configure_trace(cfg.trace);
                sh
            })
            .collect();
        let trace_sink =
            TraceBuffer::new(if cfg.trace.enabled { cfg.trace.sink_capacity } else { 0 });
        // Boundary snapshot tables: one entry per input port terminating a
        // shard-crossing link, keyed `(dest router, dest port)`. Sorting
        // groups entries by owner shard (ids are band-contiguous) and by
        // router within a shard; each `(dest, port)` pair has exactly one
        // upstream router in every supported topology, so dedup is a no-op
        // kept as a guard.
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        for id in 0..n {
            for port in 1..nports {
                let nbr = nbr_tab[id][port];
                if nbr != u16::MAX && shard_of[id] != shard_of[nbr as usize] {
                    pairs.push((nbr as usize, Dir::from_port(port).opposite_port()));
                }
            }
        }
        pairs.sort_unstable();
        pairs.dedup();
        let mut snap_idx = vec![u32::MAX; n * MAX_PORTS];
        let mut snap = Vec::with_capacity(pairs.len());
        let mut snap_src: Vec<(u16, u8)> = Vec::with_capacity(pairs.len());
        for &(dest, dport) in &pairs {
            snap_idx[dest * MAX_PORTS + dport] = snap.len() as u32;
            snap.push(PortSnap::fresh(cfg.router_buf_depth));
            snap_src.push((dest as u16, dport as u8));
        }
        let mut snap_ranges = vec![(0usize, 0usize); cfg.shards];
        {
            let mut k = 0;
            for (s, range) in snap_ranges.iter_mut().enumerate() {
                let lo = k;
                while k < snap_src.len() && shard_of[snap_src[k].0 as usize] as usize == s {
                    k += 1;
                }
                *range = (lo, k);
            }
        }
        let mut snap_router_range = vec![(0u32, 0u32); n];
        {
            let mut k = 0;
            for (id, range) in snap_router_range.iter_mut().enumerate() {
                let lo = k as u32;
                while k < snap_src.len() && snap_src[k].0 as usize == id {
                    k += 1;
                }
                *range = (lo, k as u32);
            }
        }
        let mut stats = FabricStats::default();
        stats.per_pe_busy_cycles = vec![0; n];
        stats.per_pe_committed_ops = vec![0; n];
        stats.link_flits = vec![0; n * LINKS_PER_PE];
        NexusFabric {
            pes: (0..n).map(|_| Pe::new(cfg.dmem_words)).collect(),
            routers: (0..n)
                .map(|_| Router::new(nports, cfg.router_buf_depth, cfg.t_off, cfg.t_on))
                .collect(),
            config_mem: Vec::new(),
            pending_static: vec![VecDeque::new(); n],
            axi_credit: 0.0,
            axi_rr: 0,
            pending_remaining: 0,
            topo,
            nbr_tab,
            lat_tab,
            nports,
            torus_bubble,
            shard_of,
            shards,
            snap,
            snap_src,
            snap_idx,
            snap_ranges,
            snap_router_range,
            cycle: 0,
            stats,
            trace_sink,
            cfg,
        }
    }

    /// Total cycles elapsed (all tiles, including load phases).
    pub fn cycles(&self) -> u64 {
        self.cycle
    }

    /// Reset the fabric to its just-constructed state, reusing allocations,
    /// so one instance can execute many programs back to back. A reset
    /// fabric behaves bit-identically to a freshly constructed one: the
    /// cycle counter, message ids, AXI round-robin pointer, RNG, and all
    /// statistics return to their initial values (per-tile PE/router state
    /// is rebuilt by `load_tile` anyway). [`crate::machine::Machine`] calls
    /// this before every execution instead of building a new fabric.
    pub fn reset(&mut self) {
        self.cycle = 0;
        self.axi_credit = 0.0;
        self.axi_rr = 0;
        self.pending_remaining = 0;
        for q in &mut self.pending_static {
            q.clear();
        }
        for (s, shard) in self.shards.iter_mut().enumerate() {
            shard.reset(s, self.cfg.seed);
        }
        self.trace_sink.clear();
        for e in &mut self.snap {
            *e = PortSnap::fresh(self.cfg.router_buf_depth);
        }
        self.config_mem.clear();
        // Reset every counter but keep the per-PE/per-link vector allocations.
        let mut per_pe = std::mem::take(&mut self.stats.per_pe_busy_cycles);
        per_pe.fill(0);
        let mut per_pe_ops = std::mem::take(&mut self.stats.per_pe_committed_ops);
        per_pe_ops.fill(0);
        let mut link_flits = std::mem::take(&mut self.stats.link_flits);
        link_flits.fill(0);
        self.stats = FabricStats {
            per_pe_busy_cycles: per_pe,
            per_pe_committed_ops: per_pe_ops,
            link_flits,
            ..FabricStats::default()
        };
    }

    /// Run one tile: load its images (charging AXI load cycles), execute to
    /// drain + idle-tree latency, write back outputs. Returns the output
    /// tensor in the program's logical order.
    pub fn run_program(&mut self, prog: &Program) -> Result<Vec<i16>, DeadlockError> {
        self.begin_program(prog);
        self.execute()?;
        // Writeback: outputs stream off-chip at AXI bandwidth (Fig 16's
        // "increased output movement" term).
        let wb = prog.writeback_bytes();
        let wb_cycles = (wb as f64 / self.cfg.axi_bytes_per_cycle).ceil() as u64;
        self.cycle += wb_cycles;
        self.stats.load_cycles += wb_cycles;
        self.stats.offchip_bytes += wb;
        self.collect_tile_stats();
        Ok(prog
            .outputs
            .iter()
            .map(|&(pe, addr)| self.pes[pe].dmem[addr as usize] as i16)
            .collect())
    }

    /// Validate and load a program's images *without* running it — the
    /// manual-stepping entry point used by lockstep differential tests and
    /// debugging harnesses: call [`NexusFabric::step`] to advance one cycle,
    /// [`NexusFabric::is_drained`] to detect completion, and
    /// [`NexusFabric::state_digest`] to compare two fabrics cycle by cycle.
    /// [`NexusFabric::run_program`] remains the normal path (it adds the
    /// idle-tree drain loop and the off-chip writeback accounting).
    pub fn begin_program(&mut self, prog: &Program) {
        prog.validate(&self.cfg).expect("program/arch mismatch");
        self.load_tile(prog);
    }

    /// Reset all per-tile state and load a program's images.
    fn load_tile(&mut self, prog: &Program) {
        let n = self.cfg.num_pes();
        self.config_mem = prog.config.clone();
        let mut data_bytes = 0u64;
        for id in 0..n {
            let mut pe = Pe::new(self.cfg.dmem_words);
            let img = &prog.pes[id];
            for &(addr, val) in &img.dmem_init {
                pe.dmem[addr as usize] = val;
            }
            pe.stream_mem = img.stream_elems.clone();
            pe.trigger = vec![None; self.cfg.dmem_words];
            for &(addr, base, count) in &img.triggers {
                pe.trigger[addr as usize] = Some((base, count));
            }
            data_bytes += img.dmem_init.len() as u64 * 2
                + img.stream_elems.len() as u64 * crate::pe::STREAM_ELEM_WORDS as u64 * 2;
            self.pending_static[id] = img.static_ams.iter().copied().collect();
            // Preload the on-chip AM-queue window (its fill overlaps the
            // data-memory load; §3.3.3 hides AM streaming behind execution).
            let preload = self.cfg.am_queue_entries.min(self.pending_static[id].len());
            for _ in 0..preload {
                let m = self.pending_static[id].pop_front().unwrap();
                pe.am_window.push_back(m);
                self.stats.offchip_bytes += crate::am::packed::AM_BYTES as u64;
            }
            self.pes[id] = pe;
            self.routers[id] =
                Router::new(self.nports, self.cfg.router_buf_depth, self.cfg.t_off, self.cfg.t_on);
        }
        // Data memories load *before* execution (§3.3.3: "data loading into
        // data memories occurs after each tile execution is complete").
        let load_cycles = (data_bytes as f64 / self.cfg.axi_bytes_per_cycle).ceil() as u64;
        self.cycle += load_cycles;
        self.stats.load_cycles += load_cycles;
        self.stats.offchip_bytes += data_bytes;
        self.axi_credit = 0.0;
        self.pending_remaining = self.pending_static.iter().map(|q| q.len()).sum();
        // Routers were rebuilt above, so every boundary snapshot is fresh.
        for e in &mut self.snap {
            *e = PortSnap::fresh(self.cfg.router_buf_depth);
        }
        // Initial wake-lists: routers start empty; a PE starts awake iff its
        // on-chip AM window was preloaded (everything else activates later —
        // AXI refills, message deliveries, stream triggers).
        for shard in &mut self.shards {
            shard.awake_pes.clear();
            shard.awake_routers.clear();
            shard.outbox.clear();
            // PEs were rebuilt above, so every traced PE is Idle again.
            shard.pe_state.fill(crate::trace::PeTraceState::Idle as u8);
        }
        for id in 0..n {
            if self.pes[id].has_pending_work() {
                self.shards[self.shard_of[id] as usize].awake_pes.wake(id);
            }
        }
    }

    /// Cycle loop until the global idle detector fires. Dispatches to the
    /// persistent-worker engine when both `threads` and `shards` exceed one;
    /// the parallel path produces bit-identical state for a fixed shard
    /// count.
    fn execute(&mut self) -> Result<(), DeadlockError> {
        if self.cfg.threads.min(self.cfg.shards) > 1 {
            return self.parallel_loop(None, None);
        }
        let start = self.cycle;
        let mut idle_streak = 0u64;
        loop {
            self.step();
            if self.is_drained() {
                idle_streak += 1;
                if idle_streak > self.cfg.idle_tree_latency {
                    return Ok(());
                }
            } else {
                idle_streak = 0;
            }
            if self.cycle - start > self.cfg.max_cycles {
                return Err(self.deadlock_report());
            }
        }
    }

    /// Detailed diagnostics for a timeout (used in the DeadlockError).
    fn deadlock_report(&self) -> DeadlockError {
        let in_flight: usize = self.pes.iter().map(|p| p.held_messages()).sum::<usize>()
            + self.routers.iter().map(|r| r.occupancy()).sum::<usize>();
        let mut detail = format!(
            "created {} retired {}; ",
            self.stats.msgs_created, self.stats.msgs_retired
        );
        // One culprit entry per component still holding work, naming exactly
        // which queues are non-empty (the error's machine-usable form; the
        // free-text detail below carries the same data plus head-flit
        // routing forensics).
        let mut culprits = Vec::new();
        for (id, pe) in self.pes.iter().enumerate() {
            let mut parts = Vec::new();
            if pe.inbox.is_some() {
                parts.push("inbox=1".to_string());
            }
            if pe.local_redo.is_some() {
                parts.push("redo=1".to_string());
            }
            if !pe.outq.is_empty() {
                parts.push(format!("outq={}", pe.outq.len()));
            }
            if pe.stream.is_some() {
                parts.push("stream=1".to_string());
            }
            if !pe.stream_q.is_empty() {
                parts.push(format!("stream_q={}", pe.stream_q.len()));
            }
            if !pe.am_window.is_empty() {
                parts.push(format!("am_window={}", pe.am_window.len()));
            }
            if !self.pending_static[id].is_empty() {
                parts.push(format!("pending_static={}", self.pending_static[id].len()));
            }
            if !parts.is_empty() {
                culprits.push(format!("PE{id} {}", parts.join(" ")));
            }
            if self.routers[id].occupancy() > 0 {
                culprits.push(format!("R{id} occ={}", self.routers[id].occupancy()));
            }
        }
        // Saturated-link culprits: a receiving input port advertising OFF
        // with flits queued names the directed link feeding it. (Under
        // On/Off flow control buffers hover at one free slot rather than
        // filling completely, so OFF-with-occupancy is the saturation
        // signal, not `free() == 0`.)
        for (id, r) in self.routers.iter().enumerate() {
            for p in 1..r.num_ports() {
                if !r.on_state[p] && !r.inputs[p].is_empty() {
                    let from = self.nbr_tab[id][p];
                    if from != u16::MAX {
                        let dir = Dir::from_port(p).opposite();
                        culprits.push(format!(
                            "link R{from}->R{id} {dir:?} occ={}",
                            r.inputs[p].len()
                        ));
                    }
                }
            }
        }
        for (id, pe) in self.pes.iter().enumerate() {
            if !pe.is_idle() || self.routers[id].occupancy() > 0 {
                detail += &format!(
                    "PE{id}[inbox:{} redo:{} outq:{} stream:{} sq:{} win:{} pend:{} rtr:{}] ",
                    u8::from(pe.inbox.is_some()),
                    u8::from(pe.local_redo.is_some()),
                    pe.outq.len(),
                    u8::from(pe.stream.is_some()),
                    pe.stream_q.len(),
                    pe.am_window.len(),
                    self.pending_static[id].len(),
                    self.routers[id].occupancy(),
                );
            }
        }
        // Per-port head-flit forensics: what does each stuck head want?
        // Topology-aware: enumerate the ports this router actually wires
        // instead of assuming four mesh directions.
        for id in 0..self.cfg.num_pes() {
            for p in 0..self.routers[id].num_ports() {
                let Some(m) = self.routers[id].inputs[p].head_msg() else {
                    continue;
                };
                let tgt = m.route_target();
                let acc: Vec<String> = (1..self.nports)
                    .filter_map(|port| {
                        let nbr = self.nbr_tab[id][port];
                        if nbr == u16::MAX {
                            return None;
                        }
                        let d = Dir::from_port(port);
                        Some(format!(
                            "{d:?}:{}{}",
                            u8::from(self.routers[nbr as usize].on_state[d.opposite_port()]),
                            self.routers[nbr as usize].inputs[d.opposite_port()].free()
                        ))
                    })
                    .collect();
                detail += &format!(
                    "\nR{id}.p{p} head op={:?} dests={:?}/{} vh={:?} tgt={tgt:?} nbrs[ON+free]={:?}",
                    m.opcode, &m.dests[..m.ndests as usize], m.ndests, m.valiant_hop, acc
                );
            }
        }
        // Flight-recorder dump: whatever trace events the sink still holds
        // (the most recent N when a bounded flight-recorder sink is
        // configured; empty when tracing is off). The undrained current-
        // epoch shard rings are appended in shard index order first.
        let mut events = self.trace_sink.to_vec();
        for shard in &self.shards {
            events.extend(shard.ring.iter().copied());
        }
        let flight = crate::trace::flight_lines(&events, 64);
        DeadlockError {
            cycle: self.cycle,
            in_flight,
            culprits,
            detail,
            flight,
        }
    }

    /// Global idle condition (§3.1.4): all PEs inactive, no messages in
    /// transit, no static AMs left to stream.
    ///
    /// In `ActiveSet` mode this is O(active): only wake-list members can
    /// hold work (every sleeping component is empty by the commit-time sleep
    /// invariant, which [`NexusFabric::check_wake_consistency`] verifies),
    /// and off-chip static AMs are tracked by the `pending_remaining`
    /// counter. `DenseOracle` keeps the full O(PEs) scan as the reference.
    pub fn is_drained(&self) -> bool {
        self.view().is_drained()
    }

    /// One clock cycle: AXI refill, per-shard phase passes, the epoch
    /// barrier (boundary-outbox drain), per-shard commit passes, stat
    /// merge. With `shards = 1` this is exactly the historical
    /// single-threaded cycle; see `fabric/shard.rs` for the sharding
    /// contract. Both [`StepMode`] schedules are bit-identical (see the
    /// module docs and `tests/step_equivalence.rs`).
    pub fn step(&mut self) {
        self.epoch_io().axi_refill();
        for s in 0..self.cfg.shards {
            self.shard_phases(s);
        }
        self.epoch_io().drain_outboxes();
        for s in 0..self.cfg.shards {
            self.shard_commit(s);
        }
        self.epoch_io().epoch_end();
    }

    /// Run shard `s`'s phase passes (PE, en-route, route) over its band.
    fn shard_phases(&mut self, s: usize) {
        let (base, len) = (self.shards[s].base, self.shards[s].len);
        let mut ctx = ShardCtx {
            pes: &mut self.pes[base..base + len],
            routers: &mut self.routers[base..base + len],
            shard: &mut self.shards[s],
            link_flits: &mut self.stats.link_flits
                [base * LINKS_PER_PE..(base + len) * LINKS_PER_PE],
            cfg: &self.cfg,
            config_mem: &self.config_mem,
            nbr_tab: &self.nbr_tab,
            lat_tab: &self.lat_tab,
            topo: self.topo.as_ref(),
            nports: self.nports,
            torus_bubble: self.torus_bubble,
            shard_of: &self.shard_of,
            snap: &self.snap,
            snap_idx: &self.snap_idx,
            cycle: self.cycle,
        };
        ctx.run_phases();
    }

    /// Run shard `s`'s commit pass and boundary-snapshot refresh.
    fn shard_commit(&mut self, s: usize) {
        let (base, len) = (self.shards[s].base, self.shards[s].len);
        let (lo, hi) = self.snap_ranges[s];
        let mut ctx = CommitCtx {
            pes: &mut self.pes[base..base + len],
            routers: &mut self.routers[base..base + len],
            shard: &mut self.shards[s],
            snap: &mut self.snap[lo..hi],
            snap_src: &self.snap_src[lo..hi],
            snap_router_range: &self.snap_router_range,
            snap_base: lo,
            step_mode: self.cfg.step_mode,
            cycle: self.cycle,
        };
        ctx.run_commit();
    }

    /// The coordinator's window over the fabric's non-sharded state (AXI
    /// model, outbox drain, stat merge). In serial stepping this is just a
    /// reborrow of `self`; the parallel engine builds the same window from
    /// raw pointers while workers are parked at a barrier.
    fn epoch_io(&mut self) -> EpochIo<'_> {
        EpochIo {
            cfg: &self.cfg,
            pes: &mut self.pes,
            routers: &mut self.routers,
            shards: &mut self.shards,
            shard_of: &self.shard_of,
            pending_static: &mut self.pending_static,
            axi_credit: &mut self.axi_credit,
            axi_rr: &mut self.axi_rr,
            pending_remaining: &mut self.pending_remaining,
            stats: &mut self.stats,
            trace_sink: &mut self.trace_sink,
            cycle: &mut self.cycle,
        }
    }

    /// A read-only view for drain detection and digesting, shared between
    /// the public accessors and the parallel engine's coordinator.
    fn view(&self) -> FabricView<'_> {
        FabricView {
            cfg: &self.cfg,
            pes: &self.pes,
            routers: &self.routers,
            shards: &self.shards,
            pending_static: &self.pending_static,
            pending_remaining: self.pending_remaining,
            axi_credit: self.axi_credit,
            axi_rr: self.axi_rr,
            cycle: self.cycle,
        }
    }

    /// Step exactly `cycles` cycles, recording [`NexusFabric::state_digest`]
    /// at every cycle boundary — on the parallel engine when
    /// `min(threads, shards) > 1`, serially otherwise. The digest trace is
    /// what the equivalence suite compares against serial stepping to
    /// report the *first diverging cycle*.
    pub fn run_cycles_parallel(&mut self, cycles: u64) -> Vec<u64> {
        let mut trace = Vec::with_capacity(cycles as usize);
        if self.cfg.threads.min(self.cfg.shards) > 1 {
            self.parallel_loop(Some(cycles), Some(&mut trace))
                .expect("fixed-epoch run cannot time out");
        } else {
            for _ in 0..cycles {
                self.step();
                trace.push(self.state_digest());
            }
        }
        trace
    }

    /// The persistent-worker epoch engine. Shards are distributed
    /// round-robin over `min(threads, shards)` workers; each epoch runs
    ///
    /// 1. coordinator: AXI refill, publish the cycle number;
    /// 2. *barrier* — workers run their shards' phase passes;
    /// 3. *barrier* — coordinator drains every shard outbox (in shard
    ///    index order, so boundary staging is deterministic);
    /// 4. *barrier* — workers run their shards' commit passes;
    /// 5. *barrier* — coordinator merges stat deltas, advances the cycle,
    ///    checks termination.
    ///
    /// Memory-safety scheme: workers and the coordinator share the
    /// PE/router/shard/snapshot arrays through one set of raw pointers
    /// (`Ptrs`); the barriers time-separate every conflicting access
    /// (workers touch only their own bands during 2 and 4, the coordinator
    /// touches the arrays only during 1, 3 and 5). Fields only the
    /// coordinator uses (AXI queues, aggregate stats, the cycle counter)
    /// are borrowed normally. The per-link flit vector is moved out of
    /// `stats` for the duration so the coordinator's `&mut stats` never
    /// aliases the bands workers write (shard stat deltas carry empty
    /// vectors, so the epoch merge is a no-op on it).
    ///
    /// Terminates like `execute` (idle-tree drain, or `Err` after
    /// `max_cycles`) unless `fixed_epochs` pins the epoch count.
    fn parallel_loop(
        &mut self,
        fixed_epochs: Option<u64>,
        mut trace: Option<&mut Vec<u64>>,
    ) -> Result<(), DeadlockError> {
        if fixed_epochs == Some(0) {
            return Ok(());
        }
        let n = self.cfg.num_pes();
        let nshards = self.cfg.shards;
        let nthreads = self.cfg.threads.min(nshards);
        let snap_len = self.snap.len();
        #[derive(Clone, Copy)]
        struct Band {
            s: usize,
            base: usize,
            len: usize,
            snap_lo: usize,
            snap_hi: usize,
        }
        let assign: Vec<Vec<Band>> = (0..nthreads)
            .map(|t| {
                (t..nshards)
                    .step_by(nthreads)
                    .map(|s| {
                        let (snap_lo, snap_hi) = self.snap_ranges[s];
                        Band {
                            s,
                            base: self.shards[s].base,
                            len: self.shards[s].len,
                            snap_lo,
                            snap_hi,
                        }
                    })
                    .collect()
            })
            .collect();
        struct Ctl {
            barrier: SpinBarrier,
            cycle: AtomicU64,
            stop: AtomicBool,
        }
        let ctl = Ctl {
            barrier: SpinBarrier::new(nthreads + 1),
            cycle: AtomicU64::new(self.cycle),
            stop: AtomicBool::new(false),
        };
        // Read-only fabric geometry, shared with every worker.
        let cfg = &self.cfg;
        let config_mem = &self.config_mem;
        let nbr_tab = &self.nbr_tab;
        let lat_tab = &self.lat_tab;
        let topo = self.topo.as_ref();
        let shard_of = &self.shard_of;
        let snap_idx = &self.snap_idx;
        let snap_src = &self.snap_src;
        let snap_router_range = &self.snap_router_range;
        let (nports, torus_bubble) = (self.nports, self.torus_bubble);
        // Coordinator-only mutable state (never touched by workers).
        let pending_static = &mut self.pending_static;
        let axi_credit = &mut self.axi_credit;
        let axi_rr = &mut self.axi_rr;
        let pending_remaining = &mut self.pending_remaining;
        let cycle = &mut self.cycle;
        let trace_sink = &mut self.trace_sink;
        let mut link_flits = std::mem::take(&mut self.stats.link_flits);
        let stats = &mut self.stats;
        struct Ptrs {
            pes: *mut Pe,
            routers: *mut Router,
            shards: *mut ShardState,
            snap: *mut PortSnap,
            link_flits: *mut u64,
        }
        // SAFETY: the pointers are only dereferenced inside the scope below
        // under the barrier discipline documented above.
        unsafe impl Send for Ptrs {}
        unsafe impl Sync for Ptrs {}
        let ptrs = Ptrs {
            pes: self.pes.as_mut_ptr(),
            routers: self.routers.as_mut_ptr(),
            shards: self.shards.as_mut_ptr(),
            snap: self.snap.as_mut_ptr(),
            link_flits: link_flits.as_mut_ptr(),
        };
        let timed_out = std::thread::scope(|scope| {
            let ctl = &ctl;
            let ptrs = &ptrs;
            for bands in &assign {
                scope.spawn(move || loop {
                    ctl.barrier.wait(); // (1) refill done; phases may start
                    if ctl.stop.load(Ordering::Acquire) {
                        return;
                    }
                    let cur = ctl.cycle.load(Ordering::Acquire);
                    for &b in bands {
                        // SAFETY: this worker exclusively owns shard `b.s`'s
                        // band between barriers (1) and (2); the snapshot
                        // table is read-only during phases.
                        let (pes, routers, shard, lf, snap) = unsafe {
                            (
                                std::slice::from_raw_parts_mut(ptrs.pes.add(b.base), b.len),
                                std::slice::from_raw_parts_mut(ptrs.routers.add(b.base), b.len),
                                &mut *ptrs.shards.add(b.s),
                                std::slice::from_raw_parts_mut(
                                    ptrs.link_flits.add(b.base * LINKS_PER_PE),
                                    b.len * LINKS_PER_PE,
                                ),
                                std::slice::from_raw_parts(ptrs.snap.cast_const(), snap_len),
                            )
                        };
                        let mut ctx = ShardCtx {
                            pes,
                            routers,
                            shard,
                            link_flits: lf,
                            cfg,
                            config_mem,
                            nbr_tab,
                            lat_tab,
                            topo,
                            nports,
                            torus_bubble,
                            shard_of,
                            snap,
                            snap_idx,
                            cycle: cur,
                        };
                        ctx.run_phases();
                    }
                    ctl.barrier.wait(); // (2) phases done; coordinator drains
                    ctl.barrier.wait(); // (3) drain done; commits may start
                    for &b in bands {
                        // SAFETY: exclusive band plus this shard's own
                        // snapshot range between barriers (3) and (4).
                        let (pes, routers, shard, snap) = unsafe {
                            (
                                std::slice::from_raw_parts_mut(ptrs.pes.add(b.base), b.len),
                                std::slice::from_raw_parts_mut(ptrs.routers.add(b.base), b.len),
                                &mut *ptrs.shards.add(b.s),
                                std::slice::from_raw_parts_mut(
                                    ptrs.snap.add(b.snap_lo),
                                    b.snap_hi - b.snap_lo,
                                ),
                            )
                        };
                        let mut ctx = CommitCtx {
                            pes,
                            routers,
                            shard,
                            snap,
                            snap_src: &snap_src[b.snap_lo..b.snap_hi],
                            snap_router_range,
                            snap_base: b.snap_lo,
                            step_mode: cfg.step_mode,
                            cycle: cur,
                        };
                        ctx.run_commit();
                    }
                    ctl.barrier.wait(); // (4) commits done; coordinator merges
                });
            }
            // Coordinator.
            let start = *cycle;
            let mut idle_streak = 0u64;
            let mut timed_out = false;
            loop {
                {
                    // SAFETY (here and below): workers are parked at a
                    // barrier; the coordinator has exclusive access between
                    // rendezvous.
                    let (pes, routers, shards) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(ptrs.pes, n),
                            std::slice::from_raw_parts_mut(ptrs.routers, n),
                            std::slice::from_raw_parts_mut(ptrs.shards, nshards),
                        )
                    };
                    EpochIo {
                        cfg,
                        pes,
                        routers,
                        shards,
                        shard_of,
                        pending_static: pending_static.as_mut_slice(),
                        axi_credit: &mut *axi_credit,
                        axi_rr: &mut *axi_rr,
                        pending_remaining: &mut *pending_remaining,
                        stats: &mut *stats,
                        trace_sink: &mut *trace_sink,
                        cycle: &mut *cycle,
                    }
                    .axi_refill();
                }
                ctl.cycle.store(*cycle, Ordering::Release);
                ctl.barrier.wait(); // (1)
                ctl.barrier.wait(); // (2)
                {
                    let (pes, routers, shards) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(ptrs.pes, n),
                            std::slice::from_raw_parts_mut(ptrs.routers, n),
                            std::slice::from_raw_parts_mut(ptrs.shards, nshards),
                        )
                    };
                    EpochIo {
                        cfg,
                        pes,
                        routers,
                        shards,
                        shard_of,
                        pending_static: pending_static.as_mut_slice(),
                        axi_credit: &mut *axi_credit,
                        axi_rr: &mut *axi_rr,
                        pending_remaining: &mut *pending_remaining,
                        stats: &mut *stats,
                        trace_sink: &mut *trace_sink,
                        cycle: &mut *cycle,
                    }
                    .drain_outboxes();
                }
                ctl.barrier.wait(); // (3)
                ctl.barrier.wait(); // (4)
                {
                    let (pes, routers, shards) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(ptrs.pes, n),
                            std::slice::from_raw_parts_mut(ptrs.routers, n),
                            std::slice::from_raw_parts_mut(ptrs.shards, nshards),
                        )
                    };
                    EpochIo {
                        cfg,
                        pes,
                        routers,
                        shards,
                        shard_of,
                        pending_static: pending_static.as_mut_slice(),
                        axi_credit: &mut *axi_credit,
                        axi_rr: &mut *axi_rr,
                        pending_remaining: &mut *pending_remaining,
                        stats: &mut *stats,
                        trace_sink: &mut *trace_sink,
                        cycle: &mut *cycle,
                    }
                    .epoch_end();
                }
                let view = FabricView {
                    cfg,
                    pes: unsafe { std::slice::from_raw_parts(ptrs.pes.cast_const(), n) },
                    routers: unsafe {
                        std::slice::from_raw_parts(ptrs.routers.cast_const(), n)
                    },
                    shards: unsafe {
                        std::slice::from_raw_parts(ptrs.shards.cast_const(), nshards)
                    },
                    pending_static: pending_static.as_slice(),
                    pending_remaining: *pending_remaining,
                    axi_credit: *axi_credit,
                    axi_rr: *axi_rr,
                    cycle: *cycle,
                };
                if let Some(t) = trace.as_mut() {
                    t.push(view.digest());
                }
                let done = if let Some(epochs) = fixed_epochs {
                    *cycle - start >= epochs
                } else {
                    if view.is_drained() {
                        idle_streak += 1;
                    } else {
                        idle_streak = 0;
                    }
                    if idle_streak > cfg.idle_tree_latency {
                        true
                    } else if *cycle - start > cfg.max_cycles {
                        timed_out = true;
                        true
                    } else {
                        false
                    }
                };
                if done {
                    ctl.stop.store(true, Ordering::Release);
                    ctl.barrier.wait(); // release workers into their stop check
                    break;
                }
            }
            timed_out
        });
        self.stats.link_flits = link_flits;
        if timed_out {
            return Err(self.deadlock_report());
        }
        Ok(())
    }

    // --- stats ----------------------------------------------------------------

    /// Fold per-PE and per-router counters into the aggregate stats at the
    /// end of a tile (PEs and routers are re-created per tile).
    fn collect_tile_stats(&mut self) {
        self.stats.cycles = self.cycle;
        // Closing time-series sample: captures the tail window (and makes
        // post-drain idle stepping a guaranteed no-op on the series).
        self.stats.sample_series(self.cycle);
        for (id, pe) in self.pes.iter().enumerate() {
            self.stats.per_pe_busy_cycles[id] += pe.stats.busy_cycles;
            // At most one ALU op (local or en-route claim) and one decode
            // memory op commit per PE per cycle, so busy-cycle counts *are*
            // op counts; summed over PEs this equals alu_ops + mem_ops.
            self.stats.per_pe_committed_ops[id] += pe.stats.alu_busy_cycles + pe.stats.mem_ops;
        }
        for r in &self.routers {
            for p in 0..r.num_ports() {
                // Ruche ports fold onto their mesh direction's class so the
                // Fig-14 per-port breakdown keeps its five columns.
                self.stats.absorb_port(port_class(p), &r.stats[p]);
            }
        }
    }

    /// The topology this fabric was built on (runtime-selected via
    /// [`ArchConfig::topology`]).
    pub fn topology(&self) -> &dyn Topology {
        self.topo.as_ref()
    }

    /// Message conservation at drain: everything created was retired — plus
    /// the wake-list consistency invariants (so every conservation check in
    /// the test-suite also audits the active-set scheduler).
    pub fn check_conservation(&self) -> Result<(), String> {
        if !self.is_drained() {
            return Err("fabric not drained".into());
        }
        if self.stats.msgs_created != self.stats.msgs_retired {
            return Err(format!(
                "conservation violated: created {} != retired {}",
                self.stats.msgs_created, self.stats.msgs_retired
            ));
        }
        self.check_wake_consistency()
    }

    /// Audit the wake-lists against a full dense scan. Valid at any cycle
    /// boundary (between [`NexusFabric::step`] calls), in both step modes
    /// (the lists are maintained identically; only the scheduler differs):
    ///
    /// - **no asleep-but-pending component** — a PE with work or a router
    ///   with flits missing from its wake-list would never be scheduled
    ///   again: a simulator-induced deadlock;
    /// - **no awake-but-idle leak** — a workless component still on a list
    ///   would erode the O(active) bound back toward O(PEs);
    /// - **no stale busy flags** — a sleeping PE's flags must be clear, or
    ///   an en-route claim would be wrongly suppressed and busy-cycle stats
    ///   double-counted.
    pub fn check_wake_consistency(&self) -> Result<(), String> {
        for id in 0..self.cfg.num_pes() {
            let shard = &self.shards[self.shard_of[id] as usize];
            let has = self.pes[id].has_pending_work();
            let awake = shard.awake_pes.is_awake(id);
            if has && !awake {
                return Err(format!("PE{id} asleep but has pending work (scheduler deadlock)"));
            }
            if awake && !has {
                return Err(format!("PE{id} awake but idle (wake-list leak)"));
            }
            if !awake && (self.pes[id].alu_busy || self.pes[id].decode_busy) {
                return Err(format!("PE{id} asleep with busy flags set"));
            }
            let occ = self.routers[id].occupancy();
            let r_awake = shard.awake_routers.is_awake(id);
            if occ > 0 && !r_awake {
                return Err(format!("router {id} asleep holding {occ} flits (scheduler deadlock)"));
            }
            if r_awake && occ == 0 {
                return Err(format!("router {id} awake but empty (wake-list leak)"));
            }
        }
        Ok(())
    }

    /// Number of components currently on the wake-lists, `(PEs, routers)` —
    /// the quantity active-set stepping is O of. Exposed for benches and
    /// scheduler tests; not a statistic (identical workloads produce
    /// identical sequences in both step modes, since the lists are
    /// maintained identically).
    pub fn awake_counts(&self) -> (usize, usize) {
        (
            self.shards.iter().map(|s| s.awake_pes.len()).sum(),
            self.shards.iter().map(|s| s.awake_routers.len()).sum(),
        )
    }

    /// Order-sensitive FNV-1a digest of the complete mutable simulator
    /// state: PE memories/queues/flags, router buffers/staging/hysteresis,
    /// AXI and cycle counters, in-flight message contents. Two fabrics
    /// executing bit-identically produce equal digests at every cycle
    /// boundary — the lockstep divergence probe used by
    /// `tests/step_equivalence.rs` to report the *first diverging cycle* on
    /// an equivalence failure.
    pub fn state_digest(&self) -> u64 {
        self.view().digest()
    }

    /// The merged trace-event stream recorded so far (FIFO; empty when
    /// tracing is disabled). With a flight-recorder sink this is the most
    /// recent `sink_capacity` events; otherwise the complete run.
    pub fn trace_events(&self) -> Vec<crate::trace::Event> {
        self.trace_sink.to_vec()
    }

    /// Events lost to ring-buffer overflow (shard rings + sink). Sink
    /// drops are the expected mode of a flight recorder; shard-ring drops
    /// mean `TraceConfig::shard_capacity` is too small for one epoch.
    pub fn trace_dropped(&self) -> u64 {
        self.trace_sink.dropped + self.shards.iter().map(|s| s.ring.dropped).sum::<u64>()
    }
}

/// The coordinator's mutable window over the fabric's non-sharded state:
/// AXI refill before the phase passes, the boundary-outbox drain at the
/// epoch barrier, and the stat merge that closes the epoch. Built by
/// [`NexusFabric::epoch_io`] in serial stepping and from raw pointers by
/// the parallel engine (whose workers are parked at a barrier whenever one
/// of these methods runs).
struct EpochIo<'a> {
    cfg: &'a ArchConfig,
    pes: &'a mut [Pe],
    routers: &'a mut [Router],
    shards: &'a mut [ShardState],
    shard_of: &'a [u16],
    pending_static: &'a mut [VecDeque<Message>],
    axi_credit: &'a mut f64,
    axi_rr: &'a mut usize,
    pending_remaining: &'a mut usize,
    stats: &'a mut FabricStats,
    trace_sink: &'a mut TraceBuffer,
    cycle: &'a mut u64,
}

impl EpochIo<'_> {
    /// Stream static AMs from the off-chip reservoir into on-chip AM-queue
    /// windows at AXI bandwidth (round-robin across PEs).
    fn axi_refill(&mut self) {
        if *self.pending_remaining == 0 {
            return;
        }
        // Cycles with static AMs still waiting off-chip: AXI-refill stall
        // attribution. Coordinator-counted (global, like `cycles` itself),
        // so `merge_delta` must never touch it.
        self.stats.stall_axi_cycles += 1;
        *self.axi_credit += self.cfg.axi_bytes_per_cycle;
        let n = self.cfg.num_pes();
        let am_bytes = crate::am::packed::AM_BYTES as f64;
        let mut scanned = 0;
        while *self.axi_credit >= am_bytes && scanned < n {
            let id = *self.axi_rr;
            *self.axi_rr = (*self.axi_rr + 1) % n;
            if self.pending_static[id].is_empty()
                || self.pes[id].am_window.len() >= self.cfg.am_queue_entries
            {
                scanned += 1;
                continue;
            }
            scanned = 0;
            let m = self.pending_static[id].pop_front().unwrap();
            *self.pending_remaining -= 1;
            self.pes[id].am_window.push_back(m);
            self.shards[self.shard_of[id] as usize].awake_pes.wake(id);
            *self.axi_credit -= am_bytes;
            self.stats.offchip_bytes += crate::am::packed::AM_BYTES as u64;
        }
        // Credit does not bank across idle periods beyond one burst.
        *self.axi_credit = (*self.axi_credit).min(self.cfg.axi_bytes_per_cycle * 16.0);
    }

    /// Stage every shard's boundary flits into their destination routers —
    /// the epoch barrier that makes cross-shard traffic deterministic:
    /// shards drain in index order, each outbox in route-visit order.
    /// Staging cannot conflict: each `(router, input port)` has exactly one
    /// upstream router, hence exactly one shard that can target it.
    fn drain_outboxes(&mut self) {
        for s in 0..self.shards.len() {
            let mut outbox = std::mem::take(&mut self.shards[s].outbox);
            for f in outbox.drain(..) {
                let to = f.to as usize;
                if f.wait > 0 {
                    self.routers[to].stage_delayed(f.port as usize, f.msg, f.wait);
                } else {
                    self.routers[to].stage(f.port as usize, f.msg);
                }
                self.shards[self.shard_of[to] as usize].awake_routers.wake(to);
            }
            // Hand the (now empty) allocation back for reuse.
            self.shards[s].outbox = outbox;
        }
    }

    /// Close the epoch: merge every shard's scalar stat delta into the
    /// aggregate, fold the cycle's total link demand into the peak, and
    /// advance the cycle counter.
    fn epoch_end(&mut self) {
        let mut demand = 0u64;
        for shard in self.shards.iter_mut() {
            let delta = std::mem::take(&mut shard.stats);
            self.stats.merge_delta(&delta);
            demand += shard.link_demand;
            // Deterministic trace merge: shard rings drain in index order,
            // so the sink's event stream is identical at any thread count.
            if shard.trace.enabled {
                shard.ring.drain_into(self.trace_sink);
            }
        }
        self.stats.peak_link_demand = self.stats.peak_link_demand.max(demand);
        *self.cycle += 1;
        if *self.cycle % SERIES_WINDOW == 0 {
            self.stats.sample_series(*self.cycle);
        }
    }
}

/// A read-only snapshot view over the fabric state, serving the drain
/// detector and the lockstep digest for both the serial accessors and the
/// parallel engine's coordinator.
struct FabricView<'a> {
    cfg: &'a ArchConfig,
    pes: &'a [Pe],
    routers: &'a [Router],
    shards: &'a [ShardState],
    pending_static: &'a [VecDeque<Message>],
    pending_remaining: usize,
    axi_credit: f64,
    axi_rr: usize,
    cycle: u64,
}

impl FabricView<'_> {
    /// Global idle condition (§3.1.4): all PEs inactive, no messages in
    /// transit, no static AMs left to stream.
    ///
    /// In `ActiveSet` mode this is O(active): only wake-list members can
    /// hold work (every sleeping component is empty by the commit-time
    /// sleep invariant, which `check_wake_consistency` verifies), and
    /// off-chip static AMs are tracked by the `pending_remaining` counter.
    /// `DenseOracle` keeps the full O(PEs) scan as the reference.
    fn is_drained(&self) -> bool {
        match self.cfg.step_mode {
            StepMode::DenseOracle => {
                self.pending_static.iter().all(|q| q.is_empty())
                    && self.pes.iter().all(|p| p.is_idle())
                    && self.routers.iter().all(|r| r.occupancy() == 0)
            }
            StepMode::ActiveSet => {
                // Awake routers always hold flits; an awake PE may be merely
                // cooling down its trigger timer, which `is_idle` (and the
                // dense scan) ignores.
                self.pending_remaining == 0
                    && self.shards.iter().all(|s| {
                        s.awake_routers.is_empty()
                            && s.awake_pes.iter().all(|id| self.pes[id].is_idle())
                    })
            }
        }
    }

    /// Order-sensitive FNV-1a digest of the complete mutable simulator
    /// state: PE memories/queues/flags, router buffers/staging/hysteresis,
    /// AXI and cycle counters, per-shard PRNG/id streams, in-flight message
    /// contents. Two fabrics executing bit-identically produce equal
    /// digests at every cycle boundary — the lockstep divergence probe used
    /// by `tests/step_equivalence.rs` to report the *first diverging cycle*
    /// on an equivalence failure.
    fn digest(&self) -> u64 {
        #[inline]
        fn mix(h: &mut u64, v: u64) {
            *h = (*h ^ v).wrapping_mul(0x0000_0100_0000_01b3);
        }
        fn mix_msg(h: &mut u64, m: &Message) {
            mix(
                h,
                u64::from(m.dests[0])
                    | (u64::from(m.dests[1]) << 16)
                    | (u64::from(m.dests[2]) << 32)
                    | (u64::from(m.ndests) << 48)
                    | (u64::from(m.n_pc) << 56),
            );
            mix(
                h,
                u64::from(m.opcode.encode())
                    | (u64::from(m.res_is_addr) << 8)
                    | (u64::from(m.op1_is_addr) << 9)
                    | (u64::from(m.op2_is_addr) << 10),
            );
            mix(h, ((m.result as u64) << 32) | ((m.op1 as u64) << 16) | m.op2 as u64);
            mix(h, m.id);
            mix(h, m.birth);
            mix(
                h,
                ((m.hops as u64) << 40)
                    | m.valiant_hop.map_or(0xFFFF_FFFF, |v| 0x1_0000 | u64::from(v)),
            );
            mix(h, u64::from(m.executed_enroute));
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        mix(&mut h, self.cycle);
        mix(&mut h, self.pending_remaining as u64);
        mix(&mut h, self.axi_rr as u64);
        mix(&mut h, self.axi_credit.to_bits());
        for s in self.shards {
            mix(&mut h, s.next_msg_id);
            mix(&mut h, s.rng.state());
        }
        for (id, pe) in self.pes.iter().enumerate() {
            mix(&mut h, id as u64);
            for &w in &pe.dmem {
                mix(&mut h, w as u64);
            }
            mix(&mut h, pe.trigger_wait);
            mix(&mut h, u64::from(pe.alu_busy) | (u64::from(pe.decode_busy) << 1));
            mix(&mut h, pe.last_claim_cycle.map_or(u64::MAX, |c| c.wrapping_add(1)));
            for m in pe.inbox.iter().chain(pe.local_redo.iter()) {
                mix_msg(&mut h, m);
            }
            for m in pe.outq.iter().chain(pe.am_window.iter()) {
                mix_msg(&mut h, m);
            }
            for s in pe.stream.iter().chain(pe.stream_q.iter()) {
                mix(&mut h, s.base as u64);
                mix(&mut h, s.remaining as u64);
                mix(&mut h, s.pos as u64);
                mix_msg(&mut h, &s.template);
            }
            mix(&mut h, self.pending_static[id].len() as u64);
        }
        for r in self.routers {
            for p in 0..r.num_ports() {
                mix(&mut h, r.inputs[p].len() as u64);
                for m in r.inputs[p].iter() {
                    mix_msg(&mut h, m);
                }
                if let Some(m) = &r.staging[p] {
                    mix_msg(&mut h, m);
                }
                mix(&mut h, r.staging_wait[p] as u64);
                mix(&mut h, u64::from(r.on_state[p]));
                mix(&mut h, r.rr_ptr[p] as u64);
            }
            mix(&mut h, r.locked_port.map_or(u64::MAX, |p| p as u64));
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::Message;
    use crate::compiler::ProgramBuilder;
    use crate::isa::{ConfigEntry, Opcode};
    use crate::pe::StreamMode;

    fn nexus() -> ArchConfig {
        ArchConfig::nexus()
    }

    /// Smallest possible program: one static AM stores a constant remotely.
    fn store_program(cfg: &ArchConfig, src: usize, dst: usize, val: i16) -> crate::compiler::Program {
        let mut b = ProgramBuilder::new("store1", cfg);
        let addr = b.alloc(dst, 1);
        let mut am = Message::new();
        am.opcode = Opcode::Store;
        am.op1 = val as u16;
        am.result = addr;
        am.res_is_addr = true;
        am.push_dest(dst as u16);
        b.static_am(src, am);
        b.output(dst, addr);
        b.build()
    }

    #[test]
    fn single_store_reaches_remote_pe() {
        let cfg = nexus();
        let mut f = NexusFabric::new(cfg.clone());
        let prog = store_program(&cfg, 0, 15, -7);
        let out = f.run_program(&prog).unwrap();
        assert_eq!(out, vec![-7]);
        f.check_conservation().unwrap();
        assert!(f.stats.cycles > 0);
        assert_eq!(f.stats.mem_ops, 1);
    }

    /// Load + Mul + Accum chain: the Fig 5 SpMV choreography for a single
    /// nonzero, hand-built.
    fn mac_program(cfg: &ArchConfig) -> crate::compiler::Program {
        let mut b = ProgramBuilder::new("mac1", cfg);
        // x[0] = 6 lives on PE 5; y[0] (init 10) lives on PE 10.
        let xa = b.place(5, &[6]);
        let ya = b.place(10, &[10]);
        let pc_mul = b.config(ConfigEntry::new(Opcode::Mul, 0)); // placeholder pc
        let pc_acc = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
        // Fix the chain: Mul's entry must point at the Accum entry.
        // (ProgramBuilder interns by value, so re-add with correct next_pc.)
        assert_eq!(pc_mul, 0);
        assert_eq!(pc_acc, 1);
        let mut am = Message::new();
        am.opcode = Opcode::Load; // op2 <- dmem[op2] at PE 5
        am.n_pc = pc_mul;
        am.op1 = 7; // matrix value
        am.op2 = xa;
        am.op2_is_addr = true;
        am.result = ya;
        am.res_is_addr = true;
        am.push_dest(5);
        am.push_dest(10);
        b.static_am(0, am);
        b.output(10, ya);
        let mut p = b.build();
        // Mul entry chains to Accum entry.
        p.config[0] = ConfigEntry::new(Opcode::Mul, 1);
        p.config[1] = ConfigEntry::new(Opcode::Accum, 1).res_addr();
        p
    }

    #[test]
    fn load_mul_accum_chain_computes_mac() {
        let cfg = nexus();
        let mut f = NexusFabric::new(cfg.clone());
        let prog = mac_program(&cfg);
        let out = f.run_program(&prog).unwrap();
        assert_eq!(out, vec![10 + 7 * 6]);
        f.check_conservation().unwrap();
        assert_eq!(f.stats.alu_ops, 1, "exactly one Mul");
        assert_eq!(f.stats.mem_ops, 2, "Load + Accum");
    }

    #[test]
    fn enroute_execution_happens_on_nexus_not_tia() {
        // Many independent MACs flowing between distant PEs: Nexus should
        // execute a good fraction en-route; TIA none.
        let run = |cfg: ArchConfig| {
            let mut b = ProgramBuilder::new("macs", &cfg);
            let pc_acc;
            {
                let mul = b.config(ConfigEntry::new(Opcode::Mul, 1));
                pc_acc = b.config(ConfigEntry::new(Opcode::Accum, 1).res_addr());
                assert_eq!(mul, 0);
            }
            let _ = pc_acc;
            for i in 0..40u16 {
                let src = (i as usize) % 4; // inject from west column
                let data_pe = 4 + (i as usize) % 8;
                let out_pe = 12 + (i as usize) % 4;
                let xa = b.place(data_pe, &[2]);
                let ya = b.place(out_pe, &[0]);
                let mut am = Message::new();
                am.opcode = Opcode::Load;
                am.n_pc = 0;
                am.op1 = 3;
                am.op2 = xa;
                am.op2_is_addr = true;
                am.result = ya;
                am.res_is_addr = true;
                am.push_dest(data_pe as u16);
                am.push_dest(out_pe as u16);
                b.static_am(src, am);
                b.output(out_pe, ya);
            }
            let mut p = b.build();
            p.config[0] = ConfigEntry::new(Opcode::Mul, 1);
            p.config[1] = ConfigEntry::new(Opcode::Accum, 1).res_addr();
            let mut f = NexusFabric::new(cfg);
            let out = f.run_program(&p).unwrap();
            assert!(out.iter().all(|&v| v == 6), "{out:?}");
            f.check_conservation().unwrap();
            f.stats
        };
        let nexus_stats = run(ArchConfig::nexus());
        let tia_stats = run(ArchConfig::tia());
        assert!(nexus_stats.enroute_ops > 0, "Nexus must compute en-route");
        assert_eq!(tia_stats.enroute_ops, 0, "TIA must not compute en-route");
        assert_eq!(nexus_stats.alu_ops, tia_stats.alu_ops, "same work");
    }

    #[test]
    fn valiant_routes_still_deliver() {
        let cfg = ArchConfig::tia_valiant();
        let mut f = NexusFabric::new(cfg.clone());
        let prog = store_program(&cfg, 3, 12, 99);
        let out = f.run_program(&prog).unwrap();
        assert_eq!(out, vec![99]);
        f.check_conservation().unwrap();
    }

    #[test]
    fn stream_perdest_fans_out() {
        // One Stream trigger fans out adds to 4 different PEs.
        let cfg = nexus();
        let mut b = ProgramBuilder::new("fanout", &cfg);
        let pc_noop = b.config(ConfigEntry::new(Opcode::Accum, 0).res_addr());
        assert_eq!(pc_noop, 0);
        let mut elems = Vec::new();
        let mut outs = Vec::new();
        for k in 0..4u16 {
            let pe = 12 + k as usize;
            // place target word (init 100) on each PE
            let addr = b.place(pe, &[100]);
            outs.push((pe, addr));
            elems.push(crate::pe::StreamElem {
                value: (k as i16 + 1) as u16 as i16,
                aux: addr,
                dest_pe: pe as u16,
                mode: StreamMode::PerDest,
            });
        }
        let base = b.stream(0, &elems);
        let key = b.keyed_trigger(0, base, 4);
        let mut am = Message::new();
        am.opcode = Opcode::Stream;
        am.n_pc = 0; // emitted AMs carry Accum (terminal at dest)
        am.op2 = key;
        am.op2_is_addr = true;
        am.push_dest(0); // stream trigger at PE0 itself
        b.static_am(0, am);
        for &(pe, addr) in &outs {
            b.output(pe, addr);
        }
        let mut p = b.build();
        // Emitted AMs: opcode Accum — but Accum takes op1; stream puts the
        // element value in op2. Use Add->Accum? Simpler: Store op1? For this
        // test make the emitted opcode Add with op1=0 then Accum.
        p.config[0] = ConfigEntry::new(Opcode::Add, 1).res_addr();
        p.config.push(ConfigEntry::new(Opcode::Accum, 1).res_addr());
        let mut f = NexusFabric::new(cfg);
        let out = f.run_program(&p).unwrap();
        // Each target: 100 + (0 + value).
        assert_eq!(out, vec![101, 102, 103, 104]);
        f.check_conservation().unwrap();
        assert_eq!(f.stats.stream_emissions, 4);
    }

    #[test]
    fn accmin_relaxation_triggers_and_settles() {
        // Two-vertex SSSP: dist[a]=0 relaxes dist[b] via an edge of weight 3.
        let cfg = nexus();
        let mut b = ProgramBuilder::new("relax", &cfg);
        let pe_a = 0usize;
        let pe_b = 15usize;
        let da = b.place(pe_a, &[crate::tensor::graph::INF]);
        let db = b.place(pe_b, &[crate::tensor::graph::INF]);
        // Edge a->b, weight 3: stream element at PE a.
        let e = crate::pe::StreamElem {
            value: 3,
            aux: db,
            dest_pe: pe_b as u16,
            mode: StreamMode::PerDest,
        };
        let base = b.stream(pe_a, &[e]);
        b.trigger(pe_a, da, base, 1);
        // Config: emitted AM carries Add (dist + w), then AccMin.
        // Entry 0: Add -> 1 ; entry 1: AccMin (res_addr), next 0 (emitted
        // streams restart at entry 0).
        // Static AM: AccMin dist[a] with op1 = 0.
        let mut am = Message::new();
        am.opcode = Opcode::AccMin;
        am.n_pc = 0;
        am.op1 = 0;
        am.result = da;
        am.res_is_addr = true;
        am.push_dest(pe_a as u16);
        b.static_am(pe_a, am);
        b.output(pe_a, da);
        b.output(pe_b, db);
        let mut p = b.build();
        p.config = vec![
            ConfigEntry::new(Opcode::Add, 1).res_addr(),
            ConfigEntry::new(Opcode::AccMin, 0).res_addr(),
        ];
        let mut f = NexusFabric::new(cfg);
        let out = f.run_program(&p).unwrap();
        assert_eq!(out, vec![0, 3]);
        f.check_conservation().unwrap();
    }

    #[test]
    fn valiant_storm_drains_without_deadlock() {
        // Regression for the two-phase-Valiant deadlock: a storm of
        // random-destination stores on TIA-Valiant must drain. The ROMM
        // hop constraint (minimal rectangle, west-first-legal composite)
        // is what makes this hold with 3-flit buffers and no VCs.
        let mut cfg = ArchConfig::tia_valiant();
        cfg.max_cycles = 200_000;
        let mut b = ProgramBuilder::new("storm", &cfg);
        let mut rng = crate::util::SplitMix64::new(0xF00D);
        let mut targets = Vec::new();
        for i in 0..400u16 {
            let src = rng.below_usize(16);
            let dst = rng.below_usize(16);
            let addr = b.alloc(dst, 1);
            let mut am = Message::new();
            am.opcode = Opcode::Store;
            am.op1 = i;
            am.result = addr;
            am.res_is_addr = true;
            am.push_dest(dst as u16);
            b.static_am(src, am);
            targets.push((dst, addr, i));
        }
        for &(dst, addr, _) in &targets {
            b.output(dst, addr);
        }
        let prog = b.build();
        let mut f = NexusFabric::new(cfg);
        let out = f.run_program(&prog).expect("storm must drain");
        for (k, &(_, _, v)) in targets.iter().enumerate() {
            assert_eq!(out[k], v as i16);
        }
        f.check_conservation().unwrap();
    }

    #[test]
    fn fabric_reports_deadlock_instead_of_hanging() {
        // A config chain that self-loops (MUL whose next entry is itself)
        // produces a message that never becomes terminal: the fabric must
        // report the timeout as an error instead of spinning forever.
        let mut cfg = nexus();
        cfg.max_cycles = 500;
        let mut b = ProgramBuilder::new("livelock", &cfg);
        let pc = b.config(ConfigEntry::new(Opcode::Mul, 0));
        let mut am = Message::new();
        am.opcode = Opcode::Mul;
        am.n_pc = pc;
        am.op1 = 1;
        am.op2 = 1;
        am.push_dest(15);
        b.static_am(0, am);
        let prog = b.build();
        let mut f = NexusFabric::new(cfg);
        let r = f.run_program(&prog);
        assert!(r.is_err(), "expected timeout error");
        let e = r.unwrap_err();
        assert!(e.in_flight >= 1, "stuck message should be reported");
        assert!(
            !e.culprits.is_empty(),
            "a timeout must name the components holding work"
        );
        assert!(
            e.culprits.iter().any(|c| c.starts_with("PE") || c.starts_with('R')),
            "culprits must identify PEs/routers: {:?}",
            e.culprits
        );
    }

    #[test]
    fn reset_fabric_is_bit_identical_to_fresh_in_both_modes() {
        for mode in [StepMode::ActiveSet, StepMode::DenseOracle] {
            let cfg = nexus().with_step_mode(mode);
            let prog = mac_program(&cfg);
            let mut fresh = NexusFabric::new(cfg.clone());
            let out_fresh = fresh.run_program(&prog).unwrap();
            let mut reused = NexusFabric::new(cfg);
            // Dirty the instance with a different program first, then reset.
            let store = store_program(&reused.cfg, 0, 15, -7);
            reused.run_program(&store).unwrap();
            reused.reset();
            let out_reused = reused.run_program(&prog).unwrap();
            assert_eq!(out_fresh, out_reused, "{mode:?}");
            assert_eq!(fresh.stats, reused.stats, "{mode:?}");
            assert_eq!(fresh.state_digest(), reused.state_digest(), "{mode:?}");
        }
    }

    #[test]
    fn dense_oracle_matches_active_set_on_fabric_programs() {
        // The two schedulers must be bit-identical: same outputs, same
        // cycle counts, same stats. (The broad randomized version lives in
        // tests/step_equivalence.rs; this is the in-crate smoke check.)
        let base = nexus();
        for prog in [
            store_program(&base, 0, 15, -7),
            mac_program(&base),
        ] {
            let mut fa = NexusFabric::new(base.clone().with_step_mode(StepMode::ActiveSet));
            let mut fd = NexusFabric::new(base.clone().with_step_mode(StepMode::DenseOracle));
            let oa = fa.run_program(&prog).unwrap();
            let od = fd.run_program(&prog).unwrap();
            assert_eq!(oa, od);
            assert_eq!(fa.cycles(), fd.cycles());
            assert_eq!(fa.stats, fd.stats);
            fa.check_conservation().unwrap();
            fd.check_conservation().unwrap();
        }
    }

    #[test]
    fn lockstep_digests_agree_cycle_by_cycle() {
        // Manual-stepping both schedulers over the same program: the full
        // state digest must match at *every* cycle boundary, and the wake
        // lists must satisfy their invariants throughout.
        let base = nexus();
        let prog = mac_program(&base);
        let mut fa = NexusFabric::new(base.clone().with_step_mode(StepMode::ActiveSet));
        let mut fd = NexusFabric::new(base.with_step_mode(StepMode::DenseOracle));
        fa.begin_program(&prog);
        fd.begin_program(&prog);
        assert_eq!(fa.state_digest(), fd.state_digest(), "post-load");
        for cycle in 0..200 {
            fa.step();
            fd.step();
            assert_eq!(
                fa.state_digest(),
                fd.state_digest(),
                "diverged at cycle {cycle}"
            );
            fa.check_wake_consistency().unwrap();
            fd.check_wake_consistency().unwrap();
            assert_eq!(fa.is_drained(), fd.is_drained(), "cycle {cycle}");
            if fa.is_drained() {
                return;
            }
        }
        panic!("program did not drain within 200 cycles");
    }

    #[test]
    fn sleeping_fabric_steps_are_cheap_and_safe() {
        // After drain the wake-lists empty out; stepping an empty fabric
        // must stay a no-op in both modes (cycle advances, nothing else).
        for mode in [StepMode::ActiveSet, StepMode::DenseOracle] {
            let cfg = nexus().with_step_mode(mode);
            let prog = store_program(&cfg, 0, 15, 3);
            let mut f = NexusFabric::new(cfg);
            f.run_program(&prog).unwrap();
            let (awake_pes, awake_routers) = f.awake_counts();
            assert_eq!((awake_pes, awake_routers), (0, 0), "{mode:?}");
            let before = f.stats.clone();
            let c0 = f.cycles();
            for _ in 0..8 {
                f.step();
            }
            assert_eq!(f.cycles(), c0 + 8);
            assert_eq!(f.stats, before, "{mode:?}: idle steps must not mutate stats");
            f.check_wake_consistency().unwrap();
        }
    }

    #[test]
    fn utilization_and_innetwork_metrics_populate() {
        let cfg = nexus();
        let mut f = NexusFabric::new(cfg.clone());
        let prog = mac_program(&cfg);
        f.run_program(&prog).unwrap();
        assert!(f.stats.utilization() > 0.0);
        assert!(f.stats.cycles >= f.stats.load_cycles);
        assert!(f.stats.offchip_bytes > 0);
    }

    /// Topology-variant config with non-trivial geometry on the 4x4 array:
    /// 2x2 chiplets (so boundary crossings exist) with a 3-cycle crossing.
    fn topo_cfg(kind: crate::config::TopologyKind) -> ArchConfig {
        nexus().with_topology(kind).with_chiplet((2, 2), 3)
    }

    #[test]
    fn every_topology_delivers_and_conserves() {
        use crate::config::TopologyKind;
        for kind in TopologyKind::ALL {
            for mode in [StepMode::ActiveSet, StepMode::DenseOracle] {
                let cfg = topo_cfg(kind).with_step_mode(mode);
                let mut f = NexusFabric::new(cfg.clone());
                let prog = store_program(&cfg, 0, 15, -7);
                let out = f.run_program(&prog).unwrap();
                assert_eq!(out, vec![-7], "{kind:?}/{mode:?}");
                f.check_conservation().unwrap();
                let prog = mac_program(&cfg);
                f.reset();
                let out = f.run_program(&prog).unwrap();
                assert_eq!(out, vec![10 + 7 * 6], "{kind:?}/{mode:?}");
                f.check_conservation().unwrap();
            }
        }
    }

    #[test]
    fn link_flit_counters_sum_to_flit_hops() {
        use crate::config::TopologyKind;
        for kind in TopologyKind::ALL {
            let cfg = topo_cfg(kind);
            let mut f = NexusFabric::new(cfg.clone());
            let prog = mac_program(&cfg);
            f.run_program(&prog).unwrap();
            assert_eq!(
                f.stats.link_flits_total(),
                f.stats.flit_hops,
                "{kind:?}: per-link counters must partition flit_hops"
            );
            assert!(f.stats.flit_hops > 0, "{kind:?}: MAC program crosses links");
            assert!(
                f.stats.peak_link_demand >= 1,
                "{kind:?}: some cycle moved at least one flit"
            );
            // Every counted link must be one the topology actually wires.
            for (idx, &flits) in f.stats.link_flits.iter().enumerate() {
                if flits == 0 {
                    continue;
                }
                let from = idx / crate::noc::LINKS_PER_PE;
                let dir = Dir::from_port(idx % crate::noc::LINKS_PER_PE + 1);
                assert!(
                    f.topology().neighbor(from, dir).is_some(),
                    "{kind:?}: flits counted on unwired link {from}/{dir:?}"
                );
            }
        }
    }

    #[test]
    fn torus_storm_drains_under_bubble_flow_control() {
        // The torus analogue of `valiant_storm_drains_without_deadlock`:
        // wraparound rings deadlock classic credit flow control, so this
        // regression pins the bubble rule (ring continuation may transit,
        // ring entry leaves a free slot).
        let mut cfg = nexus().with_topology(crate::config::TopologyKind::Torus2D);
        cfg.max_cycles = 200_000;
        let mut b = ProgramBuilder::new("torus-storm", &cfg);
        let mut rng = crate::util::SplitMix64::new(0xBEEF);
        let mut targets = Vec::new();
        for i in 0..400u16 {
            let src = rng.below_usize(16);
            let dst = rng.below_usize(16);
            let addr = b.alloc(dst, 1);
            let mut am = Message::new();
            am.opcode = Opcode::Store;
            am.op1 = i;
            am.result = addr;
            am.res_is_addr = true;
            am.push_dest(dst as u16);
            b.static_am(src, am);
            targets.push((dst, addr, i));
        }
        for &(dst, addr, _) in &targets {
            b.output(dst, addr);
        }
        let prog = b.build();
        let mut f = NexusFabric::new(cfg);
        let out = f.run_program(&prog).expect("torus storm must drain");
        for (k, &(_, _, v)) in targets.iter().enumerate() {
            assert_eq!(out[k], v as i16);
        }
        f.check_conservation().unwrap();
    }

    #[test]
    fn deadlock_report_names_saturated_links() {
        // Storm every PE's stores at PE0 with a tiny cycle budget: the
        // hotspot's input ports sit OFF with flits queued, so the timeout
        // report must include `link R<from>->R0 ...` culprits.
        let mut cfg = nexus();
        cfg.max_cycles = 40;
        let mut b = ProgramBuilder::new("hotspot-links", &cfg);
        let addr = b.alloc(0, 1);
        for i in 0..240u16 {
            let src = 1 + (i as usize) % 15;
            let mut am = Message::new();
            am.opcode = Opcode::Store;
            am.op1 = i;
            am.result = addr;
            am.res_is_addr = true;
            am.push_dest(0);
            b.static_am(src, am);
        }
        b.output(0, addr);
        let prog = b.build();
        let mut f = NexusFabric::new(cfg);
        let e = f.run_program(&prog).expect_err("40 cycles cannot drain 240 stores");
        assert!(
            e.culprits.iter().any(|c| c.starts_with("link R")),
            "timeout under congestion must name saturated links: {:?}",
            e.culprits
        );
    }
}
