//! The wake-list backing [`crate::config::StepMode::ActiveSet`] stepping.
//!
//! A [`WakeList`] tracks which components (PEs or routers) of the fabric
//! have pending work. Components enter on an activation event (a message
//! commit into their buffers, an AXI static-AM refill, a stream emission, a
//! trigger-timer cooldown, an en-route claim) and leave at cycle commit when
//! they have no pending work, so the scheduler's per-cycle cost is
//! O(active), not O(mesh).
//!
//! Determinism matters more than raw speed here: the fabric rotates its
//! service order every cycle (`start = cycle % n`) so no component gets
//! systematic priority, and the Valiant routing policy draws from a single
//! PRNG in service order. The wake-list therefore iterates members in
//! *rotated id order* — exactly the order the dense scan visits the same
//! components — which is what makes active-set stepping bit-identical to
//! the [`crate::config::StepMode::DenseOracle`] scan. A `BTreeSet` keeps
//! members sorted (two range scans give the rotation) with O(log n)
//! wake/sleep; a dense mask gives O(1) membership tests.

use std::collections::BTreeSet;

/// Set of awake component ids with deterministic rotated-order iteration.
#[derive(Debug, Clone)]
pub struct WakeList {
    /// O(1) membership (also guards double-insertion into the set).
    mask: Vec<bool>,
    /// Sorted members, for rotated iteration.
    set: BTreeSet<usize>,
    /// Ids this list may legally hold, as `(base, len)` over the *global*
    /// id space. A whole-fabric list owns `(0, n)`; a per-shard list owns
    /// its shard's contiguous band. Only a debug guard — sharded stepping
    /// keeps one list per shard and a cross-band `wake` means a shard
    /// touched state it does not own.
    band: (usize, usize),
}

impl WakeList {
    /// An empty wake-list over component ids `0..n`.
    pub fn new(n: usize) -> Self {
        Self::new_for_band(n, 0, n)
    }

    /// An empty wake-list whose members must fall in `base..base + len`.
    /// The mask still spans `0..n` (ids stay global; only ownership is
    /// restricted), so `is_awake` works unchanged for any fabric id.
    pub fn new_for_band(n: usize, base: usize, len: usize) -> Self {
        debug_assert!(base + len <= n);
        WakeList {
            mask: vec![false; n],
            set: BTreeSet::new(),
            band: (base, len),
        }
    }

    /// Number of awake components.
    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    /// Capacity (total component count the list was built for).
    pub fn capacity(&self) -> usize {
        self.mask.len()
    }

    #[inline]
    pub fn is_awake(&self, id: usize) -> bool {
        self.mask[id]
    }

    /// Mark `id` awake (idempotent).
    #[inline]
    pub fn wake(&mut self, id: usize) {
        debug_assert!(
            id >= self.band.0 && id < self.band.0 + self.band.1,
            "wake({id}) outside its band {:?}",
            self.band
        );
        if !self.mask[id] {
            self.mask[id] = true;
            self.set.insert(id);
        }
    }

    /// Mark `id` asleep (idempotent).
    #[inline]
    pub fn sleep(&mut self, id: usize) {
        if self.mask[id] {
            self.mask[id] = false;
            self.set.remove(&id);
        }
    }

    /// Put every component to sleep.
    pub fn clear(&mut self) {
        self.mask.fill(false);
        self.set.clear();
    }

    /// Iterate awake ids in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.set.iter().copied()
    }

    /// Append the awake ids to `out` in ascending order (commit pass).
    pub fn snapshot_into(&self, out: &mut Vec<usize>) {
        out.extend(self.set.iter().copied());
    }

    /// Append the awake ids to `out` in rotated order: `start..`, then
    /// `..start` — the dense scan's `(start + k) % n` service order
    /// restricted to awake members.
    pub fn rotated_into(&self, start: usize, out: &mut Vec<usize>) {
        out.extend(self.set.range(start..).copied());
        out.extend(self.set.range(..start).copied());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wake_sleep_roundtrip() {
        let mut w = WakeList::new(8);
        assert!(w.is_empty());
        w.wake(3);
        w.wake(3); // idempotent
        w.wake(5);
        assert_eq!(w.len(), 2);
        assert!(w.is_awake(3) && w.is_awake(5) && !w.is_awake(4));
        w.sleep(3);
        w.sleep(3); // idempotent
        assert_eq!(w.len(), 1);
        assert!(!w.is_awake(3));
        w.clear();
        assert!(w.is_empty() && !w.is_awake(5));
    }

    #[test]
    fn rotated_order_matches_dense_scan_order() {
        let mut w = WakeList::new(10);
        for id in [1, 4, 7, 9] {
            w.wake(id);
        }
        // Dense order from start=5 over ids 0..10 is 5,6,7,8,9,0,1,2,3,4;
        // restricted to awake members: 7, 9, 1, 4.
        let mut out = Vec::new();
        w.rotated_into(5, &mut out);
        assert_eq!(out, vec![7, 9, 1, 4]);
        out.clear();
        w.rotated_into(0, &mut out);
        assert_eq!(out, vec![1, 4, 7, 9]);
        out.clear();
        w.rotated_into(9, &mut out);
        assert_eq!(out, vec![9, 1, 4, 7]);
    }

    #[test]
    fn band_list_keeps_global_ids() {
        // A per-shard list over the band 4..8 of a 12-component fabric:
        // membership tests and rotated iteration stay in global id space.
        let mut w = WakeList::new_for_band(12, 4, 4);
        assert_eq!(w.capacity(), 12);
        w.wake(4);
        w.wake(7);
        assert!(w.is_awake(7) && !w.is_awake(3));
        let mut out = Vec::new();
        w.rotated_into(6, &mut out);
        assert_eq!(out, vec![7, 4]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "outside its band")]
    fn band_guard_catches_out_of_band_wake() {
        let mut w = WakeList::new_for_band(12, 4, 4);
        w.wake(9);
    }

    #[test]
    fn rotation_equivalence_property() {
        // For every membership pattern and start, rotated_into must equal
        // the dense scan order filtered by membership.
        crate::util::prop::forall(128, |rng| {
            let n = 1 + rng.below_usize(32);
            let mut w = WakeList::new(n);
            let mut awake = vec![false; n];
            for id in 0..n {
                if rng.chance(0.4) {
                    w.wake(id);
                    awake[id] = true;
                }
            }
            let start = rng.below_usize(n);
            let mut got = Vec::new();
            w.rotated_into(start, &mut got);
            let want: Vec<usize> = (0..n).map(|k| (start + k) % n).filter(|&i| awake[i]).collect();
            crate::util::prop::ensure(got == want, || {
                format!("n={n} start={start}: got {got:?}, want {want:?}")
            })
        });
    }
}
