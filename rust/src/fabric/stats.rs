//! Fabric-wide statistics: the raw event counts every figure is derived
//! from (performance, utilization, congestion, energy, bandwidth).

use crate::noc::router::{PortStats, NUM_PORTS};

/// Sampling period (cycles) of the windowed time-series in
/// [`FabricStats::series`]. A fixed constant — deliberately *not* a
/// [`crate::trace::TraceConfig`] knob — so the series (and hence the whole
/// stats block) is bit-identical whether tracing is on or off.
pub const SERIES_WINDOW: u64 = 64;

/// One windowed time-series sample: the *cumulative* counters at a window
/// boundary. Consumers derive per-window rates (active-PE fraction, link
/// occupancy, claim rate) by diffing consecutive samples, which keeps the
/// stored sample mode-invariant and cheap.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SeriesSample {
    /// Cycle the sample was taken at (a multiple of [`SERIES_WINDOW`]).
    pub cycle: u64,
    /// Cumulative [`FabricStats::active_pe_cycles`] at that cycle.
    pub active_pe_cycles: u64,
    /// Cumulative [`FabricStats::flit_hops`] (link occupancy numerator).
    pub flit_hops: u64,
    /// Cumulative [`FabricStats::enroute_ops`] (claim-rate numerator).
    pub enroute_ops: u64,
    /// Cumulative [`FabricStats::msgs_retired`] (progress indicator).
    pub msgs_retired: u64,
}

/// Aggregated run statistics for one fabric execution (possibly multi-tile).
/// `PartialEq` lets tests assert that a reset fabric reproduces a fresh
/// fabric's counters bit for bit.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FabricStats {
    /// Total execution cycles (including inter-tile data-load cycles).
    pub cycles: u64,
    /// Cycles spent purely on inter-tile off-chip data loading (§3.3.3:
    /// AM-queue streaming overlaps execution; data-memory loading does not).
    pub load_cycles: u64,
    /// ALU operations (local + en-route). The "useful ops" numerator for
    /// MOPS and utilization.
    pub alu_ops: u64,
    /// ALU operations executed en-route on intermediate PEs (Fig 11 right
    /// axis: % of computations in-network).
    pub enroute_ops: u64,
    /// Memory operations executed by decode units.
    pub mem_ops: u64,
    /// Dynamic AMs emitted by streaming decodes.
    pub stream_emissions: u64,
    /// Static AMs injected.
    pub static_injections: u64,
    /// Total messages that ever existed (conservation checks).
    pub msgs_created: u64,
    /// Messages that completed (died after their terminal op).
    pub msgs_retired: u64,
    /// Flit-hops: router-to-router link traversals (energy + congestion).
    pub flit_hops: u64,
    /// Router buffer writes (energy accounting).
    pub buf_writes: u64,
    /// Data-memory reads/writes.
    pub dmem_reads: u64,
    pub dmem_writes: u64,
    /// Config-memory reads (each message morph/advance).
    pub config_reads: u64,
    /// Scanner operations (stream element decodes, §3.3.4).
    pub scanner_ops: u64,
    /// TIA trigger/tag-match checks (0 for Nexus).
    pub trigger_checks: u64,
    /// Bytes moved over the off-chip AXI interface (AM streams + data
    /// loads + writebacks) — Fig 16's bandwidth numerator.
    pub offchip_bytes: u64,
    /// Per-PE busy-cycle counts: cycles each PE did useful work on any
    /// unit (ALU or decode) — utilization (Fig 13) + load-balance CV.
    pub per_pe_busy_cycles: Vec<u64>,
    /// Per-PE committed operations: ALU ops executed at the PE (local or
    /// en-route claimed) plus decode-unit memory ops. Unlike busy cycles
    /// this excludes stall time entirely, so it is the *work* imbalance
    /// metric the dataset corpus reports ([`FabricStats::op_cv`] /
    /// [`FabricStats::op_max_mean`]). Sums to `alu_ops + mem_ops`.
    pub per_pe_committed_ops: Vec<u64>,
    /// Per-input-port congestion aggregated over all routers (Fig 14),
    /// indexed by port class (NIC, N, E, S, W; ruche skip ports fold onto
    /// their compass heading).
    pub port: [PortStats; NUM_PORTS],
    /// Per-directed-link flit traversals, indexed by
    /// [`crate::noc::topology::link_index`] (source PE × output direction).
    /// Unwired directions stay 0; the topology-sweep bench and the corpus
    /// runner derive hot-link profiles from this.
    pub link_flits: Vec<u64>,
    /// Peak number of link traversals in any single cycle — the
    /// instantaneous bandwidth high-water mark of the whole network.
    pub peak_link_demand: u64,
    /// PE-cycles on which any unit (ALU or decode) latched work at commit
    /// — the fabric-wide running total of the per-PE busy latch, counted
    /// per cycle so time-resolved active fractions can be derived.
    pub active_pe_cycles: u64,
    /// Stall attribution: PE-cycles a PE held a ready message (inbox head
    /// or pending trigger) but launched no operation — waiting on
    /// operands/trigger cooldowns.
    pub stall_operand_cycles: u64,
    /// Stall attribution: PE-cycles a PE had a message ready to inject but
    /// its router's local port refused it (bubble rule / full buffer).
    pub stall_inject_cycles: u64,
    /// Stall attribution: flit-cycles a routed flit won allocation but was
    /// refused by the downstream buffer (On/Off backpressure), plus
    /// stream-emission cycles blocked on a full PE output queue.
    pub stall_backpressure_cycles: u64,
    /// Stall attribution: cycles the off-chip AXI interface still owed
    /// data (`pending_remaining > 0` at the refill phase). Global like
    /// `cycles` — counted once per cycle by the epoch coordinator, never
    /// part of a shard delta.
    pub stall_axi_cycles: u64,
    /// Stall attribution: en-route claim opportunities declined by the
    /// claim policy's gate (credit period not elapsed, occupancy below the
    /// steal threshold) while claimable flits were buffered — claim
    /// contention, in events.
    pub stall_claim_misses: u64,
    /// Windowed time-series: cumulative-counter samples every
    /// [`SERIES_WINDOW`] cycles. Idle windows (no counter movement since
    /// the previous sample) append nothing, so a drained fabric stepping
    /// empty cycles leaves the stats block untouched.
    pub series: Vec<SeriesSample>,
}

impl FabricStats {
    /// Cycles spent executing (total minus off-chip load/writeback phases).
    pub fn compute_cycles(&self) -> u64 {
        self.cycles.saturating_sub(self.load_cycles).max(1)
    }

    /// Fabric utilization in `[0,1]`: mean fraction of *compute* cycles each
    /// PE was busy (ALU or decode unit) — Fig 13's metric. Load phases are
    /// excluded for every architecture alike.
    pub fn utilization(&self) -> f64 {
        let n = self.per_pe_busy_cycles.len();
        if n == 0 || self.cycles == 0 {
            return 0.0;
        }
        let busy: u64 = self.per_pe_busy_cycles.iter().sum();
        (busy as f64 / (n as u64 * self.compute_cycles()) as f64).min(1.0)
    }

    /// Fraction of ALU ops executed in-network (Fig 11 right axis).
    pub fn in_network_fraction(&self) -> f64 {
        if self.alu_ops == 0 {
            0.0
        } else {
            self.enroute_ops as f64 / self.alu_ops as f64
        }
    }

    /// Load-imbalance metric: coefficient of variation of per-PE busy
    /// cycles (0 = perfectly balanced; Fig 3's bottom panels).
    pub fn load_cv(&self) -> f64 {
        let v: Vec<f64> = self.per_pe_busy_cycles.iter().map(|&c| c as f64).collect();
        crate::util::cv(&v)
    }

    /// Work-imbalance metric: coefficient of variation of per-PE committed
    /// operations (0 = every PE committed the same op count). The corpus
    /// acceptance gate: irregular inputs must push this well above the
    /// uniform-random baseline at equal density.
    pub fn op_cv(&self) -> f64 {
        let v: Vec<f64> = self
            .per_pe_committed_ops
            .iter()
            .map(|&c| c as f64)
            .collect();
        crate::util::cv(&v)
    }

    /// Work-imbalance metric: max over mean of per-PE committed operations
    /// (1 = perfectly balanced; 0 when no ops were committed). The "how bad
    /// is the worst PE" companion to [`FabricStats::op_cv`].
    pub fn op_max_mean(&self) -> f64 {
        if self.per_pe_committed_ops.is_empty() {
            return 0.0;
        }
        let total: u64 = self.per_pe_committed_ops.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let mean = total as f64 / self.per_pe_committed_ops.len() as f64;
        let max = *self.per_pe_committed_ops.iter().max().unwrap() as f64;
        max / mean
    }

    /// Useful operations per cycle across the fabric.
    pub fn ops_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            (self.alu_ops + self.mem_ops) as f64 / self.cycles as f64
        }
    }

    /// Throughput in MOPS at the given clock (Table 2).
    pub fn mops(&self, freq_mhz: f64) -> f64 {
        self.ops_per_cycle() * freq_mhz
    }

    /// Average off-chip bandwidth in bytes/cycle actually consumed.
    pub fn avg_offchip_bytes_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.offchip_bytes as f64 / self.cycles as f64
        }
    }

    /// Mean congestion (blocked fraction of occupied cycles) for one port
    /// class — Fig 14's y-axis.
    pub fn port_congestion(&self, port: usize) -> f64 {
        let p = &self.port[port];
        if p.occupied_cycles == 0 {
            0.0
        } else {
            p.blocked_cycles as f64 / p.occupied_cycles as f64
        }
    }

    /// Merge per-router port stats into the aggregate (called at run end).
    pub fn absorb_port(&mut self, port: usize, s: &PortStats) {
        self.port[port].occupied_cycles += s.occupied_cycles;
        self.port[port].blocked_cycles += s.blocked_cycles;
        self.port[port].flits_in += s.flits_in;
    }

    /// Total flit traversals summed over every directed link. Equals
    /// [`FabricStats::flit_hops`] (each hop crosses exactly one link).
    pub fn link_flits_total(&self) -> u64 {
        self.link_flits.iter().sum()
    }

    /// Traffic on the hottest directed link, as `(link index, flits)`;
    /// `None` when no flit crossed any link. Recover the endpoint with
    /// `index / LINKS_PER_PE` (source PE) and
    /// `Dir::from_port(index % LINKS_PER_PE + 1)`.
    pub fn max_link_flits(&self) -> Option<(usize, u64)> {
        self.link_flits
            .iter()
            .copied()
            .enumerate()
            .max_by_key(|&(_, f)| f)
            .filter(|&(_, f)| f > 0)
    }

    /// Total PE-cycles this run (`cycles × PE count`): the denominator
    /// for the active fraction and the stall-attribution percentages.
    pub fn total_pe_cycles(&self) -> u64 {
        self.cycles
            .saturating_mul(self.per_pe_busy_cycles.len() as u64)
    }

    /// Time-averaged fraction of PEs doing useful work per cycle, from
    /// the always-on [`FabricStats::active_pe_cycles`] counter.
    pub fn active_pe_fraction(&self) -> f64 {
        let total = self.total_pe_cycles();
        if total == 0 {
            0.0
        } else {
            self.active_pe_cycles as f64 / total as f64
        }
    }

    /// Stall-attribution breakdown as fractions of total PE-cycles, in
    /// report order: operand wait, inject/buffer backpressure, AXI refill,
    /// claim contention. (Claim contention counts *events*, the others
    /// count PE- or flit-cycles; all are normalized by PE-cycles so the
    /// classes are comparable across runs.)
    pub fn stall_fractions(&self) -> [(&'static str, f64); 4] {
        let total = self.total_pe_cycles().max(1) as f64;
        [
            ("operand", self.stall_operand_cycles as f64 / total),
            (
                "backpressure",
                (self.stall_inject_cycles + self.stall_backpressure_cycles) as f64 / total,
            ),
            ("axi", self.stall_axi_cycles as f64 / total),
            ("claim", self.stall_claim_misses as f64 / total),
        ]
    }

    /// Append a windowed time-series sample at `cycle` unless nothing
    /// moved since the previous sample (idle windows — including every
    /// post-drain cycle — must leave the stats block untouched).
    pub fn sample_series(&mut self, cycle: u64) {
        let s = SeriesSample {
            cycle,
            active_pe_cycles: self.active_pe_cycles,
            flit_hops: self.flit_hops,
            enroute_ops: self.enroute_ops,
            msgs_retired: self.msgs_retired,
        };
        let moved = |last: &SeriesSample| {
            last.active_pe_cycles != s.active_pe_cycles
                || last.flit_hops != s.flit_hops
                || last.enroute_ops != s.enroute_ops
                || last.msgs_retired != s.msgs_retired
        };
        match self.series.last() {
            Some(last) if moved(last) => self.series.push(s),
            // First sample: suppressed while every counter is still zero.
            None if moved(&SeriesSample::default()) => self.series.push(s),
            _ => {}
        }
    }

    /// Fold a per-shard statistics *delta* into this aggregate. Every
    /// additive event counter is summed; the globally-derived fields are
    /// deliberately left untouched: `cycles` and `load_cycles` advance once
    /// per epoch in the fabric's top-level loop, and `peak_link_demand` is
    /// a max over *whole-fabric* per-cycle demand, computed at the epoch
    /// barrier from the sum of per-shard demand counters (a per-shard max
    /// would undercount cycles where the peak straddles shards). Vector
    /// fields add elementwise, growing to fit.
    pub fn merge_delta(&mut self, d: &FabricStats) {
        self.alu_ops += d.alu_ops;
        self.enroute_ops += d.enroute_ops;
        self.mem_ops += d.mem_ops;
        self.stream_emissions += d.stream_emissions;
        self.static_injections += d.static_injections;
        self.msgs_created += d.msgs_created;
        self.msgs_retired += d.msgs_retired;
        self.flit_hops += d.flit_hops;
        self.buf_writes += d.buf_writes;
        self.dmem_reads += d.dmem_reads;
        self.dmem_writes += d.dmem_writes;
        self.config_reads += d.config_reads;
        self.scanner_ops += d.scanner_ops;
        self.trigger_checks += d.trigger_checks;
        self.offchip_bytes += d.offchip_bytes;
        self.active_pe_cycles += d.active_pe_cycles;
        self.stall_operand_cycles += d.stall_operand_cycles;
        self.stall_inject_cycles += d.stall_inject_cycles;
        self.stall_backpressure_cycles += d.stall_backpressure_cycles;
        self.stall_claim_misses += d.stall_claim_misses;
        // `stall_axi_cycles` is global (coordinator-counted, like
        // `cycles`); `series` is appended by the epoch coordinator only.
        for (p, s) in d.port.iter().enumerate() {
            self.absorb_port(p, s);
        }
        add_elementwise(&mut self.per_pe_busy_cycles, &d.per_pe_busy_cycles);
        add_elementwise(&mut self.per_pe_committed_ops, &d.per_pe_committed_ops);
        add_elementwise(&mut self.link_flits, &d.link_flits);
    }

    /// Field-by-field comparison: `None` when equal, otherwise the name and
    /// values of the first differing field. The step-equivalence property
    /// suite uses this so a scheduler divergence names the exact counter
    /// that split (e.g. `flit_hops: 120 vs 118`) instead of dumping two
    /// whole structs.
    pub fn diff(&self, other: &FabricStats) -> Option<String> {
        macro_rules! check {
            ($field:ident) => {
                if self.$field != other.$field {
                    return Some(format!(
                        "{}: {:?} vs {:?}",
                        stringify!($field),
                        self.$field,
                        other.$field
                    ));
                }
            };
        }
        check!(cycles);
        check!(load_cycles);
        check!(alu_ops);
        check!(enroute_ops);
        check!(mem_ops);
        check!(stream_emissions);
        check!(static_injections);
        check!(msgs_created);
        check!(msgs_retired);
        check!(flit_hops);
        check!(buf_writes);
        check!(dmem_reads);
        check!(dmem_writes);
        check!(config_reads);
        check!(scanner_ops);
        check!(trigger_checks);
        check!(offchip_bytes);
        check!(per_pe_busy_cycles);
        check!(per_pe_committed_ops);
        check!(port);
        check!(link_flits);
        check!(peak_link_demand);
        check!(active_pe_cycles);
        check!(stall_operand_cycles);
        check!(stall_inject_cycles);
        check!(stall_backpressure_cycles);
        check!(stall_axi_cycles);
        check!(stall_claim_misses);
        check!(series);
        // Guard against the field list above going stale: if the structs
        // still differ, a counter was added to FabricStats without a
        // matching check! — fail loudly instead of reporting equality.
        if self != other {
            return Some("field not covered by FabricStats::diff — update the check! list".into());
        }
        None
    }
}

/// `dst[i] += src[i]`, growing `dst` with zeros when `src` is longer.
fn add_elementwise(dst: &mut Vec<u64>, src: &[u64]) {
    if dst.len() < src.len() {
        dst.resize(src.len(), 0);
    }
    for (a, b) in dst.iter_mut().zip(src) {
        *a += *b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_bounds() {
        let mut s = FabricStats::default();
        s.cycles = 100;
        s.per_pe_busy_cycles = vec![50, 100, 0, 50];
        let u = s.utilization();
        assert!((u - 0.5).abs() < 1e-12);
    }

    #[test]
    fn in_network_fraction_zero_when_no_ops() {
        let s = FabricStats::default();
        assert_eq!(s.in_network_fraction(), 0.0);
    }

    #[test]
    fn diff_names_the_first_differing_field() {
        let mut a = FabricStats::default();
        let b = FabricStats::default();
        assert_eq!(a.diff(&b), None);
        a.flit_hops = 7;
        let d = a.diff(&b).expect("must differ");
        assert!(d.contains("flit_hops") && d.contains('7'), "{d}");
        // diff is consistent with PartialEq.
        assert_ne!(a, b);
    }

    #[test]
    fn op_imbalance_metrics() {
        let mut s = FabricStats::default();
        assert_eq!(s.op_cv(), 0.0);
        assert_eq!(s.op_max_mean(), 0.0);
        s.per_pe_committed_ops = vec![10, 10, 10, 10];
        assert_eq!(s.op_cv(), 0.0);
        assert!((s.op_max_mean() - 1.0).abs() < 1e-12);
        s.per_pe_committed_ops = vec![40, 0, 0, 0];
        // mean 10, sd sqrt(300) ~ 17.32 -> cv ~ 1.732; max/mean = 4.
        assert!((s.op_cv() - 3.0f64.sqrt()).abs() < 1e-9, "{}", s.op_cv());
        assert!((s.op_max_mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn link_stat_helpers() {
        let mut s = FabricStats::default();
        assert_eq!(s.link_flits_total(), 0);
        assert_eq!(s.max_link_flits(), None);
        s.link_flits = vec![0, 3, 0, 9, 9, 0];
        assert_eq!(s.link_flits_total(), 21);
        // Ties resolve to the last index (max_by_key keeps later maxima).
        assert_eq!(s.max_link_flits(), Some((4, 9)));
        // diff covers the new fields.
        let d = s.diff(&FabricStats::default()).expect("must differ");
        assert!(d.contains("link_flits"), "{d}");
        let p = FabricStats { peak_link_demand: 5, ..FabricStats::default() };
        let d = p.diff(&FabricStats::default()).expect("must differ");
        assert!(d.contains("peak_link_demand"), "{d}");
    }

    #[test]
    fn merge_delta_sums_counters_but_not_global_fields() {
        let mut agg = FabricStats {
            cycles: 100,
            load_cycles: 10,
            alu_ops: 5,
            peak_link_demand: 7,
            per_pe_busy_cycles: vec![1, 2],
            ..FabricStats::default()
        };
        let mut d = FabricStats {
            alu_ops: 3,
            flit_hops: 9,
            offchip_bytes: 18,
            // A shard delta may carry these, but merging must not touch
            // the aggregate's globally-derived fields.
            cycles: 999,
            load_cycles: 999,
            peak_link_demand: 999,
            per_pe_busy_cycles: vec![10, 10, 10],
            link_flits: vec![4, 0, 4],
            ..FabricStats::default()
        };
        d.port[1].flits_in = 6;
        agg.merge_delta(&d);
        assert_eq!(agg.cycles, 100);
        assert_eq!(agg.load_cycles, 10);
        assert_eq!(agg.peak_link_demand, 7);
        assert_eq!(agg.alu_ops, 8);
        assert_eq!(agg.flit_hops, 9);
        assert_eq!(agg.offchip_bytes, 18);
        assert_eq!(agg.port[1].flits_in, 6);
        assert_eq!(agg.per_pe_busy_cycles, vec![11, 12, 10]);
        assert_eq!(agg.link_flits, vec![4, 0, 4]);
        // Merging a default delta is a no-op.
        let before = agg.clone();
        agg.merge_delta(&FabricStats::default());
        assert_eq!(agg, before);
    }

    #[test]
    fn series_sampling_skips_idle_windows() {
        let mut s = FabricStats::default();
        // Nothing has moved: the very first sample is suppressed too.
        s.sample_series(64);
        assert!(s.series.is_empty());
        s.active_pe_cycles = 10;
        s.flit_hops = 3;
        s.sample_series(128);
        assert_eq!(s.series.len(), 1);
        assert_eq!(s.series[0].cycle, 128);
        // An idle window (no counter movement) appends nothing.
        s.sample_series(192);
        assert_eq!(s.series.len(), 1);
        s.msgs_retired = 1;
        s.sample_series(256);
        assert_eq!(s.series.len(), 2);
        assert_eq!(s.series[1].msgs_retired, 1);
    }

    #[test]
    fn stall_counters_merge_and_diff() {
        let mut agg = FabricStats::default();
        let d = FabricStats {
            active_pe_cycles: 4,
            stall_operand_cycles: 1,
            stall_inject_cycles: 2,
            stall_backpressure_cycles: 3,
            stall_claim_misses: 5,
            // Global: a delta must never move it through merge.
            stall_axi_cycles: 99,
            ..FabricStats::default()
        };
        agg.merge_delta(&d);
        assert_eq!(agg.active_pe_cycles, 4);
        assert_eq!(agg.stall_operand_cycles, 1);
        assert_eq!(agg.stall_inject_cycles, 2);
        assert_eq!(agg.stall_backpressure_cycles, 3);
        assert_eq!(agg.stall_claim_misses, 5);
        assert_eq!(agg.stall_axi_cycles, 0);
        // diff names each new field.
        let named = agg.diff(&FabricStats::default()).expect("must differ");
        assert!(named.contains("active_pe_cycles"), "{named}");
        let mut s = FabricStats::default();
        s.series.push(SeriesSample { cycle: 64, ..SeriesSample::default() });
        let named = s.diff(&FabricStats::default()).expect("must differ");
        assert!(named.contains("series"), "{named}");
    }

    #[test]
    fn stall_fractions_normalize_by_pe_cycles() {
        let mut s = FabricStats::default();
        s.cycles = 100;
        s.per_pe_busy_cycles = vec![0; 4]; // 400 PE-cycles
        s.active_pe_cycles = 100;
        s.stall_operand_cycles = 40;
        s.stall_inject_cycles = 10;
        s.stall_backpressure_cycles = 30;
        s.stall_axi_cycles = 20;
        s.stall_claim_misses = 4;
        assert!((s.active_pe_fraction() - 0.25).abs() < 1e-12);
        let f = s.stall_fractions();
        assert_eq!(f[0].0, "operand");
        assert!((f[0].1 - 0.10).abs() < 1e-12);
        assert!((f[1].1 - 0.10).abs() < 1e-12);
        assert!((f[2].1 - 0.05).abs() < 1e-12);
        assert!((f[3].1 - 0.01).abs() < 1e-12);
    }

    #[test]
    fn mops_scales_with_frequency() {
        let mut s = FabricStats::default();
        s.cycles = 1000;
        s.alu_ops = 500;
        s.mem_ops = 500;
        assert!((s.mops(588.0) - 588.0).abs() < 1e-9);
    }
}
